//! FxHash — the rustc-internal multiplicative hasher. The FAQ engine hashes
//! millions of short `u64`-tuple keys; SipHash (std default) costs ~3× more
//! on this workload, and HashDoS resistance is irrelevant for an analytics
//! engine processing its own synthetic data.

use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// Multiplicative hasher compatible with `Hasher`.
#[derive(Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, i: u64) {
        self.hash = (self.hash.rotate_left(5) ^ i).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add_to_hash(u64::from_le_bytes(c.try_into().expect("8-byte chunk")));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rem.len()].copy_from_slice(rem);
            self.add_to_hash(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.add_to_hash(i);
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }
}

/// `HashMap` with FxHash.
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, BuildHasherDefault<FxHasher>>;
/// `HashSet` with FxHash.
pub type FxHashSet<K> = std::collections::HashSet<K, BuildHasherDefault<FxHasher>>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_works_and_is_deterministic() {
        let mut m: FxHashMap<Vec<u64>, f64> = FxHashMap::default();
        m.insert(vec![1, 2, 3], 1.5);
        m.insert(vec![1, 2, 4], 2.5);
        *m.entry(vec![1, 2, 3]).or_insert(0.0) += 1.0;
        assert_eq!(m[&vec![1, 2, 3]], 2.5);
        assert_eq!(m.len(), 2);
    }

    #[test]
    fn hash_differs_for_different_keys() {
        use std::hash::Hash;
        let h = |k: &[u64]| {
            let mut hasher = FxHasher::default();
            k.hash(&mut hasher);
            hasher.finish()
        };
        assert_ne!(h(&[1, 2]), h(&[2, 1]));
        assert_ne!(h(&[0]), h(&[]));
    }
}
