//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt`, built once
//! by `make artifacts`) and run the Step-4 Lloyd hot path from rust. Python
//! is never on this path — the HLO text was produced at build time by
//! `python/compile/aot.py` and is compiled here by the XLA CPU client.
//!
//! Shape buckets: the manifest lists `lloyd_step_{N}x{D}x{K}` artifacts;
//! [`PjrtRuntime::lloyd`] picks the smallest bucket that fits, pads points
//! with zero-weight rows (exact no-ops for weighted Lloyd), pads dims with
//! zero columns, and pads centroids at the `1e15` sentinel (never wins an
//! argmin; `counts == 0` keeps it in place). The padding contract is
//! enforced by `python/tests/test_model.py::test_padding_contract` on the
//! python side and `padding_invariance` here.

use crate::cluster::{kmeanspp_indices, LloydConfig, LloydResult};
use crate::util::json::{self, Json};
use crate::util::SplitMix64;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Sentinel coordinate for padded centroids (squared distances ~1e30 always
/// lose the argmin against real centroids).
pub const PAD_CENTROID: f32 = 1e15;

/// One AOT artifact from the manifest.
#[derive(Clone, Debug)]
pub struct Bucket {
    pub file: String,
    pub entry: String,
    pub n: usize,
    pub d: usize,
    pub k: usize,
    /// Estimated VMEM bytes per kernel grid step (reporting only).
    pub vmem_bytes: u64,
}

/// PJRT CPU runtime with a compiled-executable cache.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    buckets: Vec<Bucket>,
    cache: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl PjrtRuntime {
    /// Default artifacts directory (`$RKMEANS_ARTIFACTS` or `./artifacts`).
    pub fn default_dir() -> PathBuf {
        std::env::var("RKMEANS_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Load the manifest and initialize the PJRT CPU client.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("read {} (run `make artifacts`)", manifest_path.display()))?;
        let doc = json::parse(&text).context("parse manifest.json")?;
        let version = doc.get("version").and_then(Json::as_usize).unwrap_or(0);
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let mut buckets = Vec::new();
        for a in doc
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
        {
            buckets.push(Bucket {
                file: a.get("file").and_then(Json::as_str).unwrap_or_default().to_string(),
                entry: a.get("entry").and_then(Json::as_str).unwrap_or_default().to_string(),
                n: a.get("n").and_then(Json::as_usize).unwrap_or(0),
                d: a.get("d").and_then(Json::as_usize).unwrap_or(0),
                k: a.get("k").and_then(Json::as_usize).unwrap_or(0),
                vmem_bytes: a.get("vmem_bytes").and_then(Json::as_f64).unwrap_or(0.0) as u64,
            });
        }
        if buckets.is_empty() {
            bail!("manifest has no artifacts");
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(PjrtRuntime {
            client,
            dir: dir.to_path_buf(),
            buckets,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// True if an artifacts directory with a manifest exists.
    pub fn available(dir: &Path) -> bool {
        dir.join("manifest.json").exists()
    }

    /// The manifest buckets.
    pub fn buckets(&self) -> &[Bucket] {
        &self.buckets
    }

    /// Smallest `lloyd_step` bucket fitting `(n, d, k)`.
    pub fn pick_bucket(&self, n: usize, d: usize, k: usize) -> Option<&Bucket> {
        self.buckets
            .iter()
            .filter(|b| b.entry == "lloyd_step" && b.n >= n && b.d >= d && b.k >= k)
            .min_by_key(|b| (b.n, b.d, b.k))
    }

    /// Compile (or fetch from cache) the executable for a bucket.
    fn ensure_compiled(&self, bucket: &Bucket) -> Result<()> {
        let mut cache = self.cache.lock().expect("runtime cache lock");
        if cache.contains_key(&bucket.file) {
            return Ok(());
        }
        let path = self.dir.join(&bucket.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe =
            self.client.compile(&comp).map_err(|e| anyhow!("compile {}: {e}", bucket.file))?;
        cache.insert(bucket.file.clone(), exe);
        Ok(())
    }

    /// Execute one padded Lloyd step on a bucket. Buffers use the padded
    /// bucket sizes. Returns (new_centroids, counts, objective).
    pub fn run_step(
        &self,
        bucket: &Bucket,
        points: &[f32],
        weights: &[f32],
        centroids: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        debug_assert_eq!(points.len(), bucket.n * bucket.d);
        debug_assert_eq!(weights.len(), bucket.n);
        debug_assert_eq!(centroids.len(), bucket.k * bucket.d);
        self.ensure_compiled(bucket)?;
        let cache = self.cache.lock().expect("runtime cache lock");
        let exe = cache.get(&bucket.file).expect("just compiled");

        let x = xla::Literal::vec1(points)
            .reshape(&[bucket.n as i64, bucket.d as i64])
            .map_err(|e| anyhow!("reshape points: {e}"))?;
        let w = xla::Literal::vec1(weights);
        let c = xla::Literal::vec1(centroids)
            .reshape(&[bucket.k as i64, bucket.d as i64])
            .map_err(|e| anyhow!("reshape centroids: {e}"))?;

        let result =
            exe.execute::<xla::Literal>(&[x, w, c]).map_err(|e| anyhow!("execute: {e}"))?;
        let out = result[0][0].to_literal_sync().map_err(|e| anyhow!("to_literal: {e}"))?;
        let (new_c, counts, obj) =
            out.to_tuple3().map_err(|e| anyhow!("expected 3-tuple output: {e}"))?;
        Ok((
            new_c.to_vec::<f32>().map_err(|e| anyhow!("read centroids: {e}"))?,
            counts.to_vec::<f32>().map_err(|e| anyhow!("read counts: {e}"))?,
            obj.to_vec::<f32>().map_err(|e| anyhow!("read objective: {e}"))?[0],
        ))
    }

    /// Full weighted Lloyd via the AOT artifact: host-side k-means++
    /// seeding and empty-cluster reseeding, device-side assignment +
    /// update. Drop-in replacement for
    /// [`crate::cluster::weighted_lloyd`] (f64 in/out).
    pub fn lloyd(
        &self,
        points: &[f64],
        weights: &[f64],
        d: usize,
        cfg: &LloydConfig,
    ) -> Result<LloydResult> {
        assert!(d > 0 && points.len() % d == 0);
        let n = points.len() / d;
        assert_eq!(weights.len(), n);
        let k = cfg.k.min(n);
        let bucket = self
            .pick_bucket(n, d, k)
            .ok_or_else(|| anyhow!("no artifact bucket fits n={n} d={d} k={k}"))?
            .clone();

        // Pad points / weights once.
        let mut px = vec![0.0f32; bucket.n * bucket.d];
        for i in 0..n {
            for j in 0..d {
                px[i * bucket.d + j] = points[i * d + j] as f32;
            }
        }
        let mut pw = vec![0.0f32; bucket.n];
        for i in 0..n {
            pw[i] = weights[i] as f32;
        }

        // Host-side k-means++ seeding (same seeding as the native engine).
        let mut rng = SplitMix64::new(cfg.seed);
        let row = |i: usize| &points[i * d..(i + 1) * d];
        let dist2 =
            |a: &[f64], b: &[f64]| -> f64 { a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum() };
        let seeds = kmeanspp_indices(n, weights, k, &mut rng, |i, j| dist2(row(i), row(j)));
        let mut pc = vec![PAD_CENTROID; bucket.k * bucket.d];
        for (c, &s) in seeds.iter().enumerate() {
            for j in 0..d {
                pc[c * bucket.d + j] = points[s * d + j] as f32;
            }
            for j in d..bucket.d {
                pc[c * bucket.d + j] = 0.0;
            }
        }

        let mut objective = f64::INFINITY;
        let mut iters = 0;
        for it in 0..cfg.max_iters.max(1) {
            iters = it + 1;
            let (new_c, counts, obj) = self.run_step(&bucket, &px, &pw, &pc)?;
            pc = new_c;
            // Host-side empty-cluster reseed: place at the heaviest point.
            for c in 0..k {
                if counts[c] == 0.0 {
                    let far = (0..n)
                        .max_by(|&a, &b| pw[a].partial_cmp(&pw[b]).expect("finite"))
                        .expect("n > 0");
                    for j in 0..bucket.d {
                        pc[c * bucket.d + j] = px[far * bucket.d + j];
                    }
                }
            }
            let obj = obj as f64;
            if objective.is_finite()
                && ((objective - obj) / objective.abs().max(1e-30)).abs() < cfg.tol
            {
                break;
            }
            objective = obj;
        }

        // Unpad centroids; recompute exact assignment host-side in f64.
        let mut centroids = vec![0.0f64; k * d];
        for c in 0..k {
            for j in 0..d {
                centroids[c * d + j] = pc[c * bucket.d + j] as f64;
            }
        }
        let mut assign = vec![0u32; n];
        let mut final_obj = 0.0;
        for i in 0..n {
            let x = row(i);
            let (mut best, mut bc) = (f64::INFINITY, 0u32);
            for c in 0..k {
                let s = dist2(x, &centroids[c * d..(c + 1) * d]);
                if s < best {
                    best = s;
                    bc = c as u32;
                }
            }
            assign[i] = bc;
            final_obj += weights[i] * best;
        }
        Ok(LloydResult { centroids, assign, objective: final_obj, iters })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::weighted_lloyd;
    use crate::util::testkit::assert_close;

    fn runtime() -> Option<PjrtRuntime> {
        let dir = PjrtRuntime::default_dir();
        if !PjrtRuntime::available(&dir) {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return None;
        }
        Some(PjrtRuntime::load(&dir).expect("load runtime"))
    }

    fn blobs(n_per: usize, centers: &[(f64, f64)], seed: u64) -> (Vec<f64>, Vec<f64>) {
        let mut rng = SplitMix64::new(seed);
        let mut pts = Vec::new();
        for &(cx, cy) in centers {
            for _ in 0..n_per {
                pts.push(cx + 0.05 * rng.normal());
                pts.push(cy + 0.05 * rng.normal());
            }
        }
        let w = vec![1.0; pts.len() / 2];
        (pts, w)
    }

    #[test]
    fn manifest_loads_and_picks_buckets() {
        let Some(rt) = runtime() else { return };
        assert!(!rt.buckets().is_empty());
        let b = rt.pick_bucket(1000, 8, 8).expect("bucket");
        assert!(b.n >= 1000 && b.d >= 8 && b.k >= 8);
        // Too-large requests get None.
        assert!(rt.pick_bucket(10_000_000, 8, 8).is_none());
    }

    #[test]
    fn xla_lloyd_matches_native_engine() {
        let Some(rt) = runtime() else { return };
        let (pts, w) = blobs(100, &[(0.0, 0.0), (10.0, 0.0), (0.0, 10.0)], 5);
        let cfg = LloydConfig::new(3);
        let native = weighted_lloyd(&pts, &w, 2, &cfg);
        let xla = rt.lloyd(&pts, &w, 2, &cfg).expect("xla lloyd");
        // Same seeding, same update rule: objectives agree to f32 noise.
        assert_close(native.objective, xla.objective, 1e-3);
        assert_eq!(native.assign, xla.assign);
    }

    #[test]
    fn padding_invariance() {
        // Bucket padding must not change the answer.
        let Some(rt) = runtime() else { return };
        let (pts, w) = blobs(60, &[(0.0, 0.0), (5.0, 5.0)], 6);
        let cfg = LloydConfig::new(2);
        let r = rt.lloyd(&pts, &w, 2, &cfg).expect("xla lloyd");
        let native = weighted_lloyd(&pts, &w, 2, &cfg);
        assert_close(r.objective, native.objective, 1e-3);
    }

    #[test]
    fn weighted_points_respected() {
        let Some(rt) = runtime() else { return };
        // One heavy point at 0, one light at 1; k=1 centroid at 0.1.
        let pts = vec![0.0, 0.0, 1.0, 0.0];
        let w = vec![9.0, 1.0];
        let cfg = LloydConfig { k: 1, ..LloydConfig::new(1) };
        let r = rt.lloyd(&pts, &w, 2, &cfg).expect("xla lloyd");
        assert_close(r.centroids[0], 0.1, 1e-3);
    }
}
