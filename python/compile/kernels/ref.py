"""Pure-jnp correctness oracle for the Lloyd kernels.

Everything here is deliberately naive and dependency-free: the pytest suite
asserts the Pallas kernel and the L2 model match these references
bit-closely, which is the core correctness signal of the compile path.
"""

from __future__ import annotations

import jax.numpy as jnp


def assign_ref(points, centroids):
    """Nearest-centroid assignment by explicit pairwise distances.

    points: [N, D]; centroids: [K, D].
    Returns (assign [N] i32, min_sq_dist [N] f32).
    """
    # [N, K, D] -> [N, K] squared distances; no algebraic expansion so it
    # is a genuinely independent computation from the kernel.
    diff = points[:, None, :] - centroids[None, :, :]
    d2 = jnp.sum(diff * diff, axis=-1)
    assign = jnp.argmin(d2, axis=1).astype(jnp.int32)
    mind = jnp.min(d2, axis=1)
    return assign, mind.astype(points.dtype)


def lloyd_step_ref(points, weights, centroids):
    """One full weighted Lloyd step.

    Returns (new_centroids [K, D], counts [K], objective scalar).
    Empty clusters keep their previous centroid (the rust host reseeds).
    """
    k = centroids.shape[0]
    assign, mind = assign_ref(points, centroids)
    onehot = (assign[:, None] == jnp.arange(k)[None, :]).astype(points.dtype)
    woh = onehot * weights[:, None]
    sums = woh.T @ points
    counts = woh.sum(axis=0)
    obj = jnp.sum(weights * mind)
    new_c = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts, 1e-30)[:, None], centroids)
    return new_c, counts, obj


def lloyd_iterate_ref(points, weights, centroids, iters: int):
    """Run ``iters`` reference Lloyd steps (python loop)."""
    c = centroids
    counts = None
    obj = None
    for _ in range(iters):
        c, counts, obj = lloyd_step_ref(points, weights, c)
    return c, counts, obj


def objective_ref(points, weights, centroids):
    """Weighted k-means objective of fixed centroids."""
    _, mind = assign_ref(points, centroids)
    return jnp.sum(weights * mind)
