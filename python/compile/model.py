"""Layer 2 — the JAX compute graph for the Step-4 hot path.

``lloyd_step`` performs one weighted Lloyd iteration over a dense coreset
embedding, calling the Layer-1 Pallas kernel for the distance/argmin part
and doing the weighted segment-sum as a one-hot matmul (which XLA fuses
into two GEMMs). ``lloyd_sweep`` runs a fixed number of steps under
``lax.scan`` so the whole sweep is a single compiled artifact.

These functions are lowered ONCE per shape bucket by :mod:`compile.aot`
into ``artifacts/*.hlo.txt`` and executed from rust via PJRT — python is
never on the clustering path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import lloyd as kernels


def lloyd_step(points, weights, centroids):
    """One weighted Lloyd iteration.

    points: [N, D] f32; weights: [N] f32; centroids: [K, D] f32.
    Returns (new_centroids [K, D], counts [K], objective []).

    Padding contract with the rust runtime: pad rows carry weight 0 (they
    cannot move centroids or the objective) and pad centroids sit at the
    1e15 sentinel (they never win an argmin; with count 0 they are kept
    as-is by the `where`).
    """
    k = centroids.shape[0]
    assign, mind = kernels.assign(points, centroids)
    onehot = (assign[:, None] == jnp.arange(k, dtype=jnp.int32)[None, :]).astype(points.dtype)
    woh = onehot * weights[:, None]
    sums = jnp.dot(woh.T, points, preferred_element_type=jnp.float32)
    counts = jnp.sum(woh, axis=0)
    obj = jnp.sum(weights * mind)
    new_c = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts, 1e-30)[:, None], centroids)
    return new_c, counts, obj


def lloyd_sweep(points, weights, centroids, iters: int):
    """``iters`` Lloyd steps under ``lax.scan`` (one artifact, T updates).

    Returns (final_centroids, final_counts, objective_trace [iters]).
    """

    def body(c, _):
        new_c, counts, obj = lloyd_step(points, weights, c)
        return new_c, (counts, obj)

    final_c, (counts_t, obj_t) = jax.lax.scan(body, centroids, None, length=iters)
    return final_c, counts_t[-1], obj_t


def assign_only(points, centroids):
    """Assignment + distances (used to score fixed centroids from rust)."""
    return kernels.assign(points, centroids)
