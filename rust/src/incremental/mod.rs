//! Incremental coreset maintenance: turn the coordinator's "recompute
//! everything" loop into true delta maintenance.
//!
//! The streaming [`crate::coordinator`] makes re-clustering affordable by
//! re-running all of Rk-means in `Õ(|D|)` per batch — but a batch of
//! `b ≪ |D|` tuple inserts/deletes perturbs only `O(b)` join-tree
//! messages and marginal entries, so even `Õ(|D|)` per batch is the wrong
//! asymptotic at production ingest rates. This subsystem maintains the
//! pipeline's state under updates instead:
//!
//! * [`deltafaq`] — the paper's Step 3 (Eq. 4) is a **counting** FAQ, and
//!   counts live in the ring ℤ. In a ring every element has an additive
//!   inverse, so a *deletion is just an insert with negative weight*: the
//!   same message-passing algebra that sums tuple contributions also
//!   cancels them exactly. [`DeltaFaq`] keeps every InsideOut message
//!   alive (plus a separator-key index per node) and propagates only the
//!   affected keys up the join tree, yielding a patched sparse grid whose
//!   zero cells are dropped and whose weights are asserted non-negative
//!   at the root. On integer-weighted databases the patched grid is
//!   **bitwise identical** to a from-scratch `grid_weights` pass.
//! * [`marginal`] — mergeable per-attribute sketches (exact counting
//!   multiset for categorical features, a sorted-run summary for
//!   continuous ones) with a Wasserstein/TV drift trigger. Step-2 gid
//!   maps stay frozen — which is what keeps the Step-3 delta exact —
//!   until a subspace's marginal has genuinely moved.
//! * [`sharded`] — shard-parallel Step 3: per-shard [`DeltaFaq`]
//!   instances over the value-hashed fact partition
//!   ([`crate::faq::shard`]), patched as independent jobs on the shared
//!   worker pool and merged at the root by exact ring-ℤ weight addition,
//!   with one composed splice log keeping the carried Step-4 state
//!   aligned with the merged grid.
//! * [`planner`] — decides per batch between *patch* (Step-3 delta +
//!   Step-4 warm start from the previous centroids) and *rebuild* (the
//!   full pipeline), records the decision and estimated savings in
//!   [`crate::metrics::Metrics`], and exposes the [`IncrementalState`]
//!   snapshot/restore API so serving stays versioned.
//!
//! The deletion-as-negative-weight trick and the mergeable-summary shape
//! follow the relational-coreset line (Chen et al. 2022, Moseley et al.
//! 2020 — see PAPERS.md); the message-passing substrate is the paper's
//! own §4.3 FAQ.

pub mod deltafaq;
pub mod marginal;
pub mod planner;
pub mod sharded;

pub use deltafaq::{DeltaFaq, PatchStats, SpillStats};
pub use marginal::{CatSketch, ContSketch, MarginalTracker};
pub use planner::{
    assigner_map, EpochPatch, IncrementalEngine, IncrementalState, PlanDecision, PlannerOpts,
    RebuildReason,
};
pub use sharded::{AssignerMap, DeltaLayer, ShardedDeltaFaq};

use crate::data::{Database, Value};
use anyhow::{ensure, Result};

/// One tuple insert (positive `weight`) or delete (negative `weight`)
/// against a base relation. The Step-3 FAQ is a ring-ℤ aggregate, so both
/// directions flow through the identical delta algebra.
#[derive(Clone, Debug)]
pub struct TupleDelta {
    /// Target base relation.
    pub relation: String,
    /// Full tuple values in schema order.
    pub values: Vec<Value>,
    /// Signed multiplicity: `+1` insert, `-1` delete, `±w` weighted.
    pub weight: f64,
}

impl TupleDelta {
    /// A unit-weight insert.
    pub fn insert(relation: &str, values: Vec<Value>) -> TupleDelta {
        TupleDelta { relation: relation.to_string(), values, weight: 1.0 }
    }

    /// A unit-weight delete (negative-weight insert).
    pub fn delete(relation: &str, values: Vec<Value>) -> TupleDelta {
        TupleDelta { relation: relation.to_string(), values, weight: -1.0 }
    }

    /// True for deletions.
    pub fn is_delete(&self) -> bool {
        self.weight < 0.0
    }
}

/// Mirror a delta batch onto the base relations themselves: inserts are
/// appended, deletes retract multiplicity via
/// [`Relation::retract_row`](crate::data::Relation::retract_row). Keeping
/// the database in lock-step with the delta state is what lets the
/// planner fall back to a full rebuild at any batch boundary.
pub fn apply_to_db(db: &mut Database, deltas: &[TupleDelta]) -> Result<()> {
    for d in deltas {
        let rel = match db.get_mut(&d.relation) {
            Some(rel) => rel,
            None => anyhow::bail!("delta references unknown relation {:?}", d.relation),
        };
        if d.weight > 0.0 {
            if d.weight == 1.0 {
                rel.push_row(&d.values);
            } else {
                rel.push_row_weighted(&d.values, d.weight);
            }
        } else {
            ensure!(
                rel.retract_row(&d.values, -d.weight),
                "cannot retract {:?} from {:?}: no matching tuple with enough multiplicity",
                d.values,
                d.relation
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Attr, Relation, Schema};

    #[test]
    fn apply_to_db_inserts_and_retracts() {
        let mut rel =
            Relation::new("t", Schema::new(vec![Attr::cat("a", 4), Attr::double("x")]));
        rel.push_row(&[Value::Cat(0), Value::Double(1.0)]);
        let mut db = Database::new();
        db.add(rel);

        let deltas = vec![
            TupleDelta::insert("t", vec![Value::Cat(1), Value::Double(2.0)]),
            TupleDelta {
                relation: "t".into(),
                values: vec![Value::Cat(2), Value::Double(3.0)],
                weight: 2.0,
            },
            TupleDelta::delete("t", vec![Value::Cat(0), Value::Double(1.0)]),
        ];
        apply_to_db(&mut db, &deltas).unwrap();
        let t = db.get("t").unwrap();
        assert_eq!(t.n_rows(), 3);
        assert_eq!(t.weight(0), 0.0); // retracted in place
        assert_eq!(t.weight(2), 2.0);

        // Deleting something that is not there is an error.
        let bad = vec![TupleDelta::delete("t", vec![Value::Cat(3), Value::Double(9.0)])];
        assert!(apply_to_db(&mut db, &bad).is_err());
        assert!(apply_to_db(&mut db, &[TupleDelta::insert("nope", vec![])]).is_err());
    }

    #[test]
    fn delta_constructors() {
        let i = TupleDelta::insert("r", vec![Value::Cat(0)]);
        let d = TupleDelta::delete("r", vec![Value::Cat(0)]);
        assert!(!i.is_delete());
        assert!(d.is_delete());
        assert_eq!(i.weight, 1.0);
        assert_eq!(d.weight, -1.0);
    }
}
