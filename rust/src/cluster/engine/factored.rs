//! Factored (grid-coreset) weighted Lloyd through the shared engine
//! (paper §4.3, Eqs. 36–38).
//!
//! Distances stay in factored form: a per-iteration `O(Σκ_j·k)` table
//! build turns each (cell, centroid) distance into `m` table lookups, and
//! the Hamerly bounds live **per grid cell**. Centroid drift and the
//! inter-centroid separations `s[c]` are computed straight from the β
//! coefficient tables using component orthogonality
//! (`‖μ − μ'‖² = Σ_j λ_j Σ_a (β_a − β'_a)²·‖u_a‖²`), so the pruning
//! machinery never densifies a centroid either. See the parent module docs
//! for the bounds invariants and the determinism contract.

use super::microkernel::best_two_buf;
use super::{resolve_threads, run_chunks, EngineOpts, PruneStats, CHUNK, SLACK_REL};
use crate::cluster::kmeanspp::kmeanspp_indices;
use crate::cluster::lloyd::LloydConfig;
use crate::cluster::sparse_lloyd::{
    cell_dist2, CentroidCoord, Components, SparseGrid, SparseLloydResult, Subspace,
};
use crate::util::SplitMix64;
use std::time::Instant;

/// Squared distance between two factored centroids (also the squared
/// drift when `a` is a centroid's previous position): orthogonality makes
/// every subspace term a coefficient-space quadratic.
fn factored_dist2(a: &[CentroidCoord], b: &[CentroidCoord], subspaces: &[Subspace]) -> f64 {
    let mut acc = 0.0;
    for ((ca, cb), sub) in a.iter().zip(b).zip(subspaces) {
        let dj = match (ca, cb, &sub.comp) {
            (CentroidCoord::Continuous(x), CentroidCoord::Continuous(y), _) => {
                let t = x - y;
                t * t
            }
            (
                CentroidCoord::Categorical(bx),
                CentroidCoord::Categorical(by),
                Components::Categorical { norm_sq },
            ) => bx
                .iter()
                .zip(by)
                .zip(norm_sq)
                .map(|((x, y), nq)| (x - y) * (x - y) * nq)
                .sum(),
            _ => unreachable!("subspace kind is fixed"),
        };
        acc += sub.lambda * dj;
    }
    acc
}

/// Indicator-coefficient centroid at a grid cell (used for seeding and
/// empty-cluster reseeds).
fn centroid_from_cell(
    grid: &SparseGrid,
    subspaces: &[Subspace],
    cell: usize,
) -> Vec<CentroidCoord> {
    let row = grid.row(cell);
    subspaces
        .iter()
        .enumerate()
        .map(|(j, sub)| match &sub.comp {
            Components::Continuous { centers } => {
                CentroidCoord::Continuous(centers[row[j] as usize])
            }
            Components::Categorical { norm_sq } => {
                let mut beta = vec![0.0; norm_sq.len()];
                beta[row[j] as usize] = 1.0;
                CentroidCoord::Categorical(beta)
            }
        })
        .collect()
}

/// Build the per-subspace distance tables `T_j[a·k + c]` for the current
/// centroids (identical arithmetic to the pre-engine implementation).
fn build_tables(
    subspaces: &[Subspace],
    kappa: &[usize],
    centroids: &[Vec<CentroidCoord>],
    k: usize,
) -> Vec<Vec<f64>> {
    subspaces
        .iter()
        .enumerate()
        .map(|(j, sub)| {
            let kj = kappa[j];
            let mut t = vec![0.0f64; kj * k];
            match &sub.comp {
                Components::Continuous { centers } => {
                    for (c, cent) in centroids.iter().enumerate() {
                        let CentroidCoord::Continuous(mu) = &cent[j] else {
                            unreachable!("subspace kind is fixed")
                        };
                        for a in 0..kj {
                            let dd = centers[a] - mu;
                            t[a * k + c] = sub.lambda * dd * dd;
                        }
                    }
                }
                Components::Categorical { norm_sq } => {
                    for (c, cent) in centroids.iter().enumerate() {
                        let CentroidCoord::Categorical(beta) = &cent[j] else {
                            unreachable!("subspace kind is fixed")
                        };
                        // S = Σ_b β²·‖u_b‖² (centroid's squared norm).
                        let s_c: f64 = beta.iter().zip(norm_sq).map(|(b, nq)| b * b * nq).sum();
                        for a in 0..kj {
                            let dd = norm_sq[a] - 2.0 * beta[a] * norm_sq[a] + s_c;
                            t[a * k + c] = sub.lambda * dd.max(0.0);
                        }
                    }
                }
            }
            t
        })
        .collect()
}

/// Per-chunk accumulator (reduced in chunk order).
struct FacAccum {
    mass: Vec<f64>,
    /// `comp_mass[j][c·κ_j + a]` = weight of cells in `c` with `g_j = a`.
    comp_mass: Vec<Vec<f64>>,
    obj: f64,
    evals: u64,
    skipped: u64,
    max_dd: f64,
}

impl FacAccum {
    fn new(k: usize, kappa: &[usize]) -> Self {
        FacAccum {
            mass: vec![0.0; k],
            comp_mass: kappa.iter().map(|&kj| vec![0.0; k * kj]).collect(),
            obj: 0.0,
            evals: 0,
            skipped: 0,
            max_dd: 0.0,
        }
    }
}

/// One chunk's view of the per-cell state.
struct FacChunk<'a> {
    /// `len × m` component ids for this chunk's cells.
    gids: &'a [u32],
    w: &'a [f64],
    assign: &'a mut [u32],
    mind2: &'a mut [f64],
    lb: &'a mut [f64],
    acc: FacAccum,
}

/// Read-only per-iteration context.
struct FacCtx<'a> {
    m: usize,
    k: usize,
    kappa: &'a [usize],
    tables: &'a [Vec<f64>],
    drift_max: f64,
    s_half: &'a [f64],
    slack: f64,
    use_bounds: bool,
    pruning: bool,
}

/// Exact distance of one cell to one centroid: `m` table lookups, summed
/// in subspace order (bitwise-identical to the full-scan accumulation).
#[inline]
fn cell_centroid_dd(gids: &[u32], tables: &[Vec<f64>], k: usize, c: usize) -> f64 {
    let mut dd = tables[0][gids[0] as usize * k + c];
    for (j, tj) in tables.iter().enumerate().skip(1) {
        dd += tj[gids[j] as usize * k + c];
    }
    dd
}

fn assign_chunk(ch: &mut FacChunk, ctx: &FacCtx) {
    let (m, k) = (ctx.m, ctx.k);
    let n = ch.w.len();

    let mut scan: Vec<u32> = Vec::with_capacity(n);
    if ctx.use_bounds {
        for i in 0..n {
            let a = ch.assign[i] as usize;
            let lbv = ch.lb[i] - ctx.drift_max;
            ch.lb[i] = lbv;
            let row = &ch.gids[i * m..(i + 1) * m];
            let dd = cell_centroid_dd(row, ctx.tables, k, a);
            let da = dd.sqrt();
            ch.acc.evals += 1;
            let bound = ctx.s_half[a].max(lbv);
            if da + ctx.slack < bound {
                ch.mind2[i] = dd;
                ch.acc.skipped += k as u64 - 1;
                if dd > ch.acc.max_dd {
                    ch.acc.max_dd = dd;
                }
            } else {
                scan.push(i as u32);
            }
        }
    } else {
        scan.extend(0..n as u32);
    }

    // Full scans: the factored m-lookup accumulation over all centroids.
    let mut dist_buf = vec![0.0f64; k];
    for &gi in &scan {
        let i = gi as usize;
        let row = &ch.gids[i * m..(i + 1) * m];
        let base0 = row[0] as usize * k;
        dist_buf.copy_from_slice(&ctx.tables[0][base0..base0 + k]);
        for j in 1..m {
            let base = row[j] as usize * k;
            let tj = &ctx.tables[j][base..base + k];
            for (dv, &t) in dist_buf.iter_mut().zip(tj) {
                *dv += t;
            }
        }
        let (d1, c1, d2) = best_two_buf(&dist_buf);
        ch.assign[i] = c1;
        ch.mind2[i] = d1;
        ch.acc.evals += k as u64;
        if d1 > ch.acc.max_dd {
            ch.acc.max_dd = d1;
        }
        if ctx.pruning {
            if d2.is_finite() {
                ch.lb[i] = d2.sqrt();
                if d2 > ch.acc.max_dd {
                    ch.acc.max_dd = d2;
                }
            } else {
                ch.lb[i] = f64::INFINITY;
            }
        }
    }

    // Ordered objective + mass accumulation (same order naive/pruned).
    for i in 0..n {
        let w = ch.w[i];
        let c = ch.assign[i] as usize;
        ch.acc.obj += w * ch.mind2[i];
        ch.acc.mass[c] += w;
        let row = &ch.gids[i * m..(i + 1) * m];
        for j in 0..m {
            ch.acc.comp_mass[j][c * ctx.kappa[j] + row[j] as usize] += w;
        }
    }
}

/// Factored weighted Lloyd over the grid coreset with engine options.
pub fn lloyd_factored(
    grid: &SparseGrid,
    subspaces: &[Subspace],
    cfg: &LloydConfig,
    opts: &EngineOpts,
) -> (SparseLloydResult, PruneStats) {
    let n = grid.n();
    assert!(n > 0, "empty grid");
    assert_eq!(grid.m, subspaces.len());
    assert!(grid.m > 0, "need at least one subspace");
    // k-means++ always yields at least one seed, so treat k = 0 as 1.
    let k = cfg.k.min(n).max(1);
    let m = grid.m;
    let t0 = Instant::now();

    let mut rng = SplitMix64::new(cfg.seed);
    let seeds = kmeanspp_indices(n, &grid.weights, k, &mut rng, |i, j| {
        cell_dist2(grid, subspaces, i, j)
    });
    let mut centroids: Vec<Vec<CentroidCoord>> =
        seeds.iter().map(|&s| centroid_from_cell(grid, subspaces, s)).collect();

    let kappa: Vec<usize> = subspaces.iter().map(|s| s.comp.len()).collect();

    // Scale term for the FP slack: the largest possible cell norm²
    // Σ_j λ_j·max_a ‖u_a‖² — the factored analog of the dense engine's
    // `xn_max`. Absolute rounding in the categorical distance expansion
    // (`‖u_a‖² − 2β_a‖u_a‖² + S`) is proportional to these magnitudes,
    // not to the distances themselves, so the skip slack must cover it.
    let norm2_max: f64 = subspaces
        .iter()
        .map(|sub| {
            let comp_max = match &sub.comp {
                Components::Continuous { centers } => {
                    centers.iter().map(|c| c * c).fold(0.0f64, f64::max)
                }
                Components::Categorical { norm_sq } => {
                    norm_sq.iter().cloned().fold(0.0f64, f64::max)
                }
            };
            sub.lambda * comp_max
        })
        .sum();

    let threads = resolve_threads(opts.threads);
    let mut assign = vec![0u32; n];
    let mut mind2 = vec![0.0f64; n];
    let mut lb = vec![0.0f64; n];
    let mut drift = vec![0.0f64; k];
    let mut s_half = vec![0.0f64; k];
    let mut bounds_valid = false;
    let mut max_dd = 0.0f64;

    let mut objective = f64::INFINITY;
    let mut iters = 0;
    let mut stats = PruneStats { points: n as u64, ..PruneStats::default() };

    for it in 0..cfg.max_iters.max(1) {
        iters = it + 1;

        let tables = build_tables(subspaces, &kappa, &centroids, k);
        let use_bounds = opts.pruning && bounds_valid;
        if use_bounds {
            for c in 0..k {
                let mut best = f64::INFINITY;
                for c2 in 0..k {
                    if c2 != c {
                        let dd = factored_dist2(&centroids[c], &centroids[c2], subspaces);
                        if dd < best {
                            best = dd;
                        }
                    }
                }
                s_half[c] = 0.5 * best.max(0.0).sqrt();
            }
        }
        let drift_max = drift.iter().cloned().fold(0.0f64, f64::max);
        let slack = SLACK_REL * (1.0 + 2.0 * max_dd.sqrt() + norm2_max.sqrt());
        let ctx = FacCtx {
            m,
            k,
            kappa: &kappa,
            tables: &tables,
            drift_max,
            s_half: &s_half,
            slack,
            use_bounds,
            pruning: opts.pruning,
        };

        let accs: Vec<FacAccum> = {
            let mut chunks: Vec<FacChunk> = Vec::with_capacity(n.div_ceil(CHUNK));
            let parts = assign
                .chunks_mut(CHUNK)
                .zip(mind2.chunks_mut(CHUNK))
                .zip(lb.chunks_mut(CHUNK));
            let mut start = 0usize;
            for ((a_s, m_s), l_s) in parts {
                let len = a_s.len();
                chunks.push(FacChunk {
                    gids: &grid.gids[start * m..(start + len) * m],
                    w: &grid.weights[start..start + len],
                    assign: a_s,
                    mind2: m_s,
                    lb: l_s,
                    acc: FacAccum::new(k, &kappa),
                });
                start += len;
            }
            run_chunks(&mut chunks, threads, |_, ch| assign_chunk(ch, &ctx));
            chunks.into_iter().map(|c| c.acc).collect()
        };

        // Fixed-order reduction.
        let mut mass = vec![0.0f64; k];
        let mut comp_mass: Vec<Vec<f64>> = kappa.iter().map(|&kj| vec![0.0; k * kj]).collect();
        let mut obj = 0.0f64;
        for a in &accs {
            for (mv, &v) in mass.iter_mut().zip(&a.mass) {
                *mv += v;
            }
            for (cm, acm) in comp_mass.iter_mut().zip(&a.comp_mass) {
                for (cv, &v) in cm.iter_mut().zip(acm) {
                    *cv += v;
                }
            }
            obj += a.obj;
            stats.dist_evals += a.evals;
            stats.dist_evals_skipped += a.skipped;
            if a.max_dd > max_dd {
                max_dd = a.max_dd;
            }
        }

        // Update (identical to the pre-engine implementation) + drift.
        let prev = if opts.pruning { Some(centroids.clone()) } else { None };
        let mut reseeded = false;
        for c in 0..k {
            if mass[c] > 0.0 {
                for (j, sub) in subspaces.iter().enumerate() {
                    let kj = kappa[j];
                    let cm = &comp_mass[j][c * kj..(c + 1) * kj];
                    match (&sub.comp, &mut centroids[c][j]) {
                        (Components::Continuous { centers }, CentroidCoord::Continuous(mu)) => {
                            let s: f64 = cm.iter().zip(centers).map(|(w, v)| w * v).sum();
                            *mu = s / mass[c];
                        }
                        (Components::Categorical { .. }, CentroidCoord::Categorical(beta)) => {
                            for a in 0..kj {
                                beta[a] = cm[a] / mass[c];
                            }
                        }
                        _ => unreachable!("subspace kind is fixed"),
                    }
                }
            } else {
                // Empty cluster: reseed at the heaviest-cost cell.
                let far = (0..n)
                    .max_by(|&a, &b| {
                        (grid.weights[a] * mind2[a])
                            .partial_cmp(&(grid.weights[b] * mind2[b]))
                            .expect("finite")
                    })
                    .expect("n > 0");
                centroids[c] = centroid_from_cell(grid, subspaces, far);
                mind2[far] = 0.0;
                reseeded = true;
            }
        }
        if let Some(prev) = prev {
            for c in 0..k {
                drift[c] = factored_dist2(&prev[c], &centroids[c], subspaces).max(0.0).sqrt();
            }
        }
        bounds_valid = opts.pruning && !reseeded;

        if objective.is_finite() {
            let improve = (objective - obj) / objective.abs().max(1e-30);
            if improve.abs() < cfg.tol {
                objective = obj;
                break;
            }
        }
        objective = obj;
    }

    stats.iters = iters;
    stats.wall = t0.elapsed();
    (SparseLloydResult { centroids, assign, objective, iters }, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::for_cases;

    fn random_problem(rng: &mut SplitMix64, n: usize) -> (SparseGrid, Vec<Subspace>) {
        let k1 = 2 + rng.below(5) as usize;
        let k2 = 2 + rng.below(5) as usize;
        let subs = vec![
            Subspace {
                name: "x".into(),
                lambda: rng.uniform(0.5, 2.0),
                comp: Components::Continuous {
                    centers: (0..k1).map(|_| rng.uniform(-5.0, 5.0)).collect(),
                },
            },
            Subspace {
                name: "c".into(),
                lambda: rng.uniform(0.5, 2.0),
                comp: Components::Categorical {
                    norm_sq: (0..k2).map(|_| rng.uniform(0.3, 1.0)).collect(),
                },
            },
        ];
        let mut gids = Vec::with_capacity(n * 2);
        let mut weights = Vec::with_capacity(n);
        for _ in 0..n {
            gids.push(rng.below(k1 as u64) as u32);
            gids.push(rng.below(k2 as u64) as u32);
            weights.push(rng.uniform(0.1, 3.0));
        }
        (SparseGrid { m: 2, gids, weights }, subs)
    }

    #[test]
    fn pruned_parallel_matches_naive_bitwise() {
        for_cases(10, |rng| {
            let n = 20 + rng.below(300) as usize;
            let (grid, subs) = random_problem(rng, n);
            let iters = 1 + rng.below(7) as usize;
            let k = 1 + rng.below(6) as usize;
            let cfg = LloydConfig { k, max_iters: iters, tol: 0.0, seed: rng.next_u64() };
            let (a, _) = lloyd_factored(&grid, &subs, &cfg, &EngineOpts::naive_serial());
            let (b, _) = lloyd_factored(&grid, &subs, &cfg, &EngineOpts::pruned().with_threads(3));
            assert_eq!(a.assign, b.assign);
            assert_eq!(a.objective.to_bits(), b.objective.to_bits());
            assert_eq!(a.iters, b.iters);
            for (ca, cb) in a.centroids.iter().zip(&b.centroids) {
                for (xa, xb) in ca.iter().zip(cb) {
                    match (xa, xb) {
                        (CentroidCoord::Continuous(u), CentroidCoord::Continuous(v)) => {
                            assert_eq!(u.to_bits(), v.to_bits())
                        }
                        (CentroidCoord::Categorical(u), CentroidCoord::Categorical(v)) => {
                            assert_eq!(u, v)
                        }
                        _ => panic!("centroid kind mismatch"),
                    }
                }
            }
        });
    }

    #[test]
    fn factored_drift_matches_bruteforce_on_grid_metric() {
        // ‖μ − μ'‖ from β tables must equal the metric the tables induce:
        // check against distances between indicator centroids, which are
        // exactly cell distances.
        for_cases(15, |rng| {
            let (grid, subs) = random_problem(rng, 12);
            let i = rng.below(grid.n() as u64) as usize;
            let j = rng.below(grid.n() as u64) as usize;
            let a = centroid_from_cell(&grid, &subs, i);
            let b = centroid_from_cell(&grid, &subs, j);
            let got = factored_dist2(&a, &b, &subs);
            let want = cell_dist2(&grid, &subs, i, j);
            crate::util::testkit::assert_close(got, want, 1e-9);
        });
    }
}
