//! Commutative semirings for FAQ aggregation.
//!
//! The FEQ in the paper's introduction computes `max(transactions.count)`
//! per output tuple — a max-product FAQ — while all of Rk-means's own
//! queries are sum-product (counting). Parameterizing the engine over the
//! semiring keeps both available and mirrors the FAQ framework [4].

/// A commutative semiring over `f64` values.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Semiring {
    /// (+, ×): counting / weighted counting.
    SumProduct,
    /// (max, ×): e.g. max aggregates over join results.
    MaxProduct,
    /// (min, +): tropical; shortest-path style aggregates.
    MinPlus,
}

impl Semiring {
    /// Additive identity.
    #[inline]
    pub fn zero(&self) -> f64 {
        match self {
            Semiring::SumProduct => 0.0,
            Semiring::MaxProduct => f64::NEG_INFINITY,
            Semiring::MinPlus => f64::INFINITY,
        }
    }

    /// Multiplicative identity.
    #[inline]
    pub fn one(&self) -> f64 {
        match self {
            Semiring::SumProduct | Semiring::MaxProduct => 1.0,
            Semiring::MinPlus => 0.0,
        }
    }

    /// Semiring addition (the aggregation operator ⊕).
    #[inline]
    pub fn add(&self, a: f64, b: f64) -> f64 {
        match self {
            Semiring::SumProduct => a + b,
            Semiring::MaxProduct => a.max(b),
            Semiring::MinPlus => a.min(b),
        }
    }

    /// Semiring multiplication (the combination operator ⊗).
    #[inline]
    pub fn mul(&self, a: f64, b: f64) -> f64 {
        match self {
            Semiring::SumProduct | Semiring::MaxProduct => a * b,
            Semiring::MinPlus => a + b,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identities_hold() {
        for s in [Semiring::SumProduct, Semiring::MaxProduct, Semiring::MinPlus] {
            for v in [0.0, 1.0, -2.5, 7.0] {
                assert_eq!(s.add(s.zero(), v), v, "{s:?} zero");
                assert_eq!(s.mul(s.one(), v), v, "{s:?} one");
            }
        }
    }

    #[test]
    fn semantics() {
        assert_eq!(Semiring::SumProduct.add(2.0, 3.0), 5.0);
        assert_eq!(Semiring::SumProduct.mul(2.0, 3.0), 6.0);
        assert_eq!(Semiring::MaxProduct.add(2.0, 3.0), 3.0);
        assert_eq!(Semiring::MaxProduct.mul(2.0, 3.0), 6.0);
        assert_eq!(Semiring::MinPlus.add(2.0, 3.0), 2.0);
        assert_eq!(Semiring::MinPlus.mul(2.0, 3.0), 5.0);
    }

    #[test]
    fn annihilation_distribution_spotcheck() {
        // a⊗(b⊕c) == (a⊗b)⊕(a⊗c) on sample values.
        for s in [Semiring::SumProduct, Semiring::MaxProduct, Semiring::MinPlus] {
            let (a, b, c) = (2.0, 5.0, 3.0);
            let lhs = s.mul(a, s.add(b, c));
            let rhs = s.add(s.mul(a, b), s.mul(a, c));
            assert!((lhs - rhs).abs() < 1e-12, "{s:?}");
        }
    }
}
