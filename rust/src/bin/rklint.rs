//! `rklint` CLI driver — run the determinism/concurrency lint over a
//! source tree and exit nonzero on any active (non-waived) diagnostic.
//!
//! ```text
//! rklint [--root <dir>] [--report [<path>]]
//! ```
//!
//! * `--root` — directory to scan (default: this crate's `src/`).
//! * `--report <path>` — also write the machine-readable JSON report
//!   (stable key order; CI archives it per commit). With no path the
//!   JSON goes to stdout instead of the human listing.

use rkmeans::analysis;
use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src");
    let mut report_path: Option<Option<PathBuf>> = None;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => match args.next() {
                Some(p) => root = PathBuf::from(p),
                None => return usage("--root needs a directory"),
            },
            "--report" => {
                // Optional value: a following non-flag token is a path.
                report_path = Some(args.next().filter(|a| !a.starts_with("--")).map(PathBuf::from));
            }
            "--help" | "-h" => {
                eprintln!("usage: rklint [--root <dir>] [--report [<path>]]");
                return ExitCode::SUCCESS;
            }
            other => return usage(&format!("unknown argument `{other}`")),
        }
    }

    let report = match analysis::lint_tree(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("rklint: {e:#}");
            return ExitCode::FAILURE;
        }
    };

    let json = report.to_json().to_string();
    match &report_path {
        Some(Some(path)) => {
            if let Err(e) = std::fs::write(path, &json) {
                eprintln!("rklint: writing {}: {e}", path.display());
                return ExitCode::FAILURE;
            }
            print_human(&report);
            eprintln!("report written to {}", path.display());
        }
        Some(None) => println!("{json}"),
        None => print_human(&report),
    }

    if report.active().count() == 0 {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

fn print_human(report: &analysis::Report) {
    for d in report.active() {
        println!("{}:{} [{}] {}", d.file, d.line, d.rule, d.message);
    }
    println!(
        "rklint: {} files, {} active, {} waived",
        report.files,
        report.active().count(),
        report.waived()
    );
}

fn usage(msg: &str) -> ExitCode {
    eprintln!("rklint: {msg}\nusage: rklint [--root <dir>] [--report [<path>]]");
    ExitCode::FAILURE
}
