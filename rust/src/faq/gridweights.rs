//! Grid-coreset weights via a free-variable FAQ (paper §4.3, Step 3).
//!
//! Given per-feature centroid assignments `c_j : Dom(A_j) -> [κ_j]` from
//! Step 2, the weight of a grid cell `g = (a_1, …, a_d)` is the number of
//! join-output tuples whose features map to those centroid ids (Eq. 4):
//!
//! ```text
//!   w_grid(g) = Σ_{x ∈ X : c_j(x_j) = a_j ∀j}  w(x)
//! ```
//!
//! This is a counting FAQ whose *free variables* are the centroid ids. We
//! evaluate it InsideOut-style with a single upward pass over the join
//! tree: each message is keyed by the separator join values and carries a
//! sparse table over the gid-combinations of the features owned by its
//! subtree. Only grid cells with non-zero weight ever exist — on FD-chains
//! this is what turns `κ^p` cells into `O(pκ)` (Lemma 4.5) with no special
//! casing: inconsistent combinations simply never occur in the data.
//!
//! ## Hot path
//!
//! Step 3 dominates the pipeline at small k (Figure 3), so the combo
//! tables use **bit-packed `u128` keys**: each feature gets a fixed bit
//! range (`⌈log₂ κ_j⌉` bits at a global shift), so combining subtree
//! combos is a single OR and the hash key is one machine-pair word instead
//! of a heap-allocated `Vec<u32>`. A generic `Vec<u32>`-keyed fallback
//! handles the (unrealistic) >128-bit layouts; both paths are
//! differential-tested against each other and against materialized joins.

use crate::data::{Database, Value};
use crate::query::{Feq, JoinTree};
use crate::util::FxHashMap;
use anyhow::{Context, Result};

/// Maps an attribute value to its subspace centroid id (Step 2 output).
pub trait GidAssigner {
    /// Centroid id in `[0, n_gids)` for a value of this attribute.
    fn gid(&self, v: Value) -> u32;
    /// Number of centroids κ_j in this subspace.
    fn n_gids(&self) -> usize;
}

/// The sparse grid-weight table: one row per non-zero-weight grid cell.
#[derive(Clone, Debug)]
pub struct GridTable {
    /// Feature names in cell order (same order as `feq.features`).
    pub feature_names: Vec<String>,
    /// `(gid per feature, weight)` — weights sum to `|X|`.
    pub cells: Vec<(Vec<u32>, f64)>,
}

impl GridTable {
    /// Number of non-zero cells `|G|`.
    pub fn len(&self) -> usize {
        self.cells.len()
    }

    /// True when the grid has no cells (empty join).
    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    /// Total weight (= `|X|`).
    pub fn mass(&self) -> f64 {
        self.cells.iter().map(|(_, w)| w).sum()
    }

    /// Merge per-shard grid tables by cell-wise weight addition, sorted
    /// by gid vector (the canonical order [`sparse_from_table`]
    /// establishes — so a merged table and an unsharded table compare
    /// cell by cell).
    ///
    /// Sharding any single relation of a join partitions the join
    /// output, so the full grid is exactly the cell-wise sum of the
    /// per-shard grids. Step 3 counts in the ring ℤ: with integer tuple
    /// multiplicities below 2⁵³ every per-shard partial sum is an
    /// exactly-represented f64 integer and the merged weights are
    /// **bitwise identical** to the single-shard build. Fractional
    /// multiplicities merge correctly but are subject to f64
    /// reassociation, like any regrouped sum.
    ///
    /// [`sparse_from_table`]: crate::coreset::sparse_from_table
    pub fn merge(tables: Vec<GridTable>) -> Result<GridTable> {
        let mut iter = tables.into_iter();
        let first = iter.next().context("cannot merge zero grid tables")?;
        let feature_names = first.feature_names;
        let mut acc: FxHashMap<Vec<u32>, f64> = FxHashMap::default();
        for (g, w) in first.cells {
            *acc.entry(g).or_insert(0.0) += w;
        }
        for t in iter {
            anyhow::ensure!(
                t.feature_names == feature_names,
                "cannot merge grid tables over different feature sets: {:?} vs {:?}",
                t.feature_names,
                feature_names
            );
            for (g, w) in t.cells {
                *acc.entry(g).or_insert(0.0) += w;
            }
        }
        let cells: Vec<(Vec<u32>, f64)> = crate::util::det::sorted_owned(acc);
        Ok(GridTable { feature_names, cells })
    }
}

/// Per-node metadata shared by both evaluation paths.
struct NodePlan<'a> {
    /// (feature idx, column idx, assigner) owned by this node.
    owned: Vec<(usize, usize, &'a dyn GidAssigner)>,
    /// (child node, separator column indices in this node's relation).
    child_cols: Vec<(usize, Vec<usize>)>,
    /// Separator columns with the parent.
    sep_cols: Vec<usize>,
}

fn build_plans<'a>(
    db: &Database,
    feq: &'a Feq,
    tree: &JoinTree,
    assigners: &'a FxHashMap<String, Box<dyn GidAssigner + 'a>>,
) -> Result<Vec<NodePlan<'a>>> {
    for f in &feq.features {
        if !assigners.contains_key(&f.attr) {
            anyhow::bail!("no gid assigner for feature {:?}", f.attr);
        }
    }
    let n = tree.len();
    let mut plans = Vec::with_capacity(n);
    for u in 0..n {
        let rel = db
            .get(&tree.rel_names[u])
            .with_context(|| format!("relation {} missing", tree.rel_names[u]))?;
        let owned: Vec<(usize, usize, &dyn GidAssigner)> = feq
            .features
            .iter()
            .enumerate()
            .filter(|(_, f)| feq.owner_of(db, &f.attr) == Some(u))
            .map(|(fi, f)| {
                let col = rel.schema.index_of(&f.attr).expect("owner contains attr");
                (fi, col, assigners[&f.attr].as_ref())
            })
            .collect();
        let child_cols: Vec<(usize, Vec<usize>)> = tree
            .children(u)
            .into_iter()
            .map(|c| {
                let cols = tree.sep[c]
                    .iter()
                    .map(|a| rel.schema.index_of(a).expect("separator attr in parent"))
                    .collect();
                (c, cols)
            })
            .collect();
        let sep_cols: Vec<usize> = tree.sep[u]
            .iter()
            .map(|a| rel.schema.index_of(a).expect("separator attr in node"))
            .collect();
        plans.push(NodePlan { owned, child_cols, sep_cols });
    }
    Ok(plans)
}

/// Compute the sparse grid-weight table. `assigners` must contain one
/// assigner per FEQ feature, keyed by attribute name.
pub fn grid_weights(
    db: &Database,
    feq: &Feq,
    tree: &JoinTree,
    assigners: &FxHashMap<String, Box<dyn GidAssigner + '_>>,
) -> Result<GridTable> {
    let plans = build_plans(db, feq, tree, assigners)?;
    // Bit layout: feature fi occupies `width` bits at `shift`.
    let mut shifts = Vec::with_capacity(feq.features.len());
    let mut total_bits = 0u32;
    for f in &feq.features {
        let kj = assigners[&f.attr].n_gids().max(2) as u64;
        let width = 64 - (kj - 1).leading_zeros().max(0);
        shifts.push((total_bits, width));
        total_bits += width;
    }
    if total_bits <= 128 {
        grid_weights_packed(db, feq, tree, &plans, &shifts)
    } else {
        grid_weights_generic(db, feq, tree, &plans)
    }
}

/// Packed path: gid combos as `u128` bit patterns (the hot path).
fn grid_weights_packed(
    db: &Database,
    feq: &Feq,
    tree: &JoinTree,
    plans: &[NodePlan<'_>],
    shifts: &[(u32, u32)],
) -> Result<GridTable> {
    let n = tree.len();
    let mut msgs: Vec<Option<FxHashMap<Vec<u64>, Vec<(u128, f64)>>>> =
        (0..n).map(|_| None).collect();

    for &u in &tree.order {
        let rel = db.get(&tree.rel_names[u]).expect("checked in plan");
        let plan = &plans[u];
        // Take child messages out (frees memory as we go up the tree).
        let child_msgs: Vec<FxHashMap<Vec<u64>, Vec<(u128, f64)>>> = plan
            .child_cols
            .iter()
            .map(|(c, _)| msgs[*c].take().expect("child processed first"))
            .collect();

        let mut out: FxHashMap<Vec<u64>, FxHashMap<u128, f64>> = FxHashMap::default();
        let mut keybuf: Vec<u64> = Vec::new();
        let mut combos: Vec<(u128, f64)> = Vec::new();
        let mut next: Vec<(u128, f64)> = Vec::new();
        'rows: for row in 0..rel.n_rows() {
            let w = rel.weight(row);
            if w == 0.0 {
                continue;
            }
            // Own gid bits.
            let mut own: u128 = 0;
            for &(fi, col, asg) in &plan.owned {
                let (shift, _) = shifts[fi];
                own |= (asg.gid(rel.value(row, col)) as u128) << shift;
            }
            combos.clear();
            combos.push((own, w));
            // Cross product with child tables (disjoint bit ranges: OR).
            for ((_, cols), msg) in plan.child_cols.iter().zip(&child_msgs) {
                keybuf.clear();
                for &cc in cols {
                    keybuf.push(rel.col(cc).key_u64(row));
                }
                let Some(table) = msg.get(keybuf.as_slice()) else { continue 'rows };
                if table.len() == 1 {
                    // Overwhelmingly common: one combo per key — in place.
                    let (g, gw) = table[0];
                    for c in combos.iter_mut() {
                        c.0 |= g;
                        c.1 *= gw;
                    }
                } else {
                    next.clear();
                    next.reserve(combos.len() * table.len());
                    for &(prefix, pw) in &combos {
                        for &(g, gw) in table {
                            next.push((prefix | g, pw * gw));
                        }
                    }
                    std::mem::swap(&mut combos, &mut next);
                }
            }
            keybuf.clear();
            for &sc in &plan.sep_cols {
                keybuf.push(rel.col(sc).key_u64(row));
            }
            let slot = match out.get_mut(keybuf.as_slice()) {
                Some(s) => s,
                None => out.entry(keybuf.clone()).or_default(),
            };
            for &(g, cw) in &combos {
                *slot.entry(g).or_insert(0.0) += cw;
            }
        }
        msgs[u] = Some(
            // rklint::allow(nondet-iteration, reason = "map-to-map rehash; inner tables feed ring-ℤ exact counting products and cell order is canonicalized by sparse_from_table's sort")
            out.into_iter().map(|(k, t)| (k, t.into_iter().collect::<Vec<_>>())).collect(),
        );
    }

    // Root: single (empty) separator key; unpack bits to gid vectors.
    let root = msgs[tree.root].take().expect("root processed");
    let table = root.into_iter().next().map(|(_, t)| t).unwrap_or_default();
    let cells: Vec<(Vec<u32>, f64)> = table
        .into_iter()
        .map(|(packed, w)| {
            let gids: Vec<u32> = shifts
                .iter()
                .map(|&(shift, width)| ((packed >> shift) & ((1u128 << width) - 1)) as u32)
                .collect();
            (gids, w)
        })
        .collect();
    Ok(GridTable {
        feature_names: feq.features.iter().map(|f| f.attr.clone()).collect(),
        cells,
    })
}

/// Generic fallback: gid combos as `Vec<u32>` (layouts over 128 bits).
fn grid_weights_generic(
    db: &Database,
    feq: &Feq,
    tree: &JoinTree,
    plans: &[NodePlan<'_>],
) -> Result<GridTable> {
    struct GridMsg {
        feats: Vec<usize>,
        map: FxHashMap<Vec<u64>, FxHashMap<Vec<u32>, f64>>,
    }
    let n = tree.len();
    let mut msgs: Vec<Option<GridMsg>> = (0..n).map(|_| None).collect();

    for &u in &tree.order {
        let rel = db.get(&tree.rel_names[u]).expect("checked in plan");
        let plan = &plans[u];
        let child_msgs: Vec<GridMsg> = plan
            .child_cols
            .iter()
            .map(|(c, _)| msgs[*c].take().expect("child processed first"))
            .collect();

        let mut feats: Vec<usize> = Vec::new();
        for m in &child_msgs {
            feats.extend(&m.feats);
        }
        feats.extend(plan.owned.iter().map(|(fi, _, _)| *fi));

        let mut out: FxHashMap<Vec<u64>, FxHashMap<Vec<u32>, f64>> = FxHashMap::default();
        let mut keybuf: Vec<u64> = Vec::new();
        'rows: for row in 0..rel.n_rows() {
            let w = rel.weight(row);
            if w == 0.0 {
                continue;
            }
            let mut tables: Vec<&FxHashMap<Vec<u32>, f64>> =
                Vec::with_capacity(plan.child_cols.len());
            for ((_, cols), msg) in plan.child_cols.iter().zip(&child_msgs) {
                keybuf.clear();
                for &cc in cols {
                    keybuf.push(rel.col(cc).key_u64(row));
                }
                match msg.map.get(keybuf.as_slice()) {
                    Some(t) if !t.is_empty() => tables.push(t),
                    _ => continue 'rows,
                }
            }
            let own_gids: Vec<u32> =
                plan.owned.iter().map(|(_, col, asg)| asg.gid(rel.value(row, *col))).collect();
            let mut combos: Vec<(Vec<u32>, f64)> = vec![(Vec::new(), w)];
            for t in &tables {
                let mut next = Vec::with_capacity(combos.len() * t.len());
                for (prefix, pw) in &combos {
                    for (gids, gw) in t.iter() {
                        let mut full = Vec::with_capacity(prefix.len() + gids.len());
                        full.extend_from_slice(prefix);
                        full.extend_from_slice(gids);
                        next.push((full, pw * gw));
                    }
                }
                combos = next;
            }
            keybuf.clear();
            for &sc in &plan.sep_cols {
                keybuf.push(rel.col(sc).key_u64(row));
            }
            let slot = out.entry(keybuf.clone()).or_default();
            for (mut gids, cw) in combos {
                gids.extend_from_slice(&own_gids);
                *slot.entry(gids).or_insert(0.0) += cw;
            }
        }
        msgs[u] = Some(GridMsg { feats, map: out });
    }

    let root_msg = msgs[tree.root].take().expect("root processed");
    let feats = root_msg.feats;
    // rklint::allow(nondet-iteration, reason = "root message has exactly one entry (empty separator key); order cannot matter for a singleton")
    let table = root_msg.map.into_iter().next().map(|(_, t)| t).unwrap_or_default();
    let mut perm = vec![usize::MAX; feq.features.len()];
    for (pos, &fi) in feats.iter().enumerate() {
        perm[fi] = pos;
    }
    debug_assert!(perm.iter().all(|&p| p != usize::MAX), "all features covered");
    let cells: Vec<(Vec<u32>, f64)> = table
        .into_iter()
        .map(|(gids, w)| {
            let ordered: Vec<u32> = perm.iter().map(|&p| gids[p]).collect();
            (ordered, w)
        })
        .collect();
    Ok(GridTable {
        feature_names: feq.features.iter().map(|f| f.attr.clone()).collect(),
        cells,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Attr, Relation, Schema};
    use crate::query::Hypergraph;

    /// Assigner mapping value -> value % n (easy to verify by hand).
    /// `claimed` lets tests force the generic (>128-bit) path.
    struct ModAssigner {
        n: u32,
        claimed: usize,
    }
    impl ModAssigner {
        fn new(n: u32) -> Self {
            ModAssigner { n, claimed: n as usize }
        }
    }
    impl GidAssigner for ModAssigner {
        fn gid(&self, v: Value) -> u32 {
            (v.key_u64() % self.n as u64) as u32
        }
        fn n_gids(&self) -> usize {
            self.claimed
        }
    }

    fn setup() -> (Database, Feq, JoinTree) {
        // fact(a, b) ⋈ dim(b, c): outputs (a,b,c).
        let mut fact =
            Relation::new("fact", Schema::new(vec![Attr::cat("a", 6), Attr::cat("b", 4)]));
        for (a, b) in [(0, 0), (1, 0), (2, 1), (3, 1), (4, 2), (5, 9)] {
            fact.push_row(&[Value::Cat(a), Value::Cat(b)]);
        }
        let mut dim = Relation::new("dim", Schema::new(vec![Attr::cat("b", 4), Attr::cat("c", 6)]));
        for (b, c) in [(0, 0), (0, 1), (1, 2), (2, 3)] {
            dim.push_row(&[Value::Cat(b), Value::Cat(c)]);
        }
        let mut db = Database::new();
        db.add(fact);
        db.add(dim);
        let feq = Feq::with_features(&["fact", "dim"], &["a", "b", "c"]);
        let tree = Hypergraph::from_feq(&db, &feq).join_tree().unwrap();
        (db, feq, tree)
    }

    fn assigners(n: u32, claimed: Option<usize>) -> FxHashMap<String, Box<dyn GidAssigner>> {
        let mut m: FxHashMap<String, Box<dyn GidAssigner>> = FxHashMap::default();
        for a in ["a", "b", "c"] {
            let mut asg = ModAssigner::new(n);
            if let Some(c) = claimed {
                asg.claimed = c;
            }
            m.insert(a.to_string(), Box::new(asg));
        }
        m
    }

    /// Brute-force join + group-by for the oracle.
    fn brute(db: &Database, n: u32) -> FxHashMap<Vec<u32>, f64> {
        let fact = db.get("fact").unwrap();
        let dim = db.get("dim").unwrap();
        let mut out: FxHashMap<Vec<u32>, f64> = FxHashMap::default();
        for fr in 0..fact.n_rows() {
            for dr in 0..dim.n_rows() {
                if fact.value(fr, 1) == dim.value(dr, 0) {
                    let key = vec![
                        (fact.col(0).key_u64(fr) % n as u64) as u32,
                        (fact.col(1).key_u64(fr) % n as u64) as u32,
                        (dim.col(1).key_u64(dr) % n as u64) as u32,
                    ];
                    *out.entry(key).or_insert(0.0) += 1.0;
                }
            }
        }
        out
    }

    #[test]
    fn matches_bruteforce_join() {
        let (db, feq, tree) = setup();
        for n in [1u32, 2, 3] {
            let gt = grid_weights(&db, &feq, &tree, &assigners(n, None)).unwrap();
            let oracle = brute(&db, n);
            assert_eq!(gt.len(), oracle.len(), "n={n}");
            for (gids, w) in &gt.cells {
                assert_eq!(oracle.get(gids), Some(w), "n={n} cell {gids:?}");
            }
        }
    }

    #[test]
    fn generic_fallback_matches_packed() {
        let (db, feq, tree) = setup();
        for n in [2u32, 3] {
            let packed = grid_weights(&db, &feq, &tree, &assigners(n, None)).unwrap();
            // Claim 2^60 gids per feature: 3×60 = 180 bits > 128 forces
            // the generic path while actual gids stay identical.
            let generic =
                grid_weights(&db, &feq, &tree, &assigners(n, Some(1usize << 60))).unwrap();
            assert_eq!(packed.len(), generic.len());
            let as_map = |gt: &GridTable| -> FxHashMap<Vec<u32>, f64> {
                gt.cells.iter().cloned().collect()
            };
            assert_eq!(as_map(&packed), as_map(&generic));
        }
    }

    #[test]
    fn mass_equals_output_size() {
        let (db, feq, tree) = setup();
        let gt = grid_weights(&db, &feq, &tree, &assigners(2, None)).unwrap();
        let total = crate::faq::output_size(&db, &tree).unwrap();
        assert!((gt.mass() - total).abs() < 1e-9);
        // 5 joining fact rows; (a=0,b=0) joins 2 dim rows + others -> mass 7.
        assert_eq!(gt.mass(), 7.0);
    }

    #[test]
    fn missing_assigner_is_error() {
        let (db, feq, tree) = setup();
        let mut m = assigners(2, None);
        m.remove("c");
        assert!(grid_weights(&db, &feq, &tree, &m).is_err());
    }

    #[test]
    fn feature_order_is_feq_order() {
        let (db, _, _) = setup();
        // Reversed feature order must still produce cells in that order.
        let feq = Feq::with_features(&["fact", "dim"], &["c", "a", "b"]);
        let tree = Hypergraph::from_feq(&db, &feq).join_tree().unwrap();
        let gt = grid_weights(&db, &feq, &tree, &assigners(3, None)).unwrap();
        assert_eq!(gt.feature_names, vec!["c", "a", "b"]);
        let oracle = brute(&db, 3);
        for (gids, w) in &gt.cells {
            // gt order (c,a,b) -> oracle order (a,b,c).
            let key = vec![gids[1], gids[2], gids[0]];
            assert_eq!(oracle.get(&key), Some(w));
        }
    }
}
