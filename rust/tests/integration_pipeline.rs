//! Integration tests: the full Rk-means pipeline against the exhaustive
//! baseline on all three paper workloads, plus the theoretical guarantees
//! that must hold on every run (approximation bound, mass conservation,
//! FD grid bound, κ monotonicity).

use rkmeans::bench_harness::paper::{self, PaperCfg};
use rkmeans::cluster::LloydConfig;
use rkmeans::coordinator::{Coordinator, CoordinatorConfig};
use rkmeans::data::Value;
use rkmeans::query::{Feq, Hypergraph};
use rkmeans::rkmeans::{
    full_objective, materialize_and_cluster, rkmeans, RkConfig,
};
use rkmeans::synthetic::{Dataset, Scale};
use rkmeans::util::testkit::assert_close;

#[test]
fn pipeline_on_all_datasets() {
    for ds in Dataset::all() {
        let db = ds.generate(Scale::tiny(), 11);
        let feq = ds.feq();
        let res = rkmeans(&db, &feq, &RkConfig::new(5))
            .unwrap_or_else(|e| panic!("{}: {e}", ds.name()));

        // Grid mass must equal the FAQ output size.
        let tree = Hypergraph::from_feq(&db, &feq).join_tree().unwrap();
        let x_size = rkmeans::faq::output_size(&db, &tree).unwrap();
        assert_close(res.grid_mass, x_size, 1e-9);

        // The coreset never exceeds the data.
        assert!(res.grid_points as f64 <= x_size);

        // Full objective obeys the W₂ triangle-inequality upper bound.
        let full = full_objective(&db, &feq, &res).unwrap();
        assert!(
            full <= res.objective_upper_bound() * (1.0 + 1e-9) + 1e-9,
            "{}: full {} > bound {}",
            ds.name(),
            full,
            res.objective_upper_bound()
        );
    }
}

#[test]
fn approximation_ratio_well_below_theorem_bound() {
    // Theorem 3.4: with α = 1 (exact subspace solvers) and Lloyd's γ, the
    // paper observes ratios well below the 9× worst case. Verify against
    // the exhaustive baseline on small instances of every dataset.
    for ds in Dataset::all() {
        let db = ds.generate(Scale::tiny(), 13);
        let feq = ds.feq();
        let k = 5;
        let res = rkmeans(&db, &feq, &RkConfig::new(k).with_seed(1)).unwrap();
        let rk_obj = full_objective(&db, &feq, &res).unwrap();
        let base =
            materialize_and_cluster(&db, &feq, &LloydConfig { seed: 1, ..LloydConfig::new(k) })
                .unwrap();
        let ratio = rk_obj / base.objective.max(1e-12);
        assert!(
            ratio < 9.0,
            "{}: approximation ratio {ratio:.3} ≥ 9 (rk {rk_obj:.4e} vs base {:.4e})",
            ds.name(),
            base.objective
        );
        // And the paper's observation: usually close to 1.
        assert!(ratio < 3.0, "{}: ratio {ratio:.3} surprisingly high", ds.name());
    }
}

#[test]
fn kappa_monotonicity() {
    // Larger κ: finer coreset, (weakly) more cells and lower quantization.
    let db = Dataset::Favorita.generate(Scale::tiny(), 17);
    let feq = Dataset::Favorita.feq();
    let mut last_cells = 0usize;
    let mut last_quant = f64::INFINITY;
    for kappa in [2usize, 4, 8, 16] {
        let res = rkmeans(&db, &feq, &RkConfig::new(8).with_kappa(kappa)).unwrap();
        assert!(
            res.grid_points >= last_cells,
            "κ={kappa}: cells {} < previous {last_cells}",
            res.grid_points
        );
        assert!(
            res.quantization_cost <= last_quant + 1e-9,
            "κ={kappa}: quantization {} > previous {last_quant}",
            res.quantization_cost
        );
        last_cells = res.grid_points;
        last_quant = res.quantization_cost;
    }
}

#[test]
fn paper_smoke_tables_generate() {
    // The paper-table machinery end to end at smoke scale.
    let cfg = PaperCfg::smoke();
    assert_eq!(paper::table1(&cfg).unwrap().rows.len(), 3);
    let t2 = paper::table2(Dataset::Yelp, &cfg).unwrap();
    assert!(!t2.rows.is_empty());
    let f3 = paper::fig3(Dataset::Retailer, &cfg).unwrap();
    assert_eq!(f3.rows.len(), cfg.ks.len());
}

#[test]
fn coordinator_streams_and_reclusters() {
    let db = Dataset::Retailer.generate(Scale::tiny(), 23);
    let feq = Dataset::Retailer.feq();
    let inv_schema = db.get("inventory").unwrap().schema.clone();
    let stores = inv_schema.attr(0).domain as u64;
    let dates = inv_schema.attr(1).domain as u64;
    let skus = inv_schema.attr(2).domain as u64;

    let mut cfg = CoordinatorConfig::new(RkConfig::new(4));
    cfg.recluster_every = 200;
    let coord = Coordinator::start(db, feq, cfg);

    let mut rng = rkmeans::util::SplitMix64::new(5);
    for _ in 0..200 {
        coord
            .insert(
                "inventory",
                vec![
                    Value::Cat(rng.below(stores) as u32),
                    Value::Cat(rng.below(dates) as u32),
                    Value::Cat(rng.below(skus) as u32),
                    Value::Double(rng.below(20) as f64),
                ],
            )
            .unwrap();
    }
    let update = coord.recv_update(std::time::Duration::from_secs(120)).expect("update");
    assert_eq!(update.ingested, 200);
    assert!(update.result.grid_points > 0);
    assert_eq!(coord.metrics().counter("coordinator.ingested").get(), 200);
    coord.shutdown().unwrap();
}

#[test]
fn cyclic_query_is_handled_end_to_end() {
    // Triangle query: rkmeans must rewrite and still satisfy the bound.
    use rkmeans::data::{Attr, Database, Relation, Schema};
    let mut rng = rkmeans::util::SplitMix64::new(3);
    let mk = |name: &str, a: &str, b: &str, rng: &mut rkmeans::util::SplitMix64| {
        let mut r = Relation::new(
            name,
            Schema::new(vec![Attr::cat(a, 5), Attr::cat(b, 5), Attr::double(&format!("p_{name}"))]),
        );
        for _ in 0..30 {
            r.push_row(&[
                Value::Cat(rng.below(5) as u32),
                Value::Cat(rng.below(5) as u32),
                Value::Double(rng.below(8) as f64),
            ]);
        }
        r
    };
    let mut db = Database::new();
    db.add(mk("r", "a", "b", &mut rng));
    db.add(mk("s", "b", "c", &mut rng));
    db.add(mk("t", "c", "a", &mut rng));
    let feq = Feq::with_features(&["r", "s", "t"], &["p_r", "p_s", "p_t", "a", "b", "c"]);
    assert!(Hypergraph::from_feq(&db, &feq).join_tree().is_err(), "should be cyclic");

    let res = rkmeans(&db, &feq, &RkConfig::new(4)).unwrap();
    assert!(res.grid_points > 0);
}

#[test]
fn feature_weights_change_the_geometry() {
    use rkmeans::query::FeatureSpec;
    let db = Dataset::Retailer.generate(Scale::tiny(), 29);
    // Upweight `units` heavily: quantization cost must be dominated by it.
    let feq_flat = Dataset::Retailer.feq();
    let feq_weighted = Feq::new(
        &["inventory", "location", "census", "weather", "items"],
        feq_flat
            .features
            .iter()
            .map(|f| {
                if f.attr == "units" {
                    FeatureSpec::weighted("units", 100.0)
                } else {
                    FeatureSpec::new(&f.attr)
                }
            })
            .collect(),
    );
    let flat = rkmeans(&db, &feq_flat, &RkConfig::new(4).with_kappa(3)).unwrap();
    let heavy = rkmeans(&db, &feq_weighted, &RkConfig::new(4).with_kappa(3)).unwrap();
    let flat_units = flat.models.iter().find(|m| m.name == "units").unwrap().cost;
    let heavy_units = heavy.models.iter().find(|m| m.name == "units").unwrap().cost;
    assert_close(heavy_units, 100.0 * flat_units, 1e-9);
}
