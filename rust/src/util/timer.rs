//! Wall-clock timing helpers used by the pipeline step breakdown (Figure 3)
//! and the bench harness.

use std::time::{Duration, Instant};

/// A simple stopwatch accumulating named laps.
#[derive(Debug, Default)]
pub struct Stopwatch {
    laps: Vec<(String, Duration)>,
    current: Option<(String, Instant)>,
}

impl Stopwatch {
    /// Create an idle stopwatch.
    pub fn new() -> Self {
        Self::default()
    }

    /// Start (or restart) timing a named lap; finishes any running lap first.
    pub fn start(&mut self, name: &str) {
        self.stop();
        self.current = Some((name.to_string(), Instant::now()));
    }

    /// Stop the running lap, if any, and record it.
    pub fn stop(&mut self) {
        if let Some((name, t0)) = self.current.take() {
            self.laps.push((name, t0.elapsed()));
        }
    }

    /// Time a closure as a named lap and return its value.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        self.start(name);
        let out = f();
        self.stop();
        out
    }

    /// Total duration attributed to `name` (laps may repeat).
    pub fn total_for(&self, name: &str) -> Duration {
        self.laps
            .iter()
            .filter(|(n, _)| n == name)
            .map(|(_, d)| *d)
            .sum()
    }

    /// Sum of all recorded laps.
    pub fn total(&self) -> Duration {
        self.laps.iter().map(|(_, d)| *d).sum()
    }

    /// All laps in recording order.
    pub fn laps(&self) -> &[(String, Duration)] {
        &self.laps
    }
}

/// The blessed telemetry clock: the one sanctioned way for code outside
/// the telemetry modules (`metrics`, `bench_harness`, `serve::load`) to
/// read wall time.
///
/// Timing reads in core paths feed planner statistics and log lines —
/// never results — and routing them through this single chokepoint
/// keeps that auditable: the `wall-clock-in-core` rklint rule (see
/// [`crate::analysis`]) flags any raw `Instant::now()` elsewhere, so a
/// clock read can never silently creep into a deterministic
/// computation.
pub fn now() -> Instant {
    Instant::now()
}

/// Measure a closure's wall time.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Duration as fractional seconds (for report tables).
pub fn secs(d: Duration) -> f64 {
    d.as_secs_f64()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn laps_accumulate_by_name() {
        let mut sw = Stopwatch::new();
        sw.time("a", || std::thread::sleep(Duration::from_millis(2)));
        sw.time("b", || std::thread::sleep(Duration::from_millis(2)));
        sw.time("a", || std::thread::sleep(Duration::from_millis(2)));
        assert_eq!(sw.laps().len(), 3);
        assert!(sw.total_for("a") >= Duration::from_millis(4));
        assert!(sw.total() >= sw.total_for("a") + sw.total_for("b"));
    }

    #[test]
    fn start_finishes_previous_lap() {
        let mut sw = Stopwatch::new();
        sw.start("x");
        sw.start("y");
        sw.stop();
        assert_eq!(sw.laps().len(), 2);
    }

    #[test]
    fn time_it_returns_value() {
        let (v, d) = time_it(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(d < Duration::from_secs(1));
    }
}
