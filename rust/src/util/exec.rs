//! Persistent deterministic execution pool for chunked parallel work.
//!
//! The Step-4 engines, the streaming
//! [`CentroidScorer`](crate::cluster::CentroidScorer), the
//! `coordinator` worker and the serving tier's micro-batching assign
//! front ([`crate::serve::AssignFront`] fanning request batches over
//! the replicated mesh) all run
//! the same shape of job: a slice of independent work items, each mutated
//! in place, with results read back **in item order** by the caller so the
//! output never depends on scheduling (the engine's determinism contract).
//! Before this module, every such job spawned scoped `std::thread` workers
//! — tens of microseconds of spawn/join per Lloyd iteration, a real
//! fraction of per-iteration time in the small-`|G|`, many-iteration and
//! streaming-patch regimes the grid coreset creates.
//!
//! [`ExecPool`] keeps the workers alive instead: jobs are handed to the
//! same threads over and over through an epoch-counted condvar handshake.
//! The work-distribution discipline is identical to the scoped executor
//! (an atomic cursor over the item list; items mutated in place), so a
//! pooled dispatch is **bitwise identical** to a scoped or serial one —
//! the pool only changes *who* computes an item, never the arithmetic or
//! the reduction order. `tests/property_engine.rs` pins pooled ≡ scoped ≡
//! serial for both engines.
//!
//! One process-wide pool ([`shared_pool`]) is created lazily at the
//! machine's parallelism (honoring `RKMEANS_THREADS`) and shared by every
//! default-configured engine, scorer and coordinator job; per-job
//! `threads` requests clamp the number of *active* workers without
//! resizing the pool. Concurrent submitters serialize on the pool (one
//! job at a time), which doubles as oversubscription control when the
//! coordinator worker and a foreground sweep share the machine.
//!
//! Do **not** submit a job from inside a pool worker (the submit lock is
//! not reentrant); the engines never nest dispatches.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};
use std::thread::JoinHandle;

/// Lock ignoring poisoning: the pool's shared state is managed through
/// explicit fields (and payload panics are re-raised at the submitter),
/// so a poisoned mutex carries no extra information — and must not brick
/// the process-wide shared pool.
fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Resolve a worker-thread count (0 = auto: `RKMEANS_THREADS` env var,
/// else the machine's available parallelism).
pub fn resolve_threads(requested: usize) -> usize {
    if requested > 0 {
        return requested;
    }
    if let Ok(v) = std::env::var("RKMEANS_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Type-erased job body: `f(worker_index)` pulls work items off the job's
/// atomic cursor until it is exhausted. The pointer is only dereferenced
/// between the epoch bump and the all-workers acknowledgement, while the
/// submitting stack frame (which owns the closure) is blocked.
#[derive(Clone, Copy)]
struct Task(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (shared-call safe) and the submitter keeps
// it alive for the whole handshake (see `Task` docs).
unsafe impl Send for Task {}

struct Ctrl {
    /// Bumped once per job; workers run each epoch exactly once.
    epoch: u64,
    /// Workers with index < `active` execute the task; the rest just
    /// acknowledge the epoch.
    active: usize,
    task: Option<Task>,
    /// Workers yet to acknowledge the current epoch.
    remaining: usize,
    shutdown: bool,
}

struct Shared {
    ctrl: Mutex<Ctrl>,
    start: Condvar,
    done: Condvar,
    panicked: AtomicBool,
}

fn worker(idx: usize, shared: &Shared) {
    let mut seen = 0u64;
    loop {
        let (task, active) = {
            let mut c = lock_unpoisoned(&shared.ctrl);
            loop {
                if c.shutdown {
                    return;
                }
                if c.epoch != seen {
                    break;
                }
                c = shared.start.wait(c).unwrap_or_else(|e| e.into_inner());
            }
            seen = c.epoch;
            (c.task.expect("task set for live epoch"), c.active)
        };
        if idx < active {
            // Keep the worker alive across payload panics; the submitter
            // re-raises after the job completes.
            let f = unsafe { &*task.0 };
            if catch_unwind(AssertUnwindSafe(|| f(idx))).is_err() {
                shared.panicked.store(true, Ordering::SeqCst);
            }
        }
        let mut c = lock_unpoisoned(&shared.ctrl);
        c.remaining -= 1;
        if c.remaining == 0 {
            shared.done.notify_all();
        }
    }
}

/// A persistent pool of worker threads executing chunked jobs with the
/// engine's deterministic work-distribution discipline (see module docs).
pub struct ExecPool {
    shared: Arc<Shared>,
    /// Serializes submitters: one job owns the workers at a time.
    submit: Mutex<()>,
    handles: Vec<JoinHandle<()>>,
    threads: usize,
    dispatches: AtomicU64,
}

impl ExecPool {
    /// Spawn a pool of `threads` workers (0 = auto via
    /// [`resolve_threads`]). A single-thread pool spawns no workers and
    /// runs every job serially on the caller.
    pub fn new(threads: usize) -> Arc<ExecPool> {
        let threads = resolve_threads(threads);
        let shared = Arc::new(Shared {
            ctrl: Mutex::new(Ctrl {
                epoch: 0,
                active: 0,
                task: None,
                remaining: 0,
                shutdown: false,
            }),
            start: Condvar::new(),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        let handles = if threads > 1 {
            (0..threads)
                .map(|idx| {
                    let s = Arc::clone(&shared);
                    std::thread::Builder::new()
                        .name(format!("rk-exec-{idx}"))
                        .spawn(move || worker(idx, &s))
                        .expect("spawn exec pool worker")
                })
                .collect()
        } else {
            Vec::new()
        };
        Arc::new(ExecPool {
            shared,
            submit: Mutex::new(()),
            handles,
            threads,
            dispatches: AtomicU64::new(0),
        })
    }

    /// Number of worker threads the pool was sized for.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Parallel dispatches executed so far (serial fast-path jobs are not
    /// counted) — the `PruneStats::pool_dispatches` feed.
    pub fn dispatches(&self) -> u64 {
        self.dispatches.load(Ordering::Relaxed)
    }

    /// Run `f(index, &mut works[index])` once for every work item,
    /// spreading items over at most `threads` pool workers (0 = the whole
    /// pool) via an atomic cursor. Items are mutated in place, so the
    /// caller reads results back in item order — scheduling never affects
    /// the output. Returns `true` when the job was dispatched to the pool
    /// (vs. the serial fast path). Panics in `f` are re-raised here after
    /// every worker has finished the job.
    pub fn run_chunks<W, F>(&self, works: &mut [W], threads: usize, f: F) -> bool
    where
        W: Send,
        F: Fn(usize, &mut W) + Sync,
    {
        let requested = if threads == 0 { self.threads } else { threads };
        let t = requested.min(self.threads).min(works.len());
        if t <= 1 || self.handles.is_empty() {
            for (i, w) in works.iter_mut().enumerate() {
                f(i, w);
            }
            return false;
        }

        let next = AtomicUsize::new(0);
        // Each index is claimed exactly once, so the per-item locks are
        // uncontended; they only exist to hand `&mut W` across threads.
        let cells: Vec<Mutex<&mut W>> = works.iter_mut().map(Mutex::new).collect();
        let body = |_worker: usize| loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= cells.len() {
                break;
            }
            let mut guard = cells[i].lock().expect("chunk lock");
            f(i, &mut **guard);
        };
        self.dispatch(&body, t);
        true
    }

    /// [`ExecPool::run_chunks`] with an explicit **claim order**: the
    /// atomic cursor walks `order` instead of `0..len`, so a size-graded
    /// caller can hand out the largest items first and cut tail latency
    /// under skewed per-item cost (shard builds over a Zipf fact table,
    /// pruned engine chunks). Items are still mutated in place and read
    /// back in *item* order, so the schedule affects only wall-clock —
    /// never the output: for any `order`, results are bitwise identical
    /// to [`ExecPool::run_chunks`]. `order` must be a permutation of
    /// `0..works.len()` (checked in debug builds).
    pub fn run_chunks_ordered<W, F>(
        &self,
        works: &mut [W],
        threads: usize,
        order: &[usize],
        f: F,
    ) -> bool
    where
        W: Send,
        F: Fn(usize, &mut W) + Sync,
    {
        debug_assert_eq!(order.len(), works.len(), "order must cover every work item");
        debug_assert!(
            {
                let mut seen = vec![false; works.len()];
                order
                    .iter()
                    .all(|&i| i < works.len() && !std::mem::replace(&mut seen[i], true))
            },
            "order must be a permutation of 0..works.len()"
        );
        let requested = if threads == 0 { self.threads } else { threads };
        let t = requested.min(self.threads).min(works.len());
        if t <= 1 || self.handles.is_empty() {
            for &i in order {
                f(i, &mut works[i]);
            }
            return false;
        }

        let next = AtomicUsize::new(0);
        let cells: Vec<Mutex<&mut W>> = works.iter_mut().map(Mutex::new).collect();
        let body = |_worker: usize| loop {
            let pos = next.fetch_add(1, Ordering::Relaxed);
            if pos >= order.len() {
                break;
            }
            let i = order[pos];
            let mut guard = cells[i].lock().expect("chunk lock");
            f(i, &mut **guard);
        };
        self.dispatch(&body, t);
        true
    }

    /// The epoch-counted condvar handshake shared by every dispatch
    /// flavor: hand `body` to the workers, wake them, wait for all
    /// acknowledgements, re-raise any payload panic.
    fn dispatch(&self, body: &(dyn Fn(usize) + Sync), active: usize) {
        let task = Task(body as *const (dyn Fn(usize) + Sync));

        let _submit = lock_unpoisoned(&self.submit);
        {
            let mut c = lock_unpoisoned(&self.shared.ctrl);
            c.epoch += 1;
            c.active = active;
            c.task = Some(task);
            c.remaining = self.handles.len();
            self.shared.start.notify_all();
            while c.remaining > 0 {
                c = self.shared.done.wait(c).unwrap_or_else(|e| e.into_inner());
            }
            c.task = None;
        }
        self.dispatches.fetch_add(1, Ordering::Relaxed);
        if self.shared.panicked.swap(false, Ordering::SeqCst) {
            panic!("ExecPool worker panicked during a chunk dispatch");
        }
    }
}

impl Drop for ExecPool {
    fn drop(&mut self) {
        {
            let mut c = lock_unpoisoned(&self.shared.ctrl);
            c.shutdown = true;
        }
        self.shared.start.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl std::fmt::Debug for ExecPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ExecPool")
            .field("threads", &self.threads)
            .field("dispatches", &self.dispatches())
            .finish()
    }
}

/// The process-wide shared pool: created lazily at the machine's
/// parallelism (honoring `RKMEANS_THREADS` at first use), then reused by
/// every default-configured engine, scorer and coordinator job for the
/// rest of the process. Per-job `threads` limits apply at dispatch time.
pub fn shared_pool() -> Arc<ExecPool> {
    static SHARED: OnceLock<Arc<ExecPool>> = OnceLock::new();
    Arc::clone(SHARED.get_or_init(|| ExecPool::new(0)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn visits_every_item_exactly_once() {
        let pool = ExecPool::new(4);
        let mut works: Vec<u32> = vec![0; 137];
        let parallel = pool.run_chunks(&mut works, 4, |i, w| *w += i as u32 + 1);
        assert!(parallel);
        for (i, w) in works.iter().enumerate() {
            assert_eq!(*w, i as u32 + 1);
        }
        assert_eq!(pool.dispatches(), 1);
    }

    #[test]
    fn serial_fast_paths() {
        // Single item, single requested thread, and a 1-thread pool all
        // run on the caller without a dispatch.
        let pool = ExecPool::new(4);
        let mut one = [7u32];
        assert!(!pool.run_chunks(&mut one, 4, |_, w| *w += 1));
        assert_eq!(one[0], 8);
        let mut works = vec![0u32; 10];
        assert!(!pool.run_chunks(&mut works, 1, |i, w| *w = i as u32));
        assert_eq!(works[9], 9);

        let single = ExecPool::new(1);
        let mut works = vec![0u32; 10];
        assert!(!single.run_chunks(&mut works, 0, |i, w| *w = i as u32 * 2));
        assert_eq!(works[5], 10);
        assert_eq!(single.dispatches(), 0);
    }

    #[test]
    fn reusable_across_many_jobs() {
        let pool = ExecPool::new(3);
        let mut works: Vec<u64> = vec![0; 64];
        for round in 1..=50u64 {
            pool.run_chunks(&mut works, 0, |_, w| *w += round);
        }
        let want: u64 = (1..=50).sum();
        assert!(works.iter().all(|&w| w == want));
        assert_eq!(pool.dispatches(), 50);
    }

    #[test]
    fn active_worker_clamp_does_not_change_results() {
        let pool = ExecPool::new(8);
        let mut base: Vec<u32> = (0..500).collect();
        pool.run_chunks(&mut base, 2, |i, w| *w = w.wrapping_mul(31).wrapping_add(i as u32));
        for t in [3usize, 8, 64] {
            let mut works: Vec<u32> = (0..500).collect();
            pool.run_chunks(&mut works, t, |i, w| {
                *w = w.wrapping_mul(31).wrapping_add(i as u32)
            });
            assert_eq!(works, base, "threads={t}");
        }
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = ExecPool::new(2);
        let mut works = vec![0u32; 8];
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run_chunks(&mut works, 2, |i, _| {
                if i == 3 {
                    panic!("boom");
                }
            });
        }));
        assert!(result.is_err(), "worker panic must reach the submitter");
        // The pool stays usable after a payload panic.
        let mut works = vec![0u32; 8];
        assert!(pool.run_chunks(&mut works, 2, |i, w| *w = i as u32));
        assert_eq!(works[7], 7);
    }

    #[test]
    fn ordered_schedule_visits_every_item_exactly_once() {
        let pool = ExecPool::new(4);
        let mut works: Vec<u32> = vec![0; 137];
        let order: Vec<usize> = (0..works.len()).rev().collect();
        let parallel = pool.run_chunks_ordered(&mut works, 4, &order, |i, w| {
            *w += i as u32 + 1
        });
        assert!(parallel);
        for (i, w) in works.iter().enumerate() {
            assert_eq!(*w, i as u32 + 1);
        }
    }

    #[test]
    fn ordered_schedule_is_bitwise_equal_to_default_schedule() {
        // The claim order affects only which worker computes an item —
        // results must be bit-for-bit the schedule-free answer.
        let pool = ExecPool::new(4);
        let mut base: Vec<u64> = (0..301).map(|i| i * 17 + 3).collect();
        pool.run_chunks(&mut base, 0, |i, w| {
            *w = w.wrapping_mul(0x9e37_79b9).rotate_left((i % 31) as u32)
        });
        let orders: Vec<Vec<usize>> = vec![
            (0..301).collect(),
            (0..301).rev().collect(),
            (0..301).map(|i| (i * 151) % 301).collect(), // gcd(151, 301) = 1
        ];
        for order in &orders {
            let mut works: Vec<u64> = (0..301).map(|i| i * 17 + 3).collect();
            pool.run_chunks_ordered(&mut works, 0, order, |i, w| {
                *w = w.wrapping_mul(0x9e37_79b9).rotate_left((i % 31) as u32)
            });
            assert_eq!(works, base);
        }
    }

    #[test]
    fn ordered_serial_fast_path_follows_the_order() {
        let single = ExecPool::new(1);
        let mut works = vec![0u32; 6];
        let order = [5usize, 3, 1, 0, 2, 4];
        let log = Mutex::new(Vec::new());
        assert!(!single.run_chunks_ordered(&mut works, 0, &order, |i, w| {
            *w = i as u32;
            log.lock().expect("order log lock").push(i);
        }));
        assert_eq!(*log.lock().expect("order log lock"), order);
        assert_eq!(works, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn shared_pool_is_a_singleton() {
        let a = shared_pool();
        let b = shared_pool();
        assert!(Arc::ptr_eq(&a, &b));
        assert!(a.threads() >= 1);
    }

    #[test]
    fn thread_resolution_prefers_explicit() {
        assert_eq!(resolve_threads(3), 3);
        assert!(resolve_threads(0) >= 1);
    }
}
