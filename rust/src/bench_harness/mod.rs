//! Benchmark harness: timing utilities and the paper-table renderers used
//! by `examples/paper_tables.rs` and the `rust/benches/*` targets. The
//! environment is offline (no criterion), so the harness implements the
//! warmup + repeated-measurement + min/mean/median reporting itself.

pub mod paper;

use crate::util::timer::secs;
use std::time::{Duration, Instant};

/// One measured benchmark: run statistics in seconds.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub samples: Vec<f64>,
}

impl Measurement {
    /// Fastest observed run (criterion's preferred robust statistic).
    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Mean of samples.
    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len().max(1) as f64
    }

    /// Median of samples.
    pub fn median(&self) -> f64 {
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).expect("finite"));
        s[s.len() / 2]
    }

    /// Render one line: `name  min  mean  median  (n samples)`.
    pub fn line(&self) -> String {
        format!(
            "{:<44} min {:>9.4}s  mean {:>9.4}s  median {:>9.4}s  (n={})",
            self.name,
            self.min(),
            self.mean(),
            self.median(),
            self.samples.len()
        )
    }
}

/// Time `f` `samples` times after `warmup` unmeasured runs.
pub fn bench<T>(
    name: &str,
    warmup: usize,
    samples: usize,
    mut f: impl FnMut() -> T,
) -> Measurement {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut out = Vec::with_capacity(samples);
    for _ in 0..samples.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        out.push(secs(t0.elapsed()));
    }
    Measurement { name: name.to_string(), samples: out }
}

/// Time a single (expensive, end-to-end) run.
pub fn bench_once<T>(name: &str, f: impl FnOnce() -> T) -> (T, Measurement) {
    let t0 = Instant::now();
    let v = f();
    let d = secs(t0.elapsed());
    (v, Measurement { name: name.to_string(), samples: vec![d] })
}

/// A markdown-style table builder for the paper-table reports.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with a title and column headers.
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (stringified cells).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells);
    }

    /// Render as a GitHub-flavored markdown table.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncol {
                s.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            s
        };
        let mut out = format!("\n### {}\n\n", self.title);
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// Format a duration in seconds with appropriate precision.
pub fn fmt_secs(d: Duration) -> String {
    let s = secs(d);
    if s < 0.01 {
        format!("{:.2}ms", s * 1000.0)
    } else {
        format!("{s:.2}s")
    }
}

/// Format a speedup factor like the paper (`15.38×`).
pub fn fmt_speedup(x: f64) -> String {
    format!("{x:.2}×")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measurement_stats() {
        let m = Measurement { name: "x".into(), samples: vec![3.0, 1.0, 2.0] };
        assert_eq!(m.min(), 1.0);
        assert_eq!(m.mean(), 2.0);
        assert_eq!(m.median(), 2.0);
        assert!(m.line().contains("x"));
    }

    #[test]
    fn bench_runs_and_counts() {
        let mut calls = 0;
        let m = bench("inc", 2, 5, || {
            calls += 1;
            calls
        });
        assert_eq!(m.samples.len(), 5);
        assert_eq!(calls, 7);
    }

    #[test]
    fn table_renders_markdown() {
        let mut t = Table::new("Demo", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("### Demo"));
        assert!(r.contains("| a "));
        assert!(r.contains("| 1 "));
        assert!(r.lines().any(|l| l.starts_with("|--") || l.starts_with("|---")));
    }

    #[test]
    #[should_panic(expected = "column count")]
    fn table_rejects_bad_rows() {
        let mut t = Table::new("Demo", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_speedup(15.379), "15.38×");
        assert!(fmt_secs(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_secs(Duration::from_secs(2)).ends_with('s'));
    }
}
