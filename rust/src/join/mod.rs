//! Join materialization — the *baseline* path Rk-means avoids.
//!
//! The conventional workflow (paper Fig. 1a) computes the FEQ output `X`
//! (here: [`materialize`], the stand-in for PostgreSQL in the paper's
//! experiments), one-hot encodes it ([`embed`]) and runs k-means on the
//! dense matrix. `X` can be polynomially larger than the database
//! (`|X| ≤ N^ρ*`), which is exactly the cost Rk-means sidesteps.
//!
//! [`stream_rows`] enumerates the join output *without storing it* — used
//! to evaluate clustering objectives over the full `X` with O(1) memory,
//! and as the semantics oracle in integration tests.
//!
//! [`acyclic`] rewrites cyclic FEQs into acyclic ones by greedily merging
//! relations (a poor man's hypertree decomposition), so the rest of the
//! pipeline can assume a join tree exists.

pub mod acyclic;
pub mod embed;
pub mod materialize;

pub use acyclic::ensure_acyclic;
pub use embed::{EmbedSpec, FeatEmb};
pub use materialize::{materialize, materialize_capped, stream_rows, DataMatrix};
