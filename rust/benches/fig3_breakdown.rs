//! Bench F3 — regenerates paper Figure 3: per-step time breakdown of
//! Rk-means vs k, with the compute-X reference bar, per dataset.

use rkmeans::bench_harness::paper::{fig3, PaperCfg};
use rkmeans::synthetic::Dataset;

fn main() -> anyhow::Result<()> {
    let scale: f64 =
        std::env::var("RKMEANS_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.05);
    let mut cfg = PaperCfg::new(scale);
    cfg.eval_approx = false; // breakdown only
    for ds in Dataset::all() {
        println!("{}", fig3(ds, &cfg)?.render());
    }
    Ok(())
}
