//! Cross-engine integration: the XLA/PJRT artifact path must be
//! interchangeable with the native engine on real coreset workloads (not
//! just synthetic blobs). Skips gracefully when `artifacts/` is absent.
//! The whole file is gated on the `pjrt` feature (the default build has no
//! PJRT backend at all).
#![cfg(feature = "pjrt")]

use rkmeans::cluster::{weighted_lloyd, LloydConfig};
use rkmeans::coreset::{build_grid, grid_dense_embed, solve_subspaces};
use rkmeans::faq::{full_join_counts, marginals};
use rkmeans::join::EmbedSpec;
use rkmeans::query::Hypergraph;
use rkmeans::runtime::PjrtRuntime;
use rkmeans::synthetic::{Dataset, Scale};
use rkmeans::util::SplitMix64;

fn runtime() -> Option<PjrtRuntime> {
    let dir = PjrtRuntime::default_dir();
    if !PjrtRuntime::available(&dir) {
        eprintln!("skipping xla tests: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(PjrtRuntime::load(&dir).expect("load runtime"))
}

#[test]
fn xla_clusters_a_real_coreset() {
    let Some(rt) = runtime() else { return };
    // Retailer at tiny scale has one-hot D well inside the 64-dim bucket.
    let ds = Dataset::Retailer;
    let db = ds.generate(Scale::tiny(), 31);
    let feq = ds.feq();
    let tree = Hypergraph::from_feq(&db, &feq).join_tree().unwrap();
    let jc = full_join_counts(&db, &tree).unwrap();
    let margs = marginals(&db, &feq, &tree, &jc).unwrap();
    let k = 6;
    let models = solve_subspaces(&feq, &margs, k).unwrap();
    let (grid, _) = build_grid(&db, &feq, &tree, &models).unwrap();
    let spec = EmbedSpec::from_feq(&db, &feq).unwrap();
    assert!(spec.dims <= 64, "tiny retailer must fit the 64-dim bucket (D={})", spec.dims);

    let dense = grid_dense_embed(&grid, &models, &spec);
    let cfg = LloydConfig { k, seed: 9, ..LloydConfig::new(k) };
    let native = weighted_lloyd(&dense, &grid.weights, spec.dims, &cfg);
    let xla = rt.lloyd(&dense, &grid.weights, spec.dims, &cfg).expect("xla lloyd");

    // Same seeding + same algorithm, but the artifact computes distances
    // in f32 while Retailer's raw census features reach ~1e5 (squares
    // ~1e10): boundary assignments can flip and Lloyd then settles in a
    // nearby local optimum. Objectives must still agree to a few percent.
    let rel = (native.objective - xla.objective).abs() / native.objective.max(1e-9);
    assert!(
        rel < 0.10,
        "native {} vs xla {} (rel {rel:.4})",
        native.objective,
        xla.objective
    );
}

#[test]
fn xla_native_agree_across_shapes() {
    let Some(rt) = runtime() else { return };
    let mut rng = SplitMix64::new(77);
    for (n, d, k) in [(300usize, 5usize, 4usize), (1500, 12, 9), (5000, 30, 14)] {
        let pts: Vec<f64> = (0..n * d).map(|_| rng.uniform(-3.0, 3.0)).collect();
        let w: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 2.0)).collect();
        let cfg = LloydConfig { k, seed: 123, ..LloydConfig::new(k) };
        let native = weighted_lloyd(&pts, &w, d, &cfg);
        let xla = rt.lloyd(&pts, &w, d, &cfg).expect("xla lloyd");
        let rel = (native.objective - xla.objective).abs() / native.objective.max(1e-9);
        assert!(
            rel < 2e-2,
            "shape ({n},{d},{k}): native {} vs xla {}",
            native.objective,
            xla.objective
        );
    }
}

#[test]
fn oversized_requests_fail_cleanly() {
    let Some(rt) = runtime() else { return };
    let pts = vec![0.0f64; 10 * 200]; // D=200 exceeds every bucket
    let w = vec![1.0; 10];
    let err = rt.lloyd(&pts, &w, 200, &LloydConfig::new(2)).unwrap_err();
    assert!(err.to_string().contains("no artifact bucket"), "{err}");
}
