//! Regeneration of every table and figure in the paper's evaluation (§5),
//! on the synthetic workloads. Shared by `examples/paper_tables.rs`, the
//! `rust/benches/*` targets and the `rkmeans tables` CLI.
//!
//! | paper artifact | function |
//! |---|---|
//! | Table 1 (dataset/coreset statistics) | [`table1`] |
//! | Table 2 (end-to-end runtime + approximation) | [`table2`] |
//! | Figure 3 (per-step breakdown) | [`fig3`] |
//! | §4.2 FD-chain grid compression (Thm 4.6) | [`ablation_fd`] |
//! | §4.3 factored vs generic Step 4 | [`ablation_sparse`] |
//! | §5 κ < k sweep | [`kappa_sweep`] |

use super::{fmt_secs, fmt_speedup, LloydBenchRecord, Table};
use crate::cluster::{
    sparse_lloyd_with, weighted_lloyd, weighted_lloyd_with, EngineOpts, LloydConfig, PruneStats,
};
use crate::coreset::{build_grid, grid_dense_embed, solve_subspaces};
use crate::data::Database;
use crate::faq::{full_join_counts, marginals, output_size};
use crate::join::EmbedSpec;
use crate::query::{Feq, Hypergraph};
use crate::rkmeans::{
    full_objective, materialize_and_cluster_capped, rkmeans_with_tree, RkConfig,
};
use crate::synthetic::{Dataset, Scale};
use crate::util::{human_bytes, human_count};
use anyhow::Result;
use std::time::Instant;

/// Shared configuration for the paper-table runs.
#[derive(Clone, Debug)]
pub struct PaperCfg {
    /// Synthetic scale factor (1.0 ≈ paper-shaped millions of rows).
    pub scale: f64,
    pub seed: u64,
    /// k values for Table 2 / Figure 3 (paper: 5, 10, 20, 50).
    pub ks: Vec<usize>,
    /// κ values for Table 1 (paper: 5, 10, 20, 50).
    pub kappas: Vec<usize>,
    /// Baseline materialization cap (rows) to avoid OOM at big scales.
    pub baseline_cap: u64,
    /// Evaluate the relative approximation on the full `X` (costs a
    /// streaming pass per configuration).
    pub eval_approx: bool,
}

impl PaperCfg {
    /// Bench defaults: paper k/κ grids at a laptop-sized scale.
    pub fn new(scale: f64) -> Self {
        PaperCfg {
            scale,
            seed: 42,
            ks: vec![5, 10, 20, 50],
            kappas: vec![5, 10, 20, 50],
            baseline_cap: 50_000_000,
            eval_approx: true,
        }
    }

    /// Small smoke configuration for tests.
    pub fn smoke() -> Self {
        PaperCfg {
            scale: 0.002,
            seed: 7,
            ks: vec![3, 5],
            kappas: vec![3, 5],
            baseline_cap: 2_000_000,
            eval_approx: true,
        }
    }
}

/// Grid size `|G|` after steps 1–3 only.
fn coreset_size(db: &Database, feq: &Feq, kappa: usize) -> Result<usize> {
    let tree = Hypergraph::from_feq(db, feq).join_tree()?;
    let jc = full_join_counts(db, &tree)?;
    let margs = marginals(db, feq, &tree, &jc)?;
    let models = solve_subspaces(feq, &margs, kappa)?;
    let (grid, _) = build_grid(db, feq, &tree, &models)?;
    Ok(grid.n())
}

/// **Table 1**: statistics for `D`, `X` and the coreset `G` per dataset.
pub fn table1(cfg: &PaperCfg) -> Result<Table> {
    let mut header: Vec<String> = [
        "", "Relations", "Attributes", "One-hot enc.", "#Rows D", "Size D", "#Rows X", "Size X",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    for &kappa in &cfg.kappas {
        header.push(format!("|G| κ={kappa}"));
    }
    let hrefs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        &format!("Table 1 — dataset and coreset statistics (scale {})", cfg.scale),
        &hrefs,
    );
    for ds in Dataset::all() {
        let db = ds.generate(Scale::custom(cfg.scale), cfg.seed);
        let feq = ds.feq();
        let spec = EmbedSpec::from_feq(&db, &feq)?;
        let tree = Hypergraph::from_feq(&db, &feq).join_tree()?;
        let x_rows = output_size(&db, &tree)?;
        // Size of X as the paper reports it: materialized row width ×
        // rows (8 bytes per feature value, pre-one-hot).
        let x_bytes = (x_rows as u64) * (feq.n_features() as u64 * 8 + 8);
        let attrs: usize = db.relations().iter().map(|r| r.schema.len()).sum();

        let mut cells = vec![
            ds.name().to_string(),
            db.relations().len().to_string(),
            attrs.to_string(),
            spec.dims.to_string(),
            human_count(db.total_rows()),
            human_bytes(db.total_bytes()),
            human_count(x_rows as u64),
            human_bytes(x_bytes),
        ];
        for &kappa in &cfg.kappas {
            cells.push(human_count(coreset_size(&db, &feq, kappa)? as u64));
        }
        t.row(cells);
    }
    Ok(t)
}

/// One Table-2 style measurement.
#[derive(Clone, Debug)]
pub struct EndToEnd {
    pub k: usize,
    pub kappa: usize,
    pub t_materialize: f64,
    pub t_baseline_cluster: f64,
    pub t_rkmeans: f64,
    pub speedup: f64,
    /// `L(rkmeans on X) / L(baseline on X) − 1` (paper's Relative Approx.)
    pub rel_approx: Option<f64>,
    pub grid_points: usize,
    pub baseline_bytes: u64,
}

/// Run one (dataset, k, κ) end-to-end comparison.
pub fn end_to_end(
    db: &Database,
    feq: &Feq,
    k: usize,
    kappa: usize,
    cfg: &PaperCfg,
) -> Result<EndToEnd> {
    let tree = Hypergraph::from_feq(db, feq).join_tree()?;

    let t0 = Instant::now();
    let rk = rkmeans_with_tree(
        db,
        feq,
        &tree,
        &RkConfig::new(k).with_kappa(kappa).with_seed(cfg.seed),
    )?;
    let t_rkmeans = t0.elapsed().as_secs_f64();

    let lcfg = LloydConfig { k, seed: cfg.seed, ..LloydConfig::new(k) };
    let base = materialize_and_cluster_capped(db, feq, &lcfg, cfg.baseline_cap)?;
    let t_materialize = base.t_materialize.as_secs_f64() + base.t_embed.as_secs_f64();
    let t_baseline_cluster = base.t_cluster.as_secs_f64();

    let rel_approx = if cfg.eval_approx {
        let rk_full = full_objective(db, feq, &rk)?;
        Some((rk_full / base.objective.max(1e-30) - 1.0).max(0.0))
    } else {
        None
    };

    Ok(EndToEnd {
        k,
        kappa,
        t_materialize,
        t_baseline_cluster,
        t_rkmeans,
        speedup: (t_materialize + t_baseline_cluster) / t_rkmeans.max(1e-9),
        rel_approx,
        grid_points: rk.grid_points,
        baseline_bytes: base.dense_bytes,
    })
}

/// **Table 2**: end-to-end runtime and approximation for one dataset,
/// κ = k columns plus the κ < k columns (20/10 and 50/20 as in the paper).
pub fn table2(ds: Dataset, cfg: &PaperCfg) -> Result<Table> {
    let db = ds.generate(Scale::custom(cfg.scale), cfg.seed);
    let feq = ds.feq();
    let mut configs: Vec<(usize, usize)> = cfg.ks.iter().map(|&k| (k, k)).collect();
    // The paper's κ < k columns, when in range.
    for (k, kappa) in [(20, 10), (50, 20)] {
        if cfg.ks.contains(&k) {
            configs.push((k, kappa));
        }
    }

    let mut t = Table::new(
        &format!("Table 2 — {} end-to-end (scale {})", ds.name(), cfg.scale),
        &[
            "k", "κ", "Compute X", "Cluster (baseline)", "Rk-means", "Speedup", "Rel.Approx",
            "|G|",
        ],
    );
    for (k, kappa) in configs {
        let e = end_to_end(&db, &feq, k, kappa, cfg)?;
        t.row(vec![
            k.to_string(),
            kappa.to_string(),
            format!("{:.2}s", e.t_materialize),
            format!("{:.2}s", e.t_baseline_cluster),
            format!("{:.2}s", e.t_rkmeans),
            fmt_speedup(e.speedup),
            e.rel_approx.map(|a| format!("{a:.3}")).unwrap_or_else(|| "-".into()),
            human_count(e.grid_points as u64),
        ]);
    }
    Ok(t)
}

/// **Figure 3**: per-step breakdown vs k, with the compute-X reference.
pub fn fig3(ds: Dataset, cfg: &PaperCfg) -> Result<Table> {
    let db = ds.generate(Scale::custom(cfg.scale), cfg.seed);
    let feq = ds.feq();
    let tree = Hypergraph::from_feq(&db, &feq).join_tree()?;

    // Reference bar: time to materialize X (our "psql").
    let t0 = Instant::now();
    let x = crate::join::materialize_capped(&db, &feq, &tree, cfg.baseline_cap)?;
    let t_x = t0.elapsed();
    drop(x);

    let mut t = Table::new(
        &format!("Figure 3 — {} step breakdown (scale {}; compute-X ref {})",
                 ds.name(), cfg.scale, fmt_secs(t_x)),
        &["k", "Step1 marginals", "Step2 subspaces", "Step3 grid", "Step4 cluster", "Total"],
    );
    for &k in &cfg.ks {
        let rk = rkmeans_with_tree(
            &db,
            &feq,
            &tree,
            &RkConfig::new(k).with_seed(cfg.seed),
        )?;
        t.row(vec![
            k.to_string(),
            fmt_secs(rk.timings.step1_marginals),
            fmt_secs(rk.timings.step2_subspaces),
            fmt_secs(rk.timings.step3_grid),
            fmt_secs(rk.timings.step4_cluster),
            fmt_secs(rk.timings.total()),
        ]);
    }
    Ok(t)
}

/// **FD ablation** (Theorem 4.6): on Retailer's FD-chain features the
/// number of non-zero grid cells is `O(Σ dᵢ(κ−1))`, exponentially below
/// the naive `κ^d` cross-product grid.
pub fn ablation_fd(cfg: &PaperCfg) -> Result<Table> {
    let db = Dataset::Retailer.generate(Scale::custom(cfg.scale), cfg.seed);
    // FD-chain features only: zip -> city -> state (+ store_type control).
    let feq = Feq::with_features(
        &["inventory", "location", "census", "weather", "items"],
        &["zip", "city", "state", "store_type"],
    );
    let chains = db.fd_chains(&[
        "zip".to_string(),
        "city".to_string(),
        "state".to_string(),
        "store_type".to_string(),
    ]);

    let mut t = Table::new(
        &format!("FD ablation (Thm 4.6) — Retailer FD-chain features (scale {})", cfg.scale),
        &["κ", "|G| (sparse FAQ)", "cross-product κ^d", "FD bound Π(1+dᵢ(κ−1))"],
    );
    for &kappa in &cfg.kappas {
        let g = coreset_size(&db, &feq, kappa)?;
        let cross = (kappa as u128).pow(4);
        let bound: u128 = chains
            .iter()
            .map(|c| 1 + (c.len() as u128) * (kappa as u128 - 1))
            .product();
        t.row(vec![
            kappa.to_string(),
            g.to_string(),
            cross.to_string(),
            bound.to_string(),
        ]);
        // The theorem must hold on the data.
        anyhow::ensure!(
            (g as u128) <= bound,
            "FD bound violated: |G|={g} > bound={bound} at κ={kappa}"
        );
    }
    Ok(t)
}

/// **Step-4 ablation** (§4.3): factored sparse Lloyd vs generic dense
/// Lloyd over the one-hot-embedded grid — same coreset, same k.
pub fn ablation_sparse(ds: Dataset, k: usize, cfg: &PaperCfg) -> Result<Table> {
    let db = ds.generate(Scale::custom(cfg.scale), cfg.seed);
    let feq = ds.feq();
    let tree = Hypergraph::from_feq(&db, &feq).join_tree()?;
    let jc = full_join_counts(&db, &tree)?;
    let margs = marginals(&db, &feq, &tree, &jc)?;
    let models = solve_subspaces(&feq, &margs, k)?;
    let (grid, subspaces) = build_grid(&db, &feq, &tree, &models)?;
    let spec = EmbedSpec::from_feq(&db, &feq)?;

    let lcfg = LloydConfig { k, seed: cfg.seed, ..LloydConfig::new(k) };

    let t0 = Instant::now();
    let sparse = crate::cluster::sparse_lloyd(&grid, &subspaces, &lcfg);
    let t_sparse = t0.elapsed();

    let t0 = Instant::now();
    let dense_pts = grid_dense_embed(&grid, &models, &spec);
    let dense = weighted_lloyd(&dense_pts, &grid.weights, spec.dims, &lcfg);
    let t_dense = t0.elapsed();

    let mut t = Table::new(
        &format!(
            "Step-4 ablation — {} k={k} |G|={} D={} (scale {})",
            ds.name(),
            grid.n(),
            spec.dims,
            cfg.scale
        ),
        &["engine", "time", "objective", "iters"],
    );
    t.row(vec![
        "factored sparse Lloyd (§4.3)".into(),
        fmt_secs(t_sparse),
        format!("{:.4e}", sparse.objective),
        sparse.iters.to_string(),
    ]);
    t.row(vec![
        "generic dense Lloyd (embed + O(|G|Dk))".into(),
        fmt_secs(t_dense),
        format!("{:.4e}", dense.objective),
        dense.iters.to_string(),
    ]);
    Ok(t)
}

/// **Step-4 engine ablation**: naive vs. bounds-pruned engine paths on
/// one dataset's grid coreset, in both factored and dense form, with
/// pruning statistics — the per-dataset view of the `BENCH_lloyd.json`
/// trajectory. `tol = 0` fixes the iteration count so every path does the
/// same logical work, and the naive/pruned pairs are asserted to agree
/// exactly (the engine's bitwise-determinism contract).
pub fn engine_ablation(
    ds: Dataset,
    k: usize,
    iters: usize,
    cfg: &PaperCfg,
) -> Result<(Table, Vec<LloydBenchRecord>)> {
    let db = ds.generate(Scale::custom(cfg.scale), cfg.seed);
    let feq = ds.feq();
    let tree = Hypergraph::from_feq(&db, &feq).join_tree()?;
    let jc = full_join_counts(&db, &tree)?;
    let margs = marginals(&db, &feq, &tree, &jc)?;
    let models = solve_subspaces(&feq, &margs, k)?;
    let (grid, subspaces) = build_grid(&db, &feq, &tree, &models)?;
    let spec = EmbedSpec::from_feq(&db, &feq)?;
    let lcfg = LloydConfig { k, max_iters: iters, tol: 0.0, seed: cfg.seed };
    let label = format!("{}-grid", ds.name().to_lowercase());

    let (fac_naive, fs0) = sparse_lloyd_with(&grid, &subspaces, &lcfg, &EngineOpts::naive_serial());
    let (fac_pruned, fs1) = sparse_lloyd_with(&grid, &subspaces, &lcfg, &EngineOpts::pruned());
    anyhow::ensure!(
        fac_naive.assign == fac_pruned.assign && fac_naive.objective == fac_pruned.objective,
        "factored engine paths diverged on {}",
        ds.name()
    );

    let dense_pts = grid_dense_embed(&grid, &models, &spec);
    let naive_opts = EngineOpts::naive_serial();
    let (den_naive, ds0) =
        weighted_lloyd_with(&dense_pts, &grid.weights, spec.dims, &lcfg, &naive_opts);
    let (den_pruned, ds1) =
        weighted_lloyd_with(&dense_pts, &grid.weights, spec.dims, &lcfg, &EngineOpts::pruned());
    anyhow::ensure!(
        den_naive.assign == den_pruned.assign && den_naive.objective == den_pruned.objective,
        "dense engine paths diverged on {}",
        ds.name()
    );

    let mut t = Table::new(
        &format!(
            "Step-4 engine ablation — {} k={k} |G|={} D={} (scale {})",
            ds.name(),
            grid.n(),
            spec.dims,
            cfg.scale
        ),
        &["engine", "time", "points/s", "evals", "skipped", "skip%", "objective", "iters"],
    );
    let mut records: Vec<LloydBenchRecord> = Vec::with_capacity(4);
    let mut push =
        |engine: &str, dims: usize, objective: f64, stats: &PruneStats, naive: Option<usize>| {
            let mut rec = LloydBenchRecord::from_stats(&label, engine, dims, k, objective, stats);
            if let Some(idx) = naive {
                rec = rec.with_speedup_vs(&records[idx]);
            }
            t.row(vec![
                engine.to_string(),
                format!("{:.3}s", rec.wall_s),
                format!("{:.0}", rec.points_per_sec),
                rec.dist_evals.to_string(),
                rec.dist_evals_skipped.to_string(),
                format!("{:.1}%", 100.0 * rec.skip_rate),
                format!("{:.4e}", rec.objective),
                rec.iters.to_string(),
            ]);
            records.push(rec);
        };
    push("factored-naive", grid.m, fac_naive.objective, &fs0, None);
    push("factored-pruned", grid.m, fac_pruned.objective, &fs1, Some(0));
    push("dense-naive", spec.dims, den_naive.objective, &ds0, None);
    push("dense-pruned", spec.dims, den_pruned.objective, &ds1, Some(2));
    drop(push);

    Ok((t, records))
}

/// **κ sweep** (speed/approximation tradeoff, Prop 3.3b).
pub fn kappa_sweep(ds: Dataset, k: usize, kappas: &[usize], cfg: &PaperCfg) -> Result<Table> {
    let db = ds.generate(Scale::custom(cfg.scale), cfg.seed);
    let feq = ds.feq();
    let tree = Hypergraph::from_feq(&db, &feq).join_tree()?;

    let mut t = Table::new(
        &format!("κ sweep — {} k={k} (scale {})", ds.name(), cfg.scale),
        &["κ", "|G|", "time", "grid objective", "quantization", "full objective"],
    );
    for &kappa in kappas {
        let t0 = Instant::now();
        let rk = rkmeans_with_tree(
            &db,
            &feq,
            &tree,
            &RkConfig::new(k).with_kappa(kappa).with_seed(cfg.seed),
        )?;
        let elapsed = t0.elapsed();
        let full = if cfg.eval_approx {
            format!("{:.4e}", full_objective(&db, &feq, &rk)?)
        } else {
            "-".into()
        };
        t.row(vec![
            kappa.to_string(),
            human_count(rk.grid_points as u64),
            fmt_secs(elapsed),
            format!("{:.4e}", rk.objective_grid),
            format!("{:.4e}", rk.quantization_cost),
            full,
        ]);
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_smoke() {
        let t = table1(&PaperCfg::smoke()).unwrap();
        assert_eq!(t.rows.len(), 3);
        assert!(t.render().contains("Retailer"));
    }

    #[test]
    fn table2_smoke() {
        let mut cfg = PaperCfg::smoke();
        cfg.ks = vec![3];
        let t = table2(Dataset::Retailer, &cfg).unwrap();
        assert_eq!(t.rows.len(), 1);
        // Speedup column parses as a positive factor.
        let sp = &t.rows[0][5];
        assert!(sp.ends_with('×'));
    }

    #[test]
    fn fig3_smoke() {
        let mut cfg = PaperCfg::smoke();
        cfg.ks = vec![3];
        let t = fig3(Dataset::Favorita, &cfg).unwrap();
        assert_eq!(t.rows.len(), 1);
    }

    #[test]
    fn ablation_fd_bound_holds() {
        let mut cfg = PaperCfg::smoke();
        cfg.kappas = vec![2, 5];
        // ensure! inside ablation_fd asserts the theorem.
        let t = ablation_fd(&cfg).unwrap();
        assert_eq!(t.rows.len(), 2);
    }

    #[test]
    fn ablation_sparse_objectives_close() {
        let cfg = PaperCfg::smoke();
        let t = ablation_sparse(Dataset::Yelp, 3, &cfg).unwrap();
        assert_eq!(t.rows.len(), 2);
    }

    #[test]
    fn kappa_sweep_smoke() {
        let mut cfg = PaperCfg::smoke();
        cfg.eval_approx = false;
        let t = kappa_sweep(Dataset::Favorita, 5, &[2, 5], &cfg).unwrap();
        assert_eq!(t.rows.len(), 2);
    }

    #[test]
    fn engine_ablation_paths_agree() {
        // The ensure! calls inside assert the naive/pruned agreement; the
        // four rows cover factored × dense × naive × pruned.
        let cfg = PaperCfg::smoke();
        let (t, records) = engine_ablation(Dataset::Retailer, 4, 5, &cfg).unwrap();
        assert_eq!(t.rows.len(), 4);
        assert_eq!(records.len(), 4);
        assert!(records[0].speedup_vs_naive.is_none());
        assert!(records[1].speedup_vs_naive.is_some());
        assert_eq!(records[1].engine, "factored-pruned");
        assert_eq!(records[3].engine, "dense-pruned");
        // Fixed-iteration runs: every path did the same logical work.
        for r in &records {
            assert_eq!(r.iters, 5);
        }
    }
}
