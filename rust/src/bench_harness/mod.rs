//! Benchmark harness: timing utilities, the paper-table renderers used by
//! `examples/paper_tables.rs` and the `rust/benches/*` targets, and the
//! `BENCH_lloyd.json` perf-record writer. The environment is offline (no
//! criterion), so the harness implements the warmup + repeated-measurement
//! + min/mean/median reporting itself.
//!
//! # `BENCH_lloyd.json` schema (version 1)
//!
//! `benches/kernel_lloyd.rs` emits one JSON document per invocation (path
//! from `RKMEANS_BENCH_OUT`, default `BENCH_lloyd.json`) so successive PRs
//! have a Step-4 perf trajectory to beat:
//!
//! ```json
//! {
//!   "version": 1,
//!   "bench": "lloyd",
//!   "records": [
//!     {
//!       "label": "retailer-materialized",
//!       "engine": "dense-pruned",
//!       "bounds": "hamerly",
//!       "precision": "f64",
//!       "n": 120000,
//!       "dims": 53,
//!       "k": 32,
//!       "iters": 15,
//!       "wall_s": 1.84,
//!       "points_per_sec": 978260.9,
//!       "dist_evals": 8123456,
//!       "dist_evals_skipped": 49321544,
//!       "skip_rate": 0.858,
//!       "objective": 123400.0,
//!       "speedup_vs_naive": 3.1
//!     }
//!   ]
//! }
//! ```
//!
//! * `label` names the workload; `engine` is `{dense,factored}-{naive,
//!   pruned}` plus an optional policy/precision suffix on the ablation
//!   rows (e.g. `dense-pruned-elkan`, `dense-naive-f32`, `dense-xla`).
//! * `bounds` / `precision` are the engine's resolved
//!   [`PruneStats::bounds`] / [`PruneStats::precision`] labels
//!   (`hamerly`/`elkan`/`none` and `f64`/`f32`), so policy ablations are
//!   queryable without parsing the engine string.
//! * `n` counts points (dense) or grid cells (factored); `dims` is the
//!   dense dimensionality `D` or the subspace count `m` respectively.
//! * `wall_s` covers the whole run (seeding + iterations);
//!   `points_per_sec` = `n·iters / wall_s`.
//! * `dist_evals` / `dist_evals_skipped` count (point, centroid) distance
//!   evaluations performed vs. proven unnecessary by the bounds;
//!   `skip_rate` = skipped / (evals + skipped).
//! * `speedup_vs_naive` is the `points_per_sec` ratio against the
//!   reference row it was attached to (the naive serial run, or the
//!   Hamerly/f64 arm on ablation rows); absent on reference rows.
//!
//! # `BENCH_stream.json` schema (version 1)
//!
//! `benches/stream_ingest.rs` emits one document per invocation (path from
//! `RKMEANS_STREAM_OUT`, default `BENCH_stream.json`) comparing patched
//! vs. full-rebuild per-batch latency over an insert/delete trace:
//!
//! ```json
//! {
//!   "version": 1,
//!   "bench": "stream",
//!   "records": [
//!     {
//!       "label": "retailer-trace",
//!       "mode": "patched",
//!       "base_rows": 48213,
//!       "batch": 256,
//!       "batches": 8,
//!       "total_s": 0.41,
//!       "mean_batch_s": 0.051,
//!       "max_batch_s": 0.066,
//!       "grid_cells": 17342,
//!       "objective": 812345.0,
//!       "speedup_vs_rebuild": 11.8
//!     }
//!   ]
//! }
//! ```
//!
//! * `mode` is `patched` (Step-3 delta + Step-4 resume from the carried
//!   engine state, on the shared pool), `patched-cold` (same but with
//!   bound carrying disabled — the cold warm start), `patched-scoped`
//!   (carry on, scoped-spawn executor instead of the pool) or `rebuild`
//!   (full pipeline per batch); `base_rows` is `|D|` before the trace and
//!   `batch`/`batches` describe the trace shape.
//! * `mean_batch_s` / `max_batch_s` are per-batch maintenance latencies;
//!   `speedup_vs_rebuild` = rebuild mean / patched mean (patched rows
//!   only). The acceptance target is ≥ 5× at batch ≤ 1 % of `|D|`.
//!   `speedup_vs_cold` (the `patched` row only) = the `patched-cold`
//!   arm's mean / the carried arm's mean — the bound-carrying ablation
//!   the gate's `stream_carry_speedup` metric tracks.
//! * `grid_cells` / `objective` are the final state per mode. They can
//!   differ slightly across modes (patching freezes the Step-2 models, a
//!   rebuild re-solves them); the bench instead asserts the final grid
//!   *mass* — which is model-independent — matches exactly.
//!
//! # `BENCH_sweep.json` schema (version 1)
//!
//! `benches/k_sweep.rs` emits one document per invocation (path from
//! `RKMEANS_SWEEP_OUT`, default `BENCH_sweep.json`) comparing a k-sweep
//! over one shared staged-pipeline `Coreset` against independent
//! one-shot `rkmeans()` runs:
//!
//! ```json
//! {
//!   "version": 1,
//!   "bench": "sweep",
//!   "records": [
//!     {
//!       "label": "retailer",
//!       "mode": "shared-coreset",
//!       "ks": [4, 8, 16, 32],
//!       "kappa": 16,
//!       "grid_cells": 17342,
//!       "total_s": 0.41,
//!       "per_k_s": [0.02, 0.04, 0.08, 0.15],
//!       "objectives": [812345.0, 401234.0, 201234.0, 101234.0],
//!       "speedup_vs_independent": 2.7
//!     }
//!   ]
//! }
//! ```
//!
//! * `mode` is `shared-coreset` (Steps 1–3 once, Step 4 per k) or
//!   `independent` (the full pipeline per k); `kappa` is the shared
//!   Step-2 budget (fixed across the sweep so both arms build the same
//!   grid and per-k objectives are bitwise-identical).
//! * `total_s` covers the whole arm (for the shared arm this includes
//!   the one-time Steps 1–3); `per_k_s` / `objectives` are parallel to
//!   `ks`.
//! * `speedup_vs_independent` = independent total / shared total
//!   (shared rows only). The acceptance target is ≥ 2×.
//!
//! # `BENCH_shard.json` schema (version 1)
//!
//! `benches/shard_build.rs` emits one document per invocation (path from
//! `RKMEANS_SHARD_OUT`, default `BENCH_shard.json`) comparing sharded
//! Step 1–3 construction (`RkPipeline::coreset_sharded`) against the
//! serial build, after asserting the merged grid **bitwise equal** to
//! the serial one:
//!
//! ```json
//! {
//!   "version": 1,
//!   "bench": "shard",
//!   "records": [
//!     {
//!       "label": "retailer",
//!       "mode": "sharded-4",
//!       "shards": 4,
//!       "threads": 8,
//!       "step1_2_s": 0.021,
//!       "step3_s": 0.38,
//!       "build_s": 0.401,
//!       "grid_cells": 17342,
//!       "grid_mass": 120000.0,
//!       "speedup_vs_serial": 2.4
//!     }
//!   ]
//! }
//! ```
//!
//! * `mode` is `serial` (the S = 1 reference), `sharded-N` per swept
//!   shard count, or `sharded-max` (S = the machine's available
//!   parallelism — the acceptance arm); `shards` is the numeric S and
//!   `threads` the resolved worker-pool width.
//! * `step3_s` is the (fastest-of-samples) grid-construction time — the
//!   phase sharding parallelizes; `step1_2_s` is the shared serial
//!   marginals + subspace solve; `build_s` = `step1_2_s + step3_s`.
//! * `speedup_vs_serial` = serial `step3_s` / this row's `step3_s`
//!   (sharded rows only) — machine-relative, the gate's
//!   `shard_build_speedup` metric. The acceptance target is ≥ 2× at
//!   S = physical cores on the Retailer workload.
//!
//! # `BENCH_serve.json` schema (version 1)
//!
//! `benches/serve_load.rs` emits one document per invocation (path from
//! `RKMEANS_SERVE_OUT`, default `BENCH_serve.json`) measuring the
//! serving tier ([`crate::serve`]): the micro-batched mesh against the
//! un-batched one-call-per-request loop, and delta-vs-snapshot
//! publication bytes over an incremental-planner patch run:
//!
//! ```json
//! {
//!   "version": 1,
//!   "bench": "serve",
//!   "records": [
//!     {
//!       "label": "retailer",
//!       "mode": "mesh",
//!       "replicas": 2,
//!       "clients": 4,
//!       "batch": 64,
//!       "requests": 20000,
//!       "qps": 812345.0,
//!       "p50_us": 41,
//!       "p99_us": 220,
//!       "speedup_vs_naive": 3.4,
//!       "delta_bytes": 1201,
//!       "snapshot_bytes": 18233,
//!       "delta_bytes_ratio": 15.2
//!     }
//!   ]
//! }
//! ```
//!
//! * `mode` is `naive` (the reference row: one thread, one
//!   [`RkModel::assign`](crate::rkmeans::RkModel::assign) per request,
//!   no batching), `mesh` (the acceptance arm: open-loop clients
//!   through the [`AssignFront`](crate::serve::AssignFront) over a
//!   [`ModelMesh`](crate::serve::ModelMesh)), or `delta` (the
//!   publication-bytes arm — its throughput fields describe the load
//!   run concurrent with publication).
//! * `replicas` / `clients` / `batch` describe the mesh shape (1/1/1 on
//!   the naive row); `requests` counts answered requests.
//! * `qps` is sustained throughput; `p50_us` / `p99_us` are exact
//!   per-request latency percentiles (queue + compute) in microseconds.
//! * `speedup_vs_naive` = this row's `qps` / the naive row's `qps`
//!   (mesh rows only) — the gate's `serve_qps_speedup` metric. The
//!   acceptance target is ≥ 2× on the Retailer workload.
//! * `delta_bytes` / `snapshot_bytes` (delta rows only) are cumulative
//!   wire bytes over the run's publishes; `delta_bytes_ratio` =
//!   `snapshot_bytes / delta_bytes` — the gate's
//!   `serve_delta_bytes_ratio` metric. The acceptance target is ≥ 2×
//!   (deltas at most half the snapshot bytes).
//!
//! # `BENCH_rpc.json` schema (version 1)
//!
//! `benches/rpc_load.rs` emits one document per invocation (path from
//! `RKMEANS_RPC_OUT`, default `BENCH_rpc.json`) measuring the
//! multi-process socket tier ([`crate::serve::rpc`]) against the
//! in-process front, including a replica-churn arm that kills and
//! restarts a replica process mid-run:
//!
//! ```json
//! {
//!   "version": 1,
//!   "bench": "rpc",
//!   "records": [
//!     {
//!       "label": "retailer",
//!       "mode": "rpc-1",
//!       "replicas": 1,
//!       "clients": 4,
//!       "requests": 20000,
//!       "qps": 81234.0,
//!       "p50_us": 180,
//!       "p99_us": 950,
//!       "qps_ratio_vs_inproc": 0.21,
//!       "catchups": 1,
//!       "catchup_ok": 1.0
//!     }
//!   ]
//! }
//! ```
//!
//! * `mode` is `inproc` (the reference row: the same open-loop load
//!   through [`AssignFront`](crate::serve::AssignFront) with no socket
//!   in the path), `rpc-1` (one writer + one replica process over
//!   localhost), or `rpc-3-churn` (one writer + three replicas with one
//!   replica killed and restarted mid-run).
//! * `replicas` counts replica *processes* (0 on the inproc row);
//!   `clients` / `requests` / `qps` / `p50_us` / `p99_us` mirror the
//!   serve schema — socket rows include framing + kernel round-trips in
//!   latency, which is the point of the comparison.
//! * `qps_ratio_vs_inproc` = this row's `qps` / the inproc row's `qps`
//!   (socket rows only) — the gate's `rpc_qps_ratio` metric. Crossing a
//!   process boundary costs real throughput; the gate only insists the
//!   floor stays above a conservative baseline.
//! * `catchups` (churn rows) counts snapshot catch-ups the writer
//!   served; `catchup_ok` is 1.0 when every restarted replica converged
//!   back to the writer's latest version (byte-verified) before the run
//!   ended, else 0.0 — the gate's `rpc_catchup_ok` metric.
//!
//! # `BENCH_ingest.json` schema (version 1)
//!
//! `benches/ingest_scale.rs` emits one document per invocation (path
//! from `RKMEANS_INGEST_OUT`, default `BENCH_ingest.json`) measuring the
//! multi-producer ingest tier ([`crate::ingest`]): P producer threads
//! feeding S bounded per-shard queues, pumped through the epoch
//! protocol, against a serial single-stream [`DeltaFaq`] ingest of the
//! same trace — after asserting the final grids **bitwise equal**:
//!
//! ```json
//! {
//!   "version": 1,
//!   "bench": "ingest",
//!   "records": [
//!     {
//!       "label": "retailer-trace",
//!       "mode": "epochd-max",
//!       "producers": 8,
//!       "shards": 8,
//!       "base_rows": 40213,
//!       "batch": 2560,
//!       "batches": 6,
//!       "total_s": 0.41,
//!       "deltas_per_sec": 37463.4,
//!       "epoch_p50_us": 41000,
//!       "epoch_p99_us": 92000,
//!       "grid_cells": 81,
//!       "speedup_vs_serial": 2.4
//!     }
//!   ]
//! }
//! ```
//!
//! * `mode` is `serial` (one [`DeltaFaq`], one stream — the reference
//!   row), `epochd-2` (P = S = 2) or `epochd-max` (P = S = available
//!   parallelism — the acceptance arm); `producers` / `shards` are the
//!   numeric P / S (1/1 on the serial row).
//! * `base_rows` is `|D|` before the trace; `batch` / `batches`
//!   describe the trace shape (one epoch per batch).
//! * `total_s` is enqueue-to-last-epoch-closed wall time;
//!   `deltas_per_sec` = `batch·batches / total_s` — the throughput the
//!   gate's `ingest_scale_speedup` ratio is built from.
//! * `epoch_p50_us` / `epoch_p99_us` are first-entry-seen to
//!   epoch-closed latency percentiles (the `ingest.epoch_us` histogram;
//!   measured per-batch apply time on the serial row).
//! * `speedup_vs_serial` = this row's `deltas_per_sec` / the serial
//!   row's (epoch'd rows only). The acceptance target is ≥ 2× at
//!   P = physical cores on the Retailer workload; grids are asserted
//!   bitwise-identical across all arms by the emitting bench, so only
//!   speed is gated.
//!
//! [`DeltaFaq`]: crate::incremental::DeltaFaq

pub mod paper;

use crate::cluster::PruneStats;
use crate::util::json::Json;
use crate::util::timer::secs;
use std::collections::BTreeMap;
use std::path::Path;
use std::time::{Duration, Instant};

/// One measured benchmark: run statistics in seconds.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub samples: Vec<f64>,
}

impl Measurement {
    /// Fastest observed run (criterion's preferred robust statistic).
    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Mean of samples.
    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len().max(1) as f64
    }

    /// Median of samples.
    pub fn median(&self) -> f64 {
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).expect("finite"));
        s[s.len() / 2]
    }

    /// Render one line: `name  min  mean  median  (n samples)`.
    pub fn line(&self) -> String {
        format!(
            "{:<44} min {:>9.4}s  mean {:>9.4}s  median {:>9.4}s  (n={})",
            self.name,
            self.min(),
            self.mean(),
            self.median(),
            self.samples.len()
        )
    }
}

/// Time `f` `samples` times after `warmup` unmeasured runs.
pub fn bench<T>(
    name: &str,
    warmup: usize,
    samples: usize,
    mut f: impl FnMut() -> T,
) -> Measurement {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut out = Vec::with_capacity(samples);
    for _ in 0..samples.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        out.push(secs(t0.elapsed()));
    }
    Measurement { name: name.to_string(), samples: out }
}

/// Time a single (expensive, end-to-end) run.
pub fn bench_once<T>(name: &str, f: impl FnOnce() -> T) -> (T, Measurement) {
    let t0 = Instant::now();
    let v = f();
    let d = secs(t0.elapsed());
    (v, Measurement { name: name.to_string(), samples: vec![d] })
}

/// A markdown-style table builder for the paper-table reports.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with a title and column headers.
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (stringified cells).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells);
    }

    /// Render as a GitHub-flavored markdown table.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncol {
                s.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            s
        };
        let mut out = format!("\n### {}\n\n", self.title);
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// One Step-4 engine measurement for `BENCH_lloyd.json` (schema in the
/// module docs).
#[derive(Clone, Debug)]
pub struct LloydBenchRecord {
    pub label: String,
    pub engine: String,
    /// Resolved bounds policy label (`hamerly`/`elkan`/`none`).
    pub bounds: String,
    /// Kernel precision label (`f64`/`f32`).
    pub precision: String,
    /// Points (dense) or grid cells (factored).
    pub n: usize,
    /// Dense dimensionality `D`, or subspace count `m` for factored runs.
    pub dims: usize,
    pub k: usize,
    pub iters: usize,
    pub wall_s: f64,
    pub points_per_sec: f64,
    pub dist_evals: u64,
    pub dist_evals_skipped: u64,
    pub skip_rate: f64,
    pub objective: f64,
    /// `points_per_sec` ratio vs. the reference row it was attached to.
    pub speedup_vs_naive: Option<f64>,
}

impl LloydBenchRecord {
    /// Build a record from a run's engine statistics.
    pub fn from_stats(
        label: &str,
        engine: &str,
        dims: usize,
        k: usize,
        objective: f64,
        stats: &PruneStats,
    ) -> Self {
        LloydBenchRecord {
            label: label.to_string(),
            engine: engine.to_string(),
            bounds: stats.bounds.to_string(),
            precision: stats.precision.to_string(),
            n: stats.points as usize,
            dims,
            k,
            iters: stats.iters,
            wall_s: stats.wall.as_secs_f64(),
            points_per_sec: stats.points_per_sec(),
            dist_evals: stats.dist_evals,
            dist_evals_skipped: stats.dist_evals_skipped,
            skip_rate: stats.skip_rate(),
            objective,
            speedup_vs_naive: None,
        }
    }

    /// Attach the throughput speedup against a naive reference record.
    pub fn with_speedup_vs(mut self, naive: &LloydBenchRecord) -> Self {
        self.speedup_vs_naive = Some(self.points_per_sec / naive.points_per_sec.max(1e-12));
        self
    }

    /// One human-readable console line.
    pub fn line(&self) -> String {
        format!(
            "{:<26} {:<16} n={:<8} k={:<3} iters={:<3} {:>8.3}s  {:>12.0} pts/s  skip {:>5.1}%{}",
            self.label,
            self.engine,
            self.n,
            self.k,
            self.iters,
            self.wall_s,
            self.points_per_sec,
            100.0 * self.skip_rate,
            self.speedup_vs_naive
                .map(|s| format!("  ({s:.2}× vs naive)"))
                .unwrap_or_default()
        )
    }

    /// Serialize to a JSON object (schema in the module docs).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("label".to_string(), Json::Str(self.label.clone()));
        m.insert("engine".to_string(), Json::Str(self.engine.clone()));
        m.insert("bounds".to_string(), Json::Str(self.bounds.clone()));
        m.insert("precision".to_string(), Json::Str(self.precision.clone()));
        m.insert("n".to_string(), Json::Num(self.n as f64));
        m.insert("dims".to_string(), Json::Num(self.dims as f64));
        m.insert("k".to_string(), Json::Num(self.k as f64));
        m.insert("iters".to_string(), Json::Num(self.iters as f64));
        m.insert("wall_s".to_string(), Json::Num(self.wall_s));
        m.insert("points_per_sec".to_string(), Json::Num(self.points_per_sec));
        m.insert("dist_evals".to_string(), Json::Num(self.dist_evals as f64));
        m.insert(
            "dist_evals_skipped".to_string(),
            Json::Num(self.dist_evals_skipped as f64),
        );
        m.insert("skip_rate".to_string(), Json::Num(self.skip_rate));
        m.insert("objective".to_string(), Json::Num(self.objective));
        if let Some(s) = self.speedup_vs_naive {
            m.insert("speedup_vs_naive".to_string(), Json::Num(s));
        }
        Json::Obj(m)
    }
}

/// Assemble the `BENCH_lloyd.json` document.
pub fn bench_lloyd_json(records: &[LloydBenchRecord]) -> Json {
    let mut top = BTreeMap::new();
    top.insert("version".to_string(), Json::Num(1.0));
    top.insert("bench".to_string(), Json::Str("lloyd".to_string()));
    top.insert(
        "records".to_string(),
        Json::Arr(records.iter().map(LloydBenchRecord::to_json).collect()),
    );
    Json::Obj(top)
}

/// Write the `BENCH_lloyd.json` document to disk.
pub fn write_bench_lloyd(path: &Path, records: &[LloydBenchRecord]) -> std::io::Result<()> {
    std::fs::write(path, bench_lloyd_json(records).to_string())
}

/// One streaming-maintenance measurement for `BENCH_stream.json` (schema
/// in the module docs).
#[derive(Clone, Debug)]
pub struct StreamBenchRecord {
    pub label: String,
    /// `"patched"` or `"rebuild"`.
    pub mode: String,
    /// `|D|` (total base tuples) before the trace.
    pub base_rows: usize,
    /// Deltas per batch.
    pub batch: usize,
    /// Batches in the trace.
    pub batches: usize,
    /// Total maintenance time over the trace.
    pub total_s: f64,
    /// Mean per-batch maintenance latency.
    pub mean_batch_s: f64,
    /// Worst per-batch maintenance latency.
    pub max_batch_s: f64,
    /// Non-zero grid cells after the trace.
    pub grid_cells: usize,
    /// Final Step-4 objective.
    pub objective: f64,
    /// Rebuild mean / patched mean (patched rows only).
    pub speedup_vs_rebuild: Option<f64>,
    /// Cold-warm-start mean / carried mean (the bound-carrying ablation;
    /// `patched` row only).
    pub speedup_vs_cold: Option<f64>,
}

impl StreamBenchRecord {
    /// Build a record from per-batch latencies (seconds).
    pub fn from_batches(
        label: &str,
        mode: &str,
        base_rows: usize,
        batch: usize,
        batch_times: &[f64],
        grid_cells: usize,
        objective: f64,
    ) -> Self {
        let total: f64 = batch_times.iter().sum();
        let n = batch_times.len().max(1) as f64;
        StreamBenchRecord {
            label: label.to_string(),
            mode: mode.to_string(),
            base_rows,
            batch,
            batches: batch_times.len(),
            total_s: total,
            mean_batch_s: total / n,
            max_batch_s: batch_times.iter().cloned().fold(0.0, f64::max),
            grid_cells,
            objective,
            speedup_vs_rebuild: None,
            speedup_vs_cold: None,
        }
    }

    /// Attach the mean-latency speedup against the rebuild reference row.
    pub fn with_speedup_vs(mut self, rebuild: &StreamBenchRecord) -> Self {
        self.speedup_vs_rebuild = Some(rebuild.mean_batch_s / self.mean_batch_s.max(1e-12));
        self
    }

    /// Attach the mean-latency speedup against the carry-disabled
    /// (`patched-cold`) reference row.
    pub fn with_carry_speedup_vs(mut self, cold: &StreamBenchRecord) -> Self {
        self.speedup_vs_cold = Some(cold.mean_batch_s / self.mean_batch_s.max(1e-12));
        self
    }

    /// One human-readable console line.
    pub fn line(&self) -> String {
        format!(
            "{:<20} {:<8} |D|={:<8} batch={:<5}×{:<3} mean {:>8.4}s  max {:>8.4}s  |G|={}{}",
            self.label,
            self.mode,
            self.base_rows,
            self.batch,
            self.batches,
            self.mean_batch_s,
            self.max_batch_s,
            self.grid_cells,
            self.speedup_vs_rebuild
                .map(|s| format!("  ({s:.2}× vs rebuild)"))
                .unwrap_or_default()
        )
    }

    /// Serialize to a JSON object (schema in the module docs).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("label".to_string(), Json::Str(self.label.clone()));
        m.insert("mode".to_string(), Json::Str(self.mode.clone()));
        m.insert("base_rows".to_string(), Json::Num(self.base_rows as f64));
        m.insert("batch".to_string(), Json::Num(self.batch as f64));
        m.insert("batches".to_string(), Json::Num(self.batches as f64));
        m.insert("total_s".to_string(), Json::Num(self.total_s));
        m.insert("mean_batch_s".to_string(), Json::Num(self.mean_batch_s));
        m.insert("max_batch_s".to_string(), Json::Num(self.max_batch_s));
        m.insert("grid_cells".to_string(), Json::Num(self.grid_cells as f64));
        m.insert("objective".to_string(), Json::Num(self.objective));
        if let Some(s) = self.speedup_vs_rebuild {
            m.insert("speedup_vs_rebuild".to_string(), Json::Num(s));
        }
        if let Some(s) = self.speedup_vs_cold {
            m.insert("speedup_vs_cold".to_string(), Json::Num(s));
        }
        Json::Obj(m)
    }
}

/// Assemble the `BENCH_stream.json` document.
pub fn bench_stream_json(records: &[StreamBenchRecord]) -> Json {
    let mut top = BTreeMap::new();
    top.insert("version".to_string(), Json::Num(1.0));
    top.insert("bench".to_string(), Json::Str("stream".to_string()));
    top.insert(
        "records".to_string(),
        Json::Arr(records.iter().map(StreamBenchRecord::to_json).collect()),
    );
    Json::Obj(top)
}

/// Write the `BENCH_stream.json` document to disk.
pub fn write_bench_stream(path: &Path, records: &[StreamBenchRecord]) -> std::io::Result<()> {
    std::fs::write(path, bench_stream_json(records).to_string())
}

/// One k-sweep measurement for `BENCH_sweep.json` (schema in the module
/// docs).
#[derive(Clone, Debug)]
pub struct SweepBenchRecord {
    pub label: String,
    /// `"shared-coreset"` or `"independent"`.
    pub mode: String,
    /// The swept k values.
    pub ks: Vec<usize>,
    /// The fixed Step-2 budget κ shared across the sweep.
    pub kappa: usize,
    /// Non-zero grid cells `|G|` of the (shared) coreset.
    pub grid_cells: usize,
    /// Wall-clock of the whole arm (shared arm: includes Steps 1–3).
    pub total_s: f64,
    /// Per-k wall-clock, parallel to `ks`.
    pub per_k_s: Vec<f64>,
    /// Per-k Step-4 objectives, parallel to `ks`.
    pub objectives: Vec<f64>,
    /// Independent total / shared total (shared rows only).
    pub speedup_vs_independent: Option<f64>,
}

impl SweepBenchRecord {
    /// Build a record from one arm's measurements.
    #[allow(clippy::too_many_arguments)]
    pub fn from_runs(
        label: &str,
        mode: &str,
        ks: &[usize],
        kappa: usize,
        grid_cells: usize,
        total_s: f64,
        per_k_s: &[f64],
        objectives: &[f64],
    ) -> Self {
        assert_eq!(ks.len(), per_k_s.len(), "per_k_s not parallel to ks");
        assert_eq!(ks.len(), objectives.len(), "objectives not parallel to ks");
        SweepBenchRecord {
            label: label.to_string(),
            mode: mode.to_string(),
            ks: ks.to_vec(),
            kappa,
            grid_cells,
            total_s,
            per_k_s: per_k_s.to_vec(),
            objectives: objectives.to_vec(),
            speedup_vs_independent: None,
        }
    }

    /// Attach the total-time speedup against the independent reference.
    pub fn with_speedup_vs(mut self, independent: &SweepBenchRecord) -> Self {
        self.speedup_vs_independent = Some(independent.total_s / self.total_s.max(1e-12));
        self
    }

    /// One human-readable console line.
    pub fn line(&self) -> String {
        format!(
            "{:<12} {:<15} ks={:?} κ={:<3} |G|={:<8} total {:>8.3}s{}",
            self.label,
            self.mode,
            self.ks,
            self.kappa,
            self.grid_cells,
            self.total_s,
            self.speedup_vs_independent
                .map(|s| format!("  ({s:.2}× vs independent)"))
                .unwrap_or_default()
        )
    }

    /// Serialize to a JSON object (schema in the module docs).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("label".to_string(), Json::Str(self.label.clone()));
        m.insert("mode".to_string(), Json::Str(self.mode.clone()));
        m.insert(
            "ks".to_string(),
            Json::Arr(self.ks.iter().map(|&k| Json::Num(k as f64)).collect()),
        );
        m.insert("kappa".to_string(), Json::Num(self.kappa as f64));
        m.insert("grid_cells".to_string(), Json::Num(self.grid_cells as f64));
        m.insert("total_s".to_string(), Json::Num(self.total_s));
        m.insert(
            "per_k_s".to_string(),
            Json::Arr(self.per_k_s.iter().map(|&v| Json::Num(v)).collect()),
        );
        m.insert(
            "objectives".to_string(),
            Json::Arr(self.objectives.iter().map(|&v| Json::Num(v)).collect()),
        );
        if let Some(s) = self.speedup_vs_independent {
            m.insert("speedup_vs_independent".to_string(), Json::Num(s));
        }
        Json::Obj(m)
    }
}

/// Assemble the `BENCH_sweep.json` document.
pub fn bench_sweep_json(records: &[SweepBenchRecord]) -> Json {
    let mut top = BTreeMap::new();
    top.insert("version".to_string(), Json::Num(1.0));
    top.insert("bench".to_string(), Json::Str("sweep".to_string()));
    top.insert(
        "records".to_string(),
        Json::Arr(records.iter().map(SweepBenchRecord::to_json).collect()),
    );
    Json::Obj(top)
}

/// Write the `BENCH_sweep.json` document to disk.
pub fn write_bench_sweep(path: &Path, records: &[SweepBenchRecord]) -> std::io::Result<()> {
    std::fs::write(path, bench_sweep_json(records).to_string())
}

/// One sharded-construction measurement for `BENCH_shard.json` (schema
/// in the module docs).
#[derive(Clone, Debug)]
pub struct ShardBenchRecord {
    pub label: String,
    /// `"serial"`, `"sharded-N"` or `"sharded-max"`.
    pub mode: String,
    /// Shard count S (1 on the serial reference row).
    pub shards: usize,
    /// Resolved worker-pool width the build dispatched over.
    pub threads: usize,
    /// Serial Steps 1–2 (marginals + subspace solve), shared by all arms.
    pub step1_2_s: f64,
    /// Fastest observed Step-3 grid construction time.
    pub step3_s: f64,
    /// `step1_2_s + step3_s` — the full Steps 1–3 build latency.
    pub build_s: f64,
    /// Non-zero grid cells `|G|` of the (merged) coreset.
    pub grid_cells: usize,
    /// Total grid mass (= weighted `|X|`) — identical across arms by the
    /// bitwise-merge contract.
    pub grid_mass: f64,
    /// Serial `step3_s` / this row's `step3_s` (sharded rows only).
    pub speedup_vs_serial: Option<f64>,
}

impl ShardBenchRecord {
    /// Build a record from one arm's measurements.
    #[allow(clippy::too_many_arguments)]
    pub fn from_build(
        label: &str,
        mode: &str,
        shards: usize,
        threads: usize,
        step1_2_s: f64,
        step3_s: f64,
        grid_cells: usize,
        grid_mass: f64,
    ) -> Self {
        ShardBenchRecord {
            label: label.to_string(),
            mode: mode.to_string(),
            shards,
            threads,
            step1_2_s,
            step3_s,
            build_s: step1_2_s + step3_s,
            grid_cells,
            grid_mass,
            speedup_vs_serial: None,
        }
    }

    /// Attach the Step-3 speedup against the serial reference row.
    pub fn with_speedup_vs(mut self, serial: &ShardBenchRecord) -> Self {
        self.speedup_vs_serial = Some(serial.step3_s / self.step3_s.max(1e-12));
        self
    }

    /// One human-readable console line.
    pub fn line(&self) -> String {
        format!(
            "{:<12} {:<12} S={:<3} threads={:<3} step3 {:>8.4}s  build {:>8.4}s  |G|={}{}",
            self.label,
            self.mode,
            self.shards,
            self.threads,
            self.step3_s,
            self.build_s,
            self.grid_cells,
            self.speedup_vs_serial
                .map(|s| format!("  ({s:.2}× vs serial)"))
                .unwrap_or_default()
        )
    }

    /// Serialize to a JSON object (schema in the module docs).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("label".to_string(), Json::Str(self.label.clone()));
        m.insert("mode".to_string(), Json::Str(self.mode.clone()));
        m.insert("shards".to_string(), Json::Num(self.shards as f64));
        m.insert("threads".to_string(), Json::Num(self.threads as f64));
        m.insert("step1_2_s".to_string(), Json::Num(self.step1_2_s));
        m.insert("step3_s".to_string(), Json::Num(self.step3_s));
        m.insert("build_s".to_string(), Json::Num(self.build_s));
        m.insert("grid_cells".to_string(), Json::Num(self.grid_cells as f64));
        m.insert("grid_mass".to_string(), Json::Num(self.grid_mass));
        if let Some(s) = self.speedup_vs_serial {
            m.insert("speedup_vs_serial".to_string(), Json::Num(s));
        }
        Json::Obj(m)
    }
}

/// Assemble the `BENCH_shard.json` document.
pub fn bench_shard_json(records: &[ShardBenchRecord]) -> Json {
    let mut top = BTreeMap::new();
    top.insert("version".to_string(), Json::Num(1.0));
    top.insert("bench".to_string(), Json::Str("shard".to_string()));
    top.insert(
        "records".to_string(),
        Json::Arr(records.iter().map(ShardBenchRecord::to_json).collect()),
    );
    Json::Obj(top)
}

/// Write the `BENCH_shard.json` document to disk.
pub fn write_bench_shard(path: &Path, records: &[ShardBenchRecord]) -> std::io::Result<()> {
    std::fs::write(path, bench_shard_json(records).to_string())
}

/// One serving-tier measurement for `BENCH_serve.json` (schema in the
/// module docs).
#[derive(Clone, Debug)]
pub struct ServeBenchRecord {
    pub label: String,
    /// `"naive"`, `"mesh"` or `"delta"`.
    pub mode: String,
    /// Replica slots in the mesh (1 on the naive row).
    pub replicas: usize,
    /// Concurrent load-generator clients (1 on the naive row).
    pub clients: usize,
    /// Micro-batch ceiling (1 on the naive row).
    pub batch: usize,
    /// Requests answered.
    pub requests: usize,
    /// Sustained throughput, requests per second.
    pub qps: f64,
    /// Exact median per-request latency (queue + compute), µs.
    pub p50_us: u64,
    /// Exact 99th-percentile per-request latency, µs.
    pub p99_us: u64,
    /// This row's `qps` / the naive row's `qps` (mesh rows only).
    pub speedup_vs_naive: Option<f64>,
    /// Cumulative delta wire bytes over the run's publishes (delta rows).
    pub delta_bytes: Option<u64>,
    /// Cumulative snapshot bytes the same publishes would have cost.
    pub snapshot_bytes: Option<u64>,
    /// `snapshot_bytes / delta_bytes` (delta rows only).
    pub delta_bytes_ratio: Option<f64>,
}

impl ServeBenchRecord {
    /// Build a record from one arm's load report.
    #[allow(clippy::too_many_arguments)]
    pub fn from_load(
        label: &str,
        mode: &str,
        replicas: usize,
        clients: usize,
        batch: usize,
        requests: usize,
        qps: f64,
        p50_us: u64,
        p99_us: u64,
    ) -> Self {
        ServeBenchRecord {
            label: label.to_string(),
            mode: mode.to_string(),
            replicas,
            clients,
            batch,
            requests,
            qps,
            p50_us,
            p99_us,
            speedup_vs_naive: None,
            delta_bytes: None,
            snapshot_bytes: None,
            delta_bytes_ratio: None,
        }
    }

    /// Attach the throughput speedup against the naive reference row.
    pub fn with_speedup_vs(mut self, naive: &ServeBenchRecord) -> Self {
        self.speedup_vs_naive = Some(self.qps / naive.qps.max(1e-12));
        self
    }

    /// Attach publication byte accounting (the delta arm).
    pub fn with_publish_bytes(mut self, delta_bytes: u64, snapshot_bytes: u64) -> Self {
        self.delta_bytes = Some(delta_bytes);
        self.snapshot_bytes = Some(snapshot_bytes);
        self.delta_bytes_ratio = Some(snapshot_bytes as f64 / (delta_bytes as f64).max(1e-12));
        self
    }

    /// One human-readable console line.
    pub fn line(&self) -> String {
        format!(
            "{:<12} {:<7} R={:<2} C={:<2} batch={:<4} {:>8} req  {:>10.0} req/s  p50={:>5}µs \
             p99={:>6}µs{}{}",
            self.label,
            self.mode,
            self.replicas,
            self.clients,
            self.batch,
            self.requests,
            self.qps,
            self.p50_us,
            self.p99_us,
            self.speedup_vs_naive
                .map(|s| format!("  ({s:.2}× vs naive)"))
                .unwrap_or_default(),
            self.delta_bytes_ratio
                .map(|r| format!("  (delta {r:.1}× smaller)"))
                .unwrap_or_default()
        )
    }

    /// Serialize to a JSON object (schema in the module docs).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("label".to_string(), Json::Str(self.label.clone()));
        m.insert("mode".to_string(), Json::Str(self.mode.clone()));
        m.insert("replicas".to_string(), Json::Num(self.replicas as f64));
        m.insert("clients".to_string(), Json::Num(self.clients as f64));
        m.insert("batch".to_string(), Json::Num(self.batch as f64));
        m.insert("requests".to_string(), Json::Num(self.requests as f64));
        m.insert("qps".to_string(), Json::Num(self.qps));
        m.insert("p50_us".to_string(), Json::Num(self.p50_us as f64));
        m.insert("p99_us".to_string(), Json::Num(self.p99_us as f64));
        if let Some(s) = self.speedup_vs_naive {
            m.insert("speedup_vs_naive".to_string(), Json::Num(s));
        }
        if let Some(b) = self.delta_bytes {
            m.insert("delta_bytes".to_string(), Json::Num(b as f64));
        }
        if let Some(b) = self.snapshot_bytes {
            m.insert("snapshot_bytes".to_string(), Json::Num(b as f64));
        }
        if let Some(r) = self.delta_bytes_ratio {
            m.insert("delta_bytes_ratio".to_string(), Json::Num(r));
        }
        Json::Obj(m)
    }
}

/// Assemble the `BENCH_serve.json` document.
pub fn bench_serve_json(records: &[ServeBenchRecord]) -> Json {
    let mut top = BTreeMap::new();
    top.insert("version".to_string(), Json::Num(1.0));
    top.insert("bench".to_string(), Json::Str("serve".to_string()));
    top.insert(
        "records".to_string(),
        Json::Arr(records.iter().map(ServeBenchRecord::to_json).collect()),
    );
    Json::Obj(top)
}

/// Write the `BENCH_serve.json` document to disk.
pub fn write_bench_serve(path: &Path, records: &[ServeBenchRecord]) -> std::io::Result<()> {
    std::fs::write(path, bench_serve_json(records).to_string())
}

/// One socket-tier measurement for `BENCH_rpc.json` (schema in the
/// module docs).
#[derive(Clone, Debug)]
pub struct RpcBenchRecord {
    pub label: String,
    /// `"inproc"`, `"rpc-1"` or `"rpc-3-churn"`.
    pub mode: String,
    /// Replica *processes* serving the load (0 on the inproc row).
    pub replicas: usize,
    /// Concurrent load-generator clients.
    pub clients: usize,
    /// Requests answered.
    pub requests: usize,
    /// Sustained throughput, requests per second.
    pub qps: f64,
    /// Exact median per-request latency (wire + queue + compute), µs.
    pub p50_us: u64,
    /// Exact 99th-percentile per-request latency, µs.
    pub p99_us: u64,
    /// This row's `qps` / the inproc row's `qps` (socket rows only).
    pub qps_ratio_vs_inproc: Option<f64>,
    /// Snapshot catch-ups the writer served during the run (churn rows).
    pub catchups: Option<u64>,
    /// 1.0 when every restarted replica converged back to the writer's
    /// latest version before the run ended, else 0.0 (churn rows).
    pub catchup_ok: Option<f64>,
}

impl RpcBenchRecord {
    /// Build a record from one arm's load report.
    #[allow(clippy::too_many_arguments)]
    pub fn from_load(
        label: &str,
        mode: &str,
        replicas: usize,
        clients: usize,
        requests: usize,
        qps: f64,
        p50_us: u64,
        p99_us: u64,
    ) -> Self {
        RpcBenchRecord {
            label: label.to_string(),
            mode: mode.to_string(),
            replicas,
            clients,
            requests,
            qps,
            p50_us,
            p99_us,
            qps_ratio_vs_inproc: None,
            catchups: None,
            catchup_ok: None,
        }
    }

    /// Attach the throughput ratio against the in-process reference row.
    pub fn with_ratio_vs(mut self, inproc: &RpcBenchRecord) -> Self {
        self.qps_ratio_vs_inproc = Some(self.qps / inproc.qps.max(1e-12));
        self
    }

    /// Attach the churn outcome: catch-ups served and whether the
    /// restarted replica(s) converged back to the latest version.
    pub fn with_churn(mut self, catchups: u64, converged: bool) -> Self {
        self.catchups = Some(catchups);
        self.catchup_ok = Some(if converged { 1.0 } else { 0.0 });
        self
    }

    /// One human-readable console line.
    pub fn line(&self) -> String {
        format!(
            "{:<12} {:<11} R={:<2} C={:<2} {:>8} req  {:>10.0} req/s  p50={:>5}µs p99={:>6}µs{}{}",
            self.label,
            self.mode,
            self.replicas,
            self.clients,
            self.requests,
            self.qps,
            self.p50_us,
            self.p99_us,
            self.qps_ratio_vs_inproc
                .map(|r| format!("  ({r:.3}× vs inproc)"))
                .unwrap_or_default(),
            self.catchup_ok
                .map(|ok| format!(
                    "  (catchups={}, {})",
                    self.catchups.unwrap_or(0),
                    if ok >= 1.0 { "converged" } else { "DIVERGED" }
                ))
                .unwrap_or_default()
        )
    }

    /// Serialize to a JSON object (schema in the module docs).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("label".to_string(), Json::Str(self.label.clone()));
        m.insert("mode".to_string(), Json::Str(self.mode.clone()));
        m.insert("replicas".to_string(), Json::Num(self.replicas as f64));
        m.insert("clients".to_string(), Json::Num(self.clients as f64));
        m.insert("requests".to_string(), Json::Num(self.requests as f64));
        m.insert("qps".to_string(), Json::Num(self.qps));
        m.insert("p50_us".to_string(), Json::Num(self.p50_us as f64));
        m.insert("p99_us".to_string(), Json::Num(self.p99_us as f64));
        if let Some(r) = self.qps_ratio_vs_inproc {
            m.insert("qps_ratio_vs_inproc".to_string(), Json::Num(r));
        }
        if let Some(c) = self.catchups {
            m.insert("catchups".to_string(), Json::Num(c as f64));
        }
        if let Some(ok) = self.catchup_ok {
            m.insert("catchup_ok".to_string(), Json::Num(ok));
        }
        Json::Obj(m)
    }
}

/// Assemble the `BENCH_rpc.json` document.
pub fn bench_rpc_json(records: &[RpcBenchRecord]) -> Json {
    let mut top = BTreeMap::new();
    top.insert("version".to_string(), Json::Num(1.0));
    top.insert("bench".to_string(), Json::Str("rpc".to_string()));
    top.insert(
        "records".to_string(),
        Json::Arr(records.iter().map(RpcBenchRecord::to_json).collect()),
    );
    Json::Obj(top)
}

/// Write the `BENCH_rpc.json` document to disk.
pub fn write_bench_rpc(path: &Path, records: &[RpcBenchRecord]) -> std::io::Result<()> {
    std::fs::write(path, bench_rpc_json(records).to_string())
}

/// One ingest-tier measurement for `BENCH_ingest.json` (schema in the
/// module docs).
#[derive(Clone, Debug)]
pub struct IngestBenchRecord {
    pub label: String,
    /// `"serial"`, `"epochd-2"` or `"epochd-max"`.
    pub mode: String,
    /// Producer threads P (1 on the serial reference row).
    pub producers: usize,
    /// Ingest shards S (1 on the serial reference row).
    pub shards: usize,
    /// `|D|` (total base tuples) before the trace.
    pub base_rows: usize,
    /// Deltas per batch (= per epoch).
    pub batch: usize,
    /// Batches (= epochs) in the trace.
    pub batches: usize,
    /// Enqueue-to-last-epoch-closed wall time.
    pub total_s: f64,
    /// `batch · batches / total_s` — the gated throughput.
    pub deltas_per_sec: f64,
    /// Median first-entry-seen → epoch-closed latency, µs.
    pub epoch_p50_us: u64,
    /// 99th-percentile epoch-close latency, µs.
    pub epoch_p99_us: u64,
    /// Non-zero grid cells after the trace (identical across arms by
    /// the bitwise-merge contract the emitting bench asserts).
    pub grid_cells: usize,
    /// This row's `deltas_per_sec` / the serial row's (epoch'd rows).
    pub speedup_vs_serial: Option<f64>,
}

impl IngestBenchRecord {
    /// Build a record from one arm's measurements.
    #[allow(clippy::too_many_arguments)]
    pub fn from_run(
        label: &str,
        mode: &str,
        producers: usize,
        shards: usize,
        base_rows: usize,
        batch: usize,
        batches: usize,
        total_s: f64,
        epoch_p50_us: u64,
        epoch_p99_us: u64,
        grid_cells: usize,
    ) -> Self {
        IngestBenchRecord {
            label: label.to_string(),
            mode: mode.to_string(),
            producers,
            shards,
            base_rows,
            batch,
            batches,
            total_s,
            deltas_per_sec: (batch * batches) as f64 / total_s.max(1e-12),
            epoch_p50_us,
            epoch_p99_us,
            grid_cells,
            speedup_vs_serial: None,
        }
    }

    /// Attach the throughput speedup against the serial reference row.
    pub fn with_speedup_vs(mut self, serial: &IngestBenchRecord) -> Self {
        self.speedup_vs_serial = Some(self.deltas_per_sec / serial.deltas_per_sec.max(1e-12));
        self
    }

    /// One human-readable console line.
    pub fn line(&self) -> String {
        format!(
            "{:<16} {:<11} P={:<3} S={:<3} batch={:<5}×{:<3} {:>8.4}s  {:>10.0} deltas/s  \
             epoch p50={:>6}µs p99={:>7}µs{}",
            self.label,
            self.mode,
            self.producers,
            self.shards,
            self.batch,
            self.batches,
            self.total_s,
            self.deltas_per_sec,
            self.epoch_p50_us,
            self.epoch_p99_us,
            self.speedup_vs_serial
                .map(|s| format!("  ({s:.2}× vs serial)"))
                .unwrap_or_default()
        )
    }

    /// Serialize to a JSON object (schema in the module docs).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("label".to_string(), Json::Str(self.label.clone()));
        m.insert("mode".to_string(), Json::Str(self.mode.clone()));
        m.insert("producers".to_string(), Json::Num(self.producers as f64));
        m.insert("shards".to_string(), Json::Num(self.shards as f64));
        m.insert("base_rows".to_string(), Json::Num(self.base_rows as f64));
        m.insert("batch".to_string(), Json::Num(self.batch as f64));
        m.insert("batches".to_string(), Json::Num(self.batches as f64));
        m.insert("total_s".to_string(), Json::Num(self.total_s));
        m.insert("deltas_per_sec".to_string(), Json::Num(self.deltas_per_sec));
        m.insert("epoch_p50_us".to_string(), Json::Num(self.epoch_p50_us as f64));
        m.insert("epoch_p99_us".to_string(), Json::Num(self.epoch_p99_us as f64));
        m.insert("grid_cells".to_string(), Json::Num(self.grid_cells as f64));
        if let Some(s) = self.speedup_vs_serial {
            m.insert("speedup_vs_serial".to_string(), Json::Num(s));
        }
        Json::Obj(m)
    }
}

/// Assemble the `BENCH_ingest.json` document.
pub fn bench_ingest_json(records: &[IngestBenchRecord]) -> Json {
    let mut top = BTreeMap::new();
    top.insert("version".to_string(), Json::Num(1.0));
    top.insert("bench".to_string(), Json::Str("ingest".to_string()));
    top.insert(
        "records".to_string(),
        Json::Arr(records.iter().map(IngestBenchRecord::to_json).collect()),
    );
    Json::Obj(top)
}

/// Write the `BENCH_ingest.json` document to disk.
pub fn write_bench_ingest(path: &Path, records: &[IngestBenchRecord]) -> std::io::Result<()> {
    std::fs::write(path, bench_ingest_json(records).to_string())
}

/// Format a duration in seconds with appropriate precision.
pub fn fmt_secs(d: Duration) -> String {
    let s = secs(d);
    if s < 0.01 {
        format!("{:.2}ms", s * 1000.0)
    } else {
        format!("{s:.2}s")
    }
}

/// Format a speedup factor like the paper (`15.38×`).
pub fn fmt_speedup(x: f64) -> String {
    format!("{x:.2}×")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measurement_stats() {
        let m = Measurement { name: "x".into(), samples: vec![3.0, 1.0, 2.0] };
        assert_eq!(m.min(), 1.0);
        assert_eq!(m.mean(), 2.0);
        assert_eq!(m.median(), 2.0);
        assert!(m.line().contains("x"));
    }

    #[test]
    fn bench_runs_and_counts() {
        let mut calls = 0;
        let m = bench("inc", 2, 5, || {
            calls += 1;
            calls
        });
        assert_eq!(m.samples.len(), 5);
        assert_eq!(calls, 7);
    }

    #[test]
    fn table_renders_markdown() {
        let mut t = Table::new("Demo", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("### Demo"));
        assert!(r.contains("| a "));
        assert!(r.contains("| 1 "));
        assert!(r.lines().any(|l| l.starts_with("|--") || l.starts_with("|---")));
    }

    #[test]
    #[should_panic(expected = "column count")]
    fn table_rejects_bad_rows() {
        let mut t = Table::new("Demo", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_speedup(15.379), "15.38×");
        assert!(fmt_secs(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_secs(Duration::from_secs(2)).ends_with('s'));
    }

    #[test]
    fn stream_bench_json_roundtrips() {
        let rebuild = StreamBenchRecord::from_batches(
            "retailer-trace",
            "rebuild",
            10_000,
            100,
            &[0.5, 0.7, 0.6],
            400,
            99.0,
        );
        let cold = StreamBenchRecord::from_batches(
            "retailer-trace",
            "patched-cold",
            10_000,
            100,
            &[0.10, 0.14, 0.12],
            400,
            99.0,
        );
        let patched = StreamBenchRecord::from_batches(
            "retailer-trace",
            "patched",
            10_000,
            100,
            &[0.05, 0.07, 0.06],
            400,
            99.0,
        )
        .with_speedup_vs(&rebuild)
        .with_carry_speedup_vs(&cold);
        assert!((patched.speedup_vs_rebuild.unwrap() - 10.0).abs() < 1e-9);
        assert!((patched.speedup_vs_cold.unwrap() - 2.0).abs() < 1e-9);
        assert!((rebuild.mean_batch_s - 0.6).abs() < 1e-12);
        assert!((rebuild.max_batch_s - 0.7).abs() < 1e-12);
        assert!(patched.line().contains("vs rebuild"));

        let doc = bench_stream_json(&[rebuild, patched]);
        let parsed = crate::util::json::parse(&doc.to_string()).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str(), Some("stream"));
        let recs = parsed.get("records").unwrap().as_arr().unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].get("mode").unwrap().as_str(), Some("rebuild"));
        assert!(recs[0].get("speedup_vs_rebuild").is_none());
        let s = recs[1].get("speedup_vs_rebuild").unwrap().as_f64().unwrap();
        assert!((s - 10.0).abs() < 1e-9);
    }

    #[test]
    fn sweep_bench_json_roundtrips() {
        let indep = SweepBenchRecord::from_runs(
            "retailer",
            "independent",
            &[4, 8],
            8,
            400,
            2.0,
            &[0.8, 1.2],
            &[100.0, 50.0],
        );
        let shared = SweepBenchRecord::from_runs(
            "retailer",
            "shared-coreset",
            &[4, 8],
            8,
            400,
            0.5,
            &[0.1, 0.2],
            &[100.0, 50.0],
        )
        .with_speedup_vs(&indep);
        assert!((shared.speedup_vs_independent.unwrap() - 4.0).abs() < 1e-9);
        assert!(shared.line().contains("vs independent"));

        let doc = bench_sweep_json(&[indep, shared]);
        let parsed = crate::util::json::parse(&doc.to_string()).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str(), Some("sweep"));
        let recs = parsed.get("records").unwrap().as_arr().unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].get("mode").unwrap().as_str(), Some("independent"));
        assert!(recs[0].get("speedup_vs_independent").is_none());
        let ks = recs[1].get("ks").unwrap().as_arr().unwrap();
        assert_eq!(ks.len(), 2);
        assert_eq!(ks[1].as_usize(), Some(8));
        let s = recs[1].get("speedup_vs_independent").unwrap().as_f64().unwrap();
        assert!((s - 4.0).abs() < 1e-9);
    }

    #[test]
    fn shard_bench_json_roundtrips() {
        let serial =
            ShardBenchRecord::from_build("retailer", "serial", 1, 1, 0.30, 2.0, 400, 10_000.0);
        let sharded =
            ShardBenchRecord::from_build("retailer", "sharded-max", 8, 8, 0.30, 0.5, 400, 10_000.0)
                .with_speedup_vs(&serial);
        assert!((sharded.speedup_vs_serial.unwrap() - 4.0).abs() < 1e-9);
        assert!((serial.build_s - 2.3).abs() < 1e-12);
        assert!(sharded.line().contains("vs serial"));

        let doc = bench_shard_json(&[serial, sharded]);
        let parsed = crate::util::json::parse(&doc.to_string()).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str(), Some("shard"));
        assert_eq!(parsed.get("version").unwrap().as_usize(), Some(1));
        let recs = parsed.get("records").unwrap().as_arr().unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].get("mode").unwrap().as_str(), Some("serial"));
        assert!(recs[0].get("speedup_vs_serial").is_none());
        assert_eq!(recs[1].get("shards").unwrap().as_usize(), Some(8));
        assert_eq!(recs[1].get("grid_cells").unwrap().as_usize(), Some(400));
        let s = recs[1].get("speedup_vs_serial").unwrap().as_f64().unwrap();
        assert!((s - 4.0).abs() < 1e-9);
    }

    #[test]
    fn serve_bench_json_roundtrips() {
        let naive =
            ServeBenchRecord::from_load("retailer", "naive", 1, 1, 1, 5000, 50_000.0, 18, 40);
        let mesh =
            ServeBenchRecord::from_load("retailer", "mesh", 2, 4, 64, 20_000, 150_000.0, 25, 90)
                .with_speedup_vs(&naive);
        let delta =
            ServeBenchRecord::from_load("retailer", "delta", 2, 4, 64, 20_000, 140_000.0, 26, 95)
                .with_publish_bytes(1_000, 16_000);
        assert!((mesh.speedup_vs_naive.unwrap() - 3.0).abs() < 1e-9);
        assert!((delta.delta_bytes_ratio.unwrap() - 16.0).abs() < 1e-9);
        assert!(mesh.line().contains("vs naive"));
        assert!(delta.line().contains("smaller"));

        let doc = bench_serve_json(&[naive, mesh, delta]);
        let parsed = crate::util::json::parse(&doc.to_string()).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str(), Some("serve"));
        assert_eq!(parsed.get("version").unwrap().as_usize(), Some(1));
        let recs = parsed.get("records").unwrap().as_arr().unwrap();
        assert_eq!(recs.len(), 3);
        assert_eq!(recs[0].get("mode").unwrap().as_str(), Some("naive"));
        assert!(recs[0].get("speedup_vs_naive").is_none());
        assert!(recs[0].get("delta_bytes_ratio").is_none());
        let s = recs[1].get("speedup_vs_naive").unwrap().as_f64().unwrap();
        assert!((s - 3.0).abs() < 1e-9);
        assert_eq!(recs[2].get("delta_bytes").unwrap().as_usize(), Some(1_000));
        let r = recs[2].get("delta_bytes_ratio").unwrap().as_f64().unwrap();
        assert!((r - 16.0).abs() < 1e-9);
    }

    #[test]
    fn rpc_bench_json_roundtrips() {
        let inproc =
            RpcBenchRecord::from_load("retailer", "inproc", 0, 4, 20_000, 400_000.0, 20, 80);
        let one = RpcBenchRecord::from_load("retailer", "rpc-1", 1, 4, 20_000, 100_000.0, 150, 900)
            .with_ratio_vs(&inproc);
        let churn =
            RpcBenchRecord::from_load("retailer", "rpc-3-churn", 3, 4, 20_000, 90_000.0, 160, 950)
                .with_ratio_vs(&inproc)
                .with_churn(2, true);
        assert!((one.qps_ratio_vs_inproc.unwrap() - 0.25).abs() < 1e-9);
        assert_eq!(churn.catchup_ok, Some(1.0));
        assert!(one.line().contains("vs inproc"));
        assert!(churn.line().contains("converged"));

        let doc = bench_rpc_json(&[inproc, one, churn]);
        let parsed = crate::util::json::parse(&doc.to_string()).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str(), Some("rpc"));
        assert_eq!(parsed.get("version").unwrap().as_usize(), Some(1));
        let recs = parsed.get("records").unwrap().as_arr().unwrap();
        assert_eq!(recs.len(), 3);
        assert_eq!(recs[0].get("mode").unwrap().as_str(), Some("inproc"));
        assert!(recs[0].get("qps_ratio_vs_inproc").is_none());
        assert!(recs[0].get("catchup_ok").is_none());
        let r = recs[1].get("qps_ratio_vs_inproc").unwrap().as_f64().unwrap();
        assert!((r - 0.25).abs() < 1e-9);
        assert_eq!(recs[2].get("catchups").unwrap().as_usize(), Some(2));
        let ok = recs[2].get("catchup_ok").unwrap().as_f64().unwrap();
        assert!((ok - 1.0).abs() < 1e-9);
    }

    #[test]
    fn ingest_bench_json_roundtrips() {
        let serial = IngestBenchRecord::from_run(
            "retailer-trace",
            "serial",
            1,
            1,
            10_000,
            200,
            5,
            2.0,
            380_000,
            420_000,
            81,
        );
        assert!((serial.deltas_per_sec - 500.0).abs() < 1e-9);
        let max = IngestBenchRecord::from_run(
            "retailer-trace",
            "epochd-max",
            8,
            8,
            10_000,
            200,
            5,
            0.5,
            95_000,
            140_000,
            81,
        )
        .with_speedup_vs(&serial);
        assert!((max.speedup_vs_serial.unwrap() - 4.0).abs() < 1e-9);
        assert!(max.line().contains("vs serial"));

        let doc = bench_ingest_json(&[serial, max]);
        let parsed = crate::util::json::parse(&doc.to_string()).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str(), Some("ingest"));
        assert_eq!(parsed.get("version").unwrap().as_usize(), Some(1));
        let recs = parsed.get("records").unwrap().as_arr().unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].get("mode").unwrap().as_str(), Some("serial"));
        assert!(recs[0].get("speedup_vs_serial").is_none());
        assert_eq!(recs[1].get("producers").unwrap().as_usize(), Some(8));
        assert_eq!(recs[1].get("epoch_p50_us").unwrap().as_usize(), Some(95_000));
        let s = recs[1].get("speedup_vs_serial").unwrap().as_f64().unwrap();
        assert!((s - 4.0).abs() < 1e-9);
    }

    #[test]
    fn lloyd_bench_json_roundtrips() {
        let stats = PruneStats {
            iters: 3,
            points: 1000,
            dist_evals: 5000,
            dist_evals_skipped: 19000,
            bounds: "elkan",
            precision: "f32",
            wall: Duration::from_millis(500),
            ..PruneStats::default()
        };
        let naive = LloydBenchRecord::from_stats("synth", "dense-naive", 8, 8, 42.0, &stats);
        let pruned = LloydBenchRecord::from_stats("synth", "dense-pruned", 8, 8, 42.0, &stats)
            .with_speedup_vs(&naive);
        assert_eq!(pruned.speedup_vs_naive, Some(1.0));
        assert!(pruned.line().contains("dense-pruned"));

        let doc = bench_lloyd_json(&[naive, pruned]);
        let text = doc.to_string();
        let parsed = crate::util::json::parse(&text).unwrap();
        assert_eq!(parsed.get("version").unwrap().as_usize(), Some(1));
        let recs = parsed.get("records").unwrap().as_arr().unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].get("engine").unwrap().as_str(), Some("dense-naive"));
        assert_eq!(recs[0].get("bounds").unwrap().as_str(), Some("elkan"));
        assert_eq!(recs[0].get("precision").unwrap().as_str(), Some("f32"));
        assert_eq!(recs[0].get("n").unwrap().as_usize(), Some(1000));
        assert!(recs[0].get("speedup_vs_naive").is_none());
        assert_eq!(recs[1].get("speedup_vs_naive").unwrap().as_f64(), Some(1.0));
        let skip = recs[1].get("skip_rate").unwrap().as_f64().unwrap();
        assert!((skip - 19.0 / 24.0).abs() < 1e-9);
    }
}
