//! Property tests: the FAQ engine must agree exactly with brute-force
//! semantics on randomly generated acyclic databases. This is the
//! correctness backbone of the whole system — every Rk-means step trusts
//! these counts.

use rkmeans::data::{Attr, Database, Relation, Schema, Value};
use rkmeans::faq::{full_join_counts, grid_weights, marginals, output_size, GidAssigner, Marginal};
use rkmeans::join::materialize;
use rkmeans::query::{Feq, Hypergraph};
use rkmeans::util::testkit::{assert_close, for_cases};
use rkmeans::util::{FxHashMap, SplitMix64};

/// Random star schema: fact(j1..jf, payload) + one dimension per join key,
/// each dimension with a categorical and a continuous payload attribute.
/// Fan-out on dimension keys is random (1..=3 rows per key), so the join
/// both prunes (missing keys) and multiplies (duplicate keys).
fn random_star(rng: &mut SplitMix64) -> (Database, Feq) {
    let n_dims = 1 + rng.below(3) as usize;
    let key_dom = 3 + rng.below(5) as u32;
    let n_fact = 5 + rng.below(40) as usize;

    let mut db = Database::new();
    let mut rels: Vec<String> = Vec::new();
    let mut features: Vec<String> = Vec::new();

    // Fact table.
    let mut fact_attrs: Vec<Attr> =
        (0..n_dims).map(|i| Attr::cat(&format!("j{i}"), key_dom)).collect();
    fact_attrs.push(Attr::double("payload"));
    let mut fact = Relation::new("fact", Schema::new(fact_attrs));
    for _ in 0..n_fact {
        let mut vals: Vec<Value> =
            (0..n_dims).map(|_| Value::Cat(rng.below(key_dom as u64) as u32)).collect();
        vals.push(Value::Double((rng.below(6) as f64) * 0.5));
        fact.push_row(&vals);
    }
    db.add(fact);
    rels.push("fact".into());
    features.push("payload".into());

    // Dimensions with random fan-out; some keys intentionally missing.
    for i in 0..n_dims {
        let mut rel = Relation::new(
            &format!("dim{i}"),
            Schema::new(vec![
                Attr::cat(&format!("j{i}"), key_dom),
                Attr::cat(&format!("c{i}"), 6),
                Attr::double(&format!("x{i}")),
            ]),
        );
        for key in 0..key_dom {
            if rng.coin(0.85) {
                let fanout = 1 + rng.below(3);
                for _ in 0..fanout {
                    rel.push_row(&[
                        Value::Cat(key),
                        Value::Cat(rng.below(6) as u32),
                        Value::Double((rng.below(4) as f64) * 0.25),
                    ]);
                }
            }
        }
        db.add(rel);
        rels.push(format!("dim{i}"));
        features.push(format!("c{i}"));
        features.push(format!("x{i}"));
    }

    let rel_refs: Vec<&str> = rels.iter().map(|s| s.as_str()).collect();
    let feat_refs: Vec<&str> = features.iter().map(|s| s.as_str()).collect();
    (db, Feq::with_features(&rel_refs, &feat_refs))
}

#[test]
fn output_size_equals_materialized_rows() {
    for_cases(25, |rng| {
        let (db, feq) = random_star(rng);
        let tree = Hypergraph::from_feq(&db, &feq).join_tree().expect("acyclic");
        let x = materialize(&db, &feq, &tree).expect("materialize");
        let faq = output_size(&db, &tree).expect("faq");
        assert_close(faq, x.mass(), 1e-9);
    });
}

#[test]
fn marginals_match_materialized_groupby() {
    for_cases(20, |rng| {
        let (db, feq) = random_star(rng);
        let tree = Hypergraph::from_feq(&db, &feq).join_tree().expect("acyclic");
        let jc = full_join_counts(&db, &tree).expect("counts");
        let faq_marg = marginals(&db, &feq, &tree, &jc).expect("marginals");
        let x = materialize(&db, &feq, &tree).expect("materialize");

        for (fi, f) in feq.features.iter().enumerate() {
            // Brute-force group-by over the materialized output.
            match &faq_marg[&f.attr] {
                Marginal::Continuous(pairs) => {
                    let mut expect: FxHashMap<u64, f64> = FxHashMap::default();
                    for (row, w) in x.rows.iter().zip(&x.weights) {
                        *expect.entry(row[fi].as_f64().to_bits()).or_insert(0.0) += w;
                    }
                    assert_eq!(pairs.len(), expect.len(), "support of {}", f.attr);
                    for &(v, w) in pairs {
                        assert_close(expect[&v.to_bits()], w, 1e-9);
                    }
                }
                Marginal::Discrete(pairs) => {
                    let mut expect: FxHashMap<u64, f64> = FxHashMap::default();
                    for (row, w) in x.rows.iter().zip(&x.weights) {
                        *expect.entry(row[fi].key_u64()).or_insert(0.0) += w;
                    }
                    assert_eq!(pairs.len(), expect.len(), "support of {}", f.attr);
                    for &(v, w) in pairs {
                        assert_close(expect[&v], w, 1e-9);
                    }
                }
            }
        }
    });
}

struct ModAssigner(u32);
impl GidAssigner for ModAssigner {
    fn gid(&self, v: Value) -> u32 {
        match v {
            Value::Double(x) => ((x * 4.0) as i64).rem_euclid(self.0 as i64) as u32,
            other => (other.key_u64() % self.0 as u64) as u32,
        }
    }
    fn n_gids(&self) -> usize {
        self.0 as usize
    }
}

#[test]
fn grid_weights_match_materialized_assignment() {
    for_cases(20, |rng| {
        let (db, feq) = random_star(rng);
        let tree = Hypergraph::from_feq(&db, &feq).join_tree().expect("acyclic");
        let kappa = 2 + rng.below(3) as u32;
        let mut assigners: FxHashMap<String, Box<dyn GidAssigner>> = FxHashMap::default();
        for f in &feq.features {
            assigners.insert(f.attr.clone(), Box::new(ModAssigner(kappa)));
        }
        let gt = grid_weights(&db, &feq, &tree, &assigners).expect("grid");

        // Oracle: materialize + assign + group.
        let x = materialize(&db, &feq, &tree).expect("materialize");
        let asg = ModAssigner(kappa);
        let mut expect: FxHashMap<Vec<u32>, f64> = FxHashMap::default();
        for (row, w) in x.rows.iter().zip(&x.weights) {
            let key: Vec<u32> = row.iter().map(|v| asg.gid(*v)).collect();
            *expect.entry(key).or_insert(0.0) += w;
        }
        assert_eq!(gt.len(), expect.len());
        for (gids, w) in &gt.cells {
            assert_close(expect[gids], *w, 1e-9);
        }
    });
}

#[test]
fn dangling_tuples_never_counted() {
    // A fact row with a key missing from a dimension contributes nothing.
    let mut fact =
        Relation::new("fact", Schema::new(vec![Attr::cat("j", 4), Attr::double("p")]));
    fact.push_row(&[Value::Cat(0), Value::Double(1.0)]);
    fact.push_row(&[Value::Cat(3), Value::Double(2.0)]); // dangling
    let mut dim = Relation::new("dim", Schema::new(vec![Attr::cat("j", 4), Attr::cat("c", 2)]));
    dim.push_row(&[Value::Cat(0), Value::Cat(1)]);
    let mut db = Database::new();
    db.add(fact);
    db.add(dim);
    let feq = Feq::with_features(&["fact", "dim"], &["p", "c"]);
    let tree = Hypergraph::from_feq(&db, &feq).join_tree().unwrap();
    let jc = full_join_counts(&db, &tree).unwrap();
    assert_eq!(jc.total, 1.0);
    let m = marginals(&db, &feq, &tree, &jc).unwrap();
    match &m["p"] {
        Marginal::Continuous(pairs) => assert_eq!(pairs, &vec![(1.0, 1.0)]),
        _ => panic!("p is continuous"),
    }
}

#[test]
fn weighted_base_relations_flow_through() {
    for_cases(10, |rng| {
        let (mut db, feq) = random_star(rng);
        // Re-weight the fact table with random multiplicities.
        let fact = db.get_mut("fact").expect("fact");
        let mut new = Relation::new("fact", fact.schema.clone());
        let mut rng2 = SplitMix64::new(rng.next_u64());
        for r in 0..fact.n_rows() {
            new.push_row_weighted(&fact.row(r), 1.0 + rng2.below(3) as f64);
        }
        *fact = new;

        let tree = Hypergraph::from_feq(&db, &feq).join_tree().expect("acyclic");
        let x = materialize(&db, &feq, &tree).expect("materialize");
        let faq = output_size(&db, &tree).expect("faq");
        assert_close(faq, x.mass(), 1e-9);
    });
}
