//! # Rk-means: fast k-means clustering for relational data
//!
//! A production-oriented reproduction of *"Rk-means: Fast Clustering for
//! Relational Data"* (Curtin, Moseley, Ngo, Nguyen, Olteanu, Schleich, 2019).
//!
//! Conventional k-means needs the materialized data matrix `X` — the output
//! of a feature-extraction query (FEQ) joining several relations — which can
//! be asymptotically larger than the database itself. Rk-means instead:
//!
//! 1. computes the *marginal* weight of every attribute value in the
//!    (unmaterialized) join via FAQ / variable-elimination ([`faq`]),
//! 2. optimally clusters each 1-attribute subspace (dynamic programming for
//!    continuous attributes, a closed form for categorical ones) ([`cluster`]),
//! 3. assembles the weighted *grid coreset* `G = C_1 × … × C_m`, extracting
//!    only grid cells with non-zero weight — again without materializing the
//!    join ([`coreset`]),
//! 4. runs weighted k-means over the coreset with a factored distance
//!    computation that is O(1) per (grid-point, centroid, subspace)
//!    ([`cluster::sparse_lloyd`]).
//!
//! The result is a `(√α+√γ+√αγ)²`-approximation of the k-means objective on
//! the full join output (9-approximation with exact sub-solvers), computed in
//! time that can be *asymptotically smaller than `|X|`* (Theorem 4.7).
//!
//! ## Architecture (three layers, staged)
//!
//! * **Layer 3 (this crate)** — the relational engine and coordinator,
//!   organized around the **staged pipeline API**
//!   ([`rkmeans::RkPipeline`]): plan (join tree + cyclic rewrite) →
//!   [`rkmeans::Marginals`] (Step 1) → [`rkmeans::SubspaceSet`] (Step 2)
//!   → [`rkmeans::Coreset`] (Step 3) → [`rkmeans::RkModel`] (Step 4).
//!   Each stage returns an owned artifact later stages borrow, so a
//!   κ-sweep reuses the marginals and a k-sweep
//!   ([`rkmeans::Coreset::sweep`]) reuses one coreset. Step 3 also
//!   builds **shard-parallel**
//!   ([`rkmeans::RkPipeline::coreset_sharded`]): the fact relation is
//!   value-hash partitioned ([`faq::shard_of`]), one counting-FAQ grid
//!   is built per shard as a job on the shared pool, and the per-shard
//!   grids merge by exact ring-ℤ weight addition
//!   ([`rkmeans::Coreset::from_shards`]) — bitwise-identical to the
//!   serial build, so parallelism never changes results.
//!   [`rkmeans::RkModel`]
//!   is a self-contained, **serializable** serving handle
//!   (`assign`/`assign_batch` on never-materialized tuples,
//!   versioned `to_bytes`/`from_bytes` for replica shipping).
//!   Underneath sit columnar storage ([`data`]), join hypergraphs + GYO
//!   join-tree decomposition ([`query`]), a Yannakakis/InsideOut
//!   message-passing FAQ engine ([`faq`]), the materializing baseline
//!   ([`join`]), the clustering tool-box ([`cluster`]), the grid coreset
//!   internals ([`coreset`]), a streaming coordinator with backpressure
//!   and incremental re-clustering ([`coordinator`]), true delta
//!   maintenance of the grid coreset under tuple inserts/deletes —
//!   single-stream or shard-parallel ([`incremental`],
//!   [`incremental::sharded`]), the serving mesh — replicated hot-swap
//!   models, micro-batched assignment, centroid-delta publication
//!   ([`serve`]) — a persistent deterministic execution
//!   pool shared by every Step-4 dispatch ([`util::exec`]), synthetic workloads
//!   mirroring the paper's
//!   Retailer / Favorita / Yelp datasets ([`synthetic`]) and the
//!   paper-table bench harness ([`bench_harness`]).
//! * **Layer 2 (python/compile/model.py)** — the JAX weighted-Lloyd step,
//!   AOT-lowered to HLO text per shape bucket (`make artifacts`).
//! * **Layer 1 (python/compile/kernels/lloyd.py)** — the Pallas
//!   distance+argmin kernel feeding the MXU, verified against a pure-jnp
//!   oracle. Executed from rust through the PJRT CPU client ([`runtime`]).
//!
//! Python never runs on the clustering path: the rust binary is
//! self-contained once `artifacts/` is built.
//!
//! ## Serving tier
//!
//! The [`serve`] module carries the factored `assign` to request rates:
//! a [`serve::ModelMesh`] holds N hot-swappable [`rkmeans::RkModel`]
//! replicas (readers pin a version with an `Arc` clone — swaps are
//! pointer flips, never torn reads), a [`serve::AssignFront`] collects
//! concurrent assign requests into micro-batches dispatched on the
//! shared [`util::exec::ExecPool`] (served versions monotone across
//! clients), and a [`serve::Publisher`] ships new versions as
//! **centroid deltas** ([`serve::ModelDelta`],
//! [`rkmeans::RkModel::diff`] / [`rkmeans::RkModel::apply_delta`] with
//! bit-exact reconstruction and stale-delta rejection) instead of full
//! snapshots — on the incremental planner's patch path a delta is a
//! handful of centroid rows while a snapshot carries whole categorical
//! domains. `rkmeans serve` runs the loop end-to-end under the
//! open-loop generator in [`serve::load`]; the streaming-coordinator
//! demo lives on as `rkmeans stream`.
//!
//! The same tier crosses a real **process boundary** through
//! [`serve::rpc`]: a length-prefixed framed protocol over TCP with an
//! assign plane (encoded rows in, `Assignment{cluster, version}` out
//! through the same micro-batching front), a replication plane
//! (replica processes subscribe to the publisher's delta stream and
//! recover from a `VersionGap` by requesting a full snapshot, verified
//! **byte-identical** to [`rkmeans::RkModel::to_bytes`] before
//! install), and a control plane (health/version probes, remote stop).
//! `rkmeans serve --listen` runs the writer side, `rkmeans replica
//! --connect` a replica process, and `rkmeans bench-rpc` the socket
//! load generator; `tests/serve_rpc.rs` exercises the topology with
//! real processes, including a kill-one-replica → snapshot-catch-up →
//! rejoin cycle, and `benches/rpc_load.rs` measures it against the
//! in-process front.
//!
//! ## Ingest tier
//!
//! The [`ingest`] module parallelizes the *write* path the way [`serve`]
//! parallelizes the read path. P independent [`ingest::IngestProducer`]
//! handles stamp tuple deltas with an epoch number and route them into S
//! **bounded** per-shard queues (fact deltas to their
//! [`faq::shard_of`] value-hash shard, dimension deltas broadcast);
//! producers that outrun a shard block on that shard alone
//! (`ingest.backpressure`, `ingest.queue_depth.<s>`). The
//! [`ingest::IngestHub`] applies each shard's fully-sealed epochs as
//! independent [`incremental::DeltaFaq`] patches on the shared pool with
//! **no global batch barrier** — shards run ahead of each other
//! (`ingest.watermark_lag`) — and *closes* an epoch only when every
//! shard's watermark passes it, merging the per-shard snapshots by exact
//! ring-ℤ addition into one [`incremental::EpochPatch`] (merged grid,
//! composed splice log, logical delta sequence). Closed epochs feed
//! [`incremental::IncrementalEngine::apply_epoch`], so the serving tier
//! only ever publishes fully-drained epochs; resident memory per shard
//! is bounded by cold-key spilling
//! ([`incremental::DeltaFaq::set_spill_budget`], the
//! `--spill-budget` CLI knob). `rkmeans stream --producers P --shards S`
//! runs the tier end-to-end, and `benches/ingest_scale.rs` measures the
//! multi-producer speedup with the bitwise cross-arm assertion inline.
//!
//! ## Determinism contract
//!
//! The system's correctness story is a set of **bitwise** equivalences,
//! each pinned by a runtime property test *and* guarded statically by an
//! [`analysis`] (`rklint`) rule so violations fail CI before a schedule
//! ever has to catch them:
//!
//! * **naive ≡ pruned** — Hamerly/Elkan bounds never change Step-4
//!   results, and **pool ≡ scoped-spawn** — parallel dispatch never
//!   changes them either. Guarded by `rogue-thread`: every thread is
//!   created inside [`util::exec`] or listed in the spawn registry
//!   ([`analysis::rules::SPAWN_REGISTRY`]) with a reason; stray threads
//!   can't introduce unordered reductions.
//! * **patch ≡ rebuild** and **shard ≡ serial** — incremental and
//!   sharded grid builds reproduce the from-scratch bytes. Guarded by
//!   `nondet-iteration`: no storage-order iteration of
//!   `HashMap`/`FxHashMap` where order can reach FP accumulation, the
//!   wire, or display — order-sensitive walks go through the sorted
//!   adapters in [`util::det`].
//! * **epoch ≡ serial** — multi-producer epoch'd ingest publishes
//!   exactly the bytes a serial single-stream ingest of the same
//!   logical delta sequence would (spilled or unspilled); pinned by
//!   `tests/property_ingest.rs` across producer × shard shapes.
//!   Guarded by `unbounded-channel`: every `channel()` /
//!   `sync_channel(0)` queue outside the registered-queue list
//!   ([`analysis::rules::QUEUE_REGISTRY`]) is a diagnostic, so ingest
//!   paths can't silently trade the bounded-backpressure contract for
//!   unbounded growth.
//! * **`apply(diff(a,b)) ≡ b`** — the serving delta wire format
//!   reconstructs models bit-exactly, and the rpc snapshot plane ships
//!   those bytes verbatim (replicas refuse snapshots that fail the
//!   byte check). Guarded by `unchecked-cast-in-wire` (no bare `as`
//!   casts in `rkmeans/model.rs` / `serve/delta.rs` /
//!   `serve/rpc/wire.rs`; counts round-trip through checked
//!   conversions that refuse silent truncation past 2^53) and by the
//!   byte-stability tests in `tests/property_wire.rs`.
//! * **Deterministic paths never read the clock** — guarded by
//!   `wall-clock-in-core`: `Instant::now`/`SystemTime` live only in
//!   [`metrics`], [`bench_harness`], [`serve::load`], and the blessed
//!   telemetry clock [`util::timer::now`].
//! * **Lock/channel failures carry context** — guarded by
//!   `contextless-unwrap` in the serving tier and executor; replica
//!   reads degrade through lock poisoning instead of panicking.
//!
//! A legitimate exception is annotated in place:
//!
//! ```text
//! // rklint::allow(nondet-iteration, reason = "ring-ℤ exact merge; order-free")
//! ```
//!
//! The reason string is mandatory — a reasonless or unknown-rule waiver
//! is itself a diagnostic. Run the pass with `cargo run --bin rklint`
//! (add `--report out.json` for the machine-readable form CI archives);
//! `tests/lint_gate.rs` keeps the tree clean in tier-1.
//!
//! ## Quickstart
//!
//! Stage the pipeline once, then sweep k over the shared coreset and ship
//! the winning model:
//!
//! ```no_run
//! use rkmeans::{ClusterOpts, RkModel, RkPipeline, SubspaceOpts};
//! use rkmeans::synthetic::{retailer, Scale};
//!
//! let db = retailer::generate(Scale::tiny(), 42);
//! let feq = retailer::feq();
//!
//! let pipe = RkPipeline::plan(&db, &feq).unwrap();
//! let marginals = pipe.marginals().unwrap();       // Step 1 — paid once
//! let subspaces = pipe.subspaces(&marginals, &SubspaceOpts::new(10)).unwrap();
//! let coreset = pipe.coreset(&subspaces).unwrap(); // Step 3 — paid once
//! for model in coreset.sweep(&[5, 10, 20], &ClusterOpts::new(0)) {
//!     println!("k={}: objective={:.4e} |G|={}",
//!              model.k(), model.objective_grid, model.grid_points);
//! }
//!
//! // Serving: serialize, restore anywhere, assign without the database.
//! let model = coreset.cluster(&ClusterOpts::new(10));
//! let replica = RkModel::from_bytes(&model.to_bytes()).unwrap();
//! assert_eq!(replica.k(), 10);
//! ```
//!
//! The monolithic [`rkmeans()`](rkmeans::rkmeans) free function remains
//! as a one-shot convenience (bitwise-identical to the staged path):
//!
//! ```no_run
//! use rkmeans::synthetic::{retailer, Scale};
//! use rkmeans::rkmeans::{rkmeans, RkConfig};
//!
//! let db = retailer::generate(Scale::tiny(), 42);
//! let res = rkmeans(&db, &retailer::feq(), &RkConfig::new(5)).unwrap();
//! println!("objective={} grid={} in {:?}",
//!          res.objective_grid, res.grid_points, res.timings.total());
//! ```

pub mod analysis;
pub mod bench_harness;
pub mod cluster;
pub mod coordinator;
pub mod coreset;
pub mod data;
pub mod faq;
pub mod incremental;
pub mod ingest;
pub mod join;
pub mod metrics;
pub mod query;
pub mod rkmeans;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod serve;
pub mod synthetic;
pub mod util;

pub use rkmeans::{
    rkmeans, ClusterOpts, Coreset, Marginals, ModelParseError, RkConfig, RkModel, RkPipeline,
    RkResult, SubspaceOpts, SubspaceSet,
};
