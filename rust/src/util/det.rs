//! Deterministic iteration adapters — the blessed way to walk a hash
//! map (`HashMap`, [`FxHashMap`](crate::util::fx::FxHashMap)) when the
//! visit order can reach floating-point accumulation, wire encoding, or
//! display.
//!
//! Hash-map storage order is an artifact of insertion history and
//! capacity, so two logically equal maps built along different paths
//! (patch vs rebuild, shard-merge vs serial) can disagree on it. Any
//! order-sensitive consumer must therefore sort first; these adapters
//! make that one call instead of a pattern to re-derive at every site.
//! The `nondet-iteration` rklint rule (see [`crate::analysis`]) flags
//! raw iteration and points here.
//!
//! All adapters are generic over the map's `BuildHasher`, so they take
//! std and Fx maps alike, and they sort by `Ord` on the key — the same
//! total order `BTreeMap` would give.

use std::collections::{HashMap, HashSet};
use std::hash::BuildHasher;

/// Keys of `m`, sorted ascending. Clones keys; prefer
/// [`sorted_entries`] when the values are needed too.
pub fn sorted_keys<K: Ord + Clone, V, S: BuildHasher>(m: &HashMap<K, V, S>) -> Vec<K> {
    // rklint::allow(nondet-iteration, reason = "adapter interior: sorted before exposure")
    let mut keys: Vec<K> = m.keys().cloned().collect();
    keys.sort_unstable();
    keys
}

/// Borrowed `(key, value)` pairs of `m`, sorted by key ascending.
pub fn sorted_entries<K: Ord, V, S: BuildHasher>(m: &HashMap<K, V, S>) -> Vec<(&K, &V)> {
    let mut entries: Vec<(&K, &V)> = m.iter().collect();
    entries.sort_unstable_by(|a, b| a.0.cmp(b.0));
    entries
}

/// Consume `m` into owned `(key, value)` pairs, sorted by key.
pub fn sorted_owned<K: Ord, V, S: BuildHasher>(m: HashMap<K, V, S>) -> Vec<(K, V)> {
    let mut entries: Vec<(K, V)> = m.into_iter().collect();
    entries.sort_unstable_by(|a, b| a.0.cmp(&b.0));
    entries
}

/// Members of `s`, sorted ascending.
pub fn sorted_members<T: Ord, S: BuildHasher>(s: &HashSet<T, S>) -> Vec<&T> {
    let mut members: Vec<&T> = s.iter().collect();
    members.sort_unstable();
    members
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::fx::{FxHashMap, FxHashSet};

    #[test]
    fn adapters_sort_fx_and_std_maps() {
        let mut fx = FxHashMap::<u64, f64>::default();
        let mut std = HashMap::<u64, f64>::new();
        // Different insertion orders must not matter.
        for &k in &[9u64, 1, 5, 3, 7] {
            fx.insert(k, k as f64);
        }
        for &k in &[3u64, 7, 9, 5, 1] {
            std.insert(k, k as f64);
        }
        assert_eq!(sorted_keys(&fx), vec![1, 3, 5, 7, 9]);
        assert_eq!(sorted_keys(&fx), sorted_keys(&std));
        let e = sorted_entries(&fx);
        assert_eq!(e.first(), Some(&(&1u64, &1.0)));
        assert_eq!(e.last(), Some(&(&9u64, &9.0)));
        assert_eq!(sorted_owned(std).iter().map(|(k, _)| *k).collect::<Vec<_>>(), vec![
            1, 3, 5, 7, 9
        ]);
    }

    #[test]
    fn set_members_sorted() {
        let mut s = FxHashSet::<i32>::default();
        for v in [4, -2, 0, 11] {
            s.insert(v);
        }
        assert_eq!(sorted_members(&s), vec![&-2, &0, &4, &11]);
    }
}
