//! The end-to-end Rk-means pipeline (paper Algorithm 1 + §4.3) and the
//! materialize-then-cluster baseline it is benchmarked against.
//!
//! The primary API is the **staged pipeline** ([`pipeline`]): each of the
//! paper's four steps returns an owned, inspectable artifact
//! ([`Marginals`] → [`SubspaceSet`] → [`Coreset`] → [`RkModel`]) that
//! later stages borrow, so callers reuse a join tree + marginals across κ
//! choices and a single coreset across a whole k-sweep
//! ([`Coreset::sweep`]). [`RkModel`] ([`model`]) caps the pipeline as a
//! self-contained, serializable serving handle.
//!
//! ```no_run
//! use rkmeans::rkmeans::{ClusterOpts, RkPipeline, SubspaceOpts};
//! use rkmeans::synthetic::{retailer, Scale};
//! let db = retailer::generate(Scale::tiny(), 1);
//! let feq = retailer::feq();
//! let pipe = RkPipeline::plan(&db, &feq).unwrap();
//! let marginals = pipe.marginals().unwrap();
//! let subspaces = pipe.subspaces(&marginals, &SubspaceOpts::new(10)).unwrap();
//! let coreset = pipe.coreset(&subspaces).unwrap();
//! let model = coreset.cluster(&ClusterOpts::new(10));
//! ```
//!
//! The monolithic [`rkmeans`] / [`rkmeans_with_tree`] free functions
//! remain as thin one-shot convenience shims over the staged path
//! (bitwise-identical output); prefer the staged API for anything that
//! runs more than once.
//!
//! Steps (all without materializing the join):
//! 1. marginal weights `w_j` per feature — Yannakakis two-pass FAQ;
//! 2. optimal κ-clustering per subspace (`α = 1` solvers);
//! 3. sparse non-zero-weight grid coreset + `w_grid` — free-variable FAQ;
//! 4. weighted k-means over the coreset — factored Lloyd (native) or the
//!    dense XLA/PJRT artifact path (`crate::runtime`, `pjrt` feature).

pub mod baseline;
pub mod model;
pub mod pipeline;

pub use baseline::{materialize_and_cluster, materialize_and_cluster_capped, BaselineResult};
pub use model::{ModelParseError, RkModel, RKMODEL_FORMAT_VERSION};
pub use pipeline::{
    ClusterOpts, Coreset, Marginals, RkPipeline, SubspaceOpts, SubspaceSet, SweepMode,
};

use crate::cluster::sparse_lloyd::CentroidCoord;
use crate::cluster::{BoundsPolicy, ExecutorKind, Precision, PruneStats};
use crate::coreset::{centroids_dense, eval_full_objective_with, SubspaceModel};
use crate::data::Database;
use crate::join::EmbedSpec;
use crate::query::{Feq, Hypergraph, JoinTree};
use anyhow::Result;
use std::time::Duration;

/// Rk-means configuration.
#[derive(Clone, Debug)]
pub struct RkConfig {
    /// Final number of clusters k.
    pub k: usize,
    /// Per-subspace centroids κ (Step 2). `0` means κ = k. Setting κ < k
    /// trades approximation for a smaller grid (paper Table 2, right).
    pub kappa: usize,
    /// Lloyd iteration cap for Step 4.
    pub max_iters: usize,
    /// Relative-improvement stopping tolerance for Step 4.
    pub tol: f64,
    /// Seed for k-means++ and any sampling.
    pub seed: u64,
    /// Atom-penalty ρ for regularized Rk-means (paper §3): each subspace
    /// adaptively chooses κ_j ≤ κ minimizing `λ_j·cost + ρ·κ_j`. 0 = off.
    pub regularization: f64,
    /// Step-4 bounds policy ([`BoundsPolicy::Auto`] resolves against k;
    /// never changes results, only assignment throughput).
    pub bounds: BoundsPolicy,
    /// Step-4 distance-kernel precision (f32 trades bitwise f64
    /// reproducibility for ~2× kernel throughput; see
    /// [`crate::cluster::F32_OBJ_RTOL`]).
    pub precision: Precision,
    /// Step-4 worker threads (`0` = auto). On the pool executor this
    /// clamps the active workers per dispatch without resizing the
    /// process-wide pool.
    pub threads: usize,
    /// Step-4 parallel-dispatch executor kind (persistent shared pool by
    /// default; the scoped reference spawns workers per dispatch). Never
    /// changes results, only dispatch overhead.
    pub executor: ExecutorKind,
}

impl RkConfig {
    /// Paper-default configuration: κ = k, k-means++ seeding, tolerant stop.
    pub fn new(k: usize) -> Self {
        RkConfig {
            k,
            kappa: 0,
            max_iters: 50,
            tol: 1e-6,
            seed: 0xC0FFEE,
            regularization: 0.0,
            bounds: BoundsPolicy::Auto,
            precision: Precision::F64,
            threads: 0,
            executor: ExecutorKind::Pool,
        }
    }

    /// Set κ < k (speed/approximation tradeoff).
    pub fn with_kappa(mut self, kappa: usize) -> Self {
        self.kappa = kappa;
        self
    }

    /// Enable the §3 regularizer with atom penalty ρ.
    pub fn with_regularization(mut self, rho: f64) -> Self {
        self.regularization = rho;
        self
    }

    /// Override the seeding RNG seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Override the Step-4 Lloyd iteration cap.
    pub fn with_max_iters(mut self, max_iters: usize) -> Self {
        self.max_iters = max_iters;
        self
    }

    /// Override the Step-4 stopping tolerance.
    pub fn with_tol(mut self, tol: f64) -> Self {
        self.tol = tol;
        self
    }

    /// Override the Step-4 bounds policy.
    pub fn with_bounds(mut self, bounds: BoundsPolicy) -> Self {
        self.bounds = bounds;
        self
    }

    /// Override the Step-4 distance-kernel precision.
    pub fn with_precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }

    /// Override the Step-4 worker-thread clamp (`0` = auto).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Override the Step-4 executor kind (pool vs. scoped reference).
    pub fn with_executor(mut self, executor: ExecutorKind) -> Self {
        self.executor = executor;
        self
    }

    /// Effective κ.
    pub fn effective_kappa(&self) -> usize {
        if self.kappa == 0 {
            self.k
        } else {
            self.kappa
        }
    }
}

/// Wall-clock breakdown over the four steps (paper Figure 3).
#[derive(Clone, Debug, Default)]
pub struct StepTimings {
    pub step1_marginals: Duration,
    pub step2_subspaces: Duration,
    pub step3_grid: Duration,
    pub step4_cluster: Duration,
}

impl StepTimings {
    /// End-to-end time.
    pub fn total(&self) -> Duration {
        self.step1_marginals + self.step2_subspaces + self.step3_grid + self.step4_cluster
    }
}

/// Result of an Rk-means run.
#[derive(Clone, Debug)]
pub struct RkResult {
    /// Factored centroids (k × m); expand with
    /// [`crate::coreset::centroids_dense`].
    pub centroids: Vec<Vec<CentroidCoord>>,
    /// Per-subspace Step-2 models (geometry + assigners).
    pub models: Vec<SubspaceModel>,
    /// Weighted k-means objective on the coreset (`W₂²(P, Q)`).
    pub objective_grid: f64,
    /// Coreset quantization error Σ_j Step-2 cost (`W₂²(Q, P_in)`, Eq. 9).
    pub quantization_cost: f64,
    /// Number of non-zero grid cells `|G|`.
    pub grid_points: usize,
    /// Total grid mass = weighted `|X|`.
    pub grid_mass: f64,
    /// Step-4 Lloyd iterations.
    pub iters: usize,
    /// Per-step wall-clock (Figure 3).
    pub timings: StepTimings,
    /// Step-4 engine statistics: distance evaluations performed vs.
    /// skipped by the Hamerly bounds, and assignment throughput.
    pub step4_stats: PruneStats,
}

impl RkResult {
    /// Upper bound on the full-data objective without touching `X`:
    /// `L(X, C) ≤ (√quant + √grid)²` by the triangle inequality on W₂.
    pub fn objective_upper_bound(&self) -> f64 {
        let a = self.quantization_cost.max(0.0).sqrt();
        let b = self.objective_grid.max(0.0).sqrt();
        (a + b) * (a + b)
    }
}

/// One-shot convenience: run all four stages of Rk-means on a database +
/// FEQ. Cyclic FEQs are rewritten first (relation merging, see
/// [`crate::join::ensure_acyclic`]).
///
/// Deprecated in favor of the staged [`RkPipeline`]: this shim recomputes
/// Steps 1–3 on every call, so a k- or κ-sweep pays the FAQ passes
/// repeatedly. Output is bitwise-identical to the staged path with the
/// same configuration.
pub fn rkmeans(db: &Database, feq: &Feq, cfg: &RkConfig) -> Result<RkResult> {
    Ok(RkPipeline::plan(db, feq)?.run(cfg)?.into_result())
}

/// One-shot convenience with a pre-built join tree (lets callers reuse
/// the tree across calls). Deprecated in favor of
/// [`RkPipeline::with_tree`]; see [`rkmeans`]. Output is
/// bitwise-identical to the staged path with the same configuration.
pub fn rkmeans_with_tree(
    db: &Database,
    feq: &Feq,
    tree: &JoinTree,
    cfg: &RkConfig,
) -> Result<RkResult> {
    Ok(RkPipeline::with_tree(db, feq, tree).run(cfg)?.into_result())
}

/// Evaluate an Rk-means result on the full (unmaterialized) join output —
/// the "Relative Approx." numerator in the paper's Table 2. Scores with
/// the f64 kernel; see [`full_objective_with`] for the f32 streaming
/// scorer.
pub fn full_objective(db: &Database, feq: &Feq, res: &RkResult) -> Result<f64> {
    full_objective_with(db, feq, res, Precision::F64)
}

/// [`full_objective`] with an explicit streaming-scorer precision:
/// [`Precision::F32`] routes the full-`X` pass through the f32 tile
/// kernel (double the SIMD lanes) under the engine's
/// [`crate::cluster::F32_OBJ_RTOL`] tolerance contract.
pub fn full_objective_with(
    db: &Database,
    feq: &Feq,
    res: &RkResult,
    precision: Precision,
) -> Result<f64> {
    let tree = Hypergraph::from_feq(db, feq).join_tree()?;
    let spec = EmbedSpec::from_feq(db, feq)?;
    let cents = centroids_dense(&res.centroids, &res.models, &spec);
    eval_full_objective_with(db, feq, &tree, &spec, &cents, precision)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::LloydConfig;
    use crate::data::{Attr, Relation, Schema, Value};
    use crate::util::testkit::assert_close;
    use crate::util::SplitMix64;

    /// Small 2-relation star with clusterable structure.
    fn setup(n_fact: usize, seed: u64) -> (Database, Feq) {
        let mut rng = SplitMix64::new(seed);
        let mut fact = Relation::new(
            "fact",
            Schema::new(vec![Attr::cat("item", 8), Attr::double("units")]),
        );
        for _ in 0..n_fact {
            let item = rng.below(8) as u32;
            // Two unit regimes -> clear cluster structure.
            let units =
                if item < 4 { rng.uniform(0.0, 1.0) } else { rng.uniform(100.0, 101.0) };
            fact.push_row(&[Value::Cat(item), Value::Double(units)]);
        }
        let mut items =
            Relation::new("items", Schema::new(vec![Attr::cat("item", 8), Attr::double("price")]));
        for i in 0..8u32 {
            items.push_row(&[Value::Cat(i), Value::Double(if i < 4 { 1.0 } else { 50.0 })]);
        }
        let mut db = Database::new();
        db.add(fact);
        db.add(items);
        let feq = Feq::with_features(&["fact", "items"], &["item", "units", "price"]);
        (db, feq)
    }

    #[test]
    fn pipeline_runs_and_is_deterministic() {
        let (db, feq) = setup(200, 1);
        let cfg = RkConfig::new(4);
        let a = rkmeans(&db, &feq, &cfg).unwrap();
        let b = rkmeans(&db, &feq, &cfg).unwrap();
        assert_eq!(a.grid_points, b.grid_points);
        assert_close(a.objective_grid, b.objective_grid, 1e-12);
        assert_close(a.grid_mass, 200.0, 1e-9);
        assert!(a.grid_points <= 200);
        assert!(a.timings.total().as_nanos() > 0);
    }

    #[test]
    fn finds_the_two_regimes() {
        let (db, feq) = setup(300, 2);
        let res = rkmeans(&db, &feq, &RkConfig::new(2)).unwrap();
        // The units gap (0..1 vs 100..101) dominates: the full-X objective
        // of k=2 must be far below k=1 (note: with κ=k=1 the coreset
        // collapses to one cell, so compare on the full data, not the grid).
        let single = rkmeans(&db, &feq, &RkConfig::new(1)).unwrap();
        let full2 = full_objective(&db, &feq, &res).unwrap();
        let full1 = full_objective(&db, &feq, &single).unwrap();
        assert!(full2 < 0.05 * full1, "k=2 {full2} vs k=1 {full1}");
    }

    #[test]
    fn kappa_lt_k_shrinks_grid() {
        let (db, feq) = setup(400, 3);
        let full = rkmeans(&db, &feq, &RkConfig::new(6)).unwrap();
        let small = rkmeans(&db, &feq, &RkConfig::new(6).with_kappa(2)).unwrap();
        assert!(small.grid_points <= full.grid_points);
        // Quantization cost can only grow with smaller κ.
        assert!(small.quantization_cost >= full.quantization_cost - 1e-9);
    }

    #[test]
    fn full_objective_close_to_upper_bound() {
        let (db, feq) = setup(250, 4);
        let res = rkmeans(&db, &feq, &RkConfig::new(3)).unwrap();
        let full = full_objective(&db, &feq, &res).unwrap();
        assert!(
            full <= res.objective_upper_bound() + 1e-6,
            "full {} > bound {}",
            full,
            res.objective_upper_bound()
        );
    }

    #[test]
    fn approximation_vs_exhaustive_baseline() {
        // Rk-means objective on the full data vs dense Lloyd on the
        // materialized X: the paper's relative-approximation measurement.
        let (db, feq) = setup(150, 5);
        let res = rkmeans(&db, &feq, &RkConfig::new(3)).unwrap();
        let full = full_objective(&db, &feq, &res).unwrap();
        let base = materialize_and_cluster(&db, &feq, &LloydConfig::new(3)).unwrap();
        let ratio = full / base.objective.max(1e-12);
        // Theorem 3.4 gives 9; in practice this should be near 1.
        assert!(ratio < 9.0, "approximation ratio {ratio}");
    }

    #[test]
    fn regularization_shrinks_grid_gracefully() {
        let (db, feq) = setup(300, 7);
        let plain = rkmeans(&db, &feq, &RkConfig::new(5)).unwrap();
        let reg = rkmeans(&db, &feq, &RkConfig::new(5).with_regularization(50.0)).unwrap();
        // Atom penalty can only reduce per-subspace κ and hence the grid.
        assert!(reg.grid_points <= plain.grid_points);
        for (m_reg, m_plain) in reg.models.iter().zip(&plain.models) {
            assert!(m_reg.n_gids() <= m_plain.n_gids(), "subspace {}", m_reg.name);
        }
        // Quantization cost can only grow; ρ=0 must match exactly.
        assert!(reg.quantization_cost >= plain.quantization_cost - 1e-9);
        let rho0 = rkmeans(&db, &feq, &RkConfig::new(5).with_regularization(0.0)).unwrap();
        assert_eq!(rho0.grid_points, plain.grid_points);
        assert_close(rho0.objective_grid, plain.objective_grid, 1e-12);
    }

    #[test]
    fn empty_join_is_an_error() {
        let (mut db, feq) = setup(50, 6);
        *db.get_mut("items").unwrap() =
            Relation::new("items", Schema::new(vec![Attr::cat("item", 8), Attr::double("price")]));
        assert!(rkmeans(&db, &feq, &RkConfig::new(2)).is_err());
    }
}
