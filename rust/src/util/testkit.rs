//! A tiny property-testing harness (the offline environment has no
//! `proptest`). `for_cases` runs a seeded generator/checker loop and reports
//! the first failing seed so failures are reproducible one-liners.
//!
//! Usage:
//! ```no_run
//! use rkmeans::util::testkit::for_cases;
//! for_cases(64, |rng| {
//!     let n = 1 + rng.below(100) as usize;
//!     assert!(n >= 1);
//! });
//! ```

use super::rng::SplitMix64;

/// Base seed; combined with the case index so each case is independent but
/// the whole run is deterministic.
pub const BASE_SEED: u64 = 0x5eed_cafe_f00d_0001;

/// Run `cases` independent property checks. Each check receives its own
/// seeded RNG. Panics (re-raising the inner panic) with the failing case id.
pub fn for_cases(cases: u64, check: impl Fn(&mut SplitMix64) + std::panic::RefUnwindSafe) {
    for case in 0..cases {
        let seed = BASE_SEED ^ case.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        let result = std::panic::catch_unwind(|| {
            let mut rng = SplitMix64::new(seed);
            check(&mut rng);
        });
        if let Err(payload) = result {
            eprintln!("testkit: property failed at case {case} (seed {seed:#x})");
            std::panic::resume_unwind(payload);
        }
    }
}

/// Assert two floats are close in absolute-or-relative terms.
#[track_caller]
pub fn assert_close(a: f64, b: f64, tol: f64) {
    let scale = a.abs().max(b.abs()).max(1.0);
    assert!(
        (a - b).abs() <= tol * scale,
        "assert_close failed: {a} vs {b} (tol {tol}, scaled {})",
        tol * scale
    );
}

/// Assert two [`RkResult`](crate::rkmeans::RkResult)s are
/// bitwise-identical in everything but wall clock — the
/// staged-pipeline-vs-one-shot exactness contract shared by the
/// `rkmeans::pipeline` unit tests and the integration suite.
pub fn assert_bitwise_result(
    a: &crate::rkmeans::RkResult,
    b: &crate::rkmeans::RkResult,
    label: &str,
) {
    use crate::cluster::CentroidCoord;
    assert_eq!(a.grid_points, b.grid_points, "{label}: grid_points");
    assert_eq!(a.iters, b.iters, "{label}: iters");
    assert_eq!(
        a.objective_grid.to_bits(),
        b.objective_grid.to_bits(),
        "{label}: objective_grid"
    );
    assert_eq!(
        a.quantization_cost.to_bits(),
        b.quantization_cost.to_bits(),
        "{label}: quantization_cost"
    );
    assert_eq!(a.grid_mass.to_bits(), b.grid_mass.to_bits(), "{label}: grid_mass");
    assert_eq!(a.centroids.len(), b.centroids.len(), "{label}: k");
    for (ca, cb) in a.centroids.iter().zip(&b.centroids) {
        for (xa, xb) in ca.iter().zip(cb) {
            match (xa, xb) {
                (CentroidCoord::Continuous(u), CentroidCoord::Continuous(v)) => {
                    assert_eq!(u.to_bits(), v.to_bits(), "{label}: centroid coord")
                }
                (CentroidCoord::Categorical(u), CentroidCoord::Categorical(v)) => {
                    assert_eq!(u.len(), v.len(), "{label}: β length");
                    for (p, q) in u.iter().zip(v) {
                        assert_eq!(p.to_bits(), q.to_bits(), "{label}: β entry");
                    }
                }
                _ => panic!("{label}: centroid coordinate kinds diverged"),
            }
        }
    }
}

/// Assert two float slices are element-wise close.
#[track_caller]
pub fn assert_all_close(a: &[f64], b: &[f64], tol: f64) {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let scale = x.abs().max(y.abs()).max(1.0);
        assert!(
            (x - y).abs() <= tol * scale,
            "assert_all_close failed at index {i}: {x} vs {y}"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cases_are_deterministic() {
        let mut firsts = Vec::new();
        for _ in 0..2 {
            let collected = std::sync::Mutex::new(Vec::new());
            for_cases(4, |rng| {
                collected.lock().unwrap().push(rng.next_u64());
            });
            firsts.push(collected.into_inner().unwrap());
        }
        assert_eq!(firsts[0], firsts[1]);
    }

    #[test]
    #[should_panic]
    fn failing_property_panics() {
        for_cases(8, |rng| {
            assert!(rng.next_f64() < 0.5, "intentional failure");
        });
    }

    #[test]
    fn close_helpers() {
        assert_close(1.0, 1.0 + 1e-12, 1e-9);
        assert_all_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-12], 1e-9);
    }
}
