//! Dense weighted Lloyd on row-major points through the shared engine:
//! k-means++ seeding (or a warm start from caller-provided centroids),
//! the tiled microkernel for full scans (f64 or the f32 tile path),
//! bounds pruning under the selected policy (Hamerly or Elkan) to skip
//! unchanged assignments, and chunk-parallel accumulation. The bounds
//! test, ordered accumulation, reseed picker and convergence test live in
//! the shared [`core`](super::core) helpers; see the parent module docs
//! for the bounds invariants, the precision tolerance contract and the
//! determinism contract.

use super::core::{
    accumulate_pass, bounds_filter, converged, fold_chunk_stats, half_min_separation,
    record_scan, reseed_target, BoundsCtx, ChunkState, ChunkStats,
};
use super::microkernel::{self, TILE};
use super::{
    resolve_threads, BoundsPolicy, EngineOpts, EngineState, Precision, PruneStats, CHUNK,
    SLACK_REL, SLACK_REL_F32,
};
use crate::cluster::kmeanspp::kmeanspp_indices;
use crate::cluster::lloyd::{LloydConfig, LloydResult};
use crate::util::SplitMix64;

/// One chunk's view of the per-point state (disjoint mutable slices) plus
/// its accumulators, reduced in chunk order after each pass. The `*32`
/// slices are empty on the f64 path.
struct DenseChunk<'a> {
    pts: &'a [f64],
    pts32: &'a [f32],
    xnorm: &'a [f64],
    xnorm32: &'a [f32],
    st: ChunkState<'a>,
    sums: Vec<f64>,
    mass: Vec<f64>,
    obj: f64,
    stats: ChunkStats,
}

/// Read-only per-iteration context shared by all chunks. Exactly one of
/// the (`ct_t`, `cnorm`) / (`ct_t32`, `cnorm32`) pairs is populated,
/// matching `precision`.
struct PassCtx<'a> {
    d: usize,
    k: usize,
    ct_t: &'a [f64],
    cnorm: &'a [f64],
    ct_t32: &'a [f32],
    cnorm32: &'a [f32],
    precision: Precision,
    bounds: BoundsPolicy,
    drift: &'a [f64],
    drift_max: f64,
    s_half: &'a [f64],
    slack: f64,
    use_bounds: bool,
    pruning: bool,
}

/// One assignment + accumulation pass over a chunk.
fn assign_chunk(ch: &mut DenseChunk, ctx: &PassCtx) {
    let (d, k) = (ctx.d, ctx.k);
    let pts = ch.pts;

    let bctx = BoundsCtx {
        k,
        bounds: ctx.bounds,
        drift_max: ctx.drift_max,
        drift: ctx.drift,
        s_half: ctx.s_half,
        slack: ctx.slack,
        use_bounds: ctx.use_bounds,
        pruning: ctx.pruning,
    };

    match ctx.precision {
        Precision::F64 => {
            let xnorm = ch.xnorm;
            // Phase 1: bounds test (shared). The closure computes the
            // exact assigned distance with the same expansion a full scan
            // uses.
            let scan = bounds_filter(&mut ch.st, &bctx, &mut ch.stats, |i, a| {
                let x = &pts[i * d..(i + 1) * d];
                let dot = microkernel::dot_one(x, ctx.ct_t, k, a);
                let dd = xnorm[i] - 2.0 * dot + ctx.cnorm[a];
                dd.max(0.0)
            });

            // Phase 2: full scans, tiled through the microkernel.
            let mut tile = vec![0.0f64; TILE * d];
            let mut dots = vec![0.0f64; TILE * k];
            for group in scan.chunks(TILE) {
                let tp = group.len();
                for (p, &gi) in group.iter().enumerate() {
                    let i = gi as usize;
                    tile[p * d..(p + 1) * d].copy_from_slice(&pts[i * d..(i + 1) * d]);
                }
                microkernel::tile_dots(&tile[..tp * d], d, k, ctx.ct_t, &mut dots);
                for (p, &gi) in group.iter().enumerate() {
                    let i = gi as usize;
                    let drow = &dots[p * k..(p + 1) * k];
                    let (d1, c1, d2) = microkernel::best_two_expanded(xnorm[i], drow, ctx.cnorm);
                    let xn = xnorm[i];
                    record_scan(
                        &mut ch.st,
                        &mut ch.stats,
                        i,
                        c1,
                        d1.max(0.0),
                        d2.max(0.0),
                        &bctx,
                        |c| xn - 2.0 * drow[c] + ctx.cnorm[c],
                    );
                }
            }
        }
        Precision::F32 => {
            let pts32 = ch.pts32;
            let xnorm32 = ch.xnorm32;
            // Phase 1: same test through the f32 kernel — bitwise
            // consistent with the f32 scan below (microkernel contract).
            let scan = bounds_filter(&mut ch.st, &bctx, &mut ch.stats, |i, a| {
                let x = &pts32[i * d..(i + 1) * d];
                let dot = microkernel::dot_one_f32(x, ctx.ct_t32, k, a);
                let dd = xnorm32[i] - 2.0 * dot + ctx.cnorm32[a];
                dd.max(0.0) as f64
            });

            // Phase 2: full scans through the f32 tile kernel. Distances
            // widen to f64 only after the f32 clamp, so skipped and
            // scanned points stay on one arithmetic footing.
            let mut tile = vec![0.0f32; TILE * d];
            let mut dots = vec![0.0f32; TILE * k];
            for group in scan.chunks(TILE) {
                let tp = group.len();
                for (p, &gi) in group.iter().enumerate() {
                    let i = gi as usize;
                    tile[p * d..(p + 1) * d].copy_from_slice(&pts32[i * d..(i + 1) * d]);
                }
                microkernel::tile_dots_f32(&tile[..tp * d], d, k, ctx.ct_t32, &mut dots);
                for (p, &gi) in group.iter().enumerate() {
                    let i = gi as usize;
                    let drow = &dots[p * k..(p + 1) * k];
                    let (d1, c1, d2) =
                        microkernel::best_two_expanded_f32(xnorm32[i], drow, ctx.cnorm32);
                    let xn = xnorm32[i];
                    record_scan(
                        &mut ch.st,
                        &mut ch.stats,
                        i,
                        c1,
                        d1.max(0.0) as f64,
                        d2.max(0.0) as f64,
                        &bctx,
                        |c| (xn - 2.0 * drow[c] + ctx.cnorm32[c]) as f64,
                    );
                }
            }
        }
    }

    // Phase 3: objective + update accumulation in point order (shared).
    // The centroid-update sums accumulate in f64 from the original
    // coordinates in both precisions (the f32 tolerance contract).
    let sums = &mut ch.sums;
    accumulate_pass(ch.st.w, ch.st.assign, ch.st.mind2, &mut ch.obj, &mut ch.mass, |i, c, w| {
        let x = &pts[i * d..(i + 1) * d];
        let s = &mut sums[c * d..(c + 1) * d];
        for (sv, &xv) in s.iter_mut().zip(x) {
            *sv += w * xv;
        }
    });
}

/// Weighted Lloyd over `n × d` row-major `points` with engine options.
/// Returns the result plus pruning/throughput statistics.
pub fn lloyd_dense(
    points: &[f64],
    weights: &[f64],
    d: usize,
    cfg: &LloydConfig,
    opts: &EngineOpts,
) -> (LloydResult, PruneStats) {
    lloyd_dense_init(points, weights, d, cfg, opts, None)
}

/// [`lloyd_dense`] with an optional warm start: when `init` holds exactly
/// `k × d` row-major coordinates they seed the run in place of k-means++
/// (the incremental planner feeds the previous version's centroids here).
/// A shape mismatch falls back to fresh seeding, so callers can pass a
/// stale warm start safely. `init = None` is bitwise-identical to
/// [`lloyd_dense`].
pub fn lloyd_dense_init(
    points: &[f64],
    weights: &[f64],
    d: usize,
    cfg: &LloydConfig,
    opts: &EngineOpts,
    init: Option<&[f64]>,
) -> (LloydResult, PruneStats) {
    let (res, stats, _) = lloyd_dense_resume(points, weights, d, cfg, opts, init, None);
    (res, stats)
}

/// [`lloyd_dense_init`] with cross-run state carry: always returns the
/// run's carryable [`EngineState`], and accepts the previous run's state
/// so iteration 0 reuses its assignments and bounds instead of a full
/// first scan (see the parent module's "Cross-run state carry" section
/// for the validity rules). A resumed run is **bitwise identical** to the
/// same warm start without `resume`.
///
/// Panics when `resume` is stale — captured against different centroids
/// than this run starts from (including the case where a shape-invalid
/// `init` silently fell back to fresh seeding), or a different point
/// count: silently proceeding would risk corrupting bounds, so staleness
/// is a loud caller bug. A bounds-policy or precision mismatch merely
/// degrades to the cold warm start.
pub fn lloyd_dense_resume(
    points: &[f64],
    weights: &[f64],
    d: usize,
    cfg: &LloydConfig,
    opts: &EngineOpts,
    init: Option<&[f64]>,
    resume: Option<&EngineState>,
) -> (LloydResult, PruneStats, EngineState) {
    assert!(d > 0, "dimension must be positive");
    assert_eq!(points.len() % d, 0, "points not a multiple of d");
    let n = points.len() / d;
    assert_eq!(weights.len(), n, "weights length mismatch");
    assert!(n > 0, "no points");
    // k-means++ always yields at least one seed, so treat k = 0 as 1.
    let k = cfg.k.min(n).max(1);
    let t0 = crate::util::timer::now();

    let row = |i: usize| &points[i * d..(i + 1) * d];
    let dist2 = |a: &[f64], b: &[f64]| -> f64 {
        let mut s = 0.0;
        for (x, y) in a.iter().zip(b) {
            let t = x - y;
            s += t * t;
        }
        s
    };

    // Seeding: warm start when shape-valid, else k-means++ (identical to
    // the pre-engine implementation).
    let mut centroids: Vec<f64> = match init {
        Some(c0) if c0.len() == k * d => c0.to_vec(),
        _ => {
            let mut rng = SplitMix64::new(cfg.seed);
            let seeds = kmeanspp_indices(n, weights, k, &mut rng, |i, j| dist2(row(i), row(j)));
            let mut c = Vec::with_capacity(k * d);
            for &s in &seeds {
                c.extend_from_slice(row(s));
            }
            c
        }
    };

    // Invariant per-point geometry.
    let xnorm: Vec<f64> = (0..n).map(|i| row(i).iter().map(|v| v * v).sum()).collect();
    let xn_max = xnorm.iter().cloned().fold(0.0f64, f64::max);
    // f32 path: cast the points once; per-point norms accumulate in f32
    // so Phase 1 and Phase 2 share one arithmetic footing.
    let f32_kernel = opts.precision == Precision::F32;
    let pts32: Vec<f32> =
        if f32_kernel { points.iter().map(|&v| v as f32).collect() } else { Vec::new() };
    let xnorm32: Vec<f32> = if f32_kernel {
        (0..n).map(|i| pts32[i * d..(i + 1) * d].iter().map(|v| v * v).sum()).collect()
    } else {
        Vec::new()
    };

    let bounds = opts.bounds.resolve(k);
    // Per-(point, centroid) lower-bound rows for Elkan, one global bound
    // per point otherwise.
    let lb_stride = if opts.pruning && bounds == BoundsPolicy::Elkan { k } else { 1 };
    let slack_rel = match opts.precision {
        Precision::F64 => SLACK_REL,
        Precision::F32 => SLACK_REL_F32,
    };

    let threads = resolve_threads(opts.threads);
    let mut assign = vec![0u32; n];
    let mut mind2 = vec![0.0f64; n];
    let mut lb = vec![0.0f64; n * lb_stride];
    let mut drift = vec![0.0f64; k];
    let mut s_half = vec![0.0f64; k];
    let mut bounds_valid = false;
    let mut max_dd = 0.0f64;

    // Cross-run state carry: a valid prior state seeds the assignments
    // and (already final-centroid-drifted) bounds, so iteration 0 runs
    // with `use_bounds = true` and zero drift instead of a full scan.
    if let Some(st) = resume {
        let start_hash = EngineState::hash_dense(&centroids);
        bounds_valid =
            st.resume_into(start_hash, k, opts, bounds, &mut assign, &mut lb, "points");
    }

    let mut ct_t: Vec<f64> = Vec::new();
    let mut ct_t32: Vec<f32> = Vec::new();
    let mut objective = f64::INFINITY;
    let mut iters = 0;
    let mut stats = PruneStats {
        points: n as u64,
        bounds: if opts.pruning { bounds.label() } else { "none" },
        precision: opts.precision.label(),
        executor: opts.executor.label(),
        ..PruneStats::default()
    };

    for it in 0..cfg.max_iters.max(1) {
        iters = it + 1;

        // Per-iteration centroid geometry, in the kernel's precision.
        let mut cnorm = vec![0.0f64; k];
        let mut cnorm32: Vec<f32> = Vec::new();
        if f32_kernel {
            microkernel::transpose_f32(&centroids, d, k, &mut ct_t32);
            cnorm32 = centroids
                .chunks_exact(d)
                .map(|cc| cc.iter().map(|&v| (v as f32) * (v as f32)).sum())
                .collect();
        } else {
            for (c, cc) in centroids.chunks_exact(d).enumerate() {
                cnorm[c] = cc.iter().map(|v| v * v).sum();
            }
            microkernel::transpose(&centroids, d, k, &mut ct_t);
        }
        let use_bounds = opts.pruning && bounds_valid;
        if use_bounds {
            half_min_separation(k, &mut s_half, |c, c2| {
                dist2(&centroids[c * d..(c + 1) * d], &centroids[c2 * d..(c2 + 1) * d])
            });
        }
        let drift_max = drift.iter().cloned().fold(0.0f64, f64::max);
        let slack = slack_rel * (1.0 + max_dd.sqrt() + xn_max.sqrt());
        let ctx = PassCtx {
            d,
            k,
            ct_t: &ct_t,
            cnorm: &cnorm,
            ct_t32: &ct_t32,
            cnorm32: &cnorm32,
            precision: opts.precision,
            bounds,
            drift: &drift,
            drift_max,
            s_half: &s_half,
            slack,
            use_bounds,
            pruning: opts.pruning,
        };

        // Chunked assignment pass (fixed CHUNK ranges; see module docs).
        let chunks_out: Vec<(Vec<f64>, Vec<f64>, f64, ChunkStats)> = {
            let mut chunks: Vec<DenseChunk> = Vec::with_capacity(n.div_ceil(CHUNK));
            let parts = assign
                .chunks_mut(CHUNK)
                .zip(mind2.chunks_mut(CHUNK))
                .zip(lb.chunks_mut(CHUNK * lb_stride));
            let mut start = 0usize;
            for ((a_s, m_s), l_s) in parts {
                let len = a_s.len();
                chunks.push(DenseChunk {
                    pts: &points[start * d..(start + len) * d],
                    pts32: if f32_kernel { &pts32[start * d..(start + len) * d] } else { &[] },
                    xnorm: &xnorm[start..start + len],
                    xnorm32: if f32_kernel { &xnorm32[start..start + len] } else { &[] },
                    st: ChunkState {
                        w: &weights[start..start + len],
                        assign: a_s,
                        mind2: m_s,
                        lb: l_s,
                    },
                    sums: vec![0.0; k * d],
                    mass: vec![0.0; k],
                    obj: 0.0,
                    stats: ChunkStats::default(),
                });
                start += len;
            }
            if opts.executor.run_chunks(&mut chunks, threads, |_, ch| assign_chunk(ch, &ctx)) {
                stats.pool_dispatches += 1;
            }
            chunks.into_iter().map(|c| (c.sums, c.mass, c.obj, c.stats)).collect()
        };

        // Fixed-order reduction of the chunk accumulators.
        let mut sums = vec![0.0f64; k * d];
        let mut mass = vec![0.0f64; k];
        let mut obj = 0.0f64;
        for (c_sums, c_mass, c_obj, c_stats) in &chunks_out {
            for (sv, &v) in sums.iter_mut().zip(c_sums) {
                *sv += v;
            }
            for (mv, &v) in mass.iter_mut().zip(c_mass) {
                *mv += v;
            }
            obj += c_obj;
            fold_chunk_stats(&mut stats, &mut max_dd, c_stats);
        }

        // Update step (+ drift for the next iteration's bounds).
        let mut reseeded = false;
        for c in 0..k {
            if mass[c] > 0.0 {
                let mut dr = 0.0;
                for j in 0..d {
                    let nv = sums[c * d + j] / mass[c];
                    let ov = centroids[c * d + j];
                    let t = nv - ov;
                    dr += t * t;
                    centroids[c * d + j] = nv;
                }
                drift[c] = dr.sqrt();
            } else {
                // Empty cluster: reseed at the point with the largest
                // weighted distance-to-centroid contribution.
                let far = reseed_target(weights, &mind2);
                centroids[c * d..(c + 1) * d].copy_from_slice(row(far));
                mind2[far] = 0.0;
                reseeded = true;
            }
        }
        // A reseed teleports a centroid arbitrarily far; rebuild bounds
        // from scratch next iteration instead of trying to drift them.
        bounds_valid = opts.pruning && !reseeded;

        // Convergence on relative objective improvement.
        if converged(objective, obj, cfg.tol) {
            objective = obj;
            break;
        }
        objective = obj;
    }

    stats.iters = iters;
    stats.wall = t0.elapsed();

    // Capture the carryable end-of-run state (shared helper pre-drifts
    // the bounds to the final centroids).
    let state = EngineState::capture(
        assign.clone(),
        lb,
        bounds,
        opts.precision,
        opts.pruning && bounds_valid,
        &drift,
        k,
        EngineState::hash_dense(&centroids),
    );
    (LloydResult { centroids, assign, objective, iters }, stats, state)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::for_cases;

    fn clustered(rng: &mut SplitMix64, n: usize, d: usize, spread: f64) -> (Vec<f64>, Vec<f64>) {
        // A few gaussian blobs: the regime where pruning actually bites.
        let n_blobs = 4;
        let centers: Vec<f64> = (0..n_blobs * d).map(|_| rng.uniform(-8.0, 8.0)).collect();
        let mut pts = Vec::with_capacity(n * d);
        for _ in 0..n {
            let b = rng.below(n_blobs as u64) as usize;
            for j in 0..d {
                pts.push(centers[b * d + j] + spread * rng.normal());
            }
        }
        let w = (0..n).map(|_| rng.uniform(0.1, 2.0)).collect();
        (pts, w)
    }

    #[test]
    fn pruned_skips_work_on_clustered_data() {
        let mut rng = SplitMix64::new(21);
        let (pts, w) = clustered(&mut rng, 3000, 6, 0.1);
        let cfg = LloydConfig { k: 8, max_iters: 12, tol: 0.0, seed: 5 };
        let (_, stats) = lloyd_dense(&pts, &w, 6, &cfg, &EngineOpts::pruned());
        assert!(
            stats.skip_rate() > 0.3,
            "expected meaningful pruning, got skip rate {:.3}",
            stats.skip_rate()
        );
        let (_, naive) = lloyd_dense(&pts, &w, 6, &cfg, &EngineOpts::naive_serial());
        assert_eq!(naive.dist_evals_skipped, 0);
        assert!(naive.dist_evals > stats.dist_evals);
    }

    #[test]
    fn pruned_parallel_matches_naive_bitwise() {
        for_cases(10, |rng| {
            let n = 50 + rng.below(400) as usize;
            let d = 1 + rng.below(5) as usize;
            let k = 1 + rng.below(7) as usize;
            let (pts, w) = clustered(rng, n, d, 0.3);
            let iters = 1 + rng.below(8) as usize;
            let cfg = LloydConfig { k, max_iters: iters, tol: 0.0, seed: rng.next_u64() };
            let (a, _) = lloyd_dense(&pts, &w, d, &cfg, &EngineOpts::naive_serial());
            let (b, _) = lloyd_dense(&pts, &w, d, &cfg, &EngineOpts::pruned().with_threads(3));
            assert_eq!(a.assign, b.assign);
            assert_eq!(a.centroids, b.centroids);
            assert_eq!(a.objective.to_bits(), b.objective.to_bits());
            assert_eq!(a.iters, b.iters);
        });
    }

    #[test]
    fn multi_chunk_is_thread_count_invariant() {
        // n > CHUNK exercises the chunked reduction; every thread count
        // must reduce to identical bits.
        let mut rng = SplitMix64::new(33);
        let n = CHUNK + 700;
        let (pts, w) = clustered(&mut rng, n, 3, 0.2);
        let cfg = LloydConfig { k: 6, max_iters: 5, tol: 0.0, seed: 7 };
        let (base, _) = lloyd_dense(&pts, &w, 3, &cfg, &EngineOpts::pruned().with_threads(1));
        for t in [2usize, 4, 8] {
            let (r, _) = lloyd_dense(&pts, &w, 3, &cfg, &EngineOpts::pruned().with_threads(t));
            assert_eq!(base.assign, r.assign, "threads={t}");
            assert_eq!(base.centroids, r.centroids, "threads={t}");
            assert_eq!(base.objective.to_bits(), r.objective.to_bits(), "threads={t}");
        }
    }

    #[test]
    fn warm_start_from_converged_centroids_converges_immediately() {
        let mut rng = SplitMix64::new(44);
        let (pts, w) = clustered(&mut rng, 500, 4, 0.2);
        let cold_cfg = LloydConfig { k: 4, max_iters: 40, tol: 0.0, seed: 11 };
        let (cold, _) = lloyd_dense(&pts, &w, 4, &cold_cfg, &EngineOpts::pruned());
        // Warm-starting from the converged centroids must not lose quality
        // and must stop after a couple of iterations under a loose tol.
        let warm_cfg = LloydConfig { tol: 1e-6, ..cold_cfg };
        let (warm, _) = lloyd_dense_init(
            &pts,
            &w,
            4,
            &warm_cfg,
            &EngineOpts::pruned(),
            Some(&cold.centroids),
        );
        assert!(warm.objective <= cold.objective * (1.0 + 1e-9));
        assert!(warm.iters <= 3, "warm start took {} iterations", warm.iters);
    }

    #[test]
    fn elkan_matches_naive_bitwise_and_prunes_more() {
        // Elkan is an alternative bounds policy, not an approximation:
        // identical bits, strictly better (or equal) skip counts on
        // stable blob workloads.
        let mut rng = SplitMix64::new(51);
        let (pts, w) = clustered(&mut rng, 4000, 5, 0.15);
        let cfg = LloydConfig { k: 12, max_iters: 10, tol: 0.0, seed: 17 };
        let (naive, _) = lloyd_dense(&pts, &w, 5, &cfg, &EngineOpts::naive_serial());
        let ham = EngineOpts::pruned().with_bounds(BoundsPolicy::Hamerly);
        let elk = EngineOpts::pruned().with_bounds(BoundsPolicy::Elkan).with_threads(3);
        let (rh, sh) = lloyd_dense(&pts, &w, 5, &cfg, &ham);
        let (re, se) = lloyd_dense(&pts, &w, 5, &cfg, &elk);
        for r in [&rh, &re] {
            assert_eq!(naive.assign, r.assign);
            assert_eq!(naive.centroids, r.centroids);
            assert_eq!(naive.objective.to_bits(), r.objective.to_bits());
        }
        assert_eq!(sh.bounds, "hamerly");
        assert_eq!(se.bounds, "elkan");
        assert!(
            se.dist_evals_skipped >= sh.dist_evals_skipped,
            "elkan skipped {} < hamerly {}",
            se.dist_evals_skipped,
            sh.dist_evals_skipped
        );
    }

    #[test]
    fn auto_policy_resolves_by_k() {
        let mut rng = SplitMix64::new(52);
        let (pts, w) = clustered(&mut rng, 300, 3, 0.3);
        let cfg = LloydConfig { k: 4, max_iters: 3, tol: 0.0, seed: 1 };
        let (_, s) = lloyd_dense(&pts, &w, 3, &cfg, &EngineOpts::pruned());
        assert_eq!(s.bounds, "hamerly");
        let cfg = LloydConfig { k: super::super::ELKAN_AUTO_K, max_iters: 2, tol: 0.0, seed: 1 };
        let (_, s) = lloyd_dense(&pts, &w, 3, &cfg, &EngineOpts::pruned());
        assert_eq!(s.bounds, "elkan");
        let (_, s) = lloyd_dense(&pts, &w, 3, &cfg, &EngineOpts::naive_serial());
        assert_eq!(s.bounds, "none");
    }

    #[test]
    fn f32_pruned_parallel_matches_f32_naive_bitwise() {
        // The determinism contract holds within the f32 precision, for
        // both bounds policies.
        for_cases(8, |rng| {
            let n = 50 + rng.below(300) as usize;
            let d = 1 + rng.below(5) as usize;
            let k = 1 + rng.below(7) as usize;
            let (pts, w) = clustered(rng, n, d, 0.3);
            let iters = 1 + rng.below(6) as usize;
            let cfg = LloydConfig { k, max_iters: iters, tol: 0.0, seed: rng.next_u64() };
            let naive32 = EngineOpts::naive_serial().with_precision(Precision::F32);
            let (a, sa) = lloyd_dense(&pts, &w, d, &cfg, &naive32);
            for bounds in [BoundsPolicy::Hamerly, BoundsPolicy::Elkan] {
                let opts = EngineOpts::pruned()
                    .with_precision(Precision::F32)
                    .with_bounds(bounds)
                    .with_threads(3);
                let (b, sb) = lloyd_dense(&pts, &w, d, &cfg, &opts);
                assert_eq!(a.assign, b.assign, "{bounds:?}");
                assert_eq!(a.centroids, b.centroids, "{bounds:?}");
                assert_eq!(a.objective.to_bits(), b.objective.to_bits(), "{bounds:?}");
                assert_eq!(sb.precision, "f32");
            }
            assert_eq!(sa.precision, "f32");
        });
    }

    #[test]
    fn f32_objective_within_tolerance_of_f64() {
        // k matches the blob count, so both precisions converge into the
        // same basin and differ only by kernel rounding.
        let mut rng = SplitMix64::new(53);
        let (pts, w) = clustered(&mut rng, 2000, 6, 0.2);
        let cfg = LloydConfig { k: 4, max_iters: 12, tol: 0.0, seed: 9 };
        let (r64, _) = lloyd_dense(&pts, &w, 6, &cfg, &EngineOpts::pruned());
        let (r32, _) = lloyd_dense(
            &pts,
            &w,
            6,
            &cfg,
            &EngineOpts::pruned().with_precision(Precision::F32),
        );
        let rel = (r64.objective - r32.objective).abs() / r64.objective.abs().max(1e-12);
        assert!(
            rel <= super::super::F32_OBJ_RTOL,
            "f32 objective drifted {rel:.2e} (> {:.0e})",
            super::super::F32_OBJ_RTOL
        );
    }

    #[test]
    fn warm_start_shape_mismatch_falls_back_to_seeding() {
        let mut rng = SplitMix64::new(45);
        let (pts, w) = clustered(&mut rng, 200, 3, 0.3);
        let cfg = LloydConfig { k: 3, max_iters: 6, tol: 0.0, seed: 9 };
        let (cold, _) = lloyd_dense(&pts, &w, 3, &cfg, &EngineOpts::pruned());
        let bad = vec![0.0; 5]; // wrong length
        let (warm, _) =
            lloyd_dense_init(&pts, &w, 3, &cfg, &EngineOpts::pruned(), Some(&bad));
        assert_eq!(warm.objective.to_bits(), cold.objective.to_bits());
        assert_eq!(warm.centroids, cold.centroids);
    }
}
