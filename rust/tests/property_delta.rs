//! Property tests for the centroid-delta wire format
//! (`rkmeans::serve::delta`): across version sequences produced by the
//! *real* incremental planner on random traces, every consecutive pair
//! `(a, b)` must satisfy
//!
//! ```text
//! a.apply_delta(from_bytes(to_bytes(a.diff(b)))) ≡ b   (bitwise)
//! ```
//!
//! where ≡ is byte-identity of the canonical serialization — the
//! shortest-repr f64 writer makes that equivalent to bitwise equality
//! of every float. The planner is exercised on both of its paths,
//! because they stress different delta shapes:
//!
//! * **patch-heavy** (lenient thresholds): Step-2 models stay frozen,
//!   so deltas ship moved centroid rows only;
//! * **rebuild-heavy** (`rebuild_every = 1`): Step-2 models re-solve
//!   each batch, so deltas also carry whole subspace models — including
//!   reseed-heavy traces (70 % deletes) where centroids move a lot.
//!
//! Plus the staleness contract: a delta keyed `from → to` must be
//! rejected (with the version gap named) by any base that is not
//! exactly `from`.

use rkmeans::incremental::{apply_to_db, IncrementalEngine, PlannerOpts};
use rkmeans::metrics::Metrics;
use rkmeans::rkmeans::{RkConfig, RkModel};
use rkmeans::serve::{DeltaApplyError, ModelDelta};
use rkmeans::synthetic::{retailer, retailer_trace, Scale, TraceSpec};

/// Run a retailer trace through the incremental engine and collect the
/// versioned model after init and after every batch.
fn version_sequence(seed: u64, opts: PlannerOpts, spec: TraceSpec) -> Vec<RkModel> {
    let mut db = retailer::generate(Scale::tiny(), seed);
    let feq = retailer::feq();
    let trace = retailer_trace(&db, seed + 1, spec);
    let mut engine =
        IncrementalEngine::new(&db, feq, RkConfig::new(4).with_seed(seed), opts, Metrics::new())
            .expect("engine");
    let mut out = vec![engine.model()];
    for batch in &trace {
        apply_to_db(&mut db, batch).expect("trace replays cleanly");
        engine.apply_batch(&db, batch).expect("maintenance");
        out.push(engine.model());
    }
    out
}

/// Lenient thresholds: every batch takes the patch path.
fn patch_opts() -> PlannerOpts {
    PlannerOpts {
        drift_threshold: f64::INFINITY,
        max_patch_fraction: 1.0,
        max_join_churn: f64::INFINITY,
        ..PlannerOpts::default()
    }
}

/// Check the bitwise round-trip over every consecutive version pair.
fn assert_roundtrips(models: &[RkModel]) {
    for pair in models.windows(2) {
        let (a, b) = (&pair[0], &pair[1]);
        let delta = a.diff(b);
        let decoded = ModelDelta::from_bytes(&delta.to_bytes()).expect("wire decode");
        let applied = a.apply_delta(&decoded).expect("delta applies to its base");
        assert_eq!(
            applied.to_bytes(),
            b.to_bytes(),
            "delta v{} → v{} did not reconstruct bitwise",
            a.version,
            b.version
        );
    }
}

#[test]
fn patch_path_deltas_reconstruct_bitwise() {
    for seed in [11u64, 23, 47] {
        let models = version_sequence(seed, patch_opts(), TraceSpec::new(4, 120));
        assert!(models.len() > 3);
        assert_roundtrips(&models);
    }
}

#[test]
fn rebuild_path_deltas_reconstruct_bitwise() {
    let rebuild = PlannerOpts { rebuild_every: 1, ..PlannerOpts::default() };
    let models = version_sequence(5, rebuild.clone(), TraceSpec::new(3, 120));
    assert_roundtrips(&models);

    // Reseed-heavy: 70 % deletes shrink clusters until Step 4 reseeds,
    // the delta shape with the most churn per version.
    let heavy = TraceSpec { batches: 3, batch_size: 150, delete_frac: 0.7 };
    let models = version_sequence(7, rebuild, heavy);
    assert_roundtrips(&models);
}

#[test]
fn stale_deltas_name_the_version_gap() {
    let models = version_sequence(3, patch_opts(), TraceSpec::new(3, 100));
    // Find two consecutive models with distinct versions and a base
    // strictly older than the delta's `from`.
    let (a, b) = (&models[1], &models[2]);
    let base = &models[0];
    assert!(base.version < a.version && a.version < b.version, "versions advance per batch");
    let delta = a.diff(b);
    match base.apply_delta(&delta) {
        Err(DeltaApplyError::VersionGap { base: got, from, to }) => {
            assert_eq!(got, base.version);
            assert_eq!(from, a.version);
            assert_eq!(to, b.version);
        }
        other => panic!("expected a version-gap rejection, got {other:?}"),
    }
    // The error message tells the operator what to ship.
    let msg = base.apply_delta(&delta).unwrap_err().to_string();
    assert!(msg.contains("stale delta"), "unhelpful message: {msg}");
}
