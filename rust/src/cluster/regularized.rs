//! Regularized Rk-means (paper §3, "Regularized Rk-means", Prop. 3.5).
//!
//! The paper extends the coreset construction to a penalized objective
//! `W₂²(M, P_in) + Ω(M)` where `Ω` decomposes over the subspace partition,
//! penalizing each marginal measure's *supporting atoms*. With an
//! atom-count penalty `Ω_j(M_j) = ρ · |supp(M_j)|` (the ℓ0 flavor of the
//! paper's group-lasso suggestion) the regularized Step 2 has a clean
//! closed form in both subspace types:
//!
//! * **continuous** — the 1-D DP already produces the optimal cost for
//!   every κ' ≤ κ as its layer boundary values; pick
//!   `argmin_κ' cost(κ') + ρ·κ'`;
//! * **categorical** — Corollary 4.3 gives the optimal cost for every κ'
//!   from one sorted pass (heavy prefix sums + light suffix norms).
//!
//! The payoff is *adaptive per-subspace κ_j*: low-information subspaces
//! collapse to a couple of components, shrinking the grid coreset
//! multiplicatively (|G| ≤ Π κ_j) at a quantization cost the penalty
//! controls — exactly the high-dimensional regime §3 motivates.

use super::categorical::{categorical_kmeans, CatClusters};
use super::kmeans1d::{kmeans1d, Kmeans1dResult};

/// Optimal 1-D k-means cost for every k' in `1..=k_max` (index k'-1).
///
/// One DP run at `k_max` visits every layer; this re-runs the public DP
/// per layer for clarity — still `O(k_max · n log n)` in total because the
/// inner DP is layer-incremental. Distinct values are merged first, so
/// `k' ≥ #distinct` entries are exactly 0.
pub fn kmeans1d_cost_profile(points: &[(f64, f64)], k_max: usize) -> Vec<f64> {
    (1..=k_max).map(|k| kmeans1d(points, k).cost).collect()
}

/// Optimal categorical k-means cost for every κ' in `1..=k_max`
/// (Corollary 4.3 evaluated over the sorted weight profile in one pass).
pub fn categorical_cost_profile(marginal: &[(u64, f64)], k_max: usize) -> Vec<f64> {
    let mut w: Vec<f64> = marginal.iter().map(|&(_, v)| v).filter(|&v| v > 0.0).collect();
    w.sort_by(|a, b| b.partial_cmp(a).expect("finite weights"));
    let l = w.len();
    // Suffix ℓ1/ℓ2² of the light tail starting at index i.
    let mut suf1 = vec![0.0; l + 1];
    let mut suf2 = vec![0.0; l + 1];
    for i in (0..l).rev() {
        suf1[i] = suf1[i + 1] + w[i];
        suf2[i] = suf2[i + 1] + w[i] * w[i];
    }
    // κ' clusters = heaviest κ'−1 singletons (cost 0) + light tail from
    // index κ'−1 with cost ‖light‖₁ − ‖light‖₂²/‖light‖₁ (Prop 4.1 with
    // the Cor 4.3 ordering; the ‖v‖₁ and Σ_heavy terms cancel).
    (1..=k_max)
        .map(|kp| {
            let i = kp - 1; // first light index
            if i >= l || suf1[i] <= 0.0 {
                0.0
            } else {
                (suf1[i] - suf2[i] / suf1[i]).max(0.0)
            }
        })
        .collect()
}

/// Pick `argmin_κ' λ·cost(κ') + ρ·κ'` from a cost profile (1-based κ').
pub fn select_kappa(costs: &[f64], lambda: f64, rho: f64) -> usize {
    let mut best = (f64::INFINITY, 1usize);
    for (i, &c) in costs.iter().enumerate() {
        let kp = i + 1;
        let pen = lambda * c + rho * kp as f64;
        if pen < best.0 - 1e-15 {
            best = (pen, kp);
        }
    }
    best.1
}

/// Regularized continuous Step-2 solve: adaptive κ_j.
pub fn kmeans1d_regularized(
    points: &[(f64, f64)],
    k_max: usize,
    lambda: f64,
    rho: f64,
) -> (Kmeans1dResult, usize) {
    let profile = kmeans1d_cost_profile(points, k_max);
    let kappa = select_kappa(&profile, lambda, rho);
    (kmeans1d(points, kappa), kappa)
}

/// Regularized categorical Step-2 solve: adaptive κ_j.
pub fn categorical_regularized(
    marginal: &[(u64, f64)],
    k_max: usize,
    lambda: f64,
    rho: f64,
) -> (CatClusters, usize) {
    let profile = categorical_cost_profile(marginal, k_max);
    let kappa = select_kappa(&profile, lambda, rho);
    (categorical_kmeans(marginal, kappa), kappa)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::{assert_close, for_cases};

    #[test]
    fn categorical_profile_matches_direct_solver() {
        for_cases(30, |rng| {
            let l = 2 + rng.below(10) as usize;
            let marginal: Vec<(u64, f64)> =
                (0..l).map(|e| (e as u64, rng.uniform(0.1, 5.0))).collect();
            let k_max = 1 + rng.below(l as u64 + 2) as usize;
            let profile = categorical_cost_profile(&marginal, k_max);
            for (i, &c) in profile.iter().enumerate() {
                let direct = categorical_kmeans(&marginal, i + 1).cost;
                assert_close(c, direct, 1e-9);
            }
        });
    }

    #[test]
    fn continuous_profile_is_monotone() {
        for_cases(15, |rng| {
            let n = 3 + rng.below(20) as usize;
            let pts: Vec<(f64, f64)> =
                (0..n).map(|_| (rng.uniform(-5.0, 5.0), rng.uniform(0.1, 2.0))).collect();
            let profile = kmeans1d_cost_profile(&pts, 6);
            for w in profile.windows(2) {
                assert!(w[1] <= w[0] + 1e-9, "cost must not increase with κ: {profile:?}");
            }
        });
    }

    #[test]
    fn rho_zero_takes_max_kappa_rho_inf_takes_one() {
        let costs = vec![10.0, 4.0, 1.0, 0.2];
        // ρ=0: pick the smallest cost (κ'=4 here since strictly decreasing).
        assert_eq!(select_kappa(&costs, 1.0, 0.0), 4);
        // Huge ρ: collapse to a single component.
        assert_eq!(select_kappa(&costs, 1.0, 1e9), 1);
        // Moderate ρ: interior optimum. cost+2κ: 12, 8, 7, 8.2 -> κ'=3.
        assert_eq!(select_kappa(&costs, 1.0, 2.0), 3);
    }

    #[test]
    fn regularized_solvers_respect_tradeoff() {
        let pts: Vec<(f64, f64)> =
            (0..40).map(|i| ((i % 8) as f64 * 3.0, 1.0)).collect();
        let (loose, k_loose) = kmeans1d_regularized(&pts, 8, 1.0, 0.01);
        let (tight, k_tight) = kmeans1d_regularized(&pts, 8, 1.0, 50.0);
        assert!(k_tight <= k_loose);
        assert!(tight.cost >= loose.cost - 1e-9);
        // With a tiny penalty the 8 distinct values are fully resolved.
        assert_eq!(k_loose, 8);
        assert_close(loose.cost, 0.0, 1e-12);
    }

    #[test]
    fn categorical_regularized_collapses_under_pressure() {
        let marginal: Vec<(u64, f64)> = (0..6).map(|e| (e, 1.0 + e as f64)).collect();
        let (c, kappa) = categorical_regularized(&marginal, 6, 1.0, 100.0);
        assert_eq!(kappa, 1);
        assert_eq!(c.kappa(), 1);
        let (c2, kappa2) = categorical_regularized(&marginal, 6, 1.0, 1e-6);
        assert_eq!(kappa2, 6);
        assert_eq!(c2.cost, 0.0);
    }
}
