//! Synthetic load generation for the serving tier.
//!
//! [`synth_rows`] samples request tuples straight from a model's own
//! geometry (no database needed at the edge: continuous values jitter
//! around the Step-2 centers, categorical keys draw from the subspace's
//! observed heavy/light domains), and [`run_open_loop`] drives an
//! [`AssignFront`] with them: each client thread *submits* at its share
//! of the target arrival rate without waiting for answers — the
//! open-loop discipline, so queueing delay shows up in the latency tail
//! instead of throttling the generator — then drains its replies.
//! [`run_naive_loop`] is the contrast arm: one thread, one
//! [`RkModel::assign`] call per request, no batching, no pool — the
//! baseline the `serve_qps_speedup` bench gate compares against.

use crate::coreset::SubspaceSolver;
use crate::data::Value;
use crate::rkmeans::RkModel;
use crate::serve::AssignFront;
use crate::util::SplitMix64;
use std::time::{Duration, Instant};

/// Load-generator shape.
#[derive(Clone, Copy, Debug)]
pub struct LoadSpec {
    /// Total requests across all clients.
    pub requests: usize,
    /// Concurrent client threads.
    pub clients: usize,
    /// Target aggregate arrival rate (requests/s); `None` = submit as
    /// fast as possible (the saturation/throughput measurement).
    pub qps: Option<f64>,
    /// Row-sampling seed.
    pub seed: u64,
}

impl LoadSpec {
    /// A saturation run: `requests` requests from `clients` un-paced
    /// clients.
    pub fn saturate(requests: usize, clients: usize) -> LoadSpec {
        LoadSpec { requests, clients, qps: None, seed: 42 }
    }
}

/// What a load run measured.
#[derive(Clone, Copy, Debug)]
pub struct LoadReport {
    /// Requests answered.
    pub requests: usize,
    /// Wall-clock of the whole run (submit through last drain), seconds.
    pub elapsed_s: f64,
    /// Sustained throughput `requests / elapsed_s`.
    pub qps: f64,
    /// Median per-request latency (queue + compute), µs — exact over
    /// the run's samples, not histogram-bucketed.
    pub p50_us: u64,
    /// 99th-percentile per-request latency, µs.
    pub p99_us: u64,
    /// Smallest model version observed in a reply.
    pub min_version: u64,
    /// Largest model version observed in a reply.
    pub max_version: u64,
    /// Whether every client saw a non-decreasing version sequence (the
    /// front's monotonicity contract).
    pub monotonic: bool,
}

impl LoadReport {
    /// One printable summary line.
    pub fn line(&self, label: &str) -> String {
        format!(
            "{label:<10} {:>8} req in {:>7.3}s  {:>9.0} req/s  p50={:>5}µs p99={:>5}µs  \
             versions {}..={}{}",
            self.requests,
            self.elapsed_s,
            self.qps,
            self.p50_us,
            self.p99_us,
            self.min_version,
            self.max_version,
            if self.monotonic { "" } else { "  (NON-MONOTONE!)" }
        )
    }
}

/// Sample `n` plausible request tuples from the model's own geometry
/// (FEQ feature order, ready for [`RkModel::assign`]).
pub fn synth_rows(model: &RkModel, n: usize, seed: u64) -> Vec<Vec<Value>> {
    let mut rng = SplitMix64::new(seed);
    // Per-subspace candidate pools, built once.
    let pools: Vec<(Option<(f64, f64)>, Vec<u64>)> = model
        .models
        .iter()
        .map(|m| match &m.solver {
            SubspaceSolver::Continuous(r) => {
                let lo = r.centers.iter().cloned().fold(f64::INFINITY, f64::min);
                let hi = r.centers.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let pad = (hi - lo).abs().max(1.0) * 0.25;
                (Some((lo - pad, hi + pad)), Vec::new())
            }
            SubspaceSolver::Categorical(c) => {
                let mut keys: Vec<u64> = c.heavy.clone();
                keys.extend(c.light.iter().map(|&(e, _)| e));
                if keys.is_empty() {
                    keys.push(0);
                }
                (None, keys)
            }
        })
        .collect();
    (0..n)
        .map(|_| {
            pools
                .iter()
                .map(|(cont, keys)| match cont {
                    Some((lo, hi)) => Value::Double(rng.uniform(*lo, *hi)),
                    None => Value::Int(keys[rng.below(keys.len() as u64) as usize] as i64),
                })
                .collect()
        })
        .collect()
}

/// Exact percentile over a sorted sample (`0.0 < q ≤ 1.0`). Shared
/// with the socket-tier load generator (`serve::rpc`).
pub(crate) fn pct(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

/// Drive `front` with `spec.requests` tuples cycled from `rows`,
/// open-loop (module docs). Blocks until every reply has drained.
pub fn run_open_loop(front: &AssignFront, rows: &[Vec<Value>], spec: &LoadSpec) -> LoadReport {
    assert!(!rows.is_empty(), "need at least one request row");
    let clients = spec.clients.max(1);
    let total = spec.requests;
    // Per-client arrival interval: the aggregate rate split evenly.
    let interval = spec.qps.map(|q| Duration::from_secs_f64(clients as f64 / q.max(1e-9)));

    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let client = front.client();
            let share: Vec<Vec<Value>> = (0..total / clients + usize::from(c < total % clients))
                .map(|i| rows[(c + i * clients) % rows.len()].clone())
                .collect();
            std::thread::spawn(move || {
                let mut pending = Vec::with_capacity(share.len());
                let mut next_at = Instant::now();
                for row in share {
                    if let Some(iv) = interval {
                        let now = Instant::now();
                        if now < next_at {
                            std::thread::sleep(next_at - now);
                        }
                        next_at += iv;
                    }
                    pending.push(client.submit(row));
                }
                pending
                    .into_iter()
                    .map(|rx| rx.recv().expect("assign front replies"))
                    .collect::<Vec<_>>()
            })
        })
        .collect();

    let mut latencies = Vec::with_capacity(total);
    let (mut min_v, mut max_v, mut monotonic) = (u64::MAX, 0u64, true);
    for h in handles {
        let mut last = 0u64;
        for a in h.join().expect("load client") {
            monotonic &= a.version >= last;
            last = a.version;
            min_v = min_v.min(a.version);
            max_v = max_v.max(a.version);
            latencies.push(a.latency_us);
        }
    }
    let elapsed_s = t0.elapsed().as_secs_f64();
    latencies.sort_unstable();
    LoadReport {
        requests: latencies.len(),
        elapsed_s,
        qps: latencies.len() as f64 / elapsed_s.max(1e-12),
        p50_us: pct(&latencies, 0.50),
        p99_us: pct(&latencies, 0.99),
        min_version: if latencies.is_empty() { 0 } else { min_v },
        max_version: max_v,
        monotonic,
    }
}

/// The un-batched contrast arm: one thread, one `assign` per request.
pub fn run_naive_loop(model: &RkModel, rows: &[Vec<Value>], requests: usize) -> LoadReport {
    assert!(!rows.is_empty(), "need at least one request row");
    let t0 = Instant::now();
    let mut latencies = Vec::with_capacity(requests);
    for i in 0..requests {
        let t = Instant::now();
        std::hint::black_box(model.assign(&rows[i % rows.len()]));
        latencies.push(t.elapsed().as_micros() as u64);
    }
    let elapsed_s = t0.elapsed().as_secs_f64();
    latencies.sort_unstable();
    LoadReport {
        requests,
        elapsed_s,
        qps: requests as f64 / elapsed_s.max(1e-12),
        p50_us: pct(&latencies, 0.50),
        p99_us: pct(&latencies, 0.99),
        min_version: model.version,
        max_version: model.version,
        monotonic: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Metrics;
    use crate::rkmeans::{ClusterOpts, RkPipeline, SubspaceOpts};
    use crate::serve::{FrontOpts, ModelMesh};
    use crate::synthetic::{retailer, Scale};
    use crate::util::exec::ExecPool;
    use std::sync::Arc;

    fn model() -> RkModel {
        let db = retailer::generate(Scale::tiny(), 42);
        let feq = retailer::feq();
        let pipe = RkPipeline::plan(&db, &feq).unwrap();
        let marginals = pipe.marginals().unwrap();
        let subspaces = pipe.subspaces(&marginals, &SubspaceOpts::new(4)).unwrap();
        pipe.coreset(&subspaces).unwrap().cluster(&ClusterOpts::new(4)).with_version(1)
    }

    #[test]
    fn synth_rows_assign_cleanly() {
        let m = model();
        let rows = synth_rows(&m, 64, 9);
        assert_eq!(rows.len(), 64);
        for row in &rows {
            assert_eq!(row.len(), m.m());
            assert!(m.assign(row) < m.k());
        }
        // Deterministic in the seed.
        assert_eq!(synth_rows(&m, 8, 9), synth_rows(&m, 8, 9));
    }

    #[test]
    fn open_loop_answers_every_request() {
        let m = model();
        let rows = synth_rows(&m, 128, 3);
        let mesh = ModelMesh::new(m, 2, Metrics::new());
        let front = AssignFront::start(mesh, FrontOpts::default(), ExecPool::new(2));
        let spec = LoadSpec { requests: 500, clients: 3, qps: None, seed: 3 };
        let report = run_open_loop(&front, &rows, &spec);
        front.shutdown();
        assert_eq!(report.requests, 500);
        assert!(report.monotonic);
        assert_eq!((report.min_version, report.max_version), (1, 1));
        assert!(report.qps > 0.0);
        assert!(report.p50_us <= report.p99_us);
    }

    #[test]
    fn naive_loop_reports() {
        let m = model();
        let rows = synth_rows(&m, 32, 5);
        let report = run_naive_loop(&m, &rows, 200);
        assert_eq!(report.requests, 200);
        assert!(report.qps > 0.0);
        assert!(report.monotonic);
    }
}
