//! The socket RPC tier: the serving mesh across a real process boundary.
//!
//! PR 7's in-process tier ([`ModelMesh`] + [`AssignFront`](crate::serve::AssignFront)
//! + [`Publisher`](crate::serve::Publisher)) already speaks versioned
//! wire formats; this module carries them over TCP (std
//! `TcpListener`/`TcpStream`, no extra dependencies) as three planes on
//! one length-prefixed framed protocol ([`wire`]):
//!
//! * **Assign plane** — a client ships encoded rows
//!   ([`wire::encode_row`]); the serving process answers
//!   `Assignment{cluster, version}` through its local micro-batching
//!   front, so socket clients get the same batching amortization as
//!   in-process ones. Responses come back in request order per
//!   connection, which is what lets [`run_rpc_loop`] pipeline a window
//!   of requests per connection.
//! * **Replication plane** — a replica process ([`ReplicaSync`])
//!   subscribes to the writer's delta stream with the version it
//!   already has. The writer registers the subscription *before*
//!   snapshotting, then [`RpcServer::broadcast`] fans every published
//!   delta (the exact bytes [`Publisher::publish_wire`](crate::serve::Publisher::publish_wire)
//!   verified) to all live subscribers. On
//!   [`DeltaApplyError::VersionGap`] the replica requests a full
//!   snapshot, **byte-verifies** it (`from_bytes` then re-serialize
//!   must reproduce the wire bytes exactly), installs it, and rejoins
//!   the stream; deltas older than the installed version are skipped as
//!   stale. [`RpcOpts::drop_every`] deterministically drops every Nth
//!   delta per subscriber — the fault-injection hook the CI leg uses to
//!   force a real gap → catch-up → rejoin cycle.
//! * **Control plane** — an empty `PROBE` frame answers with
//!   [`wire::ProbeReply`]: served version, role, replica count, and the
//!   catch-up / gap counters the load generator and CI use to decide
//!   "healthy and caught up".
//!
//! Failure semantics: every connection runs with read/write timeouts
//! (reads double as the poll tick, so stop flags are honored within a
//! tick); the replica's connect loop retries with seeded exponential
//! backoff + jitter ([`SyncOpts`], deterministic under test); and the
//! writer keeps publishing while replicas churn — a dead subscriber is
//! pruned at the next broadcast, a reborn one catches up from its
//! subscribe snapshot. Telemetry lands in `serve.rpc.*` (frames, bytes,
//! connections, reconnects, catch-ups, gaps, dropped/applied deltas,
//! and per-plane latency histograms).

pub mod wire;

use crate::data::Value;
use crate::metrics::{Counter, Gauge, Histogram};
use crate::rkmeans::RkModel;
use crate::serve::load::{pct, LoadReport, LoadSpec};
use crate::serve::{AssignClient, DeltaApplyError, ModelDelta, ModelMesh};
use crate::util::timer;
use crate::util::SplitMix64;
use anyhow::{bail, ensure, Context, Result};
use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

// ---- shared small helpers --------------------------------------------

/// Microseconds since `t0` (saturating — a >584-millennium stall is not
/// a representable latency).
fn elapsed_us(t0: Instant) -> u64 {
    u64::try_from(t0.elapsed().as_micros()).unwrap_or(u64::MAX)
}

/// The newest model across the mesh's replica slots (slots can disagree
/// mid-install; the control and replication planes want the frontier).
fn best_model(mesh: &ModelMesh) -> Arc<RkModel> {
    let mut best = mesh.model(0);
    for i in 1..mesh.replicas() {
        let m = mesh.model(i);
        if m.version > best.version {
            best = m;
        }
    }
    best
}

/// Seeded exponential backoff with jitter: `base · 2^(attempt-1)`
/// capped at `cap`, scaled by a uniform factor in `[0.5, 1.0)` drawn
/// from `rng` — so reconnect storms decorrelate but tests seeing the
/// same seed see the same schedule.
pub(crate) fn backoff_delay(
    attempt: u32,
    base_ms: u64,
    cap_ms: u64,
    rng: &mut SplitMix64,
) -> Duration {
    let shift = attempt.saturating_sub(1).min(16);
    let exp = base_ms.saturating_mul(1u64 << shift).min(cap_ms).max(1);
    let jitter = 0.5 + 0.5 * rng.next_f64();
    Duration::from_millis(((exp as f64) * jitter).round().max(1.0) as u64)
}

/// One nonblocking-ish socket read under the connection's read timeout.
enum Inbound {
    /// `n` fresh bytes.
    Data(usize),
    /// Timeout tick — no data, connection still alive.
    Idle,
    /// EOF or a hard error — drop the connection.
    Closed,
}

fn read_chunk(stream: &mut TcpStream, buf: &mut [u8]) -> Inbound {
    match stream.read(buf) {
        Ok(0) => Inbound::Closed,
        Ok(n) => Inbound::Data(n),
        // Unix reports a read timeout as WouldBlock, Windows as TimedOut.
        Err(e)
            if matches!(
                e.kind(),
                ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted
            ) =>
        {
            Inbound::Idle
        }
        Err(_) => Inbound::Closed,
    }
}

fn configure(stream: &TcpStream, read_timeout: Duration, write_timeout: Duration) -> Result<()> {
    stream.set_read_timeout(Some(read_timeout)).context("set read timeout")?;
    stream.set_write_timeout(Some(write_timeout)).context("set write timeout")?;
    let _ = stream.set_nodelay(true);
    Ok(())
}

/// Write one frame; returns the frame's wire size.
fn send_frame(stream: &mut TcpStream, frame_kind: u8, payload: &[u8]) -> std::io::Result<usize> {
    let frame = wire::encode_frame(frame_kind, payload);
    stream.write_all(&frame)?;
    Ok(frame.len())
}

/// Block (under the stream's read timeout ticks) until one complete
/// frame arrives or `deadline` elapses.
fn read_one_frame(
    stream: &mut TcpStream,
    fb: &mut wire::FrameBuf,
    deadline: Duration,
) -> Result<(u8, Vec<u8>)> {
    let t0 = timer::now();
    let mut buf = vec![0u8; 64 * 1024];
    loop {
        if let Some(frame) = fb.next_frame()? {
            return Ok(frame);
        }
        ensure!(t0.elapsed() < deadline, "timed out after {deadline:?} waiting for a frame");
        match read_chunk(stream, &mut buf) {
            Inbound::Data(n) => fb.extend(&buf[..n]),
            Inbound::Idle => {}
            Inbound::Closed => bail!("connection closed while waiting for a frame"),
        }
    }
}

// ---- the server ------------------------------------------------------

/// Connection knobs for an [`RpcServer`].
#[derive(Clone, Copy, Debug)]
pub struct RpcOpts {
    /// Per-connection read timeout; doubles as the handler poll tick
    /// (stop flags and broadcast queues are serviced between reads).
    pub read_timeout: Duration,
    /// Per-connection write timeout.
    pub write_timeout: Duration,
    /// Fault injection: when `N > 0`, drop the 1st, (N+1)th, (2N+1)th…
    /// delta per subscriber instead of sending it — forcing the replica
    /// through a genuine `VersionGap` → snapshot catch-up cycle.
    /// `0` disables.
    pub drop_every: u64,
}

impl Default for RpcOpts {
    fn default() -> RpcOpts {
        RpcOpts {
            read_timeout: Duration::from_millis(50),
            write_timeout: Duration::from_secs(5),
            drop_every: 0,
        }
    }
}

/// One registered delta-stream subscriber (frames pre-encoded by
/// [`RpcServer::broadcast`], forwarded to the socket by its connection
/// handler).
struct Subscriber {
    tx: Sender<Vec<u8>>,
    /// Deltas considered for this subscriber (drives `drop_every`).
    seq: u64,
}

struct ServerShared {
    mesh: Arc<ModelMesh>,
    role: u64,
    opts: RpcOpts,
    stop: AtomicBool,
    subscribers: Mutex<Vec<Subscriber>>,
    frames_in: Arc<Counter>,
    frames_out: Arc<Counter>,
    bytes_in: Arc<Counter>,
    bytes_out: Arc<Counter>,
    conns: Arc<Counter>,
    catchup_serves: Arc<Counter>,
    deltas_out: Arc<Counter>,
    dropped_deltas: Arc<Counter>,
    /// Replica-side counters (shared registry) read back by probes.
    catchups: Arc<Counter>,
    gaps: Arc<Counter>,
    subscribers_gauge: Arc<Gauge>,
    assign_us: Arc<Histogram>,
    probe_us: Arc<Histogram>,
}

impl ServerShared {
    fn lock_subscribers(&self) -> std::sync::MutexGuard<'_, Vec<Subscriber>> {
        self.subscribers.lock().unwrap_or_else(PoisonError::into_inner)
    }
}

/// A framed-protocol server over one [`TcpListener`] (module docs):
/// assign, replication, and control planes on every accepted
/// connection. Runs until a `STOP` frame arrives or
/// [`RpcServer::request_stop`] is called.
pub struct RpcServer {
    inner: Arc<ServerShared>,
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
}

impl RpcServer {
    /// Start serving on `listener`. `client` is the local assign-plane
    /// entry (a fresh clone is handed to every connection handler);
    /// `role` is [`wire::ROLE_WRITER`] or [`wire::ROLE_REPLICA`] and is
    /// only reported by probes.
    pub fn start(
        listener: TcpListener,
        mesh: Arc<ModelMesh>,
        client: AssignClient,
        role: u64,
        opts: RpcOpts,
    ) -> Result<RpcServer> {
        let addr = listener.local_addr().context("listener local_addr")?;
        listener.set_nonblocking(true).context("nonblocking listener")?;
        let m = mesh.metrics().clone();
        let inner = Arc::new(ServerShared {
            role,
            opts,
            stop: AtomicBool::new(false),
            subscribers: Mutex::new(Vec::new()),
            frames_in: m.counter("serve.rpc.frames_in"),
            frames_out: m.counter("serve.rpc.frames_out"),
            bytes_in: m.counter("serve.rpc.bytes_in"),
            bytes_out: m.counter("serve.rpc.bytes_out"),
            conns: m.counter("serve.rpc.conns"),
            catchup_serves: m.counter("serve.rpc.catchup_serves"),
            deltas_out: m.counter("serve.rpc.deltas_out"),
            dropped_deltas: m.counter("serve.rpc.dropped_deltas"),
            catchups: m.counter("serve.rpc.catchups"),
            gaps: m.counter("serve.rpc.gaps"),
            subscribers_gauge: m.gauge("serve.rpc.subscribers"),
            assign_us: m.histogram("serve.rpc.assign_us"),
            probe_us: m.histogram("serve.rpc.probe_us"),
            mesh,
        });
        let shared = Arc::clone(&inner);
        let accept = std::thread::Builder::new()
            .name("rk-rpc-accept".to_string())
            .spawn(move || accept_loop(&shared, &listener, &client))
            .expect("spawn rpc accept loop");
        Ok(RpcServer { inner, addr, accept: Some(accept) })
    }

    /// The bound address (resolves `--listen 127.0.0.1:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Fan a published delta (its verified wire bytes) out to every
    /// live subscriber, pruning dead ones; returns the number of
    /// subscribers still registered. Honors the `drop_every` fault
    /// schedule per subscriber.
    pub fn broadcast(&self, delta_wire: &[u8]) -> usize {
        let frame = wire::encode_frame(wire::kind::DELTA, delta_wire);
        let drop_every = self.inner.opts.drop_every;
        let mut subs = self.inner.lock_subscribers();
        subs.retain_mut(|s| {
            let drop_this = drop_every > 0 && s.seq % drop_every == 0;
            s.seq += 1;
            if drop_this {
                self.inner.dropped_deltas.inc();
                return true;
            }
            match s.tx.send(frame.clone()) {
                Ok(()) => {
                    self.inner.deltas_out.inc();
                    true
                }
                Err(_) => false,
            }
        });
        self.inner.subscribers_gauge.set(i64::try_from(subs.len()).unwrap_or(i64::MAX));
        subs.len()
    }

    /// Subscribers currently registered on the replication plane.
    pub fn subscriber_count(&self) -> usize {
        self.inner.lock_subscribers().len()
    }

    /// Has a `STOP` frame (or [`RpcServer::request_stop`]) been seen?
    pub fn stop_requested(&self) -> bool {
        self.inner.stop.load(Ordering::SeqCst)
    }

    /// Ask the accept loop and every handler to wind down (they notice
    /// within one read-timeout tick).
    pub fn request_stop(&self) {
        self.inner.stop.store(true, Ordering::SeqCst);
    }

    /// Block until the server stops (a `STOP` frame arrives), joining
    /// the accept loop and all connection handlers.
    pub fn wait(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// [`RpcServer::request_stop`] + [`RpcServer::wait`].
    pub fn shutdown(mut self) {
        self.request_stop();
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for RpcServer {
    fn drop(&mut self) {
        self.request_stop();
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

/// Accept-loop body: poll-accept (the listener is nonblocking so the
/// stop flag stays responsive), one handler thread per connection, all
/// joined on the way out.
fn accept_loop(shared: &Arc<ServerShared>, listener: &TcpListener, client: &AssignClient) {
    let mut handles: Vec<JoinHandle<()>> = Vec::new();
    while !shared.stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let sh = Arc::clone(shared);
                let cl = client.clone();
                let spawned = std::thread::Builder::new()
                    .name("rk-rpc-conn".to_string())
                    .spawn(move || handle_conn(&sh, &cl, stream));
                match spawned {
                    Ok(h) => handles.push(h),
                    Err(_) => std::thread::sleep(shared.opts.read_timeout),
                }
            }
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                std::thread::sleep(shared.opts.read_timeout.min(Duration::from_millis(20)));
            }
            Err(_) => break,
        }
    }
    for h in handles {
        let _ = h.join();
    }
}

/// Per-connection handler: interleaves (a) forwarding broadcast frames
/// to a subscribed replica, (b) decoding inbound frames, (c) answering
/// assign batches in request order. Exits on EOF, protocol desync, I/O
/// error, or the server stop flag.
fn handle_conn(shared: &ServerShared, client: &AssignClient, mut stream: TcpStream) {
    if configure(&stream, shared.opts.read_timeout, shared.opts.write_timeout).is_err() {
        return;
    }
    shared.conns.inc();
    let mut fb = wire::FrameBuf::new();
    let mut buf = vec![0u8; 64 * 1024];
    let mut sub_rx: Option<Receiver<Vec<u8>>> = None;

    'conn: loop {
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        // (a) outbound replication frames queued by `broadcast`.
        if let Some(rx) = &sub_rx {
            while let Ok(frame) = rx.try_recv() {
                if stream.write_all(&frame).is_err() {
                    break 'conn;
                }
                shared.frames_out.inc();
                shared.bytes_out.add(u64::try_from(frame.len()).unwrap_or(u64::MAX));
            }
        }
        // (b) inbound bytes (the read timeout is the poll tick).
        match read_chunk(&mut stream, &mut buf) {
            Inbound::Data(n) => {
                shared.bytes_in.add(u64::try_from(n).unwrap_or(u64::MAX));
                fb.extend(&buf[..n]);
            }
            Inbound::Idle => continue,
            Inbound::Closed => break,
        }
        // (c) decode everything buffered; assign replies keep arrival order.
        let mut pending: Vec<(Instant, Receiver<crate::serve::Assignment>)> = Vec::new();
        loop {
            match fb.next_frame() {
                Ok(Some((k, payload))) => {
                    shared.frames_in.inc();
                    match k {
                        wire::kind::ASSIGN_REQ => match wire::decode_row(&payload) {
                            Ok(row) => pending.push((timer::now(), client.submit(row))),
                            Err(e) => {
                                if write_error(shared, &mut stream, &e.to_string()).is_err() {
                                    break 'conn;
                                }
                            }
                        },
                        wire::kind::PROBE => {
                            let t0 = timer::now();
                            let reply = probe_reply(shared).to_bytes();
                            if write_counted(shared, &mut stream, wire::kind::PROBE_RESP, &reply)
                                .is_err()
                            {
                                break 'conn;
                            }
                            shared.probe_us.observe(elapsed_us(t0));
                        }
                        wire::kind::SUBSCRIBE => {
                            let have = match wire::decode_subscribe(&payload) {
                                Ok(v) => v,
                                Err(_) => break 'conn,
                            };
                            // Register *before* snapshotting so no delta
                            // published in between is missed; the replica
                            // stale-skips any overlap.
                            let (tx, rx) = channel::<Vec<u8>>();
                            {
                                let mut subs = shared.lock_subscribers();
                                subs.push(Subscriber { tx, seq: 0 });
                                shared
                                    .subscribers_gauge
                                    .set(i64::try_from(subs.len()).unwrap_or(i64::MAX));
                            }
                            sub_rx = Some(rx);
                            let latest = best_model(&shared.mesh);
                            if latest.version != have {
                                shared.catchup_serves.inc();
                                let bytes = latest.to_bytes();
                                if write_counted(shared, &mut stream, wire::kind::SNAPSHOT, &bytes)
                                    .is_err()
                                {
                                    break 'conn;
                                }
                            }
                        }
                        wire::kind::SNAPSHOT_REQ => {
                            shared.catchup_serves.inc();
                            let bytes = best_model(&shared.mesh).to_bytes();
                            if write_counted(shared, &mut stream, wire::kind::SNAPSHOT, &bytes)
                                .is_err()
                            {
                                break 'conn;
                            }
                        }
                        wire::kind::STOP => {
                            shared.stop.store(true, Ordering::SeqCst);
                            break 'conn;
                        }
                        other => {
                            let msg = format!("unexpected frame kind {other}");
                            if write_error(shared, &mut stream, &msg).is_err() {
                                break 'conn;
                            }
                        }
                    }
                }
                Ok(None) => break,
                // Desynchronized stream (corrupt length prefix): drop it.
                Err(_) => break 'conn,
            }
        }
        for (t0, rx) in pending {
            let a = match rx.recv() {
                Ok(a) => a,
                Err(_) => break 'conn,
            };
            let payload = wire::encode_assignment(a.cluster, a.version);
            if write_counted(shared, &mut stream, wire::kind::ASSIGN_RESP, &payload).is_err() {
                break 'conn;
            }
            shared.assign_us.observe(elapsed_us(t0));
        }
    }
}

fn probe_reply(shared: &ServerShared) -> wire::ProbeReply {
    let catchups = if shared.role == wire::ROLE_WRITER {
        shared.catchup_serves.get()
    } else {
        shared.catchups.get()
    };
    wire::ProbeReply {
        version: best_model(&shared.mesh).version,
        role: shared.role,
        replicas: wire::u64_of(shared.mesh.replicas()),
        catchups,
        gaps: shared.gaps.get(),
    }
}

fn write_counted(
    shared: &ServerShared,
    stream: &mut TcpStream,
    frame_kind: u8,
    payload: &[u8],
) -> std::io::Result<()> {
    let n = send_frame(stream, frame_kind, payload)?;
    shared.frames_out.inc();
    shared.bytes_out.add(u64::try_from(n).unwrap_or(u64::MAX));
    Ok(())
}

fn write_error(shared: &ServerShared, stream: &mut TcpStream, msg: &str) -> std::io::Result<()> {
    write_counted(shared, stream, wire::kind::ERROR, msg.as_bytes())
}

// ---- the replica-side subscriber ------------------------------------

/// Reconnect/backoff knobs for [`ReplicaSync`].
#[derive(Clone, Copy, Debug)]
pub struct SyncOpts {
    /// Consecutive failed connects tolerated before the sync thread
    /// gives up.
    pub retries: u32,
    /// Backoff base, milliseconds (doubles per consecutive failure).
    pub base_ms: u64,
    /// Backoff cap, milliseconds.
    pub cap_ms: u64,
    /// Jitter seed ([`backoff_delay`] is deterministic in it).
    pub seed: u64,
    /// Subscribe-connection read timeout (also the poll tick).
    pub read_timeout: Duration,
    /// Subscribe-connection write timeout.
    pub write_timeout: Duration,
}

impl Default for SyncOpts {
    fn default() -> SyncOpts {
        SyncOpts {
            retries: 40,
            base_ms: 20,
            cap_ms: 2_000,
            seed: 0x5eed,
            read_timeout: Duration::from_millis(50),
            write_timeout: Duration::from_secs(5),
        }
    }
}

/// The replica's replication-plane client: a thread that subscribes to
/// the writer's delta stream, applies verified deltas to the local
/// mesh, and recovers from gaps and dead connections (module docs).
pub struct ReplicaSync {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl ReplicaSync {
    /// Start the sync thread against the writer at `addr`.
    pub fn start(addr: String, mesh: Arc<ModelMesh>, opts: SyncOpts) -> ReplicaSync {
        let stop = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("rk-rpc-sync".to_string())
            .spawn(move || sync_loop(&mesh, &addr, &opts, &flag))
            .expect("spawn replica sync loop");
        ReplicaSync { stop, handle: Some(handle) }
    }

    /// Stop subscribing and join the sync thread.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ReplicaSync {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Verify a snapshot payload byte-exactly and install it if it moves
/// the mesh forward. Returns the installed (or already-held) version,
/// or `None` when verification fails.
fn install_snapshot(mesh: &ModelMesh, payload: &[u8]) -> Option<u64> {
    let model = RkModel::from_bytes(payload).ok()?;
    if model.to_bytes() != payload {
        return None;
    }
    let v = model.version;
    if v >= mesh.latest_version() {
        mesh.install(Arc::new(model));
    }
    Some(v)
}

fn sync_loop(mesh: &ModelMesh, addr: &str, opts: &SyncOpts, stop: &AtomicBool) {
    let m = mesh.metrics().clone();
    let reconnects = m.counter("serve.rpc.reconnects");
    let catchups = m.counter("serve.rpc.catchups");
    let gaps = m.counter("serve.rpc.gaps");
    let stale = m.counter("serve.rpc.stale_deltas");
    let applied = m.counter("serve.rpc.deltas_applied");
    let apply_us = m.histogram("serve.rpc.apply_us");

    let mut rng = SplitMix64::new(opts.seed);
    let mut attempt = 0u32;
    let mut connected_before = false;
    'outer: while !stop.load(Ordering::SeqCst) {
        let mut stream = match TcpStream::connect(addr) {
            Ok(s) => s,
            Err(_) => {
                attempt += 1;
                if attempt > opts.retries {
                    break;
                }
                std::thread::sleep(backoff_delay(attempt, opts.base_ms, opts.cap_ms, &mut rng));
                continue;
            }
        };
        attempt = 0;
        if connected_before {
            reconnects.inc();
        }
        connected_before = true;
        if configure(&stream, opts.read_timeout, opts.write_timeout).is_err() {
            continue;
        }
        let have = mesh.latest_version();
        if send_frame(&mut stream, wire::kind::SUBSCRIBE, &wire::encode_subscribe(have)).is_err() {
            continue;
        }

        let mut fb = wire::FrameBuf::new();
        let mut buf = vec![0u8; 256 * 1024];
        // While a snapshot is in flight, deltas are unusable (they would
        // each re-trigger a gap); skip them until the snapshot lands.
        let mut awaiting_snapshot = false;
        loop {
            if stop.load(Ordering::SeqCst) {
                break 'outer;
            }
            match read_chunk(&mut stream, &mut buf) {
                Inbound::Data(n) => fb.extend(&buf[..n]),
                Inbound::Idle => continue,
                Inbound::Closed => continue 'outer,
            }
            loop {
                match fb.next_frame() {
                    Ok(Some((wire::kind::SNAPSHOT, payload))) => {
                        match install_snapshot(mesh, &payload) {
                            Some(_) => {
                                catchups.inc();
                                awaiting_snapshot = false;
                            }
                            None => continue 'outer,
                        }
                    }
                    Ok(Some((wire::kind::DELTA, payload))) => {
                        if awaiting_snapshot {
                            continue;
                        }
                        let delta = match ModelDelta::from_bytes(&payload) {
                            Ok(d) => d,
                            Err(_) => continue 'outer,
                        };
                        let cur = best_model(mesh);
                        if delta.to_version <= cur.version {
                            stale.inc();
                            continue;
                        }
                        let t0 = timer::now();
                        match cur.apply_delta(&delta) {
                            Ok(next) => {
                                mesh.install(Arc::new(next));
                                applied.inc();
                                apply_us.observe(elapsed_us(t0));
                            }
                            Err(DeltaApplyError::VersionGap { .. }) => {
                                gaps.inc();
                                awaiting_snapshot = true;
                                if send_frame(&mut stream, wire::kind::SNAPSHOT_REQ, &[]).is_err() {
                                    continue 'outer;
                                }
                            }
                            Err(_) => continue 'outer,
                        }
                    }
                    // The writer never sends anything else on this
                    // connection; tolerate strays.
                    Ok(Some(_)) => {}
                    Ok(None) => break,
                    Err(_) => continue 'outer,
                }
            }
        }
    }
}

// ---- standalone control-plane clients --------------------------------

/// Fetch and byte-verify a full model snapshot from `addr`.
pub fn fetch_snapshot(addr: &str, deadline: Duration) -> Result<RkModel> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    configure(&stream, Duration::from_millis(50), Duration::from_secs(5))?;
    send_frame(&mut stream, wire::kind::SNAPSHOT_REQ, &[]).context("send snapshot request")?;
    let mut fb = wire::FrameBuf::new();
    let (k, payload) = read_one_frame(&mut stream, &mut fb, deadline)?;
    ensure!(k == wire::kind::SNAPSHOT, "expected a snapshot frame, got kind {k}");
    let model = RkModel::from_bytes(&payload).context("decode snapshot")?;
    ensure!(model.to_bytes() == payload, "snapshot bytes failed round-trip verification");
    Ok(model)
}

/// Health/version probe against `addr`'s control plane.
pub fn probe(addr: &str, deadline: Duration) -> Result<wire::ProbeReply> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    configure(&stream, Duration::from_millis(50), Duration::from_secs(5))?;
    send_frame(&mut stream, wire::kind::PROBE, &[]).context("send probe")?;
    let mut fb = wire::FrameBuf::new();
    let (k, payload) = read_one_frame(&mut stream, &mut fb, deadline)?;
    ensure!(k == wire::kind::PROBE_RESP, "expected a probe reply, got kind {k}");
    Ok(wire::ProbeReply::from_bytes(&payload)?)
}

/// Ask the server at `addr` to shut down cleanly.
pub fn send_stop(addr: &str) -> Result<()> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    let _ = stream.set_write_timeout(Some(Duration::from_secs(5)));
    send_frame(&mut stream, wire::kind::STOP, &[]).context("send stop")?;
    Ok(())
}

// ---- the socket load generator ---------------------------------------

/// In-flight request cap per load-generator connection.
const WINDOW: usize = 32;

/// What [`run_rpc_loop`] measured, beyond the shared [`LoadReport`].
#[derive(Clone, Debug)]
pub struct RpcLoadReport {
    /// Latency/throughput summary (same shape as the in-process arms).
    pub report: LoadReport,
    /// Every distinct model version observed in a reply, sorted.
    pub versions: Vec<u64>,
    /// Requests whose replies were lost to connection churn (sent but
    /// never answered; not counted in `report.requests`).
    pub lost: usize,
    /// Mid-run reconnects across all clients.
    pub reconnects: usize,
}

struct ClientOut {
    samples: Vec<(u64, u64)>,
    lost: usize,
    reconnects: usize,
    monotonic: bool,
}

fn connect_next(
    addrs: &[String],
    which: &mut usize,
    rng: &mut SplitMix64,
    read_timeout: Duration,
) -> Option<TcpStream> {
    for attempt in 1..=20u32 {
        *which = (*which + 1) % addrs.len();
        if let Ok(stream) = TcpStream::connect(addrs[*which].as_str()) {
            if configure(&stream, read_timeout, Duration::from_secs(5)).is_ok() {
                return Some(stream);
            }
        }
        std::thread::sleep(backoff_delay(attempt, 10, 500, rng));
    }
    None
}

/// Drain whatever responses are available (one read tick); pops one
/// stamp per response in FIFO order. Returns `false` when the
/// connection died.
fn drain_responses(
    stream: &mut TcpStream,
    fb: &mut wire::FrameBuf,
    buf: &mut [u8],
    stamps: &mut VecDeque<Instant>,
    out: &mut ClientOut,
    last_version: &mut u64,
) -> bool {
    match read_chunk(stream, buf) {
        Inbound::Data(n) => fb.extend(&buf[..n]),
        Inbound::Idle => return true,
        Inbound::Closed => return false,
    }
    loop {
        match fb.next_frame() {
            Ok(Some((wire::kind::ASSIGN_RESP, payload))) => {
                let t0 = match stamps.pop_front() {
                    Some(t0) => t0,
                    None => return false, // response without a request: desync
                };
                match wire::decode_assignment(&payload) {
                    Ok((_cluster, version)) => {
                        out.monotonic &= version >= *last_version;
                        *last_version = version;
                        out.samples.push((elapsed_us(t0), version));
                    }
                    Err(_) => return false,
                }
            }
            // An ERROR frame consumes one request slot without a sample.
            Ok(Some((wire::kind::ERROR, _))) => {
                if stamps.pop_front().is_none() {
                    return false;
                }
                out.lost += 1;
            }
            Ok(Some(_)) => {}
            Ok(None) => return true,
            Err(_) => return false,
        }
    }
}

fn client_loop(
    idx: usize,
    addrs: &[String],
    share: Vec<Vec<Value>>,
    interval: Option<Duration>,
    seed: u64,
    read_timeout: Duration,
) -> ClientOut {
    let mut out = ClientOut {
        samples: Vec::with_capacity(share.len()),
        lost: 0,
        reconnects: 0,
        monotonic: true,
    };
    let mut rng = SplitMix64::new(seed);
    // Start the rotation so the first attempt lands on `idx % len`.
    let mut which = (idx + addrs.len().saturating_sub(1)) % addrs.len().max(1);
    let mut stream = match connect_next(addrs, &mut which, &mut rng, read_timeout) {
        Some(s) => s,
        None => return out,
    };
    let mut fb = wire::FrameBuf::new();
    let mut buf = vec![0u8; 64 * 1024];
    let mut stamps: VecDeque<Instant> = VecDeque::with_capacity(WINDOW);
    let mut last_version = 0u64;
    let stall_limit = Duration::from_secs(15);

    let mut reconnect = |stream: &mut TcpStream,
                         fb: &mut wire::FrameBuf,
                         stamps: &mut VecDeque<Instant>,
                         out: &mut ClientOut,
                         rng: &mut SplitMix64|
     -> bool {
        out.lost += stamps.len();
        stamps.clear();
        *fb = wire::FrameBuf::new();
        match connect_next(addrs, &mut which, rng, read_timeout) {
            Some(s) => {
                *stream = s;
                out.reconnects += 1;
                true
            }
            None => false,
        }
    };

    let mut next_at = timer::now();
    'send: for row in &share {
        if let Some(iv) = interval {
            let now = timer::now();
            if now < next_at {
                std::thread::sleep(next_at - now);
            }
            next_at += iv;
        }
        // Keep at most WINDOW requests in flight; a full window is the
        // one place the sender blocks on responses.
        let mut stalled_since = timer::now();
        while stamps.len() >= WINDOW {
            let before = stamps.len();
            let dead = !drain_responses(
                &mut stream,
                &mut fb,
                &mut buf,
                &mut stamps,
                &mut out,
                &mut last_version,
            );
            if (dead || stalled_since.elapsed() > stall_limit)
                && !reconnect(&mut stream, &mut fb, &mut stamps, &mut out, &mut rng)
            {
                return out;
            }
            if stamps.len() < before {
                stalled_since = timer::now();
            }
        }
        let payload = wire::encode_row(row);
        loop {
            match send_frame(&mut stream, wire::kind::ASSIGN_REQ, &payload) {
                Ok(_) => {
                    stamps.push_back(timer::now());
                    break;
                }
                Err(_) => {
                    if !reconnect(&mut stream, &mut fb, &mut stamps, &mut out, &mut rng) {
                        break 'send;
                    }
                }
            }
        }
    }
    // Drain the tail.
    let mut stalled_since = timer::now();
    while !stamps.is_empty() {
        let before = stamps.len();
        let ok = drain_responses(
            &mut stream,
            &mut fb,
            &mut buf,
            &mut stamps,
            &mut out,
            &mut last_version,
        );
        if !ok {
            out.lost += stamps.len();
            break;
        }
        if stamps.len() < before {
            stalled_since = timer::now();
        } else if stalled_since.elapsed() > stall_limit {
            out.lost += stamps.len();
            break;
        }
    }
    out
}

/// Drive the assign plane of the servers at `addrs` with
/// `spec.requests` rows cycled from `rows`: `spec.clients` threads,
/// each pipelining up to [`WINDOW`] requests on one connection
/// (round-robined over `addrs`), reconnecting to the next address on
/// connection death — the socket analogue of
/// [`run_open_loop`](crate::serve::run_open_loop), measured the same
/// way so the bench arms compare like for like.
pub fn run_rpc_loop(
    addrs: &[String],
    rows: &[Vec<Value>],
    spec: &LoadSpec,
) -> Result<RpcLoadReport> {
    ensure!(!addrs.is_empty(), "need at least one server address");
    ensure!(!rows.is_empty(), "need at least one request row");
    let clients = spec.clients.max(1);
    let total = spec.requests;
    let interval = spec.qps.map(|q| Duration::from_secs_f64(clients as f64 / q.max(1e-9)));
    let read_timeout = Duration::from_millis(20);

    let t0 = timer::now();
    let handles: Vec<JoinHandle<ClientOut>> = (0..clients)
        .map(|c| {
            let addrs = addrs.to_vec();
            let share: Vec<Vec<Value>> = (0..total / clients + usize::from(c < total % clients))
                .map(|i| rows[(c + i * clients) % rows.len()].clone())
                .collect();
            let seed = spec.seed ^ wire::u64_of(c).wrapping_mul(0x9e37_79b9_7f4a_7c15);
            std::thread::spawn(move || client_loop(c, &addrs, share, interval, seed, read_timeout))
        })
        .collect();

    let mut latencies = Vec::with_capacity(total);
    let mut versions: Vec<u64> = Vec::new();
    let (mut min_v, mut max_v) = (u64::MAX, 0u64);
    let mut monotonic = true;
    let (mut lost, mut reconnects) = (0usize, 0usize);
    for h in handles {
        let o = h.join().expect("rpc load client thread");
        monotonic &= o.monotonic;
        lost += o.lost;
        reconnects += o.reconnects;
        for (lat, v) in o.samples {
            latencies.push(lat);
            versions.push(v);
            min_v = min_v.min(v);
            max_v = max_v.max(v);
        }
    }
    let elapsed_s = t0.elapsed().as_secs_f64();
    latencies.sort_unstable();
    versions.sort_unstable();
    versions.dedup();
    let report = LoadReport {
        requests: latencies.len(),
        elapsed_s,
        qps: latencies.len() as f64 / elapsed_s.max(1e-12),
        p50_us: pct(&latencies, 0.50),
        p99_us: pct(&latencies, 0.99),
        min_version: if latencies.is_empty() { 0 } else { min_v },
        max_version: max_v,
        monotonic,
    };
    Ok(RpcLoadReport { report, versions, lost, reconnects })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::sparse_lloyd::CentroidCoord;
    use crate::metrics::Metrics;
    use crate::rkmeans::{ClusterOpts, RkPipeline, SubspaceOpts};
    use crate::serve::{synth_rows, AssignFront, FrontOpts, Publisher};
    use crate::synthetic::{retailer, Scale};
    use crate::util::exec::ExecPool;

    fn model(version: u64) -> RkModel {
        let db = retailer::generate(Scale::tiny(), 7);
        let feq = retailer::feq();
        let pipe = RkPipeline::plan(&db, &feq).expect("plan");
        let marginals = pipe.marginals().expect("marginals");
        let subspaces = pipe.subspaces(&marginals, &SubspaceOpts::new(4)).expect("subspaces");
        pipe.coreset(&subspaces)
            .expect("coreset")
            .cluster(&ClusterOpts::new(4))
            .with_version(version)
    }

    fn bump(base: &RkModel, version: u64) -> RkModel {
        let mut next = base.clone().with_version(version);
        match &mut next.centroids[0][0] {
            CentroidCoord::Continuous(mu) => *mu += 0.25 * version as f64,
            CentroidCoord::Categorical(beta) => beta[0] += 0.125 * version as f64,
        }
        next
    }

    #[test]
    fn backoff_is_seeded_deterministic_and_capped() {
        let mut a = SplitMix64::new(9);
        let mut b = SplitMix64::new(9);
        let da: Vec<Duration> = (1..=8).map(|i| backoff_delay(i, 20, 500, &mut a)).collect();
        let db: Vec<Duration> = (1..=8).map(|i| backoff_delay(i, 20, 500, &mut b)).collect();
        assert_eq!(da, db, "same seed, same schedule");
        for (i, d) in da.iter().enumerate() {
            let exp = (20u64 << i.min(16)).min(500);
            assert!(d.as_millis() <= u128::from(exp), "jitter only shrinks: {d:?} vs {exp}ms");
            assert!(d.as_millis() >= u128::from(exp / 2).max(1), "jitter floor: {d:?} vs {exp}ms");
        }
        let mut c = SplitMix64::new(10);
        assert_ne!(da, (1..=8).map(|i| backoff_delay(i, 20, 500, &mut c)).collect::<Vec<_>>());
    }

    #[test]
    fn rpc_tier_serves_probes_assigns_and_recovers_from_forced_gaps() {
        // Writer side: mesh + front + server with every 1st-of-2 deltas
        // dropped per subscriber (forces a genuine VersionGap).
        let v1 = model(1);
        let writer_metrics = Metrics::new();
        let writer_mesh = ModelMesh::new(v1.clone(), 2, writer_metrics.clone());
        let front =
            AssignFront::start(Arc::clone(&writer_mesh), FrontOpts::default(), ExecPool::new(2));
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
        let opts = RpcOpts { drop_every: 2, ..RpcOpts::default() };
        let server = RpcServer::start(
            listener,
            Arc::clone(&writer_mesh),
            front.client(),
            wire::ROLE_WRITER,
            opts,
        )
        .expect("start rpc server");
        let addr = server.local_addr().to_string();

        // Control plane: snapshot fetch is byte-identical to the model.
        let fetched = fetch_snapshot(&addr, Duration::from_secs(20)).expect("fetch snapshot");
        assert_eq!(fetched.to_bytes(), v1.to_bytes());
        let p = probe(&addr, Duration::from_secs(20)).expect("probe");
        assert_eq!((p.version, p.role, p.replicas), (1, wire::ROLE_WRITER, 2));

        // Replica side: own mesh seeded from the fetched snapshot.
        let replica_metrics = Metrics::new();
        let replica_mesh = ModelMesh::new(fetched, 1, replica_metrics.clone());
        let sync = ReplicaSync::start(
            addr.clone(),
            Arc::clone(&replica_mesh),
            SyncOpts { seed: 11, ..SyncOpts::default() },
        );

        // Wait until the subscription registers, then publish v2 (delta
        // dropped by fault injection) and v3 (delivered → VersionGap →
        // snapshot catch-up → rejoin).
        let t0 = timer::now();
        while server.subscriber_count() == 0 {
            assert!(t0.elapsed() < Duration::from_secs(20), "replica never subscribed");
            std::thread::sleep(Duration::from_millis(5));
        }
        let mut publisher = Publisher::new(Arc::clone(&writer_mesh));
        let v2 = bump(&v1, 2);
        let (_, wire2) = publisher.publish_wire(&v2).expect("publish v2");
        server.broadcast(&wire2);
        let v3 = bump(&v2, 3);
        let (_, wire3) = publisher.publish_wire(&v3).expect("publish v3");
        server.broadcast(&wire3);

        let t0 = timer::now();
        while replica_mesh.latest_version() < 3 {
            assert!(
                t0.elapsed() < Duration::from_secs(30),
                "replica stuck at version {} (gaps={}, catchups={})",
                replica_mesh.latest_version(),
                replica_metrics.counter("serve.rpc.gaps").get(),
                replica_metrics.counter("serve.rpc.catchups").get()
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(replica_mesh.model(0).to_bytes(), v3.to_bytes(), "catch-up is byte-exact");
        assert!(replica_metrics.counter("serve.rpc.gaps").get() >= 1, "fault injection fired");
        assert!(replica_metrics.counter("serve.rpc.catchups").get() >= 1);
        assert!(writer_metrics.counter("serve.rpc.dropped_deltas").get() >= 1);

        // Assign plane over the socket: every reply is a published
        // version and clusters are in range.
        let rows = synth_rows(&v1, 8, 13);
        let spec = LoadSpec { requests: 64, clients: 2, qps: None, seed: 5 };
        let out = run_rpc_loop(&[addr.clone()], &rows, &spec).expect("rpc load");
        assert_eq!(out.report.requests + out.lost, 64);
        assert!(out.report.monotonic);
        for v in &out.versions {
            assert!([1, 2, 3].contains(v), "unpublished version {v} served");
        }

        sync.shutdown();
        send_stop(&addr).expect("send stop");
        server.wait();
        front.shutdown();
    }
}
