//! Bench S1 — streaming maintenance: patched (Step-3 delta + Step-4 warm
//! start via the incremental planner) vs. full-pipeline rebuild per batch,
//! over a deterministic Retailer insert/delete trace
//! (`synthetic::retailer_trace`). Batch size is held ≤ 1 % of |D| — the
//! acceptance regime, where patched per-batch latency must beat the
//! rebuild by ≥ 5×. Both arms replay the *same* trace onto clones of the
//! same database; only the maintenance work is timed (the shared
//! apply-to-db mirroring is not). Results are written as one
//! `BENCH_stream.json` document (schema: see `bench_harness` docs; path
//! override: `RKMEANS_STREAM_OUT`).
//!
//! `--test` (or `--smoke`) shrinks everything for CI smoke runs.
//! `RKMEANS_STREAM_SCALE` overrides the Retailer scale (default 0.02 ≈
//! 40k fact rows).

use rkmeans::bench_harness::{write_bench_stream, StreamBenchRecord};
use rkmeans::incremental::{apply_to_db, IncrementalEngine, PlanDecision, PlannerOpts};
use rkmeans::metrics::Metrics;
use rkmeans::query::Hypergraph;
use rkmeans::rkmeans::{rkmeans_with_tree, RkConfig};
use rkmeans::synthetic::{retailer, retailer_trace, Scale, TraceSpec};
use std::path::PathBuf;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let test_mode = std::env::args().any(|a| a == "--test" || a == "--smoke");
    let scale: f64 = std::env::var("RKMEANS_STREAM_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if test_mode { 0.003 } else { 0.02 });
    let (k, batches) = if test_mode { (4usize, 3usize) } else { (8, 8) };

    let db = retailer::generate(Scale::custom(scale), 42);
    let feq = retailer::feq();
    let base_rows = db.total_rows() as usize;
    // The acceptance regime: batch ≤ 1 % of |D|.
    let batch = ((base_rows / 128).max(8)).min(base_rows / 100 + 8);
    let spec = TraceSpec { batches, batch_size: batch, delete_frac: 0.3 };
    let trace = retailer_trace(&db, 7, spec);
    let rk = RkConfig::new(k);
    println!(
        "stream workload: |D|={base_rows} rows (scale {scale}), batch={batch} \
         ({:.2}% of |D|) × {batches}, k={k}",
        100.0 * batch as f64 / base_rows as f64
    );

    // Arm 1: full rebuild per batch (the coordinator's old loop).
    let (rebuild_rec, rebuild_mass) = {
        let mut db = db.clone();
        let tree = Hypergraph::from_feq(&db, &feq).join_tree()?;
        let mut times = Vec::with_capacity(batches);
        let mut last = None;
        for b in &trace {
            apply_to_db(&mut db, b)?;
            let t0 = Instant::now();
            let res = rkmeans_with_tree(&db, &feq, &tree, &rk)?;
            times.push(t0.elapsed().as_secs_f64());
            last = Some(res);
        }
        let last = last.expect("at least one batch");
        (
            StreamBenchRecord::from_batches(
                "retailer-trace",
                "rebuild",
                base_rows,
                batch,
                &times,
                last.grid_points,
                last.objective_grid,
            ),
            last.grid_mass,
        )
    };
    println!("{}", rebuild_rec.line());

    // Arm 2: the incremental planner, forced onto the patch path.
    let (patched_rec, patched_mass, patched_all) = {
        let mut db = db.clone();
        let lenient = PlannerOpts {
            drift_threshold: 1.1,
            max_patch_fraction: 1.0,
            rebuild_every: 0,
            max_join_churn: f64::INFINITY,
        };
        // The initial full build is shared state both arms start from; it
        // is not part of the per-batch latency either way.
        let mut engine =
            IncrementalEngine::new(&db, feq.clone(), rk.clone(), lenient, Metrics::new())?;
        let mut times = Vec::with_capacity(batches);
        let mut all_patched = true;
        let mut last = None;
        for b in &trace {
            apply_to_db(&mut db, b)?;
            let t0 = Instant::now();
            let (decision, res) = engine.apply_batch(&db, b)?;
            times.push(t0.elapsed().as_secs_f64());
            all_patched &= decision == PlanDecision::Patched;
            last = Some(res);
        }
        let last = last.expect("at least one batch");
        (
            StreamBenchRecord::from_batches(
                "retailer-trace",
                "patched",
                base_rows,
                batch,
                &times,
                last.grid_points,
                last.objective_grid,
            )
            .with_speedup_vs(&rebuild_rec),
            last.grid_mass,
            all_patched,
        )
    };
    println!("{}", patched_rec.line());

    // Sanity: both arms end at the same join mass (|X| is Step-2-model
    // independent; grids can differ slightly because patching freezes the
    // Step-2 models while a rebuild re-solves them).
    anyhow::ensure!(patched_all, "planner rebuilt mid-trace; patched arm is not comparable");
    anyhow::ensure!(
        (patched_mass - rebuild_mass).abs() <= 1e-6 * rebuild_mass.abs().max(1.0),
        "final grid mass diverged: patched {patched_mass} vs rebuild {rebuild_mass}"
    );

    let speedup = patched_rec.speedup_vs_rebuild.unwrap_or(0.0);
    let records = vec![rebuild_rec, patched_rec];
    let out = PathBuf::from(
        std::env::var("RKMEANS_STREAM_OUT").unwrap_or_else(|_| "BENCH_stream.json".to_string()),
    );
    write_bench_stream(&out, &records)?;
    println!("wrote {} records to {}", records.len(), out.display());
    println!(
        "patched vs rebuild per-batch latency: {speedup:.2}× (acceptance target ≥ 5× at \
         batch ≤ 1% of |D|)"
    );
    Ok(())
}
