//! Functional aggregate queries (FAQ) over join trees.
//!
//! This is the paper's §2.1 substrate: every quantity Rk-means needs from
//! the unmaterialized join — the output size `|X|`, per-attribute marginal
//! weights `w_j` (Eq. 3), and the grid-coreset weights `w_grid` (Eq. 4) — is
//! a functional aggregate query, evaluated by variable elimination over the
//! FEQ's join tree (the InsideOut algorithm; for acyclic counting queries
//! this specializes to Yannakakis two-pass message passing).
//!
//! * [`factor`] — sparse factors: maps from variable tuples to semiring
//!   values.
//! * [`semiring`] — sum-product / max-product / min-plus aggregates, used
//!   both for counting and for MAX-style FEQ aggregates (the paper's example
//!   query computes `max(transactions.count)`).
//! * [`yannakakis`] — the two-pass engine: full-join tuple counts, `|X|`,
//!   and per-attribute marginals.
//! * [`gridweights`] — the free-variable upward pass computing sparse
//!   `w_grid` over centroid-id (gid) combinations without enumerating the
//!   cross-product grid.
//! * [`shard`] — value-hashed horizontal partitioning of the fact
//!   relation; per-shard grid tables merge by exact weight addition
//!   ([`GridTable::merge`]), putting Step 3 on the shared worker pool.

pub mod aggregate;
pub mod factor;
pub mod gridweights;
pub mod semiring;
pub mod shard;
pub mod yannakakis;

pub use aggregate::scalar_aggregate;
pub use factor::Factor;
pub use gridweights::{grid_weights, GidAssigner, GridTable};
pub use shard::{shard_databases, shard_of};
pub use semiring::Semiring;
pub use yannakakis::{full_join_counts, marginals, output_size, JoinCounts, Marginal};
