//! Mergeable per-attribute marginal sketches and the Step-2 drift trigger.
//!
//! The planner keeps Step-2 gid maps **stable** across patches (stable
//! maps are what makes the Step-3 delta exact), so it needs a cheap signal
//! for *when* a subspace's distribution has moved enough that the frozen
//! per-subspace clustering is stale. Exact join marginals would require a
//! downward delta pass; instead each feature gets a sketch of its owning
//! relation's **base** marginal. This is a heuristic, not a bound: a
//! join marginal usually moves with its base marginal, but a shift in
//! join-*key* fanout (new fact tuples landing on previously-light
//! dimension rows) moves join marginals while every base sketch stays
//! put. That blind spot is covered by the planner's join-churn backstop
//! ([`super::PlannerOpts::max_join_churn`]), which watches the exact
//! Σ|Δweight| the Step-3 delta reports at the grid root.
//!
//! * categorical / integer features: an exact counting multiset
//!   (key → weight), deletions subtract;
//! * continuous features: a sorted-run summary — deltas buffer, runs are
//!   compacted by merging, so updates are O(1) amortized and reads are a
//!   k-way merge;
//! * both are **mergeable** (shard sketches combine associatively), the
//!   property streaming/partitioned ingest needs.
//!
//! Drift is measured between the current sketch and the baseline captured
//! at the last Step-2 solve: total-variation distance for categorical
//! features, range-normalized 1-Wasserstein (area between CDFs) for
//! continuous ones — both in `[0, 1]`, compared against a single
//! configurable threshold.

use crate::data::{AttrType, Database, Value};
use crate::query::Feq;
use crate::util::det;
use crate::util::FxHashMap;
use anyhow::{Context, Result};

use super::TupleDelta;

/// Buffered continuous deltas before a compaction.
const COMPACT_BUFFER: usize = 1024;
/// Sorted runs kept before a full merge.
const MAX_RUNS: usize = 6;

/// Exact counting multiset over discrete keys.
#[derive(Clone, Debug, Default)]
pub struct CatSketch {
    counts: FxHashMap<u64, f64>,
    total: f64,
    /// Σ|w| of updates since the last [`CatSketch::reset_changed`].
    changed: f64,
}

impl CatSketch {
    /// Add (or, with negative `w`, retract) weight for a key.
    pub fn update(&mut self, key: u64, w: f64) {
        let v = self.counts.entry(key).or_insert(0.0);
        *v += w;
        if *v == 0.0 {
            self.counts.remove(&key);
        }
        self.total += w;
        self.changed += w.abs();
    }

    /// Total mass.
    pub fn total(&self) -> f64 {
        self.total
    }

    /// Total |weight| updated since the last baseline capture.
    pub fn changed(&self) -> f64 {
        self.changed
    }

    /// Mark the current state as the drift reference point.
    pub fn reset_changed(&mut self) {
        self.changed = 0.0;
    }

    /// Merge another sketch in (mergeability). Sorted key order keeps
    /// the drift accumulator's FP sum content-determined.
    pub fn merge(&mut self, other: &CatSketch) {
        for (&k, &w) in det::sorted_entries(&other.counts) {
            self.update(k, w);
        }
    }

    /// Total-variation distance `½·Σ|p − q|` between the normalized
    /// distributions, in `[0, 1]`.
    pub fn tv_distance(&self, other: &CatSketch) -> f64 {
        if self.total <= 0.0 || other.total <= 0.0 {
            return if self.total == other.total { 0.0 } else { 1.0 };
        }
        let mut acc = 0.0;
        // Sorted key order on both passes: the TV sum feeds the drift
        // threshold, so its bits should not depend on insertion history.
        for (k, &w) in det::sorted_entries(&self.counts) {
            let q = other.counts.get(k).copied().unwrap_or(0.0);
            acc += (w / self.total - q / other.total).abs();
        }
        for (k, &q) in det::sorted_entries(&other.counts) {
            if !self.counts.contains_key(k) {
                acc += (q / other.total).abs();
            }
        }
        (0.5 * acc).min(1.0)
    }
}

/// Sorted-run summary of a continuous marginal.
#[derive(Clone, Debug, Default)]
pub struct ContSketch {
    /// Sorted `(value, weight)` runs (weights may be negative mid-stream;
    /// retraction cancels on collapse).
    runs: Vec<Vec<(f64, f64)>>,
    buffer: Vec<(f64, f64)>,
    total: f64,
    /// Σ|w| of updates since the last [`ContSketch::reset_changed`].
    changed: f64,
}

impl ContSketch {
    /// Add (or retract) weight at a value.
    pub fn update(&mut self, value: f64, w: f64) {
        self.buffer.push((value, w));
        self.total += w;
        self.changed += w.abs();
        if self.buffer.len() >= COMPACT_BUFFER {
            self.compact();
        }
    }

    /// Total mass.
    pub fn total(&self) -> f64 {
        self.total
    }

    /// Total |weight| updated since the last baseline capture.
    pub fn changed(&self) -> f64 {
        self.changed
    }

    /// Mark the current state as the drift reference point.
    pub fn reset_changed(&mut self) {
        self.changed = 0.0;
    }

    fn compact(&mut self) {
        if self.buffer.is_empty() {
            return;
        }
        let mut run = std::mem::take(&mut self.buffer);
        run.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("finite feature values"));
        self.runs.push(coalesce(run));
        if self.runs.len() > MAX_RUNS {
            let all = std::mem::take(&mut self.runs);
            self.runs.push(merge_runs(all));
        }
    }

    /// Merge another sketch in (mergeability). Counts toward `changed`,
    /// keeping the drift upper bound conservative.
    pub fn merge(&mut self, other: &ContSketch) {
        for run in &other.runs {
            for &(v, w) in run {
                self.buffer.push((v, w));
                self.total += w;
                self.changed += w.abs();
            }
        }
        for &(v, w) in &other.buffer {
            self.buffer.push((v, w));
            self.total += w;
            self.changed += w.abs();
        }
        if self.buffer.len() >= COMPACT_BUFFER {
            self.compact();
        }
    }

    /// Fully merged `(value, weight)` pairs, ascending, zero and negative
    /// residues dropped.
    pub fn collapsed(&self) -> Vec<(f64, f64)> {
        let mut all: Vec<Vec<(f64, f64)>> = self.runs.clone();
        if !self.buffer.is_empty() {
            let mut b = self.buffer.clone();
            b.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("finite feature values"));
            all.push(b);
        }
        merge_runs(all).into_iter().filter(|&(_, w)| w > 0.0).collect()
    }

    /// Range-normalized 1-Wasserstein distance between the normalized
    /// distributions: `∫|F_p − F_q| / span`, in `[0, 1]`.
    pub fn w1_distance(&self, other: &ContSketch) -> f64 {
        let a = self.collapsed();
        let b = other.collapsed();
        let ta: f64 = a.iter().map(|(_, w)| w).sum();
        let tb: f64 = b.iter().map(|(_, w)| w).sum();
        if ta <= 0.0 || tb <= 0.0 {
            return if ta == tb { 0.0 } else { 1.0 };
        }
        let lo = match (a.first(), b.first()) {
            (Some(x), Some(y)) => x.0.min(y.0),
            _ => return 1.0,
        };
        let hi = match (a.last(), b.last()) {
            (Some(x), Some(y)) => x.0.max(y.0),
            _ => return 1.0,
        };
        let span = hi - lo;
        if span <= 0.0 {
            return 0.0; // both concentrated on the same single point
        }
        // Walk the merged value axis accumulating |F_a − F_b|·gap.
        let (mut i, mut j) = (0usize, 0usize);
        let (mut ca, mut cb) = (0.0f64, 0.0f64);
        let mut prev = lo;
        let mut area = 0.0f64;
        while i < a.len() || j < b.len() {
            let va = a.get(i).map(|p| p.0).unwrap_or(f64::INFINITY);
            let vb = b.get(j).map(|p| p.0).unwrap_or(f64::INFINITY);
            let v = va.min(vb);
            area += (ca / ta - cb / tb).abs() * (v - prev);
            prev = v;
            if va <= vb {
                ca += a[i].1;
                i += 1;
            }
            if vb <= va {
                cb += b[j].1;
                j += 1;
            }
        }
        (area / span).min(1.0)
    }
}

/// Sum weights of equal consecutive values in a sorted run.
fn coalesce(run: Vec<(f64, f64)>) -> Vec<(f64, f64)> {
    let mut out: Vec<(f64, f64)> = Vec::with_capacity(run.len());
    for (v, w) in run {
        match out.last_mut() {
            Some(last) if last.0 == v => last.1 += w,
            _ => out.push((v, w)),
        }
    }
    out.retain(|&(_, w)| w != 0.0);
    out
}

/// K-way merge of sorted runs into one coalesced run.
fn merge_runs(mut runs: Vec<Vec<(f64, f64)>>) -> Vec<(f64, f64)> {
    match runs.len() {
        0 => Vec::new(),
        1 => coalesce(runs.pop().expect("one run")),
        _ => {
            // Simple pairwise fold — run counts are tiny (≤ MAX_RUNS + 1).
            let mut acc = runs.pop().expect("non-empty");
            while let Some(run) = runs.pop() {
                let mut merged = Vec::with_capacity(acc.len() + run.len());
                let (mut i, mut j) = (0usize, 0usize);
                while i < acc.len() || j < run.len() {
                    let va = acc.get(i).map(|p| p.0).unwrap_or(f64::INFINITY);
                    let vb = run.get(j).map(|p| p.0).unwrap_or(f64::INFINITY);
                    if va <= vb {
                        merged.push(acc[i]);
                        i += 1;
                    } else {
                        merged.push(run[j]);
                        j += 1;
                    }
                }
                acc = merged;
            }
            coalesce(acc)
        }
    }
}

/// One tracked feature's sketch pair (current vs. Step-2 baseline).
#[derive(Clone, Debug)]
enum Sketch {
    Cat { current: CatSketch, baseline: CatSketch },
    Cont { current: ContSketch, baseline: ContSketch },
}

impl Sketch {
    fn drift(&self) -> f64 {
        match self {
            Sketch::Cat { current, baseline } => current.tv_distance(baseline),
            Sketch::Cont { current, baseline } => current.w1_distance(baseline),
        }
    }

    /// Cheap upper bound on [`Sketch::drift`], O(1): if `D = Σ|w|` of
    /// updates since the baseline and `T` is the current mass, both TV
    /// and the CDF sup-distance (hence normalized W₁) are ≤ `D / T`. The
    /// tracker skips the exact O(support) distance while this bound is
    /// under the threshold, keeping small-batch drift checks O(batch).
    fn drift_bound(&self) -> f64 {
        let (changed, total) = match self {
            Sketch::Cat { current, .. } => (current.changed(), current.total()),
            Sketch::Cont { current, .. } => (current.changed(), current.total()),
        };
        if changed == 0.0 {
            0.0
        } else if total > 0.0 {
            changed / total
        } else {
            f64::INFINITY
        }
    }

    fn rebaseline(&mut self) {
        match self {
            Sketch::Cat { current, baseline } => {
                current.reset_changed();
                *baseline = current.clone();
            }
            Sketch::Cont { current, baseline } => {
                current.reset_changed();
                *baseline = current.clone();
            }
        }
    }
}

/// Per-feature marginal sketches with the drift trigger (see module docs).
#[derive(Clone, Debug)]
pub struct MarginalTracker {
    /// (feature name, owning relation, column index) per tracked feature.
    feats: Vec<(String, String, usize)>,
    sketches: Vec<Sketch>,
}

impl MarginalTracker {
    /// Seed sketches from the current base relations (one pass over each
    /// feature's owning relation) and capture them as the baseline.
    pub fn new(db: &Database, feq: &Feq) -> Result<MarginalTracker> {
        let mut feats = Vec::with_capacity(feq.features.len());
        let mut sketches = Vec::with_capacity(feq.features.len());
        for f in &feq.features {
            let owner = feq
                .owner_of(db, &f.attr)
                .with_context(|| format!("feature {:?} has no owner", f.attr))?;
            let rel = db.get(&feq.relations[owner]).expect("owner exists");
            let col = rel.schema.index_of(&f.attr).expect("owner contains attr");
            let sketch = match rel.schema.attr(col).ty {
                AttrType::Double | AttrType::Int => {
                    let mut s = ContSketch::default();
                    for row in 0..rel.n_rows() {
                        let w = rel.weight(row);
                        if w != 0.0 {
                            s.update(rel.value(row, col).as_f64(), w);
                        }
                    }
                    s.reset_changed(); // seeding IS the baseline
                    Sketch::Cont { baseline: s.clone(), current: s }
                }
                AttrType::Cat => {
                    let mut s = CatSketch::default();
                    for row in 0..rel.n_rows() {
                        let w = rel.weight(row);
                        if w != 0.0 {
                            s.update(rel.col(col).key_u64(row), w);
                        }
                    }
                    s.reset_changed();
                    Sketch::Cat { baseline: s.clone(), current: s }
                }
            };
            feats.push((f.attr.clone(), rel.name.clone(), col));
            sketches.push(sketch);
        }
        Ok(MarginalTracker { feats, sketches })
    }

    /// Feed one tuple delta into every sketch of a feature the delta's
    /// relation owns. Malformed deltas are ignored here — validation is
    /// the Step-3 engine's job.
    pub fn apply(&mut self, delta: &TupleDelta) {
        for ((_, rel, col), sketch) in self.feats.iter().zip(self.sketches.iter_mut()) {
            if rel != &delta.relation || *col >= delta.values.len() {
                continue;
            }
            let v = delta.values[*col];
            match sketch {
                Sketch::Cont { current, .. } => current.update(v.as_f64(), delta.weight),
                Sketch::Cat { current, .. } => match v {
                    Value::Double(_) => {}
                    other => current.update(other.key_u64(), delta.weight),
                },
            }
        }
    }

    /// Largest per-feature drift and the feature carrying it.
    pub fn max_drift(&self) -> Option<(&str, f64)> {
        self.feats
            .iter()
            .zip(&self.sketches)
            .map(|((name, _, _), s)| (name.as_str(), s.drift()))
            .max_by(|a, b| a.1.partial_cmp(&b.1).expect("drift is finite"))
    }

    /// Features whose drift exceeds `threshold`, with their drifts. The
    /// exact O(support) distance is only computed for features whose
    /// cheap mass-change bound ([`Sketch::drift_bound`]) crosses the
    /// threshold, so steady-state small batches cost O(1) per feature.
    pub fn drifted(&self, threshold: f64) -> Vec<(String, f64)> {
        self.feats
            .iter()
            .zip(&self.sketches)
            .filter_map(|((name, _, _), s)| {
                if s.drift_bound() <= threshold {
                    return None;
                }
                let d = s.drift();
                (d > threshold).then(|| (name.clone(), d))
            })
            .collect()
    }

    /// Capture the current sketches as the new baseline (called after a
    /// Step-2 re-solve).
    pub fn rebaseline(&mut self) {
        for s in &mut self.sketches {
            s.rebaseline();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::assert_close;
    use crate::util::SplitMix64;

    #[test]
    fn cat_sketch_counts_and_tv() {
        let mut a = CatSketch::default();
        let mut b = CatSketch::default();
        for k in 0..4u64 {
            a.update(k, 1.0);
            b.update(k, 1.0);
        }
        assert_eq!(a.tv_distance(&b), 0.0);
        // Move half the mass of key 0 to key 9.
        b.update(0, -1.0);
        b.update(9, 1.0);
        assert_close(a.tv_distance(&b), 0.25, 1e-12);
        // Retraction to zero removes the key entirely.
        let mut c = CatSketch::default();
        c.update(5, 2.0);
        c.update(5, -2.0);
        assert_eq!(c.total(), 0.0);
        assert_eq!(c.tv_distance(&CatSketch::default()), 0.0);
    }

    #[test]
    fn cont_sketch_collapse_survives_compaction() {
        let mut s = ContSketch::default();
        let mut rng = SplitMix64::new(3);
        let mut expect: FxHashMap<u64, f64> = FxHashMap::default();
        for _ in 0..(COMPACT_BUFFER * 3 + 17) {
            let v = (rng.below(50) as f64) * 0.5;
            s.update(v, 1.0);
            *expect.entry(v.to_bits()).or_insert(0.0) += 1.0;
        }
        let collapsed = s.collapsed();
        assert_eq!(collapsed.len(), expect.len());
        for (v, w) in collapsed {
            assert_close(expect[&v.to_bits()], w, 1e-9);
        }
        // Values ascend.
        let c = s.collapsed();
        assert!(c.windows(2).all(|p| p[0].0 < p[1].0));
    }

    #[test]
    fn w1_distance_tracks_shift() {
        let mk = |offset: f64| {
            let mut s = ContSketch::default();
            for i in 0..100 {
                s.update(i as f64 + offset, 1.0);
            }
            s
        };
        let base = mk(0.0);
        assert_eq!(base.w1_distance(&base), 0.0);
        let small = base.w1_distance(&mk(1.0));
        let large = base.w1_distance(&mk(30.0));
        assert!(small > 0.0 && small < large, "small {small} large {large}");
        assert!(large <= 1.0);
    }

    #[test]
    fn sketches_are_mergeable() {
        let mut rng = SplitMix64::new(9);
        let mut whole_c = CatSketch::default();
        let (mut sa, mut sb) = (CatSketch::default(), CatSketch::default());
        let mut whole_x = ContSketch::default();
        let (mut xa, mut xb) = (ContSketch::default(), ContSketch::default());
        for i in 0..500 {
            let k = rng.below(12);
            let v = rng.below(40) as f64 * 0.25;
            whole_c.update(k, 1.0);
            whole_x.update(v, 1.0);
            if i % 2 == 0 {
                sa.update(k, 1.0);
                xa.update(v, 1.0);
            } else {
                sb.update(k, 1.0);
                xb.update(v, 1.0);
            }
        }
        sa.merge(&sb);
        xa.merge(&xb);
        assert_eq!(sa.tv_distance(&whole_c), 0.0);
        assert_close(xa.w1_distance(&whole_x), 0.0, 1e-12);
        assert_close(xa.total(), whole_x.total(), 1e-9);
    }

    #[test]
    fn tracker_triggers_on_drift_and_rebaselines() {
        use crate::data::{Attr, Relation, Schema};
        let mut fact = Relation::new(
            "fact",
            Schema::new(vec![Attr::cat("c", 8), Attr::double("x")]),
        );
        for i in 0..40u32 {
            fact.push_row(&[Value::Cat(i % 4), Value::Double((i % 10) as f64)]);
        }
        let mut db = Database::new();
        db.add(fact);
        let feq = Feq::with_features(&["fact"], &["c", "x"]);
        let mut tracker = MarginalTracker::new(&db, &feq).unwrap();
        assert!(tracker.drifted(0.01).is_empty());

        // Pour mass onto a brand-new category and a far-away value.
        for _ in 0..60 {
            tracker.apply(&TupleDelta::insert(
                "fact",
                vec![Value::Cat(7), Value::Double(500.0)],
            ));
        }
        let drifted = tracker.drifted(0.2);
        assert!(
            drifted.iter().any(|(n, _)| n == "c"),
            "categorical drift not detected: {drifted:?}"
        );
        let (_, dmax) = tracker.max_drift().unwrap();
        assert!(dmax > 0.2);

        tracker.rebaseline();
        assert!(tracker.drifted(0.01).is_empty());
    }
}
