//! The request-batching assign front.
//!
//! Concurrent callers hand single tuples to [`AssignClient::assign`] /
//! [`AssignClient::submit`]; a dedicated dispatcher thread drains the
//! shared queue into **micro-batches** (first request blocks, the rest
//! of the batch is whatever has queued up, capped at
//! [`FrontOpts::max_batch`]) and fans each batch out over the shared
//! [`ExecPool`] — so per-request cost amortizes the pool handshake and
//! the k·m assign kernels of a batch run in parallel, instead of one
//! thread grinding one request at a time. Under light load a batch is a
//! single request and the serial fast path answers it with no dispatch;
//! under heavy load batches grow toward the cap and throughput scales
//! with cores. `benches/serve_load.rs` gates the batched-vs-naive ratio.
//! The socket tier ([`crate::serve::rpc`]) feeds this same front: each
//! connection handler submits decoded rows through an [`AssignClient`],
//! so remote callers get the identical batching, version discipline,
//! and latency accounting as in-process ones.
//!
//! **Version discipline.** Each batch pins one replica
//! ([`ModelMesh::model`], round-robin) and the dispatcher only moves its
//! served version *forward*: a replica slot that has not been swapped
//! yet is skipped in favor of the version floor, so the stream of
//! [`Assignment::version`] tags is monotone across all clients even
//! while the publisher is mid-install. Every reply carries the version
//! that served it plus its measured queue+compute latency, which also
//! feeds the `serve.assign_us` histogram (p50/p99 in
//! [`Metrics::snapshot`](crate::metrics::Metrics::snapshot)).

use crate::data::Value;
use crate::serve::ModelMesh;
use crate::util::exec::ExecPool;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// Tuning knobs for the front.
#[derive(Clone, Copy, Debug)]
pub struct FrontOpts {
    /// Micro-batch cap: how many queued requests one dispatch may drain.
    pub max_batch: usize,
    /// Pool workers per batch dispatch (0 = the whole pool).
    pub threads: usize,
}

impl Default for FrontOpts {
    fn default() -> FrontOpts {
        FrontOpts { max_batch: 64, threads: 0 }
    }
}

/// One answered assign request.
#[derive(Clone, Copy, Debug)]
pub struct Assignment {
    /// Nearest-centroid cluster id.
    pub cluster: usize,
    /// Model version that served the request (monotone per client).
    pub version: u64,
    /// Queue + compute latency observed by the dispatcher, µs.
    pub latency_us: u64,
}

struct Request {
    row: Vec<Value>,
    t0: Instant,
    reply: Sender<Assignment>,
}

/// A cloneable submission handle (one per client thread —
/// [`Sender`] is `Send` but not `Sync`).
#[derive(Clone)]
pub struct AssignClient {
    tx: Sender<Request>,
}

impl AssignClient {
    /// Enqueue a request without waiting (open-loop callers); the
    /// returned channel yields the [`Assignment`] when its batch
    /// completes. Panics if the front has shut down.
    pub fn submit(&self, row: Vec<Value>) -> Receiver<Assignment> {
        let (rtx, rrx) = channel();
        self.tx
            .send(Request { row, t0: crate::util::timer::now(), reply: rtx })
            .expect("assign front is running");
        rrx
    }

    /// Enqueue a request and block for its answer (closed-loop callers).
    pub fn assign(&self, row: Vec<Value>) -> Assignment {
        self.submit(row).recv().expect("assign front replies")
    }
}

/// The micro-batching front over a [`ModelMesh`] (see module docs).
/// Dropping it (or calling [`AssignFront::shutdown`]) drains the queue
/// and joins the dispatcher.
pub struct AssignFront {
    tx: Option<Sender<Request>>,
    dispatcher: Option<JoinHandle<()>>,
}

impl AssignFront {
    /// Start the dispatcher thread serving `mesh` with batches run on
    /// `pool` (pass [`shared_pool`](crate::util::exec::shared_pool) for
    /// the process-wide workers).
    pub fn start(mesh: Arc<ModelMesh>, opts: FrontOpts, pool: Arc<ExecPool>) -> AssignFront {
        let (tx, rx) = channel::<Request>();
        let max_batch = opts.max_batch.max(1);
        let dispatcher = std::thread::Builder::new()
            .name("rk-serve-front".to_string())
            .spawn(move || dispatch_loop(&mesh, &pool, rx, max_batch, opts.threads))
            .expect("spawn assign dispatcher");
        AssignFront { tx: Some(tx), dispatcher: Some(dispatcher) }
    }

    /// A new submission handle; clone one per client thread.
    pub fn client(&self) -> AssignClient {
        AssignClient { tx: self.tx.clone().expect("front is running") }
    }

    /// Stop accepting requests, answer everything already queued, and
    /// join the dispatcher.
    pub fn shutdown(mut self) {
        self.close();
    }

    fn close(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

impl Drop for AssignFront {
    fn drop(&mut self) {
        self.close();
    }
}

/// Dispatcher body: drain → pin replica (version floor) → batch-assign
/// on the pool → reply. Exits when every client handle is gone.
fn dispatch_loop(
    mesh: &ModelMesh,
    pool: &ExecPool,
    rx: Receiver<Request>,
    max_batch: usize,
    threads: usize,
) {
    let metrics = mesh.metrics();
    let requests = metrics.counter("serve.requests");
    let batches = metrics.counter("serve.batches");
    let assign_us = metrics.histogram("serve.assign_us");
    let batch_size = metrics.histogram("serve.batch_size");

    let mut rr = 0usize;
    let mut served = mesh.model(0);
    while let Ok(first) = rx.recv() {
        let mut batch: Vec<(Request, usize)> = vec![(first, 0)];
        while batch.len() < max_batch {
            match rx.try_recv() {
                Ok(req) => batch.push((req, 0)),
                Err(_) => break,
            }
        }

        // Round-robin over replicas, never moving the served version
        // backwards (slots can disagree mid-install).
        rr = (rr + 1) % mesh.replicas();
        let candidate = mesh.model(rr);
        if candidate.version >= served.version {
            served = candidate;
        }
        let model = &served;
        pool.run_chunks(&mut batch, threads, |_, w| w.1 = model.assign(&w.0.row));

        batches.inc();
        batch_size.observe(batch.len() as u64);
        let version = model.version;
        for (req, cluster) in batch {
            let latency_us = req.t0.elapsed().as_micros() as u64;
            assign_us.observe(latency_us);
            requests.inc();
            // A client that gave up on its receiver is not an error.
            let _ = req.reply.send(Assignment { cluster, version, latency_us });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Metrics;
    use crate::rkmeans::RkModel;
    use crate::util::exec::ExecPool;

    fn tiny_model(version: u64) -> RkModel {
        use crate::cluster::kmeans1d;
        use crate::cluster::sparse_lloyd::CentroidCoord;
        use crate::coreset::{SubspaceModel, SubspaceSolver};
        let solver = kmeans1d(&[(0.0, 1.0), (10.0, 1.0)], 2);
        RkModel::from_result(&crate::rkmeans::RkResult {
            centroids: vec![
                vec![CentroidCoord::Continuous(0.0)],
                vec![CentroidCoord::Continuous(10.0)],
            ],
            models: vec![SubspaceModel {
                name: "x".to_string(),
                lambda: 1.0,
                cost: solver.cost,
                solver: SubspaceSolver::Continuous(solver),
            }],
            objective_grid: 0.0,
            quantization_cost: 0.0,
            grid_points: 2,
            grid_mass: 2.0,
            iters: 1,
            timings: Default::default(),
            step4_stats: Default::default(),
        })
        .with_version(version)
    }

    #[test]
    fn batched_assign_answers_correctly() {
        let metrics = Metrics::new();
        let mesh = ModelMesh::new(tiny_model(1), 2, metrics.clone());
        let front =
            AssignFront::start(Arc::clone(&mesh), FrontOpts::default(), ExecPool::new(2));
        let client = front.client();
        // Open-loop burst so the dispatcher actually forms batches.
        let pending: Vec<_> = (0..200)
            .map(|i| {
                let x = if i % 2 == 0 { 0.5 } else { 9.5 };
                (i, client.submit(vec![Value::Double(x)]))
            })
            .collect();
        for (i, rx) in pending {
            let a = rx.recv().expect("reply");
            assert_eq!(a.cluster, if i % 2 == 0 { 0 } else { 1 });
            assert_eq!(a.version, 1);
        }
        front.shutdown();
        assert_eq!(metrics.counter("serve.requests").get(), 200);
        let batches = metrics.counter("serve.batches").get();
        assert!((1..=200).contains(&batches));
        assert_eq!(metrics.histogram("serve.assign_us").count(), 200);
    }

    #[test]
    fn shutdown_drains_queued_requests() {
        let mesh = ModelMesh::new(tiny_model(3), 1, Metrics::new());
        let front = AssignFront::start(mesh, FrontOpts::default(), ExecPool::new(1));
        let client = front.client();
        let pending: Vec<_> = (0..32).map(|_| client.submit(vec![Value::Double(1.0)])).collect();
        drop(client);
        front.shutdown();
        for rx in pending {
            assert_eq!(rx.recv().expect("drained before shutdown").version, 3);
        }
    }
}
