//! Hot-swap concurrency contract of the serving tier: reader threads
//! assign through the batching front while a publisher swaps new
//! versions into every replica slot in a loop. Three assertions:
//!
//! * **consistency** — every reply's (cluster, version) pair matches
//!   what the published model of exactly that version answers for that
//!   row, so no reply can ever come off a torn half-swapped model;
//! * **membership** — every served version is one that was actually
//!   published;
//! * **monotonicity** — each reader's version sequence never goes
//!   backwards, even as micro-batches interleave with swaps.
//!
//! The CI matrix runs this under `--release` too (`cargo test` after
//! the release build), where torn reads would actually bite.

use rkmeans::cluster::sparse_lloyd::CentroidCoord;
use rkmeans::metrics::Metrics;
use rkmeans::rkmeans::{ClusterOpts, RkModel, RkPipeline, SubspaceOpts};
use rkmeans::serve::{synth_rows, AssignFront, FrontOpts, ModelDelta, ModelMesh, Publisher};
use rkmeans::synthetic::{retailer, Scale};
use rkmeans::util::exec::shared_pool;
use std::sync::Arc;
use std::time::Duration;

const VERSIONS: u64 = 6;

/// Version `v`'s model: the base clustering with every centroid row
/// nudged by a version-dependent amount, round-tripped through the wire
/// format so the serving caches are rebuilt from the mutated values.
fn published_model(base: &RkModel, v: u64) -> RkModel {
    let mut m = base.clone().with_version(v);
    for (i, row) in m.centroids.iter_mut().enumerate() {
        match &mut row[0] {
            CentroidCoord::Continuous(mu) => *mu += v as f64 * 0.35 + i as f64 * 0.05,
            CentroidCoord::Categorical(beta) => beta[0] += v as f64 * 0.01,
        }
    }
    RkModel::from_bytes(&m.to_bytes()).expect("wire round-trip")
}

#[test]
fn hot_swap_readers_always_see_a_published_model() {
    let db = retailer::generate(Scale::tiny(), 42);
    let feq = retailer::feq();
    let pipe = RkPipeline::plan(&db, &feq).unwrap();
    let marginals = pipe.marginals().unwrap();
    let subspaces = pipe.subspaces(&marginals, &SubspaceOpts::new(4)).unwrap();
    let base = pipe.coreset(&subspaces).unwrap().cluster(&ClusterOpts::new(4));

    let versions: Vec<RkModel> = (1..=VERSIONS).map(|v| published_model(&base, v)).collect();
    let rows = synth_rows(&versions[0], 32, 9);
    // expected[v - 1][r]: what version v's model answers for row r.
    let expected: Vec<Vec<usize>> =
        versions.iter().map(|m| rows.iter().map(|r| m.assign(r)).collect()).collect();

    let mesh = ModelMesh::new(versions[0].clone(), 3, Metrics::new());
    let front = AssignFront::start(Arc::clone(&mesh), FrontOpts::default(), shared_pool());

    // The publisher: swap in versions 2..=N while readers are live.
    let publisher_mesh = Arc::clone(&mesh);
    let to_publish: Vec<RkModel> = versions[1..].to_vec();
    let publisher = std::thread::spawn(move || {
        let mut p = Publisher::new(publisher_mesh);
        for m in &to_publish {
            std::thread::sleep(Duration::from_millis(2));
            p.publish(m).expect("publish");
        }
    });

    // Readers: blocking assigns racing the swaps, each reply checked
    // against the model of the version it claims to have been served by.
    let readers: Vec<_> = (0..3)
        .map(|c| {
            let client = front.client();
            let rows = rows.clone();
            let expected = expected.clone();
            std::thread::spawn(move || {
                let mut last = 0u64;
                for i in 0..300usize {
                    let idx = (c + i * 3) % rows.len();
                    let a = client.assign(rows[idx].clone());
                    assert!(
                        (1..=VERSIONS).contains(&a.version),
                        "served version {} was never published",
                        a.version
                    );
                    assert!(a.version >= last, "reader saw v{} after v{last}", a.version);
                    last = a.version;
                    assert_eq!(
                        a.cluster,
                        expected[(a.version - 1) as usize][idx],
                        "reply inconsistent with the version-{} model (row {idx})",
                        a.version
                    );
                }
            })
        })
        .collect();

    for r in readers {
        r.join().expect("reader thread");
    }
    publisher.join().expect("publisher thread");
    front.shutdown();

    assert_eq!(mesh.latest_version(), VERSIONS, "every version was published");
    // Every replica slot ended bit-identical to the final published model.
    for slot in 0..3 {
        assert_eq!(mesh.model(slot).to_bytes(), versions.last().unwrap().to_bytes());
    }
}

/// `Publisher::publish_wire` hands back the exact delta bytes it
/// shipped to the mesh — the same buffer the rpc tier broadcasts to
/// replica processes — so a subscriber that applies them lands
/// bit-identically on what the mesh now serves.
#[test]
fn publish_wire_returns_the_exact_broadcast_delta_bytes() {
    let db = retailer::generate(Scale::tiny(), 42);
    let feq = retailer::feq();
    let pipe = RkPipeline::plan(&db, &feq).unwrap();
    let marginals = pipe.marginals().unwrap();
    let subspaces = pipe.subspaces(&marginals, &SubspaceOpts::new(4)).unwrap();
    let base = pipe.coreset(&subspaces).unwrap().cluster(&ClusterOpts::new(4));
    let v1 = published_model(&base, 1);
    let v2 = published_model(&base, 2);

    let mesh = ModelMesh::new(v1.clone(), 2, Metrics::new());
    let mut publisher = Publisher::new(Arc::clone(&mesh));
    let (stats, wire) = publisher.publish_wire(&v2).expect("publish");
    assert_eq!(stats.version, 2);
    assert_eq!(wire.len(), stats.delta_bytes, "stats must describe the returned buffer");

    // publish() is publish_wire() minus the buffer: same stats story.
    let decoded = ModelDelta::from_bytes(&wire).expect("broadcast bytes decode");
    assert_eq!(decoded.to_version, 2);
    let applied = v1.apply_delta(&decoded).expect("subscriber-side apply");
    assert_eq!(
        applied.to_bytes(),
        mesh.model(0).to_bytes(),
        "applying the broadcast delta must land on the served bytes"
    );
    assert_eq!(mesh.latest_version(), 2);
}
