//! Bench A2 — Step-4 ablation (paper §4.3): the factored sparse Lloyd
//! (O((|G|+D)·k·m·t)) vs generic dense Lloyd over the one-hot-embedded
//! grid (O(|G|·D·k·t)), per dataset. The gap grows with the total
//! categorical domain size D.

use rkmeans::bench_harness::paper::{ablation_sparse, PaperCfg};
use rkmeans::synthetic::Dataset;

fn main() -> anyhow::Result<()> {
    let scale: f64 =
        std::env::var("RKMEANS_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.05);
    let cfg = PaperCfg::new(scale);
    for ds in Dataset::all() {
        println!("{}", ablation_sparse(ds, 10, &cfg)?.render());
    }
    Ok(())
}
