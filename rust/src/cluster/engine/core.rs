//! Shared scaffolding for the dense and factored engine variants: the
//! Phase-1 bounds test (Hamerly's global lower bound or Elkan's
//! per-(point, centroid) rows), the per-scan lower-bound bookkeeping, the
//! ordered Phase-3 accumulation loop, the empty-cluster reseed picker, the
//! inter-centroid separation table, the chunk-stat reduction, and the
//! convergence test.
//!
//! Both variants previously mirrored ~150 lines of this logic; extracting
//! it means a bounds-logic fix (or a new capability like warm starts or a
//! bounds policy) lands once. The helpers are written so the *arithmetic
//! order* of the original implementations is preserved exactly — the
//! bitwise naive≡pruned determinism contract (see the parent module docs)
//! is a property of that order, and `tests/property_engine.rs` pins it.
//!
//! The pieces that stay variant-specific are genuinely different:
//! Phase 2's full scans (tiled microkernel vs. per-subspace table
//! accumulation) and the centroid update step (dense means vs. factored β
//! tables).

use super::{BoundsPolicy, PruneStats};

/// Read-only per-iteration bounds context shared by every chunk.
pub(crate) struct BoundsCtx<'a> {
    pub k: usize,
    /// Resolved bounds policy of the run (never `Auto`).
    pub bounds: BoundsPolicy,
    /// `max_c ‖c_new − c_old‖` from the previous update step (Hamerly).
    pub drift_max: f64,
    /// Per-centroid drift `p[c] = ‖c_new − c_old‖` (Elkan).
    pub drift: &'a [f64],
    /// `s[c] = ½·min_{c'≠c} d(c, c')` per centroid.
    pub s_half: &'a [f64],
    /// FP slack for the skip test (see `SLACK_REL` / `SLACK_REL_F32`).
    pub slack: f64,
    /// Bounds are valid and may be used to skip this pass.
    pub use_bounds: bool,
    /// Maintain `lb` on full scans (pruning enabled at all).
    pub pruning: bool,
}

/// One chunk's view of the per-point bounds state (disjoint mutable
/// slices of the engine-wide arrays). `lb` holds one entry per point
/// (Hamerly) or a `k`-stride row per point (Elkan).
pub(crate) struct ChunkState<'a> {
    pub w: &'a [f64],
    pub assign: &'a mut [u32],
    pub mind2: &'a mut [f64],
    pub lb: &'a mut [f64],
}

/// Per-chunk work counters, reduced in chunk order after each pass.
#[derive(Default)]
pub(crate) struct ChunkStats {
    pub evals: u64,
    pub bound_evals: u64,
    pub skipped: u64,
    pub max_dd: f64,
}

/// Phase 1: the bounds test over one chunk. `assigned_d2(i, a)` must
/// return the *exact* squared distance of point `i` to its assigned
/// centroid `a`, computed with the same arithmetic as a full scan (the
/// caller applies its own clamping so skipped points store the identical
/// `mind2` bits a scan would have produced). Returns the indices that
/// failed the test and must be full-scanned, in index order.
pub(crate) fn bounds_filter(
    st: &mut ChunkState<'_>,
    ctx: &BoundsCtx<'_>,
    stats: &mut ChunkStats,
    mut assigned_d2: impl FnMut(usize, usize) -> f64,
) -> Vec<u32> {
    let n = st.w.len();
    let mut scan: Vec<u32> = Vec::with_capacity(n);
    if !ctx.use_bounds {
        scan.extend(0..n as u32);
        return scan;
    }
    let k = ctx.k;
    for i in 0..n {
        let a = st.assign[i] as usize;
        // Drift the bounds by the centroid movement since last pass, and
        // form the policy's point-level lower bound on the second-best
        // distance.
        let lbv = match ctx.bounds {
            BoundsPolicy::Elkan => {
                let row = &mut st.lb[i * k..(i + 1) * k];
                let mut lb_min = f64::INFINITY;
                for (c, (b, &p)) in row.iter_mut().zip(ctx.drift).enumerate() {
                    let v = *b - p;
                    *b = v;
                    if c != a && v < lb_min {
                        lb_min = v;
                    }
                }
                lb_min
            }
            _ => {
                let v = st.lb[i] - ctx.drift_max;
                st.lb[i] = v;
                v
            }
        };
        // The upper bound is the exact assigned distance, recomputed here
        // every pass (one evaluation) — which also keeps the reported
        // objective exact for skipped points. Being exact each pass, it
        // needs no cross-iteration storage (only `lb` persists).
        let dd = assigned_d2(i, a);
        let da = dd.sqrt();
        stats.evals += 1;
        stats.bound_evals += 1;
        if ctx.bounds == BoundsPolicy::Elkan {
            // Exact, hence a valid (and the tightest possible) bound on
            // the assigned centroid for later passes.
            st.lb[i * k + a] = da;
        }
        let bound = ctx.s_half[a].max(lbv);
        if da + ctx.slack < bound {
            // Provably still closest (strictly, even under ties and FP
            // rounding — see the parent module docs): skip the k-loop.
            st.mind2[i] = dd;
            stats.skipped += k as u64 - 1;
            if dd > stats.max_dd {
                stats.max_dd = dd;
            }
        } else {
            scan.push(i as u32);
        }
    }
    scan
}

/// Record one full scan's outcome: the new assignment, the exact `mind2`,
/// and (when pruning) the refreshed lower bounds — the second-best
/// distance (Hamerly) or the whole per-centroid row via `dist2_of(c)`
/// (Elkan; raw expansion values, clamped here before the √).
/// `d1`/`d2` must already carry the variant's clamping (`max(0.0)` for the
/// dense expansion; factored table sums are non-negative by construction).
#[inline]
#[allow(clippy::too_many_arguments)]
pub(crate) fn record_scan(
    st: &mut ChunkState<'_>,
    stats: &mut ChunkStats,
    i: usize,
    c1: u32,
    d1: f64,
    d2: f64,
    ctx: &BoundsCtx<'_>,
    mut dist2_of: impl FnMut(usize) -> f64,
) {
    let k = ctx.k;
    st.assign[i] = c1;
    st.mind2[i] = d1;
    stats.evals += k as u64;
    if d1 > stats.max_dd {
        stats.max_dd = d1;
    }
    if ctx.pruning {
        match ctx.bounds {
            BoundsPolicy::Elkan => {
                let row = &mut st.lb[i * k..(i + 1) * k];
                for (c, b) in row.iter_mut().enumerate() {
                    *b = dist2_of(c).max(0.0).sqrt();
                }
                if d2.is_finite() && d2 > stats.max_dd {
                    stats.max_dd = d2;
                }
            }
            _ => {
                if d2.is_finite() {
                    st.lb[i] = d2.sqrt();
                    if d2 > stats.max_dd {
                        stats.max_dd = d2;
                    }
                } else {
                    st.lb[i] = f64::INFINITY;
                }
            }
        }
    }
}

/// Phase 3: objective + mass accumulation in point order — identical
/// order for naive and pruned passes, so the chunk reductions match
/// bitwise. `extra(i, cluster, w)` accumulates the variant-specific
/// centroid-update state (dense coordinate sums / factored `comp_mass`).
pub(crate) fn accumulate_pass(
    w: &[f64],
    assign: &[u32],
    mind2: &[f64],
    obj: &mut f64,
    mass: &mut [f64],
    mut extra: impl FnMut(usize, usize, f64),
) {
    for i in 0..w.len() {
        let wi = w[i];
        let c = assign[i] as usize;
        *obj += wi * mind2[i];
        mass[c] += wi;
        extra(i, c, wi);
    }
}

/// Half the distance to the nearest other centroid (Hamerly's `s`),
/// recomputed from `dist2(c, c')` each iteration bounds are used.
pub(crate) fn half_min_separation(
    k: usize,
    s_half: &mut [f64],
    mut dist2: impl FnMut(usize, usize) -> f64,
) {
    for c in 0..k {
        let mut best = f64::INFINITY;
        for c2 in 0..k {
            if c2 != c {
                let dd = dist2(c, c2);
                if dd < best {
                    best = dd;
                }
            }
        }
        s_half[c] = 0.5 * best.max(0.0).sqrt();
    }
}

/// Empty-cluster reseed target: the point with the largest weighted
/// distance-to-centroid contribution.
pub(crate) fn reseed_target(weights: &[f64], mind2: &[f64]) -> usize {
    (0..weights.len())
        .max_by(|&a, &b| {
            (weights[a] * mind2[a])
                .partial_cmp(&(weights[b] * mind2[b]))
                .expect("finite")
        })
        .expect("n > 0")
}

/// Convergence on relative objective improvement (the previous objective
/// is `INFINITY` before the first completed iteration).
pub(crate) fn converged(prev: f64, obj: f64, tol: f64) -> bool {
    if !prev.is_finite() {
        return false;
    }
    let improve = (prev - obj) / prev.abs().max(1e-30);
    improve.abs() < tol
}

/// Fold one chunk's counters into the run statistics (chunk order).
pub(crate) fn fold_chunk_stats(stats: &mut PruneStats, max_dd: &mut f64, cs: &ChunkStats) {
    stats.dist_evals += cs.evals;
    stats.dist_evals_skipped += cs.skipped;
    stats.bound_evals += cs.bound_evals;
    if cs.max_dd > *max_dd {
        *max_dd = cs.max_dd;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounds_filter_without_bounds_scans_everything() {
        let w = vec![1.0; 4];
        let mut assign = vec![0u32; 4];
        let mut mind2 = vec![0.0; 4];
        let mut lb = vec![0.0; 4];
        let mut st = ChunkState { w: &w, assign: &mut assign, mind2: &mut mind2, lb: &mut lb };
        let ctx = BoundsCtx {
            k: 2,
            bounds: BoundsPolicy::Hamerly,
            drift_max: 0.0,
            drift: &[0.0, 0.0],
            s_half: &[0.0, 0.0],
            slack: 0.0,
            use_bounds: false,
            pruning: true,
        };
        let mut stats = ChunkStats::default();
        let scan = bounds_filter(&mut st, &ctx, &mut stats, |_, _| 0.0);
        assert_eq!(scan, vec![0, 1, 2, 3]);
        assert_eq!(stats.evals, 0);
    }

    #[test]
    fn bounds_filter_skips_provably_closest() {
        // One point far inside its centroid's safety radius, one outside.
        let w = vec![1.0; 2];
        let mut assign = vec![0u32; 2];
        let mut mind2 = vec![0.0; 2];
        let mut lb = vec![10.0, 0.1];
        let mut st = ChunkState { w: &w, assign: &mut assign, mind2: &mut mind2, lb: &mut lb };
        let ctx = BoundsCtx {
            k: 3,
            bounds: BoundsPolicy::Hamerly,
            drift_max: 0.0,
            drift: &[0.0; 3],
            s_half: &[0.0; 3],
            slack: 1e-9,
            use_bounds: true,
            pruning: true,
        };
        let mut stats = ChunkStats::default();
        let scan = bounds_filter(&mut st, &ctx, &mut stats, |i, _| if i == 0 { 1.0 } else { 4.0 });
        assert_eq!(scan, vec![1]);
        assert_eq!(stats.skipped, 2); // k - 1 for the skipped point
        assert_eq!(mind2[0], 1.0);
    }

    #[test]
    fn elkan_filter_drifts_per_centroid_and_tightens_assigned() {
        // Two points assigned to centroid 0, k = 3 with per-centroid lb
        // rows. Point 0: every other bound stays above the assigned
        // distance after its own drift — skipped. Point 1: centroid 2's
        // bound drifts below the assigned distance — scanned.
        let w = vec![1.0; 2];
        let mut assign = vec![0u32; 2];
        let mut mind2 = vec![0.0; 2];
        // Rows [c0, c1, c2] per point.
        let mut lb = vec![1.0, 10.0, 10.0, 1.0, 10.0, 2.5];
        let mut st = ChunkState { w: &w, assign: &mut assign, mind2: &mut mind2, lb: &mut lb };
        let ctx = BoundsCtx {
            k: 3,
            bounds: BoundsPolicy::Elkan,
            drift_max: 2.0, // deliberately loose: Elkan must not use it
            drift: &[0.0, 0.5, 2.0],
            s_half: &[0.0; 3],
            slack: 1e-9,
            use_bounds: true,
            pruning: true,
        };
        let mut stats = ChunkStats::default();
        // Exact assigned distance 4.0 (squared) → 2.0 Euclidean.
        let scan = bounds_filter(&mut st, &ctx, &mut stats, |_, _| 4.0);
        // Point 0: min over c≠0 of drifted lb = min(9.5, 8.0) = 8.0 > 2.0.
        // Point 1: centroid 2 drifted to 0.5 < 2.0 → must rescan.
        assert_eq!(scan, vec![1]);
        assert_eq!(stats.skipped, 2);
        assert_eq!(mind2[0], 4.0);
        // Drift applied per centroid, and the assigned bound tightened to
        // the exact distance.
        assert_eq!(&lb[0..3], &[2.0, 9.5, 8.0]);
        assert_eq!(lb[3], 2.0);
        assert_eq!(lb[4], 9.5);
        assert_eq!(lb[5], 0.5);
    }

    #[test]
    fn elkan_scan_refreshes_the_whole_row() {
        let w = vec![1.0];
        let mut assign = vec![0u32];
        let mut mind2 = vec![0.0];
        let mut lb = vec![7.0, 7.0, 7.0];
        let mut st = ChunkState { w: &w, assign: &mut assign, mind2: &mut mind2, lb: &mut lb };
        let ctx = BoundsCtx {
            k: 3,
            bounds: BoundsPolicy::Elkan,
            drift_max: 0.0,
            drift: &[0.0; 3],
            s_half: &[0.0; 3],
            slack: 0.0,
            use_bounds: false,
            pruning: true,
        };
        let mut stats = ChunkStats::default();
        let dists = [4.0, 1.0, -1e-18]; // tiny negative: clamped before √
        record_scan(&mut st, &mut stats, 0, 2, 0.0, 1.0, &ctx, |c| dists[c]);
        assert_eq!(assign[0], 2);
        assert_eq!(lb, vec![2.0, 1.0, 0.0]);
        assert_eq!(stats.evals, 3);
    }

    #[test]
    fn accumulate_matches_manual_sums() {
        let w = vec![1.0, 2.0, 3.0];
        let assign = vec![0u32, 1, 0];
        let mind2 = vec![0.5, 0.25, 1.0];
        let mut obj = 0.0;
        let mut mass = vec![0.0; 2];
        let mut seen = Vec::new();
        accumulate_pass(&w, &assign, &mind2, &mut obj, &mut mass, |i, c, wi| {
            seen.push((i, c, wi));
        });
        assert_eq!(obj, 0.5 + 0.5 + 3.0);
        assert_eq!(mass, vec![4.0, 2.0]);
        assert_eq!(seen, vec![(0, 0, 1.0), (1, 1, 2.0), (2, 0, 3.0)]);
    }

    #[test]
    fn reseed_picks_heaviest_contribution() {
        assert_eq!(reseed_target(&[1.0, 1.0, 5.0], &[1.0, 2.0, 1.0]), 2);
        assert_eq!(reseed_target(&[1.0, 3.0], &[1.0, 1.0]), 1);
    }

    #[test]
    fn convergence_criteria() {
        assert!(!converged(f64::INFINITY, 1.0, 1e-6));
        assert!(converged(1.0, 1.0 - 1e-9, 1e-6));
        assert!(!converged(1.0, 0.5, 1e-6));
    }

    #[test]
    fn separation_table() {
        // Centroids on a line at 0, 1, 5.
        let pos = [0.0, 1.0, 5.0];
        let mut s = vec![0.0; 3];
        half_min_separation(3, &mut s, |a, b| (pos[a] - pos[b]) * (pos[a] - pos[b]));
        assert_eq!(s, vec![0.5, 0.5, 2.0]);
    }
}
