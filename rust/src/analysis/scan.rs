//! Source scanning for `rklint`: comment/string-aware masking, waiver
//! extraction, and a line-tracking token stream.
//!
//! The linter never parses Rust — it pattern-matches token sequences on
//! a **masked** copy of the source in which every comment, string
//! literal (plain, raw, byte), and char literal has been replaced by
//! spaces, byte for byte, so token positions and line numbers survive.
//! That makes the rules immune to the classic grep failure modes: a
//! `thread::spawn` inside a doc comment or an error-message string is
//! invisible to every rule.
//!
//! Waivers are read **before** masking: a comment of the form
//!
//! ```text
//! // rklint::allow(wall-clock-in-core, reason = "why this site is legitimate")
//! ```
//!
//! suppresses diagnostics of the named rule on the same line and on the
//! line immediately below (so a waiver can sit on its own line above
//! the flagged statement). A waiver without a `reason` string, or one
//! naming an unknown rule, is itself reported — the waiver registry
//! stays honest by construction.

/// One inline waiver annotation extracted from a comment.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Waiver {
    /// 1-based line the annotation appears on.
    pub line: usize,
    /// Rule slug it names (not yet validated against known rules).
    pub rule: String,
    /// The mandatory justification; `None` when the author omitted it
    /// (reported as an `invalid-waiver` diagnostic).
    pub reason: Option<String>,
}

/// One token of the masked source.
#[derive(Clone, Debug)]
pub struct Tok {
    /// Token text (`::` is a single token; every other punctuation byte
    /// stands alone).
    pub s: String,
    /// 1-based source line.
    pub line: usize,
}

/// A masked + tokenized source file, ready for the rules.
pub struct Scanned {
    /// Token stream of the masked source.
    pub toks: Vec<Tok>,
    /// Waivers found in comments, in source order.
    pub waivers: Vec<Waiver>,
}

/// Mask comments/strings/chars and extract waivers (see module docs).
pub fn scan(source: &str) -> Scanned {
    let (masked, waivers) = mask(source);
    Scanned { toks: tokenize(&masked), waivers }
}

fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_'
}

fn is_ident_char(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Replace comments, string literals, and char literals with spaces
/// (newlines kept so line numbers survive); collect waiver annotations
/// from comment text.
fn mask(source: &str) -> (Vec<u8>, Vec<Waiver>) {
    let b = source.as_bytes();
    let mut out = b.to_vec();
    let mut waivers = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;

    // Blank `out[from..to]`, keeping newlines.
    let blank = |out: &mut Vec<u8>, from: usize, to: usize| {
        for x in &mut out[from..to] {
            if *x != b'\n' {
                *x = b' ';
            }
        }
    };

    while i < b.len() {
        let c = b[i];
        match c {
            b'\n' => {
                line += 1;
                i += 1;
            }
            b'/' if i + 1 < b.len() && b[i + 1] == b'/' => {
                let start = i;
                while i < b.len() && b[i] != b'\n' {
                    i += 1;
                }
                parse_waivers(&source[start..i], line, &mut waivers);
                blank(&mut out, start, i);
            }
            b'/' if i + 1 < b.len() && b[i + 1] == b'*' => {
                let start = i;
                let start_line = line;
                let mut depth = 1usize;
                i += 2;
                while i < b.len() && depth > 0 {
                    if b[i] == b'\n' {
                        line += 1;
                        i += 1;
                    } else if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                        depth += 1;
                        i += 2;
                    } else if b[i] == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
                parse_waivers(&source[start..i], start_line, &mut waivers);
                blank(&mut out, start, i);
            }
            b'"' => {
                let start = i;
                i += 1;
                while i < b.len() {
                    match b[i] {
                        b'\\' => {
                            // A backslash-newline continuation escapes a
                            // real newline — count it.
                            if i + 1 < b.len() && b[i + 1] == b'\n' {
                                line += 1;
                            }
                            i += 2;
                        }
                        b'"' => {
                            i += 1;
                            break;
                        }
                        b'\n' => {
                            line += 1;
                            i += 1;
                        }
                        _ => i += 1,
                    }
                }
                blank(&mut out, start, i);
            }
            b'r' | b'b' if !(i > 0 && is_ident_char(b[i - 1])) && raw_string_at(b, i).is_some() => {
                let (hashes, body_start) = raw_string_at(b, i).expect("checked above");
                let start = i;
                i = body_start;
                // Scan for `"` followed by `hashes` '#' bytes.
                loop {
                    if i >= b.len() {
                        break;
                    }
                    if b[i] == b'\n' {
                        line += 1;
                        i += 1;
                        continue;
                    }
                    let closes = b[i] == b'"'
                        && b[i + 1..].iter().take(hashes).filter(|&&x| x == b'#').count() == hashes;
                    if closes {
                        i += 1 + hashes;
                        break;
                    }
                    i += 1;
                }
                blank(&mut out, start, i);
            }
            b'\'' => {
                // Char literal vs lifetime: a literal is `'\...'` or
                // `'X'` (one ident/any char then a closing quote); a
                // lifetime has no closing quote after its identifier.
                if i + 1 < b.len() && b[i + 1] == b'\\' {
                    let start = i;
                    i += 2; // skip '\ and the escape head
                    while i < b.len() && b[i] != b'\'' {
                        i += 1;
                    }
                    i = (i + 1).min(b.len());
                    blank(&mut out, start, i);
                } else if i + 2 < b.len() && b[i + 2] == b'\'' && b[i + 1] != b'\'' {
                    blank(&mut out, i, i + 3);
                    i += 3;
                } else {
                    i += 1; // lifetime tick — harmless as a lone token
                }
            }
            _ => i += 1,
        }
    }
    (out, waivers)
}

/// `Some((n_hashes, body_start))` when `b[i..]` begins a raw (or raw
/// byte) string literal.
fn raw_string_at(b: &[u8], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if b[j] == b'b' {
        j += 1;
    }
    if j >= b.len() || b[j] != b'r' {
        return None;
    }
    j += 1;
    let mut hashes = 0usize;
    while j < b.len() && b[j] == b'#' {
        hashes += 1;
        j += 1;
    }
    if j < b.len() && b[j] == b'"' {
        Some((hashes, j + 1))
    } else {
        None
    }
}

/// Parse every waiver annotation (the `allow(rule, reason = "…")` form
/// behind the `rklint` namespace marker) inside one comment's text.
fn parse_waivers(comment: &str, first_line: usize, out: &mut Vec<Waiver>) {
    const MARK: &str = "rklint::allow(";
    let mut search = 0usize;
    while let Some(pos) = comment[search..].find(MARK) {
        let at = search + pos;
        let line = first_line + comment[..at].bytes().filter(|&b| b == b'\n').count();
        let rest = &comment[at + MARK.len()..];
        // Rule slug: idents and dashes up to ',' or ')'.
        let slug_end = rest.find([',', ')']).unwrap_or(rest.len());
        let rule = rest[..slug_end].trim().to_string();
        let mut reason = None;
        if rest[slug_end..].starts_with(',') {
            let tail = rest[slug_end + 1..].trim_start();
            if let Some(stripped) = tail.strip_prefix("reason") {
                let stripped = stripped.trim_start();
                if let Some(body) = stripped.strip_prefix('=') {
                    let body = body.trim_start();
                    if let Some(q) = body.strip_prefix('"') {
                        if let Some(close) = q.find('"') {
                            if !q[..close].trim().is_empty() {
                                reason = Some(q[..close].to_string());
                            }
                        }
                    }
                }
            }
        }
        out.push(Waiver { line, rule, reason });
        search = at + MARK.len();
    }
}

/// Tokenize masked source: identifiers/numbers as words, `::` fused,
/// every other non-space byte a one-byte token.
fn tokenize(masked: &[u8]) -> Vec<Tok> {
    let mut toks = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;
    while i < masked.len() {
        let c = masked[i];
        if c == b'\n' {
            line += 1;
            i += 1;
        } else if c.is_ascii_whitespace() {
            i += 1;
        } else if is_ident_start(c) {
            let start = i;
            while i < masked.len() && is_ident_char(masked[i]) {
                i += 1;
            }
            toks.push(Tok { s: String::from_utf8_lossy(&masked[start..i]).into_owned(), line });
        } else if c.is_ascii_digit() {
            let start = i;
            while i < masked.len()
                && (is_ident_char(masked[i])
                    || (masked[i] == b'.'
                        && i + 1 < masked.len()
                        && masked[i + 1].is_ascii_digit()))
            {
                i += 1;
            }
            toks.push(Tok { s: String::from_utf8_lossy(&masked[start..i]).into_owned(), line });
        } else if c == b':' && i + 1 < masked.len() && masked[i + 1] == b':' {
            toks.push(Tok { s: "::".to_string(), line });
            i += 2;
        } else {
            toks.push(Tok { s: (c as char).to_string(), line });
            i += 1;
        }
    }
    toks
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(s: &str) -> Vec<String> {
        scan(s).toks.into_iter().map(|t| t.s).collect()
    }

    #[test]
    fn comments_and_strings_are_invisible() {
        let src = r##"
// thread::spawn in a comment
let x = "thread::spawn in a string";
let y = r#"Instant::now in a raw string"#;
/* block Instant::now
   spanning lines */
let c = 'x';
"##;
        let t = texts(src);
        assert!(!t.contains(&"spawn".to_string()), "comment/string content leaked: {t:?}");
        assert!(!t.contains(&"Instant".to_string()));
        assert!(t.contains(&"let".to_string()));
    }

    #[test]
    fn line_numbers_survive_masking() {
        let src = "let a = 1;\n/* two\nlines */\nInstant::now()\n";
        let s = scan(src);
        let now = s.toks.iter().find(|t| t.s == "now").expect("token present");
        assert_eq!(now.line, 4);
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        // A lifetime tick must not start masking (it would eat code).
        let t = texts("fn f<'a>(x: &'a str) -> &'a str { x }");
        assert!(t.contains(&"str".to_string()));
        assert!(t.contains(&"fn".to_string()));
    }

    #[test]
    fn waivers_parse_with_and_without_reasons() {
        let src = "\n// rklint::allow(rogue-thread, reason = \"load generator clients\")\nx();\n\
                   // rklint::allow(wall-clock-in-core)\n";
        let s = scan(src);
        assert_eq!(s.waivers.len(), 2);
        assert_eq!(s.waivers[0].line, 2);
        assert_eq!(s.waivers[0].rule, "rogue-thread");
        assert_eq!(s.waivers[0].reason.as_deref(), Some("load generator clients"));
        assert_eq!(s.waivers[1].rule, "wall-clock-in-core");
        assert_eq!(s.waivers[1].reason, None, "missing reason must be detectable");
    }

    #[test]
    fn backslash_newline_continuation_keeps_line_count() {
        let src = "let s = \"a \\\n   b\";\n// rklint::allow(wall-clock-in-core, reason = \"x\")\n";
        let s = scan(src);
        assert_eq!(s.waivers.len(), 1);
        assert_eq!(s.waivers[0].line, 3, "continuation newline must still count");
    }

    #[test]
    fn double_colon_fuses() {
        let t = texts("std::thread::spawn(f)");
        assert_eq!(t, vec!["std", "::", "thread", "::", "spawn", "(", "f", ")"]);
    }
}
