//! The serving tier: replicated models, micro-batched assignment,
//! hot-swap publication, and centroid-delta shipping.
//!
//! This is the layer that carries the paper's query-time promise — the
//! exact O(k·m) factored [`RkModel::assign`](crate::rkmeans::RkModel::assign)
//! over never-materialized tuples — to production request rates. Four
//! pieces compose it:
//!
//! * [`ModelMesh`] (`mesh`) — N hot-swappable replica slots, each an
//!   `RwLock<Arc<RkModel>>`. Readers pin a version with a pointer
//!   clone; installs flip slots atomically, so a reader sees the old
//!   model or the new one, never a torn mix, and in-flight batches
//!   drain on the version they pinned.
//! * [`AssignFront`] (`front`) — the request-batching front. Concurrent
//!   clients enqueue single tuples; a dispatcher drains them into
//!   micro-batches and fans each batch over the shared
//!   [`ExecPool`](crate::util::exec::ExecPool), amortizing dispatch
//!   overhead and putting every core behind the assign kernels. Served
//!   versions are monotone across all clients (a round-robin replica
//!   pick with a version floor).
//! * [`ModelDelta`] + [`RkModel::diff`](crate::rkmeans::RkModel::diff) /
//!   [`RkModel::apply_delta`](crate::rkmeans::RkModel::apply_delta)
//!   (`delta`) — the versioned wire format between model versions:
//!   changed centroid rows and re-solved subspace models only, keyed
//!   `from_version → to_version`, with bit-exact reconstruction
//!   (`apply_delta(diff(a, b)) ≡ b` bitwise) and stale-delta rejection.
//! * [`Publisher`] (`publish`) — the writer side: diff against what
//!   replicas serve, ship the delta through the wire encoding, verify
//!   bitwise reconstruction, hot-swap every slot. Delta-vs-snapshot
//!   byte accounting lands in `serve.*` metrics.
//!
//! A fifth piece, [`rpc`], carries all of the above across a real
//! **process boundary**: a length-prefixed framed protocol over TCP
//! with an assign plane (encoded rows in, `Assignment{cluster,
//! version}` out through the same micro-batching front), a replication
//! plane (replica processes subscribe to the publisher's delta stream,
//! recover from `VersionGap` via byte-verified snapshot catch-up, and
//! rejoin), and a control plane (health/version probes). `rkmeans
//! serve --listen` / `rkmeans replica --connect` run the two sides;
//! see the [`rpc`] module docs for the failure semantics.
//!
//! [`load`] provides the open-loop generator ([`run_open_loop`]) and
//! the un-batched contrast arm ([`run_naive_loop`]) that
//! `benches/serve_load.rs` measures; [`run_rpc_loop`] is the socket
//! analogue `benches/rpc_load.rs` drives. `rkmeans serve` wires all of
//! it into a CLI server loop fed by the incremental engine. Telemetry:
//! `serve.requests`, `serve.batches`, `serve.assign_us.{count,p50,p99}`,
//! `serve.batch_size.*`, `serve.swaps`, `serve.publishes`,
//! `serve.delta_bytes`, `serve.snapshot_bytes`, `serve.stale_deltas`,
//! `serve.version`, `serve.replicas`, plus the socket tier's
//! `serve.rpc.{frames_in,frames_out,bytes_in,bytes_out,conns,
//! subscribers,deltas_out,dropped_deltas,deltas_applied,stale_deltas,
//! reconnects,catchups,catchup_serves,gaps}` counters and
//! `serve.rpc.{assign_us,probe_us,apply_us}` histograms.

pub mod delta;
pub mod front;
pub mod load;
pub mod mesh;
pub mod publish;
pub mod rpc;

pub use delta::{DeltaApplyError, ModelDelta, MODEL_DELTA_FORMAT_VERSION};
pub use front::{AssignClient, AssignFront, Assignment, FrontOpts};
pub use load::{run_naive_loop, run_open_loop, synth_rows, LoadReport, LoadSpec};
pub use mesh::ModelMesh;
pub use publish::{PublishStats, Publisher};
pub use rpc::{
    fetch_snapshot, probe, run_rpc_loop, send_stop, ReplicaSync, RpcLoadReport, RpcOpts, RpcServer,
    SyncOpts,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Metrics;
    use crate::rkmeans::{ClusterOpts, RkPipeline, SubspaceOpts};
    use crate::synthetic::{retailer, Scale};
    use crate::util::exec::shared_pool;
    use std::sync::Arc;

    /// End-to-end smoke: build → mesh → front → load → publish → load.
    #[test]
    fn serve_tier_end_to_end() {
        let db = retailer::generate(Scale::tiny(), 42);
        let feq = retailer::feq();
        let pipe = RkPipeline::plan(&db, &feq).unwrap();
        let marginals = pipe.marginals().unwrap();
        let subspaces = pipe.subspaces(&marginals, &SubspaceOpts::new(4)).unwrap();
        let coreset = pipe.coreset(&subspaces).unwrap();
        let v1 = coreset.cluster(&ClusterOpts::new(4)).with_version(1);
        let v2 = coreset.cluster(&ClusterOpts::new(4).with_seed(7)).with_version(2);

        let metrics = Metrics::new();
        let mesh = ModelMesh::new(v1.clone(), 2, metrics.clone());
        let front = AssignFront::start(Arc::clone(&mesh), FrontOpts::default(), shared_pool());
        let rows = synth_rows(&v1, 64, 11);

        let before = run_open_loop(&front, &rows, &LoadSpec::saturate(200, 2));
        assert_eq!(before.requests, 200);
        assert_eq!(before.max_version, 1);

        let mut publisher = Publisher::new(Arc::clone(&mesh));
        let stats = publisher.publish(&v2).unwrap();
        assert_eq!(stats.version, 2);

        let after = run_open_loop(&front, &rows, &LoadSpec::saturate(200, 2));
        assert_eq!(after.requests, 200);
        assert_eq!(after.max_version, 2, "post-publish traffic serves the new version");
        assert!(after.monotonic);
        front.shutdown();

        let snap = metrics.snapshot();
        let get = |k: &str| snap.iter().find(|(n, _)| n == k).map(|(_, v)| *v);
        assert_eq!(get("serve.requests"), Some(400));
        assert_eq!(get("serve.publishes"), Some(1));
        assert_eq!(get("serve.swaps"), Some(2));
        assert!(get("serve.assign_us.p99").unwrap() >= get("serve.assign_us.p50").unwrap());
    }
}
