//! Lightweight metrics registry for the streaming coordinator and CLI:
//! atomic counters and gauges with a printable snapshot. No external
//! dependencies; safe to share across worker threads.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// A monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Increment by `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Increment by one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A settable gauge.
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    /// Set the value.
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Add a (possibly negative) delta.
    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A shared registry of named counters and gauges.
#[derive(Clone, Default)]
pub struct Metrics {
    inner: Arc<Inner>,
}

#[derive(Default)]
struct Inner {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
}

impl Metrics {
    /// New empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Get or create a counter.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.inner.counters.lock().expect("metrics lock");
        m.entry(name.to_string()).or_default().clone()
    }

    /// Get or create a gauge.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.inner.gauges.lock().expect("metrics lock");
        m.entry(name.to_string()).or_default().clone()
    }

    /// Snapshot all metrics as sorted `(name, value)` pairs.
    pub fn snapshot(&self) -> Vec<(String, i64)> {
        let mut out = Vec::new();
        for (k, c) in self.inner.counters.lock().expect("metrics lock").iter() {
            out.push((k.clone(), c.get() as i64));
        }
        for (k, g) in self.inner.gauges.lock().expect("metrics lock").iter() {
            out.push((k.clone(), g.get()));
        }
        out.sort();
        out
    }

    /// Render the snapshot as `name=value` lines.
    pub fn render(&self) -> String {
        self.snapshot()
            .into_iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join("\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges() {
        let m = Metrics::new();
        m.counter("tuples_in").add(5);
        m.counter("tuples_in").inc();
        m.gauge("queue_depth").set(3);
        m.gauge("queue_depth").add(-1);
        let snap = m.snapshot();
        assert_eq!(snap, vec![("queue_depth".to_string(), 2), ("tuples_in".to_string(), 6)]);
        assert!(m.render().contains("tuples_in=6"));
    }

    #[test]
    fn shared_across_threads() {
        let m = Metrics::new();
        let c = m.counter("hits");
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let c = c.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().expect("worker");
        }
        assert_eq!(m.counter("hits").get(), 4000);
    }
}
