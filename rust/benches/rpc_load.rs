//! Bench W4 — the multi-process socket tier (`rkmeans::serve::rpc`):
//! the in-process assign front vs. real writer/replica processes over
//! localhost TCP, plus a replica-churn arm that kills and restarts a
//! replica mid-run to measure snapshot catch-up. Three arms:
//!
//! * `inproc`      — the same open-loop load through `AssignFront`
//!   with no socket in the path: the reference the `rpc_qps_ratio`
//!   gate metric is relative to;
//! * `rpc-1`       — one writer process + one replica process; the
//!   load generator pipelines framed assign requests to the replica's
//!   socket (`run_rpc_loop`), so framing + kernel round-trips are in
//!   the measured latency;
//! * `rpc-3-churn` — one writer (publishing with forced delta drops)
//!   + three replicas; one replica is killed mid-run and a fresh one
//!   started, which must fetch a byte-verified snapshot and converge
//!   back to the writer's latest version. Convergence and the writer's
//!   catch-up count become the `rpc_catchup_ok` gate metric.
//!
//! Results are written as one `BENCH_rpc.json` document (schema: see
//! `bench_harness` docs; path override: `RKMEANS_RPC_OUT`).
//!
//! `--test` (or `--smoke`) shrinks everything for CI smoke runs.
//! `RKMEANS_RPC_SCALE` overrides the Retailer scale.

use anyhow::{bail, ensure, Context, Result};
use rkmeans::bench_harness::{write_bench_rpc, RpcBenchRecord};
use rkmeans::incremental::{IncrementalEngine, PlannerOpts};
use rkmeans::metrics::Metrics;
use rkmeans::rkmeans::RkConfig;
use rkmeans::serve::{
    fetch_snapshot, probe, run_open_loop, run_rpc_loop, send_stop, synth_rows, AssignFront,
    FrontOpts, LoadSpec, ModelMesh,
};
use rkmeans::synthetic::{retailer, Scale};
use rkmeans::util::exec::shared_pool;
use std::io::{BufRead, BufReader};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A child `rkmeans` process with its stdout forwarded line-by-line
/// through a channel (the drain thread also keeps the pipe from
/// backing up when the child prints its metrics dump on exit).
struct Proc {
    child: Child,
    lines: mpsc::Receiver<String>,
    addr: Option<String>,
}

fn spawn_rkmeans(args: &[String]) -> Result<Proc> {
    let exe = env!("CARGO_BIN_EXE_rkmeans");
    let mut child = Command::new(exe)
        .args(args)
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .with_context(|| format!("spawning {exe} {args:?}"))?;
    let stdout = child.stdout.take().expect("piped stdout");
    let (tx, rx) = mpsc::channel();
    std::thread::spawn(move || {
        for line in BufReader::new(stdout).lines().map_while(|l| l.ok()) {
            if tx.send(line).is_err() {
                break;
            }
        }
    });
    Ok(Proc { child, lines: rx, addr: None })
}

impl Proc {
    /// Wait for the child's `rpc listening on <addr>` line.
    fn listening_addr(&mut self, deadline: Duration) -> Result<String> {
        if let Some(a) = &self.addr {
            return Ok(a.clone());
        }
        let t0 = Instant::now();
        while t0.elapsed() < deadline {
            match self.lines.recv_timeout(Duration::from_millis(100)) {
                Ok(line) => {
                    if let Some(a) = line.strip_prefix("rpc listening on ") {
                        let a = a.trim().to_string();
                        self.addr = Some(a.clone());
                        return Ok(a);
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        bail!("child printed no listening line within {deadline:?}")
    }

    /// Graceful stop: control-plane STOP, then wait (kill on timeout).
    fn stop(mut self) {
        if let Some(a) = &self.addr {
            let _ = send_stop(a);
        }
        let t0 = Instant::now();
        loop {
            match self.child.try_wait() {
                Ok(Some(_)) => return,
                Ok(None) if t0.elapsed() < Duration::from_secs(10) => {
                    std::thread::sleep(Duration::from_millis(50));
                }
                _ => {
                    let _ = self.child.kill();
                    let _ = self.child.wait();
                    return;
                }
            }
        }
    }

    /// Hard kill (the churn arm's failure injection).
    fn kill(mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn writer_args(scale: f64, k: usize, publishes: usize, publish_ms: u64, drop: u64) -> Vec<String> {
    [
        "serve",
        "--dataset",
        "retailer",
        "--scale",
        &scale.to_string(),
        "--k",
        &k.to_string(),
        "--seed",
        "42",
        "--listen",
        "127.0.0.1:0",
        "--publishes",
        &publishes.to_string(),
        "--publish-ms",
        &publish_ms.to_string(),
        "--drop-every",
        &drop.to_string(),
    ]
    .iter()
    .map(|s| s.to_string())
    .collect()
}

fn replica_args(writer: &str) -> Vec<String> {
    ["replica", "--connect", writer, "--listen", "127.0.0.1:0"]
        .iter()
        .map(|s| s.to_string())
        .collect()
}

fn main() -> Result<()> {
    let test_mode = std::env::args().any(|a| a == "--test" || a == "--smoke");
    let scale: f64 = std::env::var("RKMEANS_RPC_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if test_mode { 0.005 } else { 0.02 });
    let k = if test_mode { 8 } else { 32 };
    let inproc_requests = if test_mode { 10_000 } else { 50_000 };
    let rpc_requests = if test_mode { 3_000 } else { 30_000 };
    let churn_requests = if test_mode { 3_000 } else { 30_000 };
    let churn_qps = if test_mode { 1_500.0 } else { 15_000.0 };
    let publishes = if test_mode { 2 } else { 4 };
    let publish_ms = if test_mode { 300 } else { 400 };
    let clients = if test_mode { 2 } else { 4 };
    let seed = 42u64;
    let startup = Duration::from_secs(60);

    // ---- arm 1: in-process reference --------------------------------
    // Same dataset / k / seed the writer process uses, so the factored
    // assign cost is identical and the ratio isolates the socket.
    let db = retailer::generate(Scale::custom(scale), seed);
    let feq = retailer::feq();
    let metrics = Metrics::new();
    let rk = RkConfig::new(k).with_seed(seed);
    let engine = IncrementalEngine::new(&db, feq, rk, PlannerOpts::default(), metrics.clone())?;
    let model = engine.model();
    let rows = synth_rows(&model, 256, seed ^ 0x9e37_79b9);
    println!(
        "rpc workload: |D|={} rows (scale {scale}), k={k}, {clients} clients",
        db.total_rows()
    );

    let mesh = ModelMesh::new(model, 2, metrics);
    let front = AssignFront::start(Arc::clone(&mesh), FrontOpts::default(), shared_pool());
    let inproc_report = run_open_loop(&front, &rows, &LoadSpec::saturate(inproc_requests, clients));
    front.shutdown();
    let inproc_rec = RpcBenchRecord::from_load(
        "retailer",
        "inproc",
        0,
        clients,
        inproc_report.requests,
        inproc_report.qps,
        inproc_report.p50_us,
        inproc_report.p99_us,
    );
    println!("{}", inproc_rec.line());

    // ---- arm 2: one writer + one replica process --------------------
    let mut writer = spawn_rkmeans(&writer_args(scale, k, 0, publish_ms, 0))?;
    let waddr = writer.listening_addr(startup)?;
    let mut replica = spawn_rkmeans(&replica_args(&waddr))?;
    let raddr = replica.listening_addr(startup)?;
    let served = fetch_snapshot(&raddr, Duration::from_secs(30))?;
    let rpc_rows = synth_rows(&served, 256, seed ^ 0x9e37_79b9);
    let one = run_rpc_loop(
        &[raddr.clone()],
        &rpc_rows,
        &LoadSpec { requests: rpc_requests, clients, qps: None, seed },
    )?;
    replica.stop();
    writer.stop();
    ensure!(one.report.monotonic, "rpc-1 arm served non-monotone versions");
    let one_rec = RpcBenchRecord::from_load(
        "retailer",
        "rpc-1",
        1,
        clients,
        one.report.requests,
        one.report.qps,
        one.report.p50_us,
        one.report.p99_us,
    )
    .with_ratio_vs(&inproc_rec);
    println!("{}", one_rec.line());

    // ---- arm 3: three replicas, one killed + restarted mid-run ------
    // `--drop-every 3` forces delta drops on the replication plane, so
    // surviving replicas also exercise VersionGap → snapshot catch-up.
    let mut writer = spawn_rkmeans(&writer_args(scale, k, publishes, publish_ms, 3))?;
    let waddr = writer.listening_addr(startup)?;
    let mut replicas = Vec::new();
    let mut raddrs = Vec::new();
    for _ in 0..3 {
        let mut r = spawn_rkmeans(&replica_args(&waddr))?;
        raddrs.push(r.listening_addr(startup)?);
        replicas.push(r);
    }

    let load_addrs = raddrs.clone();
    let load_rows = rpc_rows.clone();
    let load = std::thread::spawn(move || {
        run_rpc_loop(
            &load_addrs,
            &load_rows,
            &LoadSpec { requests: churn_requests, clients, qps: Some(churn_qps), seed },
        )
    });

    // Let the run get going, then kill one replica and start a fresh
    // one (new port — the load generator keeps rotating over the
    // original three, reconnecting away from the dead socket).
    std::thread::sleep(Duration::from_millis(publish_ms));
    replicas.remove(0).kill();
    let mut fresh = spawn_rkmeans(&replica_args(&waddr))?;
    let fresh_addr = fresh.listening_addr(startup)?;

    let churn = load.join().expect("rpc load thread")?;
    println!(
        "churn load: {} answered, {} lost to the kill, {} reconnects",
        churn.report.requests, churn.lost, churn.reconnects
    );

    // Convergence: the restarted replica must reach the writer's final
    // version (its installs are byte-verified on the way in).
    let deadline = Instant::now() + Duration::from_secs(60);
    let mut converged = false;
    while Instant::now() < deadline {
        let w = probe(&waddr, Duration::from_secs(10))?;
        let f = probe(&fresh_addr, Duration::from_secs(10))?;
        if f.version == w.version {
            converged = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    let catchups = probe(&waddr, Duration::from_secs(10))?.catchups;
    fresh.stop();
    for r in replicas {
        r.stop();
    }
    writer.stop();

    let churn_rec = RpcBenchRecord::from_load(
        "retailer",
        "rpc-3-churn",
        3,
        clients,
        churn.report.requests,
        churn.report.qps,
        churn.report.p50_us,
        churn.report.p99_us,
    )
    .with_ratio_vs(&inproc_rec)
    .with_churn(catchups, converged);
    println!("{}", churn_rec.line());
    ensure!(converged, "restarted replica never converged to the writer's version");
    ensure!(catchups >= 1, "writer served no snapshot catch-ups under churn");

    let ratio = one_rec.qps_ratio_vs_inproc.unwrap_or(0.0);
    let records = vec![inproc_rec, one_rec, churn_rec];
    let out = PathBuf::from(
        std::env::var("RKMEANS_RPC_OUT").unwrap_or_else(|_| "BENCH_rpc.json".to_string()),
    );
    write_bench_rpc(&out, &records)?;
    println!("wrote {} records to {}", records.len(), out.display());
    println!(
        "rpc-1 vs inproc: {ratio:.3}× QPS across the process boundary; churn arm converged \
         with {catchups} snapshot catch-up(s) served"
    );
    Ok(())
}
