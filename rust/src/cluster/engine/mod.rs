//! The shared Step-4 execution engine: a blocked distance microkernel
//! (f64 and f32 tile paths), bounds pruning under a selectable policy
//! (Hamerly or Elkan), and a deterministic chunk-parallel executor — used
//! by both the dense ([`dense`]) and the factored ([`factored`])
//! weighted-Lloyd variants, and by the streaming full-objective scorer
//! ([`CentroidScorer`]).
//!
//! # Bounds invariants
//!
//! For every point `i` with current assignment `a(i)` the engine maintains
//! *Euclidean* (not squared) bounds:
//!
//! * the upper bound on `d(x_i, c_{a(i)})` is the *exact* assigned
//!   distance, recomputed at every pass (one distance evaluation per
//!   point). Because it is exact each pass it is never stored across
//!   iterations — this is also what keeps the reported objective exact
//!   rather than bounded, and what makes pruned output bitwise-equal to
//!   naive output.
//! * lower bounds, per the [`BoundsPolicy`]:
//!   * **Hamerly** ("Making k-means even faster", 2010):
//!     `lb[i] ≤ min_{c ≠ a(i)} d(x_i, c)` — a single global lower bound
//!     on the distance to the *second-closest* centroid. After every
//!     update it is drifted by the maximum movement: `lb -= max_c p[c]`.
//!   * **Elkan** ("Using the triangle inequality to accelerate k-means",
//!     2003): `lb[i·k + c] ≤ d(x_i, c)` — one lower bound per
//!     (point, centroid), each drifted by *its own* centroid's movement:
//!     `lb[i·k + c] -= p[c]`. O(n·k) memory; a full scan resets the whole
//!     row to the exact distances, and the Phase-1 test uses
//!     `min_{c ≠ a(i)} lb[i·k + c]`, which stays far tighter than the
//!     Hamerly bound at large k where `max_c p[c]` is dominated by a few
//!     still-moving centroids.
//! * `p[c] = ‖c_new − c_old‖` — per-centroid drift. The dense engine takes
//!   it from the raw coordinates; the factored engine computes it from the
//!   per-subspace β coefficient tables using component orthogonality
//!   (`‖Δμ_j‖² = Σ_a Δβ_a²·‖u_a‖²`), so it never densifies a centroid.
//! * `s[c] = ½·min_{c' ≠ c} d(c, c')` — half the distance to the nearest
//!   other centroid (recomputed each iteration).
//!
//! With `ub` exact, the engine skips the inner k-loop whenever
//!
//! ```text
//!   d(x_i, c_{a(i)}) + slack < max(lb_i, s[a(i)])
//! ```
//!
//! (`lb_i` being the policy's point-level lower bound on the second-best
//! distance), which by the triangle inequality proves no other centroid
//! can be strictly closer. The `slack` term (a small multiple of the data
//! scale, [`SLACK_REL`]) absorbs floating-point rounding in the bound
//! chain so that a skipped point provably agrees with what a full scan
//! would have chosen — including tie-breaking, because ties never satisfy
//! the strict inequality and therefore always rescan.
//!
//! # Choosing a bounds policy and a precision
//!
//! The two engine axes compose freely (Hamerly/Elkan × f64/f32) and are
//! selected via [`EngineOpts::bounds`] / [`EngineOpts::precision`]:
//!
//! | | **Hamerly** | **Elkan** |
//! |---|---|---|
//! | bounds memory | O(n) | O(n·k) |
//! | Phase-1 cost per point | O(1) | O(k) (drift + row min) |
//! | scan cost | k distances | k distances + k √ (bound refresh) |
//! | wins when | k ≲ 64, or memory-tight | k ≳ 64 ([`ELKAN_AUTO_K`]), stable assignments, few fast-moving centroids |
//! | output | bitwise = naive | bitwise = naive |
//!
//! [`BoundsPolicy::Auto`] (the default) picks Elkan at k ≥
//! [`ELKAN_AUTO_K`] and Hamerly below; both policies keep the determinism
//! contract, so switching never changes results, only throughput.
//!
//! [`Precision::F32`] runs the distance kernels in f32 (double the SIMD
//! lanes of the `‖x‖² − 2·x·c + ‖c‖²` contraction) while keeping the
//! objective and the centroid-update sums in f64, mirroring the XLA f32
//! artifact's tolerance story: on well-scaled inputs the final objective
//! agrees with the f64 path within [`F32_OBJ_RTOL`] (relative), and the
//! determinism contract holds *within* the precision — f32
//! pruned-parallel is bitwise-identical to f32 naive-serial. Use f32 when
//! distances have head-room (|values| ≲ 10³ and relative objective error
//! of ~1e-3 is acceptable); stay on f64 for bitwise reproducibility
//! against archived results or ill-scaled data.
//!
//! # Determinism contract
//!
//! Results are **bitwise identical** for any thread count and for the
//! pruned vs. naive paths:
//!
//! * Points are partitioned into fixed [`CHUNK`]-sized ranges independent
//!   of the thread count; each chunk accumulates its own `sums`/`mass`/
//!   `obj` in point order, and chunk accumulators are reduced left-to-right
//!   on the coordinating thread (a fixed-shape tree reduction). The thread
//!   pool only changes *who* computes a chunk, never the arithmetic.
//! * Pruned and full-scan paths compute distances with the same
//!   accumulation order (see [`microkernel`]), so a pruned iteration
//!   produces the same `assign`/`mind2` bits as a naive one. The
//!   `tests/property_engine.rs` suite asserts exact equality of
//!   assignments, centroids and objectives across (naive serial) ×
//!   (pruned parallel) on seeded random inputs, dense and factored.
//!
//! The contract is validated—not just assumed—because the FP-slack
//! argument above is only rigorous for data whose dynamic range is sane
//! (|values| ≪ 1/√ε·distances); pathological inputs would merely prune
//! less, never corrupt bounds in the unsafe direction.
//!
//! # Shared scaffolding and warm starts
//!
//! The variant-independent pieces — the Phase-1 bounds test, the ordered
//! Phase-3 accumulation, the empty-cluster reseed picker, the separation
//! table, chunk-stat reduction and the convergence test — live once in
//! [`core`] and are parameterized over a distance provider (a closure
//! computing the exact assigned distance) and a per-point accumulator
//! callback, so bounds-logic fixes land in both engines simultaneously.
//! Both variants also expose `*_init` entry points
//! ([`dense::lloyd_dense_init`], [`factored::lloyd_factored_init`]) that
//! accept a warm start — previous centroids seeding the run in place of
//! k-means++ — which the incremental planner
//! ([`crate::incremental::planner`]) uses to re-cluster a delta-patched
//! grid in a couple of iterations.

pub(crate) mod core;
pub mod dense;
pub mod factored;
pub(crate) mod microkernel;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Fixed parallel work-unit size (points per chunk). Part of the
/// determinism contract: reductions happen per chunk and then in chunk
/// order, so results do not depend on the thread count. Inputs smaller
/// than one chunk take a purely serial path.
pub const CHUNK: usize = 4096;

/// Relative slack applied to the skip test to absorb rounding in the
/// bound chain (see the module docs). Chosen ≫ accumulated f64 rounding
/// (~1e-13·scale over a Lloyd run) and ≪ any real cluster separation, so
/// it costs essentially no pruning.
pub(crate) const SLACK_REL: f64 = 1e-6;

/// The f32-path analog of [`SLACK_REL`]: f32 kernels round at ~1e-7
/// relative per operation and the `‖x‖² − 2·x·c + ‖c‖²` expansion
/// cancels, so the skip slack must be correspondingly wider for a skipped
/// point to provably agree with an f32 full scan.
pub(crate) const SLACK_REL_F32: f64 = 1e-3;

/// `Auto` bounds-policy crossover: below this k the O(k) per-point
/// Phase-1 bookkeeping of Elkan outweighs its tighter bounds; above it
/// the saved full scans dominate (see the module-level decision table).
pub const ELKAN_AUTO_K: usize = 64;

/// Documented tolerance contract of the f32 tile path: on well-scaled
/// inputs (|values| ≲ 10³, genuine cluster structure) the final objective
/// of a [`Precision::F32`] run agrees with the f64 run within this
/// *relative* tolerance. `tests/property_engine.rs` pins it on the
/// synthetic Retailer/Favorita workloads.
pub const F32_OBJ_RTOL: f64 = 1e-3;

/// Which lower-bound family the pruned engine maintains. Both policies
/// produce **bitwise-identical** results to the naive reference (the
/// determinism contract); they differ only in how much Phase-2 scan work
/// the Phase-1 test proves away, and at what bookkeeping cost. See the
/// module-level decision table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BoundsPolicy {
    /// Resolve per run: [`Elkan`](BoundsPolicy::Elkan) at
    /// k ≥ [`ELKAN_AUTO_K`], [`Hamerly`](BoundsPolicy::Hamerly) below.
    Auto,
    /// One global second-best lower bound per point, drifted by the
    /// maximum centroid movement. O(n) memory, O(1) per-point Phase 1.
    Hamerly,
    /// Per-(point, centroid) lower bounds, each drifted by its own
    /// centroid's movement. O(n·k) memory, O(k) per-point Phase 1, much
    /// tighter at large k.
    Elkan,
}

impl BoundsPolicy {
    /// Resolve [`Auto`](BoundsPolicy::Auto) against the run's k; the
    /// engines call this once per run, so `Auto` never reaches the
    /// per-pass machinery.
    pub fn resolve(self, k: usize) -> BoundsPolicy {
        match self {
            BoundsPolicy::Auto => {
                if k >= ELKAN_AUTO_K {
                    BoundsPolicy::Elkan
                } else {
                    BoundsPolicy::Hamerly
                }
            }
            other => other,
        }
    }

    /// Stable label for stats and bench records.
    pub fn label(self) -> &'static str {
        match self {
            BoundsPolicy::Auto => "auto",
            BoundsPolicy::Hamerly => "hamerly",
            BoundsPolicy::Elkan => "elkan",
        }
    }
}

/// Distance-kernel precision (see the module-level decision table).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    /// f64 kernels throughout; bitwise-reproducible against archived
    /// results.
    F64,
    /// f32 kernels (2× SIMD lanes) with f64 accumulation for the
    /// objective and the centroid-update sums. Results carry f32 rounding
    /// ([`F32_OBJ_RTOL`]); the determinism contract holds *within* the
    /// f32 path.
    F32,
}

impl Precision {
    /// Stable label for stats and bench records.
    pub fn label(self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::F32 => "f32",
        }
    }
}

/// Engine execution options shared by the dense and factored paths.
#[derive(Clone, Debug)]
pub struct EngineOpts {
    /// Maintain bounds and skip provably-unchanged assignments.
    pub pruning: bool,
    /// Worker threads; `0` = auto (`RKMEANS_THREADS` env var, else the
    /// machine's available parallelism).
    pub threads: usize,
    /// Lower-bound policy for the pruned path ([`BoundsPolicy::Auto`]
    /// resolves against the run's k).
    pub bounds: BoundsPolicy,
    /// Distance-kernel precision.
    pub precision: Precision,
}

impl Default for EngineOpts {
    fn default() -> Self {
        EngineOpts::pruned()
    }
}

impl EngineOpts {
    /// The production configuration: bounds pruning (auto policy) + auto
    /// parallelism, f64 kernels.
    pub fn pruned() -> Self {
        EngineOpts {
            pruning: true,
            threads: 0,
            bounds: BoundsPolicy::Auto,
            precision: Precision::F64,
        }
    }

    /// The retained reference: full scans, single thread. The property
    /// suite pins the pruned/parallel paths to this bit-for-bit (within a
    /// precision).
    pub fn naive_serial() -> Self {
        EngineOpts {
            pruning: false,
            threads: 1,
            bounds: BoundsPolicy::Auto,
            precision: Precision::F64,
        }
    }

    /// Override the worker-thread count.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Override the bounds policy.
    pub fn with_bounds(mut self, bounds: BoundsPolicy) -> Self {
        self.bounds = bounds;
        self
    }

    /// Override the distance-kernel precision.
    pub fn with_precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }
}

/// Work counters for one Lloyd run (the bench-trajectory payload of
/// `BENCH_lloyd.json`; see `bench_harness` for the serialized schema).
#[derive(Clone, Debug)]
pub struct PruneStats {
    /// Lloyd iterations executed.
    pub iters: usize,
    /// Points (or grid cells) per iteration.
    pub points: u64,
    /// (point, centroid) distance evaluations actually performed.
    pub dist_evals: u64,
    /// Evaluations proven unnecessary by the bounds and skipped.
    pub dist_evals_skipped: u64,
    /// Phase-1 upper-bound tightening evaluations (one per point per
    /// bounded pass; included in `dist_evals`) — the per-policy pruning
    /// overhead.
    pub bound_evals: u64,
    /// Resolved bounds policy of the run (`"hamerly"` / `"elkan"`;
    /// `"none"` when pruning was disabled).
    pub bounds: &'static str,
    /// Distance-kernel precision of the run (`"f64"` / `"f32"`).
    pub precision: &'static str,
    /// Wall time of the whole run (seeding + all iterations).
    pub wall: Duration,
}

impl Default for PruneStats {
    /// Zero counters with the label contract intact: a run that never
    /// touched the engine reports `bounds = "none"`, `precision = "f64"`
    /// (never empty strings).
    fn default() -> Self {
        PruneStats {
            iters: 0,
            points: 0,
            dist_evals: 0,
            dist_evals_skipped: 0,
            bound_evals: 0,
            bounds: "none",
            precision: "f64",
            wall: Duration::default(),
        }
    }
}

impl PruneStats {
    /// Fraction of candidate evaluations skipped.
    pub fn skip_rate(&self) -> f64 {
        let total = self.dist_evals + self.dist_evals_skipped;
        if total == 0 {
            0.0
        } else {
            self.dist_evals_skipped as f64 / total as f64
        }
    }

    /// Assignment throughput: points × iterations / wall seconds.
    pub fn points_per_sec(&self) -> f64 {
        let s = self.wall.as_secs_f64();
        if s <= 0.0 {
            0.0
        } else {
            (self.points * self.iters as u64) as f64 / s
        }
    }
}

/// Resolve the worker-thread count (0 = auto).
pub(crate) fn resolve_threads(requested: usize) -> usize {
    if requested > 0 {
        return requested;
    }
    if let Ok(v) = std::env::var("RKMEANS_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(chunk_index, &mut work)` once for every work item, spreading the
/// items over `threads` scoped workers via an atomic cursor. Items are
/// mutated in place, so the caller reads results back in chunk order —
/// scheduling never affects the output (see the determinism contract).
pub(crate) fn run_chunks<W, F>(works: &mut [W], threads: usize, f: F)
where
    W: Send,
    F: Fn(usize, &mut W) + Sync,
{
    let t = threads.max(1).min(works.len());
    if t <= 1 {
        for (i, w) in works.iter_mut().enumerate() {
            f(i, w);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    let cells: Vec<Mutex<&mut W>> = works.iter_mut().map(Mutex::new).collect();
    std::thread::scope(|scope| {
        for _ in 0..t {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= cells.len() {
                    break;
                }
                // Each index is claimed exactly once, so the lock is
                // uncontended; it only exists to hand &mut across threads.
                let mut guard = cells[i].lock().expect("chunk lock");
                f(i, &mut **guard);
            });
        }
    });
}

/// Streaming scorer for fixed dense centroids: feed `(row, weight)` pairs,
/// get `Σ w·min_c d²(row, c)` back. Rows are buffered into contiguous
/// tiles and pushed through the shared microkernel, so the streaming
/// full-`X` objective pass reuses the same hot loop as the Lloyd engine.
pub struct CentroidScorer {
    d: usize,
    k: usize,
    /// `d × k` transposed centroids (microkernel layout).
    ct_t: Vec<f64>,
    cnorm: Vec<f64>,
    tile: Vec<f64>,
    wbuf: Vec<f64>,
    dots: Vec<f64>,
    fill: usize,
    obj: f64,
}

/// Rows buffered per scoring tile.
const SCORE_TILE: usize = 32;

impl CentroidScorer {
    /// Build a scorer over row-major `k × d` centroids.
    pub fn new(centroids: &[f64], d: usize) -> Self {
        assert!(d > 0, "dimension must be positive");
        assert_eq!(centroids.len() % d, 0, "centroids not a multiple of d");
        let k = centroids.len() / d;
        assert!(k > 0, "need at least one centroid");
        let mut ct_t = Vec::new();
        microkernel::transpose(centroids, d, k, &mut ct_t);
        let cnorm = centroids
            .chunks_exact(d)
            .map(|c| c.iter().map(|v| v * v).sum())
            .collect();
        CentroidScorer {
            d,
            k,
            ct_t,
            cnorm,
            tile: vec![0.0; SCORE_TILE * d],
            wbuf: vec![0.0; SCORE_TILE],
            dots: vec![0.0; SCORE_TILE * k],
            fill: 0,
            obj: 0.0,
        }
    }

    /// Score one row (length `d`) with weight `w`.
    pub fn push(&mut self, row: &[f64], w: f64) {
        debug_assert_eq!(row.len(), self.d);
        let p = self.fill;
        self.tile[p * self.d..(p + 1) * self.d].copy_from_slice(row);
        self.wbuf[p] = w;
        self.fill += 1;
        if self.fill == SCORE_TILE {
            self.flush();
        }
    }

    fn flush(&mut self) {
        let tp = self.fill;
        if tp == 0 {
            return;
        }
        let (d, k) = (self.d, self.k);
        microkernel::tile_dots(&self.tile[..tp * d], d, k, &self.ct_t, &mut self.dots);
        for p in 0..tp {
            let row = &self.tile[p * d..(p + 1) * d];
            let xn: f64 = row.iter().map(|v| v * v).sum();
            let (d1, _, _) =
                microkernel::best_two_expanded(xn, &self.dots[p * k..(p + 1) * k], &self.cnorm);
            self.obj += self.wbuf[p] * d1.max(0.0);
        }
        self.fill = 0;
    }

    /// Flush the partial tile and return the accumulated objective.
    pub fn finish(mut self) -> f64 {
        self.flush();
        self.obj
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::{assert_close, for_cases};
    use crate::util::SplitMix64;

    #[test]
    fn run_chunks_visits_every_item_once() {
        let mut works: Vec<u32> = vec![0; 37];
        run_chunks(&mut works, 4, |i, w| *w += i as u32 + 1);
        for (i, w) in works.iter().enumerate() {
            assert_eq!(*w, i as u32 + 1);
        }
        // Serial path too.
        let mut works: Vec<u32> = vec![0; 5];
        run_chunks(&mut works, 1, |i, w| *w = i as u32);
        assert_eq!(works, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn scorer_matches_naive_objective() {
        for_cases(20, |rng| {
            let d = 1 + rng.below(6) as usize;
            let k = 1 + rng.below(5) as usize;
            let n = 1 + rng.below(150) as usize;
            let pts: Vec<f64> = (0..n * d).map(|_| rng.uniform(-4.0, 4.0)).collect();
            let w: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 2.0)).collect();
            let cents: Vec<f64> = (0..k * d).map(|_| rng.uniform(-4.0, 4.0)).collect();

            let mut scorer = CentroidScorer::new(&cents, d);
            for i in 0..n {
                scorer.push(&pts[i * d..(i + 1) * d], w[i]);
            }
            let got = scorer.finish();
            let want = crate::cluster::lloyd::objective(&pts, &w, d, &cents);
            assert_close(got, want, 1e-9);
        });
    }

    #[test]
    fn stats_rates() {
        let s = PruneStats {
            iters: 2,
            points: 100,
            dist_evals: 30,
            dist_evals_skipped: 70,
            wall: Duration::from_secs(1),
            ..PruneStats::default()
        };
        assert_close(s.skip_rate(), 0.7, 1e-12);
        assert_close(s.points_per_sec(), 200.0, 1e-9);
        assert_eq!(PruneStats::default().skip_rate(), 0.0);
        assert_eq!(PruneStats::default().points_per_sec(), 0.0);
    }

    #[test]
    fn thread_resolution_prefers_explicit() {
        assert_eq!(resolve_threads(3), 3);
        assert!(resolve_threads(0) >= 1);
    }

    #[test]
    fn bounds_policy_resolution_and_labels() {
        assert_eq!(BoundsPolicy::Auto.resolve(ELKAN_AUTO_K - 1), BoundsPolicy::Hamerly);
        assert_eq!(BoundsPolicy::Auto.resolve(ELKAN_AUTO_K), BoundsPolicy::Elkan);
        assert_eq!(BoundsPolicy::Hamerly.resolve(1000), BoundsPolicy::Hamerly);
        assert_eq!(BoundsPolicy::Elkan.resolve(1), BoundsPolicy::Elkan);
        assert_eq!(BoundsPolicy::Elkan.label(), "elkan");
        assert_eq!(Precision::F32.label(), "f32");
    }

    #[test]
    fn scorer_handles_partial_tiles() {
        let mut rng = SplitMix64::new(4);
        let cents = vec![0.0, 0.0, 5.0, 5.0]; // k=2, d=2
        let mut scorer = CentroidScorer::new(&cents, 2);
        let mut want = 0.0;
        for _ in 0..(SCORE_TILE * 2 + 3) {
            let p = [rng.uniform(-1.0, 6.0), rng.uniform(-1.0, 6.0)];
            let d0 = p[0] * p[0] + p[1] * p[1];
            let d1 = (p[0] - 5.0) * (p[0] - 5.0) + (p[1] - 5.0) * (p[1] - 5.0);
            want += d0.min(d1);
            scorer.push(&p, 1.0);
        }
        assert_close(scorer.finish(), want, 1e-9);
    }
}
