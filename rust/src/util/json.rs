//! Minimal JSON support (the build environment is offline; no serde).
//!
//! Only the subset needed for the artifact manifest and metric snapshots:
//! objects, arrays, strings, numbers, booleans and null. The parser is a
//! straightforward recursive-descent implementation with byte-precise error
//! positions; the writer escapes strings per RFC 8259.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. `BTreeMap` keeps object key order deterministic, which
/// makes serialized manifests diff-friendly.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Smallest non-negative integer an f64 (and therefore a JSON number on
/// our wire) can NOT be trusted to carry: 2^53. Every integer strictly
/// below round-trips exactly; at 2^53 and above, distinct integers
/// collapse to the same f64, so both [`Json::count`] and
/// [`Json::as_usize`] treat the range as out of bounds. Counts that can
/// legitimately exceed it (state versions, category keys) travel as
/// decimal strings instead.
pub const JSON_EXACT_INT_LIMIT: u64 = 1 << 53;

impl Json {
    /// Interpret as f64 if numeric.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// Encode an integral count as a JSON number, checking that the
    /// value survives the f64 round-trip exactly. Panics at
    /// [`JSON_EXACT_INT_LIMIT`] (2^53) and above, and on negative
    /// input — silently corrupting a count on the wire is worse than
    /// aborting the dump.
    pub fn count<T>(n: T) -> Json
    where
        T: TryInto<u64> + Copy + fmt::Debug,
    {
        let v: u64 =
            n.try_into().unwrap_or_else(|_| panic!("count {n:?} is negative or exceeds u64"));
        assert!(
            v < JSON_EXACT_INT_LIMIT,
            "count {v} is not exactly representable as a JSON number (limit 2^53); \
             carry it as a decimal string instead"
        );
        Json::Num(v as f64)
    }

    /// Interpret as usize if a non-negative integral number strictly
    /// below 2^53. The bound is inclusive-exclusive on purpose: an f64
    /// equal to 2^53 may be a rounded 2^53+1, so the value is already
    /// ambiguous and gets rejected rather than guessed at.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(v) if *v >= 0.0 && v.fract() == 0.0 && *v < JSON_EXACT_INT_LIMIT as f64 => {
                Some(*v as usize)
            }
            _ => None,
        }
    }

    /// Interpret as string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Interpret as array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Interpret as object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub at: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, JsonError> {
        Err(JsonError {
            at: self.i,
            msg: msg.to_string(),
        })
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            self.err(&format!("expected '{}'", c as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("expected a value"),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            self.err(&format!("expected '{s}'"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).expect("ascii slice");
        match s.parse::<f64>() {
            Ok(v) => Ok(Json::Num(v)),
            Err(_) => self.err("bad number"),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return self.err("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| JsonError {
                                    at: self.i,
                                    msg: "bad \\u escape".into(),
                                })?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| JsonError {
                                at: self.i,
                                msg: "bad \\u escape".into(),
                            })?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a run of plain bytes (valid UTF-8 passes through).
                    let start = self.i;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i]).map_err(|_| JsonError {
                            at: start,
                            msg: "invalid utf-8".into(),
                        })?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        b: text.as_bytes(),
        i: 0,
    };
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(v) => {
                if v.fract() == 0.0 && v.abs() < 1e15 {
                    write!(f, "{}", *v as i64)
                } else {
                    write!(f, "{v}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(items) => {
                write!(f, "[")?;
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{it}")?;
                }
                write!(f, "]")
            }
            Json::Obj(map) => {
                write!(f, "{{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like_doc() {
        let text = r#"{"artifacts":[{"file":"lloyd_4096x32x16.hlo.txt","n":4096,"d":32,"k":16}],"version":1}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("version").unwrap().as_usize(), Some(1));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("n").unwrap().as_usize(), Some(4096));
        // Round-trip through Display.
        let again = parse(&v.to_string()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = parse(r#""a\n\t\"A\\""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"A\\"));
    }

    #[test]
    fn parses_numbers() {
        assert_eq!(parse("-3.5e2").unwrap().as_f64(), Some(-350.0));
        assert_eq!(parse("0").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn count_encodes_exact_integers_only() {
        assert_eq!(Json::count(0usize), Json::Num(0.0));
        assert_eq!(Json::count(4096u32), Json::Num(4096.0));
        assert_eq!(Json::count(JSON_EXACT_INT_LIMIT - 1), Json::Num((1u64 << 53) as f64 - 1.0));
    }

    #[test]
    #[should_panic(expected = "not exactly representable")]
    fn count_panics_at_the_exactness_limit() {
        let _ = Json::count(JSON_EXACT_INT_LIMIT);
    }

    #[test]
    #[should_panic(expected = "negative")]
    fn count_panics_on_negative_input() {
        let _ = Json::count(-1i64);
    }

    #[test]
    fn as_usize_rejects_values_past_the_exactness_limit() {
        // 2^53 + 1 parses to the f64 2^53 — the wire already lost the
        // distinction, so the ambiguous value must be refused.
        assert_eq!(parse("9007199254740993").unwrap().as_usize(), None);
        assert_eq!(parse("9007199254740992").unwrap().as_usize(), None);
        assert_eq!(parse("9007199254740991").unwrap().as_usize(), Some((1 << 53) - 1));
        assert_eq!(parse("-1").unwrap().as_usize(), None);
        assert_eq!(parse("1.5").unwrap().as_usize(), None);
    }

    #[test]
    fn nested_structures() {
        let v = parse(r#"{"a":[1,[2,{"b":null}],true]}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2], Json::Bool(true));
    }
}
