//! Two-pass Yannakakis message passing over a join tree.
//!
//! This is the workhorse of Steps 1 and 3 (paper §4.3): a single upward +
//! downward pass computes, for *every tuple of every base relation*, the
//! (weighted) number of full-join outputs it participates in — in time
//! linear in the database, never materializing the join. Per-attribute
//! marginals `w_j` (Eq. 3) then fall out by grouping those counts at the
//! attribute's owning relation.

use crate::data::{AttrType, Database, Relation};
use crate::query::{Feq, JoinTree};
use crate::util::FxHashMap;
use anyhow::{Context, Result};

use super::factor::Factor;

/// Per-tuple full-join participation counts.
#[derive(Clone, Debug)]
pub struct JoinCounts {
    /// `counts[node][row]` — weighted number of join outputs extending the
    /// row (0 for dangling tuples).
    pub counts: Vec<Vec<f64>>,
    /// Total weighted output size `|X|`.
    pub total: f64,
}

/// A per-attribute marginal weight function `w_j` (Eq. 3): the weight each
/// attribute value receives from the (unmaterialized) join output.
#[derive(Clone, Debug)]
pub enum Marginal {
    /// Continuous attribute: sorted `(value, weight)` pairs.
    Continuous(Vec<(f64, f64)>),
    /// Discrete attribute (Int/Cat): `(key, weight)` pairs sorted by key.
    Discrete(Vec<(u64, f64)>),
}

impl Marginal {
    /// Total weight mass (equals `|X|` for every attribute).
    pub fn mass(&self) -> f64 {
        match self {
            Marginal::Continuous(v) => v.iter().map(|(_, w)| w).sum(),
            Marginal::Discrete(v) => v.iter().map(|(_, w)| w).sum(),
        }
    }

    /// Number of distinct values with non-zero weight.
    pub fn support(&self) -> usize {
        match self {
            Marginal::Continuous(v) => v.len(),
            Marginal::Discrete(v) => v.len(),
        }
    }
}

/// Column indices in `rel` for the given attribute names.
fn col_indices(rel: &Relation, attrs: &[String]) -> Vec<usize> {
    attrs
        .iter()
        .map(|a| {
            rel.schema
                .index_of(a)
                .unwrap_or_else(|| panic!("attribute {a:?} missing from {}", rel.name))
        })
        .collect()
}

/// Extract the join key for a row into `buf`.
#[inline]
fn key_into(rel: &Relation, row: usize, cols: &[usize], buf: &mut Vec<u64>) {
    buf.clear();
    for &c in cols {
        buf.push(rel.col(c).key_u64(row));
    }
}

/// Upward pass: per-tuple products of child messages, and the upward
/// message of each node. Returns (tuple_up, msg_up).
fn upward(
    db: &Database,
    tree: &JoinTree,
) -> Result<(Vec<Vec<f64>>, Vec<Factor>)> {
    let n = tree.len();
    let mut tuple_up: Vec<Vec<f64>> = vec![Vec::new(); n];
    let mut msg_up: Vec<Factor> = vec![Factor::default(); n];
    let children: Vec<Vec<usize>> = (0..n).map(|u| tree.children(u)).collect();

    for &u in &tree.order {
        let rel = db
            .get(&tree.rel_names[u])
            .with_context(|| format!("relation {} missing", tree.rel_names[u]))?;
        let child_cols: Vec<(usize, Vec<usize>)> = children[u]
            .iter()
            .map(|&c| (c, col_indices(rel, &tree.sep[c])))
            .collect();
        let sep_cols = col_indices(rel, &tree.sep[u]);

        let mut up = vec![0.0; rel.n_rows()];
        let mut msg = Factor::new(tree.sep[u].clone());
        let mut buf: Vec<u64> = Vec::new();
        'rows: for row in 0..rel.n_rows() {
            let mut w = rel.weight(row);
            for (c, cols) in &child_cols {
                key_into(rel, row, cols, &mut buf);
                match msg_up[*c].data.get(buf.as_slice()) {
                    Some(&m) if m != 0.0 => w *= m,
                    _ => continue 'rows, // dangling: contributes nothing
                }
            }
            up[row] = w;
            if w != 0.0 {
                key_into(rel, row, &sep_cols, &mut buf);
                msg.add(buf.clone(), w);
            }
        }
        tuple_up[u] = up;
        msg_up[u] = msg;
    }
    Ok((tuple_up, msg_up))
}

/// Weighted output size `|X|` of the FEQ (upward pass only).
pub fn output_size(db: &Database, tree: &JoinTree) -> Result<f64> {
    let (tuple_up, _) = upward(db, tree)?;
    Ok(tuple_up[tree.root].iter().sum())
}

/// Full two-pass computation of per-tuple join counts.
pub fn full_join_counts(db: &Database, tree: &JoinTree) -> Result<JoinCounts> {
    let n = tree.len();
    let (tuple_up, msg_up) = upward(db, tree)?;
    let children: Vec<Vec<usize>> = (0..n).map(|u| tree.children(u)).collect();

    // Downward pass, parents before children (reverse removal order).
    let mut msg_down: Vec<Option<Factor>> = vec![None; n];
    let mut counts: Vec<Vec<f64>> = vec![Vec::new(); n];
    for &u in tree.order.iter().rev() {
        let rel = db.get(&tree.rel_names[u]).expect("checked in upward");
        let sep_cols = col_indices(rel, &tree.sep[u]);
        let child_cols: Vec<(usize, Vec<usize>)> = children[u]
            .iter()
            .map(|&c| (c, col_indices(rel, &tree.sep[c])))
            .collect();
        let nc = child_cols.len();

        let mut down_factors: Vec<Factor> = child_cols
            .iter()
            .map(|(c, _)| Factor::new(tree.sep[*c].clone()))
            .collect();
        let mut cnt = vec![0.0; rel.n_rows()];
        let mut buf: Vec<u64> = Vec::new();
        let mut child_m: Vec<f64> = vec![0.0; nc];

        for row in 0..rel.n_rows() {
            if tuple_up[u][row] == 0.0 {
                continue; // dangling rows never contribute
            }
            // Message from above (1 at the root).
            let from_above = match &msg_down[u] {
                None => 1.0,
                Some(f) => {
                    key_into(rel, row, &sep_cols, &mut buf);
                    match f.data.get(buf.as_slice()) {
                        Some(&m) => m,
                        None => 0.0,
                    }
                }
            };
            cnt[row] = tuple_up[u][row] * from_above;
            if nc == 0 || from_above == 0.0 {
                continue;
            }
            // Per-child message values for this row.
            for (i, (c, cols)) in child_cols.iter().enumerate() {
                key_into(rel, row, cols, &mut buf);
                child_m[i] = msg_up[*c].data.get(buf.as_slice()).copied().unwrap_or(0.0);
            }
            // prefix/suffix products so each child's "everything but me"
            // product is O(children), not O(children²).
            let base = rel.weight(row) * from_above;
            let mut suffix = vec![1.0; nc + 1];
            for i in (0..nc).rev() {
                suffix[i] = suffix[i + 1] * child_m[i];
            }
            let mut prefix = 1.0;
            for i in 0..nc {
                let without_me = base * prefix * suffix[i + 1];
                if without_me != 0.0 {
                    key_into(rel, row, &child_cols[i].1, &mut buf);
                    down_factors[i].add(buf.clone(), without_me);
                }
                prefix *= child_m[i];
            }
        }
        for ((c, _), f) in child_cols.iter().zip(down_factors) {
            msg_down[*c] = Some(f);
        }
        counts[u] = cnt;
    }

    let total = counts[tree.root].iter().sum();
    Ok(JoinCounts { counts, total })
}

/// Per-feature marginal weights `w_j` (Eq. 3), computed by grouping the
/// full-join counts at each feature's owning relation.
pub fn marginals(
    db: &Database,
    feq: &Feq,
    tree: &JoinTree,
    counts: &JoinCounts,
) -> Result<FxHashMap<String, Marginal>> {
    // `tree` indexes `counts` by construction; assert the correspondence.
    debug_assert_eq!(tree.len(), counts.counts.len());
    let _ = tree;
    let mut out = FxHashMap::default();
    for f in &feq.features {
        let owner = feq
            .owner_of(db, &f.attr)
            .with_context(|| format!("feature {:?} has no owner", f.attr))?;
        let rel = db.get(&feq.relations[owner]).expect("owner exists");
        let col = rel.schema.index_of(&f.attr).expect("owner contains attr");
        let cnt = &counts.counts[owner];
        let marginal = match rel.schema.attr(col).ty {
            // Numeric features (Double and Int) get continuous marginals —
            // they embed as a single coordinate and are clustered on the
            // number line by the 1-D DP. Only Cat features are one-hot.
            AttrType::Double | AttrType::Int => {
                let mut acc: FxHashMap<u64, f64> = FxHashMap::default();
                for row in 0..rel.n_rows() {
                    if cnt[row] != 0.0 {
                        let v = rel.value(row, col).as_f64();
                        *acc.entry(v.to_bits()).or_insert(0.0) += cnt[row];
                    }
                }
                // Bit-order first, then stable value sort: ties on value
                // (e.g. ±0.0) keep a content-determined order instead of
                // the map's storage order.
                let mut pairs: Vec<(f64, f64)> = crate::util::det::sorted_owned(acc)
                    .into_iter()
                    .map(|(b, w)| (f64::from_bits(b), w))
                    .collect();
                pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("finite feature values"));
                Marginal::Continuous(pairs)
            }
            AttrType::Cat => {
                let mut acc: FxHashMap<u64, f64> = FxHashMap::default();
                for row in 0..rel.n_rows() {
                    if cnt[row] != 0.0 {
                        *acc.entry(rel.col(col).key_u64(row)).or_insert(0.0) += cnt[row];
                    }
                }
                let pairs: Vec<(u64, f64)> = crate::util::det::sorted_owned(acc);
                Marginal::Discrete(pairs)
            }
        };
        out.insert(f.attr.clone(), marginal);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Attr, Schema, Value};
    use crate::query::Hypergraph;

    /// The paper's running example: product ⋈ transactions ⋈ store.
    fn retail_example() -> (Database, Feq) {
        let mut product = Relation::new(
            "product",
            Schema::new(vec![Attr::cat("item", 3), Attr::double("price")]),
        );
        product.push_row(&[Value::Cat(0), Value::Double(1.0)]);
        product.push_row(&[Value::Cat(1), Value::Double(2.0)]);
        product.push_row(&[Value::Cat(2), Value::Double(2.0)]);

        let mut store =
            Relation::new("store", Schema::new(vec![Attr::cat("store", 2), Attr::cat("zip", 2)]));
        store.push_row(&[Value::Cat(0), Value::Cat(0)]);
        store.push_row(&[Value::Cat(1), Value::Cat(1)]);

        let mut tx = Relation::new(
            "tx",
            Schema::new(vec![Attr::cat("item", 3), Attr::cat("store", 2), Attr::double("count")]),
        );
        tx.push_row(&[Value::Cat(0), Value::Cat(0), Value::Double(5.0)]);
        tx.push_row(&[Value::Cat(0), Value::Cat(1), Value::Double(7.0)]);
        tx.push_row(&[Value::Cat(1), Value::Cat(0), Value::Double(2.0)]);
        // Dangling: item 9 not in product — must not count. (domain allows)
        let mut db = Database::new();
        db.add(product);
        db.add(store);
        db.add(tx);
        let feq = Feq::with_features(
            &["tx", "product", "store"],
            &["item", "store", "price", "zip", "count"],
        );
        (db, feq)
    }

    fn tree_of(db: &Database, feq: &Feq) -> JoinTree {
        Hypergraph::from_feq(db, feq).join_tree().unwrap()
    }

    #[test]
    fn output_size_matches_bruteforce() {
        let (db, feq) = retail_example();
        let tree = tree_of(&db, &feq);
        // All 3 tx rows join successfully: |X| = 3.
        assert_eq!(output_size(&db, &tree).unwrap(), 3.0);
    }

    #[test]
    fn counts_per_tuple() {
        let (db, feq) = retail_example();
        let tree = tree_of(&db, &feq);
        let jc = full_join_counts(&db, &tree).unwrap();
        assert_eq!(jc.total, 3.0);
        // Counts are indexed by tree node = position in feq.relations
        // (tx=0, product=1, store=2).
        // product: item0 appears in 2 outputs, item1 in 1, item2 dangling.
        assert_eq!(jc.counts[1], vec![2.0, 1.0, 0.0]);
        // store: store0 twice, store1 once.
        assert_eq!(jc.counts[2], vec![2.0, 1.0]);
        // tx rows each appear exactly once.
        assert_eq!(jc.counts[0], vec![1.0, 1.0, 1.0]);
    }

    #[test]
    fn marginals_match_join_semantics() {
        let (db, feq) = retail_example();
        let tree = tree_of(&db, &feq);
        let jc = full_join_counts(&db, &tree).unwrap();
        let m = marginals(&db, &feq, &tree, &jc).unwrap();
        // Every marginal has the same mass |X| = 3.
        for f in &feq.features {
            let mg = &m[&f.attr];
            assert!((mg.mass() - 3.0).abs() < 1e-9, "attr {} mass {}", f.attr, mg.mass());
        }
        // price: 1.0 appears twice (item0), 2.0 once (item1).
        match &m["price"] {
            Marginal::Continuous(v) => assert_eq!(v, &vec![(1.0, 2.0), (2.0, 1.0)]),
            _ => panic!("price should be continuous"),
        }
        // item: 0 -> 2, 1 -> 1; item 2 absent.
        match &m["item"] {
            Marginal::Discrete(v) => assert_eq!(v, &vec![(0, 2.0), (1, 1.0)]),
            _ => panic!("item should be discrete"),
        }
    }

    #[test]
    fn weighted_tuples_scale_counts() {
        let (mut db, feq) = retail_example();
        // Double the multiplicity of the first tx row.
        {
            let tx = db.get_mut("tx").unwrap();
            let mut rows: Vec<(Vec<Value>, f64)> =
                (0..tx.n_rows()).map(|r| (tx.row(r), tx.weight(r))).collect();
            rows[0].1 = 2.0;
            let mut new_tx = Relation::new("tx", tx.schema.clone());
            for (vals, w) in rows {
                new_tx.push_row_weighted(&vals, w);
            }
            *tx = new_tx;
        }
        let tree = tree_of(&db, &feq);
        let jc = full_join_counts(&db, &tree).unwrap();
        assert_eq!(jc.total, 4.0);
    }

    #[test]
    fn empty_relation_gives_zero() {
        let (mut db, feq) = retail_example();
        *db.get_mut("tx").unwrap() = Relation::new(
            "tx",
            Schema::new(vec![Attr::cat("item", 3), Attr::cat("store", 2), Attr::double("count")]),
        );
        let tree = tree_of(&db, &feq);
        let jc = full_join_counts(&db, &tree).unwrap();
        assert_eq!(jc.total, 0.0);
    }
}
