//! The grid coreset `G = C_1 × … × C_m` (paper §3 Algorithm 1 + §4).
//!
//! * [`solve_subspaces`] — Step 2: per-feature optimal clustering of the
//!   marginals (1-D DP for continuous features, closed-form heavy/light for
//!   categorical ones; both are `α = 1` solvers).
//! * [`build_grid`] — Step 3: the sparse non-zero-weight grid via the
//!   free-variable FAQ ([`crate::faq::grid_weights`]), returned in the
//!   factored [`SparseGrid`] form Step 4 consumes. FD-chains compress the
//!   grid automatically (only consistent combinations occur in the data).
//! * [`build_grid_sharded`] — the same grid built from S fact shards in
//!   parallel on the shared pool and merged by exact weight addition
//!   (bitwise identical to [`build_grid`] under integer multiplicities).
//! * [`grid_dense_embed`] / [`centroids_dense`] — dense one-hot views of
//!   the coreset and of factored centroids, shared by the XLA hot path,
//!   the dense-Lloyd ablation, and full-`X` objective evaluation.
//! * [`eval_full_objective`] — streams the (unmaterialized) join output to
//!   score centroids on all of `X` with O(1) memory.

use crate::cluster::sparse_lloyd::{CentroidCoord, Components, SparseGrid, Subspace};
use crate::cluster::{categorical_kmeans, kmeans1d, CatClusters, CentroidScorer, Kmeans1dResult};
use crate::data::{Database, Value};
use crate::faq::{grid_weights, GidAssigner, GridTable, Marginal};
use crate::join::{stream_rows, EmbedSpec};
use crate::join::embed::EmbKind;
use crate::query::{Feq, JoinTree};
use crate::util::FxHashMap;
use anyhow::{Context, Result};

/// Step-2 solver output for one subspace.
#[derive(Clone, Debug)]
pub enum SubspaceSolver {
    Continuous(Kmeans1dResult),
    Categorical(CatClusters),
}

/// One solved subspace: solver + feature weight λ + bookkeeping.
#[derive(Clone, Debug)]
pub struct SubspaceModel {
    pub name: String,
    pub lambda: f64,
    pub solver: SubspaceSolver,
    /// Optimal Step-2 cost in this subspace, scaled by λ. Summed over
    /// subspaces this equals `W₂²(Q, P_in)` — the coreset quantization
    /// error of Eq. 9.
    pub cost: f64,
}

impl SubspaceModel {
    /// Number of components κ_j produced.
    pub fn n_gids(&self) -> usize {
        match &self.solver {
            SubspaceSolver::Continuous(r) => r.k(),
            SubspaceSolver::Categorical(c) => c.kappa(),
        }
    }

    /// Component geometry for the factored Step-4 solver.
    pub fn components(&self) -> Components {
        match &self.solver {
            SubspaceSolver::Continuous(r) => Components::Continuous { centers: r.centers.clone() },
            SubspaceSolver::Categorical(c) => Components::Categorical {
                norm_sq: (0..c.kappa() as u32).map(|g| c.component_norm_sq(g)).collect(),
            },
        }
    }

    /// Subspace description for [`sparse_lloyd`](crate::cluster::sparse_lloyd).
    pub fn subspace(&self) -> Subspace {
        Subspace { name: self.name.clone(), lambda: self.lambda, comp: self.components() }
    }

    /// Centroid id for a raw value.
    pub fn gid(&self, v: Value) -> u32 {
        match &self.solver {
            SubspaceSolver::Continuous(r) => r.assign(v.as_f64()),
            SubspaceSolver::Categorical(c) => c.gid(v.key_u64()),
        }
    }
}

impl GidAssigner for &SubspaceModel {
    fn gid(&self, v: Value) -> u32 {
        SubspaceModel::gid(self, v)
    }
    fn n_gids(&self) -> usize {
        SubspaceModel::n_gids(self)
    }
}

/// Step 2: optimally cluster every subspace marginal with κ centroids.
/// Continuous features use the exact 1-D DP; categorical features the
/// closed form of Theorem 4.4 — so `α = 1` throughout.
pub fn solve_subspaces(
    feq: &Feq,
    marginals: &FxHashMap<String, Marginal>,
    kappa: usize,
) -> Result<Vec<SubspaceModel>> {
    solve_subspaces_regularized(feq, marginals, kappa, 0.0)
}

/// Regularized Step 2 (paper §3 "Regularized Rk-means"): with atom
/// penalty ρ > 0 each subspace gets an *adaptive* κ_j ≤ κ minimizing
/// `λ_j·cost_j(κ') + ρ·κ'` (see [`crate::cluster::regularized`]), which
/// shrinks the grid coreset on low-information subspaces. ρ = 0 recovers
/// the unregularized solver exactly.
pub fn solve_subspaces_regularized(
    feq: &Feq,
    marginals: &FxHashMap<String, Marginal>,
    kappa: usize,
    rho: f64,
) -> Result<Vec<SubspaceModel>> {
    use crate::cluster::regularized::{categorical_regularized, kmeans1d_regularized};
    let mut models = Vec::with_capacity(feq.features.len());
    for f in &feq.features {
        let marginal = marginals
            .get(&f.attr)
            .with_context(|| format!("no marginal for feature {:?}", f.attr))?;
        let (solver, raw_cost) = match marginal {
            Marginal::Continuous(pts) => {
                let r = if rho > 0.0 {
                    kmeans1d_regularized(pts, kappa, f.weight, rho).0
                } else {
                    kmeans1d(pts, kappa)
                };
                let c = r.cost;
                (SubspaceSolver::Continuous(r), c)
            }
            Marginal::Discrete(pts) => {
                let c = if rho > 0.0 {
                    categorical_regularized(pts, kappa, f.weight, rho).0
                } else {
                    categorical_kmeans(pts, kappa)
                };
                let cost = c.cost;
                (SubspaceSolver::Categorical(c), cost)
            }
        };
        models.push(SubspaceModel {
            name: f.attr.clone(),
            lambda: f.weight,
            cost: f.weight * raw_cost,
            solver,
        });
    }
    Ok(models)
}

/// Step 3: the sparse weighted grid, in factored form, plus the subspace
/// geometry for Step 4. Cells are deterministic (sorted) so downstream
/// seeding is reproducible.
pub fn build_grid(
    db: &Database,
    feq: &Feq,
    tree: &JoinTree,
    models: &[SubspaceModel],
) -> Result<(SparseGrid, Vec<Subspace>)> {
    let mut assigners: FxHashMap<String, Box<dyn GidAssigner + '_>> = FxHashMap::default();
    for m in models {
        assigners.insert(m.name.clone(), Box::new(m));
    }
    let table = grid_weights(db, feq, tree, &assigners)?;
    Ok(sparse_from_table(table, models))
}

/// Sharded Step 3: partition the designated fact relation (the FEQ's
/// first relation) into `shards` value-hashed horizontal shards
/// ([`crate::faq::shard_databases`]), run the counting-FAQ grid-weight
/// pass per shard as independent jobs on the process-wide
/// [`ExecPool`](crate::util::exec::ExecPool), and merge the per-shard
/// tables by exact weight addition ([`GridTable::merge`]). With integer
/// tuple multiplicities (the ring-ℤ contract) the result is **bitwise
/// identical** to [`build_grid`] for any shard count; `shards <= 1`
/// delegates outright.
///
/// Shards are dispatched largest-fact-first
/// ([`ExecPool::run_chunks_ordered`](crate::util::exec::ExecPool::run_chunks_ordered))
/// so a Zipf-skewed partition doesn't leave one straggler holding the
/// merge; results are still merged in shard order, so the schedule never
/// affects the output. Must not be called from inside a pool worker (the
/// pool is not reentrant).
pub fn build_grid_sharded(
    db: &Database,
    feq: &Feq,
    tree: &JoinTree,
    models: &[SubspaceModel],
    shards: usize,
) -> Result<(SparseGrid, Vec<Subspace>)> {
    if shards <= 1 {
        return build_grid(db, feq, tree, models);
    }
    let fact = feq.relations.first().context("FEQ names no relations")?;
    let shard_dbs = crate::faq::shard_databases(db, fact, shards)?;
    let mut order: Vec<usize> = (0..shard_dbs.len()).collect();
    order.sort_by_key(|&s| {
        std::cmp::Reverse(shard_dbs[s].get(fact).map_or(0, |r| r.n_rows()))
    });
    let mut works: Vec<(Database, Option<Result<GridTable>>)> =
        shard_dbs.into_iter().map(|sdb| (sdb, None)).collect();
    let pool = crate::util::exec::shared_pool();
    pool.run_chunks_ordered(&mut works, 0, &order, |_, (sdb, out)| {
        // Assigner boxes are built inside the job (a `Box<dyn _>` map is
        // not `Sync`); they borrow the shared Step-2 models, which are.
        let mut assigners: FxHashMap<String, Box<dyn GidAssigner + '_>> =
            FxHashMap::default();
        for m in models {
            assigners.insert(m.name.clone(), Box::new(m));
        }
        *out = Some(grid_weights(sdb, feq, tree, &assigners));
    });
    let tables: Vec<GridTable> = works
        .into_iter()
        .map(|(_, out)| out.expect("every shard job ran"))
        .collect::<Result<_>>()?;
    let merged = GridTable::merge(tables)?;
    Ok(sparse_from_table(merged, models))
}

/// Convert a Step-3 grid-weight table into the factored [`SparseGrid`] +
/// subspace geometry Step 4 consumes, in the same deterministic (sorted)
/// cell order as [`build_grid`]. Shared with the incremental planner,
/// whose delta-maintained [`crate::incremental::DeltaFaq`] produces the
/// table without a from-scratch FAQ pass.
pub fn sparse_from_table(
    table: crate::faq::gridweights::GridTable,
    models: &[SubspaceModel],
) -> (SparseGrid, Vec<Subspace>) {
    let m = models.len();
    let mut cells = table.cells;
    // The planner's patch path hands over an already-sorted table every
    // batch (`DeltaFaq::grid_table`); an O(|G|) check beats re-sorting.
    if !cells.windows(2).all(|p| p[0].0 <= p[1].0) {
        cells.sort_by(|a, b| a.0.cmp(&b.0));
    }
    let mut gids = Vec::with_capacity(cells.len() * m);
    let mut weights = Vec::with_capacity(cells.len());
    for (g, w) in cells {
        debug_assert_eq!(g.len(), m);
        gids.extend_from_slice(&g);
        weights.push(w);
    }
    let subspaces: Vec<Subspace> = models.iter().map(|m| m.subspace()).collect();
    (SparseGrid { m, gids, weights }, subspaces)
}

/// Dense one-hot coordinates of one component of one subspace, written
/// into `out[offset..offset+width]` (scaled by √λ via `spec`).
fn component_into(model: &SubspaceModel, fe: &crate::join::FeatEmb, gid: u32, out: &mut [f64]) {
    let block = &mut out[fe.offset..fe.offset + fe.width];
    block.fill(0.0);
    match (&model.solver, fe.kind) {
        (SubspaceSolver::Continuous(r), EmbKind::Numeric) => {
            block[0] = fe.scale * r.centers[gid as usize];
        }
        (SubspaceSolver::Categorical(c), EmbKind::OneHot) => {
            if (gid as usize) < c.heavy.len() {
                block[c.heavy[gid as usize] as usize] = fe.scale;
            } else if c.has_light() {
                for &(e, w) in &c.light {
                    block[e as usize] = fe.scale * w / c.light_mass;
                }
            }
        }
        // Int features embed numerically but their marginal is discrete,
        // so they get the categorical solver — expand via key as numeric.
        (SubspaceSolver::Categorical(_), EmbKind::Numeric) => {
            unreachable!(
                "Int feature {:?} needs a numeric-capable solver; declare it Cat or Double",
                model.name
            )
        }
        (SubspaceSolver::Continuous(_), EmbKind::OneHot) => {
            unreachable!("continuous solver on one-hot embedding")
        }
    }
}

/// Dense embedding of every grid cell (`|G| × spec.dims`, row-major) — the
/// input to the dense-Lloyd ablation and the XLA hot path.
pub fn grid_dense_embed(grid: &SparseGrid, models: &[SubspaceModel], spec: &EmbedSpec) -> Vec<f64> {
    let n = grid.n();
    let d = spec.dims;
    let mut out = vec![0.0; n * d];
    for i in 0..n {
        let row = &grid.gids[i * grid.m..(i + 1) * grid.m];
        let dst = &mut out[i * d..(i + 1) * d];
        for (j, model) in models.iter().enumerate() {
            component_into(model, &spec.feats[j], row[j], dst);
        }
    }
    out
}

/// Expand factored centroids to dense one-hot coordinates (`k × spec.dims`).
pub fn centroids_dense(
    centroids: &[Vec<CentroidCoord>],
    models: &[SubspaceModel],
    spec: &EmbedSpec,
) -> Vec<f64> {
    let d = spec.dims;
    let mut out = vec![0.0; centroids.len() * d];
    let mut comp_buf = vec![0.0; d];
    for (c, coords) in centroids.iter().enumerate() {
        let dst = &mut out[c * d..(c + 1) * d];
        for (j, (coord, model)) in coords.iter().zip(models).enumerate() {
            let fe = &spec.feats[j];
            match coord {
                CentroidCoord::Continuous(mu) => dst[fe.offset] = fe.scale * mu,
                CentroidCoord::Categorical(beta) => {
                    // μ_j = Σ_a β_a · u_a (expand each component, weighted).
                    for (a, &b) in beta.iter().enumerate() {
                        if b == 0.0 {
                            continue;
                        }
                        component_into(model, fe, a as u32, &mut comp_buf);
                        for t in fe.offset..fe.offset + fe.width {
                            dst[t] += b * comp_buf[t];
                        }
                    }
                }
            }
        }
    }
    out
}

/// Evaluate the weighted k-means objective of dense centroids over the
/// *entire* (unmaterialized) join output by streaming rows. Memory is
/// O(D): rows are buffered into small tiles and scored through the shared
/// Step-4 engine microkernel ([`CentroidScorer`]), so the streaming pass
/// gets the same hoisted-norm distance expansion as the Lloyd hot loop.
/// Scores with the f64 kernel; see [`eval_full_objective_with`] for the
/// f32 tile path.
pub fn eval_full_objective(
    db: &Database,
    feq: &Feq,
    tree: &JoinTree,
    spec: &EmbedSpec,
    centroids: &[f64],
) -> Result<f64> {
    eval_full_objective_with(db, feq, tree, spec, centroids, crate::cluster::Precision::F64)
}

/// [`eval_full_objective`] with an explicit scorer precision:
/// [`Precision::F32`](crate::cluster::Precision::F32) runs the distance
/// contraction through the f32 tile kernel (2× SIMD lanes, f64 weight
/// accumulation) under the engine's
/// [`F32_OBJ_RTOL`](crate::cluster::F32_OBJ_RTOL) tolerance contract.
pub fn eval_full_objective_with(
    db: &Database,
    feq: &Feq,
    tree: &JoinTree,
    spec: &EmbedSpec,
    centroids: &[f64],
    precision: crate::cluster::Precision,
) -> Result<f64> {
    let d = spec.dims;
    let mut scorer = CentroidScorer::new_with(centroids, d, precision);
    let mut buf = vec![0.0; d];
    stream_rows(db, feq, tree, |vals, w| {
        spec.embed_into(vals, &mut buf);
        scorer.push(&buf, w);
    })?;
    Ok(scorer.finish())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{sparse_lloyd, LloydConfig};
    use crate::data::{Attr, Relation, Schema};
    use crate::faq::{full_join_counts, marginals};
    use crate::query::Hypergraph;
    use crate::util::testkit::assert_close;

    /// fact(item, store, units) ⋈ items(item, price): mixed-type features.
    fn setup() -> (Database, Feq, JoinTree) {
        let mut fact = Relation::new(
            "fact",
            Schema::new(vec![Attr::cat("item", 4), Attr::cat("store", 3), Attr::double("units")]),
        );
        for (i, s, u) in [
            (0u32, 0u32, 1.0),
            (0, 1, 1.5),
            (1, 0, 10.0),
            (1, 2, 10.5),
            (2, 1, 20.0),
            (3, 2, 20.5),
        ] {
            fact.push_row(&[Value::Cat(i), Value::Cat(s), Value::Double(u)]);
        }
        let mut items =
            Relation::new("items", Schema::new(vec![Attr::cat("item", 4), Attr::double("price")]));
        for (i, p) in [(0u32, 5.0), (1, 6.0), (2, 7.0), (3, 8.0)] {
            items.push_row(&[Value::Cat(i), Value::Double(p)]);
        }
        let mut db = Database::new();
        db.add(fact);
        db.add(items);
        let feq = Feq::with_features(&["fact", "items"], &["item", "store", "units", "price"]);
        let tree = Hypergraph::from_feq(&db, &feq).join_tree().unwrap();
        (db, feq, tree)
    }

    #[allow(clippy::type_complexity)]
    fn pipeline(
        kappa: usize,
    ) -> (Database, Feq, JoinTree, Vec<SubspaceModel>, SparseGrid, Vec<Subspace>) {
        let (db, feq, tree) = setup();
        let jc = full_join_counts(&db, &tree).unwrap();
        let m = marginals(&db, &feq, &tree, &jc).unwrap();
        let models = solve_subspaces(&feq, &m, kappa).unwrap();
        let (grid, subs) = build_grid(&db, &feq, &tree, &models).unwrap();
        (db, feq, tree, models, grid, subs)
    }

    #[test]
    fn grid_mass_equals_output_size() {
        let (_, _, _, models, grid, _) = pipeline(2);
        assert_eq!(models.len(), 4);
        assert_close(grid.weights.iter().sum::<f64>(), 6.0, 1e-9);
        // Every gid is within its subspace's component count.
        for i in 0..grid.n() {
            for (j, model) in models.iter().enumerate() {
                assert!((grid.gids[i * grid.m + j] as usize) < model.n_gids());
            }
        }
    }

    #[test]
    fn step2_cost_is_quantization_error() {
        // κ = |support| everywhere makes the coreset exact: step-2 cost 0.
        let (_, _, _, models, grid, _) = pipeline(8);
        let total: f64 = models.iter().map(|m| m.cost).sum();
        assert_close(total, 0.0, 1e-12);
        // Exact coreset: |G| = #distinct feature combinations = 6 rows.
        assert_eq!(grid.n(), 6);
    }

    #[test]
    fn grid_weights_match_bruteforce_assignment() {
        // For κ=2, recompute w_grid by materializing and assigning.
        let (db, feq, tree, models, grid, _) = pipeline(2);
        let x = crate::join::materialize(&db, &feq, &tree).unwrap();
        let mut expect: FxHashMap<Vec<u32>, f64> = FxHashMap::default();
        for (row, w) in x.rows.iter().zip(&x.weights) {
            let key: Vec<u32> = row.iter().zip(&models).map(|(v, m)| m.gid(*v)).collect();
            *expect.entry(key).or_insert(0.0) += w;
        }
        assert_eq!(grid.n(), expect.len());
        for i in 0..grid.n() {
            let key = grid.gids[i * grid.m..(i + 1) * grid.m].to_vec();
            assert_close(expect[&key], grid.weights[i], 1e-9);
        }
    }

    #[test]
    fn dense_embed_objective_matches_factored() {
        let (db, feq, _, models, grid, subs) = pipeline(2);
        let spec = EmbedSpec::from_feq(&db, &feq).unwrap();
        let cfg = LloydConfig { k: 2, max_iters: 10, tol: 0.0, seed: 3 };
        let res = sparse_lloyd(&grid, &subs, &cfg);

        // Dense re-evaluation of the factored result must agree.
        let dense_pts = grid_dense_embed(&grid, &models, &spec);
        let dense_cents = centroids_dense(&res.centroids, &models, &spec);
        let obj =
            crate::cluster::lloyd::objective(&dense_pts, &grid.weights, spec.dims, &dense_cents);
        assert_close(obj, res.objective, 1e-7);
    }

    #[test]
    fn full_objective_via_streaming_matches_materialized() {
        let (db, feq, tree, models, grid, subs) = pipeline(2);
        let spec = EmbedSpec::from_feq(&db, &feq).unwrap();
        let res = sparse_lloyd(&grid, &subs, &LloydConfig::new(2));
        let cents = centroids_dense(&res.centroids, &models, &spec);

        let streamed = eval_full_objective(&db, &feq, &tree, &spec, &cents).unwrap();
        let x = crate::join::materialize(&db, &feq, &tree).unwrap();
        let dense_x = spec.embed_matrix(&x);
        let direct = crate::cluster::lloyd::objective(&dense_x, &x.weights, spec.dims, &cents);
        assert_close(streamed, direct, 1e-9);
    }

    #[test]
    fn lambda_flows_through_subspace() {
        let (db, _, tree) = setup();
        let feq = Feq::new(
            &["fact", "items"],
            vec![
                crate::query::FeatureSpec::weighted("units", 9.0),
                crate::query::FeatureSpec::new("item"),
            ],
        );
        let jc = full_join_counts(&db, &tree).unwrap();
        let m = marginals(&db, &feq, &tree, &jc).unwrap();
        let models = solve_subspaces(&feq, &m, 2).unwrap();
        assert_eq!(models[0].lambda, 9.0);
        // Cost is scaled by λ.
        let unweighted =
            solve_subspaces(&Feq::with_features(&["fact", "items"], &["units", "item"]), &m, 2)
                .unwrap();
        assert_close(models[0].cost, 9.0 * unweighted[0].cost, 1e-9);
    }
}
