//! Step 4: weighted Lloyd over the grid coreset in *factored* form
//! (paper §4.3, Eqs. 36–38).
//!
//! A grid point is a tuple of per-subspace component ids `(g_1, …, g_m)`;
//! the component vectors of a categorical subspace (κ−1 one-hot "heavy"
//! singletons plus the weight-normalized "light" centroid) are **mutually
//! orthogonal**, so every Lloyd centroid — a convex combination of
//! component vectors — is fully described by its coefficient vector β per
//! subspace. Squared distances become
//!
//! ```text
//!   ‖u_a − μ‖² = ‖u_a‖² − 2·β_a·‖u_a‖² + Σ_b β_b²·‖u_b‖²
//! ```
//!
//! i.e. O(1) per (component, centroid) after a per-iteration `O(κ·k)`
//! table build — the paper's `O((|G| + D)·k·m·t)` bound, improving on the
//! generic `O(|G|·D·k·t)` dense Lloyd by the total categorical domain size.
//! Since grid points only enter distances through their component ids, the
//! assignment loop is `m` table lookups per (cell, centroid).
//!
//! This module owns the factored *data model*; the iteration itself runs
//! on the shared bounds-pruned, chunk-parallel Step-4 engine
//! ([`crate::cluster::engine::factored`]). [`sparse_lloyd`] uses the
//! production engine configuration; [`sparse_lloyd_with`] exposes the
//! engine options (naive reference, thread count) and the pruning
//! statistics.

use super::engine::factored::{lloyd_factored, lloyd_factored_init, lloyd_factored_resume};
use super::engine::{EngineOpts, EngineState, PruneStats};
use super::lloyd::LloydConfig;

/// Per-subspace component geometry (Step 2 output).
#[derive(Clone, Debug)]
pub enum Components {
    /// Continuous subspace: κ scalar centers from the optimal 1-D DP.
    Continuous { centers: Vec<f64> },
    /// Categorical subspace: squared norms of the κ orthogonal component
    /// vectors (1 for heavy singletons, `‖v‖₂²/‖v‖₁²` for the light one).
    Categorical { norm_sq: Vec<f64> },
}

impl Components {
    /// Number of components κ_j.
    pub fn len(&self) -> usize {
        match self {
            Components::Continuous { centers } => centers.len(),
            Components::Categorical { norm_sq } => norm_sq.len(),
        }
    }

    /// True when the subspace has no components (degenerate).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A subspace of the partition `[d] = S_1 ∪ … ∪ S_m` with its feature
/// weight λ (scales squared distances).
#[derive(Clone, Debug)]
pub struct Subspace {
    pub name: String,
    pub lambda: f64,
    pub comp: Components,
}

/// The grid coreset in component-id form.
#[derive(Clone, Debug)]
pub struct SparseGrid {
    /// Number of subspaces m.
    pub m: usize,
    /// Row-major `n × m` component ids.
    pub gids: Vec<u32>,
    /// Cell weights (sum = |X|).
    pub weights: Vec<f64>,
}

impl SparseGrid {
    /// Number of grid cells `|G|`.
    pub fn n(&self) -> usize {
        self.weights.len()
    }

    /// Component ids of cell `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[u32] {
        &self.gids[i * self.m..(i + 1) * self.m]
    }
}

/// One coordinate of a centroid in factored form.
#[derive(Clone, Debug)]
pub enum CentroidCoord {
    /// Continuous subspace: the scalar centroid coordinate.
    Continuous(f64),
    /// Categorical subspace: convex coefficients β over the κ components.
    Categorical(Vec<f64>),
}

/// Result of a factored Lloyd run.
#[derive(Clone, Debug)]
pub struct SparseLloydResult {
    /// `k × m` factored centroids.
    pub centroids: Vec<Vec<CentroidCoord>>,
    /// Cluster per grid cell.
    pub assign: Vec<u32>,
    /// Weighted objective over the coreset = W₂²(Q, P) in paper terms.
    pub objective: f64,
    pub iters: usize,
}

/// Squared distance between two grid cells (for seeding): orthogonality
/// makes the categorical case `‖u_a‖² + ‖u_b‖²` when `a ≠ b`.
pub(crate) fn cell_dist2(grid: &SparseGrid, subspaces: &[Subspace], i: usize, j: usize) -> f64 {
    let (ri, rj) = (grid.row(i), grid.row(j));
    let mut s = 0.0;
    for (jj, sub) in subspaces.iter().enumerate() {
        let (a, b) = (ri[jj] as usize, rj[jj] as usize);
        if a == b {
            continue;
        }
        s += sub.lambda
            * match &sub.comp {
                Components::Continuous { centers } => {
                    let t = centers[a] - centers[b];
                    t * t
                }
                Components::Categorical { norm_sq } => norm_sq[a] + norm_sq[b],
            };
    }
    s
}

/// Factored weighted Lloyd over the grid coreset (bounds-pruned,
/// chunk-parallel production engine).
pub fn sparse_lloyd(
    grid: &SparseGrid,
    subspaces: &[Subspace],
    cfg: &LloydConfig,
) -> SparseLloydResult {
    lloyd_factored(grid, subspaces, cfg, &EngineOpts::default()).0
}

/// Factored weighted Lloyd with explicit engine options; also returns the
/// pruning/throughput statistics ([`PruneStats`]).
pub fn sparse_lloyd_with(
    grid: &SparseGrid,
    subspaces: &[Subspace],
    cfg: &LloydConfig,
    opts: &EngineOpts,
) -> (SparseLloydResult, PruneStats) {
    lloyd_factored(grid, subspaces, cfg, opts)
}

/// [`sparse_lloyd_with`] plus an optional warm start: previous factored
/// centroids seed the run in place of k-means++ (shape mismatches fall
/// back to fresh seeding). The incremental planner's patch path uses this
/// so a delta-patched grid re-clusters in a couple of Lloyd iterations.
pub fn sparse_lloyd_warm_with(
    grid: &SparseGrid,
    subspaces: &[Subspace],
    cfg: &LloydConfig,
    opts: &EngineOpts,
    init: Option<&[Vec<CentroidCoord>]>,
) -> (SparseLloydResult, PruneStats) {
    lloyd_factored_init(grid, subspaces, cfg, opts, init)
}

/// [`sparse_lloyd_warm_with`] plus cross-run state carry: always returns
/// the run's carryable [`EngineState`] and accepts the previous run's
/// state so iteration 0 reuses its assignments and bounds (see
/// [`crate::cluster::engine`]'s "Cross-run state carry" docs for the
/// validity rules — notably, a stale state panics loudly). The
/// incremental planner's patch path splices the state across grid edits
/// and re-clusters through this entry point.
pub fn sparse_lloyd_resume_with(
    grid: &SparseGrid,
    subspaces: &[Subspace],
    cfg: &LloydConfig,
    opts: &EngineOpts,
    init: Option<&[Vec<CentroidCoord>]>,
    resume: Option<&EngineState>,
) -> (SparseLloydResult, PruneStats, EngineState) {
    lloyd_factored_resume(grid, subspaces, cfg, opts, init, resume)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::{assert_close, for_cases};

    /// A grid over one continuous subspace reduces to plain weighted 1-D
    /// k-means over the component centers.
    #[test]
    fn continuous_only_matches_dense_lloyd() {
        let subs = vec![Subspace {
            name: "x".into(),
            lambda: 1.0,
            comp: Components::Continuous { centers: vec![0.0, 1.0, 10.0, 11.0] },
        }];
        let grid = SparseGrid { m: 1, gids: vec![0, 1, 2, 3], weights: vec![1.0, 1.0, 1.0, 1.0] };
        let r = sparse_lloyd(&grid, &subs, &LloydConfig::new(2));
        // Optimal: {0,1} and {10,11}: cost 2·0.25 + 2·0.25 = 1.
        assert_close(r.objective, 1.0, 1e-9);
        let dense = crate::cluster::weighted_lloyd(
            &[0.0, 1.0, 10.0, 11.0],
            &[1.0; 4],
            1,
            &LloydConfig::new(2),
        );
        assert_close(r.objective, dense.objective, 1e-9);
    }

    /// Categorical geometry: one heavy + light component, hand-checked.
    #[test]
    fn categorical_distances_match_one_hot_algebra() {
        // Two components: heavy (‖u‖²=1) and light with ‖u‖² = 0.5.
        let subs = vec![Subspace {
            name: "c".into(),
            lambda: 1.0,
            comp: Components::Categorical { norm_sq: vec![1.0, 0.5] },
        }];
        // Two cells, one per component, equal weight; k = 1.
        let grid = SparseGrid { m: 1, gids: vec![0, 1], weights: vec![1.0, 1.0] };
        let r = sparse_lloyd(&grid, &subs, &LloydConfig { k: 1, ..LloydConfig::new(1) });
        // Centroid β = (0.5, 0.5). Distances:
        // d²(u_0, μ) = 1 − 2·0.5·1 + (0.25·1 + 0.25·0.5) = 0.375
        // d²(u_1, μ) = 0.5 − 2·0.5·0.5 + 0.375 = 0.375
        assert_close(r.objective, 0.75, 1e-9);
        let CentroidCoord::Categorical(beta) = &r.centroids[0][0] else { panic!() };
        assert_close(beta[0], 0.5, 1e-9);
    }

    /// The factored objective must equal a brute-force dense computation
    /// on explicitly embedded orthogonal component vectors.
    #[test]
    fn factored_matches_dense_embedding() {
        for_cases(20, |rng| {
            // Build 2 subspaces: 1 continuous (3 comps), 1 categorical
            // (3 comps: two heavy + one light of 2 cats with norm² 0.5).
            let centers =
                vec![rng.uniform(-2.0, 2.0), rng.uniform(-2.0, 2.0), rng.uniform(-2.0, 2.0)];
            let light_norm = 0.5; // two equal light cats: (w²+w²)/(2w)² = 1/2
            let subs = vec![
                Subspace {
                    name: "x".into(),
                    lambda: 1.0,
                    comp: Components::Continuous { centers: centers.clone() },
                },
                Subspace {
                    name: "c".into(),
                    lambda: 1.0,
                    comp: Components::Categorical { norm_sq: vec![1.0, 1.0, light_norm] },
                },
            ];
            // Dense embedding: continuous -> 1 dim; categorical -> 4 dims
            // (heavy cats e0, e1; light cats e2, e3 with coords 0.5 each).
            let embed = |g: &[u32]| -> Vec<f64> {
                let mut v = vec![0.0; 5];
                v[0] = centers[g[0] as usize];
                match g[1] {
                    0 => v[1] = 1.0,
                    1 => v[2] = 1.0,
                    2 => {
                        v[3] = 0.5;
                        v[4] = 0.5;
                    }
                    _ => unreachable!(),
                }
                v
            };
            let n = 6 + rng.below(10) as usize;
            let mut gids = Vec::new();
            let mut weights = Vec::new();
            for _ in 0..n {
                gids.push(rng.below(3) as u32);
                gids.push(rng.below(3) as u32);
                weights.push(rng.uniform(0.2, 3.0));
            }
            let grid = SparseGrid { m: 2, gids, weights: weights.clone() };
            let k = 2;
            let cfg = LloydConfig { k, max_iters: 8, tol: 0.0, seed: 77 };
            let r = sparse_lloyd(&grid, &subs, &cfg);

            // Recompute the objective densely from the factored centroids.
            let mut dense_centroids = vec![vec![0.0; 5]; k];
            for (c, dc) in dense_centroids.iter_mut().enumerate() {
                let CentroidCoord::Continuous(mu) = &r.centroids[c][0] else { panic!() };
                dc[0] = *mu;
                let CentroidCoord::Categorical(beta) = &r.centroids[c][1] else { panic!() };
                dc[1] = beta[0];
                dc[2] = beta[1];
                dc[3] = beta[2] * 0.5;
                dc[4] = beta[2] * 0.5;
            }
            let mut obj = 0.0;
            for i in 0..grid.n() {
                let x = embed(grid.row(i));
                let mut best = f64::INFINITY;
                for dc in &dense_centroids {
                    let d: f64 = x.iter().zip(dc).map(|(a, b)| (a - b) * (a - b)).sum();
                    best = best.min(d);
                }
                obj += grid.weights[i] * best;
            }
            assert_close(obj, r.objective, 1e-7);
        });
    }

    #[test]
    fn lambda_scales_objective() {
        let subs = |lam: f64| {
            vec![Subspace {
                name: "x".into(),
                lambda: lam,
                comp: Components::Continuous { centers: vec![0.0, 2.0] },
            }]
        };
        let grid = SparseGrid { m: 1, gids: vec![0, 1], weights: vec![1.0, 1.0] };
        let cfg = LloydConfig { k: 1, ..LloydConfig::new(1) };
        let r1 = sparse_lloyd(&grid, &subs(1.0), &cfg);
        let r4 = sparse_lloyd(&grid, &subs(4.0), &cfg);
        assert_close(r4.objective, 4.0 * r1.objective, 1e-9);
    }

    #[test]
    fn monotone_objective() {
        for_cases(10, |rng| {
            let kj = 4;
            let subs = vec![
                Subspace {
                    name: "a".into(),
                    lambda: 1.0,
                    comp: Components::Continuous {
                        centers: (0..kj).map(|_| rng.uniform(-3.0, 3.0)).collect(),
                    },
                },
                Subspace {
                    name: "b".into(),
                    lambda: 1.0,
                    comp: Components::Categorical {
                        norm_sq: (0..kj).map(|_| rng.uniform(0.3, 1.0)).collect(),
                    },
                },
            ];
            let n = 10 + rng.below(20) as usize;
            let mut gids = Vec::new();
            let mut weights = Vec::new();
            for _ in 0..n {
                gids.push(rng.below(kj as u64) as u32);
                gids.push(rng.below(kj as u64) as u32);
                weights.push(rng.uniform(0.1, 2.0));
            }
            let grid = SparseGrid { m: 2, gids, weights };
            let mut last = f64::INFINITY;
            for iters in 1..=5 {
                let cfg = LloydConfig { k: 3, max_iters: iters, tol: 0.0, seed: 13 };
                let r = sparse_lloyd(&grid, &subs, &cfg);
                assert!(r.objective <= last + 1e-9);
                last = r.objective;
            }
        });
    }

    #[test]
    fn k_one_centroid_is_weighted_mean() {
        let subs = vec![Subspace {
            name: "x".into(),
            lambda: 1.0,
            comp: Components::Continuous { centers: vec![0.0, 4.0] },
        }];
        let grid = SparseGrid { m: 1, gids: vec![0, 1], weights: vec![3.0, 1.0] };
        let r = sparse_lloyd(&grid, &subs, &LloydConfig { k: 1, ..LloydConfig::new(1) });
        let CentroidCoord::Continuous(mu) = &r.centroids[0][0] else { panic!() };
        assert_close(*mu, 1.0, 1e-9);
    }

    #[test]
    fn stats_report_full_scan_work_for_naive() {
        let subs = vec![Subspace {
            name: "x".into(),
            lambda: 1.0,
            comp: Components::Continuous { centers: vec![0.0, 1.0, 10.0, 11.0] },
        }];
        let grid = SparseGrid { m: 1, gids: vec![0, 1, 2, 3], weights: vec![1.0; 4] };
        let cfg = LloydConfig { k: 2, max_iters: 3, tol: 0.0, seed: 1 };
        let (_, stats) =
            sparse_lloyd_with(&grid, &subs, &cfg, &crate::cluster::EngineOpts::naive_serial());
        assert_eq!(stats.dist_evals, 4 * 2 * 3); // n·k per iteration
        assert_eq!(stats.dist_evals_skipped, 0);
        assert_eq!(stats.points, 4);
        assert_eq!(stats.iters, 3);
    }
}
