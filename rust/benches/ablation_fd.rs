//! Bench A1 — FD-chain ablation (paper §4.2, Theorem 4.6): the number of
//! non-zero-weight grid cells on Retailer's `zip → city → state` chain vs
//! the naive κ^d cross-product and the Π(1 + dᵢ(κ−1)) bound.

use rkmeans::bench_harness::paper::{ablation_fd, PaperCfg};

fn main() -> anyhow::Result<()> {
    let scale: f64 =
        std::env::var("RKMEANS_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.05);
    let cfg = PaperCfg::new(scale);
    println!("{}", ablation_fd(&cfg)?.render());
    Ok(())
}
