//! Deterministic insert/delete trace generators for the streaming /
//! incremental benchmarks and tests.
//!
//! A trace is a sequence of delta batches over a dataset's **fact table**:
//! inserts are drawn with the same shape as the dataset's generator
//! (Zipf-skewed keys, realistic value ranges), deletes always target a
//! tuple known to exist (tracked in a live pool seeded from the base
//! table), so a trace replays cleanly through both the incremental engine
//! and the ring-style [`Relation::retract_row`](crate::data::Relation)
//! path. Everything is seeded via [`crate::util::SplitMix64`], so a
//! `(db, seed, spec)` triple always produces the same trace — the bench
//! and the property suite share these generators.

use crate::data::{Database, Value};
use crate::incremental::TupleDelta;
use crate::util::{SplitMix64, Zipf};

/// Shape of a generated trace.
#[derive(Clone, Copy, Debug)]
pub struct TraceSpec {
    /// Number of delta batches.
    pub batches: usize,
    /// Deltas per batch.
    pub batch_size: usize,
    /// Fraction of deltas that are deletes (the rest are inserts).
    pub delete_frac: f64,
}

impl TraceSpec {
    /// A trace of `batches` × `batch_size` with ~30 % deletes.
    pub fn new(batches: usize, batch_size: usize) -> TraceSpec {
        TraceSpec { batches, batch_size, delete_frac: 0.3 }
    }
}

/// Generic fact-table trace: deletes sample uniformly from the live pool
/// (base rows + prior inserts), inserts come from `fresh(rng)`.
fn fact_trace(
    db: &Database,
    fact: &str,
    seed: u64,
    spec: TraceSpec,
    mut fresh: impl FnMut(&mut SplitMix64) -> Vec<Value>,
) -> Vec<Vec<TupleDelta>> {
    let rel = db.get(fact).unwrap_or_else(|| panic!("fact relation {fact:?} missing"));
    let mut pool: Vec<Vec<Value>> = (0..rel.n_rows())
        .filter(|&r| rel.weight(r) != 0.0)
        .map(|r| rel.row(r))
        .collect();
    let mut rng = SplitMix64::new(seed ^ 0x7ace_7ace_7ace_7ace);
    let mut out = Vec::with_capacity(spec.batches);
    for _ in 0..spec.batches {
        let mut batch = Vec::with_capacity(spec.batch_size);
        for _ in 0..spec.batch_size {
            if !pool.is_empty() && rng.coin(spec.delete_frac) {
                let i = rng.below(pool.len() as u64) as usize;
                let vals = pool.swap_remove(i);
                batch.push(TupleDelta::delete(fact, vals));
            } else {
                let vals = fresh(&mut rng);
                pool.push(vals.clone());
                batch.push(TupleDelta::insert(fact, vals));
            }
        }
        out.push(batch);
    }
    out
}

/// Insert/delete trace over the Retailer `inventory` fact table.
/// Inserts mirror [`super::retailer::generate`]'s Zipf-skewed shape;
/// domain sizes are read off the base table's schema so the trace always
/// matches the database it was generated against.
pub fn retailer_trace(db: &Database, seed: u64, spec: TraceSpec) -> Vec<Vec<TupleDelta>> {
    let inv = db.get("inventory").expect("retailer database has inventory");
    let stores = inv.schema.attr(0).domain.max(1) as u64;
    let dates = inv.schema.attr(1).domain.max(1) as u64;
    let skus = inv.schema.attr(2).domain.max(1) as usize;
    let sku_zipf = Zipf::new(skus, 1.1);
    fact_trace(db, "inventory", seed, spec, move |rng| {
        let sku = sku_zipf.sample(rng);
        let base = 40.0 / (1.0 + sku as f64).sqrt();
        vec![
            Value::Cat(rng.below(stores) as u32),
            Value::Cat(rng.below(dates) as u32),
            Value::Cat(sku as u32),
            Value::Double((base * rng.uniform(0.2, 2.0)).round().max(0.0)),
        ]
    })
}

/// Insert/delete trace over the Favorita `sales` fact table
/// (`date, store, item, unit_sales, onpromotion`).
pub fn favorita_trace(db: &Database, seed: u64, spec: TraceSpec) -> Vec<Vec<TupleDelta>> {
    let sales = db.get("sales").expect("favorita database has sales");
    let dates = sales.schema.attr(0).domain.max(1) as u64;
    let stores = sales.schema.attr(1).domain.max(1) as u64;
    let items = sales.schema.attr(2).domain.max(1) as usize;
    let item_zipf = Zipf::new(items, 1.05);
    fact_trace(db, "sales", seed, spec, move |rng| {
        vec![
            Value::Cat(rng.below(dates) as u32),
            Value::Cat(rng.below(stores) as u32),
            Value::Cat(item_zipf.sample(rng) as u32),
            Value::Double(((2.0 + rng.normal()).exp() * 4.0).round() / 4.0),
            Value::Cat(u32::from(rng.coin(0.08))),
        ]
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::incremental::apply_to_db;
    use crate::synthetic::{favorita, retailer, Scale};

    #[test]
    fn traces_are_deterministic() {
        let db = retailer::generate(Scale::tiny(), 1);
        let spec = TraceSpec::new(3, 16);
        let a = retailer_trace(&db, 9, spec);
        let b = retailer_trace(&db, 9, spec);
        assert_eq!(a.len(), 3);
        for (ba, bb) in a.iter().zip(&b) {
            assert_eq!(ba.len(), 16);
            for (da, db_) in ba.iter().zip(bb) {
                assert_eq!(da.relation, db_.relation);
                assert_eq!(da.weight, db_.weight);
                assert_eq!(da.values, db_.values);
            }
        }
        // Different seeds differ somewhere.
        let c = retailer_trace(&db, 10, spec);
        let flat = |t: &Vec<Vec<TupleDelta>>| -> Vec<String> {
            t.iter().flatten().map(|d| format!("{:?}{:?}", d.values, d.weight)).collect()
        };
        assert_ne!(flat(&a), flat(&c));
    }

    #[test]
    fn traces_replay_cleanly_onto_the_database() {
        for (db, trace) in [
            {
                let db = retailer::generate(Scale::tiny(), 2);
                let spec = TraceSpec { batches: 4, batch_size: 24, delete_frac: 0.4 };
                let t = retailer_trace(&db, 5, spec);
                (db, t)
            },
            {
                let db = favorita::generate(Scale::tiny(), 2);
                let spec = TraceSpec { batches: 4, batch_size: 24, delete_frac: 0.4 };
                let t = favorita_trace(&db, 5, spec);
                (db, t)
            },
        ] {
            let mut db = db;
            // Every delete must find its tuple: apply_to_db errors otherwise.
            for batch in &trace {
                apply_to_db(&mut db, batch).unwrap();
            }
        }
    }

    #[test]
    fn delete_fraction_is_roughly_respected() {
        let db = retailer::generate(Scale::tiny(), 3);
        let trace =
            retailer_trace(&db, 4, TraceSpec { batches: 2, batch_size: 200, delete_frac: 0.3 });
        let total: usize = trace.iter().map(|b| b.len()).sum();
        let deletes: usize =
            trace.iter().flatten().filter(|d| d.is_delete()).count();
        let frac = deletes as f64 / total as f64;
        assert!((0.15..0.45).contains(&frac), "delete fraction {frac}");
    }
}
