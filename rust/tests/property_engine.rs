//! Property suite for the Step-4 engine's determinism contract: on seeded
//! random weighted inputs, the bounds-pruned, chunk-parallel engine must
//! produce **identical** assignments, centroids and objective to the
//! retained naive serial reference — for both the dense and the factored
//! form, for both bounds policies (Hamerly and Elkan), across thread
//! counts, and across the multi-chunk boundary. The f32 tile path obeys
//! the same contract within its precision, and its objective stays within
//! the documented tolerance of f64 on the synthetic paper workloads.
//!
//! The `RKMEANS_PRECISION=f32` environment variable reruns the main
//! equality properties through the f32 kernels (the CI matrix's
//! f32-precision leg).

use rkmeans::cluster::engine::dense::{lloyd_dense_init, lloyd_dense_resume};
use rkmeans::cluster::engine::CHUNK;
use rkmeans::cluster::sparse_lloyd::{Components, SparseGrid, Subspace};
use rkmeans::cluster::{
    sparse_lloyd_resume_with, sparse_lloyd_warm_with, sparse_lloyd_with, weighted_lloyd_with,
    BoundsPolicy, CentroidCoord, EngineOpts, Executor, LloydConfig, Precision, F32_OBJ_RTOL,
};
use rkmeans::join::{materialize, EmbedSpec};
use rkmeans::query::Hypergraph;
use rkmeans::synthetic::{Dataset, Scale};
use rkmeans::util::exec::ExecPool;
use rkmeans::util::testkit::for_cases;
use rkmeans::util::SplitMix64;

/// Apply the CI matrix's precision selection (`RKMEANS_PRECISION=f32`)
/// to an engine configuration; the equality properties below hold within
/// either precision.
fn env_precision(opts: EngineOpts) -> EngineOpts {
    match std::env::var("RKMEANS_PRECISION").as_deref() {
        Ok("f32") => opts.with_precision(Precision::F32),
        _ => opts,
    }
}

/// Mixed blob + uniform points with random weights: blobs give the
/// pruning something to skip, the uniform fraction keeps assignments
/// churning so full scans and skips interleave.
fn dense_input(rng: &mut SplitMix64, n: usize, d: usize) -> (Vec<f64>, Vec<f64>) {
    let blobs = 5usize;
    let centers: Vec<f64> = (0..blobs * d).map(|_| rng.uniform(-6.0, 6.0)).collect();
    let mut pts = Vec::with_capacity(n * d);
    for _ in 0..n {
        if rng.coin(0.8) {
            let b = rng.below(blobs as u64) as usize;
            for j in 0..d {
                pts.push(centers[b * d + j] + 0.4 * rng.normal());
            }
        } else {
            for _ in 0..d {
                pts.push(rng.uniform(-8.0, 8.0));
            }
        }
    }
    let w: Vec<f64> = (0..n).map(|_| rng.uniform(0.05, 3.0)).collect();
    (pts, w)
}

fn grid_input(rng: &mut SplitMix64, n: usize) -> (SparseGrid, Vec<Subspace>) {
    let m = 1 + rng.below(4) as usize;
    let mut subs = Vec::with_capacity(m);
    for j in 0..m {
        let kj = 2 + rng.below(8) as usize;
        let comp = if rng.coin(0.5) {
            Components::Continuous {
                centers: (0..kj).map(|_| rng.uniform(-10.0, 10.0)).collect(),
            }
        } else {
            Components::Categorical {
                norm_sq: (0..kj).map(|_| rng.uniform(0.2, 1.0)).collect(),
            }
        };
        subs.push(Subspace { name: format!("s{j}"), lambda: rng.uniform(0.3, 3.0), comp });
    }
    let kappas: Vec<usize> = subs.iter().map(|s| s.comp.len()).collect();
    let mut gids = Vec::with_capacity(n * m);
    let mut weights = Vec::with_capacity(n);
    for _ in 0..n {
        for &kj in &kappas {
            gids.push(rng.below(kj as u64) as u32);
        }
        weights.push(rng.uniform(0.05, 4.0));
    }
    (SparseGrid { m, gids, weights }, subs)
}

fn assert_factored_centroids_equal(
    a: &[Vec<CentroidCoord>],
    b: &[Vec<CentroidCoord>],
) {
    assert_eq!(a.len(), b.len());
    for (ca, cb) in a.iter().zip(b) {
        assert_eq!(ca.len(), cb.len());
        for (xa, xb) in ca.iter().zip(cb) {
            match (xa, xb) {
                (CentroidCoord::Continuous(u), CentroidCoord::Continuous(v)) => {
                    assert_eq!(u.to_bits(), v.to_bits())
                }
                (CentroidCoord::Categorical(u), CentroidCoord::Categorical(v)) => {
                    assert_eq!(u.len(), v.len());
                    for (p, q) in u.iter().zip(v) {
                        assert_eq!(p.to_bits(), q.to_bits());
                    }
                }
                _ => panic!("centroid kind mismatch"),
            }
        }
    }
}

#[test]
fn dense_pruned_parallel_equals_naive_serial() {
    for_cases(20, |rng| {
        let n = 30 + rng.below(800) as usize;
        let d = 1 + rng.below(6) as usize;
        let k = 1 + rng.below(9) as usize;
        let (pts, w) = dense_input(rng, n, d);
        // Mix converged and capped runs: tol 0 forces every iteration,
        // a finite tol exercises the early-stop path. Alternate bounds
        // policies so both prune paths hit the same contract.
        let tol = if rng.coin(0.5) { 0.0 } else { 1e-6 };
        let bounds = if rng.coin(0.5) { BoundsPolicy::Hamerly } else { BoundsPolicy::Elkan };
        let iters = 1 + rng.below(12) as usize;
        let cfg = LloydConfig { k, max_iters: iters, tol, seed: rng.next_u64() };
        let naive = env_precision(EngineOpts::naive_serial());
        let pruned = env_precision(EngineOpts::pruned().with_bounds(bounds).with_threads(4));
        let (a, sa) = weighted_lloyd_with(&pts, &w, d, &cfg, &naive);
        let (b, sb) = weighted_lloyd_with(&pts, &w, d, &cfg, &pruned);
        assert_eq!(a.assign, b.assign);
        assert_eq!(a.centroids, b.centroids);
        assert_eq!(a.objective.to_bits(), b.objective.to_bits());
        assert_eq!(a.iters, b.iters);
        // Work accounting: the pruned path pays at most one extra
        // (ub-tightening) evaluation per point per iteration on top of
        // whatever the naive reference would have done.
        assert!(sb.dist_evals <= sa.dist_evals + sb.points * sb.iters as u64);
        assert_eq!(sa.dist_evals_skipped, 0);
    });
}

#[test]
fn factored_pruned_parallel_equals_naive_serial() {
    for_cases(20, |rng| {
        let n = 20 + rng.below(600) as usize;
        let (grid, subs) = grid_input(rng, n);
        let k = 1 + rng.below(8) as usize;
        let tol = if rng.coin(0.5) { 0.0 } else { 1e-6 };
        let bounds = if rng.coin(0.5) { BoundsPolicy::Hamerly } else { BoundsPolicy::Elkan };
        let iters = 1 + rng.below(10) as usize;
        let cfg = LloydConfig { k, max_iters: iters, tol, seed: rng.next_u64() };
        let naive = env_precision(EngineOpts::naive_serial());
        let pruned = env_precision(EngineOpts::pruned().with_bounds(bounds).with_threads(4));
        let (a, sa) = sparse_lloyd_with(&grid, &subs, &cfg, &naive);
        let (b, sb) = sparse_lloyd_with(&grid, &subs, &cfg, &pruned);
        assert_eq!(a.assign, b.assign);
        assert_eq!(a.objective.to_bits(), b.objective.to_bits());
        assert_eq!(a.iters, b.iters);
        assert_factored_centroids_equal(&a.centroids, &b.centroids);
        assert!(sb.dist_evals <= sa.dist_evals + sb.points * sb.iters as u64);
        assert_eq!(sa.dist_evals_skipped, 0);
    });
}

#[test]
fn dense_multi_chunk_thread_count_invariant() {
    // Cross the CHUNK boundary so the parallel reduction actually has
    // multiple chunk accumulators to combine, and check every thread
    // count reduces to identical bits (including the naive reference).
    let mut rng = SplitMix64::new(0xFEED);
    let n = CHUNK + CHUNK / 2;
    let d = 3;
    let (pts, w) = dense_input(&mut rng, n, d);
    let cfg = LloydConfig { k: 7, max_iters: 6, tol: 0.0, seed: 99 };
    let (base, _) = weighted_lloyd_with(&pts, &w, d, &cfg, &EngineOpts::naive_serial());
    for threads in [1usize, 2, 3, 8] {
        let opts = EngineOpts::pruned().with_threads(threads);
        let (r, stats) = weighted_lloyd_with(&pts, &w, d, &cfg, &opts);
        assert_eq!(base.assign, r.assign, "threads={threads}");
        assert_eq!(base.centroids, r.centroids, "threads={threads}");
        assert_eq!(base.objective.to_bits(), r.objective.to_bits(), "threads={threads}");
        assert_eq!(stats.points, n as u64);
    }
}

#[test]
fn factored_multi_chunk_thread_count_invariant() {
    let mut rng = SplitMix64::new(0xBEEF);
    let (grid, subs) = grid_input(&mut rng, CHUNK + 321);
    let cfg = LloydConfig { k: 6, max_iters: 5, tol: 0.0, seed: 4242 };
    let (base, _) = sparse_lloyd_with(&grid, &subs, &cfg, &EngineOpts::naive_serial());
    for threads in [1usize, 2, 5] {
        let opts = EngineOpts::pruned().with_threads(threads);
        let (r, _) = sparse_lloyd_with(&grid, &subs, &cfg, &opts);
        assert_eq!(base.assign, r.assign, "threads={threads}");
        assert_eq!(base.objective.to_bits(), r.objective.to_bits(), "threads={threads}");
        assert_factored_centroids_equal(&base.centroids, &r.centroids);
    }
}

#[test]
fn elkan_reseed_invalidation_stays_bitwise() {
    // Duplicate-heavy inputs with k above the number of distinct
    // locations force empty clusters, so the reseed path (which
    // invalidates all carried bounds) fires repeatedly — Elkan's O(n·k)
    // rows must rebuild exactly like Hamerly's global bound does.
    for_cases(12, |rng| {
        let d = 1 + rng.below(4) as usize;
        let distinct = 2 + rng.below(4) as usize; // 2..=5 locations
        let k = distinct + 1 + rng.below(4) as usize; // k > distinct
        let centers: Vec<f64> = (0..distinct * d).map(|_| rng.uniform(-5.0, 5.0)).collect();
        let n = 40 + rng.below(200) as usize;
        let mut pts = Vec::with_capacity(n * d);
        for _ in 0..n {
            let b = rng.below(distinct as u64) as usize;
            pts.extend_from_slice(&centers[b * d..(b + 1) * d]);
        }
        let w: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 2.0)).collect();
        let cfg = LloydConfig { k, max_iters: 8, tol: 0.0, seed: rng.next_u64() };
        let (a, _) = weighted_lloyd_with(&pts, &w, d, &cfg, &EngineOpts::naive_serial());
        for bounds in [BoundsPolicy::Hamerly, BoundsPolicy::Elkan] {
            let opts = EngineOpts::pruned().with_bounds(bounds).with_threads(3);
            let (b, _) = weighted_lloyd_with(&pts, &w, d, &cfg, &opts);
            assert_eq!(a.assign, b.assign, "{bounds:?}");
            assert_eq!(a.centroids, b.centroids, "{bounds:?}");
            assert_eq!(a.objective.to_bits(), b.objective.to_bits(), "{bounds:?}");
        }
    });
}

#[test]
fn elkan_warm_start_stays_bitwise_dense_and_factored() {
    // Warm starts skip seeding but must not inherit stale bounds: the
    // first warm iteration full-scans, and carried-bounds runs agree
    // bitwise with the naive warm-started reference for both policies.
    for_cases(8, |rng| {
        let n = 50 + rng.below(400) as usize;
        let d = 1 + rng.below(5) as usize;
        let (pts, w) = dense_input(rng, n, d);
        let k = 2 + rng.below(6) as usize;
        let cold_cfg = LloydConfig { k, max_iters: 6, tol: 0.0, seed: rng.next_u64() };
        let (cold, _) = weighted_lloyd_with(&pts, &w, d, &cold_cfg, &EngineOpts::pruned());
        let warm_cfg = LloydConfig { max_iters: 5, ..cold_cfg.clone() };
        let (wa, _) = lloyd_dense_init(
            &pts,
            &w,
            d,
            &warm_cfg,
            &EngineOpts::naive_serial(),
            Some(&cold.centroids),
        );
        for bounds in [BoundsPolicy::Hamerly, BoundsPolicy::Elkan] {
            let opts = EngineOpts::pruned().with_bounds(bounds).with_threads(3);
            let (wb, _) = lloyd_dense_init(&pts, &w, d, &warm_cfg, &opts, Some(&cold.centroids));
            assert_eq!(wa.assign, wb.assign, "{bounds:?}");
            assert_eq!(wa.centroids, wb.centroids, "{bounds:?}");
            assert_eq!(wa.objective.to_bits(), wb.objective.to_bits(), "{bounds:?}");
        }

        let (grid, subs) = grid_input(rng, n);
        let (fcold, _) = sparse_lloyd_with(&grid, &subs, &cold_cfg, &EngineOpts::pruned());
        let (fa, _) = sparse_lloyd_warm_with(
            &grid,
            &subs,
            &warm_cfg,
            &EngineOpts::naive_serial(),
            Some(&fcold.centroids),
        );
        for bounds in [BoundsPolicy::Hamerly, BoundsPolicy::Elkan] {
            let opts = EngineOpts::pruned().with_bounds(bounds).with_threads(3);
            let (fb, _) =
                sparse_lloyd_warm_with(&grid, &subs, &warm_cfg, &opts, Some(&fcold.centroids));
            assert_eq!(fa.assign, fb.assign, "{bounds:?}");
            assert_eq!(fa.objective.to_bits(), fb.objective.to_bits(), "{bounds:?}");
            assert_factored_centroids_equal(&fa.centroids, &fb.centroids);
        }
    });
}

#[test]
fn f32_pruned_parallel_equals_f32_naive_serial() {
    // The determinism contract within the f32 precision, both forms and
    // both bounds policies.
    for_cases(10, |rng| {
        let n = 30 + rng.below(500) as usize;
        let d = 1 + rng.below(6) as usize;
        let k = 1 + rng.below(8) as usize;
        let (pts, w) = dense_input(rng, n, d);
        let iters = 1 + rng.below(8) as usize;
        let cfg = LloydConfig { k, max_iters: iters, tol: 0.0, seed: rng.next_u64() };
        let naive = EngineOpts::naive_serial().with_precision(Precision::F32);
        let (a, _) = weighted_lloyd_with(&pts, &w, d, &cfg, &naive);
        let bounds = if rng.coin(0.5) { BoundsPolicy::Hamerly } else { BoundsPolicy::Elkan };
        let pruned = EngineOpts::pruned()
            .with_precision(Precision::F32)
            .with_bounds(bounds)
            .with_threads(4);
        let (b, _) = weighted_lloyd_with(&pts, &w, d, &cfg, &pruned);
        assert_eq!(a.assign, b.assign);
        assert_eq!(a.centroids, b.centroids);
        assert_eq!(a.objective.to_bits(), b.objective.to_bits());

        let (grid, subs) = grid_input(rng, n);
        let (fa, _) = sparse_lloyd_with(&grid, &subs, &cfg, &naive);
        let (fb, _) = sparse_lloyd_with(&grid, &subs, &cfg, &pruned);
        assert_eq!(fa.assign, fb.assign);
        assert_eq!(fa.objective.to_bits(), fb.objective.to_bits());
        assert_factored_centroids_equal(&fa.centroids, &fb.centroids);
    });
}

#[test]
fn f32_objective_within_tolerance_on_paper_traces() {
    // The documented tolerance contract (engine::F32_OBJ_RTOL) on the
    // materialized synthetic Retailer and Favorita workloads — the same
    // embeddings the bench acceptance rows use.
    for ds in [Dataset::Retailer, Dataset::Favorita] {
        let db = ds.generate(Scale::tiny(), 42);
        let feq = ds.feq();
        let tree = Hypergraph::from_feq(&db, &feq).join_tree().unwrap();
        let x = materialize(&db, &feq, &tree).unwrap();
        let spec = EmbedSpec::from_feq(&db, &feq).unwrap();
        let dense = spec.embed_matrix(&x);
        // Small k on strongly structured data: both precisions converge
        // into the same basin, so the comparison measures kernel rounding
        // rather than trajectory divergence.
        let cfg = LloydConfig { k: 4, max_iters: 10, tol: 0.0, seed: 7 };
        let opts64 = EngineOpts::pruned();
        let (r64, _) = weighted_lloyd_with(&dense, &x.weights, spec.dims, &cfg, &opts64);
        let opts32 = EngineOpts::pruned().with_precision(Precision::F32);
        let (r32, s32) = weighted_lloyd_with(&dense, &x.weights, spec.dims, &cfg, &opts32);
        assert_eq!(s32.precision, "f32");
        let rel = (r64.objective - r32.objective).abs() / r64.objective.abs().max(1e-12);
        assert!(
            rel <= F32_OBJ_RTOL,
            "{}: f32 objective {} drifted {rel:.2e} from f64 {}",
            ds.name(),
            r32.objective,
            r64.objective
        );
    }
}

#[test]
fn pooled_executor_equals_scoped_bitwise() {
    // The persistent pool is a pure dispatch mechanism: for every thread
    // count it must reduce to the same bits as the scoped-spawn executor,
    // dense and factored.
    for_cases(8, |rng| {
        let n = 40 + rng.below(600) as usize;
        let d = 1 + rng.below(5) as usize;
        let k = 1 + rng.below(8) as usize;
        let (pts, w) = dense_input(rng, n, d);
        let iters = 1 + rng.below(8) as usize;
        let cfg = LloydConfig { k, max_iters: iters, tol: 0.0, seed: rng.next_u64() };
        let scoped =
            env_precision(EngineOpts::pruned().with_executor(Executor::Scoped).with_threads(4));
        let (a, sa) = weighted_lloyd_with(&pts, &w, d, &cfg, &scoped);
        assert_eq!(sa.executor, "scoped");
        assert_eq!(sa.pool_dispatches, 0);
        let (grid, subs) = grid_input(rng, n);
        let (fa, _) = sparse_lloyd_with(&grid, &subs, &cfg, &scoped);
        for t in [2usize, 4, 8] {
            let pool = ExecPool::new(t);
            let pooled = env_precision(
                EngineOpts::pruned().with_executor(Executor::Pool(pool)).with_threads(t),
            );
            let (b, sb) = weighted_lloyd_with(&pts, &w, d, &cfg, &pooled);
            assert_eq!(a.assign, b.assign, "threads={t}");
            assert_eq!(a.centroids, b.centroids, "threads={t}");
            assert_eq!(a.objective.to_bits(), b.objective.to_bits(), "threads={t}");
            assert_eq!(a.iters, b.iters, "threads={t}");
            assert_eq!(sb.executor, "pool");
            let (fb, _) = sparse_lloyd_with(&grid, &subs, &cfg, &pooled);
            assert_eq!(fa.assign, fb.assign, "factored threads={t}");
            assert_eq!(fa.objective.to_bits(), fb.objective.to_bits(), "factored threads={t}");
            assert_factored_centroids_equal(&fa.centroids, &fb.centroids);
        }
    });
}

#[test]
fn shared_pool_multi_chunk_thread_count_invariant() {
    // Cross the CHUNK boundary on the default (shared-pool) executor so
    // real parallel pool dispatches happen, for every thread clamp.
    let mut rng = SplitMix64::new(0xDEC0);
    let n = CHUNK + 901;
    let d = 3;
    let (pts, w) = dense_input(&mut rng, n, d);
    let cfg = LloydConfig { k: 6, max_iters: 5, tol: 0.0, seed: 31 };
    let (base, _) = weighted_lloyd_with(&pts, &w, d, &cfg, &EngineOpts::naive_serial());
    for threads in [1usize, 2, 3, 8] {
        let opts = EngineOpts::pruned().with_threads(threads);
        let (r, stats) = weighted_lloyd_with(&pts, &w, d, &cfg, &opts);
        assert_eq!(base.assign, r.assign, "threads={threads}");
        assert_eq!(base.centroids, r.centroids, "threads={threads}");
        assert_eq!(base.objective.to_bits(), r.objective.to_bits(), "threads={threads}");
        assert_eq!(stats.executor, "pool", "threads={threads}");
    }
}

#[test]
fn dense_resume_equals_cold_warm_start_bitwise() {
    // Carrying the EngineState across runs is a pure throughput artifact:
    // a resumed warm start must produce identical bits to the cold warm
    // start from the same centroids, for both bounds policies.
    for_cases(8, |rng| {
        let n = 60 + rng.below(400) as usize;
        let d = 1 + rng.below(5) as usize;
        let k = 2 + rng.below(6) as usize;
        let (pts, w) = dense_input(rng, n, d);
        for bounds in [BoundsPolicy::Hamerly, BoundsPolicy::Elkan] {
            let opts = env_precision(EngineOpts::pruned().with_bounds(bounds).with_threads(3));
            let cfg1 = LloydConfig { k, max_iters: 4, tol: 0.0, seed: rng.next_u64() };
            let (r1, _, st) = lloyd_dense_resume(&pts, &w, d, &cfg1, &opts, None, None);
            let cfg2 = LloydConfig { max_iters: 5, ..cfg1.clone() };
            let (cold, sc, _) =
                lloyd_dense_resume(&pts, &w, d, &cfg2, &opts, Some(&r1.centroids), None);
            let (res, sr, _) =
                lloyd_dense_resume(&pts, &w, d, &cfg2, &opts, Some(&r1.centroids), Some(&st));
            assert_eq!(cold.assign, res.assign, "{bounds:?}");
            assert_eq!(cold.centroids, res.centroids, "{bounds:?}");
            assert_eq!(cold.objective.to_bits(), res.objective.to_bits(), "{bounds:?}");
            assert_eq!(cold.iters, res.iters, "{bounds:?}");
            // Both runs report the same shape of work, whatever the skip
            // sets did (the cold/resumed split is a throughput detail).
            assert_eq!(sc.points, sr.points, "{bounds:?}");
            assert_eq!(sc.iters, sr.iters, "{bounds:?}");
        }
    });
}

#[test]
fn factored_resume_equals_cold_warm_start_bitwise() {
    for_cases(8, |rng| {
        let n = 40 + rng.below(400) as usize;
        let (grid, subs) = grid_input(rng, n);
        let k = 2 + rng.below(6) as usize;
        for bounds in [BoundsPolicy::Hamerly, BoundsPolicy::Elkan] {
            let opts = env_precision(EngineOpts::pruned().with_bounds(bounds).with_threads(3));
            let cfg1 = LloydConfig { k, max_iters: 4, tol: 0.0, seed: rng.next_u64() };
            let (r1, _, st) = sparse_lloyd_resume_with(&grid, &subs, &cfg1, &opts, None, None);
            let cfg2 = LloydConfig { max_iters: 5, ..cfg1.clone() };
            let (cold, _, _) = sparse_lloyd_resume_with(
                &grid,
                &subs,
                &cfg2,
                &opts,
                Some(&r1.centroids),
                None,
            );
            let (res, _, _) = sparse_lloyd_resume_with(
                &grid,
                &subs,
                &cfg2,
                &opts,
                Some(&r1.centroids),
                Some(&st),
            );
            assert_eq!(cold.assign, res.assign, "{bounds:?}");
            assert_eq!(cold.objective.to_bits(), res.objective.to_bits(), "{bounds:?}");
            assert_eq!(cold.iters, res.iters, "{bounds:?}");
            assert_factored_centroids_equal(&cold.centroids, &res.centroids);
        }
    });
}

#[test]
fn resume_survives_reseed_heavy_runs() {
    // Duplicate-heavy inputs with k above the distinct-location count
    // force reseeds; a state captured from such a run (often with
    // invalidated bounds) must still resume to the cold warm start's
    // exact bits for both policies.
    for_cases(8, |rng| {
        let d = 1 + rng.below(3) as usize;
        let distinct = 2 + rng.below(3) as usize;
        let k = distinct + 1 + rng.below(3) as usize;
        let centers: Vec<f64> = (0..distinct * d).map(|_| rng.uniform(-5.0, 5.0)).collect();
        let n = 40 + rng.below(150) as usize;
        let mut pts = Vec::with_capacity(n * d);
        for _ in 0..n {
            let b = rng.below(distinct as u64) as usize;
            pts.extend_from_slice(&centers[b * d..(b + 1) * d]);
        }
        let w: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 2.0)).collect();
        for bounds in [BoundsPolicy::Hamerly, BoundsPolicy::Elkan] {
            let opts = env_precision(EngineOpts::pruned().with_bounds(bounds).with_threads(3));
            let cfg1 = LloydConfig { k, max_iters: 5, tol: 0.0, seed: rng.next_u64() };
            let (r1, _, st) = lloyd_dense_resume(&pts, &w, d, &cfg1, &opts, None, None);
            let cfg2 = LloydConfig { max_iters: 4, ..cfg1.clone() };
            let (cold, _, _) =
                lloyd_dense_resume(&pts, &w, d, &cfg2, &opts, Some(&r1.centroids), None);
            let (res, _, _) =
                lloyd_dense_resume(&pts, &w, d, &cfg2, &opts, Some(&r1.centroids), Some(&st));
            assert_eq!(cold.assign, res.assign, "{bounds:?}");
            assert_eq!(cold.centroids, res.centroids, "{bounds:?}");
            assert_eq!(cold.objective.to_bits(), res.objective.to_bits(), "{bounds:?}");
        }
    });
}

#[test]
#[should_panic(expected = "stale EngineState")]
fn dense_stale_state_is_rejected_loudly() {
    let mut rng = SplitMix64::new(0x51A1E);
    let (pts, w) = dense_input(&mut rng, 200, 3);
    let cfg = LloydConfig { k: 3, max_iters: 4, tol: 0.0, seed: 1 };
    let opts = EngineOpts::pruned();
    let (r, _, st) = lloyd_dense_resume(&pts, &w, 3, &cfg, &opts, None, None);
    // Perturbed centroids: the state's hash no longer matches the run's
    // starting point — silently proceeding could corrupt bounds.
    let mut stale = r.centroids.clone();
    stale[0] += 0.5;
    let _ = lloyd_dense_resume(&pts, &w, 3, &cfg, &opts, Some(&stale), Some(&st));
}

#[test]
#[should_panic(expected = "stale EngineState")]
fn factored_stale_state_is_rejected_loudly() {
    let mut rng = SplitMix64::new(0x51A1F);
    let (grid, subs) = grid_input(&mut rng, 120);
    let cfg = LloydConfig { k: 3, max_iters: 4, tol: 0.0, seed: 2 };
    let opts = EngineOpts::pruned();
    let (r, _, st) = sparse_lloyd_resume_with(&grid, &subs, &cfg, &opts, None, None);
    let mut stale = r.centroids.clone();
    match &mut stale[0][0] {
        CentroidCoord::Continuous(x) => *x += 0.5,
        CentroidCoord::Categorical(beta) => beta[0] += 0.5,
    }
    let _ = sparse_lloyd_resume_with(&grid, &subs, &cfg, &opts, Some(&stale), Some(&st));
}

#[test]
fn pruning_actually_prunes_on_stable_workloads() {
    // Not just correct — the bounds must pay: a well-separated workload
    // run for enough iterations should skip most of the inner k-loops.
    let mut rng = SplitMix64::new(0xACE);
    let d = 4;
    let blobs = 6usize;
    let centers: Vec<f64> = (0..blobs * d).map(|_| rng.uniform(-40.0, 40.0)).collect();
    let n = 6000usize;
    let mut pts = Vec::with_capacity(n * d);
    for _ in 0..n {
        let b = rng.below(blobs as u64) as usize;
        for j in 0..d {
            pts.push(centers[b * d + j] + 0.2 * rng.normal());
        }
    }
    let w = vec![1.0; n];
    let cfg = LloydConfig { k: 8, max_iters: 15, tol: 0.0, seed: 7 };
    let (_, stats) = weighted_lloyd_with(&pts, &w, d, &cfg, &EngineOpts::pruned());
    assert!(
        stats.skip_rate() > 0.5,
        "well-separated blobs should skip most evaluations, got {:.3}",
        stats.skip_rate()
    );
}
