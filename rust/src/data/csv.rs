//! CSV import/export for relations.
//!
//! Format: header row `name:type[:domain]` per column (`type` one of
//! `int|double|cat`), then one row per tuple. Categorical values are raw ids.
//! A trailing `__weight:double` column round-trips tuple multiplicities.
//! This is the on-disk interchange for the CLI (`rkmeans gen` / `cluster`).

use super::relation::Relation;
use super::schema::{Attr, AttrType, Schema};
use super::value::Value;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Write a relation to a CSV file.
pub fn write_relation(rel: &Relation, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    let mut header: Vec<String> = rel
        .schema
        .attrs()
        .iter()
        .map(|a| match a.ty {
            AttrType::Int => format!("{}:int", a.name),
            AttrType::Double => format!("{}:double", a.name),
            AttrType::Cat => format!("{}:cat:{}", a.name, a.domain),
        })
        .collect();
    if rel.has_weights() {
        header.push("__weight:double".to_string());
    }
    writeln!(w, "{}", header.join(","))?;
    for row in 0..rel.n_rows() {
        let mut fields: Vec<String> = (0..rel.n_cols())
            .map(|c| match rel.value(row, c) {
                Value::Int(v) => v.to_string(),
                Value::Double(v) => format!("{v}"),
                Value::Cat(v) => v.to_string(),
            })
            .collect();
        if rel.has_weights() {
            fields.push(format!("{}", rel.weight(row)));
        }
        writeln!(w, "{}", fields.join(","))?;
    }
    Ok(())
}

/// Read a relation from a CSV file written by [`write_relation`].
pub fn read_relation(name: &str, path: &Path) -> Result<Relation> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let mut lines = std::io::BufReader::new(f).lines();
    let header = lines
        .next()
        .context("empty csv")?
        .context("read header")?;
    let mut attrs = Vec::new();
    let mut has_weight = false;
    for spec in header.split(',') {
        let parts: Vec<&str> = spec.split(':').collect();
        match parts.as_slice() {
            ["__weight", "double"] => has_weight = true,
            [name, "int"] => attrs.push(Attr::int(name)),
            [name, "double"] => attrs.push(Attr::double(name)),
            [name, "cat", dom] => {
                attrs.push(Attr::cat(name, dom.parse().context("bad domain")?))
            }
            [name, "cat"] => attrs.push(Attr::cat(name, 0)),
            _ => bail!("bad header field {spec:?}"),
        }
    }
    let schema = Schema::new(attrs);
    let n_cols = schema.len();
    let mut rel = Relation::new(name, schema);
    for (lineno, line) in lines.enumerate() {
        let line = line.context("read row")?;
        if line.is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split(',').collect();
        let expected = n_cols + usize::from(has_weight);
        if fields.len() != expected {
            bail!("row {}: expected {} fields, got {}", lineno + 2, expected, fields.len());
        }
        let mut vals = Vec::with_capacity(n_cols);
        let rowno = lineno + 2;
        for (c, field) in fields.iter().take(n_cols).enumerate() {
            let v = match rel.schema.attr(c).ty {
                AttrType::Int => Value::Int(
                    field.parse().with_context(|| format!("row {rowno}: bad int {field:?}"))?,
                ),
                AttrType::Double => Value::Double(
                    field.parse().with_context(|| format!("row {rowno}: bad double {field:?}"))?,
                ),
                AttrType::Cat => Value::Cat(
                    field.parse().with_context(|| format!("row {rowno}: bad cat id {field:?}"))?,
                ),
            };
            vals.push(v);
        }
        if has_weight {
            let w: f64 = fields[n_cols].parse().context("bad weight")?;
            rel.push_row_weighted(&vals, w);
        } else {
            rel.push_row(&vals);
        }
    }
    Ok(rel)
}

/// Write a whole database as one CSV file per relation under `dir`.
pub fn write_database(db: &super::Database, dir: &Path) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    for rel in db.relations() {
        write_relation(rel, &dir.join(format!("{}.csv", rel.name)))?;
    }
    // FDs as a sidecar file.
    let mut w = BufWriter::new(std::fs::File::create(dir.join("_fds.txt"))?);
    for fd in &db.fds {
        writeln!(w, "{} -> {}", fd.determinant, fd.dependent)?;
    }
    Ok(())
}

/// Read a database written by [`write_database`].
pub fn read_database(dir: &Path) -> Result<super::Database> {
    let mut db = super::Database::new();
    let mut names: Vec<String> = Vec::new();
    for entry in std::fs::read_dir(dir).with_context(|| format!("read {}", dir.display()))? {
        let p = entry?.path();
        if p.extension().map(|e| e == "csv").unwrap_or(false) {
            names.push(p.file_stem().expect("csv has a stem").to_string_lossy().to_string());
        }
    }
    names.sort();
    for name in names {
        db.add(read_relation(&name, &dir.join(format!("{name}.csv")))?);
    }
    let fd_path = dir.join("_fds.txt");
    if fd_path.exists() {
        for line in std::fs::read_to_string(fd_path)?.lines() {
            if let Some((a, b)) = line.split_once("->") {
                db.add_fd(a.trim(), b.trim());
            }
        }
    }
    Ok(db)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Database;

    fn sample() -> Relation {
        let mut r = Relation::new(
            "t",
            Schema::new(vec![Attr::int("id"), Attr::double("x"), Attr::cat("c", 5)]),
        );
        r.push_row(&[Value::Int(1), Value::Double(0.5), Value::Cat(2)]);
        r.push_row_weighted(&[Value::Int(-2), Value::Double(1.25), Value::Cat(4)], 3.0);
        r
    }

    #[test]
    fn roundtrip_relation() {
        let dir = std::env::temp_dir().join(format!("rk_csv_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        let orig = sample();
        write_relation(&orig, &path).unwrap();
        let back = read_relation("t", &path).unwrap();
        assert_eq!(back.n_rows(), 2);
        assert_eq!(back.value(0, 0), Value::Int(1));
        assert_eq!(back.value(1, 0), Value::Int(-2));
        assert_eq!(back.value(1, 2), Value::Cat(4));
        assert_eq!(back.weight(1), 3.0);
        assert_eq!(back.schema.attr(2).domain, 5);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn roundtrip_database_with_fds() {
        let dir = std::env::temp_dir().join(format!("rk_csvdb_{}", std::process::id()));
        let mut db = Database::new();
        db.add(sample());
        db.add_fd("id", "c");
        write_database(&db, &dir).unwrap();
        let back = read_database(&dir).unwrap();
        assert_eq!(back.relations().len(), 1);
        assert_eq!(back.fds.len(), 1);
        assert_eq!(back.fds[0].determinant, "id");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn malformed_rows_error() {
        let dir = std::env::temp_dir().join(format!("rk_csvbad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        std::fs::write(&path, "a:int,b:double\n1\n").unwrap();
        assert!(read_relation("bad", &path).is_err());
        std::fs::write(&path, "a:int,b:double\nx,1.0\n").unwrap();
        assert!(read_relation("bad", &path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
