//! Bench I1 — ingest-tier scaling: P producer threads feeding S bounded
//! per-shard queues through the epoch protocol (`rkmeans::ingest`) vs. a
//! serial single-stream `DeltaFaq` ingest of the same Retailer trace.
//!
//! Arms (same database, same trace, same fixed assigners):
//! * `serial`     — one `DeltaFaq`, one stream, one batch at a time (the
//!   reference row);
//! * `epochd-2`   — P = S = 2 through the hub;
//! * `epochd-max` — P = S = available parallelism (the acceptance arm;
//!   target ≥ 2× serial throughput on multi-core hardware).
//!
//! Before anything is recorded the bench asserts every arm's final grid
//! **bitwise identical** to the serial one — the ring-ℤ determinism
//! contract the ingest tier is built on — so the speedup rows can never
//! mask a divergence. Epoch-close latency percentiles come from the
//! hub's `ingest.epoch_us` histogram (first entry seen → epoch closed).
//!
//! Results are written as one `BENCH_ingest.json` document (schema: see
//! `bench_harness` docs; path override: `RKMEANS_INGEST_OUT`).
//!
//! `--test` (or `--smoke`) shrinks everything for CI smoke runs.
//! `RKMEANS_INGEST_SCALE` overrides the Retailer scale (default 0.02 ≈
//! 40k fact rows).

use rkmeans::bench_harness::{write_bench_ingest, IngestBenchRecord};
use rkmeans::data::{Database, Value};
use rkmeans::faq::{GidAssigner, GridTable};
use rkmeans::incremental::{DeltaFaq, TupleDelta};
use rkmeans::ingest::{IngestConfig, IngestHub};
use rkmeans::metrics::Metrics;
use rkmeans::query::{Feq, Hypergraph, JoinTree};
use rkmeans::synthetic::{retailer, retailer_trace, Scale, TraceSpec};
use rkmeans::util::FxHashMap;
use std::path::PathBuf;
use std::time::Instant;

/// Fixed mod-assigner: the bench measures the epoch protocol and the
/// shard-parallel Step-3 patching, not the Step-2 solvers, so grid
/// assignment is a cheap deterministic hash shared by every arm.
struct ModAssigner {
    n: u32,
}
impl GidAssigner for ModAssigner {
    fn gid(&self, v: Value) -> u32 {
        let k = match v {
            Value::Double(x) => ((x * 4.0) as i64).rem_euclid(self.n as i64) as u64,
            other => other.key_u64(),
        };
        (k % self.n as u64) as u32
    }
    fn n_gids(&self) -> usize {
        self.n as usize
    }
}

fn mod_assigners(feq: &Feq) -> FxHashMap<String, Box<dyn GidAssigner>> {
    let mut m: FxHashMap<String, Box<dyn GidAssigner>> = FxHashMap::default();
    for f in &feq.features {
        m.insert(f.attr.clone(), Box::new(ModAssigner { n: 3 }));
    }
    m
}

/// Sorted (cell, bits) view of a grid for exact cross-arm comparison.
fn grid_bits(gt: &GridTable) -> Vec<(Vec<u32>, u64)> {
    let mut v: Vec<(Vec<u32>, u64)> =
        gt.cells.iter().map(|(g, w)| (g.clone(), w.to_bits())).collect();
    v.sort();
    v
}

/// Exact percentile over raw per-epoch latencies (the serial arm has no
/// hub histogram; sort-and-index matches the histogram's exactness on
/// these magnitudes closely enough for a reporting row).
fn pctl(samples: &mut [u64], p: f64) -> u64 {
    if samples.is_empty() {
        return 0;
    }
    samples.sort_unstable();
    let idx = ((samples.len() as f64 * p) as usize).min(samples.len() - 1);
    samples[idx]
}

/// Run one epoch'd arm: P scoped producer threads deal the trace
/// round-robin into the hub while the main thread pumps until every
/// epoch closes. Returns the record and the final grid bits.
#[allow(clippy::too_many_arguments)]
fn hub_arm(
    db: &Database,
    feq: &Feq,
    tree: &JoinTree,
    trace: &[Vec<TupleDelta>],
    producers: usize,
    shards: usize,
    mode: &str,
    base_rows: usize,
) -> anyhow::Result<(IngestBenchRecord, Vec<(Vec<u32>, u64)>)> {
    let metrics = Metrics::new();
    let cfg = IngestConfig { producers, shards, queue_capacity: 8192, spill_budget: 0 };
    let mut hub = IngestHub::new(db, feq, tree, &cfg, || mod_assigners(feq), metrics.clone())?;
    let handles: Vec<_> = (0..producers).map(|p| hub.producer(p)).collect();
    let epochs = trace.len() as u64;
    let batch = trace.first().map_or(0, Vec::len);

    let t0 = Instant::now();
    std::thread::scope(|scope| -> anyhow::Result<()> {
        for (p, h) in handles.into_iter().enumerate() {
            scope.spawn(move || {
                for (i, b) in trace.iter().enumerate() {
                    let epoch = (i + 1) as u64;
                    for d in b.iter().skip(p).step_by(producers) {
                        if h.send(epoch, d.clone()).is_err() {
                            return;
                        }
                    }
                    if h.seal(epoch).is_err() {
                        return;
                    }
                }
            });
        }
        while hub.closed_epoch() < epochs {
            hub.pump(|| mod_assigners(feq))?;
            std::thread::yield_now();
        }
        Ok(())
    })?;
    let total_s = t0.elapsed().as_secs_f64();

    let epoch_us = metrics.histogram("ingest.epoch_us");
    let rec = IngestBenchRecord::from_run(
        "retailer-trace",
        mode,
        producers,
        shards,
        base_rows,
        batch,
        trace.len(),
        total_s,
        epoch_us.percentile(0.50),
        epoch_us.percentile(0.99),
        hub.grid_table().cells.len(),
    );
    Ok((rec, grid_bits(&hub.grid_table())))
}

fn main() -> anyhow::Result<()> {
    let test_mode = std::env::args().any(|a| a == "--test" || a == "--smoke");
    let scale: f64 = std::env::var("RKMEANS_INGEST_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if test_mode { 0.003 } else { 0.02 });
    let batches = if test_mode { 3usize } else { 6 };

    let db = retailer::generate(Scale::custom(scale), 42);
    let feq = retailer::feq();
    let tree = Hypergraph::from_feq(&db, &feq).join_tree()?;
    let base_rows = db.total_rows() as usize;
    let batch = if test_mode { 96 } else { (base_rows / 16).max(512) };
    let trace = retailer_trace(&db, 7, TraceSpec { batches, batch_size: batch, delete_frac: 0.3 });
    let max_p = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).max(2);
    println!(
        "ingest workload: |D|={base_rows} rows (scale {scale}), batch={batch} × {batches} \
         epochs, max P={max_p}"
    );

    // Reference arm: one DeltaFaq, one stream. Only the apply is timed
    // (the epoch'd arms carry their queue + merge overhead on top, which
    // keeps the speedup honest).
    let asg = mod_assigners(&feq);
    let mut serial = DeltaFaq::init(&db, &feq, &tree, &asg)?;
    let mut epoch_us: Vec<u64> = Vec::with_capacity(batches);
    let t0 = Instant::now();
    for b in &trace {
        let e0 = Instant::now();
        serial.apply(b, &asg)?;
        epoch_us.push(e0.elapsed().as_micros() as u64);
    }
    let serial_s = t0.elapsed().as_secs_f64();
    let serial_bits = grid_bits(&serial.grid_table());
    let serial_rec = IngestBenchRecord::from_run(
        "retailer-trace",
        "serial",
        1,
        1,
        base_rows,
        batch,
        batches,
        serial_s,
        pctl(&mut epoch_us.clone(), 0.50),
        pctl(&mut epoch_us, 0.99),
        serial_bits.len(),
    );
    println!("{}", serial_rec.line());

    let (two_rec, two_bits) = hub_arm(&db, &feq, &tree, &trace, 2, 2, "epochd-2", base_rows)?;
    let two_rec = two_rec.with_speedup_vs(&serial_rec);
    println!("{}", two_rec.line());

    let (max_rec, max_bits) =
        hub_arm(&db, &feq, &tree, &trace, max_p, max_p, "epochd-max", base_rows)?;
    let max_rec = max_rec.with_speedup_vs(&serial_rec);
    println!("{}", max_rec.line());

    // The cross-arm bitwise assertion: neither the producer interleave
    // nor the shard partition may change a single bit of the final grid.
    for (label, bits) in [("epochd-2", &two_bits), ("epochd-max", &max_bits)] {
        anyhow::ensure!(
            *bits == serial_bits,
            "{label}: final grid diverged from the serial single-stream ingest — \
             the ring-ℤ merge contract is broken"
        );
    }
    println!("bitwise: all arms identical to serial ({} grid cells)", serial_bits.len());

    let speedup = max_rec.speedup_vs_serial.unwrap_or(0.0);
    let records = vec![serial_rec, two_rec, max_rec];
    let out = PathBuf::from(
        std::env::var("RKMEANS_INGEST_OUT").unwrap_or_else(|_| "BENCH_ingest.json".to_string()),
    );
    write_bench_ingest(&out, &records)?;
    println!("wrote {} records to {}", records.len(), out.display());
    println!(
        "epochd-max vs serial ingest throughput: {speedup:.2}× at P=S={max_p} \
         (acceptance target ≥ 2× on multi-core hardware)"
    );
    Ok(())
}
