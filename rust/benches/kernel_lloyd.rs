//! Bench K1 — the Step-4 hot path across engines and shape buckets:
//! the bounds-pruned parallel engine vs. the naive serial reference on
//! synthetic blob shapes and on the materialized synthetic Retailer
//! workload (the acceptance target: n ≥ 100k, k ≥ 32), plus the XLA/PJRT
//! AOT path when built with `--features pjrt` and artifacts exist. Both
//! engine paths run in one invocation so the pruning speedup and skip
//! rates are directly visible, and all rows are written as one
//! `BENCH_lloyd.json` document per invocation (schema: see
//! `bench_harness` docs; path override: `RKMEANS_BENCH_OUT`).
//!
//! `--test` (or `--smoke`) shrinks everything for CI smoke runs.
//! `RKMEANS_BENCH_SCALE` overrides the Retailer scale (default 0.06 ≈
//! 120k join rows).

use rkmeans::bench_harness::{write_bench_lloyd, LloydBenchRecord};
use rkmeans::cluster::{weighted_lloyd_with, EngineOpts, LloydConfig};
use rkmeans::join::{materialize, EmbedSpec};
use rkmeans::query::Hypergraph;
use rkmeans::synthetic::{retailer, Scale};
use rkmeans::util::SplitMix64;
use std::path::PathBuf;

/// Blob-structured synthetic points: the regime where assignments
/// stabilize after a few iterations (like real coresets), which is what
/// bounds pruning exploits. Uniform noise would understate the win.
fn synth(n: usize, d: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let mut rng = SplitMix64::new(seed);
    let blobs = 8usize;
    let centers: Vec<f64> = (0..blobs * d).map(|_| rng.uniform(-8.0, 8.0)).collect();
    let mut pts = Vec::with_capacity(n * d);
    for _ in 0..n {
        let b = rng.below(blobs as u64) as usize;
        for j in 0..d {
            pts.push(centers[b * d + j] + 0.5 * rng.normal());
        }
    }
    let w: Vec<f64> = (0..n).map(|_| rng.uniform(0.2, 2.0)).collect();
    (pts, w)
}

/// Run naive-serial and pruned-parallel on one workload, assert they
/// agree exactly, print both rows, and record them.
fn run_pair(
    label: &str,
    pts: &[f64],
    w: &[f64],
    d: usize,
    k: usize,
    iters: usize,
    records: &mut Vec<LloydBenchRecord>,
) {
    let cfg = LloydConfig { k, max_iters: iters, tol: 0.0, seed: 3 };
    let (rn, sn) = weighted_lloyd_with(pts, w, d, &cfg, &EngineOpts::naive_serial());
    let (rp, sp) = weighted_lloyd_with(pts, w, d, &cfg, &EngineOpts::pruned());
    assert_eq!(
        rn.objective.to_bits(),
        rp.objective.to_bits(),
        "{label}: engine paths diverged"
    );
    assert!(rn.assign == rp.assign, "{label}: assignments diverged");
    let naive = LloydBenchRecord::from_stats(label, "dense-naive", d, k, rn.objective, &sn);
    let pruned = LloydBenchRecord::from_stats(label, "dense-pruned", d, k, rp.objective, &sp)
        .with_speedup_vs(&naive);
    println!("{}", naive.line());
    println!("{}\n", pruned.line());
    records.push(naive);
    records.push(pruned);
}

fn main() -> anyhow::Result<()> {
    let test_mode = std::env::args().any(|a| a == "--test" || a == "--smoke");
    let mut records: Vec<LloydBenchRecord> = Vec::new();

    // Synthetic shape sweep.
    let shapes: &[(usize, usize, usize)] = if test_mode {
        &[(1024, 8, 8), (4096, 16, 16)]
    } else {
        &[(4096, 16, 16), (16384, 32, 16), (65536, 16, 32)]
    };
    let iters = if test_mode { 3 } else { 10 };
    for &(n, d, k) in shapes {
        let (pts, w) = synth(n, d, 1);
        run_pair(&format!("synth-{n}x{d}"), &pts, &w, d, k, iters, &mut records);
    }

    // The acceptance workload: materialized synthetic Retailer (|X| =
    // fact rows; scale 0.06 ≈ 120k), dense engine, k ≥ 32.
    let scale: f64 = std::env::var("RKMEANS_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if test_mode { 0.002 } else { 0.06 });
    let (rk, riters) = if test_mode { (4usize, 3usize) } else { (32, 15) };
    let db = retailer::generate(Scale::custom(scale), 42);
    let feq = retailer::feq();
    let tree = Hypergraph::from_feq(&db, &feq).join_tree()?;
    let x = materialize(&db, &feq, &tree)?;
    let spec = EmbedSpec::from_feq(&db, &feq)?;
    let dense = spec.embed_matrix(&x);
    println!(
        "retailer workload: |X|={} rows × D={} (scale {scale}), k={rk}",
        x.len(),
        spec.dims
    );
    run_pair("retailer-materialized", &dense, &x.weights, spec.dims, rk, riters, &mut records);

    // XLA/PJRT comparison rows when the artifact path is available.
    xla_rows(&mut records, test_mode);

    let out = PathBuf::from(
        std::env::var("RKMEANS_BENCH_OUT").unwrap_or_else(|_| "BENCH_lloyd.json".to_string()),
    );
    write_bench_lloyd(&out, &records)?;
    println!("wrote {} records to {}", records.len(), out.display());

    // The headline number the ROADMAP trajectory tracks.
    if let Some(r) = records
        .iter()
        .find(|r| r.label == "retailer-materialized" && r.engine == "dense-pruned")
    {
        println!(
            "retailer dense pruned vs naive: {:.2}× points/sec (skip rate {:.1}%)",
            r.speedup_vs_naive.unwrap_or(0.0),
            100.0 * r.skip_rate
        );
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn xla_rows(records: &mut Vec<LloydBenchRecord>, test_mode: bool) {
    use rkmeans::runtime::PjrtRuntime;
    let dir = PjrtRuntime::default_dir();
    if !PjrtRuntime::available(&dir) {
        println!("(no artifacts — XLA rows skipped; run `make artifacts`)\n");
        return;
    }
    let rt = match PjrtRuntime::load(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            println!("(XLA rows skipped: {e})\n");
            return;
        }
    };
    let (n, d, k, iters) = if test_mode { (1024, 8, 8, 3) } else { (16384, 32, 16, 10) };
    let (pts, w) = synth(n, d, 1);
    let cfg = LloydConfig { k, max_iters: iters, tol: 0.0, seed: 3 };
    let t0 = std::time::Instant::now();
    match rt.lloyd(&pts, &w, d, &cfg) {
        Ok(res) => {
            let wall = t0.elapsed().as_secs_f64();
            let rec = LloydBenchRecord {
                label: format!("synth-{n}x{d}"),
                engine: "dense-xla".to_string(),
                n,
                dims: d,
                k,
                iters: res.iters,
                wall_s: wall,
                points_per_sec: if wall > 0.0 { (n * res.iters) as f64 / wall } else { 0.0 },
                dist_evals: (n * k * res.iters) as u64,
                dist_evals_skipped: 0,
                skip_rate: 0.0,
                objective: res.objective,
                speedup_vs_naive: None,
            };
            println!("{}\n", rec.line());
            records.push(rec);
        }
        Err(e) => println!("(xla skipped: {e})\n"),
    }
}

#[cfg(not(feature = "pjrt"))]
fn xla_rows(_records: &mut Vec<LloydBenchRecord>, _test_mode: bool) {
    println!("(built without `pjrt` — XLA rows skipped)\n");
}
