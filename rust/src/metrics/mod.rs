//! Lightweight metrics registry for the streaming coordinator, the
//! serving mesh, the socket RPC tier, and the CLI: atomic counters,
//! gauges, and lock-free latency histograms with a printable snapshot.
//! No external dependencies; safe to share across worker threads.
//!
//! Registration is get-or-create by name, so independent subsystems
//! sharing one [`Metrics`] converge on the same instrument — e.g. in a
//! replica process the rpc server's probe handler and the delta-stream
//! sync loop both bump `serve.rpc.catchups`, and a control-plane probe
//! reads the combined truth.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Bucket count of [`Histogram`]: 16 exact low buckets plus 4
/// sub-buckets for each power of two up to `u64::MAX`.
const HIST_BUCKETS: usize = 16 + 4 * 60;

/// A monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Increment by `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Increment by one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A settable gauge.
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    /// Set the value.
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Add a (possibly negative) delta.
    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A lock-free log-bucketed histogram of non-negative integer samples
/// (the serving tier records latencies in microseconds).
///
/// Values 0–15 get exact buckets; every power of two above that is
/// split into 4 log sub-buckets, so percentile answers are exact below
/// 16 and within ~25 % relative error everywhere else — plenty for
/// p50/p99 latency reporting, with `observe` costing one relaxed
/// `fetch_add` (safe on every hot path).
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Histogram")
            .field("count", &self.count())
            .field("p50", &self.percentile(0.50))
            .field("p99", &self.percentile(0.99))
            .finish()
    }
}

/// Bucket index of a sample: identity below 16, then
/// `16 + (msb−4)·4 + next-2-bits` above.
fn bucket_of(v: u64) -> usize {
    if v < 16 {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros() as usize;
    16 + (msb - 4) * 4 + ((v >> (msb - 2)) & 3) as usize
}

/// Smallest sample value mapping to bucket `i` (inverse of
/// [`bucket_of`]); percentiles report this lower bound.
fn bucket_floor(i: usize) -> u64 {
    if i < 16 {
        return i as u64;
    }
    let msb = (i - 16) / 4 + 4;
    let sub = ((i - 16) % 4) as u64;
    (1u64 << msb) + (sub << (msb - 2))
}

impl Histogram {
    /// Record one sample.
    pub fn observe(&self, v: u64) {
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all recorded samples (mean = `sum / count`).
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Lower bound of the bucket holding the `p`-quantile sample
    /// (`0.0 < p ≤ 1.0`), or 0 when empty. Exact for samples below 16,
    /// within one log sub-bucket (~25 %) above.
    pub fn percentile(&self, p: f64) -> u64 {
        let count = self.count();
        if count == 0 {
            return 0;
        }
        let rank = ((p * count as f64).ceil() as u64).clamp(1, count);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                return bucket_floor(i);
            }
        }
        bucket_floor(HIST_BUCKETS - 1)
    }
}

/// A shared registry of named counters, gauges, and histograms.
#[derive(Clone, Default)]
pub struct Metrics {
    inner: Arc<Inner>,
}

#[derive(Default)]
struct Inner {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Metrics {
    /// New empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Get or create a counter.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.inner.counters.lock().expect("metrics lock");
        m.entry(name.to_string()).or_default().clone()
    }

    /// Get or create a gauge.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.inner.gauges.lock().expect("metrics lock");
        m.entry(name.to_string()).or_default().clone()
    }

    /// Get or create a histogram.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut m = self.inner.histograms.lock().expect("metrics lock");
        m.entry(name.to_string()).or_default().clone()
    }

    /// Snapshot all metrics as sorted `(name, value)` pairs. Each
    /// histogram expands to `{name}.count`, `{name}.p50`, `{name}.p99`.
    pub fn snapshot(&self) -> Vec<(String, i64)> {
        let mut out = Vec::new();
        for (k, c) in self.inner.counters.lock().expect("metrics lock").iter() {
            out.push((k.clone(), c.get() as i64));
        }
        for (k, g) in self.inner.gauges.lock().expect("metrics lock").iter() {
            out.push((k.clone(), g.get()));
        }
        for (k, h) in self.inner.histograms.lock().expect("metrics lock").iter() {
            out.push((format!("{k}.count"), h.count() as i64));
            out.push((format!("{k}.p50"), h.percentile(0.50) as i64));
            out.push((format!("{k}.p99"), h.percentile(0.99) as i64));
        }
        out.sort();
        out
    }

    /// Render the snapshot as `name=value` lines.
    pub fn render(&self) -> String {
        self.snapshot()
            .into_iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join("\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges() {
        let m = Metrics::new();
        m.counter("tuples_in").add(5);
        m.counter("tuples_in").inc();
        m.gauge("queue_depth").set(3);
        m.gauge("queue_depth").add(-1);
        let snap = m.snapshot();
        assert_eq!(snap, vec![("queue_depth".to_string(), 2), ("tuples_in".to_string(), 6)]);
        assert!(m.render().contains("tuples_in=6"));
    }

    #[test]
    fn histogram_buckets_invert() {
        // bucket_floor is a left inverse of bucket_of on bucket floors,
        // and bucket_of is monotone across a wide sample of values.
        for i in 0..HIST_BUCKETS {
            assert_eq!(bucket_of(bucket_floor(i)), i, "bucket {i}");
        }
        let mut prev = 0;
        for v in [0u64, 1, 15, 16, 17, 31, 32, 1000, 1 << 20, u64::MAX / 2, u64::MAX] {
            let b = bucket_of(v);
            assert!(b >= prev, "bucket_of must be monotone at {v}");
            assert!(bucket_floor(b) <= v, "floor must bound {v} from below");
            prev = b;
        }
        assert_eq!(bucket_of(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn histogram_percentiles() {
        let h = Histogram::default();
        assert_eq!(h.percentile(0.5), 0, "empty histogram reports 0");
        for v in 0..10u64 {
            h.observe(v);
        }
        // Exact below 16: rank ⌈0.5·10⌉ = 5 ⇒ the 5th smallest sample.
        assert_eq!(h.count(), 10);
        assert_eq!(h.sum(), 45);
        assert_eq!(h.percentile(0.5), 4);
        assert_eq!(h.percentile(1.0), 9);
        // A tail outlier moves p99 but not p50.
        h.observe(1_000_000);
        assert_eq!(h.percentile(0.5), 5);
        let p99 = h.percentile(0.99);
        assert!((750_000..=1_000_000).contains(&p99), "p99 within a sub-bucket: {p99}");
    }

    #[test]
    fn histogram_snapshot_keys() {
        let m = Metrics::new();
        m.histogram("assign_us").observe(7);
        m.histogram("assign_us").observe(9);
        let snap = m.snapshot();
        assert_eq!(
            snap,
            vec![
                ("assign_us.count".to_string(), 2),
                ("assign_us.p50".to_string(), 7),
                ("assign_us.p99".to_string(), 9),
            ]
        );
    }

    #[test]
    fn shared_across_threads() {
        let m = Metrics::new();
        let c = m.counter("hits");
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let c = c.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().expect("worker");
        }
        assert_eq!(m.counter("hits").get(), 4000);
    }
}
