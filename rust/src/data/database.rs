//! A database: a set of named relations plus declared functional
//! dependencies (FDs). FDs matter to Rk-means because FD-chains bound the
//! number of non-zero-weight grid-coreset cells by `O(dk)` instead of
//! `O(k^d)` (paper §4.2, Lemma 4.5 / Theorem 4.6).

use super::relation::Relation;
use std::collections::HashMap;

/// A declared functional dependency `determinant -> dependent` between two
/// attributes of the same relation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Fd {
    pub determinant: String,
    pub dependent: String,
}

/// A collection of relations with name lookup and FD metadata.
#[derive(Clone, Debug, Default)]
pub struct Database {
    relations: Vec<Relation>,
    by_name: HashMap<String, usize>,
    pub fds: Vec<Fd>,
}

impl Database {
    /// Empty database.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a relation; names must be unique.
    pub fn add(&mut self, rel: Relation) {
        assert!(
            !self.by_name.contains_key(&rel.name),
            "duplicate relation name {}",
            rel.name
        );
        self.by_name.insert(rel.name.clone(), self.relations.len());
        self.relations.push(rel);
    }

    /// Declare a functional dependency.
    pub fn add_fd(&mut self, determinant: &str, dependent: &str) {
        self.fds.push(Fd {
            determinant: determinant.to_string(),
            dependent: dependent.to_string(),
        });
    }

    /// All relations.
    pub fn relations(&self) -> &[Relation] {
        &self.relations
    }

    /// Mutable access to all relations (used by the streaming coordinator).
    pub fn relations_mut(&mut self) -> &mut [Relation] {
        &mut self.relations
    }

    /// Relation by name.
    pub fn get(&self, name: &str) -> Option<&Relation> {
        self.by_name.get(name).map(|&i| &self.relations[i])
    }

    /// Mutable relation by name.
    pub fn get_mut(&mut self, name: &str) -> Option<&mut Relation> {
        let idx = *self.by_name.get(name)?;
        Some(&mut self.relations[idx])
    }

    /// Index of a relation by name.
    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.by_name.get(name).copied()
    }

    /// Total number of tuples across relations (the paper's `|D|` rows).
    pub fn total_rows(&self) -> u64 {
        self.relations.iter().map(|r| r.n_rows() as u64).sum()
    }

    /// Total estimated bytes across relations (the paper's "Size of D").
    pub fn total_bytes(&self) -> u64 {
        self.relations.iter().map(|r| r.byte_size()).sum()
    }

    /// Verify a declared FD against the data: every determinant value maps
    /// to exactly one dependent value. Returns false if violated or if the
    /// attributes do not co-occur in any relation.
    pub fn verify_fd(&self, fd: &Fd) -> bool {
        for rel in &self.relations {
            let (Some(di), Some(pi)) = (
                rel.schema.index_of(&fd.determinant),
                rel.schema.index_of(&fd.dependent),
            ) else {
                continue;
            };
            let mut seen: HashMap<u64, u64> = HashMap::new();
            for row in 0..rel.n_rows() {
                let d = rel.col(di).key_u64(row);
                let p = rel.col(pi).key_u64(row);
                match seen.entry(d) {
                    std::collections::hash_map::Entry::Occupied(e) => {
                        if *e.get() != p {
                            return false;
                        }
                    }
                    std::collections::hash_map::Entry::Vacant(e) => {
                        e.insert(p);
                    }
                }
            }
            return true;
        }
        false
    }

    /// Maximal FD-chains over the given attribute set: sequences
    /// `a1 -> a2 -> … -> ap` following declared FDs. Attributes not in any
    /// chain form singleton chains (Theorem 4.6's general case).
    pub fn fd_chains(&self, attrs: &[String]) -> Vec<Vec<String>> {
        let in_set = |a: &str| attrs.iter().any(|x| x == a);
        // next[a] = b if a -> b declared and both in `attrs`.
        let mut next: HashMap<&str, &str> = HashMap::new();
        let mut has_pred: HashMap<&str, bool> = HashMap::new();
        for fd in &self.fds {
            if in_set(&fd.determinant) && in_set(&fd.dependent) {
                next.insert(&fd.determinant, &fd.dependent);
                has_pred.insert(&fd.dependent, true);
            }
        }
        let mut chains = Vec::new();
        let mut used: Vec<&str> = Vec::new();
        for a in attrs {
            if *has_pred.get(a.as_str()).unwrap_or(&false) {
                continue; // not a chain head
            }
            let mut chain = vec![a.clone()];
            used.push(a);
            let mut cur = a.as_str();
            while let Some(&nxt) = next.get(cur) {
                if used.contains(&nxt) {
                    break; // guard against cyclic declarations
                }
                chain.push(nxt.to_string());
                used.push(nxt);
                cur = nxt;
            }
            chains.push(chain);
        }
        // Anything unreachable (e.g. part of a declared cycle) becomes a singleton.
        for a in attrs {
            if !used.contains(&a.as_str()) {
                chains.push(vec![a.clone()]);
            }
        }
        chains
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::schema::{Attr, Schema};
    use crate::data::value::Value;

    fn location_db() -> Database {
        let mut rel = Relation::new(
            "location",
            Schema::new(vec![Attr::cat("store", 4), Attr::cat("zip", 3), Attr::cat("city", 2)]),
        );
        // store -> zip -> city holds.
        rel.push_row(&[Value::Cat(0), Value::Cat(0), Value::Cat(0)]);
        rel.push_row(&[Value::Cat(1), Value::Cat(0), Value::Cat(0)]);
        rel.push_row(&[Value::Cat(2), Value::Cat(1), Value::Cat(1)]);
        rel.push_row(&[Value::Cat(3), Value::Cat(2), Value::Cat(1)]);
        let mut db = Database::new();
        db.add(rel);
        db.add_fd("store", "zip");
        db.add_fd("zip", "city");
        db
    }

    #[test]
    fn lookup_and_sizes() {
        let db = location_db();
        assert!(db.get("location").is_some());
        assert!(db.get("missing").is_none());
        assert_eq!(db.total_rows(), 4);
        assert!(db.total_bytes() > 0);
    }

    #[test]
    fn fd_verification() {
        let db = location_db();
        assert!(db.verify_fd(&Fd { determinant: "store".into(), dependent: "zip".into() }));
        assert!(db.verify_fd(&Fd { determinant: "zip".into(), dependent: "city".into() }));
        // zip does NOT determine store.
        assert!(!db.verify_fd(&Fd { determinant: "zip".into(), dependent: "store".into() }));
        // Unknown attribute pair.
        assert!(!db.verify_fd(&Fd { determinant: "a".into(), dependent: "b".into() }));
    }

    #[test]
    fn fd_chains_follow_declarations() {
        let db = location_db();
        let attrs: Vec<String> =
            ["store", "zip", "city", "other"].iter().map(|s| s.to_string()).collect();
        let chains = db.fd_chains(&attrs);
        assert!(chains.contains(&vec!["store".to_string(), "zip".to_string(), "city".to_string()]));
        assert!(chains.contains(&vec!["other".to_string()]));
        // Every attribute appears exactly once across all chains.
        let total: usize = chains.iter().map(|c| c.len()).sum();
        assert_eq!(total, attrs.len());
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn duplicate_relation_rejected() {
        let mut db = location_db();
        let rel = Relation::new("location", Schema::new(vec![Attr::int("x")]));
        db.add(rel);
    }
}
