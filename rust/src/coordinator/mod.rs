//! Streaming coordinator: the Layer-3 orchestrator that keeps clusters
//! fresh while relational tuples stream in.
//!
//! The paper's engine is batch; a production deployment of Rk-means sits
//! behind an ingestion pipeline. This module provides that shape:
//!
//! * **Bounded ingestion** — producers `insert()` tuples through a
//!   `sync_channel`; when the coordinator falls behind, producers block
//!   (backpressure) instead of ballooning memory.
//! * **Delta-triggered re-clustering** — after `recluster_every` new
//!   tuples (or an explicit [`Coordinator::flush`]) the worker re-runs the
//!   full Rk-means pipeline. Because Rk-means touches only the base
//!   relations (never `X`), a re-cluster costs `Õ(|D|)`, which is what
//!   makes *streaming* re-clustering affordable at all — the baseline
//!   would re-materialize the join every time.
//! * **Versioned results** — each completed job is published on a results
//!   channel as a [`ClusteringUpdate`]; consumers read the latest.
//! * **Metrics** — counters for ingested/dropped tuples, job counts and
//!   durations, via [`crate::metrics::Metrics`].

use crate::data::{Database, Value};
use crate::metrics::Metrics;
use crate::query::Feq;
use crate::rkmeans::{rkmeans, RkConfig, RkResult};
use anyhow::{anyhow, Result};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::Mutex;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Re-cluster after this many ingested tuples.
    pub recluster_every: usize,
    /// Bounded queue depth; producers block beyond this (backpressure).
    pub channel_capacity: usize,
    /// Clustering configuration for each job.
    pub rk: RkConfig,
}

impl CoordinatorConfig {
    /// Sensible defaults for examples/tests.
    pub fn new(rk: RkConfig) -> Self {
        CoordinatorConfig { recluster_every: 10_000, channel_capacity: 1024, rk }
    }
}

/// A published clustering result.
#[derive(Debug)]
pub struct ClusteringUpdate {
    /// Monotonically increasing job id.
    pub version: u64,
    /// Total tuples ingested when the job started.
    pub ingested: u64,
    /// The clustering itself.
    pub result: RkResult,
    /// Wall-clock of this job.
    pub elapsed: Duration,
}

enum Msg {
    Insert { relation: String, values: Vec<Value>, weight: f64 },
    Flush,
    Shutdown,
}

/// Handle to the coordinator worker.
pub struct Coordinator {
    tx: SyncSender<Msg>,
    results: Mutex<Receiver<ClusteringUpdate>>,
    worker: Option<JoinHandle<Database>>,
    metrics: Metrics,
}

impl Coordinator {
    /// Start the worker thread owning `db`.
    pub fn start(db: Database, feq: Feq, cfg: CoordinatorConfig) -> Coordinator {
        let (tx, rx) = sync_channel::<Msg>(cfg.channel_capacity);
        let (res_tx, res_rx) = sync_channel::<ClusteringUpdate>(16);
        let metrics = Metrics::new();
        let m = metrics.clone();

        let worker = std::thread::spawn(move || {
            let mut db = db;
            let mut since_recluster = 0usize;
            let mut ingested = 0u64;
            let mut version = 0u64;
            let ingest_ctr = m.counter("coordinator.ingested");
            let err_ctr = m.counter("coordinator.insert_errors");
            let job_ctr = m.counter("coordinator.jobs");
            let depth = m.gauge("coordinator.since_recluster");

            let run_job = |db: &Database, ingested: u64, version: &mut u64| {
                let t0 = Instant::now();
                match rkmeans(db, &feq, &cfg.rk) {
                    Ok(result) => {
                        *version += 1;
                        job_ctr.inc();
                        // Drop the update if consumers are slow — latest
                        // result wins; never block ingestion on readers.
                        let _ = res_tx.try_send(ClusteringUpdate {
                            version: *version,
                            ingested,
                            result,
                            elapsed: t0.elapsed(),
                        });
                    }
                    Err(e) => eprintln!("coordinator: clustering failed: {e}"),
                }
            };

            while let Ok(msg) = rx.recv() {
                match msg {
                    Msg::Insert { relation, values, weight } => {
                        match db.get_mut(&relation) {
                            Some(rel) if values.len() == rel.n_cols() => {
                                if weight == 1.0 {
                                    rel.push_row(&values);
                                } else {
                                    rel.push_row_weighted(&values, weight);
                                }
                                ingested += 1;
                                since_recluster += 1;
                                ingest_ctr.inc();
                                depth.set(since_recluster as i64);
                            }
                            _ => err_ctr.inc(),
                        }
                        if since_recluster >= cfg.recluster_every {
                            since_recluster = 0;
                            depth.set(0);
                            run_job(&db, ingested, &mut version);
                        }
                    }
                    Msg::Flush => {
                        since_recluster = 0;
                        depth.set(0);
                        run_job(&db, ingested, &mut version);
                    }
                    Msg::Shutdown => break,
                }
            }
            db
        });

        Coordinator { tx, results: Mutex::new(res_rx), worker: Some(worker), metrics }
    }

    /// Ingest one tuple; blocks when the queue is full (backpressure).
    pub fn insert(&self, relation: &str, values: Vec<Value>) -> Result<()> {
        self.tx
            .send(Msg::Insert { relation: relation.to_string(), values, weight: 1.0 })
            .map_err(|_| anyhow!("coordinator is shut down"))
    }

    /// Ingest one weighted tuple.
    pub fn insert_weighted(&self, relation: &str, values: Vec<Value>, weight: f64) -> Result<()> {
        self.tx
            .send(Msg::Insert { relation: relation.to_string(), values, weight })
            .map_err(|_| anyhow!("coordinator is shut down"))
    }

    /// Force a re-cluster of the current state.
    pub fn flush(&self) -> Result<()> {
        self.tx.send(Msg::Flush).map_err(|_| anyhow!("coordinator is shut down"))
    }

    /// Wait for the next clustering update.
    pub fn recv_update(&self, timeout: Duration) -> Option<ClusteringUpdate> {
        match self.results.lock().expect("results lock").recv_timeout(timeout) {
            Ok(u) => Some(u),
            Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => None,
        }
    }

    /// Shared metrics registry.
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Stop the worker and return the final database state.
    pub fn shutdown(mut self) -> Result<Database> {
        let _ = self.tx.send(Msg::Shutdown);
        let worker = self.worker.take().expect("worker present until shutdown");
        worker.join().map_err(|_| anyhow!("coordinator worker panicked"))
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Attr, Relation, Schema};

    fn setup() -> (Database, Feq) {
        let mut fact =
            Relation::new("fact", Schema::new(vec![Attr::cat("c", 4), Attr::double("x")]));
        for i in 0..20u32 {
            fact.push_row(&[Value::Cat(i % 4), Value::Double(i as f64)]);
        }
        let mut db = Database::new();
        db.add(fact);
        (db, Feq::with_features(&["fact"], &["c", "x"]))
    }

    #[test]
    fn ingest_then_flush_publishes_update() {
        let (db, feq) = setup();
        let cfg = CoordinatorConfig::new(RkConfig::new(2));
        let coord = Coordinator::start(db, feq, cfg);
        for i in 0..50u32 {
            coord.insert("fact", vec![Value::Cat(i % 4), Value::Double(i as f64 + 100.0)]).unwrap();
        }
        coord.flush().unwrap();
        let update = coord.recv_update(Duration::from_secs(10)).expect("update");
        assert_eq!(update.version, 1);
        assert_eq!(update.ingested, 50);
        assert!(update.result.grid_points > 0);
        let db = coord.shutdown().unwrap();
        assert_eq!(db.get("fact").unwrap().n_rows(), 70);
    }

    #[test]
    fn delta_threshold_triggers_job() {
        let (db, feq) = setup();
        let mut cfg = CoordinatorConfig::new(RkConfig::new(2));
        cfg.recluster_every = 10;
        let coord = Coordinator::start(db, feq, cfg);
        for i in 0..10u32 {
            coord.insert("fact", vec![Value::Cat(i % 4), Value::Double(i as f64)]).unwrap();
        }
        let update = coord.recv_update(Duration::from_secs(10)).expect("auto update");
        assert_eq!(update.ingested, 10);
        coord.shutdown().unwrap();
    }

    #[test]
    fn bad_inserts_are_counted_not_fatal() {
        let (db, feq) = setup();
        let coord = Coordinator::start(db, feq, CoordinatorConfig::new(RkConfig::new(2)));
        coord.insert("missing_relation", vec![Value::Cat(0)]).unwrap();
        coord.insert("fact", vec![Value::Cat(0)]).unwrap(); // arity mismatch
        coord.flush().unwrap();
        let _ = coord.recv_update(Duration::from_secs(10));
        assert_eq!(coord.metrics().counter("coordinator.insert_errors").get(), 2);
        coord.shutdown().unwrap();
    }

    #[test]
    fn shutdown_is_idempotent_under_drop() {
        let (db, feq) = setup();
        let coord = Coordinator::start(db, feq, CoordinatorConfig::new(RkConfig::new(2)));
        drop(coord); // must not hang or panic
    }
}
