//! `RkModel` — the self-contained serving handle capping the staged
//! pipeline (see [`crate::rkmeans::pipeline`]).
//!
//! A model owns the factored Step-4 centroids plus the Step-2 subspace
//! assigners, which is everything needed to answer *"which cluster does
//! this tuple belong to?"* for tuples of the (never materialized) join
//! output — no [`Database`](crate::data::Database), join tree, or grid
//! required at serving time. Assignment is exact: for each subspace the
//! squared distance to a factored centroid is computed in O(1) via the
//! orthogonal-component algebra of §4.3, so
//! [`RkModel::assign`] agrees with the argmin over the dense
//! [`centroids_dense`](crate::coreset::centroids_dense) expansion up to
//! f64 rounding.
//!
//! Models serialize to a **versioned** byte format
//! ([`RkModel::to_bytes`] / [`RkModel::from_bytes`], JSON via
//! [`crate::util::json`]): a writer process can snapshot its
//! [`IncrementalState`](crate::incremental::IncrementalState) or a
//! coordinator [`ClusteringUpdate`](crate::coordinator::ClusteringUpdate)
//! as a model, ship the bytes, and have replicas serve that version while
//! the writer keeps patching. Parsing returns the **typed**
//! [`ModelParseError`] naming the failing field (truncated payloads,
//! missing fields, shape mismatches); a format-version mismatch fails
//! loudly instead of mis-deserializing. Between versions, the serving
//! tier ships **centroid deltas** rather than full snapshots:
//! [`RkModel::diff`] / [`RkModel::apply_delta`] live in
//! [`crate::serve::delta`] and reuse this module's canonical JSON
//! writer, so every shipped f64 round-trips bit-exactly.
//!
//! ```no_run
//! use rkmeans::rkmeans::{RkModel, RkPipeline, ClusterOpts, SubspaceOpts};
//! use rkmeans::synthetic::{retailer, Scale};
//!
//! let db = retailer::generate(Scale::tiny(), 42);
//! let feq = retailer::feq();
//! let pipe = RkPipeline::plan(&db, &feq).unwrap();
//! let marginals = pipe.marginals().unwrap();
//! let subspaces = pipe.subspaces(&marginals, &SubspaceOpts::new(8)).unwrap();
//! let coreset = pipe.coreset(&subspaces).unwrap();
//! let model = coreset.cluster(&ClusterOpts::new(8));
//!
//! // Ship to a replica; serve without the database. `assign` takes a
//! // tuple's feature values in FEQ feature order.
//! let bytes = model.to_bytes();
//! let replica = RkModel::from_bytes(&bytes).unwrap();
//! assert_eq!(replica.k(), 8);
//! ```

use super::{RkResult, StepTimings};
use crate::cluster::sparse_lloyd::CentroidCoord;
use crate::cluster::{CatClusters, Kmeans1dResult, PruneStats};
use crate::coreset::{SubspaceModel, SubspaceSolver};
use crate::data::Value;
use crate::util::json::{self, Json};
use crate::util::FxHashMap;
use std::collections::BTreeMap;
use std::fmt;
use std::sync::OnceLock;

/// Version tag of the `RkModel` byte format. Bumped on any incompatible
/// layout change; [`RkModel::from_bytes`] refuses other versions.
pub const RKMODEL_FORMAT_VERSION: usize = 1;

/// Typed parse error for the model (and model-delta) wire formats.
///
/// Every variant names what failed — the field for missing/malformed
/// entries, the found version for format mismatches — so a replica
/// rejecting a payload can log something actionable instead of a generic
/// JSON error. Implements [`std::error::Error`], so `?` still converts
/// into [`anyhow::Error`] at existing call sites.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ModelParseError {
    /// The payload is not valid UTF-8 (e.g. a torn or binary write).
    Utf8,
    /// The payload is not valid JSON; the message carries the parser's
    /// diagnosis (truncated documents land here).
    Json(String),
    /// The document parses but lacks the expected `"format"` tag — it is
    /// some other JSON, not a `expected` document.
    NotADocument {
        /// The format tag this reader expects (`"rkmodel"` /
        /// `"rkmodel-delta"`).
        expected: &'static str,
    },
    /// Known document kind, incompatible format version.
    UnsupportedFormatVersion {
        /// Version tag found in the payload.
        found: usize,
        /// The single version this build reads.
        supported: usize,
    },
    /// A required field is absent (or carries the wrong JSON type).
    MissingField {
        /// Name/path of the absent field.
        field: String,
    },
    /// A field is present but malformed; `reason` says how.
    BadField {
        /// Name/path of the offending field.
        field: String,
        /// What is wrong with it.
        reason: String,
    },
}

impl ModelParseError {
    pub(crate) fn missing(field: impl Into<String>) -> ModelParseError {
        ModelParseError::MissingField { field: field.into() }
    }

    pub(crate) fn bad(field: impl Into<String>, reason: impl Into<String>) -> ModelParseError {
        ModelParseError::BadField { field: field.into(), reason: reason.into() }
    }
}

impl fmt::Display for ModelParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ModelParseError::Utf8 => write!(f, "rkmodel: bytes are not valid UTF-8"),
            ModelParseError::Json(msg) => write!(f, "rkmodel: {msg}"),
            ModelParseError::NotADocument { expected } => write!(
                f,
                "rkmodel: byte stream is not a {expected:?} document (missing \"format\" tag)"
            ),
            ModelParseError::UnsupportedFormatVersion { found, supported } => write!(
                f,
                "rkmodel: unsupported format version {found} (this build reads version \
                 {supported}); re-export with a matching writer"
            ),
            ModelParseError::MissingField { field } => {
                write!(f, "rkmodel: missing field {field:?}")
            }
            ModelParseError::BadField { field, reason } => {
                write!(f, "rkmodel: bad field {field:?}: {reason}")
            }
        }
    }
}

impl std::error::Error for ModelParseError {}

/// Serving lookup tables, built lazily on the first
/// [`RkModel::assign`]/[`RkModel::distance2`] call so Step-4-only
/// consumers (the incremental patch path, k-sweeps) never pay the
/// O(total-category-keys) construction per run.
#[derive(Clone, Debug)]
struct ServeCache {
    /// Per-subspace index for categorical features:
    /// `key → (component id, ⟨e_key, u_component⟩)`. `None` for
    /// continuous subspaces.
    cat_dots: Vec<Option<FxHashMap<u64, (u32, f64)>>>,
    /// `‖μ_cj‖²` per (centroid, subspace) for categorical subspaces
    /// (0.0 for continuous ones), hoisted out of the assignment loop.
    cent_norm_sq: Vec<Vec<f64>>,
}

impl ServeCache {
    fn build(models: &[SubspaceModel], centroids: &[Vec<CentroidCoord>]) -> ServeCache {
        let cat_dots: Vec<Option<FxHashMap<u64, (u32, f64)>>> = models
            .iter()
            .map(|m| match &m.solver {
                SubspaceSolver::Continuous(_) => None,
                SubspaceSolver::Categorical(c) => {
                    let mut dots: FxHashMap<u64, (u32, f64)> = FxHashMap::default();
                    for (i, &e) in c.heavy.iter().enumerate() {
                        let gid = u32::try_from(i).expect("heavy-hitter index fits u32");
                        dots.insert(e, (gid, 1.0));
                    }
                    if c.has_light() {
                        let g = c.light_gid();
                        for &(e, w) in &c.light {
                            dots.insert(e, (g, w / c.light_mass));
                        }
                    }
                    Some(dots)
                }
            })
            .collect();
        let cent_norm_sq: Vec<Vec<f64>> = centroids
            .iter()
            .map(|coords| {
                coords
                    .iter()
                    .zip(models)
                    .map(|(coord, m)| match (coord, &m.solver) {
                        (CentroidCoord::Categorical(beta), SubspaceSolver::Categorical(c)) => {
                            beta.iter()
                                .enumerate()
                                .map(|(b, &x)| {
                                    let b = u32::try_from(b).expect("group index fits u32");
                                    x * x * c.component_norm_sq(b)
                                })
                                .sum()
                        }
                        _ => 0.0,
                    })
                    .collect()
            })
            .collect();
        ServeCache { cat_dots, cent_norm_sq }
    }
}

/// A self-contained, serializable Rk-means serving model: factored
/// centroids + per-subspace assigners (see module docs).
#[derive(Clone, Debug)]
pub struct RkModel {
    /// State version this model serves (the incremental engine's
    /// monotonically increasing version; 0 for plain batch builds).
    pub version: u64,
    /// Per-subspace Step-2 models (geometry + assigners).
    pub models: Vec<SubspaceModel>,
    /// Factored centroids (k × m); expand with
    /// [`crate::coreset::centroids_dense`].
    pub centroids: Vec<Vec<CentroidCoord>>,
    /// Weighted k-means objective on the coreset this model was fit to.
    pub objective_grid: f64,
    /// Coreset quantization error Σ_j Step-2 cost (Eq. 9).
    pub quantization_cost: f64,
    /// Non-zero grid cells `|G|` of the coreset.
    pub grid_points: usize,
    /// Total grid mass (= weighted `|X|`) of the coreset.
    pub grid_mass: f64,
    /// Step-4 Lloyd iterations of the fit.
    pub iters: usize,
    /// Per-step wall-clock of the build (not serialized; default after
    /// [`RkModel::from_bytes`]).
    pub timings: StepTimings,
    /// Step-4 engine statistics of the fit (not serialized).
    pub step4_stats: PruneStats,
    /// Lazily-built serving tables (see [`ServeCache`]).
    serve: OnceLock<ServeCache>,
}

impl RkModel {
    /// Build a model from pipeline outputs. Serving caches are **not**
    /// built here — they materialize on the first
    /// [`RkModel::assign`]/[`RkModel::distance2`] call, so hot paths that
    /// only need the [`RkResult`] shape (the incremental patch loop,
    /// k-sweeps) stay O(1) in the categorical domain size.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn assemble(
        models: Vec<SubspaceModel>,
        centroids: Vec<Vec<CentroidCoord>>,
        objective_grid: f64,
        quantization_cost: f64,
        grid_points: usize,
        grid_mass: f64,
        iters: usize,
        timings: StepTimings,
        step4_stats: PruneStats,
        version: u64,
    ) -> RkModel {
        RkModel {
            version,
            models,
            centroids,
            objective_grid,
            quantization_cost,
            grid_points,
            grid_mass,
            iters,
            timings,
            step4_stats,
            serve: OnceLock::new(),
        }
    }

    /// The serving tables, built on first use.
    fn serve(&self) -> &ServeCache {
        self.serve.get_or_init(|| ServeCache::build(&self.models, &self.centroids))
    }

    /// Number of clusters k.
    pub fn k(&self) -> usize {
        self.centroids.len()
    }

    /// Number of subspaces m.
    pub fn m(&self) -> usize {
        self.models.len()
    }

    /// Tag the model with a serving/state version.
    pub fn with_version(mut self, version: u64) -> Self {
        self.version = version;
        self
    }

    /// Wrap an [`RkResult`] (e.g. a coordinator
    /// [`ClusteringUpdate`](crate::coordinator::ClusteringUpdate) payload)
    /// as a serving model.
    pub fn from_result(res: &RkResult) -> RkModel {
        RkModel::assemble(
            res.models.clone(),
            res.centroids.clone(),
            res.objective_grid,
            res.quantization_cost,
            res.grid_points,
            res.grid_mass,
            res.iters,
            res.timings.clone(),
            res.step4_stats.clone(),
            0,
        )
    }

    /// Convert into the classic [`RkResult`] (the shape the deprecated
    /// one-shot [`rkmeans`](crate::rkmeans::rkmeans) shim returns).
    pub fn into_result(self) -> RkResult {
        RkResult {
            centroids: self.centroids,
            models: self.models,
            objective_grid: self.objective_grid,
            quantization_cost: self.quantization_cost,
            grid_points: self.grid_points,
            grid_mass: self.grid_mass,
            iters: self.iters,
            timings: self.timings,
            step4_stats: self.step4_stats,
        }
    }

    /// Exact squared distance (in the dense one-hot embedding, scaled by
    /// the feature weights λ) between a raw feature tuple and centroid
    /// `c`, computed in O(m) without materializing either vector.
    ///
    /// `vals` are the tuple's feature values in FEQ feature order —
    /// exactly one [`Value`] per subspace. Panics on an arity mismatch or
    /// on a continuous value supplied for a categorical subspace (numeric
    /// values on continuous subspaces accept any variant via their
    /// numeric view, matching the dense embedding).
    pub fn distance2(&self, vals: &[Value], c: usize) -> f64 {
        assert_eq!(
            vals.len(),
            self.models.len(),
            "tuple arity mismatch: model expects {} feature values",
            self.models.len()
        );
        let serve = self.serve();
        let coords = &self.centroids[c];
        let mut d = 0.0;
        for (j, (m, coord)) in self.models.iter().zip(coords).enumerate() {
            d += m.lambda
                * match (coord, &m.solver) {
                    (CentroidCoord::Continuous(mu), SubspaceSolver::Continuous(_)) => {
                        let t = vals[j].as_f64() - mu;
                        t * t
                    }
                    (CentroidCoord::Categorical(beta), SubspaceSolver::Categorical(_)) => {
                        // ‖e − μ‖² = 1 − 2⟨e, μ⟩ + ‖μ‖² with the
                        // orthogonal-component expansion of ⟨e, μ⟩;
                        // unseen keys have ⟨e, μ⟩ = 0.
                        let key = match vals[j] {
                            Value::Double(_) => panic!(
                                "feature {:?} is categorical but received a continuous \
                                 value; pass Cat/Int keys in FEQ feature order",
                                m.name
                            ),
                            v => v.key_u64(),
                        };
                        let dots = serve.cat_dots[j].as_ref().expect("categorical cache");
                        let dot = dots
                            .get(&key)
                            .map(|&(g, x)| {
                                let g = usize::try_from(g).expect("group id fits usize");
                                beta[g] * x
                            })
                            .unwrap_or(0.0);
                        1.0 - 2.0 * dot + serve.cent_norm_sq[c][j]
                    }
                    _ => unreachable!("centroid coordinate kind mismatches subspace solver"),
                };
        }
        d
    }

    /// Nearest centroid plus its squared distance for a raw tuple.
    pub fn assign_with_distance(&self, vals: &[Value]) -> (usize, f64) {
        let mut best = 0usize;
        let mut best_d = f64::INFINITY;
        for c in 0..self.centroids.len() {
            let d = self.distance2(vals, c);
            if d < best_d {
                best = c;
                best_d = d;
            }
        }
        (best, best_d)
    }

    /// Cluster id of the nearest centroid for a raw tuple (values in FEQ
    /// feature order). Exact w.r.t. the dense embedding; O(k·m).
    pub fn assign(&self, vals: &[Value]) -> usize {
        self.assign_with_distance(vals).0
    }

    /// [`RkModel::assign`] over a batch of tuples.
    pub fn assign_batch(&self, rows: &[Vec<Value>]) -> Vec<usize> {
        rows.iter().map(|r| self.assign(r)).collect()
    }

    /// Serialize to the versioned byte format (JSON, UTF-8). The payload
    /// is self-contained: [`RkModel::from_bytes`] in a fresh process
    /// restores a model that assigns identically.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut top: BTreeMap<String, Json> = BTreeMap::new();
        top.insert("format".to_string(), Json::Str("rkmodel".to_string()));
        top.insert("format_version".to_string(), Json::count(RKMODEL_FORMAT_VERSION));
        // Like category keys, the version is a decimal string so the
        // full u64 range round-trips exactly (f64 only covers 2^53).
        top.insert("state_version".to_string(), Json::Str(self.version.to_string()));
        top.insert("k".to_string(), Json::count(self.centroids.len()));
        top.insert("objective_grid".to_string(), Json::Num(self.objective_grid));
        top.insert(
            "quantization_cost".to_string(),
            Json::Num(self.quantization_cost),
        );
        top.insert("grid_points".to_string(), Json::count(self.grid_points));
        top.insert("grid_mass".to_string(), Json::Num(self.grid_mass));
        top.insert("iters".to_string(), Json::count(self.iters));
        top.insert(
            "subspaces".to_string(),
            Json::Arr(self.models.iter().map(subspace_json).collect()),
        );
        top.insert(
            "centroids".to_string(),
            Json::Arr(
                self.centroids
                    .iter()
                    .map(|coords| Json::Arr(coords.iter().map(coord_json).collect()))
                    .collect(),
            ),
        );
        Json::Obj(top).to_string().into_bytes()
    }

    /// Restore a model from [`RkModel::to_bytes`] output. Fails with a
    /// typed [`ModelParseError`] naming the failing field on truncated
    /// or malformed payloads, and on format-version mismatches (forward
    /// compatibility is explicit, never silent).
    pub fn from_bytes(bytes: &[u8]) -> Result<RkModel, ModelParseError> {
        let text = std::str::from_utf8(bytes).map_err(|_| ModelParseError::Utf8)?;
        let doc = json::parse(text).map_err(|e| ModelParseError::Json(e.to_string()))?;
        expect_format(&doc, "rkmodel")?;
        let fmt = usize_field(&doc, "format_version")?;
        if fmt != RKMODEL_FORMAT_VERSION {
            return Err(ModelParseError::UnsupportedFormatVersion {
                found: fmt,
                supported: RKMODEL_FORMAT_VERSION,
            });
        }
        let version = u64_str_field(&doc, "state_version")?;
        let k = usize_field(&doc, "k")?;
        let objective_grid = num_field(&doc, "objective_grid")?;
        let quantization_cost = num_field(&doc, "quantization_cost")?;
        let grid_points = usize_field(&doc, "grid_points")?;
        let grid_mass = num_field(&doc, "grid_mass")?;
        let iters = usize_field(&doc, "iters")?;

        let subs_json = arr_field(&doc, "subspaces")?;
        let mut models = Vec::with_capacity(subs_json.len());
        for s in subs_json {
            models.push(subspace_from_json(s)?);
        }

        let cents_json = arr_field(&doc, "centroids")?;
        if cents_json.len() != k {
            return Err(ModelParseError::bad(
                "centroids",
                format!("{} centroid rows but k = {k}", cents_json.len()),
            ));
        }
        let mut centroids = Vec::with_capacity(cents_json.len());
        for cj in cents_json {
            let coords_json = cj.as_arr().ok_or_else(|| {
                ModelParseError::bad("centroids", "centroid is not an array of coordinates")
            })?;
            if coords_json.len() != models.len() {
                return Err(ModelParseError::bad(
                    "centroids",
                    format!(
                        "centroid has {} coordinates but the model has {} subspaces",
                        coords_json.len(),
                        models.len()
                    ),
                ));
            }
            let mut coords = Vec::with_capacity(coords_json.len());
            for (j, coord) in coords_json.iter().enumerate() {
                coords.push(coord_from_json(coord, &models[j])?);
            }
            centroids.push(coords);
        }

        Ok(RkModel::assemble(
            models,
            centroids,
            objective_grid,
            quantization_cost,
            grid_points,
            grid_mass,
            iters,
            StepTimings::default(),
            PruneStats::default(),
            version,
        ))
    }
}

/// Check the document's `"format"` tag (shared with the delta reader).
pub(crate) fn expect_format(doc: &Json, expected: &'static str) -> Result<(), ModelParseError> {
    match doc.get("format").and_then(Json::as_str) {
        Some(tag) if tag == expected => Ok(()),
        _ => Err(ModelParseError::NotADocument { expected }),
    }
}

pub(crate) fn num_field(o: &Json, key: &str) -> Result<f64, ModelParseError> {
    o.get(key).and_then(Json::as_f64).ok_or_else(|| ModelParseError::missing(key))
}

pub(crate) fn usize_field(o: &Json, key: &str) -> Result<usize, ModelParseError> {
    let v = o.get(key).ok_or_else(|| ModelParseError::missing(key))?;
    v.as_usize().ok_or_else(|| {
        ModelParseError::bad(key, "not an exact non-negative integer below 2^53")
    })
}

pub(crate) fn arr_field<'a>(o: &'a Json, key: &str) -> Result<&'a [Json], ModelParseError> {
    o.get(key).and_then(Json::as_arr).ok_or_else(|| ModelParseError::missing(key))
}

/// A u64 carried as a decimal string (versions, like category keys, use
/// strings so the full u64 range round-trips exactly — f64 JSON numbers
/// only cover 2^53).
pub(crate) fn u64_str_field(o: &Json, key: &str) -> Result<u64, ModelParseError> {
    let s = o.get(key).and_then(Json::as_str).ok_or_else(|| ModelParseError::missing(key))?;
    s.parse::<u64>()
        .map_err(|_| ModelParseError::bad(key, format!("{s:?} is not a u64 decimal string")))
}

pub(crate) fn f64_arr(j: &Json, what: &str) -> Result<Vec<f64>, ModelParseError> {
    let arr = j.as_arr().ok_or_else(|| ModelParseError::bad(what, "not an array"))?;
    arr.iter()
        .map(|v| v.as_f64().ok_or_else(|| ModelParseError::bad(what, "non-numeric entry")))
        .collect()
}

/// Category keys serialize as decimal strings so the full u64 range
/// round-trips exactly (f64 JSON numbers only cover 2^53).
pub(crate) fn key_arr(j: &Json, what: &str) -> Result<Vec<u64>, ModelParseError> {
    let arr = j.as_arr().ok_or_else(|| ModelParseError::bad(what, "not an array"))?;
    arr.iter()
        .map(|v| -> Result<u64, ModelParseError> {
            let s = v
                .as_str()
                .ok_or_else(|| ModelParseError::bad(what, "category key is not a string"))?;
            s.parse::<u64>()
                .map_err(|_| ModelParseError::bad(what, format!("bad category key {s:?}")))
        })
        .collect()
}

pub(crate) fn subspace_json(m: &SubspaceModel) -> Json {
    let mut o: BTreeMap<String, Json> = BTreeMap::new();
    o.insert("name".to_string(), Json::Str(m.name.clone()));
    o.insert("lambda".to_string(), Json::Num(m.lambda));
    o.insert("cost".to_string(), Json::Num(m.cost));
    match &m.solver {
        SubspaceSolver::Continuous(r) => {
            o.insert("solver".to_string(), Json::Str("continuous".to_string()));
            o.insert(
                "centers".to_string(),
                Json::Arr(r.centers.iter().map(|&v| Json::Num(v)).collect()),
            );
            o.insert(
                "boundaries".to_string(),
                Json::Arr(r.boundaries.iter().map(|&v| Json::Num(v)).collect()),
            );
            o.insert("solver_cost".to_string(), Json::Num(r.cost));
        }
        SubspaceSolver::Categorical(c) => {
            o.insert("solver".to_string(), Json::Str("categorical".to_string()));
            o.insert(
                "heavy".to_string(),
                Json::Arr(c.heavy.iter().map(|e| Json::Str(e.to_string())).collect()),
            );
            o.insert(
                "heavy_w".to_string(),
                Json::Arr(c.heavy_w.iter().map(|&v| Json::Num(v)).collect()),
            );
            o.insert(
                "light".to_string(),
                Json::Arr(
                    c.light
                        .iter()
                        .map(|&(e, w)| Json::Arr(vec![Json::Str(e.to_string()), Json::Num(w)]))
                        .collect(),
                ),
            );
            o.insert("solver_cost".to_string(), Json::Num(c.cost));
        }
    }
    Json::Obj(o)
}

pub(crate) fn subspace_from_json(s: &Json) -> Result<SubspaceModel, ModelParseError> {
    let name = s
        .get("name")
        .and_then(Json::as_str)
        .ok_or_else(|| ModelParseError::missing("subspace name"))?
        .to_string();
    let lambda = num_field(s, "lambda")?;
    let cost = num_field(s, "cost")?;
    let solver_cost = num_field(s, "solver_cost")?;
    let solver = match s.get("solver").and_then(Json::as_str) {
        Some("continuous") => {
            let centers = f64_arr(
                s.get("centers")
                    .ok_or_else(|| ModelParseError::missing(format!("{name}.centers")))?,
                "centers",
            )?;
            let boundaries = f64_arr(
                s.get("boundaries")
                    .ok_or_else(|| ModelParseError::missing(format!("{name}.boundaries")))?,
                "boundaries",
            )?;
            SubspaceSolver::Continuous(Kmeans1dResult { centers, boundaries, cost: solver_cost })
        }
        Some("categorical") => {
            let heavy = key_arr(
                s.get("heavy").ok_or_else(|| ModelParseError::missing(format!("{name}.heavy")))?,
                "heavy",
            )?;
            let heavy_w = f64_arr(
                s.get("heavy_w")
                    .ok_or_else(|| ModelParseError::missing(format!("{name}.heavy_w")))?,
                "heavy_w",
            )?;
            if heavy.len() != heavy_w.len() {
                return Err(ModelParseError::bad(
                    format!("{name}.heavy_w"),
                    "heavy/heavy_w length mismatch",
                ));
            }
            let light_json = s
                .get("light")
                .and_then(Json::as_arr)
                .ok_or_else(|| ModelParseError::missing(format!("{name}.light")))?;
            let mut light = Vec::with_capacity(light_json.len());
            for pair in light_json {
                let entry =
                    ModelParseError::bad(format!("{name}.light"), "not a [key, weight] pair");
                let pair = pair.as_arr().ok_or_else(|| entry.clone())?;
                if pair.len() != 2 {
                    return Err(entry);
                }
                let key = pair[0]
                    .as_str()
                    .ok_or_else(|| entry.clone())?
                    .parse::<u64>()
                    .map_err(|_| {
                        ModelParseError::bad(format!("{name}.light"), "bad light key")
                    })?;
                let w = pair[1].as_f64().ok_or_else(|| {
                    ModelParseError::bad(format!("{name}.light"), "light weight is not a number")
                })?;
                light.push((key, w));
            }
            SubspaceSolver::Categorical(CatClusters::from_parts(heavy, heavy_w, light, solver_cost))
        }
        other => {
            return Err(ModelParseError::bad(
                format!("{name}.solver"),
                format!("unknown solver kind {other:?}"),
            ))
        }
    };
    Ok(SubspaceModel { name, lambda, solver, cost })
}

pub(crate) fn coord_json(c: &CentroidCoord) -> Json {
    let mut o: BTreeMap<String, Json> = BTreeMap::new();
    match c {
        CentroidCoord::Continuous(mu) => {
            o.insert("mu".to_string(), Json::Num(*mu));
        }
        CentroidCoord::Categorical(beta) => {
            o.insert(
                "beta".to_string(),
                Json::Arr(beta.iter().map(|&b| Json::Num(b)).collect()),
            );
        }
    }
    Json::Obj(o)
}

/// Parses one centroid coordinate without knowing which subspace it
/// belongs to: `"mu"` ⇒ continuous, `"beta"` ⇒ categorical. Shape
/// validation against a concrete subspace lives in [`check_coord`].
pub(crate) fn coord_from_json_raw(j: &Json) -> Result<CentroidCoord, ModelParseError> {
    if let Some(mu) = j.get("mu").and_then(Json::as_f64) {
        Ok(CentroidCoord::Continuous(mu))
    } else if let Some(beta) = j.get("beta") {
        Ok(CentroidCoord::Categorical(f64_arr(beta, "beta")?))
    } else {
        Err(ModelParseError::bad("centroids", "centroid coordinate must carry \"mu\" or \"beta\""))
    }
}

/// Validates a parsed coordinate against its subspace: the kind must
/// match the solver and a categorical β must have exactly κ entries.
pub(crate) fn check_coord(
    coord: &CentroidCoord,
    model: &SubspaceModel,
) -> Result<(), ModelParseError> {
    match (coord, &model.solver) {
        (CentroidCoord::Continuous(_), SubspaceSolver::Continuous(_)) => Ok(()),
        (CentroidCoord::Categorical(beta), SubspaceSolver::Categorical(c)) => {
            if beta.len() != c.kappa() {
                return Err(ModelParseError::bad(
                    "centroids",
                    format!(
                        "centroid β length {} ≠ κ = {} in subspace {:?}",
                        beta.len(),
                        c.kappa(),
                        model.name
                    ),
                ));
            }
            Ok(())
        }
        (CentroidCoord::Continuous(_), SubspaceSolver::Categorical(_)) => {
            Err(ModelParseError::bad(
                "centroids",
                format!("continuous centroid coordinate on categorical subspace {:?}", model.name),
            ))
        }
        (CentroidCoord::Categorical(_), SubspaceSolver::Continuous(_)) => {
            Err(ModelParseError::bad(
                "centroids",
                format!("categorical centroid coordinate on continuous subspace {:?}", model.name),
            ))
        }
    }
}

fn coord_from_json(j: &Json, model: &SubspaceModel) -> Result<CentroidCoord, ModelParseError> {
    let coord = coord_from_json_raw(j)?;
    check_coord(&coord, model)?;
    Ok(coord)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::categorical_kmeans;
    use crate::cluster::kmeans1d;
    use crate::util::testkit::assert_close;

    /// A small hand-built model: one continuous + one categorical
    /// subspace, two centroids.
    fn sample_model() -> RkModel {
        let cont = kmeans1d(&[(0.0, 2.0), (1.0, 1.0), (10.0, 2.0)], 2);
        let cat = categorical_kmeans(&[(7u64, 5.0), (8, 3.0), (9, 1.0), (11, 1.0)], 3);
        let models = vec![
            SubspaceModel {
                name: "x".to_string(),
                lambda: 2.0,
                cost: 2.0 * cont.cost,
                solver: SubspaceSolver::Continuous(cont),
            },
            SubspaceModel {
                name: "c".to_string(),
                lambda: 1.0,
                cost: cat.cost,
                solver: SubspaceSolver::Categorical(cat),
            },
        ];
        let centroids = vec![
            vec![
                CentroidCoord::Continuous(0.4),
                CentroidCoord::Categorical(vec![0.7, 0.2, 0.1]),
            ],
            vec![
                CentroidCoord::Continuous(10.0),
                CentroidCoord::Categorical(vec![0.0, 0.5, 0.5]),
            ],
        ];
        RkModel::assemble(
            models,
            centroids,
            12.5,
            0.75,
            4,
            9.0,
            3,
            StepTimings::default(),
            PruneStats::default(),
            7,
        )
    }

    /// Dense reference: expand the tuple and centroid into explicit
    /// one-hot coordinates and compare distances.
    fn dense_distance(m: &RkModel, vals: &[Value], c: usize) -> f64 {
        // Layout: [x | e7 e8 e9 e11] with √λ scaling.
        let keys = [7u64, 8, 9, 11];
        let embed = |vals: &[Value]| -> Vec<f64> {
            let mut v = vec![0.0; 5];
            v[0] = 2.0f64.sqrt() * vals[0].as_f64();
            let key = vals[1].key_u64();
            if let Some(p) = keys.iter().position(|&k| k == key) {
                v[1 + p] = 1.0;
            }
            v
        };
        let SubspaceSolver::Categorical(cat) = &m.models[1].solver else { panic!() };
        let mut cent = vec![0.0; 5];
        let CentroidCoord::Continuous(mu) = &m.centroids[c][0] else { panic!() };
        cent[0] = 2.0f64.sqrt() * mu;
        let CentroidCoord::Categorical(beta) = &m.centroids[c][1] else { panic!() };
        for (a, &b) in beta.iter().enumerate() {
            if a < cat.heavy.len() {
                let key = cat.heavy[a];
                let p = keys.iter().position(|&k| k == key).unwrap();
                cent[1 + p] += b;
            } else {
                for &(key, w) in &cat.light {
                    let p = keys.iter().position(|&k| k == key).unwrap();
                    cent[1 + p] += b * w / cat.light_mass;
                }
            }
        }
        let x = embed(vals);
        x.iter().zip(&cent).map(|(a, b)| (a - b) * (a - b)).sum()
    }

    #[test]
    fn distance_matches_dense_embedding() {
        let m = sample_model();
        // Heavy, light, and unseen categorical keys; on/off-center values.
        for vals in [
            vec![Value::Double(0.3), Value::Cat(7)],
            vec![Value::Double(5.0), Value::Cat(9)],
            vec![Value::Double(9.7), Value::Cat(11)],
            vec![Value::Double(-2.0), Value::Cat(42)], // unseen key
        ] {
            for c in 0..m.k() {
                assert_close(m.distance2(&vals, c), dense_distance(&m, &vals, c), 1e-9);
            }
            let (a, d) = m.assign_with_distance(&vals);
            assert!(d <= m.distance2(&vals, 1 - a) + 1e-12);
        }
    }

    #[test]
    fn bytes_round_trip_preserves_assignment() {
        let m = sample_model();
        let bytes = m.to_bytes();
        let r = RkModel::from_bytes(&bytes).unwrap();
        assert_eq!(r.version, 7);
        // Versions beyond 2^53 round-trip exactly (string encoding).
        let big = m.clone().with_version(u64::MAX);
        assert_eq!(RkModel::from_bytes(&big.to_bytes()).unwrap().version, u64::MAX);
        assert_eq!(r.k(), 2);
        assert_eq!(r.m(), 2);
        assert_eq!(r.grid_points, 4);
        assert_close(r.grid_mass, 9.0, 0.0);
        assert_close(r.objective_grid, 12.5, 0.0);
        assert_close(r.quantization_cost, 0.75, 0.0);
        for vals in [
            vec![Value::Double(0.1), Value::Cat(7)],
            vec![Value::Double(10.2), Value::Cat(8)],
            vec![Value::Double(4.9), Value::Cat(99)],
        ] {
            assert_eq!(m.assign(&vals), r.assign(&vals));
            for c in 0..m.k() {
                // Distances are bit-identical: every serialized f64
                // round-trips through the shortest-repr JSON writer.
                assert_eq!(
                    m.distance2(&vals, c).to_bits(),
                    r.distance2(&vals, c).to_bits()
                );
            }
        }
    }

    #[test]
    fn version_mismatch_fails_clearly() {
        let m = sample_model();
        let text = String::from_utf8(m.to_bytes()).unwrap();
        let bumped = text.replace("\"format_version\":1", "\"format_version\":999");
        assert_ne!(text, bumped, "fixture must actually change the version");
        let err = RkModel::from_bytes(bumped.as_bytes()).unwrap_err();
        let msg = err.to_string();
        assert!(
            msg.contains("unsupported format version 999"),
            "unclear error: {msg}"
        );
    }

    #[test]
    fn garbage_bytes_fail_clearly() {
        assert!(RkModel::from_bytes(b"\xff\xfe").is_err());
        assert!(RkModel::from_bytes(b"{\"not\":\"a model\"}").is_err());
        let msg = RkModel::from_bytes(b"{}").unwrap_err().to_string();
        assert!(msg.contains("format"), "unclear error: {msg}");
    }

    #[test]
    fn truncated_payload_is_a_typed_json_error() {
        let bytes = sample_model().to_bytes();
        let cut = &bytes[..bytes.len() / 2];
        match RkModel::from_bytes(cut) {
            Err(ModelParseError::Json(_)) => {}
            other => panic!("expected ModelParseError::Json, got {other:?}"),
        }
    }

    #[test]
    fn missing_fields_are_named_in_the_error() {
        let text = String::from_utf8(sample_model().to_bytes()).unwrap();
        for field in
            ["k", "objective_grid", "grid_mass", "iters", "state_version", "subspaces", "centroids"]
        {
            let broken = text.replace(&format!("\"{field}\":"), &format!("\"_{field}\":"));
            assert_ne!(text, broken, "fixture must actually drop {field:?}");
            let err = RkModel::from_bytes(broken.as_bytes()).unwrap_err();
            assert_eq!(err, ModelParseError::missing(field), "field {field:?}");
            assert!(err.to_string().contains(field), "error must name {field:?}: {err}");
        }
    }

    #[test]
    fn bad_state_version_names_the_field() {
        let text = String::from_utf8(sample_model().to_bytes()).unwrap();
        let broken = text.replace("\"state_version\":\"7\"", "\"state_version\":\"not-a-u64\"");
        assert_ne!(text, broken, "fixture must actually corrupt the version");
        let err = RkModel::from_bytes(broken.as_bytes()).unwrap_err();
        let msg = err.to_string();
        assert!(
            matches!(err, ModelParseError::BadField { ref field, .. } if field == "state_version"),
            "expected BadField(state_version), got {err:?}"
        );
        assert!(msg.contains("state_version"), "unclear error: {msg}");
    }

    #[test]
    fn oversize_count_field_is_rejected_not_truncated() {
        let text = String::from_utf8(sample_model().to_bytes()).unwrap();
        // 2^53 + 1 parses to the f64 2^53 — the old `as usize` decode
        // would silently hand back the wrong integer; now it's typed.
        let broken = text.replace("\"iters\":3", "\"iters\":9007199254740993");
        assert_ne!(text, broken, "fixture must actually inflate iters");
        let err = RkModel::from_bytes(broken.as_bytes()).unwrap_err();
        assert!(
            matches!(err, ModelParseError::BadField { ref field, .. } if field == "iters"),
            "expected BadField(iters), got {err:?}"
        );
        assert!(err.to_string().contains("2^53"), "error should state the bound: {err}");
    }

    #[test]
    fn centroid_shape_mismatch_is_rejected() {
        let text = String::from_utf8(sample_model().to_bytes()).unwrap();
        // β of length 2 on a κ = 3 categorical subspace.
        let broken = text.replace("\"beta\":[0.7,0.2,0.1]", "\"beta\":[0.7,0.2]");
        assert_ne!(text, broken, "fixture must actually truncate a β row");
        let err = RkModel::from_bytes(broken.as_bytes()).unwrap_err();
        assert!(
            matches!(err, ModelParseError::BadField { ref field, .. } if field == "centroids"),
            "expected BadField(centroids), got {err:?}"
        );
    }

    #[test]
    fn batch_matches_single() {
        let m = sample_model();
        let rows = vec![
            vec![Value::Double(0.0), Value::Cat(7)],
            vec![Value::Double(11.0), Value::Cat(9)],
        ];
        assert_eq!(m.assign_batch(&rows), vec![m.assign(&rows[0]), m.assign(&rows[1])]);
    }
}
