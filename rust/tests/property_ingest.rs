//! Property tests for the multi-producer ingest tier: across producer
//! counts, shard counts, and trace shapes, the epoch'd merged build must
//! be **bitwise identical** to a serial single-stream ingest of the same
//! logical delta sequence.
//!
//! Bitwise equality is meaningful because the Step-3 FAQ is a counting
//! query in the ring ℤ: with unit tuple weights every per-cell sum is an
//! exactly-represented f64 integer, so neither the producer interleave,
//! the shard partition, nor the canonical intra-epoch reorder can change
//! a single bit of the merged grid (see the `ingest` module docs).
//! The suite drives the same Retailer/Favorita trace generators the
//! stream benchmarks measure, in delete-heavy and reseed-heavy
//! (insert-dominated) shapes, plus:
//!
//! * spill-then-reload ≡ never-spilled under a tiny per-shard
//!   `spill_budget` (spilling is a residency knob, not a semantic one);
//! * epoch-consistent publication: nothing closes until every producer
//!   has sealed the epoch at every shard;
//! * carried `EngineState` survives epoch merges: an engine resuming
//!   Step 4 from the carried state over the composed splice log publishes
//!   the same bits as a cold-warm-start twin.

use rkmeans::data::{Database, Value};
use rkmeans::faq::{GidAssigner, GridTable};
use rkmeans::incremental::{
    apply_to_db, assigner_map, DeltaFaq, IncrementalEngine, PlanDecision, PlannerOpts,
    SpillStats, TupleDelta,
};
use rkmeans::ingest::{IngestConfig, IngestHub};
use rkmeans::metrics::Metrics;
use rkmeans::query::{Feq, Hypergraph};
use rkmeans::rkmeans::RkConfig;
use rkmeans::synthetic::{favorita, favorita_trace, retailer, retailer_trace, Scale, TraceSpec};
use rkmeans::util::FxHashMap;

/// Fixed mod-assigner (Step-2 models are out of scope here: the property
/// under test is the epoch protocol, not the solvers). Doubles quantize
/// at quarter steps so Favorita's `unit_sales` stays exact.
struct ModAssigner {
    n: u32,
}
impl GidAssigner for ModAssigner {
    fn gid(&self, v: Value) -> u32 {
        let k = match v {
            Value::Double(x) => ((x * 4.0) as i64).rem_euclid(self.n as i64) as u64,
            other => other.key_u64(),
        };
        (k % self.n as u64) as u32
    }
    fn n_gids(&self) -> usize {
        self.n as usize
    }
}

fn mod_assigners(feq: &Feq) -> FxHashMap<String, Box<dyn GidAssigner>> {
    let mut m: FxHashMap<String, Box<dyn GidAssigner>> = FxHashMap::default();
    for f in &feq.features {
        m.insert(f.attr.clone(), Box::new(ModAssigner { n: 3 }));
    }
    m
}

fn cells_bits(gt: &GridTable) -> FxHashMap<Vec<u32>, u64> {
    gt.cells.iter().map(|(g, w)| (g.clone(), w.to_bits())).collect()
}

/// Deal `batch` across `producers` handles (round-robin, each producer's
/// share sent in reverse to stress the canonical reorder), seal, pump,
/// and assert every closed epoch equals the serial single-stream state.
fn check_epochd_equals_serial(
    db: &Database,
    feq: &Feq,
    trace: &[Vec<TupleDelta>],
    producers: usize,
    shards: usize,
) {
    let tree = Hypergraph::from_feq(db, feq).join_tree().expect("acyclic");
    let asg = mod_assigners(feq);
    let mut serial = DeltaFaq::init(db, feq, &tree, &asg).expect("serial init");
    let cfg = IngestConfig { producers, shards, queue_capacity: 1024, spill_budget: 0 };
    let mut hub = IngestHub::new(db, feq, &tree, &cfg, || mod_assigners(feq), Metrics::new())
        .expect("hub init");
    assert_eq!(
        cells_bits(&hub.grid_table()),
        cells_bits(&serial.grid_table()),
        "P={producers} S={shards}: sharded base grid diverged"
    );
    let handles: Vec<_> = (0..producers).map(|p| hub.producer(p)).collect();
    for (i, batch) in trace.iter().enumerate() {
        let epoch = (i + 1) as u64;
        for (p, h) in handles.iter().enumerate() {
            let share: Vec<&TupleDelta> = batch.iter().skip(p).step_by(producers).collect();
            for d in share.into_iter().rev() {
                h.send(epoch, d.clone()).expect("send");
            }
            h.seal(epoch).expect("seal");
        }
        let patches = hub.pump(|| mod_assigners(feq)).expect("pump");
        assert_eq!(patches.len(), 1, "P={producers} S={shards} epoch {epoch}");
        let patch = &patches[0];
        assert_eq!(patch.epoch, epoch);
        assert_eq!(patch.deltas.len(), batch.len());
        serial.apply(batch, &asg).expect("serial apply");
        assert_eq!(
            cells_bits(&patch.table),
            cells_bits(&serial.grid_table()),
            "P={producers} S={shards} epoch {epoch}: epoch'd merge diverged from serial"
        );
    }
    assert_eq!(hub.closed_epoch(), trace.len() as u64);
}

#[test]
fn retailer_delete_heavy_epochd_matches_serial_bitwise() {
    let db = retailer::generate(Scale::tiny(), 21);
    let feq = retailer::feq();
    let trace =
        retailer_trace(&db, 31, TraceSpec { batches: 3, batch_size: 32, delete_frac: 0.5 });
    // The full P × S matrix the issue names.
    for p in [1usize, 2, 4] {
        for s in [1usize, 2, 7] {
            check_epochd_equals_serial(&db, &feq, &trace, p, s);
        }
    }
}

#[test]
fn retailer_reseed_heavy_epochd_matches_serial_bitwise() {
    // Insert-dominated: the grid keeps growing fresh cells, stressing the
    // merge/diff path rather than ring cancellation.
    let db = retailer::generate(Scale::tiny(), 22);
    let feq = retailer::feq();
    let trace =
        retailer_trace(&db, 32, TraceSpec { batches: 3, batch_size: 32, delete_frac: 0.05 });
    for (p, s) in [(1usize, 2usize), (2, 7), (4, 2)] {
        check_epochd_equals_serial(&db, &feq, &trace, p, s);
    }
}

#[test]
fn favorita_epochd_matches_serial_bitwise() {
    let db = favorita::generate(Scale::tiny(), 23);
    let feq = favorita::feq();
    for (seed, delete_frac) in [(33u64, 0.5), (34u64, 0.05)] {
        let trace =
            favorita_trace(&db, seed, TraceSpec { batches: 2, batch_size: 24, delete_frac });
        for (p, s) in [(2usize, 2usize), (4, 7)] {
            check_epochd_equals_serial(&db, &feq, &trace, p, s);
        }
    }
}

#[test]
fn spill_then_reload_matches_never_spilled_bitwise() {
    // A per-shard budget of one resident message table forces constant
    // spill/reload churn; the published bits must not notice.
    let db = retailer::generate(Scale::tiny(), 24);
    let feq = retailer::feq();
    let tree = Hypergraph::from_feq(&db, &feq).join_tree().expect("acyclic");
    let plain_cfg =
        IngestConfig { producers: 2, shards: 2, queue_capacity: 1024, spill_budget: 0 };
    let spill_cfg = IngestConfig { spill_budget: 1, ..plain_cfg.clone() };
    let mut plain =
        IngestHub::new(&db, &feq, &tree, &plain_cfg, || mod_assigners(&feq), Metrics::new())
            .expect("plain hub");
    let mut spilly =
        IngestHub::new(&db, &feq, &tree, &spill_cfg, || mod_assigners(&feq), Metrics::new())
            .expect("spilly hub");
    let trace =
        retailer_trace(&db, 35, TraceSpec { batches: 4, batch_size: 24, delete_frac: 0.3 });
    for (i, batch) in trace.iter().enumerate() {
        let epoch = (i + 1) as u64;
        for hub in [&plain, &spilly] {
            let p0 = hub.producer(0);
            let p1 = hub.producer(1);
            for (j, d) in batch.iter().enumerate() {
                if j % 2 == 0 {
                    p0.send(epoch, d.clone()).expect("send");
                } else {
                    p1.send(epoch, d.clone()).expect("send");
                }
            }
            p0.seal(epoch).expect("seal");
            p1.seal(epoch).expect("seal");
        }
        let a = plain.pump(|| mod_assigners(&feq)).expect("plain pump");
        let b = spilly.pump(|| mod_assigners(&feq)).expect("spilly pump");
        assert_eq!(a.len(), 1);
        assert_eq!(b.len(), 1);
        assert_eq!(cells_bits(&a[0].table), cells_bits(&b[0].table), "epoch {epoch}");
    }
    assert!(spilly.spill_stats().spilled > 0, "budget 1 must force spills");
    assert!(spilly.spill_stats().reloaded > 0, "patching cold keys must reload");
    assert_eq!(plain.spill_stats(), SpillStats::default());
}

#[test]
fn no_epoch_closes_until_every_producer_seals_every_shard() {
    // Epoch-consistent publication: with one producer's seal missing, no
    // grid version may close — however many deltas are already applied.
    let db = retailer::generate(Scale::tiny(), 25);
    let feq = retailer::feq();
    let tree = Hypergraph::from_feq(&db, &feq).join_tree().expect("acyclic");
    let cfg = IngestConfig { producers: 2, shards: 2, queue_capacity: 1024, spill_budget: 0 };
    let mut hub = IngestHub::new(&db, &feq, &tree, &cfg, || mod_assigners(&feq), Metrics::new())
        .expect("hub init");
    let p0 = hub.producer(0);
    let p1 = hub.producer(1);
    let trace =
        retailer_trace(&db, 36, TraceSpec { batches: 1, batch_size: 20, delete_frac: 0.3 });
    let batch = &trace[0];
    for (j, d) in batch.iter().enumerate() {
        if j % 2 == 0 {
            p0.send(1, d.clone()).expect("send");
        } else {
            p1.send(1, d.clone()).expect("send");
        }
    }
    p0.seal(1).expect("seal");
    assert!(hub.pump(|| mod_assigners(&feq)).expect("pump").is_empty());
    assert_eq!(hub.closed_epoch(), 0);

    // The missing seal lands: the epoch closes with *all* deltas, equal
    // to a fresh build over the post-batch database.
    p1.seal(1).expect("seal");
    let patches = hub.pump(|| mod_assigners(&feq)).expect("pump");
    assert_eq!(patches.len(), 1);
    assert_eq!(patches[0].deltas.len(), batch.len());
    let mut db2 = db.clone();
    apply_to_db(&mut db2, batch).expect("replay");
    let asg = mod_assigners(&feq);
    let fresh = DeltaFaq::init(&db2, &feq, &tree, &asg).expect("fresh");
    assert_eq!(cells_bits(&patches[0].table), cells_bits(&fresh.grid_table()));
}

#[test]
fn carried_engine_state_resumes_bitwise_equal_to_cold_across_epochs() {
    // Two engines over the same database and config, differing only in
    // `carry_state`: the composed splice logs must keep the carried
    // Step-4 state aligned with every merged epoch grid, so the resumed
    // engine publishes bit-for-bit what the cold-warm-start twin does.
    let db0 = retailer::generate(Scale::tiny(), 26);
    let feq = retailer::feq();
    let tree = Hypergraph::from_feq(&db0, &feq).join_tree().expect("acyclic");
    let rk = RkConfig::new(4);
    let lenient = PlannerOpts {
        drift_threshold: 1.1,
        max_patch_fraction: 1.0,
        max_join_churn: f64::INFINITY,
        ..PlannerOpts::default()
    };
    let carry_opts = PlannerOpts { carry_state: true, ..lenient.clone() };
    let cold_opts = PlannerOpts { carry_state: false, ..lenient };
    let carry_metrics = Metrics::new();
    let mut eng_carry =
        IncrementalEngine::new(&db0, feq.clone(), rk.clone(), carry_opts, carry_metrics.clone())
            .expect("carry engine");
    let mut eng_cold =
        IncrementalEngine::new(&db0, feq.clone(), rk, cold_opts, Metrics::new())
            .expect("cold engine");

    // One hub feeds both engines (EpochPatch is cloneable); its grids are
    // anchored on the engines' (identical, frozen) Step-2 models.
    let shared = eng_carry.shared_result();
    let cfg = IngestConfig { producers: 2, shards: 2, queue_capacity: 1024, spill_budget: 0 };
    let mut hub =
        IngestHub::new(&db0, &feq, &tree, &cfg, || assigner_map(&shared.models), Metrics::new())
            .expect("hub init");
    let p0 = hub.producer(0);
    let p1 = hub.producer(1);
    let trace =
        retailer_trace(&db0, 41, TraceSpec { batches: 3, batch_size: 16, delete_frac: 0.3 });
    let mut db = db0.clone();
    for (i, batch) in trace.iter().enumerate() {
        let epoch = (i + 1) as u64;
        for (j, d) in batch.iter().enumerate() {
            if j % 2 == 0 {
                p0.send(epoch, d.clone()).expect("send");
            } else {
                p1.send(epoch, d.clone()).expect("send");
            }
        }
        p0.seal(epoch).expect("seal");
        p1.seal(epoch).expect("seal");
        apply_to_db(&mut db, batch).expect("replay");
        let patches = hub.pump(|| assigner_map(&shared.models)).expect("pump");
        assert_eq!(patches.len(), 1);
        let (d1, r_carry) = eng_carry.apply_epoch(&db, &patches[0]).expect("carry epoch");
        let (d2, r_cold) = eng_cold.apply_epoch(&db, &patches[0]).expect("cold epoch");
        assert_eq!(d1, PlanDecision::Patched, "epoch {epoch}");
        assert_eq!(d2, PlanDecision::Patched, "epoch {epoch}");
        assert_eq!(
            format!("{:?}", r_carry.centroids),
            format!("{:?}", r_cold.centroids),
            "epoch {epoch}: resumed centroids diverged from cold warm start"
        );
        assert_eq!(
            r_carry.objective_grid.to_bits(),
            r_cold.objective_grid.to_bits(),
            "epoch {epoch}"
        );
        assert_eq!(r_carry.grid_points, r_cold.grid_points, "epoch {epoch}");
    }
    // The carry engine genuinely resumed (the shape filter did not veto).
    assert!(
        carry_metrics.counter("incremental.resumes").get() >= 1,
        "carried state was never resumed — the pin is vacuous"
    );
}
