//! Join hypergraphs, GYO acyclicity testing and join-tree construction.
//!
//! The FAQ engine (paper §2.1) runs variable elimination over a join tree.
//! For α-acyclic queries — all three paper workloads are — the GYO ear
//! removal procedure yields a tree whose nodes are the relations and whose
//! separators are the shared attributes; Yannakakis message passing over it
//! computes counting FAQs in `Õ(N)`. We also report crude width statistics
//! (`ρ*` upper bound via greedy integral edge cover) for the Theorem 4.7
//! style `|X| ≤ N^ρ*` discussion in the bench reports.

use crate::data::Database;
use crate::query::Feq;
use anyhow::{bail, Result};
use std::collections::HashSet;

/// A join hypergraph: vertices are attribute names, hyperedges are the
/// attribute sets of the participating relations.
#[derive(Clone, Debug)]
pub struct Hypergraph {
    pub vertices: Vec<String>,
    /// (relation name, vertex indices)
    pub edges: Vec<(String, Vec<usize>)>,
}

impl Hypergraph {
    /// Build the hypergraph of an FEQ.
    pub fn from_feq(db: &Database, feq: &Feq) -> Self {
        let mut vertices: Vec<String> = Vec::new();
        let vid = |name: &str, vs: &mut Vec<String>| -> usize {
            if let Some(i) = vs.iter().position(|v| v == name) {
                i
            } else {
                vs.push(name.to_string());
                vs.len() - 1
            }
        };
        let mut edges = Vec::new();
        for rname in &feq.relations {
            let rel = db.get(rname).expect("relation exists");
            let mut e = Vec::new();
            for a in rel.schema.attrs() {
                e.push(vid(&a.name, &mut vertices));
            }
            edges.push((rname.clone(), e));
        }
        Hypergraph { vertices, edges }
    }

    /// Greedy integral edge cover of all vertices — an upper bound on the
    /// fractional edge cover number ρ* (so `N^bound` upper-bounds `|X|`).
    pub fn edge_cover_bound(&self) -> usize {
        let mut uncovered: HashSet<usize> = (0..self.vertices.len()).collect();
        let mut count = 0;
        while !uncovered.is_empty() {
            // Pick the edge covering the most uncovered vertices.
            let (best, gain) = self
                .edges
                .iter()
                .enumerate()
                .map(|(i, (_, e))| (i, e.iter().filter(|v| uncovered.contains(v)).count()))
                .max_by_key(|&(_, g)| g)
                .expect("non-empty hypergraph");
            if gain == 0 {
                break; // isolated vertices (shouldn't happen: every vertex comes from an edge)
            }
            for v in &self.edges[best].1 {
                uncovered.remove(v);
            }
            count += 1;
        }
        count
    }

    /// GYO ear-removal. Returns a join tree if the hypergraph is α-acyclic,
    /// or an error naming the stuck residual edges otherwise.
    pub fn join_tree(&self) -> Result<JoinTree> {
        let n = self.edges.len();
        let sets: Vec<HashSet<usize>> = self
            .edges
            .iter()
            .map(|(_, e)| e.iter().copied().collect())
            .collect();
        let mut alive: Vec<bool> = vec![true; n];
        let mut parent: Vec<Option<usize>> = vec![None; n];
        let mut order: Vec<usize> = Vec::new(); // removal order: leaves first
        let mut remaining = n;

        while remaining > 1 {
            // Find an ear: an edge e whose vertices-shared-with-others are
            // all contained in some single other edge w (the witness).
            let mut found = None;
            'search: for e in 0..n {
                if !alive[e] {
                    continue;
                }
                // Vertices of e that appear in any other alive edge.
                let shared: HashSet<usize> = sets[e]
                    .iter()
                    .filter(|v| {
                        (0..n).any(|o| o != e && alive[o] && sets[o].contains(v))
                    })
                    .copied()
                    .collect();
                for w in 0..n {
                    if w == e || !alive[w] {
                        continue;
                    }
                    if shared.is_subset(&sets[w]) {
                        found = Some((e, w));
                        break 'search;
                    }
                }
            }
            match found {
                Some((e, w)) => {
                    parent[e] = Some(w);
                    alive[e] = false;
                    order.push(e);
                    remaining -= 1;
                }
                None => {
                    let stuck: Vec<&str> = (0..n)
                        .filter(|&i| alive[i])
                        .map(|i| self.edges[i].0.as_str())
                        .collect();
                    bail!("cyclic join hypergraph; residual edges: {stuck:?}");
                }
            }
        }
        let root = (0..n).find(|&i| alive[i]).expect("one edge remains");
        order.push(root);

        // Separators: shared vertices between each node and its parent.
        let mut sep: Vec<Vec<String>> = vec![Vec::new(); n];
        for e in 0..n {
            if let Some(p) = parent[e] {
                let mut s: Vec<String> = sets[e]
                    .intersection(&sets[p])
                    .map(|&v| self.vertices[v].clone())
                    .collect();
                s.sort();
                sep[e] = s;
            }
        }

        Ok(JoinTree {
            rel_names: self.edges.iter().map(|(n, _)| n.clone()).collect(),
            parent,
            order,
            sep,
            root,
        })
    }
}

/// A rooted join tree over the FEQ's relations.
///
/// `order` lists node indices leaves-first (the last entry is the root), so
/// an upward Yannakakis pass is a single scan of `order` and a downward pass
/// a single reverse scan.
#[derive(Clone, Debug)]
pub struct JoinTree {
    pub rel_names: Vec<String>,
    pub parent: Vec<Option<usize>>,
    /// Leaves-first processing order (root last).
    pub order: Vec<usize>,
    /// Separator attributes shared with the parent (empty for the root).
    pub sep: Vec<Vec<String>>,
    pub root: usize,
}

impl JoinTree {
    /// Children of a node.
    pub fn children(&self, node: usize) -> Vec<usize> {
        self.parent
            .iter()
            .enumerate()
            .filter(|(_, p)| **p == Some(node))
            .map(|(i, _)| i)
            .collect()
    }

    /// Number of nodes.
    pub fn len(&self) -> usize {
        self.rel_names.len()
    }

    /// True when the tree has no nodes.
    pub fn is_empty(&self) -> bool {
        self.rel_names.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Attr, Relation, Schema};

    fn rel(name: &str, attrs: &[&str]) -> Relation {
        Relation::new(
            name,
            Schema::new(attrs.iter().map(|a| Attr::cat(a, 10)).collect()),
        )
    }

    fn db_of(rels: Vec<Relation>) -> Database {
        let mut db = Database::new();
        for r in rels {
            db.add(r);
        }
        db
    }

    #[test]
    fn star_query_is_acyclic() {
        // fact(store, sku, date) with three dimension tables.
        let db = db_of(vec![
            rel("fact", &["store", "sku", "date"]),
            rel("stores", &["store", "city"]),
            rel("items", &["sku", "cat"]),
            rel("dates", &["date", "holiday"]),
        ]);
        let feq = Feq::with_features(&["fact", "stores", "items", "dates"], &["store"]);
        let hg = Hypergraph::from_feq(&db, &feq);
        let tree = hg.join_tree().unwrap();
        assert_eq!(tree.len(), 4);
        // The dimension tables hang off the fact table (fact itself may end
        // up as an ear of its last remaining dimension — also a valid tree).
        let fact = 0;
        assert_eq!(tree.parent[1], Some(fact), "stores under fact");
        assert_eq!(tree.parent[2], Some(fact), "items under fact");
        assert_eq!(tree.sep[1], vec!["store".to_string()]);
        assert_eq!(tree.sep[2], vec!["sku".to_string()]);
        // Upward order visits children before parents.
        let pos: Vec<usize> =
            (0..4).map(|i| tree.order.iter().position(|&x| x == i).unwrap()).collect();
        for i in 0..4 {
            if let Some(p) = tree.parent[i] {
                assert!(pos[i] < pos[p], "child {i} must precede parent {p}");
            }
        }
    }

    #[test]
    fn chain_query_is_acyclic() {
        let db = db_of(vec![
            rel("a", &["x", "y"]),
            rel("b", &["y", "z"]),
            rel("c", &["z", "w"]),
        ]);
        let feq = Feq::with_features(&["a", "b", "c"], &["x"]);
        let tree = Hypergraph::from_feq(&db, &feq).join_tree().unwrap();
        assert_eq!(tree.len(), 3);
        // Exactly one root.
        assert_eq!(tree.parent.iter().filter(|p| p.is_none()).count(), 1);
    }

    #[test]
    fn triangle_is_cyclic() {
        let db = db_of(vec![
            rel("ab", &["a", "b"]),
            rel("bc", &["b", "c"]),
            rel("ca", &["c", "a"]),
        ]);
        let feq = Feq::with_features(&["ab", "bc", "ca"], &["a"]);
        let err = Hypergraph::from_feq(&db, &feq).join_tree().unwrap_err();
        assert!(err.to_string().contains("cyclic"));
    }

    #[test]
    fn edge_cover_bound_sane() {
        let db = db_of(vec![
            rel("fact", &["store", "sku"]),
            rel("stores", &["store", "city"]),
        ]);
        let feq = Feq::with_features(&["fact", "stores"], &["store"]);
        let hg = Hypergraph::from_feq(&db, &feq);
        // Two edges suffice; one edge can't cover city+sku.
        assert_eq!(hg.edge_cover_bound(), 2);
    }

    #[test]
    fn single_relation_tree() {
        let db = db_of(vec![rel("only", &["a", "b"])]);
        let feq = Feq::with_features(&["only"], &["a"]);
        let tree = Hypergraph::from_feq(&db, &feq).join_tree().unwrap();
        assert_eq!(tree.len(), 1);
        assert_eq!(tree.root, 0);
        assert!(tree.sep[0].is_empty());
    }
}
