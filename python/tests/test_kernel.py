"""L1 Pallas kernel vs the pure-jnp oracle — the core correctness signal.

Hypothesis sweeps shapes and value ranges; fixed tests cover the padding
contract the rust runtime relies on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lloyd as kernels
from compile.kernels import ref


def rand(shape, seed, lo=-5.0, hi=5.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(lo, hi, size=shape).astype(np.float32))


@settings(max_examples=25, deadline=None)
@given(
    blocks=st.integers(1, 4),
    d=st.integers(1, 48),
    k=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
    block_n=st.sampled_from([8, 32, 128]),
)
def test_assign_matches_ref(blocks, d, k, seed, block_n):
    n = blocks * block_n
    pts = rand((n, d), seed)
    cents = rand((k, d), seed + 1)
    a_k, m_k = kernels.assign(pts, cents, block_n=block_n)
    a_r, m_r = ref.assign_ref(pts, cents)
    # Distances must match tightly.
    np.testing.assert_allclose(np.asarray(m_k), np.asarray(m_r), rtol=2e-4, atol=2e-4)
    # Assignments may differ only on (near-)ties; verify via distances.
    d_k = np.sum((np.asarray(pts)[:, None, :] - np.asarray(cents)[None, :, :]) ** 2, axis=-1)
    picked = d_k[np.arange(n), np.asarray(a_k)]
    best = d_k.min(axis=1)
    np.testing.assert_allclose(picked, best, rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_separated_clusters_exact_assignment(seed):
    # Far-apart centroids: no ties, assignments must match exactly.
    rng = np.random.default_rng(seed)
    k, d, n = 4, 8, 256
    centers = rng.uniform(-100, 100, size=(k, d)).astype(np.float32)
    labels = rng.integers(0, k, size=n)
    pts = centers[labels] + rng.normal(0, 0.01, size=(n, d)).astype(np.float32)
    a_k, _ = kernels.assign(jnp.asarray(pts), jnp.asarray(centers))
    np.testing.assert_array_equal(np.asarray(a_k), labels)


def test_rejects_bad_shapes():
    with pytest.raises(ValueError, match="multiple"):
        kernels.assign(rand((100, 4), 0), rand((2, 4), 1))
    with pytest.raises(ValueError, match="dim mismatch"):
        kernels.assign(rand((128, 4), 0), rand((2, 5), 1))


def test_padding_sentinel_centroids_never_win():
    # The rust runtime pads K with centroids at 1e15.
    pts = rand((128, 4), 7)
    cents = jnp.concatenate([rand((3, 4), 8), jnp.full((5, 4), 1e15, jnp.float32)])
    a, m = kernels.assign(pts, cents)
    assert int(jnp.max(a)) < 3
    assert bool(jnp.all(jnp.isfinite(m)))


def test_zero_distance_for_exact_centroid_points():
    cents = rand((4, 16), 11)
    pts = jnp.tile(cents, (32, 1))  # 128 rows, each exactly a centroid
    a, m = kernels.assign(pts, cents)
    np.testing.assert_allclose(np.asarray(m), 0.0, atol=1e-3)
    np.testing.assert_array_equal(np.asarray(a), np.tile(np.arange(4), 32))


def test_vmem_estimate_positive_and_monotone():
    small = kernels.vmem_bytes(128, 8, 8)
    big = kernels.vmem_bytes(128, 64, 64)
    assert 0 < small < big
    # The biggest AOT bucket must fit a 16 MiB VMEM budget comfortably.
    assert kernels.vmem_bytes(128, 64, 64) < 16 * 1024 * 1024


def test_dtype_is_preserved():
    a, m = kernels.assign(rand((128, 4), 3), rand((2, 4), 4))
    assert a.dtype == jnp.int32
    assert m.dtype == jnp.float32
