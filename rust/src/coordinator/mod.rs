//! Streaming coordinator: the Layer-3 orchestrator that keeps clusters
//! fresh while relational tuples stream in.
//!
//! The paper's engine is batch; a production deployment of Rk-means sits
//! behind an ingestion pipeline. This module provides that shape:
//!
//! * **Bounded ingestion** — producers `insert()` / `delete()` tuples
//!   through a `sync_channel`; when the coordinator falls behind,
//!   producers block (backpressure) instead of ballooning memory. Time
//!   spent blocked and per-job queue depth are recorded in [`Metrics`].
//! * **Planned re-clustering** — after `recluster_every` new tuples (or
//!   an explicit [`Coordinator::flush`]) the worker runs a job through
//!   the incremental planner ([`crate::incremental::IncrementalEngine`]):
//!   small batches **patch** the Step-3 grid in place and warm-start
//!   Step 4 from the previous centroids, falling back to a full
//!   `Õ(|D|)` pipeline **rebuild** when the planner's drift / batch-size
//!   triggers fire (or when `incremental` is disabled / the FEQ is
//!   cyclic, in which case every job is a rebuild, as before).
//! * **Multi-producer ingest** — [`Coordinator::start_multi`] swaps the
//!   single message stream for the sharded ingest tier
//!   ([`crate::ingest`]): P epoch-stamping [`IngestProducer`] handles
//!   feed S bounded shard queues, the worker pumps the [`IngestHub`]
//!   (barrier-free shard-local Step-3 patching) and publishes exactly
//!   one update per fully-drained epoch, tagged
//!   [`ClusteringUpdate::epoch`]; after a planner rebuild the hub is
//!   rebased onto the new Step-2 models.
//! * **Versioned results** — each completed job is published on a results
//!   channel as a [`ClusteringUpdate`] tagged with its [`UpdateMode`];
//!   consumers read the latest. On shutdown the worker first **drains**
//!   all queued messages, then — if any deltas arrived since the last
//!   job — runs one final job so the last published update covers every
//!   ingested tuple (this also happens on drop).
//! * **Metrics** — counters for ingested/deleted/dropped tuples, job
//!   counts and durations, backpressure waits, queue depths, and the
//!   planner's `incremental.*` family, via [`crate::metrics::Metrics`].
//! * **Shared execution pool** — every job's Step 4 dispatches onto the
//!   process-wide persistent worker pool
//!   ([`crate::util::exec::shared_pool`], via the [`RkConfig`] executor
//!   default) instead of spawning scoped threads per Lloyd iteration;
//!   concurrent foreground work serializes on the same pool, so the
//!   coordinator never oversubscribes the machine.
//!
//! ## Replica serving from a shipped model
//!
//! Every [`ClusteringUpdate`] converts to a self-contained
//! [`RkModel`] via [`ClusteringUpdate::model`]: the writer serializes it
//! with [`RkModel::to_bytes`], ships the bytes, and replicas serve that
//! version — assigning never-materialized tuples with
//! [`RkModel::assign`] — while the coordinator keeps patching. For the
//! in-process replica tier, pair the update stream with the serving
//! mesh instead: feed each version to a
//! [`Publisher`](crate::serve::Publisher), which ships only the
//! **centroid delta** against what the
//! [`ModelMesh`](crate::serve::ModelMesh) replicas currently serve and
//! hot-swaps every slot atomically (see [`crate::serve`]):
//!
//! ```no_run
//! use rkmeans::coordinator::{Coordinator, CoordinatorConfig};
//! use rkmeans::rkmeans::{RkConfig, RkModel};
//! use rkmeans::synthetic::{retailer, Scale};
//! use std::time::Duration;
//!
//! let db = retailer::generate(Scale::tiny(), 1);
//! let coord =
//!     Coordinator::start(db, retailer::feq(), CoordinatorConfig::new(RkConfig::new(4)));
//! coord.flush().unwrap();
//! let update = coord.recv_update(Duration::from_secs(60)).unwrap();
//!
//! // Writer side: serialize this version's model and ship the bytes.
//! let bytes = update.model().to_bytes();
//!
//! // Replica side (typically another process): restore and serve without
//! // a database — `assign` takes feature values in FEQ feature order.
//! let replica = RkModel::from_bytes(&bytes).unwrap();
//! assert_eq!(replica.version, update.version);
//! ```

use crate::data::{Database, Value};
use crate::incremental::{
    apply_to_db, assigner_map, IncrementalEngine, PlanDecision, PlannerOpts, TupleDelta,
};
use crate::ingest::{IngestConfig, IngestHub, IngestProducer};
use crate::metrics::{Counter, Metrics};
use crate::query::{Feq, Hypergraph};
use crate::rkmeans::{RkConfig, RkModel, RkPipeline, RkResult};
use anyhow::{anyhow, Result};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Re-cluster after this many ingested tuples.
    pub recluster_every: usize,
    /// Bounded queue depth; producers block beyond this (backpressure).
    pub channel_capacity: usize,
    /// Clustering configuration for each job.
    pub rk: RkConfig,
    /// Route jobs through the incremental planner (patch vs. rebuild).
    /// When false — or when the planner cannot handle the FEQ — every job
    /// is a full pipeline rebuild.
    pub incremental: bool,
    /// Planner thresholds (used when `incremental` is on).
    pub planner: PlannerOpts,
    /// Independent epoch-stamping producers for the multi-producer ingest
    /// tier ([`Coordinator::start_multi`]). [`Coordinator::start`]
    /// ignores this: its single `insert`/`delete` stream has exactly one
    /// logical producer.
    pub producers: usize,
    /// Ingest-queue shard count for [`Coordinator::start_multi`] (the
    /// hub runs one bounded queue + one delta state per shard; see
    /// [`crate::ingest`]). Independent of [`PlannerOpts::shards`], which
    /// shards the single-stream engine's own delta layer.
    pub shards: usize,
}

impl CoordinatorConfig {
    /// Sensible defaults for examples/tests.
    pub fn new(rk: RkConfig) -> Self {
        CoordinatorConfig {
            recluster_every: 10_000,
            channel_capacity: 1024,
            rk,
            incremental: true,
            planner: PlannerOpts::default(),
            producers: 1,
            shards: 1,
        }
    }
}

/// How a published clustering was produced.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdateMode {
    /// Full pipeline run.
    Rebuilt,
    /// Step-3 delta patch + Step-4 warm start.
    Patched,
}

/// A published clustering result.
#[derive(Clone, Debug)]
pub struct ClusteringUpdate {
    /// Monotonically increasing job id.
    pub version: u64,
    /// Total tuples ingested when the job started.
    pub ingested: u64,
    /// The clustering itself (shared: updates are cloned onto the
    /// results channel and retained by the worker for the shutdown
    /// drain, so the payload is reference-counted rather than deep-copied
    /// on the per-job path).
    pub result: Arc<RkResult>,
    /// Wall-clock of this job.
    pub elapsed: Duration,
    /// Patch or rebuild (always [`UpdateMode::Rebuilt`] with the planner
    /// disabled).
    pub mode: UpdateMode,
    /// The ingest epoch this update covers — multi-producer mode only
    /// ([`Coordinator::start_multi`]), where every published version
    /// corresponds to exactly one fully-drained epoch (`Some(0)` is the
    /// initial build). `None` on the single-stream path.
    pub epoch: Option<u64>,
}

impl ClusteringUpdate {
    /// Wrap this update's payload as a self-contained serving
    /// [`RkModel`], tagged with the update's version — the
    /// replica-shipping path (serialize with [`RkModel::to_bytes`]; see
    /// the module docs example).
    pub fn model(&self) -> RkModel {
        RkModel::from_result(&self.result).with_version(self.version)
    }
}

enum Msg {
    Insert { relation: String, values: Vec<Value>, weight: f64 },
    Delete { relation: String, values: Vec<Value>, weight: f64 },
    Flush,
    Shutdown,
}

/// Multi-producer worker poll cadence: the ingest hub is pumped at least
/// this often even when no control message arrives.
const PUMP_INTERVAL: Duration = Duration::from_millis(5);

/// Handle to the coordinator worker.
pub struct Coordinator {
    tx: SyncSender<Msg>,
    results: Mutex<Receiver<ClusteringUpdate>>,
    worker: Option<JoinHandle<(Database, Option<ClusteringUpdate>)>>,
    metrics: Metrics,
    /// Producer-side counters, cached so the ingest hot path never takes
    /// the metrics-registry lock.
    enqueued: Arc<Counter>,
    bp_events: Arc<Counter>,
    bp_wait_us: Arc<Counter>,
}

/// Worker-side job state: the planner engine is built lazily on the first
/// job and dropped permanently if it cannot be built (cyclic FEQ, …).
struct JobState {
    engine: Option<IncrementalEngine>,
    engine_failed: bool,
    pending: Vec<TupleDelta>,
}

impl Coordinator {
    /// Start the worker thread owning `db`.
    pub fn start(db: Database, feq: Feq, cfg: CoordinatorConfig) -> Coordinator {
        let (tx, rx) = sync_channel::<Msg>(cfg.channel_capacity);
        let (res_tx, res_rx) = sync_channel::<ClusteringUpdate>(16);
        let metrics = Metrics::new();
        let m = metrics.clone();

        let worker = std::thread::spawn(move || {
            let mut db = db;
            let mut since_recluster = 0usize;
            let mut ingested = 0u64;
            let mut version = 0u64;
            let ingest_ctr = m.counter("coordinator.ingested");
            let delete_ctr = m.counter("coordinator.deleted");
            let err_ctr = m.counter("coordinator.insert_errors");
            let job_ctr = m.counter("coordinator.jobs");
            let depth = m.gauge("coordinator.since_recluster");
            let enqueued = m.counter("coordinator.enqueued");
            let dequeued = m.counter("coordinator.dequeued");
            let job_depth = m.gauge("coordinator.job_queue_depth");

            let mut js = JobState { engine: None, engine_failed: false, pending: Vec::new() };
            let mut last_published: Option<ClusteringUpdate> = None;

            let run_job = |db: &Database,
                               js: &mut JobState,
                               ingested: u64,
                               version: &mut u64,
                               last: &mut Option<ClusteringUpdate>| {
                // Per-job queue depth: what producers have enqueued that
                // the worker has not yet seen.
                job_depth.set(enqueued.get().saturating_sub(dequeued.get()) as i64);
                let t0 = crate::util::timer::now();
                // Build the planner engine on first use (its initial full
                // build doubles as this job's result).
                if cfg.incremental && js.engine.is_none() && !js.engine_failed {
                    match IncrementalEngine::new(
                        db,
                        feq.clone(),
                        cfg.rk.clone(),
                        cfg.planner.clone(),
                        m.clone(),
                    ) {
                        Ok(engine) => {
                            js.engine = Some(engine);
                            js.pending.clear(); // covered by the initial build
                            *version += 1;
                            job_ctr.inc();
                            let result = js.engine.as_ref().expect("just built").shared_result();
                            let update = ClusteringUpdate {
                                version: *version,
                                ingested,
                                result,
                                elapsed: t0.elapsed(),
                                mode: UpdateMode::Rebuilt,
                                epoch: None,
                            };
                            let _ = res_tx.try_send(update.clone());
                            *last = Some(update);
                            return;
                        }
                        Err(e) => {
                            // Structural failures (cyclic FEQ, invalid
                            // feature set) can never succeed — stop
                            // trying. Data-dependent ones (e.g. an empty
                            // join while the stream warms up) retry on
                            // the next job.
                            let structural = feq.validate(db).is_err()
                                || Hypergraph::from_feq(db, &feq).join_tree().is_err();
                            js.engine_failed = structural;
                            eprintln!(
                                "coordinator: incremental planner unavailable ({e}); \
                                 falling back to a full rebuild{}",
                                if structural { " permanently" } else { " for this job" }
                            );
                        }
                    }
                }
                if let Some(mut engine) = js.engine.take() {
                    let pending = std::mem::take(&mut js.pending);
                    match engine.apply_batch(db, &pending) {
                        Ok((decision, result)) => {
                            js.engine = Some(engine);
                            *version += 1;
                            job_ctr.inc();
                            let mode = match decision {
                                PlanDecision::Patched => UpdateMode::Patched,
                                PlanDecision::Rebuilt(_) => UpdateMode::Rebuilt,
                            };
                            // The channel drops updates when consumers
                            // are slow (never block ingestion); the worker
                            // keeps the latest one for the shutdown drain.
                            let update = ClusteringUpdate {
                                version: *version,
                                ingested,
                                result,
                                elapsed: t0.elapsed(),
                                mode,
                                epoch: None,
                            };
                            let _ = res_tx.try_send(update.clone());
                            *last = Some(update);
                            return;
                        }
                        Err(e) => {
                            // The engine's own patch-failure path already
                            // rebuilds internally, so an error here means
                            // the full pipeline failed too. Drop the
                            // (possibly poisoned) state; the next job
                            // re-initializes from the database.
                            eprintln!(
                                "coordinator: incremental job failed ({e}); \
                                 re-initializing on the next job"
                            );
                        }
                    }
                }
                // Plain full-pipeline path (staged; see
                // `crate::rkmeans::pipeline`).
                js.pending.clear();
                match RkPipeline::plan(db, &feq)
                    .and_then(|pipe| pipe.run(&cfg.rk))
                    .map(RkModel::into_result)
                {
                    Ok(result) => {
                        *version += 1;
                        job_ctr.inc();
                        let update = ClusteringUpdate {
                            version: *version,
                            ingested,
                            result: Arc::new(result),
                            elapsed: t0.elapsed(),
                            mode: UpdateMode::Rebuilt,
                            epoch: None,
                        };
                        let _ = res_tx.try_send(update.clone());
                        *last = Some(update);
                    }
                    Err(e) => eprintln!("coordinator: clustering failed: {e}"),
                }
            };

            while let Ok(msg) = rx.recv() {
                dequeued.inc();
                let mut force_job = false;
                match msg {
                    Msg::Insert { relation, values, weight } => {
                        match db.get_mut(&relation) {
                            Some(rel) if values.len() == rel.n_cols() => {
                                if weight == 1.0 {
                                    rel.push_row(&values);
                                } else {
                                    rel.push_row_weighted(&values, weight);
                                }
                                js.pending.push(TupleDelta { relation, values, weight });
                                ingested += 1;
                                since_recluster += 1;
                                ingest_ctr.inc();
                                depth.set(since_recluster as i64);
                            }
                            _ => err_ctr.inc(),
                        }
                    }
                    Msg::Delete { relation, values, weight } => {
                        let retracted = match db.get_mut(&relation) {
                            Some(rel) => {
                                let ok = rel.retract_row(&values, weight);
                                // Reclaim tombstones once they dominate the
                                // relation (bounds memory and the retract
                                // scan under delete-heavy load; the delta
                                // state never references row positions, so
                                // compaction is invisible to the planner).
                                if ok && rel.n_rows() > 256 && rel.zero_rows() * 2 > rel.n_rows()
                                {
                                    rel.compact();
                                }
                                ok
                            }
                            None => false,
                        };
                        if retracted {
                            js.pending.push(TupleDelta { relation, values, weight: -weight });
                            ingested += 1;
                            since_recluster += 1;
                            delete_ctr.inc();
                            depth.set(since_recluster as i64);
                        } else {
                            err_ctr.inc();
                        }
                    }
                    Msg::Flush => force_job = true,
                    Msg::Shutdown => {
                        // Everything enqueued before the shutdown message
                        // has already been drained (the channel is FIFO);
                        // publish one final update covering any deltas
                        // that never hit the recluster threshold.
                        if since_recluster > 0 || !js.pending.is_empty() {
                            since_recluster = 0;
                            depth.set(0);
                            run_job(&db, &mut js, ingested, &mut version, &mut last_published);
                        }
                        break;
                    }
                }
                if force_job || since_recluster >= cfg.recluster_every {
                    since_recluster = 0;
                    depth.set(0);
                    run_job(&db, &mut js, ingested, &mut version, &mut last_published);
                }
            }
            (db, last_published)
        });

        let enqueued = metrics.counter("coordinator.enqueued");
        let bp_events = metrics.counter("coordinator.backpressure_events");
        let bp_wait_us = metrics.counter("coordinator.backpressure_wait_us");
        Coordinator {
            tx,
            results: Mutex::new(res_rx),
            worker: Some(worker),
            metrics,
            enqueued,
            bp_events,
            bp_wait_us,
        }
    }

    /// Start the worker in multi-producer mode: data flows through the
    /// returned epoch-stamping [`IngestProducer`] handles (one per
    /// `cfg.producers`) into `cfg.shards` bounded shard queues
    /// ([`crate::ingest`]) — not through [`Coordinator::insert`] /
    /// [`Coordinator::delete`], which are counted as
    /// `coordinator.insert_errors` here. The worker pumps the
    /// [`IngestHub`] continuously: every epoch all producers have sealed
    /// and all shards have drained through is closed, mirrored onto the
    /// worker's database, planned through
    /// [`IncrementalEngine::apply_epoch`], and published as exactly one
    /// [`ClusteringUpdate`] tagged with its epoch
    /// ([`ClusteringUpdate::epoch`]). When the planner votes rebuild, the
    /// hub is rebased onto the rebuilt Step-2 models before the next
    /// pump (in-flight epochs are replayed inside the rebase).
    ///
    /// Fails when the FEQ is invalid or cyclic — unlike
    /// [`Coordinator::start`] there is no recompute-everything fallback,
    /// because the epoch protocol is only defined on the planner path.
    ///
    /// Shutdown closes only fully-sealed epochs: producers must seal
    /// their last epoch before the coordinator is shut down, or that
    /// epoch's deltas are discarded with the hub.
    pub fn start_multi(
        db: Database,
        feq: Feq,
        cfg: CoordinatorConfig,
    ) -> Result<(Coordinator, Vec<IngestProducer>)> {
        let metrics = Metrics::new();
        let t0 = crate::util::timer::now();
        let engine = IncrementalEngine::new(
            &db,
            feq.clone(),
            cfg.rk.clone(),
            cfg.planner.clone(),
            metrics.clone(),
        )?;
        let init_elapsed = t0.elapsed();
        let tree = Hypergraph::from_feq(&db, &feq).join_tree()?;
        let icfg = IngestConfig {
            producers: cfg.producers.max(1),
            shards: cfg.shards.max(1),
            queue_capacity: cfg.channel_capacity,
            spill_budget: cfg.planner.spill_budget,
        };
        let hub = IngestHub::new(
            &db,
            &feq,
            &tree,
            &icfg,
            || assigner_map(engine.models()),
            metrics.clone(),
        )?;
        let producers: Vec<IngestProducer> =
            (0..icfg.producers).map(|i| hub.producer(i)).collect();

        let (tx, rx) = sync_channel::<Msg>(cfg.channel_capacity);
        let (res_tx, res_rx) = sync_channel::<ClusteringUpdate>(16);
        let m = metrics.clone();

        let worker = std::thread::spawn(move || {
            let mut db = db;
            let mut hub = hub;
            let mut engine = engine;
            let mut ingested = 0u64;
            let mut last_published: Option<ClusteringUpdate>;
            let job_ctr = m.counter("coordinator.jobs");
            let err_ctr = m.counter("coordinator.insert_errors");

            // Publish the engine's initial full build so consumers hold a
            // model before the first epoch closes.
            job_ctr.inc();
            let update = ClusteringUpdate {
                version: engine.version(),
                ingested,
                result: engine.shared_result(),
                elapsed: init_elapsed,
                mode: UpdateMode::Rebuilt,
                epoch: Some(0),
            };
            let _ = res_tx.try_send(update.clone());
            last_published = Some(update);

            let run_epochs = |hub: &mut IngestHub,
                              engine: &mut IncrementalEngine,
                              db: &mut Database,
                              ingested: &mut u64,
                              last: &mut Option<ClusteringUpdate>| {
                let patches = {
                    // Borrow the current models through the shared result
                    // so the pump's pool jobs get a Sync assigner source.
                    let shared = engine.shared_result();
                    match hub.pump(|| assigner_map(&shared.models)) {
                        Ok(p) => p,
                        Err(e) => {
                            eprintln!("coordinator: ingest pump failed ({e})");
                            return;
                        }
                    }
                };
                for patch in patches {
                    if let Err(e) = apply_to_db(db, &patch.deltas) {
                        eprintln!(
                            "coordinator: epoch {} cannot mirror onto the database \
                             ({e}); dropping the epoch",
                            patch.epoch
                        );
                        continue;
                    }
                    let t0 = crate::util::timer::now();
                    match engine.apply_epoch(db, &patch) {
                        Ok((decision, result)) => {
                            let rebuilt = matches!(decision, PlanDecision::Rebuilt(_));
                            if rebuilt {
                                // New Step-2 models: re-anchor the hub's
                                // shard grids on the rebuilt boundary.
                                let shared = engine.shared_result();
                                if let Err(e) =
                                    hub.rebase(db, || assigner_map(&shared.models))
                                {
                                    eprintln!("coordinator: hub rebase failed ({e})");
                                }
                            }
                            *ingested += patch.stats.deltas as u64;
                            job_ctr.inc();
                            let update = ClusteringUpdate {
                                version: engine.version(),
                                ingested: *ingested,
                                result,
                                elapsed: t0.elapsed(),
                                mode: if rebuilt {
                                    UpdateMode::Rebuilt
                                } else {
                                    UpdateMode::Patched
                                },
                                epoch: Some(patch.epoch),
                            };
                            let _ = res_tx.try_send(update.clone());
                            *last = Some(update);
                        }
                        Err(e) => {
                            eprintln!("coordinator: epoch {} job failed ({e})", patch.epoch)
                        }
                    }
                }
            };

            loop {
                match rx.recv_timeout(PUMP_INTERVAL) {
                    // Data must arrive epoch-stamped through the producer
                    // handles; the unstamped single-stream API has no
                    // place in the epoch protocol.
                    Ok(Msg::Insert { .. }) | Ok(Msg::Delete { .. }) => err_ctr.inc(),
                    Ok(Msg::Flush) | Err(RecvTimeoutError::Timeout) => run_epochs(
                        &mut hub,
                        &mut engine,
                        &mut db,
                        &mut ingested,
                        &mut last_published,
                    ),
                    Ok(Msg::Shutdown) | Err(RecvTimeoutError::Disconnected) => {
                        // Everything producers enqueued before shutdown is
                        // already in the shard queues (their sends
                        // returned): one final pump closes every
                        // fully-sealed epoch.
                        run_epochs(
                            &mut hub,
                            &mut engine,
                            &mut db,
                            &mut ingested,
                            &mut last_published,
                        );
                        break;
                    }
                }
            }
            (db, last_published)
        });

        let enqueued = metrics.counter("coordinator.enqueued");
        let bp_events = metrics.counter("coordinator.backpressure_events");
        let bp_wait_us = metrics.counter("coordinator.backpressure_wait_us");
        Ok((
            Coordinator {
                tx,
                results: Mutex::new(res_rx),
                worker: Some(worker),
                metrics,
                enqueued,
                bp_events,
                bp_wait_us,
            },
            producers,
        ))
    }

    /// Send with backpressure accounting: a full queue blocks the
    /// producer and the wait is recorded in
    /// `coordinator.backpressure_wait_us` / `.backpressure_events`.
    fn send_msg(&self, msg: Msg) -> Result<()> {
        match self.tx.try_send(msg) {
            Ok(()) => {
                self.enqueued.inc();
                Ok(())
            }
            Err(TrySendError::Full(msg)) => {
                let t0 = crate::util::timer::now();
                self.tx.send(msg).map_err(|_| anyhow!("coordinator is shut down"))?;
                self.enqueued.inc();
                self.bp_events.inc();
                self.bp_wait_us.add(t0.elapsed().as_micros() as u64);
                Ok(())
            }
            Err(TrySendError::Disconnected(_)) => Err(anyhow!("coordinator is shut down")),
        }
    }

    /// Ingest one tuple; blocks when the queue is full (backpressure).
    pub fn insert(&self, relation: &str, values: Vec<Value>) -> Result<()> {
        self.send_msg(Msg::Insert { relation: relation.to_string(), values, weight: 1.0 })
    }

    /// Ingest one weighted tuple. The weight must be strictly positive —
    /// a retraction goes through [`Coordinator::delete`], not a negative
    /// insert (a zero/negative weight here would poison the incremental
    /// delta state).
    pub fn insert_weighted(&self, relation: &str, values: Vec<Value>, weight: f64) -> Result<()> {
        if !(weight > 0.0) {
            return Err(anyhow!("tuple weight must be positive, got {weight}"));
        }
        self.send_msg(Msg::Insert { relation: relation.to_string(), values, weight })
    }

    /// Retract one unit-weight tuple (ring-style delete; the tuple must
    /// exist with multiplicity ≥ 1). A retraction that finds no matching
    /// tuple is counted in `coordinator.insert_errors`, like a malformed
    /// insert. Tuples ingested with [`Coordinator::insert_weighted`] are
    /// retracted via [`Coordinator::delete_weighted`] with the matching
    /// weight.
    pub fn delete(&self, relation: &str, values: Vec<Value>) -> Result<()> {
        self.delete_weighted(relation, values, 1.0)
    }

    /// Retract `weight` of a tuple's multiplicity (must be positive and
    /// no larger than the tuple's remaining weight).
    pub fn delete_weighted(&self, relation: &str, values: Vec<Value>, weight: f64) -> Result<()> {
        if !(weight > 0.0) {
            return Err(anyhow!("retraction weight must be positive, got {weight}"));
        }
        self.send_msg(Msg::Delete { relation: relation.to_string(), values, weight })
    }

    /// Force a re-cluster of the current state.
    pub fn flush(&self) -> Result<()> {
        self.send_msg(Msg::Flush)
    }

    /// Wait for the next clustering update.
    pub fn recv_update(&self, timeout: Duration) -> Option<ClusteringUpdate> {
        match self.results.lock().expect("results lock").recv_timeout(timeout) {
            Ok(u) => Some(u),
            Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => None,
        }
    }

    /// Shared metrics registry.
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Stop the worker and return the final database state. All in-flight
    /// messages are drained first, and a final update is published when
    /// un-reclustered deltas remain (see [`Coordinator::shutdown_with_final`]
    /// to receive it).
    pub fn shutdown(self) -> Result<Database> {
        self.shutdown_with_final().map(|(db, _)| db)
    }

    /// [`Coordinator::shutdown`], also returning the latest published
    /// update — after the drain-on-shutdown job, that update covers every
    /// successfully ingested delta. The worker hands its last update back
    /// directly, so this holds even when slow consumers made the bounded
    /// results channel drop updates.
    pub fn shutdown_with_final(mut self) -> Result<(Database, Option<ClusteringUpdate>)> {
        let _ = self.tx.send(Msg::Shutdown);
        let worker = self.worker.take().expect("worker present until shutdown");
        let (db, last) =
            worker.join().map_err(|_| anyhow!("coordinator worker panicked"))?;
        Ok((db, last))
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Attr, Relation, Schema};

    fn setup() -> (Database, Feq) {
        let mut fact =
            Relation::new("fact", Schema::new(vec![Attr::cat("c", 4), Attr::double("x")]));
        for i in 0..20u32 {
            fact.push_row(&[Value::Cat(i % 4), Value::Double(i as f64)]);
        }
        let mut db = Database::new();
        db.add(fact);
        (db, Feq::with_features(&["fact"], &["c", "x"]))
    }

    #[test]
    fn ingest_then_flush_publishes_update() {
        let (db, feq) = setup();
        let cfg = CoordinatorConfig::new(RkConfig::new(2));
        let coord = Coordinator::start(db, feq, cfg);
        for i in 0..50u32 {
            coord.insert("fact", vec![Value::Cat(i % 4), Value::Double(i as f64 + 100.0)]).unwrap();
        }
        coord.flush().unwrap();
        let update = coord.recv_update(Duration::from_secs(10)).expect("update");
        assert_eq!(update.version, 1);
        assert_eq!(update.ingested, 50);
        assert!(update.result.grid_points > 0);
        let db = coord.shutdown().unwrap();
        assert_eq!(db.get("fact").unwrap().n_rows(), 70);
    }

    #[test]
    fn delta_threshold_triggers_job() {
        let (db, feq) = setup();
        let mut cfg = CoordinatorConfig::new(RkConfig::new(2));
        cfg.recluster_every = 10;
        let coord = Coordinator::start(db, feq, cfg);
        for i in 0..10u32 {
            coord.insert("fact", vec![Value::Cat(i % 4), Value::Double(i as f64)]).unwrap();
        }
        let update = coord.recv_update(Duration::from_secs(10)).expect("auto update");
        assert_eq!(update.ingested, 10);
        coord.shutdown().unwrap();
    }

    #[test]
    fn second_job_is_patched() {
        let (db, feq) = setup();
        let mut cfg = CoordinatorConfig::new(RkConfig::new(2));
        cfg.recluster_every = 10;
        // Lenient planner so the small batches always patch.
        cfg.planner = PlannerOpts {
            drift_threshold: 1.1,
            max_patch_fraction: 1.0,
            rebuild_every: 0,
            max_join_churn: f64::INFINITY,
            ..PlannerOpts::default()
        };
        let coord = Coordinator::start(db, feq, cfg);
        for i in 0..20u32 {
            coord.insert("fact", vec![Value::Cat(i % 4), Value::Double(i as f64)]).unwrap();
        }
        let first = coord.recv_update(Duration::from_secs(30)).expect("first update");
        assert_eq!(first.mode, UpdateMode::Rebuilt); // initial build
        let second = coord.recv_update(Duration::from_secs(30)).expect("second update");
        assert_eq!(second.mode, UpdateMode::Patched);
        assert_eq!(second.ingested, 20);
        assert!(second.result.grid_points > 0);
        let m = coord.metrics().clone();
        coord.shutdown().unwrap();
        assert!(m.counter("incremental.patches").get() >= 1);
    }

    #[test]
    fn deletes_flow_through_jobs() {
        let (db, feq) = setup();
        let mut cfg = CoordinatorConfig::new(RkConfig::new(2));
        cfg.planner = PlannerOpts {
            drift_threshold: 1.1,
            max_patch_fraction: 1.0,
            rebuild_every: 0,
            max_join_churn: f64::INFINITY,
            ..PlannerOpts::default()
        };
        let coord = Coordinator::start(db, feq, cfg);
        coord.flush().unwrap(); // initial build over the 20 base tuples
        let first = coord.recv_update(Duration::from_secs(30)).expect("first");
        let mass0 = first.result.grid_mass;
        coord.delete("fact", vec![Value::Cat(0), Value::Double(0.0)]).unwrap();
        coord.delete("fact", vec![Value::Cat(1), Value::Double(1.0)]).unwrap();
        // Deleting a tuple that is not there is an error, not a crash.
        coord.delete("fact", vec![Value::Cat(3), Value::Double(999.0)]).unwrap();
        coord.flush().unwrap();
        let second = coord.recv_update(Duration::from_secs(30)).expect("second");
        assert!((second.result.grid_mass - (mass0 - 2.0)).abs() < 1e-9);
        assert_eq!(coord.metrics().counter("coordinator.insert_errors").get(), 1);
        assert_eq!(coord.metrics().counter("coordinator.deleted").get(), 2);
        coord.shutdown().unwrap();
    }

    #[test]
    fn updates_ship_as_serving_models() {
        let (db, feq) = setup();
        let coord = Coordinator::start(db, feq, CoordinatorConfig::new(RkConfig::new(2)));
        coord.flush().unwrap();
        let update = coord.recv_update(Duration::from_secs(30)).expect("update");
        // Ship the model bytes; a replica restores and serves a tuple in
        // FEQ feature order (c, x) without touching any database.
        let bytes = update.model().to_bytes();
        let replica = RkModel::from_bytes(&bytes).unwrap();
        assert_eq!(replica.version, update.version);
        assert_eq!(replica.m(), 2);
        let vals = vec![Value::Cat(1), Value::Double(3.0)];
        assert!(replica.assign(&vals) < replica.k());
        assert_eq!(replica.assign(&vals), update.model().assign(&vals));
        coord.shutdown().unwrap();
    }

    #[test]
    fn bad_inserts_are_counted_not_fatal() {
        let (db, feq) = setup();
        let coord = Coordinator::start(db, feq, CoordinatorConfig::new(RkConfig::new(2)));
        coord.insert("missing_relation", vec![Value::Cat(0)]).unwrap();
        coord.insert("fact", vec![Value::Cat(0)]).unwrap(); // arity mismatch
        coord.flush().unwrap();
        let _ = coord.recv_update(Duration::from_secs(10));
        assert_eq!(coord.metrics().counter("coordinator.insert_errors").get(), 2);
        coord.shutdown().unwrap();
    }

    #[test]
    fn shutdown_drains_inflight_deltas() {
        let (db, feq) = setup();
        let mut cfg = CoordinatorConfig::new(RkConfig::new(2));
        cfg.recluster_every = 1_000; // never auto-trigger
        let coord = Coordinator::start(db, feq, cfg);
        for i in 0..30u32 {
            coord.insert("fact", vec![Value::Cat(i % 4), Value::Double(i as f64)]).unwrap();
        }
        // No flush: all 30 tuples are in flight when shutdown arrives.
        let (db, last) = coord.shutdown_with_final().unwrap();
        assert_eq!(db.get("fact").unwrap().n_rows(), 50);
        let last = last.expect("drain-on-shutdown update");
        assert_eq!(last.ingested, 30);
        assert!(last.result.grid_points > 0);
    }

    #[test]
    fn final_update_survives_dropped_channel_updates() {
        // More jobs than the results channel holds, no consumer: the
        // bounded channel drops updates, but the worker's own copy of the
        // latest one must still come back from shutdown_with_final.
        let (db, feq) = setup();
        let mut cfg = CoordinatorConfig::new(RkConfig::new(2));
        cfg.recluster_every = 1; // one job per insert → 20 jobs > capacity 16
        let coord = Coordinator::start(db, feq, cfg);
        for i in 0..20u32 {
            coord.insert("fact", vec![Value::Cat(i % 4), Value::Double(i as f64)]).unwrap();
        }
        let (_, last) = coord.shutdown_with_final().unwrap();
        let last = last.expect("latest update");
        assert_eq!(last.ingested, 20);
        assert_eq!(last.version, 20);
    }

    #[test]
    fn weighted_insert_rejects_non_positive_weights() {
        let (db, feq) = setup();
        let coord = Coordinator::start(db, feq, CoordinatorConfig::new(RkConfig::new(2)));
        assert!(coord
            .insert_weighted("fact", vec![Value::Cat(0), Value::Double(1.0)], 0.0)
            .is_err());
        assert!(coord
            .insert_weighted("fact", vec![Value::Cat(0), Value::Double(1.0)], -2.0)
            .is_err());
        assert!(coord
            .insert_weighted("fact", vec![Value::Cat(0), Value::Double(1.0)], 2.0)
            .is_ok());
        coord.shutdown().unwrap();
    }

    #[test]
    fn weighted_delete_round_trips() {
        let (db, feq) = setup();
        let mut cfg = CoordinatorConfig::new(RkConfig::new(2));
        cfg.planner = PlannerOpts {
            drift_threshold: 1.1,
            max_patch_fraction: 1.0,
            rebuild_every: 0,
            max_join_churn: f64::INFINITY,
            ..PlannerOpts::default()
        };
        let coord = Coordinator::start(db, feq, cfg);
        coord.flush().unwrap();
        let first = coord.recv_update(Duration::from_secs(30)).expect("first");
        let mass0 = first.result.grid_mass;
        // A weight-3 tuple retracts only via the matching weighted delete.
        coord.insert_weighted("fact", vec![Value::Cat(2), Value::Double(7.0)], 3.0).unwrap();
        coord.delete_weighted("fact", vec![Value::Cat(2), Value::Double(7.0)], 3.0).unwrap();
        coord.flush().unwrap();
        let second = coord.recv_update(Duration::from_secs(30)).expect("second");
        assert!((second.result.grid_mass - mass0).abs() < 1e-9);
        assert!(coord.delete_weighted("fact", vec![Value::Cat(2)], 0.0).is_err());
        coord.shutdown().unwrap();
    }

    #[test]
    fn drop_drains_inflight_deltas_too() {
        let (db, feq) = setup();
        let mut cfg = CoordinatorConfig::new(RkConfig::new(2));
        cfg.recluster_every = 1_000;
        let coord = Coordinator::start(db, feq, cfg);
        let m = coord.metrics().clone();
        for i in 0..5u32 {
            coord.insert("fact", vec![Value::Cat(i % 4), Value::Double(i as f64)]).unwrap();
        }
        drop(coord); // must process the 5 inserts and run one final job
        assert_eq!(m.counter("coordinator.ingested").get(), 5);
        assert_eq!(m.counter("coordinator.jobs").get(), 1);
    }

    #[test]
    fn shutdown_is_idempotent_under_drop() {
        let (db, feq) = setup();
        let coord = Coordinator::start(db, feq, CoordinatorConfig::new(RkConfig::new(2)));
        drop(coord); // must not hang or panic
    }

    fn lenient_planner() -> PlannerOpts {
        PlannerOpts {
            drift_threshold: 1.1,
            max_patch_fraction: 1.0,
            rebuild_every: 0,
            max_join_churn: f64::INFINITY,
            ..PlannerOpts::default()
        }
    }

    #[test]
    fn multi_producer_epochs_publish_versions() {
        let (db, feq) = setup();
        let mut cfg = CoordinatorConfig::new(RkConfig::new(2));
        cfg.producers = 2;
        cfg.shards = 2;
        cfg.planner = lenient_planner();
        let (coord, producers) = Coordinator::start_multi(db, feq, cfg).unwrap();
        let first = coord.recv_update(Duration::from_secs(30)).expect("initial build");
        assert_eq!(first.version, 1);
        assert_eq!(first.mode, UpdateMode::Rebuilt);
        assert_eq!(first.epoch, Some(0));

        // Epoch 1: both producers contribute, then seal.
        for i in 0..6u32 {
            let d = TupleDelta::insert(
                "fact",
                vec![Value::Cat(i % 4), Value::Double(i as f64 + 50.0)],
            );
            producers[(i % 2) as usize].send(1, d).unwrap();
        }
        producers[0].seal(1).unwrap();
        producers[1].seal(1).unwrap();
        let second = coord.recv_update(Duration::from_secs(30)).expect("epoch 1");
        assert_eq!(second.version, 2);
        assert_eq!(second.epoch, Some(1));
        assert_eq!(second.ingested, 6);
        assert_eq!(second.mode, UpdateMode::Patched);
        assert!(second.result.grid_points > 0);

        // An epoch sealed right before shutdown still publishes: the
        // final pump drains it.
        producers[0]
            .send(2, TupleDelta::delete("fact", vec![Value::Cat(0), Value::Double(0.0)]))
            .unwrap();
        producers[0].seal(2).unwrap();
        producers[1].seal(2).unwrap();
        let (db, last) = coord.shutdown_with_final().unwrap();
        let last = last.expect("final update");
        assert_eq!(last.epoch, Some(2));
        assert_eq!(last.ingested, 7);
        // 20 base rows + 6 inserts; the delete retracts in place.
        assert_eq!(db.get("fact").unwrap().n_rows(), 26);
    }

    #[test]
    fn multi_mode_rejects_direct_ingestion() {
        let (db, feq) = setup();
        let mut cfg = CoordinatorConfig::new(RkConfig::new(2));
        cfg.planner = lenient_planner();
        let (coord, _producers) = Coordinator::start_multi(db, feq, cfg).unwrap();
        let m = coord.metrics().clone();
        coord.insert("fact", vec![Value::Cat(0), Value::Double(1.0)]).unwrap();
        coord.delete("fact", vec![Value::Cat(0), Value::Double(0.0)]).unwrap();
        coord.shutdown().unwrap();
        assert_eq!(m.counter("coordinator.insert_errors").get(), 2);
    }

    #[test]
    fn multi_mode_rebuild_rebases_hub_and_keeps_publishing() {
        let (db, feq) = setup();
        let mut cfg = CoordinatorConfig::new(RkConfig::new(2));
        cfg.producers = 1;
        cfg.shards = 2;
        cfg.planner = PlannerOpts { rebuild_every: 1, ..lenient_planner() };
        let (coord, producers) = Coordinator::start_multi(db, feq, cfg).unwrap();
        let p = &producers[0];
        let _ = coord.recv_update(Duration::from_secs(30)).expect("initial build");

        let mut modes = Vec::new();
        for epoch in 1..=3u64 {
            for i in 0..4u32 {
                p.send(
                    epoch,
                    TupleDelta::insert(
                        "fact",
                        vec![Value::Cat(i % 4), Value::Double((epoch * 10 + i as u64) as f64)],
                    ),
                )
                .unwrap();
            }
            p.seal(epoch).unwrap();
            let u = coord.recv_update(Duration::from_secs(30)).expect("epoch update");
            assert_eq!(u.epoch, Some(epoch));
            assert_eq!(u.version, 1 + epoch);
            assert!(u.result.grid_points > 0);
            modes.push(u.mode);
        }
        // rebuild_every = 1: patch, scheduled rebuild (hub rebased), then
        // the next epoch must patch again over the rebased hub.
        assert_eq!(
            modes,
            vec![UpdateMode::Patched, UpdateMode::Rebuilt, UpdateMode::Patched]
        );
        let m = coord.metrics().clone();
        coord.shutdown().unwrap();
        assert_eq!(m.counter("ingest.epochs_closed").get(), 3);
    }

    #[test]
    fn queue_metrics_are_recorded() {
        let (db, feq) = setup();
        let mut cfg = CoordinatorConfig::new(RkConfig::new(2));
        cfg.channel_capacity = 2; // tiny queue: force backpressure
        cfg.recluster_every = 4;
        let coord = Coordinator::start(db, feq, cfg);
        for i in 0..40u32 {
            coord.insert("fact", vec![Value::Cat(i % 4), Value::Double(i as f64)]).unwrap();
        }
        let m = coord.metrics().clone();
        coord.shutdown().unwrap();
        assert_eq!(m.counter("coordinator.enqueued").get(), 40);
        assert_eq!(m.counter("coordinator.dequeued").get(), 41); // + shutdown
        // With a 2-slot queue and recluster jobs on the worker thread, at
        // least one producer send must have blocked.
        assert!(m.counter("coordinator.backpressure_events").get() > 0);
    }
}
