//! Property tests for the incremental Step-3 state: after ANY random
//! sequence of tuple inserts/deletes over a small acyclic schema, the
//! delta-maintained grid weights must be **bitwise equal** to a
//! from-scratch `grid_weights` pass over the updated database — for both
//! the bit-packed `u128` and the generic `Vec<u32>` combo-key paths.
//!
//! Bitwise equality is meaningful here because the Step-3 FAQ is a
//! counting query in the ring ℤ: with unit tuple weights every message
//! entry is an exactly-represented f64 integer, so insert/delete
//! cancellation is exact regardless of evaluation order (see the
//! `incremental::deltafaq` module docs).

use rkmeans::data::{Attr, Database, Relation, Schema, Value};
use rkmeans::faq::{grid_weights, GidAssigner, GridTable};
use rkmeans::incremental::{apply_to_db, DeltaFaq, TupleDelta};
use rkmeans::query::{Feq, Hypergraph};
use rkmeans::synthetic::{retailer, retailer_trace, Scale, TraceSpec};
use rkmeans::util::testkit::for_cases;
use rkmeans::util::{FxHashMap, SplitMix64};

/// Gid assigner: key (or value·4 for doubles) mod n. `claimed` inflates
/// the advertised κ to force the >128-bit generic combo path.
struct ModAssigner {
    n: u32,
    claimed: usize,
}
impl GidAssigner for ModAssigner {
    fn gid(&self, v: Value) -> u32 {
        let k = match v {
            Value::Double(x) => ((x * 4.0) as i64).rem_euclid(self.n as i64) as u64,
            other => other.key_u64(),
        };
        (k % self.n as u64) as u32
    }
    fn n_gids(&self) -> usize {
        self.claimed
    }
}

const FEATURES: [&str; 6] = ["pay", "c0", "x0", "c1", "c2", "x2"];

fn assigners(n: u32, claimed: usize) -> FxHashMap<String, Box<dyn GidAssigner>> {
    let mut m: FxHashMap<String, Box<dyn GidAssigner>> = FxHashMap::default();
    for a in FEATURES {
        m.insert(a.to_string(), Box::new(ModAssigner { n, claimed }));
    }
    m
}

/// The shadow database: per relation, a list of unit-weight tuples. The
/// oracle rebuilds a `Database` from it after every batch.
struct Shadow {
    schemas: Vec<(String, Schema)>,
    rows: Vec<Vec<Vec<Value>>>,
}

impl Shadow {
    fn to_db(&self) -> Database {
        let mut db = Database::new();
        for ((name, schema), rows) in self.schemas.iter().zip(&self.rows) {
            let mut rel = Relation::new(name, schema.clone());
            for r in rows {
                rel.push_row(r);
            }
            db.add(rel);
        }
        db
    }
}

/// Chain + star schema exercising multi-hop propagation and multi-child
/// telescoping: fact(j0, j1, pay) ⋈ dim0(j0, c0, x0) ⋈ dim1(j1, j2, c1)
/// ⋈ deep(j2, c2, x2).
fn random_instance(rng: &mut SplitMix64) -> (Shadow, Feq) {
    let dom = 3 + rng.below(4) as u32; // join-key domain
    let schemas = vec![
        (
            "fact".to_string(),
            Schema::new(vec![Attr::cat("j0", dom), Attr::cat("j1", dom), Attr::cat("pay", 6)]),
        ),
        (
            "dim0".to_string(),
            Schema::new(vec![Attr::cat("j0", dom), Attr::cat("c0", 5), Attr::double("x0")]),
        ),
        (
            "dim1".to_string(),
            Schema::new(vec![Attr::cat("j1", dom), Attr::cat("j2", dom), Attr::cat("c1", 5)]),
        ),
        (
            "deep".to_string(),
            Schema::new(vec![Attr::cat("j2", dom), Attr::cat("c2", 4), Attr::double("x2")]),
        ),
    ];
    let fresh = |rel: usize, rng: &mut SplitMix64| fresh_row(rel, dom, rng);
    let mut rows: Vec<Vec<Vec<Value>>> = vec![Vec::new(); 4];
    for (rel, row_list) in rows.iter_mut().enumerate() {
        // Sparse-ish initial fill; some join keys intentionally missing.
        let n = 3 + rng.below(15) as usize;
        for _ in 0..n {
            row_list.push(fresh(rel, rng));
        }
    }
    let feq = Feq::with_features(&["fact", "dim0", "dim1", "deep"], &FEATURES);
    (Shadow { schemas, rows }, feq)
}

fn fresh_row(rel: usize, dom: u32, rng: &mut SplitMix64) -> Vec<Value> {
    let key = |rng: &mut SplitMix64| Value::Cat(rng.below(dom as u64) as u32);
    let frac = |rng: &mut SplitMix64| Value::Double(rng.below(8) as f64 * 0.25);
    match rel {
        0 => vec![key(rng), key(rng), Value::Cat(rng.below(6) as u32)],
        1 => vec![key(rng), Value::Cat(rng.below(5) as u32), frac(rng)],
        2 => vec![key(rng), key(rng), Value::Cat(rng.below(5) as u32)],
        3 => vec![key(rng), Value::Cat(rng.below(4) as u32), frac(rng)],
        _ => unreachable!(),
    }
}

/// Random batch of inserts/deletes, applied to the shadow as generated so
/// deletes always reference live tuples.
fn random_batch(shadow: &mut Shadow, dom: u32, rng: &mut SplitMix64) -> Vec<TupleDelta> {
    let n = rng.below(12) as usize; // occasionally empty
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let rel = rng.below(4) as usize;
        let delete = rng.coin(0.4) && !shadow.rows[rel].is_empty();
        if delete {
            let i = rng.below(shadow.rows[rel].len() as u64) as usize;
            let vals = shadow.rows[rel].swap_remove(i);
            out.push(TupleDelta::delete(&shadow.schemas[rel].0, vals));
        } else {
            let vals = fresh_row(rel, dom, rng);
            shadow.rows[rel].push(vals.clone());
            out.push(TupleDelta::insert(&shadow.schemas[rel].0, vals));
        }
    }
    out
}

fn cells_bits(gt: &GridTable) -> FxHashMap<Vec<u32>, u64> {
    gt.cells.iter().map(|(g, w)| (g.clone(), w.to_bits())).collect()
}

fn check_random_sequences(claimed_gids: Option<usize>, expect_packed: bool) {
    for_cases(20, |rng| {
        let (mut shadow, feq) = random_instance(rng);
        let dom = shadow.schemas[0].1.attr(0).domain;
        let kappa = 2 + rng.below(3) as u32;
        let claimed = claimed_gids.unwrap_or(kappa as usize);
        let asg = assigners(kappa, claimed);

        let db0 = shadow.to_db();
        let tree = Hypergraph::from_feq(&db0, &feq).join_tree().expect("acyclic");
        let mut delta = DeltaFaq::init(&db0, &feq, &tree, &asg).expect("init");
        assert_eq!(delta.is_packed(), expect_packed);

        for round in 0..6 {
            let batch = random_batch(&mut shadow, dom, rng);
            delta.apply(&batch, &asg).expect("apply");

            // Oracle: rebuild the database and run the batch evaluator.
            let db = shadow.to_db();
            let tree = Hypergraph::from_feq(&db, &feq).join_tree().expect("acyclic");
            let scratch = grid_weights(&db, &feq, &tree, &asg).expect("scratch");
            let inc = delta.grid_table();
            assert_eq!(inc.feature_names, scratch.feature_names, "round {round}");
            assert_eq!(
                cells_bits(&inc),
                cells_bits(&scratch),
                "round {round}: delta-maintained grid diverged from scratch"
            );
        }
    });
}

#[test]
fn delta_grid_bitwise_equals_scratch_packed_u128() {
    check_random_sequences(None, true);
}

#[test]
fn delta_grid_bitwise_equals_scratch_generic_vec() {
    // Claim 2^60 gids per feature: 6×60 bits > 128 forces the Vec<u32>
    // path in both the delta engine and the from-scratch evaluator,
    // while actual gids stay identical.
    check_random_sequences(Some(1usize << 60), false);
}

/// The shared Retailer trace generator replays through the delta engine
/// and stays bitwise-consistent with from-scratch evaluation (ties the
/// property suite to the exact trace shape the stream bench measures).
#[test]
fn retailer_trace_patches_bitwise() {
    let mut db = retailer::generate(Scale::tiny(), 11);
    let feq = retailer::feq();
    let tree = Hypergraph::from_feq(&db, &feq).join_tree().expect("acyclic");
    // Fixed mod-assigners (Step-2 models are out of scope here: the
    // property under test is the FAQ delta, not the solvers).
    let mut asg: FxHashMap<String, Box<dyn GidAssigner>> = FxHashMap::default();
    for f in &feq.features {
        asg.insert(f.attr.clone(), Box::new(ModAssigner { n: 3, claimed: 3 }));
    }
    let mut delta = DeltaFaq::init(&db, &feq, &tree, &asg).expect("init");
    let trace =
        retailer_trace(&db, 23, TraceSpec { batches: 3, batch_size: 32, delete_frac: 0.35 });
    for (round, batch) in trace.iter().enumerate() {
        apply_to_db(&mut db, batch).expect("replay");
        delta.apply(batch, &asg).expect("apply");
        let scratch = grid_weights(&db, &feq, &tree, &asg).expect("scratch");
        assert_eq!(cells_bits(&delta.grid_table()), cells_bits(&scratch), "batch {round}");
    }
}
