//! Attribute types and relation schemas.

/// The type of an attribute.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AttrType {
    /// Integer (join key or discrete feature).
    Int,
    /// Continuous feature.
    Double,
    /// Dictionary-encoded categorical feature; one-hot encoded in the data
    /// matrix (the paper's "categorical subspace", §4.1).
    Cat,
}

/// A named, typed attribute. `domain` is the declared domain size for
/// categorical attributes (one-hot width); 0 means "infer from data".
#[derive(Clone, Debug)]
pub struct Attr {
    pub name: String,
    pub ty: AttrType,
    pub domain: u32,
}

impl Attr {
    /// Integer attribute.
    pub fn int(name: &str) -> Self {
        Attr { name: name.to_string(), ty: AttrType::Int, domain: 0 }
    }

    /// Continuous attribute.
    pub fn double(name: &str) -> Self {
        Attr { name: name.to_string(), ty: AttrType::Double, domain: 0 }
    }

    /// Categorical attribute with a declared domain size.
    pub fn cat(name: &str, domain: u32) -> Self {
        Attr { name: name.to_string(), ty: AttrType::Cat, domain }
    }
}

/// An ordered list of attributes with O(1) lookup by name.
#[derive(Clone, Debug, Default)]
pub struct Schema {
    attrs: Vec<Attr>,
}

impl Schema {
    /// Build from a list of attributes. Names must be unique.
    pub fn new(attrs: Vec<Attr>) -> Self {
        for i in 0..attrs.len() {
            for j in (i + 1)..attrs.len() {
                assert_ne!(attrs[i].name, attrs[j].name, "duplicate attribute name");
            }
        }
        Schema { attrs }
    }

    /// Number of attributes.
    pub fn len(&self) -> usize {
        self.attrs.len()
    }

    /// True if the schema has no attributes.
    pub fn is_empty(&self) -> bool {
        self.attrs.is_empty()
    }

    /// All attributes, in column order.
    pub fn attrs(&self) -> &[Attr] {
        &self.attrs
    }

    /// Attribute at a column index.
    pub fn attr(&self, idx: usize) -> &Attr {
        &self.attrs[idx]
    }

    /// Column index of a named attribute.
    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.attrs.iter().position(|a| a.name == name)
    }

    /// True if the schema contains the attribute.
    pub fn contains(&self, name: &str) -> bool {
        self.index_of(name).is_some()
    }

    /// Names of all attributes.
    pub fn names(&self) -> Vec<&str> {
        self.attrs.iter().map(|a| a.name.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_by_name() {
        let s = Schema::new(vec![Attr::int("a"), Attr::double("b"), Attr::cat("c", 10)]);
        assert_eq!(s.len(), 3);
        assert_eq!(s.index_of("b"), Some(1));
        assert_eq!(s.index_of("z"), None);
        assert!(s.contains("c"));
        assert_eq!(s.attr(2).domain, 10);
        assert_eq!(s.names(), vec!["a", "b", "c"]);
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn duplicate_names_rejected() {
        Schema::new(vec![Attr::int("a"), Attr::double("a")]);
    }
}
