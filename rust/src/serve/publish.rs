//! The writer-side publisher: versions go out as centroid deltas, land
//! as atomic hot-swaps.
//!
//! A [`Publisher`] sits between a model writer (the incremental
//! engine's [`model()`](crate::incremental::IncrementalEngine::model)
//! per batch, or any source of versioned [`RkModel`]s) and a
//! [`ModelMesh`]. Each [`Publisher::publish`] exercises the full wire
//! path a multi-process deployment would take: diff against the
//! replicas' current version, serialize the [`ModelDelta`], decode it
//! back, splice it onto the replica-side base, and verify the result
//! serializes **bit-identically** to the writer's snapshot before
//! installing it — a corrupt or stale delta can never reach a replica
//! slot. Delta and snapshot byte sizes are accumulated in
//! `serve.delta_bytes` / `serve.snapshot_bytes` (their ratio is the
//! gated `serve_delta_bytes_ratio`), and `serve.stale_deltas` counts
//! rejected version gaps.

use crate::metrics::Counter;
use crate::rkmeans::RkModel;
use crate::serve::{DeltaApplyError, ModelDelta, ModelMesh};
use anyhow::{ensure, Result};
use std::sync::Arc;

/// Byte accounting for one published version.
#[derive(Clone, Copy, Debug)]
pub struct PublishStats {
    /// Version now serving on every replica.
    pub version: u64,
    /// Wire size of the shipped delta.
    pub delta_bytes: usize,
    /// Wire size a full snapshot would have cost.
    pub snapshot_bytes: usize,
    /// Changed parts shipped (subspaces + centroid rows).
    pub changes: usize,
}

impl PublishStats {
    /// `snapshot_bytes / delta_bytes` — how much cheaper the delta was
    /// (∞-safe: a zero-byte delta cannot happen, the scalars always
    /// ship).
    pub fn bytes_ratio(&self) -> f64 {
        self.snapshot_bytes as f64 / self.delta_bytes as f64
    }
}

/// Ships versions to a [`ModelMesh`] as verified deltas (module docs).
pub struct Publisher {
    mesh: Arc<ModelMesh>,
    /// What every replica currently serves — the delta base.
    current: Arc<RkModel>,
    publishes: Arc<Counter>,
    delta_bytes: Arc<Counter>,
    snapshot_bytes: Arc<Counter>,
    stale_deltas: Arc<Counter>,
}

impl Publisher {
    /// A publisher whose base is the mesh's current model.
    pub fn new(mesh: Arc<ModelMesh>) -> Publisher {
        let current = mesh.model(0);
        let m = mesh.metrics().clone();
        Publisher {
            current,
            publishes: m.counter("serve.publishes"),
            delta_bytes: m.counter("serve.delta_bytes"),
            snapshot_bytes: m.counter("serve.snapshot_bytes"),
            stale_deltas: m.counter("serve.stale_deltas"),
            mesh,
        }
    }

    /// Version the replicas currently serve.
    pub fn version(&self) -> u64 {
        self.current.version
    }

    /// Ship `next` to every replica via the delta wire path, verifying
    /// bitwise reconstruction before the swap (module docs). Returns the
    /// byte accounting; the mesh's `serve.*` counters accumulate it.
    pub fn publish(&mut self, next: &RkModel) -> Result<PublishStats> {
        self.publish_wire(next).map(|(stats, _)| stats)
    }

    /// [`Publisher::publish`], but also hand back the verified delta
    /// wire bytes — exactly what went through the decode→apply→byte
    /// check — so a socket tier ([`crate::serve::rpc`]) can broadcast
    /// the same bytes to out-of-process replicas.
    pub fn publish_wire(&mut self, next: &RkModel) -> Result<(PublishStats, Vec<u8>)> {
        let delta = self.current.diff(next);
        let wire = delta.to_bytes();
        let snapshot = next.to_bytes();

        // Replica-side path: decode the wire bytes, splice onto the
        // served base, insist on bit-exact reconstruction.
        let decoded = ModelDelta::from_bytes(&wire)?;
        let applied = match self.current.apply_delta(&decoded) {
            Ok(m) => m,
            Err(e @ DeltaApplyError::VersionGap { .. }) => {
                self.stale_deltas.inc();
                return Err(e.into());
            }
            Err(e) => return Err(e.into()),
        };
        ensure!(
            applied.to_bytes() == snapshot,
            "delta round-trip diverged from the version-{} snapshot",
            next.version
        );

        let installed = Arc::new(applied);
        self.mesh.install(Arc::clone(&installed));
        self.current = installed;
        self.publishes.inc();
        self.delta_bytes.add(wire.len() as u64);
        self.snapshot_bytes.add(snapshot.len() as u64);
        let stats = PublishStats {
            version: next.version,
            delta_bytes: wire.len(),
            snapshot_bytes: snapshot.len(),
            changes: delta.changes(),
        };
        Ok((stats, wire))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::sparse_lloyd::CentroidCoord;
    use crate::metrics::Metrics;
    use crate::rkmeans::{ClusterOpts, RkPipeline, SubspaceOpts};
    use crate::synthetic::{retailer, Scale};

    fn model(version: u64) -> RkModel {
        let db = retailer::generate(Scale::tiny(), 7);
        let feq = retailer::feq();
        let pipe = RkPipeline::plan(&db, &feq).unwrap();
        let marginals = pipe.marginals().unwrap();
        let subspaces = pipe.subspaces(&marginals, &SubspaceOpts::new(4)).unwrap();
        pipe.coreset(&subspaces).unwrap().cluster(&ClusterOpts::new(4)).with_version(version)
    }

    #[test]
    fn publish_ships_deltas_and_swaps() {
        let metrics = Metrics::new();
        let base = model(1);
        let mesh = ModelMesh::new(base.clone(), 2, metrics.clone());
        let mut publisher = Publisher::new(Arc::clone(&mesh));

        let mut next = base.clone().with_version(2);
        match &mut next.centroids[1][0] {
            CentroidCoord::Continuous(mu) => *mu += 0.5,
            CentroidCoord::Categorical(beta) => beta[0] += 0.125,
        }
        let stats = publisher.publish(&next).unwrap();
        assert_eq!(stats.version, 2);
        assert_eq!(stats.changes, 1, "one moved row");
        assert!(stats.bytes_ratio() > 2.0, "delta must be much smaller: {stats:?}");
        assert_eq!(publisher.version(), 2);
        assert_eq!(mesh.latest_version(), 2);
        // Replica-served bytes are bit-identical to the writer's model.
        assert_eq!(mesh.model(0).to_bytes(), next.to_bytes());
        assert_eq!(metrics.counter("serve.publishes").get(), 1);
        assert_eq!(metrics.counter("serve.swaps").get(), 2);
        assert!(
            metrics.counter("serve.delta_bytes").get()
                < metrics.counter("serve.snapshot_bytes").get()
        );
    }

    #[test]
    fn republishing_same_version_is_cheap_and_exact() {
        let base = model(1);
        let mesh = ModelMesh::new(base.clone(), 1, Metrics::new());
        let mut publisher = Publisher::new(mesh);
        let stats = publisher.publish(&base).unwrap();
        assert_eq!(stats.changes, 0, "self-delta ships nothing but scalars");
        assert!(stats.delta_bytes < stats.snapshot_bytes);
    }
}
