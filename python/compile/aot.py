"""AOT driver: lower the L2 model to HLO **text** per shape bucket.

Run as ``python -m compile.aot --out ../artifacts`` (the Makefile's
``artifacts`` target). Produces:

* ``lloyd_step_<N>x<D>x<K>.hlo.txt`` — one Lloyd iteration;
* ``lloyd_sweep_<N>x<D>x<K>x<T>.hlo.txt`` — a fused ``T``-step scan for the
  kernel bench;
* ``manifest.json`` — the shape-bucket index the rust runtime loads.

Interchange format is HLO *text*, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids that the crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import lloyd as kernels

# Shape buckets (N points, D dims, K centroids). N is a multiple of the
# kernel BLOCK_N; the rust runtime picks the smallest bucket that fits and
# pads. Keep the set small — every bucket is compiled by PJRT on first use.
BUCKETS = [
    (1024, 8, 8),
    (1024, 32, 16),
    (4096, 16, 16),
    (4096, 64, 16),
    (16384, 32, 16),
    (16384, 32, 64),
    (65536, 16, 16),
    (65536, 64, 64),
]

# Fused-sweep iteration count for the kernel bench artifact.
SWEEP_ITERS = 5
SWEEP_BUCKETS = [(4096, 16, 16), (16384, 32, 16)]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_step(n: int, d: int, k: int) -> str:
    pts = jax.ShapeDtypeStruct((n, d), jnp.float32)
    wts = jax.ShapeDtypeStruct((n,), jnp.float32)
    cts = jax.ShapeDtypeStruct((k, d), jnp.float32)
    return to_hlo_text(jax.jit(model.lloyd_step).lower(pts, wts, cts))


def lower_sweep(n: int, d: int, k: int, iters: int) -> str:
    pts = jax.ShapeDtypeStruct((n, d), jnp.float32)
    wts = jax.ShapeDtypeStruct((n,), jnp.float32)
    cts = jax.ShapeDtypeStruct((k, d), jnp.float32)
    fn = lambda p, w, c: model.lloyd_sweep(p, w, c, iters)  # noqa: E731
    return to_hlo_text(jax.jit(fn).lower(pts, wts, cts))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--quick", action="store_true", help="only the smallest bucket (for tests)"
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    buckets = BUCKETS[:1] if args.quick else BUCKETS
    sweeps = [] if args.quick else SWEEP_BUCKETS
    manifest = {"version": 1, "block_n": kernels.BLOCK_N, "artifacts": []}

    for n, d, k in buckets:
        name = f"lloyd_step_{n}x{d}x{k}.hlo.txt"
        path = os.path.join(args.out, name)
        text = lower_step(n, d, k)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "file": name,
                "entry": "lloyd_step",
                "n": n,
                "d": d,
                "k": k,
                "vmem_bytes": kernels.vmem_bytes(kernels.BLOCK_N, d, k),
            }
        )
        print(f"wrote {name} ({len(text)} chars)")

    for n, d, k in sweeps:
        name = f"lloyd_sweep_{n}x{d}x{k}x{SWEEP_ITERS}.hlo.txt"
        path = os.path.join(args.out, name)
        text = lower_sweep(n, d, k, SWEEP_ITERS)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "file": name,
                "entry": "lloyd_sweep",
                "n": n,
                "d": d,
                "k": k,
                "iters": SWEEP_ITERS,
                "vmem_bytes": kernels.vmem_bytes(kernels.BLOCK_N, d, k),
            }
        )
        print(f"wrote {name} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote manifest.json ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
