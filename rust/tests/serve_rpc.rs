//! Multi-process tests for the socket RPC tier (`rkmeans::serve::rpc`):
//! a real writer process (`rkmeans serve --listen`) and real replica
//! processes (`rkmeans replica --connect`) over localhost TCP, driven
//! through `CARGO_BIN_EXE_rkmeans`.
//!
//! Properties pinned here:
//!
//! * the snapshot catch-up payload on the wire is **byte-identical** to
//!   `RkModel::to_bytes` (read with a raw socket client, no library
//!   verification in the path);
//! * every `Assignment.version` served over the socket is a version the
//!   writer actually published (scraped from its `published v<N>`
//!   stdout lines) or the initial model version;
//! * killing a replica mid-run and starting a fresh one ends with the
//!   newcomer converged on the writer's latest version, with the writer
//!   having served snapshot catch-ups (`--drop-every` also forces a
//!   VersionGap → catch-up → rejoin cycle on the *surviving* replica);
//! * the deprecated `rkmeans serve --rate/--batches` spelling still
//!   parses and forwards to the stream demo with the plain warning.

use rkmeans::rkmeans::RkModel;
use rkmeans::serve::rpc::wire::{self, kind};
use rkmeans::serve::{fetch_snapshot, probe, run_rpc_loop, send_stop, LoadSpec};
use std::collections::BTreeSet;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::process::{Child, Command, Stdio};
use std::sync::mpsc;
use std::time::{Duration, Instant};

const SCALE: &str = "0.005";
const STARTUP: Duration = Duration::from_secs(120);

/// A child `rkmeans` process with stdout forwarded line-by-line; the
/// drain thread keeps the pipe from backing up under the metrics dump.
struct Proc {
    child: Child,
    lines: mpsc::Receiver<String>,
    seen: Vec<String>,
    addr: Option<String>,
}

fn spawn_rkmeans(args: &[&str]) -> Proc {
    let exe = env!("CARGO_BIN_EXE_rkmeans");
    let mut child = Command::new(exe)
        .args(args)
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .unwrap_or_else(|e| panic!("spawning {exe} {args:?}: {e}"));
    let stdout = child.stdout.take().expect("piped stdout");
    let (tx, rx) = mpsc::channel();
    std::thread::spawn(move || {
        for line in BufReader::new(stdout).lines().map_while(|l| l.ok()) {
            if tx.send(line).is_err() {
                break;
            }
        }
    });
    Proc { child, lines: rx, seen: Vec::new(), addr: None }
}

impl Proc {
    /// Pull buffered stdout lines into `seen` without blocking.
    fn drain(&mut self) {
        while let Ok(line) = self.lines.try_recv() {
            self.seen.push(line);
        }
    }

    /// Wait for the `rpc listening on <addr>` line.
    fn listening_addr(&mut self) -> String {
        if let Some(a) = &self.addr {
            return a.clone();
        }
        let t0 = Instant::now();
        while t0.elapsed() < STARTUP {
            match self.lines.recv_timeout(Duration::from_millis(100)) {
                Ok(line) => {
                    let found = line.strip_prefix("rpc listening on ").map(str::to_string);
                    self.seen.push(line);
                    if let Some(a) = found {
                        let a = a.trim().to_string();
                        self.addr = Some(a.clone());
                        return a;
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        let _ = self.child.kill();
        panic!("no `rpc listening on` line within {STARTUP:?}; got {:?}", self.seen);
    }

    /// Versions from `published v<N> …` stdout lines seen so far.
    fn published_versions(&mut self) -> BTreeSet<u64> {
        self.drain();
        self.seen
            .iter()
            .filter_map(|l| l.strip_prefix("published v"))
            .filter_map(|rest| {
                rest.split_whitespace().next().and_then(|tok| {
                    tok.trim_end_matches(|c: char| !c.is_ascii_digit()).parse().ok()
                })
            })
            .collect()
    }

    /// Graceful stop via the control plane; returns the exit status.
    fn stop(mut self) -> std::process::ExitStatus {
        if let Some(a) = &self.addr {
            let _ = send_stop(a);
        }
        let t0 = Instant::now();
        loop {
            if let Ok(Some(status)) = self.child.try_wait() {
                return status;
            }
            if t0.elapsed() > Duration::from_secs(30) {
                let _ = self.child.kill();
                return self.child.wait().expect("child wait after kill");
            }
            std::thread::sleep(Duration::from_millis(50));
        }
    }

    fn kill(mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn writer(publishes: &str, publish_ms: &str, drop_every: &str) -> Proc {
    spawn_rkmeans(&[
        "serve", "--dataset", "retailer", "--scale", SCALE, "--k", "4", "--seed", "42",
        "--listen", "127.0.0.1:0", "--publishes", publishes, "--publish-ms", publish_ms,
        "--drop-every", drop_every,
    ])
}

fn replica(writer_addr: &str) -> Proc {
    spawn_rkmeans(&["replica", "--connect", writer_addr, "--listen", "127.0.0.1:0"])
}

/// Raw snapshot request: no library-side verification in the path, so
/// the assertion below really is about the bytes on the wire.
fn raw_snapshot(addr: &str) -> Vec<u8> {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(30))).expect("read timeout");
    stream.write_all(&wire::encode_frame(kind::SNAPSHOT_REQ, &[])).expect("send");
    let mut fb = wire::FrameBuf::new();
    let mut buf = [0u8; 16 * 1024];
    let deadline = Instant::now() + Duration::from_secs(60);
    while Instant::now() < deadline {
        if let Some((k, payload)) = fb.next_frame().expect("well-formed frame") {
            assert_eq!(k, kind::SNAPSHOT, "expected a snapshot frame");
            return payload;
        }
        let n = stream.read(&mut buf).expect("read");
        assert!(n > 0, "server closed before answering the snapshot request");
        fb.extend(&buf[..n]);
    }
    panic!("no snapshot frame within 60s");
}

#[test]
fn snapshot_bytes_on_wire_match_model_exactly() {
    let mut w = writer("0", "100", "0");
    let addr = w.listening_addr();

    let payload = raw_snapshot(&addr);
    let model = RkModel::from_bytes(&payload).expect("wire payload parses as a model");
    assert_eq!(
        model.to_bytes(),
        payload,
        "snapshot catch-up payload must be byte-identical to RkModel::to_bytes"
    );
    // And the verifying client agrees with the raw read.
    let fetched = fetch_snapshot(&addr, Duration::from_secs(30)).expect("fetch_snapshot");
    assert_eq!(fetched.to_bytes(), payload);
    assert_eq!(fetched.version, model.version);

    let status = w.stop();
    assert!(status.success(), "writer exited with {status:?}");
}

#[test]
fn served_versions_are_published_and_killed_replica_catches_up() {
    // drop-every 2 drops each subscriber's first delta (v2), so the
    // surviving replica is forced through VersionGap → snapshot
    // catch-up → rejoin while the load runs.
    let mut w = writer("2", "400", "2");
    let waddr = w.listening_addr();
    let initial = probe(&waddr, Duration::from_secs(30)).expect("probe writer");
    assert_eq!(initial.role, wire::ROLE_WRITER);
    let v0 = initial.version;

    let mut ra = replica(&waddr);
    let mut rb = replica(&waddr);
    let a_addr = ra.listening_addr();
    let b_addr = rb.listening_addr();

    // Paced socket load across both replicas, long enough (~4 s) to
    // span both publishes and the churn below.
    let addrs = vec![a_addr.clone(), b_addr.clone()];
    let load = std::thread::spawn(move || {
        let model = fetch_snapshot(&addrs[0], Duration::from_secs(30))?;
        let rows = rkmeans::serve::synth_rows(&model, 64, 7);
        run_rpc_loop(
            &addrs,
            &rows,
            &LoadSpec { requests: 1200, clients: 2, qps: Some(300.0), seed: 9 },
        )
    });

    // Kill replica B mid-run; its clients must fail over to A. Then
    // start a fresh replica which has to snapshot-catch-up from cold.
    std::thread::sleep(Duration::from_millis(600));
    rb.kill();
    let mut rc = replica(&waddr);
    let c_addr = rc.listening_addr();

    let out = load.join().expect("load thread").expect("rpc load");
    assert!(out.report.requests > 0, "no requests survived the churn");
    assert!(out.report.monotonic, "per-client served versions must be monotone");

    // Every served version is the initial one or one the writer
    // actually published (scraped from its stdout).
    let mut published = w.published_versions();
    published.insert(v0);
    for v in &out.versions {
        assert!(
            published.contains(v),
            "served version {v} was never published (published: {published:?})"
        );
    }

    // The fresh replica converges on the writer's latest version.
    let deadline = Instant::now() + Duration::from_secs(120);
    let mut converged = false;
    while Instant::now() < deadline {
        let wp = probe(&waddr, Duration::from_secs(10)).expect("probe writer");
        let cp = probe(&c_addr, Duration::from_secs(10)).expect("probe fresh replica");
        if cp.version == wp.version && !w.published_versions().is_empty() {
            converged = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    assert!(converged, "restarted replica never reached the writer's version");

    // Byte-equality across the process boundary: the fresh replica's
    // served snapshot matches the writer's exactly.
    let from_writer = fetch_snapshot(&waddr, Duration::from_secs(30)).expect("writer snapshot");
    let from_fresh = fetch_snapshot(&c_addr, Duration::from_secs(30)).expect("replica snapshot");
    assert_eq!(from_writer.to_bytes(), from_fresh.to_bytes());

    // The writer served at least one snapshot catch-up (the fresh
    // replica's cold start guarantees one; the forced gap adds more),
    // and the surviving replica went through the gap → catch-up cycle.
    let wp = probe(&waddr, Duration::from_secs(10)).expect("probe writer");
    assert!(wp.catchups >= 1, "writer served no snapshot catch-ups: {wp:?}");
    let deadline = Instant::now() + Duration::from_secs(60);
    let mut survivor_caught_up = false;
    while Instant::now() < deadline {
        let ap = probe(&a_addr, Duration::from_secs(10)).expect("probe survivor");
        if ap.gaps >= 1 && ap.catchups >= 1 {
            survivor_caught_up = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    assert!(survivor_caught_up, "survivor never hit VersionGap → snapshot catch-up");

    assert!(ra.stop().success(), "replica A exit");
    assert!(rc.stop().success(), "replica C exit");
    assert!(w.stop().success(), "writer exit");
}

#[test]
fn stream_alias_forwarding_still_parses() {
    // The pre-mesh demo spelling must keep parsing: forwarded to
    // `stream` with the plain deprecation warning on stderr.
    let exe = env!("CARGO_BIN_EXE_rkmeans");
    let out = Command::new(exe)
        .args([
            "serve", "--dataset", "retailer", "--scale", SCALE, "--rate", "10", "--batches", "0",
        ])
        .output()
        .expect("run alias spelling");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "alias invocation failed: {stderr}");
    assert!(
        stderr.contains("warning: the streaming-coordinator demo is now `rkmeans stream`"),
        "missing plain deprecation warning, got: {stderr}"
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("streaming retailer"), "did not forward to the stream demo");
}
