"""Layer 1 — Pallas kernel for the weighted-Lloyd assignment step.

The hot spot of Rk-means Step 4 (and of the dense baseline) is computing,
for a block of points, the squared distance to every centroid and the
argmin. We expand ``‖x − c‖² = ‖x‖² − 2·x·cᵀ + ‖c‖²`` so the dominant cost
is the ``x·cᵀ`` contraction — on a real TPU this feeds the MXU systolic
array; here the kernel runs under ``interpret=True`` because the CPU PJRT
plugin cannot execute Mosaic custom-calls (see DESIGN.md
§Hardware-Adaptation and /opt/xla-example/README.md).

Tiling: the grid iterates over N-blocks of ``block_n`` points. Each step
streams one ``[block_n, D]`` point tile HBM→VMEM while the full ``[K, D]``
centroid tile stays VMEM-resident (K and D are bucketed small; the VMEM
budget per bucket is recorded in DESIGN.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile height: 128 matches the MXU/VPU lane count on TPU and is a
# divisor of every AOT bucket size.
BLOCK_N = 128


def _assign_kernel(x_ref, c_ref, assign_ref, mind_ref):
    """One grid step: distances + argmin for a block of points.

    x_ref: [block_n, D] f32 — point tile.
    c_ref: [K, D] f32 — all centroids (VMEM-resident).
    assign_ref: [block_n] i32 — out: nearest-centroid index.
    mind_ref: [block_n] f32 — out: squared distance to it.
    """
    x = x_ref[...]
    c = c_ref[...]
    # MXU contraction; accumulate in f32.
    xc = jnp.dot(x, c.T, preferred_element_type=jnp.float32)
    xn = jnp.sum(x * x, axis=1, keepdims=True)
    cn = jnp.sum(c * c, axis=1)[None, :]
    d = xn - 2.0 * xc + cn
    assign_ref[...] = jnp.argmin(d, axis=1).astype(jnp.int32)
    # Clamp tiny negatives from the expansion.
    mind_ref[...] = jnp.maximum(jnp.min(d, axis=1), 0.0)


@functools.partial(jax.jit, static_argnames=("block_n",))
def assign(points: jax.Array, centroids: jax.Array, *, block_n: int = BLOCK_N):
    """Nearest-centroid assignment via the Pallas kernel.

    points: [N, D] f32 (N must be a multiple of ``block_n``; the AOT
    buckets guarantee this, and the rust runtime pads).
    centroids: [K, D] f32.
    Returns (assign [N] i32, min_sq_dist [N] f32).
    """
    n, d = points.shape
    k, d2 = centroids.shape
    if d != d2:
        raise ValueError(f"dim mismatch: points D={d} centroids D={d2}")
    if n % block_n != 0:
        raise ValueError(f"N={n} not a multiple of block_n={block_n}")
    grid = (n // block_n,)
    return pl.pallas_call(
        _assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d2), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(points, centroids)


def vmem_bytes(block_n: int, d: int, k: int) -> int:
    """Estimated VMEM footprint of one grid step (f32 tiles + outputs).

    Used by DESIGN.md §Perf to size buckets against the ~16 MiB/core VMEM
    budget of a TPU: point tile + centroid tile + distance tile + outputs.
    """
    f32 = 4
    return f32 * (block_n * d + k * d + block_n * k + 2 * block_n)
