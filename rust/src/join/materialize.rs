//! Acyclic join enumeration: materialized or streamed.
//!
//! After a semi-join pruning pass (full-join counts > 0), enumeration over
//! the join tree is output-linear: every partial assignment extends to at
//! least one output row, so the DFS never dead-ends.

use crate::data::{Database, Relation, Value};
use crate::faq::full_join_counts;
use crate::query::{Feq, JoinTree};
use crate::util::FxHashMap;
use anyhow::{bail, Result};

/// A materialized FEQ output: the paper's data matrix `X` (pre-one-hot).
#[derive(Clone, Debug)]
pub struct DataMatrix {
    pub feature_names: Vec<String>,
    /// One entry per output tuple; values in `feature_names` order.
    pub rows: Vec<Vec<Value>>,
    /// Tuple multiplicities (all 1 for unweighted base relations).
    pub weights: Vec<f64>,
}

impl DataMatrix {
    /// Number of tuples `|X|`.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when the join output is empty.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Total weight mass.
    pub fn mass(&self) -> f64 {
        self.weights.iter().sum()
    }

    /// Estimated in-memory bytes (8 bytes per value + weight), for the
    /// Table-1 style "Size of X" report.
    pub fn byte_size(&self) -> u64 {
        (self.feature_names.len() as u64 * 8 + 8) * self.rows.len() as u64
    }
}

/// Plan shared by [`materialize`] and [`stream_rows`].
struct EnumPlan<'a> {
    db: &'a Database,
    tree: &'a JoinTree,
    /// Pre-order of tree nodes (root first; parents before children).
    preorder: Vec<usize>,
    /// For each node (by tree index): hash index sep-key -> surviving rows.
    index: Vec<FxHashMap<Vec<u64>, Vec<u32>>>,
    /// Rows of the root that survive pruning.
    root_rows: Vec<u32>,
    /// For each feature: (node, column) where its value lives.
    feat_src: Vec<(usize, usize)>,
    /// For each non-root node: column indices *in its parent* forming the key.
    parent_key_cols: Vec<Vec<usize>>,
    /// For each node: its position in `preorder`.
    pre_pos: Vec<usize>,
}

fn build_plan<'a>(db: &'a Database, feq: &'a Feq, tree: &'a JoinTree) -> Result<EnumPlan<'a>> {
    let jc = full_join_counts(db, tree)?;
    let n = tree.len();

    // Pre-order traversal.
    let mut preorder = Vec::with_capacity(n);
    let mut stack = vec![tree.root];
    while let Some(u) = stack.pop() {
        preorder.push(u);
        for c in tree.children(u) {
            stack.push(c);
        }
    }

    // Hash indexes on surviving rows (count > 0) for non-root nodes.
    let mut index: Vec<FxHashMap<Vec<u64>, Vec<u32>>> = vec![FxHashMap::default(); n];
    let mut root_rows = Vec::new();
    for u in 0..n {
        let rel = rel_of(db, tree, u);
        if u == tree.root {
            for row in 0..rel.n_rows() {
                if jc.counts[u][row] > 0.0 {
                    root_rows.push(row as u32);
                }
            }
            continue;
        }
        let sep_cols: Vec<usize> = tree.sep[u]
            .iter()
            .map(|a| rel.schema.index_of(a).expect("sep attr in node"))
            .collect();
        let idx = &mut index[u];
        for row in 0..rel.n_rows() {
            if jc.counts[u][row] > 0.0 {
                let key: Vec<u64> = sep_cols.iter().map(|&c| rel.col(c).key_u64(row)).collect();
                idx.entry(key).or_default().push(row as u32);
            }
        }
    }

    // Feature sources.
    let mut feat_src = Vec::with_capacity(feq.features.len());
    for f in &feq.features {
        let owner = feq
            .owner_of(db, &f.attr)
            .ok_or_else(|| anyhow::anyhow!("feature {:?} has no owner", f.attr))?;
        let rel = rel_of(db, tree, owner);
        feat_src.push((owner, rel.schema.index_of(&f.attr).expect("attr in owner")));
    }

    // Parent-side key columns per node.
    let mut parent_key_cols = vec![Vec::new(); n];
    for u in 0..n {
        if let Some(p) = tree.parent[u] {
            let prel = rel_of(db, tree, p);
            parent_key_cols[u] = tree.sep[u]
                .iter()
                .map(|a| prel.schema.index_of(a).expect("sep attr in parent"))
                .collect();
        }
    }

    let mut pre_pos = vec![0usize; n];
    for (i, &u) in preorder.iter().enumerate() {
        pre_pos[u] = i;
    }

    Ok(EnumPlan { db, tree, preorder, index, root_rows, feat_src, parent_key_cols, pre_pos })
}

fn rel_of<'a>(db: &'a Database, tree: &'a JoinTree, u: usize) -> &'a Relation {
    db.get(&tree.rel_names[u]).expect("relation exists")
}

impl<'a> EnumPlan<'a> {
    /// DFS over the pre-order, invoking `emit` for every output tuple.
    /// Returns the number of emitted tuples or stops early when `emit`
    /// returns `false`.
    fn enumerate(&self, mut emit: impl FnMut(&[u32], f64) -> bool) -> u64 {
        let n = self.tree.len();
        if n == 0 || self.root_rows.is_empty() {
            return 0;
        }
        // current[pos] = chosen row of preorder[pos]; choice index per level.
        let mut current = vec![0u32; n];
        let mut emitted = 0u64;

        // Candidates at each level, computed from the parent's current row.
        // Level 0 candidates are the surviving root rows.
        let mut cand: Vec<&[u32]> = vec![&[]; n];
        let mut cursor = vec![0usize; n];
        cand[0] = &self.root_rows;
        cursor[0] = 0;
        let mut level = 0usize;

        'outer: loop {
            if cursor[level] >= cand[level].len() {
                // Exhausted this level: backtrack.
                if level == 0 {
                    break;
                }
                level -= 1;
                cursor[level] += 1;
                continue;
            }
            current[level] = cand[level][cursor[level]];
            if level + 1 == n {
                // Full assignment: emit.
                let w = self.row_weight(&current);
                emitted += 1;
                if !emit(&current, w) {
                    break 'outer;
                }
                cursor[level] += 1;
                continue;
            }
            // Descend: compute candidates of the next pre-order node from
            // its (already assigned) parent.
            let u = self.preorder[level + 1];
            let p = self.tree.parent[u].expect("non-root in preorder tail");
            let prel = rel_of(self.db, self.tree, p);
            let prow = current[self.pre_pos[p]] as usize;
            let key: Vec<u64> = self.parent_key_cols[u]
                .iter()
                .map(|&c| prel.col(c).key_u64(prow))
                .collect();
            match self.index[u].get(&key) {
                Some(rows) if !rows.is_empty() => {
                    level += 1;
                    cand[level] = rows;
                    cursor[level] = 0;
                }
                // Semi-join pruning guarantees a match; defensive skip.
                _ => {
                    cursor[level] += 1;
                }
            }
        }
        emitted
    }

    fn row_weight(&self, current: &[u32]) -> f64 {
        let mut w = 1.0;
        for (pos, &u) in self.preorder.iter().enumerate() {
            let rel = rel_of(self.db, self.tree, u);
            if rel.has_weights() {
                w *= rel.weight(current[pos] as usize);
            }
        }
        w
    }

    fn extract(&self, current: &[u32], out: &mut Vec<Value>) {
        out.clear();
        for &(node, col) in &self.feat_src {
            let rel = rel_of(self.db, self.tree, node);
            out.push(rel.value(current[self.pre_pos[node]] as usize, col));
        }
    }
}

/// Stream the FEQ output without storing it: `f(feature_values, weight)`
/// per output tuple. Returns the number of tuples enumerated.
pub fn stream_rows(
    db: &Database,
    feq: &Feq,
    tree: &JoinTree,
    mut f: impl FnMut(&[Value], f64),
) -> Result<u64> {
    let plan = build_plan(db, feq, tree)?;
    let mut vals: Vec<Value> = Vec::with_capacity(feq.features.len());
    let emitted = plan.enumerate(|current, w| {
        plan.extract(current, &mut vals);
        f(&vals, w);
        true
    });
    Ok(emitted)
}

/// Materialize the full data matrix `X`. This is the expensive baseline
/// step; use [`materialize_capped`] where a runaway join would OOM.
pub fn materialize(db: &Database, feq: &Feq, tree: &JoinTree) -> Result<DataMatrix> {
    materialize_capped(db, feq, tree, u64::MAX)
}

/// Materialize with a row cap; errors when the output exceeds it.
pub fn materialize_capped(
    db: &Database,
    feq: &Feq,
    tree: &JoinTree,
    cap: u64,
) -> Result<DataMatrix> {
    let plan = build_plan(db, feq, tree)?;
    let mut rows = Vec::new();
    let mut weights = Vec::new();
    let mut vals: Vec<Value> = Vec::with_capacity(feq.features.len());
    let mut overflow = false;
    plan.enumerate(|current, w| {
        if rows.len() as u64 >= cap {
            overflow = true;
            return false;
        }
        plan.extract(current, &mut vals);
        rows.push(vals.clone());
        weights.push(w);
        true
    });
    if overflow {
        bail!("join output exceeds cap of {cap} rows");
    }
    Ok(DataMatrix {
        feature_names: feq.features.iter().map(|f| f.attr.clone()).collect(),
        rows,
        weights,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Attr, Schema};
    use crate::query::Hypergraph;

    fn setup() -> (Database, Feq, JoinTree) {
        // fact(a,b) ⋈ dim(b,c) ⋈ dim2(b,e): a 3-node tree with fanout.
        let mut fact =
            Relation::new("fact", Schema::new(vec![Attr::cat("a", 8), Attr::cat("b", 4)]));
        for (a, b) in [(0, 0), (1, 0), (2, 1), (3, 3)] {
            fact.push_row(&[Value::Cat(a), Value::Cat(b)]);
        }
        let mut dim = Relation::new("dim", Schema::new(vec![Attr::cat("b", 4), Attr::cat("c", 8)]));
        for (b, c) in [(0, 0), (0, 1), (1, 2)] {
            dim.push_row(&[Value::Cat(b), Value::Cat(c)]);
        }
        let mut dim2 =
            Relation::new("dim2", Schema::new(vec![Attr::cat("b", 4), Attr::double("e")]));
        for (b, e) in [(0, 0.5), (1, 1.5), (1, 2.5)] {
            dim2.push_row(&[Value::Cat(b), Value::Double(e)]);
        }
        let mut db = Database::new();
        db.add(fact);
        db.add(dim);
        db.add(dim2);
        let feq = Feq::with_features(&["fact", "dim", "dim2"], &["a", "b", "c", "e"]);
        let tree = Hypergraph::from_feq(&db, &feq).join_tree().unwrap();
        (db, feq, tree)
    }

    #[test]
    fn materialize_matches_nested_loop() {
        let (db, feq, tree) = setup();
        let x = materialize(&db, &feq, &tree).unwrap();
        // By hand: b=0 -> fact rows {0,1} × dim {0,1} × dim2 {0} = 4
        //          b=1 -> fact {2} × dim {2} × dim2 {1,2} = 2
        //          b=3 -> dangling. Total 6.
        assert_eq!(x.len(), 6);
        assert_eq!(x.mass(), 6.0);
        assert_eq!(x.feature_names, vec!["a", "b", "c", "e"]);
        // Output size must agree with the FAQ count.
        let total = crate::faq::output_size(&db, &tree).unwrap();
        assert_eq!(x.mass(), total);
        // Spot-check one row: (a=2, b=1, c=2, e=1.5) must exist.
        assert!(x.rows.iter().any(|r| r
            == &vec![Value::Cat(2), Value::Cat(1), Value::Cat(2), Value::Double(1.5)]));
    }

    #[test]
    fn stream_agrees_with_materialize() {
        let (db, feq, tree) = setup();
        let x = materialize(&db, &feq, &tree).unwrap();
        let mut streamed = Vec::new();
        let n = stream_rows(&db, &feq, &tree, |vals, w| {
            streamed.push((vals.to_vec(), w));
        })
        .unwrap();
        assert_eq!(n as usize, x.len());
        // Same multiset of rows (order may differ).
        for (vals, _) in &streamed {
            assert!(x.rows.contains(vals));
        }
    }

    #[test]
    fn cap_is_enforced() {
        let (db, feq, tree) = setup();
        assert!(materialize_capped(&db, &feq, &tree, 3).is_err());
        assert!(materialize_capped(&db, &feq, &tree, 6).is_ok());
    }

    #[test]
    fn weighted_relations_multiply() {
        let (mut db, feq, _) = setup();
        {
            let dim2 = db.get_mut("dim2").unwrap();
            let mut new = Relation::new("dim2", dim2.schema.clone());
            for r in 0..dim2.n_rows() {
                new.push_row_weighted(&dim2.row(r), 2.0);
            }
            *dim2 = new;
        }
        let tree = Hypergraph::from_feq(&db, &feq).join_tree().unwrap();
        let x = materialize(&db, &feq, &tree).unwrap();
        assert_eq!(x.len(), 6);
        assert_eq!(x.mass(), 12.0);
    }

    #[test]
    fn empty_join_is_empty_matrix() {
        let (mut db, feq, _) = setup();
        *db.get_mut("dim").unwrap() =
            Relation::new("dim", Schema::new(vec![Attr::cat("b", 4), Attr::cat("c", 8)]));
        let tree = Hypergraph::from_feq(&db, &feq).join_tree().unwrap();
        let x = materialize(&db, &feq, &tree).unwrap();
        assert!(x.is_empty());
    }
}
