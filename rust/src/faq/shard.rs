//! Horizontal sharding of the designated fact relation for parallel
//! Step-3 builds.
//!
//! Sharding any *single* relation of a join partitions the join output:
//! every output tuple extends exactly one fact tuple, so the grid-weight
//! table of the full database is the **cell-wise sum** of the per-shard
//! tables ([`GridTable::merge`](super::GridTable::merge)). The Step-3 FAQ
//! is a counting query in the ring ℤ — with integer tuple multiplicities
//! every partial sum is an exactly-represented f64 integer, so the merged
//! table is *bitwise identical* to the single-shard build regardless of
//! how tuples were partitioned (fractional multiplicities are subject to
//! f64 reassociation, like any regrouped sum).
//!
//! The partition is **value-hashed**, not row-ranged: a tuple's shard
//! depends only on its values, so the incremental layer can route a
//! `TupleDelta` to the shard holding every copy of that tuple — a delete
//! lands where its inserts did, preserving per-shard non-negative
//! multiplicities (see [`crate::incremental::sharded`]).

use crate::data::{Database, Relation, Value};
use anyhow::{Context, Result};

/// FNV-1a offset basis / prime (the same family as the engine's state
/// hashing; any stable mix works — this one is allocation-free).
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x100_0000_01b3;

/// Deterministic shard of a tuple: a stable value hash of the full row,
/// mod `shards`. Doubles hash by bit pattern with `-0.0` normalized to
/// `0.0`, matching [`Relation`]'s value-keyed row index, so a tuple and
/// its later retraction always land on the same shard.
pub fn shard_of(values: &[Value], shards: usize) -> usize {
    debug_assert!(shards > 0, "shard count must be positive");
    let mut h = FNV_OFFSET;
    for v in values {
        let k = match v {
            Value::Int(x) => *x as u64,
            Value::Cat(c) => *c as u64,
            Value::Double(x) => {
                let x = if *x == 0.0 { 0.0 } else { *x };
                x.to_bits()
            }
        };
        h = (h ^ k).wrapping_mul(FNV_PRIME);
    }
    (h % shards as u64) as usize
}

/// Split `db` into `shards` databases that partition the `fact` relation
/// by [`shard_of`] and replicate every other relation (dimension tables
/// are small next to the fact table — the memory cost is `S × |dims|`).
/// Relation order, schemas, tuple weights and declared FDs carry over, so
/// each shard is a drop-in input for any FAQ pass over the same join
/// tree. Zero-weight tombstones are not copied (every FAQ pass already
/// treats them as absent).
pub fn shard_databases(db: &Database, fact: &str, shards: usize) -> Result<Vec<Database>> {
    anyhow::ensure!(shards > 0, "shard count must be positive, got {shards}");
    let fact_rel =
        db.get(fact).with_context(|| format!("fact relation {fact:?} missing"))?;
    let mut out: Vec<Database> = (0..shards)
        .map(|_| {
            let mut sdb = Database::new();
            sdb.fds = db.fds.clone();
            for rel in db.relations() {
                if rel.name == fact {
                    sdb.add(Relation::new(fact, rel.schema.clone()));
                } else {
                    sdb.add(rel.clone());
                }
            }
            sdb
        })
        .collect();
    for row in 0..fact_rel.n_rows() {
        let w = fact_rel.weight(row);
        if w == 0.0 {
            continue;
        }
        let vals = fact_rel.row(row);
        let s = shard_of(&vals, shards);
        let target = out[s].get_mut(fact).expect("fact shard relation exists");
        if w == 1.0 {
            target.push_row(&vals);
        } else {
            target.push_row_weighted(&vals, w);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Attr, Schema};

    fn sample_db() -> Database {
        let mut fact = Relation::new(
            "fact",
            Schema::new(vec![Attr::cat("item", 8), Attr::double("units")]),
        );
        for i in 0..50u32 {
            fact.push_row(&[Value::Cat(i % 8), Value::Double((i % 5) as f64 * 0.5)]);
        }
        let mut items =
            Relation::new("items", Schema::new(vec![Attr::cat("item", 8), Attr::double("p")]));
        for i in 0..8u32 {
            items.push_row(&[Value::Cat(i), Value::Double(i as f64)]);
        }
        let mut db = Database::new();
        db.add(fact);
        db.add(items);
        db.add_fd("item", "p");
        db
    }

    #[test]
    fn shard_of_is_stable_and_in_range() {
        let vals = vec![Value::Cat(3), Value::Double(1.5), Value::Int(-7)];
        for s in [1usize, 2, 7, 16] {
            let first = shard_of(&vals, s);
            assert!(first < s);
            assert_eq!(first, shard_of(&vals, s), "hash must be deterministic");
        }
        assert_eq!(shard_of(&vals, 1), 0);
        // -0.0 and 0.0 are the same tuple value, hence the same shard.
        assert_eq!(
            shard_of(&[Value::Double(0.0)], 7),
            shard_of(&[Value::Double(-0.0)], 7)
        );
    }

    #[test]
    fn shards_partition_the_fact_and_replicate_dims() {
        let db = sample_db();
        for s in [1usize, 2, 5] {
            let shards = shard_databases(&db, "fact", s).unwrap();
            assert_eq!(shards.len(), s);
            let total: usize =
                shards.iter().map(|d| d.get("fact").unwrap().n_rows()).sum();
            assert_eq!(total, db.get("fact").unwrap().n_rows());
            for sdb in &shards {
                assert_eq!(sdb.get("items").unwrap().n_rows(), 8);
                assert_eq!(sdb.fds, db.fds);
            }
        }
    }

    #[test]
    fn duplicate_tuples_land_on_one_shard() {
        let mut db = sample_db();
        let dup = vec![Value::Cat(2), Value::Double(9.75)];
        for _ in 0..4 {
            db.get_mut("fact").unwrap().push_row(&dup);
        }
        let shards = shard_databases(&db, "fact", 3).unwrap();
        let holders: Vec<usize> = (0..3)
            .filter(|&s| {
                let rel = shards[s].get("fact").unwrap();
                (0..rel.n_rows()).any(|r| rel.row(r) == dup)
            })
            .collect();
        assert_eq!(holders.len(), 1, "all copies of a tuple share a shard");
        assert_eq!(holders[0], shard_of(&dup, 3));
    }

    #[test]
    fn tombstones_are_not_copied() {
        let mut db = sample_db();
        let victim = db.get("fact").unwrap().row(0);
        assert!(db.get_mut("fact").unwrap().retract_row(&victim, 1.0));
        let before = db.get("fact").unwrap().n_rows(); // storage keeps the tombstone
        let shards = shard_databases(&db, "fact", 2).unwrap();
        let total: usize = shards.iter().map(|d| d.get("fact").unwrap().n_rows()).sum();
        assert_eq!(total, before - 1);
    }

    #[test]
    fn missing_fact_or_zero_shards_error() {
        let db = sample_db();
        assert!(shard_databases(&db, "nope", 2).is_err());
        assert!(shard_databases(&db, "fact", 0).is_err());
    }
}
