//! Small self-contained utilities: a seedable RNG, Zipf sampling, timers,
//! a minimal JSON reader/writer (the environment is offline, so we avoid
//! external crates), a tiny property-testing harness, and the persistent
//! deterministic execution pool ([`exec`]) shared by the Step-4 engines.

pub mod det;
pub mod exec;
pub mod fx;
pub mod json;
pub mod rng;
pub mod testkit;
pub mod timer;

pub use exec::{shared_pool, ExecPool};
pub use fx::{FxHashMap, FxHashSet};
pub use rng::{SplitMix64, Zipf};
pub use timer::Stopwatch;

/// Format a byte count as a human-readable string (e.g. `1.50 GB`).
pub fn human_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format a large count with thousands separators plus an M/K suffix view,
/// e.g. `12_345_678 -> "12.35M"`.
pub fn human_count(n: u64) -> String {
    if n >= 1_000_000_000 {
        format!("{:.2}B", n as f64 / 1e9)
    } else if n >= 1_000_000 {
        format!("{:.2}M", n as f64 / 1e6)
    } else if n >= 10_000 {
        format!("{:.2}K", n as f64 / 1e3)
    } else {
        format!("{n}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_scales() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MB");
    }

    #[test]
    fn human_count_scales() {
        assert_eq!(human_count(950), "950");
        assert_eq!(human_count(12_345), "12.35K");
        assert_eq!(human_count(12_345_678), "12.35M");
        assert_eq!(human_count(2_500_000_000), "2.50B");
    }
}
