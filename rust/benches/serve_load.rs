//! Bench W3 — the serving tier (`rkmeans::serve`): micro-batched
//! assignment through the `ModelMesh`/`AssignFront` vs. the un-batched
//! one-`assign`-per-request loop, plus centroid-delta publication bytes
//! vs. full snapshots while the mesh is under load (hot swaps under
//! fire). Three arms:
//!
//! * `naive` — one thread calling [`RkModel::assign`] per request: the
//!   reference the `serve_qps_speedup` gate metric is relative to;
//! * `mesh`  — open-loop clients through the batching front over a
//!   replicated mesh on the shared pool (the acceptance arm);
//! * `delta` — the same load while a writer replays an incremental
//!   patch trace and publishes every version as a verified
//!   [`ModelDelta`]; cumulative delta vs. snapshot wire bytes become
//!   the `serve_delta_bytes_ratio` gate metric.
//!
//! Results are written as one `BENCH_serve.json` document (schema: see
//! `bench_harness` docs; path override: `RKMEANS_SERVE_OUT`).
//! Acceptance targets: mesh ≥ 2× naive QPS on the Retailer workload,
//! deltas ≤ 0.5× snapshot bytes (ratio ≥ 2×), and served versions
//! monotone under concurrent publication.
//!
//! `--test` (or `--smoke`) shrinks everything for CI smoke runs.
//! `RKMEANS_SERVE_SCALE` overrides the Retailer scale (default 0.1).
//!
//! [`RkModel::assign`]: rkmeans::rkmeans::RkModel::assign
//! [`ModelDelta`]: rkmeans::serve::ModelDelta

use rkmeans::bench_harness::{write_bench_serve, ServeBenchRecord};
use rkmeans::incremental::{apply_to_db, IncrementalEngine, PlannerOpts};
use rkmeans::metrics::Metrics;
use rkmeans::rkmeans::RkConfig;
use rkmeans::serve::{
    run_naive_loop, run_open_loop, synth_rows, AssignFront, FrontOpts, LoadSpec, ModelMesh,
    Publisher,
};
use rkmeans::synthetic::{retailer, retailer_trace, Scale, TraceSpec};
use rkmeans::util::exec::{resolve_threads, shared_pool};
use std::path::PathBuf;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let test_mode = std::env::args().any(|a| a == "--test" || a == "--smoke");
    let scale: f64 = std::env::var("RKMEANS_SERVE_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if test_mode { 0.02 } else { 0.1 });
    // Big enough k·κ that one factored assign outweighs per-request
    // queueing overhead — the regime the batching front is built for.
    let (k, kappa) = if test_mode { (32, 16) } else { (64, 32) };
    let naive_requests = if test_mode { 5_000 } else { 50_000 };
    let mesh_requests = if test_mode { 10_000 } else { 100_000 };
    let publishes = if test_mode { 3 } else { 5 };
    let clients = resolve_threads(0).clamp(2, 8);
    let replicas = 2;
    let batch = 64;
    let seed = 42u64;

    let mut db = retailer::generate(Scale::custom(scale), seed);
    let feq = retailer::feq();
    println!(
        "serve workload: |D|={} rows (scale {scale}), k={k} κ={kappa}, {clients} clients, \
         {replicas} replicas, batch ≤ {batch}",
        db.total_rows()
    );

    // Writer state: the incremental engine with the planner forced onto
    // the patch path, so published versions differ by moved centroid
    // rows only — the delta wire format's best (and intended) case.
    let lenient = PlannerOpts {
        drift_threshold: f64::INFINITY,
        max_patch_fraction: 1.0,
        max_join_churn: f64::INFINITY,
        ..PlannerOpts::default()
    };
    let metrics = Metrics::new();
    let rk = RkConfig::new(k).with_kappa(kappa).with_seed(seed);
    let mut engine = IncrementalEngine::new(&db, feq, rk, lenient, metrics.clone())?;
    let model = engine.model();
    let rows = synth_rows(&model, 512, 7);

    // Arm 1: the un-batched reference loop.
    let naive_report = run_naive_loop(&model, &rows, naive_requests);
    let naive_rec = ServeBenchRecord::from_load(
        "retailer",
        "naive",
        1,
        1,
        1,
        naive_report.requests,
        naive_report.qps,
        naive_report.p50_us,
        naive_report.p99_us,
    );
    println!("{}", naive_rec.line());

    // Arm 2: saturation through the micro-batching front.
    let mesh = ModelMesh::new(model, replicas, metrics.clone());
    let fopts = FrontOpts { max_batch: batch, threads: 0 };
    let front = AssignFront::start(Arc::clone(&mesh), fopts, shared_pool());
    let mesh_report = run_open_loop(&front, &rows, &LoadSpec::saturate(mesh_requests, clients));
    anyhow::ensure!(mesh_report.monotonic, "mesh arm served non-monotone versions");
    let mesh_rec = ServeBenchRecord::from_load(
        "retailer",
        "mesh",
        replicas,
        clients,
        batch,
        mesh_report.requests,
        mesh_report.qps,
        mesh_report.p50_us,
        mesh_report.p99_us,
    )
    .with_speedup_vs(&naive_rec);
    println!("{}", mesh_rec.line());

    // Arm 3: the same load while the writer patches and publishes —
    // every hot swap happens under live traffic.
    let trace = retailer_trace(&db, seed + 1, TraceSpec::new(publishes, 256));
    let mut publisher = Publisher::new(Arc::clone(&mesh));
    let writer = std::thread::spawn(move || -> anyhow::Result<(u64, u64)> {
        let (mut delta_b, mut snap_b) = (0u64, 0u64);
        for deltas in &trace {
            apply_to_db(&mut db, deltas)?;
            engine.apply_batch(&db, deltas)?;
            let stats = publisher.publish(&engine.model())?;
            delta_b += stats.delta_bytes as u64;
            snap_b += stats.snapshot_bytes as u64;
        }
        Ok((delta_b, snap_b))
    });
    let delta_report = run_open_loop(&front, &rows, &LoadSpec::saturate(mesh_requests, clients));
    let (delta_bytes, snapshot_bytes) = writer.join().expect("writer thread")?;
    front.shutdown();
    anyhow::ensure!(delta_report.monotonic, "delta arm served non-monotone versions");
    let delta_rec = ServeBenchRecord::from_load(
        "retailer",
        "delta",
        replicas,
        clients,
        batch,
        delta_report.requests,
        delta_report.qps,
        delta_report.p50_us,
        delta_report.p99_us,
    )
    .with_publish_bytes(delta_bytes, snapshot_bytes);
    println!("{}", delta_rec.line());

    let speedup = mesh_rec.speedup_vs_naive.unwrap_or(0.0);
    let ratio = delta_rec.delta_bytes_ratio.unwrap_or(0.0);
    let records = vec![naive_rec, mesh_rec, delta_rec];
    let out = PathBuf::from(
        std::env::var("RKMEANS_SERVE_OUT").unwrap_or_else(|_| "BENCH_serve.json".to_string()),
    );
    write_bench_serve(&out, &records)?;
    println!("wrote {} records to {}", records.len(), out.display());
    println!(
        "mesh vs naive: {speedup:.2}× QPS (acceptance target ≥ 2×); {publishes} publishes \
         shipped {delta_bytes} delta bytes vs {snapshot_bytes} snapshot bytes — {ratio:.1}× \
         smaller (acceptance target ≥ 2×, hot swaps monotone under load)"
    );
    Ok(())
}
