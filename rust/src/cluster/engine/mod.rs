//! The shared Step-4 execution engine: a blocked distance microkernel
//! (f64 and f32 tile paths), bounds pruning under a selectable policy
//! (Hamerly or Elkan), and a deterministic chunk-parallel executor — used
//! by both the dense ([`dense`]) and the factored ([`factored`])
//! weighted-Lloyd variants, and by the streaming full-objective scorer
//! ([`CentroidScorer`]).
//!
//! # Bounds invariants
//!
//! For every point `i` with current assignment `a(i)` the engine maintains
//! *Euclidean* (not squared) bounds:
//!
//! * the upper bound on `d(x_i, c_{a(i)})` is the *exact* assigned
//!   distance, recomputed at every pass (one distance evaluation per
//!   point). Because it is exact each pass it is never stored across
//!   iterations — this is also what keeps the reported objective exact
//!   rather than bounded, and what makes pruned output bitwise-equal to
//!   naive output.
//! * lower bounds, per the [`BoundsPolicy`]:
//!   * **Hamerly** ("Making k-means even faster", 2010):
//!     `lb[i] ≤ min_{c ≠ a(i)} d(x_i, c)` — a single global lower bound
//!     on the distance to the *second-closest* centroid. After every
//!     update it is drifted by the maximum movement: `lb -= max_c p[c]`.
//!   * **Elkan** ("Using the triangle inequality to accelerate k-means",
//!     2003): `lb[i·k + c] ≤ d(x_i, c)` — one lower bound per
//!     (point, centroid), each drifted by *its own* centroid's movement:
//!     `lb[i·k + c] -= p[c]`. O(n·k) memory; a full scan resets the whole
//!     row to the exact distances, and the Phase-1 test uses
//!     `min_{c ≠ a(i)} lb[i·k + c]`, which stays far tighter than the
//!     Hamerly bound at large k where `max_c p[c]` is dominated by a few
//!     still-moving centroids.
//! * `p[c] = ‖c_new − c_old‖` — per-centroid drift. The dense engine takes
//!   it from the raw coordinates; the factored engine computes it from the
//!   per-subspace β coefficient tables using component orthogonality
//!   (`‖Δμ_j‖² = Σ_a Δβ_a²·‖u_a‖²`), so it never densifies a centroid.
//! * `s[c] = ½·min_{c' ≠ c} d(c, c')` — half the distance to the nearest
//!   other centroid (recomputed each iteration).
//!
//! With `ub` exact, the engine skips the inner k-loop whenever
//!
//! ```text
//!   d(x_i, c_{a(i)}) + slack < max(lb_i, s[a(i)])
//! ```
//!
//! (`lb_i` being the policy's point-level lower bound on the second-best
//! distance), which by the triangle inequality proves no other centroid
//! can be strictly closer. The `slack` term (a small multiple of the data
//! scale, [`SLACK_REL`]) absorbs floating-point rounding in the bound
//! chain so that a skipped point provably agrees with what a full scan
//! would have chosen — including tie-breaking, because ties never satisfy
//! the strict inequality and therefore always rescan.
//!
//! # Choosing a bounds policy and a precision
//!
//! The two engine axes compose freely (Hamerly/Elkan × f64/f32) and are
//! selected via [`EngineOpts::bounds`] / [`EngineOpts::precision`]:
//!
//! | | **Hamerly** | **Elkan** |
//! |---|---|---|
//! | bounds memory | O(n) | O(n·k) |
//! | Phase-1 cost per point | O(1) | O(k) (drift + row min) |
//! | scan cost | k distances | k distances + k √ (bound refresh) |
//! | wins when | k ≲ 64, or memory-tight | k ≳ 64 ([`ELKAN_AUTO_K`]), stable assignments, few fast-moving centroids |
//! | output | bitwise = naive | bitwise = naive |
//!
//! [`BoundsPolicy::Auto`] (the default) picks Elkan at k ≥
//! [`ELKAN_AUTO_K`] and Hamerly below; both policies keep the determinism
//! contract, so switching never changes results, only throughput.
//!
//! [`Precision::F32`] runs the distance kernels in f32 (double the SIMD
//! lanes of the `‖x‖² − 2·x·c + ‖c‖²` contraction) while keeping the
//! objective and the centroid-update sums in f64, mirroring the XLA f32
//! artifact's tolerance story: on well-scaled inputs the final objective
//! agrees with the f64 path within [`F32_OBJ_RTOL`] (relative), and the
//! determinism contract holds *within* the precision — f32
//! pruned-parallel is bitwise-identical to f32 naive-serial. Use f32 when
//! distances have head-room (|values| ≲ 10³ and relative objective error
//! of ~1e-3 is acceptable); stay on f64 for bitwise reproducibility
//! against archived results or ill-scaled data.
//!
//! # Execution model
//!
//! Parallel work is dispatched through an [`Executor`]:
//!
//! * [`Executor::Pool`] (the default, via the process-wide
//!   [`crate::util::exec::shared_pool`]) hands each pass's chunk list to a
//!   **persistent** worker pool ([`crate::util::exec::ExecPool`]). The
//!   pool is created once and shared by the dense engine, the factored
//!   engine, the streaming [`CentroidScorer`] and the coordinator worker,
//!   so the per-iteration thread spawn/join cost of the scoped executor
//!   (tens of µs) disappears — a real win in the small-`|G|`,
//!   many-iteration and streaming-patch regimes. Concurrent jobs
//!   serialize on the pool, which doubles as oversubscription control.
//! * [`Executor::Scoped`] is the retained PR-1 reference: scoped
//!   `std::thread` workers spawned per dispatch.
//!
//! Both executors use the identical work-distribution discipline (an
//! atomic cursor over fixed [`CHUNK`]-sized ranges, items mutated in
//! place, accumulators reduced in chunk order on the coordinating
//! thread), so pooled, scoped and serial dispatches are **bitwise
//! identical** — the executor only changes *who* computes a chunk, never
//! the arithmetic. [`EngineOpts::threads`] clamps the number of *active*
//! pool workers per job without resizing the pool;
//! [`PruneStats::executor`] / [`PruneStats::pool_dispatches`] report what
//! actually ran.
//!
//! Construction work rides the same pool. Sharded Step 1–3 builds
//! ([`crate::rkmeans::RkPipeline::coreset_sharded`],
//! [`crate::incremental::ShardedDeltaFaq`]) submit one counting-FAQ job
//! per value-hashed fact shard ([`crate::faq::shard`]) through
//! [`crate::util::exec::ExecPool::run_chunks_ordered`] — a size-graded
//! (largest-shard-first) claim order under the same atomic-cursor
//! protocol, so the long pole starts first while results are still read
//! back in shard order and merged by exact ring-ℤ addition:
//! bitwise-identical to the serial build, just off the serial path. The
//! streaming [`CentroidScorer`] overlaps in the other direction: full row
//! blocks are handed to a dedicated ingestion worker that scores them on
//! the pool while the caller streams (and embeds) the next block, with at
//! most one block in flight and partial objectives folded in submission
//! order — double-buffering that hides embed/stream time behind kernel
//! time without touching the reduction order.
//!
//! # Cross-run state carry
//!
//! A run's convergence context — final assignments and lower bounds — is
//! returned as a first-class [`EngineState`] artifact by the `*_resume`
//! entry points ([`dense::lloyd_dense_resume`],
//! [`factored::lloyd_factored_resume`]) and accepted back on the next
//! run, so a warm start no longer rebuilds its bounds with a full first
//! scan. Validity rules:
//!
//! * the state is tagged with a **hash of the centroids** it was captured
//!   against; resuming against any other starting centroids is a caller
//!   bug and panics loudly (stale state must never silently corrupt
//!   bounds). Resume therefore only composes with a warm start from the
//!   exact previous centroids.
//! * the captured bounds are pre-drifted by the final update's centroid
//!   movement, so they are valid lower bounds **for the final centroids**
//!   and iteration 0 of the resumed run can use them with zero drift.
//! * a state whose run ended in an empty-cluster reseed is captured with
//!   `bounds_valid = false` and resumes like a cold warm start (bounds
//!   rebuilt by the first full scan).
//! * a resolved bounds-policy or precision mismatch (configuration
//!   changed between runs) silently degrades to the cold warm start —
//!   the state is a pure throughput artifact, never a correctness input.
//! * grid edits between runs are patched in with [`EngineState::splice`]:
//!   cells removed by a patch drop their entries, inserted cells get a
//!   `-∞` bounds row (never skippable by the lb test, hence re-scanned or
//!   proven by the assignment-independent separation test), and
//!   weight-only changes need no invalidation at all — assignments and
//!   bounds do not depend on weights. This is what makes the incremental
//!   planner's patch cost `O(b + changed cells)` instead of a full first
//!   scan.
//!
//! Because every skipped point provably stores the same bits a full scan
//! would have produced, a resumed run is **bitwise identical** to the
//! equivalent cold warm start (within a precision) — pinned by
//! `tests/property_engine.rs` for both engines and both bounds policies.
//!
//! # Determinism contract
//!
//! Results are **bitwise identical** for any thread count, for either
//! executor, and for the pruned vs. naive paths:
//!
//! * Points are partitioned into fixed [`CHUNK`]-sized ranges independent
//!   of the thread count; each chunk accumulates its own `sums`/`mass`/
//!   `obj` in point order, and chunk accumulators are reduced left-to-right
//!   on the coordinating thread (a fixed-shape tree reduction). The thread
//!   pool only changes *who* computes a chunk, never the arithmetic.
//! * Pruned and full-scan paths compute distances with the same
//!   accumulation order (see [`microkernel`]), so a pruned iteration
//!   produces the same `assign`/`mind2` bits as a naive one. The
//!   `tests/property_engine.rs` suite asserts exact equality of
//!   assignments, centroids and objectives across (naive serial) ×
//!   (pruned parallel) × (scoped / pooled) on seeded random inputs, dense
//!   and factored.
//!
//! The contract is validated—not just assumed—because the FP-slack
//! argument above is only rigorous for data whose dynamic range is sane
//! (|values| ≪ 1/√ε·distances); pathological inputs would merely prune
//! less, never corrupt bounds in the unsafe direction.
//!
//! # Shared scaffolding and warm starts
//!
//! The variant-independent pieces — the Phase-1 bounds test, the ordered
//! Phase-3 accumulation, the empty-cluster reseed picker, the separation
//! table, chunk-stat reduction and the convergence test — live once in
//! [`core`] and are parameterized over a distance provider (a closure
//! computing the exact assigned distance) and a per-point accumulator
//! callback, so bounds-logic fixes land in both engines simultaneously.
//! Both variants also expose `*_init` entry points
//! ([`dense::lloyd_dense_init`], [`factored::lloyd_factored_init`]) that
//! accept a warm start — previous centroids seeding the run in place of
//! k-means++ — which the incremental planner
//! ([`crate::incremental::planner`]) uses to re-cluster a delta-patched
//! grid in a couple of iterations.

pub(crate) mod core;
pub mod dense;
pub mod factored;
pub(crate) mod microkernel;

use crate::cluster::sparse_lloyd::CentroidCoord;
use crate::util::exec::{self, ExecPool};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Fixed parallel work-unit size (points per chunk). Part of the
/// determinism contract: reductions happen per chunk and then in chunk
/// order, so results do not depend on the thread count. Inputs smaller
/// than one chunk take a purely serial path.
pub const CHUNK: usize = 4096;

/// Relative slack applied to the skip test to absorb rounding in the
/// bound chain (see the module docs). Chosen ≫ accumulated f64 rounding
/// (~1e-13·scale over a Lloyd run) and ≪ any real cluster separation, so
/// it costs essentially no pruning.
pub(crate) const SLACK_REL: f64 = 1e-6;

/// The f32-path analog of [`SLACK_REL`]: f32 kernels round at ~1e-7
/// relative per operation and the `‖x‖² − 2·x·c + ‖c‖²` expansion
/// cancels, so the skip slack must be correspondingly wider for a skipped
/// point to provably agree with an f32 full scan.
pub(crate) const SLACK_REL_F32: f64 = 1e-3;

/// `Auto` bounds-policy crossover: below this k the O(k) per-point
/// Phase-1 bookkeeping of Elkan outweighs its tighter bounds; above it
/// the saved full scans dominate (see the module-level decision table).
pub const ELKAN_AUTO_K: usize = 64;

/// Documented tolerance contract of the f32 tile path: on well-scaled
/// inputs (|values| ≲ 10³, genuine cluster structure) the final objective
/// of a [`Precision::F32`] run agrees with the f64 run within this
/// *relative* tolerance. `tests/property_engine.rs` pins it on the
/// synthetic Retailer/Favorita workloads.
pub const F32_OBJ_RTOL: f64 = 1e-3;

/// Which lower-bound family the pruned engine maintains. Both policies
/// produce **bitwise-identical** results to the naive reference (the
/// determinism contract); they differ only in how much Phase-2 scan work
/// the Phase-1 test proves away, and at what bookkeeping cost. See the
/// module-level decision table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BoundsPolicy {
    /// Resolve per run: [`Elkan`](BoundsPolicy::Elkan) at
    /// k ≥ [`ELKAN_AUTO_K`], [`Hamerly`](BoundsPolicy::Hamerly) below.
    Auto,
    /// One global second-best lower bound per point, drifted by the
    /// maximum centroid movement. O(n) memory, O(1) per-point Phase 1.
    Hamerly,
    /// Per-(point, centroid) lower bounds, each drifted by its own
    /// centroid's movement. O(n·k) memory, O(k) per-point Phase 1, much
    /// tighter at large k.
    Elkan,
}

impl BoundsPolicy {
    /// Resolve [`Auto`](BoundsPolicy::Auto) against the run's k; the
    /// engines call this once per run, so `Auto` never reaches the
    /// per-pass machinery.
    pub fn resolve(self, k: usize) -> BoundsPolicy {
        match self {
            BoundsPolicy::Auto => {
                if k >= ELKAN_AUTO_K {
                    BoundsPolicy::Elkan
                } else {
                    BoundsPolicy::Hamerly
                }
            }
            other => other,
        }
    }

    /// Stable label for stats and bench records.
    pub fn label(self) -> &'static str {
        match self {
            BoundsPolicy::Auto => "auto",
            BoundsPolicy::Hamerly => "hamerly",
            BoundsPolicy::Elkan => "elkan",
        }
    }
}

/// Distance-kernel precision (see the module-level decision table).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    /// f64 kernels throughout; bitwise-reproducible against archived
    /// results.
    F64,
    /// f32 kernels (2× SIMD lanes) with f64 accumulation for the
    /// objective and the centroid-update sums. Results carry f32 rounding
    /// ([`F32_OBJ_RTOL`]); the determinism contract holds *within* the
    /// f32 path.
    F32,
}

impl Precision {
    /// Stable label for stats and bench records.
    pub fn label(self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::F32 => "f32",
        }
    }
}

/// How parallel chunk dispatches execute (see the module-level "Execution
/// model" section). Both executors are bitwise-identical; they differ
/// only in per-dispatch overhead.
#[derive(Clone)]
pub enum Executor {
    /// Scoped `std::thread` workers spawned per dispatch — the retained
    /// PR-1 reference executor.
    Scoped,
    /// A persistent worker pool; dispatches reuse its threads.
    Pool(Arc<ExecPool>),
}

impl Executor {
    /// The production executor: the process-wide shared pool.
    pub fn shared() -> Executor {
        Executor::Pool(exec::shared_pool())
    }

    /// Stable label for stats and bench records.
    pub fn label(&self) -> &'static str {
        match self {
            Executor::Scoped => "scoped",
            Executor::Pool(_) => "pool",
        }
    }

    /// Run `f(i, &mut works[i])` for every work item over at most
    /// `threads` workers; returns `true` when the job was dispatched to a
    /// pool in parallel (the `PruneStats::pool_dispatches` feed).
    pub(crate) fn run_chunks<W, F>(&self, works: &mut [W], threads: usize, f: F) -> bool
    where
        W: Send,
        F: Fn(usize, &mut W) + Sync,
    {
        match self {
            Executor::Scoped => {
                run_chunks(works, threads, f);
                false
            }
            Executor::Pool(pool) => pool.run_chunks(works, threads, f),
        }
    }
}

impl std::fmt::Debug for Executor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Executor::Scoped => f.write_str("Scoped"),
            Executor::Pool(p) => write!(f, "Pool(threads={})", p.threads()),
        }
    }
}

/// Pool-free executor selector for lightweight configurations
/// ([`crate::rkmeans::RkConfig`], the CLI): resolved to an [`Executor`]
/// at engine-options build time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecutorKind {
    /// The shared persistent pool (production default).
    Pool,
    /// Scoped spawn per dispatch (reference / ablation arm).
    Scoped,
}

impl ExecutorKind {
    /// Resolve to a concrete executor.
    pub fn executor(self) -> Executor {
        match self {
            ExecutorKind::Pool => Executor::shared(),
            ExecutorKind::Scoped => Executor::Scoped,
        }
    }
}

/// Engine execution options shared by the dense and factored paths.
#[derive(Clone, Debug)]
pub struct EngineOpts {
    /// Maintain bounds and skip provably-unchanged assignments.
    pub pruning: bool,
    /// Worker threads; `0` = auto (`RKMEANS_THREADS` env var, else the
    /// machine's available parallelism). On a pool executor this clamps
    /// the *active* workers per dispatch without resizing the pool.
    pub threads: usize,
    /// Lower-bound policy for the pruned path ([`BoundsPolicy::Auto`]
    /// resolves against the run's k).
    pub bounds: BoundsPolicy,
    /// Distance-kernel precision.
    pub precision: Precision,
    /// Parallel-dispatch executor (see the module-level "Execution
    /// model"). Never changes results, only dispatch overhead.
    pub executor: Executor,
}

impl Default for EngineOpts {
    fn default() -> Self {
        EngineOpts::pruned()
    }
}

impl EngineOpts {
    /// The production configuration: bounds pruning (auto policy) + auto
    /// parallelism on the shared persistent pool, f64 kernels.
    pub fn pruned() -> Self {
        EngineOpts {
            pruning: true,
            threads: 0,
            bounds: BoundsPolicy::Auto,
            precision: Precision::F64,
            executor: Executor::shared(),
        }
    }

    /// The retained reference: full scans, single thread, scoped
    /// executor. The property suite pins the pruned/parallel paths to
    /// this bit-for-bit (within a precision).
    pub fn naive_serial() -> Self {
        EngineOpts {
            pruning: false,
            threads: 1,
            bounds: BoundsPolicy::Auto,
            precision: Precision::F64,
            executor: Executor::Scoped,
        }
    }

    /// Override the worker-thread count.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Override the bounds policy.
    pub fn with_bounds(mut self, bounds: BoundsPolicy) -> Self {
        self.bounds = bounds;
        self
    }

    /// Override the distance-kernel precision.
    pub fn with_precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }

    /// Override the parallel-dispatch executor.
    pub fn with_executor(mut self, executor: Executor) -> Self {
        self.executor = executor;
        self
    }
}

/// One structural edit to a run's point list (the incremental planner's
/// grid patch): apply to a carried [`EngineState`] via
/// [`EngineState::splice`] **in the order the edits were performed**, so
/// positions stay aligned with the patched grid.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StateSplice {
    /// A cell was inserted at this position (position valid at the time
    /// of the edit).
    Insert(usize),
    /// The cell at this position was removed (position valid at the time
    /// of the edit).
    Remove(usize),
}

/// Carryable end-of-run convergence context: the final assignments and
/// (pre-drifted) lower bounds of a Lloyd run, tagged with everything
/// needed to check they are still valid — see the module-level
/// "Cross-run state carry" section for the validity rules. Produced and
/// consumed by the `*_resume` engine entry points; pure throughput
/// artifact (a resumed run is bitwise-identical to the cold warm start).
#[derive(Clone, Debug)]
pub struct EngineState {
    /// Final cluster per point/cell.
    assign: Vec<u32>,
    /// Lower bounds, already drifted to the final centroids: one entry
    /// per point (Hamerly) or a k-stride row per point (Elkan).
    lb: Vec<f64>,
    /// Resolved bounds policy the `lb` layout follows (never `Auto`).
    bounds: BoundsPolicy,
    /// Kernel precision the bounds were computed under.
    precision: Precision,
    /// False when the run ended in an empty-cluster reseed (bounds were
    /// invalidated); resuming then degrades to a cold warm start.
    bounds_valid: bool,
    /// Hash of the final centroids ([`EngineState::hash_dense`] /
    /// [`EngineState::hash_factored`]); resume validates the starting
    /// centroids against it.
    centroid_hash: u64,
    /// k the run resolved to (the Elkan row stride).
    k: usize,
}

impl EngineState {
    /// Number of points/cells the state covers.
    pub fn n(&self) -> usize {
        self.assign.len()
    }

    /// k the state was captured at.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Resolved bounds policy of the captured bounds.
    pub fn bounds(&self) -> BoundsPolicy {
        self.bounds
    }

    /// Kernel precision of the captured bounds.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// True when the bounds survived the run (no trailing reseed).
    pub fn bounds_valid(&self) -> bool {
        self.bounds_valid
    }

    /// Hash of the centroids this state is valid against.
    pub fn centroid_hash(&self) -> u64 {
        self.centroid_hash
    }

    /// Consume this state at the start of a run (shared by both engine
    /// variants): panics when the state is stale — captured against a
    /// different centroid hash or shape than the run starts from — and
    /// otherwise copies the carried assignments/bounds into the run
    /// arrays when they are usable (bounds valid, same resolved policy
    /// and precision). Returns whether the bounds were installed.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn resume_into(
        &self,
        start_hash: u64,
        k: usize,
        opts: &EngineOpts,
        bounds: BoundsPolicy,
        assign: &mut [u32],
        lb: &mut [f64],
        unit: &str,
    ) -> bool {
        let n = assign.len();
        assert!(
            self.centroid_hash == start_hash && self.n() == n && self.k == k,
            "stale EngineState: resume requires the exact centroids and shape the state was \
             captured against (state: {} {unit}, k={}, hash {:#018x}; run: {n} {unit}, k={k}, \
             hash {:#018x})",
            self.n(),
            self.k,
            self.centroid_hash,
            start_hash,
        );
        if opts.pruning
            && self.bounds_valid
            && self.bounds == bounds
            && self.precision == opts.precision
        {
            assign.copy_from_slice(&self.assign);
            lb.copy_from_slice(&self.lb);
            true
        } else {
            false
        }
    }

    /// Capture the end-of-run state (shared by both engine variants).
    /// The run loop leaves `lb` valid for the last pass's pre-update
    /// centroids; when the bounds survived, this drifts them once more by
    /// the final update's movement so they are valid for the *final*
    /// centroids and the resumed run starts with zero drift (see the
    /// module-level "Cross-run state carry" docs).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn capture(
        assign: Vec<u32>,
        mut lb: Vec<f64>,
        bounds: BoundsPolicy,
        precision: Precision,
        bounds_valid: bool,
        drift: &[f64],
        k: usize,
        centroid_hash: u64,
    ) -> EngineState {
        if bounds_valid {
            match bounds {
                BoundsPolicy::Elkan => {
                    for row in lb.chunks_mut(k) {
                        for (b, &p) in row.iter_mut().zip(drift) {
                            *b -= p;
                        }
                    }
                }
                _ => {
                    let dm = drift.iter().cloned().fold(0.0f64, f64::max);
                    for b in lb.iter_mut() {
                        *b -= dm;
                    }
                }
            }
        }
        EngineState { assign, lb, bounds, precision, bounds_valid, centroid_hash, k }
    }

    /// Entries of `lb` per point — derived from the actual array shapes
    /// (k for a pruned Elkan state, 1 otherwise; a non-pruned run captures
    /// a 1-stride `lb` even when the resolved policy label says Elkan).
    fn lb_stride(&self) -> usize {
        if self.assign.is_empty() {
            1
        } else {
            (self.lb.len() / self.assign.len()).max(1)
        }
    }

    /// Patch the state across a structural grid edit (see
    /// [`StateSplice`]): removed cells drop their entries, inserted cells
    /// get assignment 0 with a `-∞` bounds row — never skippable by the
    /// lb test, so they are re-scanned (or proven closest by the
    /// separation test, which is valid for *any* tentative assignment).
    /// Weight-only cell changes need no splice: assignments and bounds do
    /// not depend on weights.
    pub fn splice(&mut self, edits: &[StateSplice]) {
        let stride = self.lb_stride();
        for e in edits {
            match *e {
                StateSplice::Insert(pos) => {
                    self.assign.insert(pos, 0);
                    // One splice per row: a per-element `insert` would
                    // memmove the tail `stride` times (O(n·k²) per cell
                    // at Elkan stride).
                    self.lb.splice(
                        pos * stride..pos * stride,
                        std::iter::repeat(f64::NEG_INFINITY).take(stride),
                    );
                }
                StateSplice::Remove(pos) => {
                    self.assign.remove(pos);
                    self.lb.drain(pos * stride..(pos + 1) * stride);
                }
            }
        }
    }

    /// FNV-1a-style hash over the bit patterns of dense `k × d` row-major
    /// centroids.
    pub fn hash_dense(centroids: &[f64]) -> u64 {
        let mut h = HASH_SEED;
        for &v in centroids {
            h = hash_mix(h, v.to_bits());
        }
        h
    }

    /// Hash over factored centroids (coordinate kinds, β lengths and bit
    /// patterns all participate).
    pub fn hash_factored(centroids: &[Vec<CentroidCoord>]) -> u64 {
        let mut h = HASH_SEED;
        h = hash_mix(h, centroids.len() as u64);
        for cent in centroids {
            h = hash_mix(h, cent.len() as u64);
            for coord in cent {
                match coord {
                    CentroidCoord::Continuous(x) => {
                        h = hash_mix(h, 1);
                        h = hash_mix(h, x.to_bits());
                    }
                    CentroidCoord::Categorical(beta) => {
                        h = hash_mix(h, 2);
                        h = hash_mix(h, beta.len() as u64);
                        for &b in beta {
                            h = hash_mix(h, b.to_bits());
                        }
                    }
                }
            }
        }
        h
    }
}

const HASH_SEED: u64 = 0xcbf2_9ce4_8422_2325;

#[inline]
fn hash_mix(h: u64, x: u64) -> u64 {
    // FNV-1a over the 8 bytes of `x`, folded into `h`.
    let mut h = h;
    let mut x = x;
    for _ in 0..8 {
        h = (h ^ (x & 0xff)).wrapping_mul(0x0000_0100_0000_01b3);
        x >>= 8;
    }
    h
}

/// Work counters for one Lloyd run (the bench-trajectory payload of
/// `BENCH_lloyd.json`; see `bench_harness` for the serialized schema).
#[derive(Clone, Debug)]
pub struct PruneStats {
    /// Lloyd iterations executed.
    pub iters: usize,
    /// Points (or grid cells) per iteration.
    pub points: u64,
    /// (point, centroid) distance evaluations actually performed.
    pub dist_evals: u64,
    /// Evaluations proven unnecessary by the bounds and skipped.
    pub dist_evals_skipped: u64,
    /// Lower-bound comparisons charged to the pruning machinery: one per
    /// point per bounded pass (the Phase-1 global test), plus — on the
    /// factored Elkan path — `k − 1` per *scanned* point for the
    /// within-scan per-centroid tests (`lb[i·k+c] > ub + slack`) that
    /// skip individual centroids inside the m-lookup loop. Bound tests
    /// are O(1) compares, not distance kernels, so this is the
    /// bookkeeping overhead bought in exchange for `dist_evals_skipped`.
    pub bound_evals: u64,
    /// Resolved bounds policy of the run (`"hamerly"` / `"elkan"`;
    /// `"none"` when pruning was disabled).
    pub bounds: &'static str,
    /// Distance-kernel precision of the run (`"f64"` / `"f32"`).
    pub precision: &'static str,
    /// Executor the run was configured with (`"pool"` / `"scoped"`;
    /// `"none"` when no engine ran).
    pub executor: &'static str,
    /// Parallel pool dispatches the run performed (0 on the scoped
    /// executor and on serial fast-path passes).
    pub pool_dispatches: u64,
    /// Wall time of the whole run (seeding + all iterations).
    pub wall: Duration,
}

impl Default for PruneStats {
    /// Zero counters with the label contract intact: a run that never
    /// touched the engine reports `bounds = "none"`, `precision = "f64"`
    /// (never empty strings).
    fn default() -> Self {
        PruneStats {
            iters: 0,
            points: 0,
            dist_evals: 0,
            dist_evals_skipped: 0,
            bound_evals: 0,
            bounds: "none",
            precision: "f64",
            executor: "none",
            pool_dispatches: 0,
            wall: Duration::default(),
        }
    }
}

impl PruneStats {
    /// Fraction of candidate evaluations skipped.
    pub fn skip_rate(&self) -> f64 {
        let total = self.dist_evals + self.dist_evals_skipped;
        if total == 0 {
            0.0
        } else {
            self.dist_evals_skipped as f64 / total as f64
        }
    }

    /// Assignment throughput: points × iterations / wall seconds.
    pub fn points_per_sec(&self) -> f64 {
        let s = self.wall.as_secs_f64();
        if s <= 0.0 {
            0.0
        } else {
            (self.points * self.iters as u64) as f64 / s
        }
    }
}

/// Resolve the worker-thread count (0 = auto); see
/// [`crate::util::exec::resolve_threads`].
pub(crate) fn resolve_threads(requested: usize) -> usize {
    exec::resolve_threads(requested)
}

/// The scoped-spawn executor ([`Executor::Scoped`]): run
/// `f(chunk_index, &mut work)` once for every work item, spreading the
/// items over `threads` scoped workers via an atomic cursor. Items are
/// mutated in place, so the caller reads results back in chunk order —
/// scheduling never affects the output (see the determinism contract).
/// Retained as the per-dispatch reference the persistent pool is pinned
/// against.
pub(crate) fn run_chunks<W, F>(works: &mut [W], threads: usize, f: F)
where
    W: Send,
    F: Fn(usize, &mut W) + Sync,
{
    let t = threads.max(1).min(works.len());
    if t <= 1 {
        for (i, w) in works.iter_mut().enumerate() {
            f(i, w);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    let cells: Vec<Mutex<&mut W>> = works.iter_mut().map(Mutex::new).collect();
    std::thread::scope(|scope| {
        for _ in 0..t {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= cells.len() {
                    break;
                }
                // Each index is claimed exactly once, so the lock is
                // uncontended; it only exists to hand &mut across threads.
                let mut guard = cells[i].lock().expect("chunk lock");
                f(i, &mut **guard);
            });
        }
    });
}

/// Streaming scorer for fixed dense centroids: feed `(row, weight)` pairs,
/// get `Σ w·min_c d²(row, c)` back. Rows are buffered into a block of
/// contiguous tiles and pushed through the shared microkernel (f64 or the
/// f32 tile path, per [`Precision`]), so the streaming full-`X` objective
/// pass reuses the same hot loop as the Lloyd engine.
///
/// Ingestion is **double-buffered**: when a block fills, it is handed to
/// a lazily-spawned ingestion worker that scores it on the configured
/// executor (the shared persistent pool by default — override with
/// [`CentroidScorer::with_executor`]) while the caller keeps streaming
/// rows into a second buffer, so embed/stream time overlaps kernel time
/// on the full-`X` pass. At most one block is ever in flight and the
/// running objective is threaded through the jobs in submission order —
/// one partial objective per tile, reduced in tile order, then folded
/// into the running sum exactly as an inline flush would — so the result
/// is **bitwise identical** to synchronous scoring, independent of the
/// executor and thread count. The f32 path follows the engine's
/// [`F32_OBJ_RTOL`] tolerance contract (f32 distances, f64 weight
/// accumulation).
pub struct CentroidScorer {
    /// Read-only scoring context, shared with the ingestion worker.
    ctx: Arc<ScoreCtx>,
    /// Front buffer: the block currently being filled by `push`.
    block: ScoreBlock,
    /// Buffers reclaimed from the last finished job, reused for the next
    /// swap (steady state allocates nothing).
    spare: Option<ScoreBlock>,
    /// Running objective; while a job is in flight this holds the value
    /// *before* that block (the job returns the folded-forward sum).
    obj: f64,
    worker: Option<ScoreWorker>,
}

/// The immutable inputs of a block score: dimensions, transposed
/// centroids and the dispatch configuration. Exactly one of the f64/f32
/// vector pairs is populated, matching `precision`.
#[derive(Clone)]
struct ScoreCtx {
    d: usize,
    k: usize,
    precision: Precision,
    /// `d × k` transposed centroids (microkernel layout).
    ct_t: Vec<f64>,
    cnorm: Vec<f64>,
    ct_t32: Vec<f32>,
    cnorm32: Vec<f32>,
    executor: Executor,
    threads: usize,
}

/// One block's traveling buffer set: row/weight storage plus the
/// per-tile work items its score dispatch uses. Two of these alternate
/// between the caller and the ingestion worker.
struct ScoreBlock {
    /// Block row buffer (`SCORE_BLOCK × d`), in the kernel's precision.
    rows: Vec<f64>,
    rows32: Vec<f32>,
    wbuf: Vec<f64>,
    fill: usize,
    /// Per-tile work items (partial objective + reusable kernel
    /// scratch); allocated on the first flush, reused thereafter.
    tiles: Vec<ScoreTile>,
}

impl ScoreBlock {
    fn fresh(d: usize, f32_kernel: bool) -> ScoreBlock {
        ScoreBlock {
            rows: if f32_kernel { Vec::new() } else { vec![0.0; SCORE_BLOCK * d] },
            rows32: if f32_kernel { vec![0.0; SCORE_BLOCK * d] } else { Vec::new() },
            wbuf: vec![0.0; SCORE_BLOCK],
            fill: 0,
            tiles: Vec::new(),
        }
    }
}

/// The lazily-spawned ingestion worker: one job (running objective +
/// block) in flight at a time, buffers round-tripped for reuse.
struct ScoreWorker {
    job_tx: std::sync::mpsc::Sender<(f64, ScoreBlock)>,
    done_rx: std::sync::mpsc::Receiver<(f64, ScoreBlock)>,
    handle: std::thread::JoinHandle<()>,
    in_flight: bool,
}

impl ScoreWorker {
    /// Spawn the ingestion thread. It is an ordinary (non-pool) thread,
    /// so its block scores may dispatch onto the shared pool without
    /// violating the pool's no-reentrancy rule; it exits when the job
    /// channel closes (scorer finished or dropped mid-stream).
    fn spawn(ctx: Arc<ScoreCtx>) -> ScoreWorker {
        let (job_tx, job_rx) = std::sync::mpsc::channel::<(f64, ScoreBlock)>();
        let (done_tx, done_rx) = std::sync::mpsc::channel();
        let handle = std::thread::Builder::new()
            .name("rk-score-ingest".into())
            .spawn(move || {
                while let Ok((obj, mut block)) = job_rx.recv() {
                    let obj = score_block(&ctx, &mut block, obj);
                    if done_tx.send((obj, block)).is_err() {
                        break; // receiver dropped mid-stream
                    }
                }
            })
            .expect("spawn scorer ingestion worker");
        ScoreWorker { job_tx, done_rx, handle, in_flight: false }
    }
}

/// One tile's pooled work item: the partial objective it produced plus
/// its reusable `dots` scratch (exactly one of the two matches the
/// scorer's precision).
#[derive(Default)]
struct ScoreTile {
    out: f64,
    dots: Vec<f64>,
    dots32: Vec<f32>,
}

/// Rows per scoring tile (the microkernel work unit).
const SCORE_TILE: usize = 32;
/// Rows buffered per pooled block flush (a multiple of [`SCORE_TILE`]).
const SCORE_BLOCK: usize = SCORE_TILE * 64;

impl CentroidScorer {
    /// Build an f64 scorer over row-major `k × d` centroids.
    pub fn new(centroids: &[f64], d: usize) -> Self {
        CentroidScorer::new_with(centroids, d, Precision::F64)
    }

    /// Build a scorer with an explicit kernel precision.
    /// [`Precision::F32`] doubles the SIMD lanes of the distance
    /// contraction under the [`F32_OBJ_RTOL`] tolerance contract.
    pub fn new_with(centroids: &[f64], d: usize, precision: Precision) -> Self {
        assert!(d > 0, "dimension must be positive");
        assert_eq!(centroids.len() % d, 0, "centroids not a multiple of d");
        let k = centroids.len() / d;
        assert!(k > 0, "need at least one centroid");
        let f32_kernel = precision == Precision::F32;
        let mut ct_t = Vec::new();
        let mut ct_t32 = Vec::new();
        let mut cnorm = Vec::new();
        let mut cnorm32 = Vec::new();
        if f32_kernel {
            microkernel::transpose_f32(centroids, d, k, &mut ct_t32);
            cnorm32 = centroids
                .chunks_exact(d)
                .map(|c| c.iter().map(|&v| (v as f32) * (v as f32)).sum())
                .collect();
        } else {
            microkernel::transpose(centroids, d, k, &mut ct_t);
            cnorm = centroids.chunks_exact(d).map(|c| c.iter().map(|v| v * v).sum()).collect();
        }
        let ctx = ScoreCtx {
            d,
            k,
            precision,
            ct_t,
            cnorm,
            ct_t32,
            cnorm32,
            executor: Executor::shared(),
            threads: 0,
        };
        CentroidScorer {
            ctx: Arc::new(ctx),
            block: ScoreBlock::fresh(d, f32_kernel),
            spare: None,
            obj: 0.0,
            worker: None,
        }
    }

    /// Override the dispatch executor and worker-thread clamp (`0` =
    /// auto) — the same knobs as [`EngineOpts`]; the default is the
    /// shared pool at full parallelism. Never changes the result (the
    /// per-tile partial reduction is executor- and thread-count
    /// independent). Builder-only: call before the first `push`.
    pub fn with_executor(mut self, executor: Executor, threads: usize) -> Self {
        debug_assert!(self.worker.is_none(), "with_executor after scoring started");
        let ctx = Arc::make_mut(&mut self.ctx);
        ctx.executor = executor;
        ctx.threads = threads;
        self
    }

    /// Score one row (length `d`) with weight `w`.
    pub fn push(&mut self, row: &[f64], w: f64) {
        debug_assert_eq!(row.len(), self.ctx.d);
        let (d, p) = (self.ctx.d, self.block.fill);
        match self.ctx.precision {
            Precision::F64 => {
                self.block.rows[p * d..(p + 1) * d].copy_from_slice(row);
            }
            Precision::F32 => {
                for (dst, &v) in self.block.rows32[p * d..(p + 1) * d].iter_mut().zip(row) {
                    *dst = v as f32;
                }
            }
        }
        self.block.wbuf[p] = w;
        self.block.fill += 1;
        if self.block.fill == SCORE_BLOCK {
            self.dispatch_block();
        }
    }

    /// Hand the filled front block to the ingestion worker and swap in a
    /// fresh (or reclaimed) buffer set, so the caller keeps streaming
    /// while the block scores. Reclaims the previous job first, so at
    /// most one block is ever in flight and partial objectives fold in
    /// submission order (the bitwise contract).
    fn dispatch_block(&mut self) {
        if self.worker.is_none() {
            self.worker = Some(ScoreWorker::spawn(Arc::clone(&self.ctx)));
        }
        self.reclaim();
        let next = self.spare.take().unwrap_or_else(|| {
            ScoreBlock::fresh(self.ctx.d, self.ctx.precision == Precision::F32)
        });
        let full = std::mem::replace(&mut self.block, next);
        let worker = self.worker.as_mut().expect("ingestion worker");
        worker.job_tx.send((self.obj, full)).expect("scorer ingestion worker hung up");
        worker.in_flight = true;
    }

    /// Wait for the in-flight block (if any), adopt its folded-forward
    /// objective and reclaim its buffers. Propagates a worker panic onto
    /// the caller.
    fn reclaim(&mut self) {
        let in_flight = self.worker.as_ref().is_some_and(|w| w.in_flight);
        if !in_flight {
            return;
        }
        let worker = self.worker.as_mut().expect("ingestion worker");
        worker.in_flight = false;
        match worker.done_rx.recv() {
            Ok((obj, block)) => {
                self.obj = obj;
                self.spare = Some(block);
            }
            Err(_) => {
                // The worker hung up mid-job: it panicked (a kernel
                // assert or a pool fault). Join and re-raise here rather
                // than returning a silently-partial objective.
                let worker = self.worker.take().expect("ingestion worker");
                drop(worker.job_tx);
                match worker.handle.join() {
                    Err(payload) => std::panic::resume_unwind(payload),
                    Ok(()) => unreachable!("scorer worker exited without a result"),
                }
            }
        }
    }

    /// Drain the in-flight block, score the partial tail inline, retire
    /// the ingestion worker and return the accumulated objective.
    pub fn finish(mut self) -> f64 {
        self.reclaim();
        self.obj = score_block(&self.ctx, &mut self.block, self.obj);
        if let Some(worker) = self.worker.take() {
            drop(worker.job_tx);
            if let Err(payload) = worker.handle.join() {
                std::panic::resume_unwind(payload);
            }
        }
        self.obj
    }
}

/// Score one full or partial block on the context's executor and fold
/// its per-tile partials into `obj` in tile order — the single scoring
/// routine behind both the ingestion worker and the inline tail flush,
/// so both paths produce identical bits. Returns the updated running
/// objective and resets the block for refilling.
fn score_block(ctx: &ScoreCtx, block: &mut ScoreBlock, mut obj: f64) -> f64 {
    let fill = block.fill;
    if fill == 0 {
        return obj;
    }
    let (d, k) = (ctx.d, ctx.k);
    let n_tiles = fill.div_ceil(SCORE_TILE);
    // One partial objective per tile, computed in point order within
    // the tile and reduced in tile order below — thread-count
    // independent by construction. The per-tile `dots` scratch lives
    // in the work item, so it is allocated once and reused across
    // blocks.
    if block.tiles.len() < n_tiles {
        block.tiles.resize_with(n_tiles, ScoreTile::default);
    }
    let threads = resolve_threads(ctx.threads);
    let wbuf = &block.wbuf;
    let works = &mut block.tiles[..n_tiles];
    match ctx.precision {
        Precision::F64 => {
            let rows = &block.rows;
            let ct_t = &ctx.ct_t;
            let cnorm = &ctx.cnorm;
            ctx.executor.run_chunks(works, threads, |ti, tile| {
                let lo = ti * SCORE_TILE;
                let hi = (lo + SCORE_TILE).min(fill);
                let tp = hi - lo;
                tile.dots.resize(SCORE_TILE * k, 0.0);
                let dots = &mut tile.dots[..tp * k];
                microkernel::tile_dots(&rows[lo * d..hi * d], d, k, ct_t, dots);
                let mut acc = 0.0f64;
                for p in 0..tp {
                    let row = &rows[(lo + p) * d..(lo + p + 1) * d];
                    let xn: f64 = row.iter().map(|v| v * v).sum();
                    let (d1, _, _) =
                        microkernel::best_two_expanded(xn, &dots[p * k..(p + 1) * k], cnorm);
                    acc += wbuf[lo + p] * d1.max(0.0);
                }
                tile.out = acc;
            });
        }
        Precision::F32 => {
            let rows32 = &block.rows32;
            let ct_t32 = &ctx.ct_t32;
            let cnorm32 = &ctx.cnorm32;
            ctx.executor.run_chunks(works, threads, |ti, tile| {
                let lo = ti * SCORE_TILE;
                let hi = (lo + SCORE_TILE).min(fill);
                let tp = hi - lo;
                tile.dots32.resize(SCORE_TILE * k, 0.0);
                let dots = &mut tile.dots32[..tp * k];
                microkernel::tile_dots_f32(&rows32[lo * d..hi * d], d, k, ct_t32, dots);
                let mut acc = 0.0f64;
                for p in 0..tp {
                    let row = &rows32[(lo + p) * d..(lo + p + 1) * d];
                    let xn: f32 = row.iter().map(|v| v * v).sum();
                    let (d1, _, _) = microkernel::best_two_expanded_f32(
                        xn,
                        &dots[p * k..(p + 1) * k],
                        cnorm32,
                    );
                    // Weight accumulation stays in f64 (the tolerance
                    // contract); distances widen after the f32 clamp.
                    acc += wbuf[lo + p] * d1.max(0.0) as f64;
                }
                tile.out = acc;
            });
        }
    }
    for t in &block.tiles[..n_tiles] {
        obj += t.out;
    }
    block.fill = 0;
    obj
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::{assert_close, for_cases};
    use crate::util::SplitMix64;

    #[test]
    fn run_chunks_visits_every_item_once() {
        let mut works: Vec<u32> = vec![0; 37];
        run_chunks(&mut works, 4, |i, w| *w += i as u32 + 1);
        for (i, w) in works.iter().enumerate() {
            assert_eq!(*w, i as u32 + 1);
        }
        // Serial path too.
        let mut works: Vec<u32> = vec![0; 5];
        run_chunks(&mut works, 1, |i, w| *w = i as u32);
        assert_eq!(works, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn scorer_matches_naive_objective() {
        for_cases(20, |rng| {
            let d = 1 + rng.below(6) as usize;
            let k = 1 + rng.below(5) as usize;
            let n = 1 + rng.below(150) as usize;
            let pts: Vec<f64> = (0..n * d).map(|_| rng.uniform(-4.0, 4.0)).collect();
            let w: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 2.0)).collect();
            let cents: Vec<f64> = (0..k * d).map(|_| rng.uniform(-4.0, 4.0)).collect();

            let mut scorer = CentroidScorer::new(&cents, d);
            for i in 0..n {
                scorer.push(&pts[i * d..(i + 1) * d], w[i]);
            }
            let got = scorer.finish();
            let want = crate::cluster::lloyd::objective(&pts, &w, d, &cents);
            assert_close(got, want, 1e-9);
        });
    }

    #[test]
    fn stats_rates() {
        let s = PruneStats {
            iters: 2,
            points: 100,
            dist_evals: 30,
            dist_evals_skipped: 70,
            wall: Duration::from_secs(1),
            ..PruneStats::default()
        };
        assert_close(s.skip_rate(), 0.7, 1e-12);
        assert_close(s.points_per_sec(), 200.0, 1e-9);
        assert_eq!(PruneStats::default().skip_rate(), 0.0);
        assert_eq!(PruneStats::default().points_per_sec(), 0.0);
    }

    #[test]
    fn thread_resolution_prefers_explicit() {
        assert_eq!(resolve_threads(3), 3);
        assert!(resolve_threads(0) >= 1);
    }

    #[test]
    fn bounds_policy_resolution_and_labels() {
        assert_eq!(BoundsPolicy::Auto.resolve(ELKAN_AUTO_K - 1), BoundsPolicy::Hamerly);
        assert_eq!(BoundsPolicy::Auto.resolve(ELKAN_AUTO_K), BoundsPolicy::Elkan);
        assert_eq!(BoundsPolicy::Hamerly.resolve(1000), BoundsPolicy::Hamerly);
        assert_eq!(BoundsPolicy::Elkan.resolve(1), BoundsPolicy::Elkan);
        assert_eq!(BoundsPolicy::Elkan.label(), "elkan");
        assert_eq!(Precision::F32.label(), "f32");
    }

    #[test]
    fn scorer_handles_partial_tiles() {
        let mut rng = SplitMix64::new(4);
        let cents = vec![0.0, 0.0, 5.0, 5.0]; // k=2, d=2
        let mut scorer = CentroidScorer::new(&cents, 2);
        let mut want = 0.0;
        for _ in 0..(SCORE_TILE * 2 + 3) {
            let p = [rng.uniform(-1.0, 6.0), rng.uniform(-1.0, 6.0)];
            let d0 = p[0] * p[0] + p[1] * p[1];
            let d1 = (p[0] - 5.0) * (p[0] - 5.0) + (p[1] - 5.0) * (p[1] - 5.0);
            want += d0.min(d1);
            scorer.push(&p, 1.0);
        }
        assert_close(scorer.finish(), want, 1e-9);
    }

    #[test]
    fn scorer_pooled_block_boundary_matches_naive() {
        // Cross the pooled-flush block boundary: the partial-per-tile
        // reduction (in tile order) must agree with a plain streaming sum.
        let mut rng = SplitMix64::new(9);
        let d = 3;
        let k = 4;
        let cents: Vec<f64> = (0..k * d).map(|_| rng.uniform(-3.0, 3.0)).collect();
        let n = SCORE_BLOCK + SCORE_TILE + 7;
        let pts: Vec<f64> = (0..n * d).map(|_| rng.uniform(-4.0, 4.0)).collect();
        let w: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 2.0)).collect();
        let mut scorer = CentroidScorer::new(&cents, d);
        for i in 0..n {
            scorer.push(&pts[i * d..(i + 1) * d], w[i]);
        }
        let got = scorer.finish();
        let want = crate::cluster::lloyd::objective(&pts, &w, d, &cents);
        assert_close(got, want, 1e-9);
    }

    #[test]
    fn scorer_double_buffering_is_bitwise_deterministic() {
        // Stream several full blocks so the ingestion worker carries
        // real in-flight jobs, and pin the double-buffered result: equal
        // bits across repeated runs, executors and thread clamps, and
        // matching the plain point-order oracle to rounding.
        let mut rng = SplitMix64::new(21);
        let d = 3;
        let k = 5;
        let cents: Vec<f64> = (0..k * d).map(|_| rng.uniform(-3.0, 3.0)).collect();
        let n = SCORE_BLOCK * 3 + SCORE_TILE + 5;
        let pts: Vec<f64> = (0..n * d).map(|_| rng.uniform(-4.0, 4.0)).collect();
        let w: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 2.0)).collect();
        let run = |executor: Executor, threads: usize| {
            let mut s = CentroidScorer::new(&cents, d).with_executor(executor, threads);
            for i in 0..n {
                s.push(&pts[i * d..(i + 1) * d], w[i]);
            }
            s.finish()
        };
        let pooled_a = run(Executor::shared(), 0);
        let pooled_b = run(Executor::shared(), 2);
        let scoped = run(Executor::Scoped, 1);
        assert_eq!(pooled_a.to_bits(), pooled_b.to_bits());
        assert_eq!(pooled_a.to_bits(), scoped.to_bits());
        let want = crate::cluster::lloyd::objective(&pts, &w, d, &cents);
        assert_close(pooled_a, want, 1e-9);
    }

    #[test]
    fn scorer_drop_without_finish_releases_worker() {
        // Abandoning a scorer mid-stream (caller unwound) must not hang:
        // dropping the job channel retires the ingestion worker.
        let cents = vec![0.0, 1.0]; // k = 2, d = 1
        let mut s = CentroidScorer::new(&cents, 1);
        for i in 0..SCORE_BLOCK + 5 {
            s.push(&[i as f64], 1.0);
        }
        drop(s);
    }

    #[test]
    fn scorer_f32_within_tolerance_and_deterministic() {
        let mut rng = SplitMix64::new(11);
        let d = 4;
        let k = 3;
        let cents: Vec<f64> = (0..k * d).map(|_| rng.uniform(-2.0, 2.0)).collect();
        let n = SCORE_BLOCK / 2 + 11;
        let pts: Vec<f64> = (0..n * d).map(|_| rng.uniform(-3.0, 3.0)).collect();
        let w: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 2.0)).collect();
        let run = |precision: Precision| {
            let mut s = CentroidScorer::new_with(&cents, d, precision);
            for i in 0..n {
                s.push(&pts[i * d..(i + 1) * d], w[i]);
            }
            s.finish()
        };
        let f64_obj = run(Precision::F64);
        let f32_a = run(Precision::F32);
        let f32_b = run(Precision::F32);
        // Deterministic within the precision (pool scheduling never
        // changes the tile-order reduction)…
        assert_eq!(f32_a.to_bits(), f32_b.to_bits());
        // …executor-independent (scoped serial reduces identically)…
        let scoped = {
            let mut s = CentroidScorer::new_with(&cents, d, Precision::F32)
                .with_executor(Executor::Scoped, 1);
            for i in 0..n {
                s.push(&pts[i * d..(i + 1) * d], w[i]);
            }
            s.finish()
        };
        assert_eq!(scoped.to_bits(), f32_a.to_bits());
        // …and within the documented tolerance of the f64 pass.
        let rel = (f64_obj - f32_a).abs() / f64_obj.abs().max(1e-12);
        assert!(rel <= F32_OBJ_RTOL, "f32 scorer drifted {rel:.2e} from f64");
    }

    #[test]
    fn state_splice_reshapes_assign_and_bounds() {
        // Hamerly stride (1): remove position 1, insert at 0. (Zero final
        // drift, so `capture` freezes the arrays as-is.)
        let mut st = EngineState::capture(
            vec![0, 1, 2],
            vec![0.5, 1.5, 2.5],
            BoundsPolicy::Hamerly,
            Precision::F64,
            true,
            &[0.0; 3],
            3,
            42,
        );
        st.splice(&[StateSplice::Remove(1), StateSplice::Insert(0)]);
        assert_eq!(st.n(), 3);
        assert_eq!(st.assign.as_slice(), &[0, 0, 2]);
        assert_eq!(st.lb.as_slice()[0], f64::NEG_INFINITY);
        assert_eq!(st.lb.as_slice()[1], 0.5);
        assert_eq!(st.lb.as_slice()[2], 2.5);

        // Elkan stride (k = 2): whole rows move together.
        let mut st = EngineState::capture(
            vec![1, 0],
            vec![1.0, 2.0, 3.0, 4.0],
            BoundsPolicy::Elkan,
            Precision::F64,
            true,
            &[0.0; 2],
            2,
            7,
        );
        st.splice(&[StateSplice::Insert(1)]);
        assert_eq!(st.n(), 3);
        assert_eq!(st.assign.as_slice(), &[1, 0, 0]);
        assert_eq!(st.lb.as_slice(), &[1.0, 2.0, f64::NEG_INFINITY, f64::NEG_INFINITY, 3.0, 4.0]);
        st.splice(&[StateSplice::Remove(0)]);
        assert_eq!(st.assign.as_slice(), &[0, 0]);
        assert_eq!(st.lb.as_slice(), &[f64::NEG_INFINITY, f64::NEG_INFINITY, 3.0, 4.0]);
    }

    #[test]
    fn centroid_hashes_detect_changes() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let mut b = a.clone();
        assert_eq!(EngineState::hash_dense(&a), EngineState::hash_dense(&b));
        b[2] = 3.0000001;
        assert_ne!(EngineState::hash_dense(&a), EngineState::hash_dense(&b));

        let fa = vec![vec![
            crate::cluster::CentroidCoord::Continuous(1.5),
            crate::cluster::CentroidCoord::Categorical(vec![0.25, 0.75]),
        ]];
        let mut fb = fa.clone();
        assert_eq!(EngineState::hash_factored(&fa), EngineState::hash_factored(&fb));
        if let crate::cluster::CentroidCoord::Categorical(beta) = &mut fb[0][1] {
            beta[0] = 0.26;
        }
        assert_ne!(EngineState::hash_factored(&fa), EngineState::hash_factored(&fb));
    }

    #[test]
    fn executor_labels_and_dispatch() {
        assert_eq!(Executor::Scoped.label(), "scoped");
        assert_eq!(Executor::shared().label(), "pool");
        assert_eq!(ExecutorKind::Scoped.executor().label(), "scoped");
        assert_eq!(ExecutorKind::Pool.executor().label(), "pool");
        let mut works = vec![0u32; 9];
        let pooled = Executor::shared().run_chunks(&mut works, 3, |i, w| *w = i as u32);
        assert_eq!(works[8], 8);
        // Whether the dispatch went parallel depends on the machine; the
        // scoped executor never reports a pool dispatch.
        let mut works = vec![0u32; 9];
        assert!(!Executor::Scoped.run_chunks(&mut works, 3, |i, w| *w = i as u32));
        assert_eq!(works[8], 8);
        let _ = pooled;
    }
}
