"""L2 model vs the oracle: full Lloyd steps, the scan sweep, and the
padding contract end to end."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def rand(shape, seed, lo=-5.0, hi=5.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(lo, hi, size=shape).astype(np.float32))


@settings(max_examples=15, deadline=None)
@given(
    blocks=st.integers(1, 3),
    d=st.integers(1, 24),
    k=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_step_matches_ref(blocks, d, k, seed):
    n = blocks * 128
    pts = rand((n, d), seed)
    wts = rand((n,), seed + 1, 0.1, 2.0)
    cts = rand((k, d), seed + 2)
    c_m, n_m, o_m = model.lloyd_step(pts, wts, cts)
    c_r, n_r, o_r = ref.lloyd_step_ref(pts, wts, cts)
    np.testing.assert_allclose(np.asarray(o_m), np.asarray(o_r), rtol=1e-3)
    # Counts can differ on distance ties; require total mass to agree and
    # centroid sums to be consistent with their own counts.
    np.testing.assert_allclose(
        float(jnp.sum(n_m)), float(jnp.sum(n_r)), rtol=1e-5
    )
    # With no ties (generic random floats) everything matches.
    np.testing.assert_allclose(np.asarray(n_m), np.asarray(n_r), rtol=1e-4, atol=1e-2)
    np.testing.assert_allclose(np.asarray(c_m), np.asarray(c_r), rtol=5e-3, atol=5e-3)


def test_objective_decreases_over_sweep():
    pts = rand((512, 8), 3)
    wts = rand((512,), 4, 0.5, 1.5)
    cts = rand((6, 8), 5)
    _, _, obj_t = model.lloyd_sweep(pts, wts, cts, 6)
    objs = np.asarray(obj_t)
    assert np.all(np.diff(objs) <= 1e-3), f"objective rose: {objs}"


def test_sweep_equals_iterated_steps():
    pts = rand((256, 4), 6)
    wts = jnp.ones((256,), jnp.float32)
    cts = rand((4, 4), 7)
    c_s, n_s, obj_t = model.lloyd_sweep(pts, wts, cts, 3)
    c_i, n_i, o_i = ref.lloyd_iterate_ref(pts, wts, cts, 3)
    np.testing.assert_allclose(np.asarray(c_s), np.asarray(c_i), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(n_s), np.asarray(n_i), rtol=1e-4, atol=1e-2)
    np.testing.assert_allclose(float(obj_t[-1]), float(o_i), rtol=1e-4)


def test_padding_contract():
    """Padded rows (w=0) and sentinel centroids must be exact no-ops."""
    n_real, n_pad = 100, 28
    d, k_real, k_pad = 6, 3, 2
    pts_r = rand((n_real, d), 8)
    wts_r = rand((n_real,), 9, 0.5, 1.5)
    cts_r = rand((k_real, d), 10)

    pts = jnp.concatenate([pts_r, jnp.zeros((n_pad, d), jnp.float32)])
    wts = jnp.concatenate([wts_r, jnp.zeros((n_pad,), jnp.float32)])
    cts = jnp.concatenate([cts_r, jnp.full((k_pad, d), 1e15, jnp.float32)])

    c_pad, n_pad_counts, o_pad = model.lloyd_step(pts, wts, cts)
    c_ref, n_ref, o_ref = ref.lloyd_step_ref(pts_r, wts_r, cts_r)

    np.testing.assert_allclose(float(o_pad), float(o_ref), rtol=1e-3)
    np.testing.assert_allclose(
        np.asarray(c_pad[:k_real]), np.asarray(c_ref), rtol=5e-3, atol=5e-3
    )
    # Pad centroids: zero mass, unchanged position.
    np.testing.assert_allclose(np.asarray(n_pad_counts[k_real:]), 0.0)
    np.testing.assert_allclose(np.asarray(c_pad[k_real:]), 1e15, rtol=1e-6)


def test_empty_cluster_keeps_centroid():
    pts = jnp.zeros((128, 2), jnp.float32)
    wts = jnp.ones((128,), jnp.float32)
    # Second centroid is far away: it gets no points.
    cts = jnp.asarray([[0.0, 0.0], [50.0, 50.0]], jnp.float32)
    new_c, counts, _ = model.lloyd_step(pts, wts, cts)
    assert float(counts[1]) == 0.0
    np.testing.assert_allclose(np.asarray(new_c[1]), [50.0, 50.0])
