//! Clustering algorithms: the per-subspace optimal solvers of Step 2, the
//! k-means++ seeder, dense weighted Lloyd (the mlpack-style baseline and
//! the XLA hot-path's host-side twin), and the factored sparse Lloyd that
//! implements Step 4's O(1)-per-(cell, centroid, subspace) distance trick.
//! Both Lloyd variants execute on the shared [`engine`]: a tiled distance
//! microkernel, Hamerly bounds pruning, and a deterministic chunk-parallel
//! executor.
//!
//! | paper piece | module |
//! |---|---|
//! | optimal weighted 1-D k-means (DP, [42]) | [`kmeans1d`] |
//! | closed-form categorical k-means (Thm 4.4) | [`categorical`] |
//! | k-means++ seeding [7] | [`kmeanspp`] |
//! | Lloyd over dense `X` (mlpack comparator) | [`lloyd`] |
//! | Step-4 factored Lloyd over the grid (§4.3) | [`sparse_lloyd`] |
//! | shared Step-4 execution engine | [`engine`] |

pub mod categorical;
pub mod engine;
pub mod kmeans1d;
pub mod kmedian;
pub mod kmeanspp;
pub mod lloyd;
pub mod regularized;
pub mod sparse_lloyd;

pub use categorical::{categorical_kmeans, CatClusters};
pub use engine::{
    BoundsPolicy, CentroidScorer, EngineOpts, EngineState, Executor, ExecutorKind, Precision,
    PruneStats, StateSplice, ELKAN_AUTO_K, F32_OBJ_RTOL,
};
pub use kmeans1d::{kmeans1d, Kmeans1dResult};
pub use kmedian::{kmedian1d, weighted_kmedian, Kmedian1dResult, KmedianResult};
pub use kmeanspp::kmeanspp_indices;
pub use lloyd::{weighted_lloyd, weighted_lloyd_with, LloydConfig, LloydResult};
pub use sparse_lloyd::{
    sparse_lloyd, sparse_lloyd_resume_with, sparse_lloyd_warm_with, sparse_lloyd_with,
    CentroidCoord, Components, SparseGrid, SparseLloydResult, Subspace,
};
