//! The conventional baseline (paper Fig. 1a / Table 2 "psql + mlpack"):
//! materialize the FEQ output, one-hot encode it, run k-means++ + Lloyd on
//! the dense matrix. Memory and time both scale with `|X| × D` — the cost
//! Rk-means exists to avoid.

use crate::cluster::{weighted_lloyd, LloydConfig, LloydResult};
use crate::data::Database;
use crate::join::{materialize_capped, EmbedSpec};
use crate::query::{Feq, Hypergraph};
use anyhow::Result;
use std::time::Duration;

/// Timing + quality of a baseline run.
#[derive(Clone, Debug)]
pub struct BaselineResult {
    /// Dense `k × D` centroids.
    pub centroids: Vec<f64>,
    /// Final weighted objective on the full `X`.
    pub objective: f64,
    /// Output rows `|X|`.
    pub rows: usize,
    /// One-hot dimensionality `D`.
    pub dims: usize,
    /// Estimated bytes held for the dense matrix (the paper's OOM story).
    pub dense_bytes: u64,
    /// Time to materialize `X` (the "Compute X (psql)" row of Table 2).
    pub t_materialize: Duration,
    /// Time to one-hot encode.
    pub t_embed: Duration,
    /// Time for k-means++ + Lloyd (the "Clustering (mlpack)" row).
    pub t_cluster: Duration,
    /// Lloyd iterations.
    pub iters: usize,
}

impl BaselineResult {
    /// End-to-end time (materialize + embed + cluster).
    pub fn total_time(&self) -> Duration {
        self.t_materialize + self.t_embed + self.t_cluster
    }
}

/// Materialize-then-cluster with no row cap.
pub fn materialize_and_cluster(
    db: &Database,
    feq: &Feq,
    cfg: &LloydConfig,
) -> Result<BaselineResult> {
    materialize_and_cluster_capped(db, feq, cfg, u64::MAX)
}

/// Materialize-then-cluster, erroring if `|X|` exceeds `cap` rows (keeps
/// benches from OOMing the way mlpack did at 900 GiB in the paper).
pub fn materialize_and_cluster_capped(
    db: &Database,
    feq: &Feq,
    cfg: &LloydConfig,
    cap: u64,
) -> Result<BaselineResult> {
    feq.validate(db)?;
    let tree = Hypergraph::from_feq(db, feq).join_tree()?;

    let t0 = crate::util::timer::now();
    let x = materialize_capped(db, feq, &tree, cap)?;
    let t_materialize = t0.elapsed();

    let t0 = crate::util::timer::now();
    let spec = EmbedSpec::from_feq(db, feq)?;
    let dense = spec.embed_matrix(&x);
    let t_embed = t0.elapsed();
    let dense_bytes = (dense.len() * std::mem::size_of::<f64>()) as u64;

    let t0 = crate::util::timer::now();
    let LloydResult { centroids, objective, iters, .. } =
        weighted_lloyd(&dense, &x.weights, spec.dims, cfg);
    let t_cluster = t0.elapsed();

    Ok(BaselineResult {
        centroids,
        objective,
        rows: x.len(),
        dims: spec.dims,
        dense_bytes,
        t_materialize,
        t_embed,
        t_cluster,
        iters,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Attr, Relation, Schema, Value};
    use crate::util::SplitMix64;

    fn setup(n: usize) -> (Database, Feq) {
        let mut rng = SplitMix64::new(42);
        let mut fact =
            Relation::new("fact", Schema::new(vec![Attr::cat("c", 4), Attr::double("x")]));
        for _ in 0..n {
            let c = rng.below(4) as u32;
            fact.push_row(&[Value::Cat(c), Value::Double(c as f64 * 10.0 + rng.next_f64())]);
        }
        let mut db = Database::new();
        db.add(fact);
        let feq = Feq::with_features(&["fact"], &["c", "x"]);
        (db, feq)
    }

    #[test]
    fn baseline_end_to_end() {
        let (db, feq) = setup(100);
        let r = materialize_and_cluster(&db, &feq, &LloydConfig::new(4)).unwrap();
        assert_eq!(r.rows, 100);
        assert_eq!(r.dims, 5);
        assert!(r.objective.is_finite());
        assert!(r.dense_bytes > 0);
        // 4 well-separated numeric regimes: objective far below variance.
        assert!(r.objective < 100.0, "objective {}", r.objective);
    }

    #[test]
    fn cap_propagates() {
        let (db, feq) = setup(100);
        assert!(
            materialize_and_cluster_capped(&db, &feq, &LloydConfig::new(2), 10).is_err()
        );
    }
}
