import os
import sys

# Make `compile.*` importable when pytest runs from the repo root
# (`pytest python/tests/`), matching the Makefile/CI invocation.
sys.path.insert(0, os.path.dirname(__file__))
