//! The `rklint` rule set (R1–R6) over the masked token stream.
//!
//! Every rule is deny-by-default: a match is a diagnostic unless the
//! site carries an inline waiver with a reason, or the site is listed
//! in the relevant registry ([`SPAWN_REGISTRY`] for R1 thread
//! creation, [`QUEUE_REGISTRY`] for R6 channel construction). See
//! [`crate::analysis`] for the rule catalogue and the determinism
//! contract each rule guards.

use super::scan::{Scanned, Tok};
use super::{Diagnostic, RULES};
use std::collections::BTreeSet;

/// R1 — the explicit registry of legitimate thread-creation sites
/// outside `util::exec`. An entry matches on (file suffix, enclosing
/// `fn` name) so it survives line drift; matched sites surface in the
/// report as *waived* diagnostics carrying the registry reason.
pub const SPAWN_REGISTRY: &[(&str, &str, &str)] = &[
    (
        "coordinator/mod.rs",
        "start",
        "single long-lived coordinator service thread; its compute jobs all dispatch on ExecPool",
    ),
    (
        "main.rs",
        "cmd_serve",
        "serve-loop writer thread driving the publisher while the foreground runs the load generator",
    ),
    (
        "cluster/engine/mod.rs",
        "run_chunks",
        "scoped fallback executor, bitwise-pinned against ExecPool by tests/property_exec.rs",
    ),
    (
        "cluster/engine/mod.rs",
        "spawn",
        "single score-ingest worker overlapping streaming with scoring; scoring itself runs on ExecPool",
    ),
    (
        "serve/front.rs",
        "start",
        "single dispatcher service thread; batch compute fans onto the shared ExecPool",
    ),
    (
        "serve/load.rs",
        "run_open_loop",
        "open-loop load-generator clients: intentionally independent arrival processes, measurement only",
    ),
    (
        "metrics/mod.rs",
        "shared_across_threads",
        "test exercising cross-thread counter visibility",
    ),
    (
        "serve/rpc/mod.rs",
        "start",
        "socket-tier service threads (accept loop / replica delta-stream subscriber); all model \
         compute stays on ExecPool via the assign front",
    ),
    (
        "serve/rpc/mod.rs",
        "accept_loop",
        "one handler thread per accepted connection, joined by the accept loop on shutdown; \
         handlers only frame/deframe and relay to the front",
    ),
    (
        "serve/rpc/mod.rs",
        "run_rpc_loop",
        "socket load-generator clients: intentionally independent arrival processes, measurement \
         only (mirrors serve/load.rs run_open_loop)",
    ),
    (
        "coordinator/mod.rs",
        "start_multi",
        "single multi-producer coordinator service thread; epoch merges and patches all dispatch \
         on the shared ExecPool",
    ),
    (
        "main.rs",
        "cmd_stream",
        "scoped CLI producer threads feeding the bounded per-shard ingest queues; all clustering \
         compute stays on ExecPool",
    ),
];

/// R6 — the explicit registry of legitimate unbounded-channel sites.
/// Same shape as [`SPAWN_REGISTRY`]: (file suffix, enclosing `fn`,
/// reason). Everything else must use `sync_channel(cap)` with a real
/// capacity so backpressure is accounted for — the ingest tier's
/// per-shard queues ([`crate::ingest`]) are the reference pattern.
pub const QUEUE_REGISTRY: &[(&str, &str, &str)] = &[
    (
        "serve/front.rs",
        "submit",
        "per-request reply channel: exactly one message ever in flight by protocol",
    ),
    (
        "serve/front.rs",
        "start",
        "front request queue: clients are closed-loop (one outstanding request each), so depth \
         is bounded by the client count, not the queue",
    ),
    (
        "cluster/engine/mod.rs",
        "spawn",
        "score-worker job/done round-trip channels: at most one block in flight each way by \
         protocol",
    ),
];

/// Map/set type names whose iteration order is hash-dependent (R2).
/// `BTreeMap`/`BTreeSet` are deliberately absent — ordered iteration is
/// the fix, not a finding.
const HASH_TYPES: &[&str] = &["HashMap", "HashSet", "FxHashMap", "FxHashSet"];

/// Receiver methods that walk a map in storage order (R2).
const ITER_METHODS: &[&str] = &[
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "into_iter",
    "into_keys",
    "into_values",
    "drain",
    "retain",
];

/// Numeric target types of a bare `as` cast (R4).
const NUM_TYPES: &[&str] = &[
    "u8", "u16", "u32", "u64", "u128", "usize", "i8", "i16", "i32", "i64", "i128", "isize",
    "f32", "f64",
];

/// Result-producing methods whose `.unwrap()` loses context (R5).
const FALLIBLE_SYNC_METHODS: &[&str] =
    &["lock", "read", "write", "recv", "try_recv", "recv_timeout", "send", "join", "wait"];

/// Files (suffix match) where rules do not apply at all.
fn rule_applies(rule: &str, file: &str) -> bool {
    match rule {
        // The executor owns thread creation.
        "rogue-thread" => !file.ends_with("util/exec.rs"),
        // The sorted adapters must themselves iterate the raw map.
        "nondet-iteration" => !file.ends_with("util/det.rs"),
        // Telemetry, benches, the load generator, and the blessed clock
        // are the only homes for wall-clock reads.
        "wall-clock-in-core" => {
            !(file.contains("src/metrics/")
                || file.contains("src/bench_harness/")
                || file.ends_with("serve/load.rs")
                || file.ends_with("util/timer.rs"))
        }
        // Wire encode/decode paths only.
        "unchecked-cast-in-wire" => {
            file.ends_with("rkmeans/model.rs")
                || file.ends_with("serve/delta.rs")
                || file.ends_with("serve/rpc/wire.rs")
        }
        // Serving tier + executor hot paths only.
        "contextless-unwrap" => file.contains("src/serve/") || file.ends_with("util/exec.rs"),
        _ => true,
    }
}

/// Run every rule over one scanned file; returns raw diagnostics (not
/// yet matched against waivers — [`super::apply_waivers`] does that).
pub fn check(file: &str, scanned: &Scanned) -> Vec<Diagnostic> {
    let toks = &scanned.toks;
    let fns = enclosing_fns(toks);
    let mut out = Vec::new();

    if rule_applies("rogue-thread", file) {
        r1_rogue_thread(file, toks, &fns, &mut out);
    }
    if rule_applies("nondet-iteration", file) {
        r2_nondet_iteration(file, toks, &mut out);
    }
    if rule_applies("wall-clock-in-core", file) {
        r3_wall_clock(file, toks, &mut out);
    }
    if rule_applies("unchecked-cast-in-wire", file) {
        r4_unchecked_cast(file, toks, &mut out);
    }
    if rule_applies("contextless-unwrap", file) {
        r5_contextless_unwrap(file, toks, &mut out);
    }
    if rule_applies("unbounded-channel", file) {
        r6_unbounded_channel(file, toks, &fns, &mut out);
    }
    check_waiver_annotations(file, scanned, &mut out);
    out
}

/// For each token index, the name of the most recent `fn` declaration —
/// a scope approximation that is exact for this codebase's layout
/// (spawn sites are never between a file's start and its first fn).
fn enclosing_fns(toks: &[Tok]) -> Vec<String> {
    let mut cur = String::new();
    let mut out = Vec::with_capacity(toks.len());
    let mut i = 0usize;
    while i < toks.len() {
        if toks[i].s == "fn" && i + 1 < toks.len() && is_ident(&toks[i + 1].s) {
            cur = toks[i + 1].s.clone();
        }
        out.push(cur.clone());
        i += 1;
    }
    out
}

fn is_ident(s: &str) -> bool {
    s.as_bytes().first().is_some_and(|&b| b.is_ascii_alphabetic() || b == b'_')
}

fn diag(rule: &'static str, file: &str, line: usize, message: String) -> Diagnostic {
    Diagnostic {
        rule,
        file: file.to_string(),
        line,
        message,
        waived: false,
        waiver_reason: None,
    }
}

/// R1: `thread::spawn`, `thread::Builder`, or `scope.spawn` outside
/// `util::exec` and the [`SPAWN_REGISTRY`].
fn r1_rogue_thread(file: &str, toks: &[Tok], fns: &[String], out: &mut Vec<Diagnostic>) {
    for i in 0..toks.len() {
        let hit = (toks[i].s == "thread"
            && tok_at(toks, i + 1) == "::"
            && matches!(tok_at(toks, i + 2), "spawn" | "Builder"))
            || (toks[i].s == "scope"
                && tok_at(toks, i + 1) == "."
                && tok_at(toks, i + 2) == "spawn");
        if !hit {
            continue;
        }
        let line = toks[i].line;
        let enclosing = fns[i].as_str();
        if let Some((_, _, reason)) = SPAWN_REGISTRY
            .iter()
            .find(|(suffix, f, _)| file.ends_with(suffix) && *f == enclosing)
        {
            let mut d = diag(
                "rogue-thread",
                file,
                line,
                format!("thread creation in fn `{enclosing}` (registered)"),
            );
            d.waived = true;
            d.waiver_reason = Some(format!("registry: {reason}"));
            out.push(d);
        } else {
            out.push(diag(
                "rogue-thread",
                file,
                line,
                format!(
                    "thread creation in fn `{enclosing}` outside util::exec and the spawn \
                     registry; route parallel compute through ExecPool or register the site"
                ),
            ));
        }
    }
}

/// R2: iteration over a hash-ordered map/set. Identifiers are
/// harvested from `let` bindings and `name: HashType<` declarations
/// (fields and params); flagged uses are storage-order receiver methods
/// and bare `for … in map` loops.
fn r2_nondet_iteration(file: &str, toks: &[Tok], out: &mut Vec<Diagnostic>) {
    let maps = collect_map_idents(toks);
    if maps.is_empty() {
        return;
    }
    for i in 0..toks.len() {
        // `m.iter()` / `self.m.keys()` — receiver just before the dot.
        if toks[i].s == "."
            && ITER_METHODS.contains(&tok_at(toks, i + 1))
            && tok_at(toks, i + 2) == "("
            && i > 0
            && maps.contains(&toks[i - 1].s)
        {
            out.push(diag(
                "nondet-iteration",
                file,
                toks[i + 1].line,
                format!(
                    "`{}.{}()` iterates a hash-ordered map; use util::det::sorted_* or waive \
                     with a reason",
                    toks[i - 1].s,
                    toks[i + 1].s
                ),
            ));
        }
        // `for (k, v) in &m {` — expression is refs/idents/dots only.
        if toks[i].s == "for" {
            if let Some(in_at) = (i + 1..(i + 40).min(toks.len())).find(|&j| toks[j].s == "in") {
                if let Some(brace) =
                    (in_at + 1..(in_at + 12).min(toks.len())).find(|&j| toks[j].s == "{")
                {
                    let expr = &toks[in_at + 1..brace];
                    let simple = !expr.is_empty()
                        && expr.iter().all(|t| {
                            t.s == "&" || t.s == "mut" || t.s == "." || is_ident(&t.s)
                        });
                    if simple {
                        let last = &expr[expr.len() - 1];
                        if maps.contains(&last.s) {
                            out.push(diag(
                                "nondet-iteration",
                                file,
                                last.line,
                                format!(
                                    "`for … in {}` iterates a hash-ordered map; use \
                                     util::det::sorted_* or waive with a reason",
                                    last.s
                                ),
                            ));
                        }
                    }
                }
            }
        }
    }
}

/// Harvest identifiers declared with a hash-map/set type in this file.
fn collect_map_idents(toks: &[Tok]) -> BTreeSet<String> {
    let mut maps = BTreeSet::new();
    for i in 0..toks.len() {
        // `let [mut] name` then `= HashType` or a `: …HashType…` type
        // up to the initializer.
        if toks[i].s == "let" {
            let mut j = i + 1;
            if tok_at(toks, j) == "mut" {
                j += 1;
            }
            if !is_ident(tok_at(toks, j)) {
                continue;
            }
            let name = toks[j].s.clone();
            match tok_at(toks, j + 1) {
                "=" => {
                    if HASH_TYPES.contains(&tok_at(toks, j + 2)) {
                        maps.insert(name);
                    }
                }
                ":" => {
                    // The hash type must be the *outermost* type of the
                    // annotation — `Vec<FxHashMap<…>>` is a vector, and
                    // iterating it is fine.
                    let mut k = j + 2;
                    while matches!(tok_at(toks, k), "&" | "mut") {
                        k += 1;
                    }
                    if HASH_TYPES.contains(&tok_at(toks, k)) {
                        maps.insert(name);
                    }
                }
                _ => {}
            }
        }
        // `name: [&][mut] HashType<` — struct fields and fn params.
        if toks[i].s == ":" && i > 0 && is_ident(&toks[i - 1].s) {
            let mut j = i + 1;
            while matches!(tok_at(toks, j), "&" | "mut") {
                j += 1;
            }
            if HASH_TYPES.contains(&tok_at(toks, j)) && tok_at(toks, j + 1) == "<" {
                maps.insert(toks[i - 1].s.clone());
            }
        }
    }
    maps
}

/// R3: `Instant::now` / `SystemTime` outside the telemetry allowlist.
fn r3_wall_clock(file: &str, toks: &[Tok], out: &mut Vec<Diagnostic>) {
    for i in 0..toks.len() {
        if toks[i].s == "Instant" && tok_at(toks, i + 1) == "::" && tok_at(toks, i + 2) == "now" {
            out.push(diag(
                "wall-clock-in-core",
                file,
                toks[i].line,
                "`Instant::now()` outside telemetry modules; use util::timer::now() so clock \
                 reads stay auditable"
                    .to_string(),
            ));
        }
        if toks[i].s == "SystemTime" {
            out.push(diag(
                "wall-clock-in-core",
                file,
                toks[i].line,
                "`SystemTime` outside telemetry modules".to_string(),
            ));
        }
    }
}

/// R4: bare `as <numeric>` casts in the wire encode/decode files.
fn r4_unchecked_cast(file: &str, toks: &[Tok], out: &mut Vec<Diagnostic>) {
    for i in 0..toks.len() {
        if toks[i].s == "as" && NUM_TYPES.contains(&tok_at(toks, i + 1)) {
            out.push(diag(
                "unchecked-cast-in-wire",
                file,
                toks[i].line,
                format!(
                    "bare `as {}` cast in a wire-format file; use a checked conversion \
                     (try_from / count_json) or waive with a reason",
                    toks[i + 1].s
                ),
            ));
        }
    }
}

/// R5: `.unwrap()` directly on a lock/channel/join result.
fn r5_contextless_unwrap(file: &str, toks: &[Tok], out: &mut Vec<Diagnostic>) {
    for i in 3..toks.len() {
        if !(toks[i].s == "." && tok_at(toks, i + 1) == "unwrap" && tok_at(toks, i + 2) == "(") {
            continue;
        }
        // Walk back over the `(...)` of the producing call.
        if toks[i - 1].s != ")" {
            continue;
        }
        let mut depth = 1usize;
        let mut j = i - 1;
        while j > 0 && depth > 0 {
            j -= 1;
            match toks[j].s.as_str() {
                ")" => depth += 1,
                "(" => depth -= 1,
                _ => {}
            }
        }
        if j == 0 {
            continue;
        }
        let meth = &toks[j - 1].s;
        if FALLIBLE_SYNC_METHODS.contains(&meth.as_str()) {
            out.push(diag(
                "contextless-unwrap",
                file,
                toks[i + 1].line,
                format!(
                    "`.{meth}().unwrap()` on a lock/channel result; use `.expect(\"…\")` with \
                     actionable context or poison-tolerant recovery"
                ),
            ));
        }
    }
}

/// R6: `mpsc::channel()` (no capacity bound) or `sync_channel(0)`
/// (zero-capacity rendezvous — `try_send` always fails, so the
/// backpressure-accounting pattern degenerates to a blocking send)
/// outside the [`QUEUE_REGISTRY`]. Bounded `sync_channel(N > 0)` is
/// the pattern, not a finding.
fn r6_unbounded_channel(file: &str, toks: &[Tok], fns: &[String], out: &mut Vec<Diagnostic>) {
    for i in 0..toks.len() {
        let name = toks[i].s.as_str();
        if name != "channel" && name != "sync_channel" {
            continue;
        }
        // A declaration (`fn channel(`) is not a construction site.
        if i > 0 && toks[i - 1].s == "fn" {
            continue;
        }
        let Some(open) = call_open_paren(toks, i) else {
            continue;
        };
        let what = if name == "channel" {
            "`channel()` has no capacity bound"
        } else {
            // Only the literal-zero capacity is a rendezvous; any other
            // argument shape is treated as a real bound.
            if !(tok_at(toks, open + 1) == "0" && tok_at(toks, open + 2) == ")") {
                continue;
            }
            "`sync_channel(0)` is a zero-capacity rendezvous"
        };
        let line = toks[i].line;
        let enclosing = fns[i].as_str();
        if let Some((_, _, reason)) = QUEUE_REGISTRY
            .iter()
            .find(|(suffix, f, _)| file.ends_with(suffix) && *f == enclosing)
        {
            let mut d = diag(
                "unbounded-channel",
                file,
                line,
                format!("{what} in fn `{enclosing}` (registered)"),
            );
            d.waived = true;
            d.waiver_reason = Some(format!("registry: {reason}"));
            out.push(d);
        } else {
            out.push(diag(
                "unbounded-channel",
                file,
                line,
                format!(
                    "{what} in fn `{enclosing}` outside the queue registry; use \
                     `sync_channel(cap)` so backpressure is accounted for, or register the queue"
                ),
            ));
        }
    }
}

/// Index of the call's opening `(` after an optional turbofish
/// (`::<T, …>`), or `None` when the name is not immediately called.
fn call_open_paren(toks: &[Tok], i: usize) -> Option<usize> {
    let mut j = i + 1;
    if tok_at(toks, j) == "::" && tok_at(toks, j + 1) == "<" {
        let mut depth = 1usize;
        j += 2;
        while j < toks.len() && depth > 0 {
            match tok_at(toks, j) {
                "<" => depth += 1,
                ">" => depth -= 1,
                _ => {}
            }
            j += 1;
        }
    }
    (tok_at(toks, j) == "(").then_some(j)
}

/// Waiver annotations themselves are checked: unknown rule names and
/// missing reasons are diagnostics that cannot be waived.
fn check_waiver_annotations(file: &str, scanned: &Scanned, out: &mut Vec<Diagnostic>) {
    for w in &scanned.waivers {
        if !RULES.contains(&w.rule.as_str()) {
            out.push(diag(
                "invalid-waiver",
                file,
                w.line,
                format!("waiver names unknown rule `{}`", w.rule),
            ));
        } else if w.reason.is_none() {
            out.push(diag(
                "invalid-waiver",
                file,
                w.line,
                format!(
                    "waiver for `{}` has no reason string; every waiver must justify itself",
                    w.rule
                ),
            ));
        }
    }
}

fn tok_at(toks: &[Tok], i: usize) -> &str {
    toks.get(i).map_or("", |t| t.s.as_str())
}
