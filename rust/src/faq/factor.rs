//! Sparse factors: the unit of FAQ message passing.

use crate::util::det;
use crate::util::FxHashMap;

/// A sparse factor ψ over an ordered list of variables: a map from value
/// tuples (join-key encoded `u64`s, in `vars` order) to a weight. Missing
/// tuples are implicitly the semiring zero.
#[derive(Clone, Debug, Default)]
pub struct Factor {
    pub vars: Vec<String>,
    pub data: FxHashMap<Vec<u64>, f64>,
}

impl Factor {
    /// Empty factor over the given variables.
    pub fn new(vars: Vec<String>) -> Self {
        Factor { vars, data: FxHashMap::default() }
    }

    /// Number of non-zero entries.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True if the factor has no entries.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Add `w` to the entry for `key` (sum-product aggregation).
    #[inline]
    pub fn add(&mut self, key: Vec<u64>, w: f64) {
        *self.data.entry(key).or_insert(0.0) += w;
    }

    /// Lookup; `None` for absent tuples.
    #[inline]
    pub fn get(&self, key: &[u64]) -> Option<f64> {
        self.data.get(key).copied()
    }

    /// Total mass (sum over all entries). Summed in sorted key order so
    /// the FP result is a function of the factor's *contents*, not its
    /// hash-map insertion history.
    pub fn mass(&self) -> f64 {
        det::sorted_entries(&self.data).iter().map(|(_, &w)| w).sum()
    }

    /// Project (marginalize) onto a subset of variables, summing weights.
    /// Panics if `onto` contains a variable not in this factor.
    pub fn project(&self, onto: &[String]) -> Factor {
        let idx: Vec<usize> = onto
            .iter()
            .map(|v| {
                self.vars
                    .iter()
                    .position(|x| x == v)
                    .unwrap_or_else(|| panic!("projection variable {v:?} missing"))
            })
            .collect();
        let mut out = Factor::new(onto.to_vec());
        // Sorted key order: colliding projections accumulate in a
        // content-determined order, keeping the result bit-stable across
        // construction histories.
        for (key, &w) in det::sorted_entries(&self.data) {
            let sub: Vec<u64> = idx.iter().map(|&i| key[i]).collect();
            out.add(sub, w);
        }
        out
    }

    /// Position of a variable.
    pub fn var_index(&self, name: &str) -> Option<usize> {
        self.vars.iter().position(|v| v == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Factor {
        let mut f = Factor::new(vec!["a".into(), "b".into()]);
        f.add(vec![1, 10], 2.0);
        f.add(vec![1, 11], 3.0);
        f.add(vec![2, 10], 5.0);
        f
    }

    #[test]
    fn add_accumulates() {
        let mut f = sample();
        f.add(vec![1, 10], 1.0);
        assert_eq!(f.get(&[1, 10]), Some(3.0));
        assert_eq!(f.get(&[9, 9]), None);
        assert_eq!(f.len(), 3);
    }

    #[test]
    fn mass_is_total() {
        assert_eq!(sample().mass(), 10.0);
    }

    #[test]
    fn project_marginalizes() {
        let f = sample();
        let p = f.project(&["a".to_string()]);
        assert_eq!(p.get(&[1]), Some(5.0));
        assert_eq!(p.get(&[2]), Some(5.0));
        // Project to nothing: a single scalar entry with the full mass.
        let unit = f.project(&[]);
        assert_eq!(unit.get(&[]), Some(10.0));
    }

    #[test]
    #[should_panic(expected = "missing")]
    fn project_unknown_var_panics() {
        sample().project(&["zzz".to_string()]);
    }
}
