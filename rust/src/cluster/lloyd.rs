//! Dense weighted Lloyd k-means over row-major points.
//!
//! This is (a) the materialize-then-cluster baseline — the role mlpack
//! plays in the paper's Table 2 — and (b) the host-side twin of the
//! XLA/PJRT hot path (`runtime::XlaLloyd`), kept in lock-step by tests so
//! the two engines are interchangeable.
//!
//! Distances use the `‖x‖² − 2·x·c + ‖c‖²` expansion with centroid norms
//! hoisted out of the inner loop; the `x·c` contraction is the part the
//! Pallas kernel maps onto the MXU in the AOT artifact.

use super::kmeanspp::kmeanspp_indices;
use crate::util::SplitMix64;

/// Configuration for Lloyd iterations.
#[derive(Clone, Debug)]
pub struct LloydConfig {
    pub k: usize,
    pub max_iters: usize,
    /// Stop when the relative objective improvement drops below this.
    pub tol: f64,
    pub seed: u64,
}

impl LloydConfig {
    /// Defaults matching the paper's experimental setup (k-means++ init,
    /// run to convergence with a practical iteration cap).
    pub fn new(k: usize) -> Self {
        LloydConfig { k, max_iters: 50, tol: 1e-6, seed: 0xC0FFEE }
    }
}

/// Result of a Lloyd run.
#[derive(Clone, Debug)]
pub struct LloydResult {
    /// Row-major `k × d` centroids.
    pub centroids: Vec<f64>,
    /// Cluster id per point.
    pub assign: Vec<u32>,
    /// Final weighted objective Σ w·d²(x, C).
    pub objective: f64,
    /// Iterations executed.
    pub iters: usize,
}

/// Weighted Lloyd on `n × d` row-major `points` with per-point `weights`.
pub fn weighted_lloyd(points: &[f64], weights: &[f64], d: usize, cfg: &LloydConfig) -> LloydResult {
    assert!(d > 0, "dimension must be positive");
    assert_eq!(points.len() % d, 0, "points not a multiple of d");
    let n = points.len() / d;
    assert_eq!(weights.len(), n, "weights length mismatch");
    assert!(n > 0, "no points");
    let k = cfg.k.min(n);

    let mut rng = SplitMix64::new(cfg.seed);
    let row = |i: usize| &points[i * d..(i + 1) * d];
    let dist2 = |a: &[f64], b: &[f64]| -> f64 {
        let mut s = 0.0;
        for (x, y) in a.iter().zip(b) {
            let t = x - y;
            s += t * t;
        }
        s
    };

    // k-means++ seeding.
    let seeds = kmeanspp_indices(n, weights, k, &mut rng, |i, j| dist2(row(i), row(j)));
    let mut centroids: Vec<f64> = Vec::with_capacity(k * d);
    for &s in &seeds {
        centroids.extend_from_slice(row(s));
    }

    let mut assign = vec![0u32; n];
    let mut objective = f64::INFINITY;
    let mut iters = 0;
    let mut mind2 = vec![0.0f64; n];

    for it in 0..cfg.max_iters.max(1) {
        iters = it + 1;
        // --- assignment ---
        let mut cnorm = vec![0.0f64; k];
        for c in 0..k {
            let cc = &centroids[c * d..(c + 1) * d];
            cnorm[c] = cc.iter().map(|v| v * v).sum();
        }
        let mut obj = 0.0;
        for i in 0..n {
            let x = row(i);
            let xn: f64 = x.iter().map(|v| v * v).sum();
            let mut best = f64::INFINITY;
            let mut best_c = 0u32;
            for c in 0..k {
                let cc = &centroids[c * d..(c + 1) * d];
                let mut dot = 0.0;
                for (a, b) in x.iter().zip(cc) {
                    dot += a * b;
                }
                let dd = xn - 2.0 * dot + cnorm[c];
                if dd < best {
                    best = dd;
                    best_c = c as u32;
                }
            }
            let best = best.max(0.0);
            assign[i] = best_c;
            mind2[i] = best;
            obj += weights[i] * best;
        }

        // --- update ---
        let mut sums = vec![0.0f64; k * d];
        let mut mass = vec![0.0f64; k];
        for i in 0..n {
            let c = assign[i] as usize;
            let w = weights[i];
            mass[c] += w;
            let x = row(i);
            let s = &mut sums[c * d..(c + 1) * d];
            for (sv, xv) in s.iter_mut().zip(x) {
                *sv += w * xv;
            }
        }
        for c in 0..k {
            if mass[c] > 0.0 {
                for j in 0..d {
                    centroids[c * d + j] = sums[c * d + j] / mass[c];
                }
            } else {
                // Empty cluster: reseed at the point with the largest
                // weighted distance-to-centroid contribution.
                let far = (0..n)
                    .max_by(|&a, &b| {
                        (weights[a] * mind2[a])
                            .partial_cmp(&(weights[b] * mind2[b]))
                            .expect("finite")
                    })
                    .expect("n > 0");
                centroids[c * d..(c + 1) * d].copy_from_slice(row(far));
                mind2[far] = 0.0;
            }
        }

        // --- convergence ---
        if objective.is_finite() {
            let improve = (objective - obj) / objective.abs().max(1e-30);
            if improve.abs() < cfg.tol {
                objective = obj;
                break;
            }
        }
        objective = obj;
    }

    LloydResult { centroids, assign, objective, iters }
}

/// Evaluate the weighted k-means objective of fixed centroids on a dense
/// point set (used for cross-engine comparisons and full-`X` evaluation).
pub fn objective(points: &[f64], weights: &[f64], d: usize, centroids: &[f64]) -> f64 {
    let n = points.len() / d;
    let k = centroids.len() / d;
    let mut obj = 0.0;
    for i in 0..n {
        let x = &points[i * d..(i + 1) * d];
        let mut best = f64::INFINITY;
        for c in 0..k {
            let cc = &centroids[c * d..(c + 1) * d];
            let mut s = 0.0;
            for (a, b) in x.iter().zip(cc) {
                let t = a - b;
                s += t * t;
            }
            if s < best {
                best = s;
            }
        }
        obj += weights[i] * best;
    }
    obj
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::{assert_close, for_cases};

    fn blobs(rng: &mut SplitMix64, centers: &[(f64, f64)], per: usize) -> (Vec<f64>, Vec<f64>) {
        let mut pts = Vec::new();
        for &(cx, cy) in centers {
            for _ in 0..per {
                pts.push(cx + 0.05 * rng.normal());
                pts.push(cy + 0.05 * rng.normal());
            }
        }
        let w = vec![1.0; pts.len() / 2];
        (pts, w)
    }

    #[test]
    fn recovers_separated_blobs() {
        let mut rng = SplitMix64::new(11);
        let centers = [(0.0, 0.0), (10.0, 0.0), (0.0, 10.0)];
        let (pts, w) = blobs(&mut rng, &centers, 50);
        let res = weighted_lloyd(&pts, &w, 2, &LloydConfig::new(3));
        // Objective ≈ n · E[d²] = 150 · 2·0.05² = 0.75.
        assert!(res.objective < 2.0, "objective {}", res.objective);
        // Every true center has a nearby learned centroid.
        for &(cx, cy) in &centers {
            let near = (0..3).any(|c| {
                let dx = res.centroids[c * 2] - cx;
                let dy = res.centroids[c * 2 + 1] - cy;
                dx * dx + dy * dy < 0.5
            });
            assert!(near, "no centroid near ({cx},{cy})");
        }
    }

    #[test]
    fn objective_decreases_monotonically() {
        // Lloyd's invariant: each iteration cannot increase the objective.
        for_cases(15, |rng| {
            let n = 20 + rng.below(60) as usize;
            let d = 1 + rng.below(4) as usize;
            let pts: Vec<f64> = (0..n * d).map(|_| rng.uniform(-5.0, 5.0)).collect();
            let w: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 2.0)).collect();
            let k = 2 + rng.below(4) as usize;
            let mut last = f64::INFINITY;
            for iters in 1..=6 {
                let cfg = LloydConfig { k, max_iters: iters, tol: 0.0, seed: 5 };
                let r = weighted_lloyd(&pts, &w, d, &cfg);
                assert!(
                    r.objective <= last + 1e-9,
                    "objective rose from {last} to {} at iter {iters}",
                    r.objective
                );
                last = r.objective;
            }
        });
    }

    #[test]
    fn weights_pull_centroid() {
        // Two points, k=1: centroid is the weighted mean.
        let pts = vec![0.0, 0.0, 1.0, 0.0];
        let w = vec![3.0, 1.0];
        let r = weighted_lloyd(&pts, &w, 2, &LloydConfig::new(1));
        assert_close(r.centroids[0], 0.25, 1e-9);
    }

    #[test]
    fn zero_weight_points_are_free() {
        let pts = vec![0.0, 100.0];
        let w = vec![1.0, 0.0];
        let r = weighted_lloyd(&pts, &w, 1, &LloydConfig::new(1));
        assert_close(r.centroids[0], 0.0, 1e-9);
        assert_close(r.objective, 0.0, 1e-9);
    }

    #[test]
    fn k_ge_n_zero_objective() {
        let pts = vec![0.0, 1.0, 2.0, 3.0];
        let w = vec![1.0; 4];
        let r = weighted_lloyd(&pts, &w, 1, &LloydConfig::new(10));
        assert_close(r.objective, 0.0, 1e-12);
    }

    #[test]
    fn objective_function_matches_result() {
        let mut rng = SplitMix64::new(7);
        let (pts, w) = blobs(&mut rng, &[(0.0, 0.0), (5.0, 5.0)], 30);
        let r = weighted_lloyd(&pts, &w, 2, &LloydConfig::new(2));
        let ev = objective(&pts, &w, 2, &r.centroids);
        assert_close(ev, r.objective, 1e-9);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = SplitMix64::new(9);
        let (pts, w) = blobs(&mut rng, &[(0.0, 0.0), (3.0, 3.0)], 20);
        let a = weighted_lloyd(&pts, &w, 2, &LloydConfig::new(2));
        let b = weighted_lloyd(&pts, &w, 2, &LloydConfig::new(2));
        assert_eq!(a.assign, b.assign);
        assert_eq!(a.centroids, b.centroids);
    }
}
