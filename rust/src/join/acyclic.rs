//! Cyclic-query fallback: greedy relation merging.
//!
//! All of the paper's workloads are α-acyclic, but the engine should not
//! fall over on a cyclic FEQ. `ensure_acyclic` repeatedly materializes the
//! pairwise natural join of the two relations sharing the most attributes
//! until GYO succeeds — a crude hypertree decomposition whose intermediate
//! size is bounded by the pairwise join sizes (fine at the scales where a
//! cyclic exploratory query is plausible).

use crate::data::{Database, Relation, Schema, Value};
use crate::query::{Feq, Hypergraph};
use crate::util::FxHashMap;
use anyhow::{bail, Result};

/// Natural join of two relations (hash join on all shared attributes).
/// Shared columns appear once, from `a`'s side.
pub fn pairwise_join(a: &Relation, b: &Relation, name: &str) -> Relation {
    let shared: Vec<String> = a
        .schema
        .attrs()
        .iter()
        .filter(|x| b.schema.contains(&x.name))
        .map(|x| x.name.clone())
        .collect();
    let a_key: Vec<usize> = shared.iter().map(|s| a.schema.index_of(s).expect("shared")).collect();
    let b_key: Vec<usize> = shared.iter().map(|s| b.schema.index_of(s).expect("shared")).collect();
    let b_extra: Vec<usize> = (0..b.n_cols())
        .filter(|&c| !shared.contains(&b.schema.attr(c).name))
        .collect();

    let mut attrs = a.schema.attrs().to_vec();
    for &c in &b_extra {
        attrs.push(b.schema.attr(c).clone());
    }
    let mut out = Relation::new(name, Schema::new(attrs));

    // Build side: index b by key.
    let mut idx: FxHashMap<Vec<u64>, Vec<u32>> = FxHashMap::default();
    for row in 0..b.n_rows() {
        let key: Vec<u64> = b_key.iter().map(|&c| b.col(c).key_u64(row)).collect();
        idx.entry(key).or_default().push(row as u32);
    }
    // Probe side.
    let mut vals: Vec<Value> = Vec::with_capacity(out.schema.len());
    for arow in 0..a.n_rows() {
        let key: Vec<u64> = a_key.iter().map(|&c| a.col(c).key_u64(arow)).collect();
        let Some(brows) = idx.get(&key) else { continue };
        for &brow in brows {
            vals.clear();
            for c in 0..a.n_cols() {
                vals.push(a.value(arow, c));
            }
            for &c in &b_extra {
                vals.push(b.value(brow as usize, c));
            }
            let w = a.weight(arow) * b.weight(brow as usize);
            if w == 1.0 {
                out.push_row(&vals);
            } else {
                out.push_row_weighted(&vals, w);
            }
        }
    }
    out
}

/// Rewrite `(db, feq)` into an acyclic equivalent by merging relations.
/// Returns the inputs unchanged (cheaply cloned) when already acyclic.
pub fn ensure_acyclic(db: &Database, feq: &Feq) -> Result<(Database, Feq)> {
    if Hypergraph::from_feq(db, feq).join_tree().is_ok() {
        return Ok((db.clone(), feq.clone()));
    }
    let mut db = db.clone();
    let mut feq = feq.clone();
    let mut merge_id = 0usize;
    loop {
        if Hypergraph::from_feq(&db, &feq).join_tree().is_ok() {
            return Ok((db, feq));
        }
        if feq.relations.len() < 2 {
            bail!("cannot acyclify a single-relation query (bug)");
        }
        // Pick the pair of participating relations sharing the most attrs.
        let mut best: Option<(usize, usize, usize)> = None;
        for i in 0..feq.relations.len() {
            for j in (i + 1)..feq.relations.len() {
                let a = db.get(&feq.relations[i]).expect("exists");
                let b = db.get(&feq.relations[j]).expect("exists");
                let shared =
                    a.schema.attrs().iter().filter(|x| b.schema.contains(&x.name)).count();
                if best.map(|(_, _, s)| shared > s).unwrap_or(true) {
                    best = Some((i, j, shared));
                }
            }
        }
        let (i, j, shared) = best.expect("≥2 relations");
        if shared == 0 {
            // Cartesian merge as a last resort — still correct.
        }
        let name = format!("__merged_{merge_id}");
        merge_id += 1;
        let joined = pairwise_join(
            db.get(&feq.relations[i]).expect("exists"),
            db.get(&feq.relations[j]).expect("exists"),
            &name,
        );
        db.add(joined);
        // Replace i and j with the merged relation in the FEQ.
        let (ri, rj) = (feq.relations[i].clone(), feq.relations[j].clone());
        feq.relations.retain(|r| r != &ri && r != &rj);
        feq.relations.push(name);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Attr;
    use crate::join::materialize;

    fn rel(name: &str, attrs: &[&str], rows: &[&[u32]]) -> Relation {
        let mut r = Relation::new(
            name,
            Schema::new(attrs.iter().map(|a| Attr::cat(a, 8)).collect()),
        );
        for row in rows {
            let vals: Vec<Value> = row.iter().map(|&v| Value::Cat(v)).collect();
            r.push_row(&vals);
        }
        r
    }

    #[test]
    fn pairwise_join_semantics() {
        let a = rel("a", &["x", "y"], &[&[0, 0], &[0, 1], &[1, 0]]);
        let b = rel("b", &["y", "z"], &[&[0, 5], &[0, 6], &[2, 7]]);
        let j = pairwise_join(&a, &b, "ab");
        // y=0 matches: a rows {0,2} × b rows {0,1} = 4 outputs.
        assert_eq!(j.n_rows(), 4);
        assert_eq!(j.schema.names(), vec!["x", "y", "z"]);
    }

    #[test]
    fn triangle_becomes_acyclic_and_preserves_join() {
        // R(a,b), S(b,c), T(c,a): classic triangle.
        let r = rel("r", &["a", "b"], &[&[0, 0], &[0, 1], &[1, 1]]);
        let s = rel("s", &["b", "c"], &[&[0, 0], &[1, 0], &[1, 1]]);
        let t = rel("t", &["c", "a"], &[&[0, 0], &[1, 1], &[1, 0]]);
        let mut db = Database::new();
        db.add(r);
        db.add(s);
        db.add(t);
        let feq = Feq::with_features(&["r", "s", "t"], &["a", "b", "c"]);
        assert!(Hypergraph::from_feq(&db, &feq).join_tree().is_err());

        let (db2, feq2) = ensure_acyclic(&db, &feq).unwrap();
        let tree = Hypergraph::from_feq(&db2, &feq2).join_tree().unwrap();
        let x = materialize(&db2, &feq2, &tree).unwrap();
        // Brute-force triangles: (a,b,c) with R(a,b),S(b,c),T(c,a):
        // (0,0,0): R✓ S✓ T✓ -> yes. (0,1,0): R✓ S(1,0)✓ T(0,0)✓ -> yes.
        // (0,1,1): R✓ S✓ T(1,0)✓ -> yes. (1,1,1): R✓ S✓ T(1,1)✓ -> yes.
        // (1,1,0): R✓ S(1,0)✓ T(0,1)? no. Total 4.
        assert_eq!(x.len(), 4);
    }

    #[test]
    fn acyclic_input_passes_through() {
        let a = rel("a", &["x", "y"], &[&[0, 0]]);
        let b = rel("b", &["y", "z"], &[&[0, 5]]);
        let mut db = Database::new();
        db.add(a);
        db.add(b);
        let feq = Feq::with_features(&["a", "b"], &["x", "z"]);
        let (db2, feq2) = ensure_acyclic(&db, &feq).unwrap();
        assert_eq!(db2.relations().len(), 2);
        assert_eq!(feq2.relations, feq.relations);
    }

    #[test]
    fn weighted_join_multiplies() {
        let mut a = Relation::new("a", Schema::new(vec![Attr::cat("x", 4)]));
        a.push_row_weighted(&[Value::Cat(0)], 3.0);
        let mut b = Relation::new("b", Schema::new(vec![Attr::cat("x", 4), Attr::cat("y", 4)]));
        b.push_row_weighted(&[Value::Cat(0), Value::Cat(1)], 2.0);
        let j = pairwise_join(&a, &b, "ab");
        assert_eq!(j.n_rows(), 1);
        assert_eq!(j.weight(0), 6.0);
    }
}
