//! Bench E1 — FAQ engine throughput: the cost of Step 1 (two-pass
//! marginals) and Step 3 (free-variable grid weights) against full join
//! materialization on the same data. This is the substrate behind
//! Theorem 4.7's claim that Rk-means can run faster than even *computing*
//! the data matrix.

use rkmeans::bench_harness::bench;
use rkmeans::coreset::solve_subspaces;
use rkmeans::faq::{full_join_counts, marginals};
use rkmeans::join::materialize;
use rkmeans::query::Hypergraph;
use rkmeans::synthetic::{Dataset, Scale};

fn main() -> anyhow::Result<()> {
    let scale: f64 =
        std::env::var("RKMEANS_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.05);
    for ds in Dataset::all() {
        let db = ds.generate(Scale::custom(scale), 42);
        let feq = ds.feq();
        let tree = Hypergraph::from_feq(&db, &feq).join_tree()?;

        let m1 = bench(&format!("{}: step1 marginals (2-pass FAQ)", ds.name()), 1, 3, || {
            let jc = full_join_counts(&db, &tree).expect("counts");
            marginals(&db, &feq, &tree, &jc).expect("marginals")
        });
        println!("{}", m1.line());

        let jc = full_join_counts(&db, &tree)?;
        let margs = marginals(&db, &feq, &tree, &jc)?;
        let models = solve_subspaces(&feq, &margs, 10)?;
        let m3 = bench(&format!("{}: step3 grid weights (free-var FAQ)", ds.name()), 1, 3, || {
            rkmeans::coreset::build_grid(&db, &feq, &tree, &models).expect("grid")
        });
        println!("{}", m3.line());

        let mx = bench(&format!("{}: materialize X (baseline)", ds.name()), 0, 2, || {
            materialize(&db, &feq, &tree).expect("materialize")
        });
        println!("{}", mx.line());
        println!(
            "  -> steps 1+3 vs materialize: {:.2}× faster\n",
            mx.min() / (m1.min() + m3.min())
        );
    }
    Ok(())
}
