//! `rklint` — the repo-native determinism & concurrency static-analysis
//! pass.
//!
//! The bitwise contracts this codebase ships (naive≡pruned,
//! patch≡rebuild, shard≡serial, `apply(diff(a,b))≡b`) rest on a handful
//! of unwritten conventions: parallel compute routes through
//! [`ExecPool`](crate::util::exec::ExecPool), nothing iterates a hash
//! map where order can reach floating-point accumulation or the wire,
//! deterministic paths never read the wall clock, wire encode/decode
//! never truncates silently, and lock/channel failures carry context.
//! `rklint` turns those conventions into deny-by-default rules checked
//! at CI time, so a violation fails tier-1 instead of waiting for a
//! property test's schedule to catch it.
//!
//! ## Rules
//!
//! | rule | guards |
//! |------|--------|
//! | `rogue-thread` | all thread creation lives in `util::exec` or the explicit [`rules::SPAWN_REGISTRY`] |
//! | `nondet-iteration` | no storage-order iteration of `HashMap`/`HashSet`/`FxHashMap`/`FxHashSet`; use [`util::det`](crate::util::det) |
//! | `wall-clock-in-core` | `Instant::now`/`SystemTime` only in `metrics`, `bench_harness`, `serve::load`, `util::timer` |
//! | `unchecked-cast-in-wire` | no bare `as` numeric casts in `rkmeans/model.rs` + `serve/delta.rs` + `serve/rpc/wire.rs` |
//! | `contextless-unwrap` | no `.unwrap()` on lock/channel results in `serve/` + `util/exec.rs` |
//! | `unbounded-channel` | every queue is bounded: no `mpsc::channel()` / `sync_channel(0)` outside the explicit [`rules::QUEUE_REGISTRY`] |
//!
//! A site that is genuinely legitimate carries an inline waiver **with a
//! mandatory reason**:
//!
//! ```text
//! // rklint::allow(nondet-iteration, reason = "ring-ℤ exact merge; order-free by construction")
//! ```
//!
//! on the flagged line or the line above. Waivers naming unknown rules
//! or omitting the reason are themselves diagnostics (`invalid-waiver`)
//! and cannot be waived — the escape hatch audits itself.
//!
//! The scanner ([`scan`]) masks comments, string literals (plain, raw,
//! byte), and char literals before tokenizing, so rules never misfire
//! on documentation or error messages, and it requires no external
//! parser — the build stays hermetic. `tests/lint_gate.rs` runs the
//! pass over the real tree in tier-1 and seeds synthetic violations to
//! prove each rule still fires; `src/bin/rklint.rs` is the CLI driver
//! whose `--report` JSON lands in CI artifacts next to the `BENCH_*`
//! trajectory.

pub mod rules;
pub mod scan;

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Every rule slug `rklint` knows, including the meta-rule for bad
/// waiver annotations.
pub const RULES: &[&str] = &[
    "rogue-thread",
    "nondet-iteration",
    "wall-clock-in-core",
    "unchecked-cast-in-wire",
    "contextless-unwrap",
    "unbounded-channel",
    "invalid-waiver",
];

/// One finding at a source location. `waived == true` means the site
/// carries a justification (inline waiver or registry entry) and does
/// not fail the build — it still appears in the report so the full
/// waiver surface is auditable per commit.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    /// Rule slug from [`RULES`].
    pub rule: &'static str,
    /// Path relative to the crate root, forward slashes.
    pub file: String,
    /// 1-based line of the flagged token.
    pub line: usize,
    /// Human-readable finding, including the suggested fix.
    pub message: String,
    /// Whether a waiver (or registry entry) covers this site.
    pub waived: bool,
    /// The justification when waived.
    pub waiver_reason: Option<String>,
}

/// The result of linting a tree: all diagnostics (active + waived) in
/// (file, line) order, plus scan statistics.
#[derive(Debug, Default)]
pub struct Report {
    /// Every finding, waived or not.
    pub diagnostics: Vec<Diagnostic>,
    /// Number of `.rs` files scanned.
    pub files: usize,
}

impl Report {
    /// Findings that fail the build (not waived).
    pub fn active(&self) -> impl Iterator<Item = &Diagnostic> {
        self.diagnostics.iter().filter(|d| !d.waived)
    }

    /// Number of waived findings.
    pub fn waived(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.waived).count()
    }

    /// Machine-readable form for CI artifact archiving (stable key
    /// order via the `util::json` BTreeMap writer).
    pub fn to_json(&self) -> Json {
        let mut root = BTreeMap::new();
        root.insert("format".to_string(), Json::Str("rklint-report".to_string()));
        root.insert("version".to_string(), Json::Num(1.0));
        root.insert("files_scanned".to_string(), Json::Num(self.files as f64));
        root.insert("active".to_string(), Json::Num(self.active().count() as f64));
        root.insert("waived".to_string(), Json::Num(self.waived() as f64));
        root.insert(
            "rules".to_string(),
            Json::Arr(RULES.iter().map(|r| Json::Str(r.to_string())).collect()),
        );
        let diags = self
            .diagnostics
            .iter()
            .map(|d| {
                let mut o = BTreeMap::new();
                o.insert("rule".to_string(), Json::Str(d.rule.to_string()));
                o.insert("file".to_string(), Json::Str(d.file.clone()));
                o.insert("line".to_string(), Json::Num(d.line as f64));
                o.insert("message".to_string(), Json::Str(d.message.clone()));
                o.insert("waived".to_string(), Json::Bool(d.waived));
                if let Some(r) = &d.waiver_reason {
                    o.insert("reason".to_string(), Json::Str(r.clone()));
                }
                Json::Obj(o)
            })
            .collect();
        root.insert("diagnostics".to_string(), Json::Arr(diags));
        Json::Obj(root)
    }
}

/// Lint a single source text under its crate-relative path. This is
/// the unit the gate test drives with synthetic-violation fixtures.
pub fn lint_source(rel_path: &str, source: &str) -> Vec<Diagnostic> {
    let scanned = scan::scan(source);
    let mut diags = rules::check(rel_path, &scanned);
    apply_waivers(&mut diags, &scanned.waivers);
    diags.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    diags
}

/// Match diagnostics against inline waivers: a waiver covers findings
/// of its rule on its own line and the line directly below. Waivers
/// without a reason never match (and are already reported as
/// `invalid-waiver` by the rules pass); `invalid-waiver` itself cannot
/// be waived.
fn apply_waivers(diags: &mut [Diagnostic], waivers: &[scan::Waiver]) {
    for d in diags.iter_mut() {
        if d.waived || d.rule == "invalid-waiver" {
            continue;
        }
        if let Some(w) = waivers.iter().find(|w| {
            w.rule == d.rule && w.reason.is_some() && (w.line == d.line || w.line + 1 == d.line)
        }) {
            d.waived = true;
            d.waiver_reason = w.reason.clone();
        }
    }
}

/// Lint every `.rs` file under `root` (recursively, sorted traversal).
/// Paths in the report are relative to `root`'s parent, i.e. they read
/// `src/…` when `root` is the crate's `src` directory.
pub fn lint_tree(root: &Path) -> Result<Report> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)
        .with_context(|| format!("walking {}", root.display()))?;
    files.sort();
    let base = root.parent().unwrap_or(root);
    let mut report = Report::default();
    for path in &files {
        let source = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let rel = path
            .strip_prefix(base)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        report.diagnostics.extend(lint_source(&rel, &source));
        report.files += 1;
    }
    Ok(report)
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn waiver_covers_same_line_and_next_line() {
        let src = "\
fn core() {
    // rklint::allow(wall-clock-in-core, reason = \"demo\")
    let t = Instant::now();
    let u = Instant::now(); // rklint::allow(wall-clock-in-core, reason = \"demo2\")
    let v = Instant::now();
}
";
        let diags = lint_source("src/cluster/x.rs", src);
        let active: Vec<_> = diags.iter().filter(|d| !d.waived).collect();
        assert_eq!(active.len(), 1, "only the unwaived site stays active: {diags:?}");
        assert_eq!(active[0].line, 5);
        assert_eq!(diags.iter().filter(|d| d.waived).count(), 2);
    }

    #[test]
    fn reasonless_waiver_does_not_suppress_and_is_flagged() {
        let src = "\
fn core() {
    // rklint::allow(wall-clock-in-core)
    let t = Instant::now();
}
";
        let diags = lint_source("src/cluster/x.rs", src);
        assert!(diags.iter().any(|d| d.rule == "invalid-waiver" && !d.waived));
        assert!(diags.iter().any(|d| d.rule == "wall-clock-in-core" && !d.waived));
    }

    #[test]
    fn unknown_rule_waiver_is_flagged() {
        let diags =
            lint_source("src/x.rs", "// rklint::allow(made-up-rule, reason = \"nope\")\n");
        assert!(diags.iter().any(|d| d.rule == "invalid-waiver" && !d.waived));
    }

    #[test]
    fn report_json_shape() {
        let mut report = Report { diagnostics: Vec::new(), files: 3 };
        report.diagnostics.extend(lint_source(
            "src/cluster/x.rs",
            "fn f() { let t = Instant::now(); }\n",
        ));
        let j = report.to_json().to_string();
        assert!(j.contains("\"format\":\"rklint-report\""));
        assert!(j.contains("\"active\":1"));
        assert!(j.contains("wall-clock-in-core"));
    }
}
