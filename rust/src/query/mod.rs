//! Feature-extraction queries (FEQs) and their hypergraph structure.
//!
//! An FEQ is the natural join of a set of relations projected onto a list of
//! feature attributes. Its hypergraph (vertices = attributes, hyperedges =
//! relations) determines whether the join is *acyclic* — in which case a
//! GYO-derived join tree drives the Yannakakis/InsideOut message passing
//! used throughout Rk-means — and bounds the size of the materialized
//! output (`|X| ≤ N^ρ*`, fractional edge cover, paper §4.4).

pub mod feq;
pub mod hypergraph;

pub use feq::{Feq, FeatureSpec};
pub use hypergraph::{Hypergraph, JoinTree};
