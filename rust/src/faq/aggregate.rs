//! Generic scalar FAQ aggregates over the join (paper §2.1, Eq. 1).
//!
//! The paper's motivating FEQ computes `max(transactions.count)` — a
//! max-product FAQ. This module evaluates `⊕_{x ∈ X} ⊗_{F} ψ_F(x_F)` for
//! any [`Semiring`] with one upward InsideOut pass over the join tree:
//! sum-product recovers (weighted) counting, max-product the paper's MAX
//! aggregate, min-plus tropical costs.

use crate::data::{Database, Relation};
use crate::query::JoinTree;
use crate::util::FxHashMap;
use anyhow::{Context, Result};

use super::semiring::Semiring;

/// Per-tuple factor value ψ_F(t): relation name + row → value. Return the
/// semiring's `one()` for relations that are pure existence predicates.
pub type FactorFn<'a> = &'a dyn Fn(&Relation, usize) -> f64;

/// Evaluate the scalar FAQ `⊕_x ⊗_F ψ_F(x_F)` over the join output.
/// Returns the semiring zero for an empty join.
pub fn scalar_aggregate(
    db: &Database,
    tree: &JoinTree,
    semiring: Semiring,
    factor: FactorFn<'_>,
) -> Result<f64> {
    let n = tree.len();
    let children: Vec<Vec<usize>> = (0..n).map(|u| tree.children(u)).collect();
    let mut msgs: Vec<Option<FxHashMap<Vec<u64>, f64>>> = (0..n).map(|_| None).collect();

    for &u in &tree.order {
        let rel = db
            .get(&tree.rel_names[u])
            .with_context(|| format!("relation {} missing", tree.rel_names[u]))?;
        let child_cols: Vec<(usize, Vec<usize>)> = children[u]
            .iter()
            .map(|&c| {
                let cols = tree.sep[c]
                    .iter()
                    .map(|a| rel.schema.index_of(a).expect("sep attr in parent"))
                    .collect();
                (c, cols)
            })
            .collect();
        let sep_cols: Vec<usize> = tree.sep[u]
            .iter()
            .map(|a| rel.schema.index_of(a).expect("sep attr in node"))
            .collect();

        let mut out: FxHashMap<Vec<u64>, f64> = FxHashMap::default();
        let mut keybuf: Vec<u64> = Vec::new();
        'rows: for row in 0..rel.n_rows() {
            let mut val = factor(rel, row);
            for (c, cols) in &child_cols {
                keybuf.clear();
                for &cc in cols {
                    keybuf.push(rel.col(cc).key_u64(row));
                }
                match msgs[*c].as_ref().expect("child processed").get(keybuf.as_slice()) {
                    Some(&m) => val = semiring.mul(val, m),
                    None => continue 'rows, // dangling
                }
            }
            keybuf.clear();
            for &sc in &sep_cols {
                keybuf.push(rel.col(sc).key_u64(row));
            }
            match out.get_mut(keybuf.as_slice()) {
                Some(slot) => *slot = semiring.add(*slot, val),
                None => {
                    out.insert(keybuf.clone(), val);
                }
            }
        }
        msgs[u] = Some(out);
    }

    let root = msgs[tree.root].take().expect("root processed");
    Ok(root.into_values().next().unwrap_or_else(|| semiring.zero()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Attr, Schema, Value};
    use crate::query::{Feq, Hypergraph};

    /// The paper's intro query: product ⋈ transactions ⋈ store with a MAX
    /// over transactions.count.
    fn setup() -> (Database, JoinTree) {
        let mut product =
            Relation::new("product", Schema::new(vec![Attr::cat("item", 4)]));
        for i in 0..3u32 {
            product.push_row(&[Value::Cat(i)]);
        }
        let mut store = Relation::new("store", Schema::new(vec![Attr::cat("store", 3)]));
        for s in 0..2u32 {
            store.push_row(&[Value::Cat(s)]);
        }
        let mut tx = Relation::new(
            "tx",
            Schema::new(vec![Attr::cat("item", 4), Attr::cat("store", 3), Attr::double("count")]),
        );
        for (i, s, c) in [(0u32, 0u32, 5.0), (0, 1, 7.0), (1, 0, 2.0), (3, 0, 99.0)] {
            tx.push_row(&[Value::Cat(i), Value::Cat(s), Value::Double(c)]);
        }
        let mut db = Database::new();
        db.add(product);
        db.add(store);
        db.add(tx);
        let feq = Feq::with_features(&["tx", "product", "store"], &["item"]);
        let tree = Hypergraph::from_feq(&db, &feq).join_tree().unwrap();
        (db, tree)
    }

    #[test]
    fn max_product_reproduces_intro_query() {
        let (db, tree) = setup();
        // ψ_tx = count, ψ_product = ψ_store = 1 (existence predicates).
        // Tuple (3,0) dangles (item 3 not in product): max = 7, not 99.
        let max = scalar_aggregate(&db, &tree, Semiring::MaxProduct, &|rel, row| {
            if rel.name == "tx" {
                rel.value(row, 2).as_f64()
            } else {
                1.0
            }
        })
        .unwrap();
        assert_eq!(max, 7.0);
    }

    #[test]
    fn sum_product_equals_output_size() {
        let (db, tree) = setup();
        let count = scalar_aggregate(&db, &tree, Semiring::SumProduct, &|rel, row| {
            rel.weight(row)
        })
        .unwrap();
        let direct = crate::faq::output_size(&db, &tree).unwrap();
        assert_eq!(count, direct);
        assert_eq!(count, 3.0);
    }

    #[test]
    fn min_plus_finds_cheapest_join_tuple() {
        let (db, tree) = setup();
        // Cost = tx.count, other relations free: min over joining tuples.
        let min = scalar_aggregate(&db, &tree, Semiring::MinPlus, &|rel, row| {
            if rel.name == "tx" {
                rel.value(row, 2).as_f64()
            } else {
                0.0
            }
        })
        .unwrap();
        assert_eq!(min, 2.0);
    }

    #[test]
    fn empty_join_returns_zero_element() {
        let (mut db, _) = setup();
        *db.get_mut("tx").unwrap() = Relation::new(
            "tx",
            Schema::new(vec![Attr::cat("item", 4), Attr::cat("store", 3), Attr::double("count")]),
        );
        let feq = Feq::with_features(&["tx", "product", "store"], &["item"]);
        let tree = Hypergraph::from_feq(&db, &feq).join_tree().unwrap();
        let max = scalar_aggregate(&db, &tree, Semiring::MaxProduct, &|_, _| 1.0).unwrap();
        assert_eq!(max, f64::NEG_INFINITY);
    }
}
