//! Dense weighted Lloyd on row-major points through the shared engine:
//! k-means++ seeding, the tiled microkernel for full scans, Hamerly bounds
//! to skip unchanged assignments, and chunk-parallel accumulation. See the
//! parent module docs for the bounds invariants and determinism contract.

use super::microkernel::{self, TILE};
use super::{resolve_threads, run_chunks, EngineOpts, PruneStats, CHUNK, SLACK_REL};
use crate::cluster::kmeanspp::kmeanspp_indices;
use crate::cluster::lloyd::{LloydConfig, LloydResult};
use crate::util::SplitMix64;
use std::time::Instant;

/// Per-chunk accumulator, reduced in chunk order after each pass.
struct Accum {
    sums: Vec<f64>,
    mass: Vec<f64>,
    obj: f64,
    evals: u64,
    skipped: u64,
    max_dd: f64,
}

impl Accum {
    fn new(k: usize, d: usize) -> Self {
        Accum {
            sums: vec![0.0; k * d],
            mass: vec![0.0; k],
            obj: 0.0,
            evals: 0,
            skipped: 0,
            max_dd: 0.0,
        }
    }
}

/// One chunk's view of the per-point state (disjoint mutable slices).
struct DenseChunk<'a> {
    pts: &'a [f64],
    w: &'a [f64],
    xnorm: &'a [f64],
    assign: &'a mut [u32],
    mind2: &'a mut [f64],
    lb: &'a mut [f64],
    acc: Accum,
}

/// Read-only per-iteration context shared by all chunks.
struct PassCtx<'a> {
    d: usize,
    k: usize,
    ct_t: &'a [f64],
    cnorm: &'a [f64],
    drift_max: f64,
    s_half: &'a [f64],
    slack: f64,
    /// Bounds are valid and may be used to skip (pruning + not first
    /// iteration + no reseed last iteration).
    use_bounds: bool,
    /// Maintain ub/lb on full scans (pruning enabled at all).
    pruning: bool,
}

/// One assignment + accumulation pass over a chunk.
fn assign_chunk(ch: &mut DenseChunk, ctx: &PassCtx) {
    let (d, k) = (ctx.d, ctx.k);
    let n = ch.w.len();

    // Phase 1: bounds test. Points that cannot be proven unchanged are
    // queued (in index order) for a full tiled scan.
    let mut scan: Vec<u32> = Vec::with_capacity(n);
    if ctx.use_bounds {
        for i in 0..n {
            let a = ch.assign[i] as usize;
            // Drift the bounds by the centroid movement since last pass.
            let lbv = ch.lb[i] - ctx.drift_max;
            ch.lb[i] = lbv;
            // The upper bound is the exact assigned distance, recomputed
            // here every pass (one evaluation) — which also keeps the
            // reported objective exact for skipped points, and uses the
            // same arithmetic as a full scan. Being exact each pass, it
            // needs no cross-iteration storage (only `lb` persists).
            let x = &ch.pts[i * d..(i + 1) * d];
            let dot = microkernel::dot_one(x, ctx.ct_t, k, a);
            let dd = ch.xnorm[i] - 2.0 * dot + ctx.cnorm[a];
            let dd = dd.max(0.0);
            let da = dd.sqrt();
            ch.acc.evals += 1;
            let m = ctx.s_half[a].max(lbv);
            if da + ctx.slack < m {
                // Provably still closest (strictly, even under ties and FP
                // rounding — see module docs), so skip the k-loop.
                ch.mind2[i] = dd;
                ch.acc.skipped += k as u64 - 1;
                if dd > ch.acc.max_dd {
                    ch.acc.max_dd = dd;
                }
            } else {
                scan.push(i as u32);
            }
        }
    } else {
        scan.extend(0..n as u32);
    }

    // Phase 2: full scans, tiled through the microkernel.
    let mut tile = vec![0.0f64; TILE * d];
    let mut dots = vec![0.0f64; TILE * k];
    for group in scan.chunks(TILE) {
        let tp = group.len();
        for (p, &gi) in group.iter().enumerate() {
            let i = gi as usize;
            tile[p * d..(p + 1) * d].copy_from_slice(&ch.pts[i * d..(i + 1) * d]);
        }
        microkernel::tile_dots(&tile[..tp * d], d, k, ctx.ct_t, &mut dots);
        for (p, &gi) in group.iter().enumerate() {
            let i = gi as usize;
            let (d1, c1, d2) =
                microkernel::best_two_expanded(ch.xnorm[i], &dots[p * k..(p + 1) * k], ctx.cnorm);
            let dd = d1.max(0.0);
            ch.assign[i] = c1;
            ch.mind2[i] = dd;
            ch.acc.evals += k as u64;
            if dd > ch.acc.max_dd {
                ch.acc.max_dd = dd;
            }
            if ctx.pruning {
                if d2.is_finite() {
                    let dd2 = d2.max(0.0);
                    ch.lb[i] = dd2.sqrt();
                    if dd2 > ch.acc.max_dd {
                        ch.acc.max_dd = dd2;
                    }
                } else {
                    ch.lb[i] = f64::INFINITY;
                }
            }
        }
    }

    // Phase 3: objective + update accumulation, in point order — identical
    // order for naive and pruned passes, so the reductions match bitwise.
    for i in 0..n {
        let w = ch.w[i];
        let c = ch.assign[i] as usize;
        ch.acc.obj += w * ch.mind2[i];
        ch.acc.mass[c] += w;
        let x = &ch.pts[i * d..(i + 1) * d];
        let s = &mut ch.acc.sums[c * d..(c + 1) * d];
        for (sv, &xv) in s.iter_mut().zip(x) {
            *sv += w * xv;
        }
    }
}

/// Weighted Lloyd over `n × d` row-major `points` with engine options.
/// Returns the result plus pruning/throughput statistics.
pub fn lloyd_dense(
    points: &[f64],
    weights: &[f64],
    d: usize,
    cfg: &LloydConfig,
    opts: &EngineOpts,
) -> (LloydResult, PruneStats) {
    assert!(d > 0, "dimension must be positive");
    assert_eq!(points.len() % d, 0, "points not a multiple of d");
    let n = points.len() / d;
    assert_eq!(weights.len(), n, "weights length mismatch");
    assert!(n > 0, "no points");
    // k-means++ always yields at least one seed, so treat k = 0 as 1.
    let k = cfg.k.min(n).max(1);
    let t0 = Instant::now();

    let row = |i: usize| &points[i * d..(i + 1) * d];
    let dist2 = |a: &[f64], b: &[f64]| -> f64 {
        let mut s = 0.0;
        for (x, y) in a.iter().zip(b) {
            let t = x - y;
            s += t * t;
        }
        s
    };

    // k-means++ seeding (identical to the pre-engine implementation).
    let mut rng = SplitMix64::new(cfg.seed);
    let seeds = kmeanspp_indices(n, weights, k, &mut rng, |i, j| dist2(row(i), row(j)));
    let mut centroids: Vec<f64> = Vec::with_capacity(k * d);
    for &s in &seeds {
        centroids.extend_from_slice(row(s));
    }

    // Invariant per-point geometry.
    let xnorm: Vec<f64> = (0..n).map(|i| row(i).iter().map(|v| v * v).sum()).collect();
    let xn_max = xnorm.iter().cloned().fold(0.0f64, f64::max);

    let threads = resolve_threads(opts.threads);
    let mut assign = vec![0u32; n];
    let mut mind2 = vec![0.0f64; n];
    let mut lb = vec![0.0f64; n];
    let mut drift = vec![0.0f64; k];
    let mut s_half = vec![0.0f64; k];
    let mut bounds_valid = false;
    let mut max_dd = 0.0f64;

    let mut ct_t: Vec<f64> = Vec::new();
    let mut objective = f64::INFINITY;
    let mut iters = 0;
    let mut stats = PruneStats { points: n as u64, ..PruneStats::default() };

    for it in 0..cfg.max_iters.max(1) {
        iters = it + 1;

        // Per-iteration centroid geometry.
        let mut cnorm = vec![0.0f64; k];
        for (c, cc) in centroids.chunks_exact(d).enumerate() {
            cnorm[c] = cc.iter().map(|v| v * v).sum();
        }
        microkernel::transpose(&centroids, d, k, &mut ct_t);
        let use_bounds = opts.pruning && bounds_valid;
        if use_bounds {
            // Half-distance to the nearest other centroid (Hamerly's s).
            for c in 0..k {
                let mut best = f64::INFINITY;
                for c2 in 0..k {
                    if c2 != c {
                        let dd = dist2(&centroids[c * d..(c + 1) * d], &centroids[c2 * d..(c2 + 1) * d]);
                        if dd < best {
                            best = dd;
                        }
                    }
                }
                s_half[c] = 0.5 * best.max(0.0).sqrt();
            }
        }
        let drift_max = drift.iter().cloned().fold(0.0f64, f64::max);
        let slack = SLACK_REL * (1.0 + max_dd.sqrt() + xn_max.sqrt());
        let ctx = PassCtx {
            d,
            k,
            ct_t: &ct_t,
            cnorm: &cnorm,
            drift_max,
            s_half: &s_half,
            slack,
            use_bounds,
            pruning: opts.pruning,
        };

        // Chunked assignment pass (fixed CHUNK ranges; see module docs).
        let accs: Vec<Accum> = {
            let mut chunks: Vec<DenseChunk> = Vec::with_capacity(n.div_ceil(CHUNK));
            let parts = assign
                .chunks_mut(CHUNK)
                .zip(mind2.chunks_mut(CHUNK))
                .zip(lb.chunks_mut(CHUNK));
            let mut start = 0usize;
            for ((a_s, m_s), l_s) in parts {
                let len = a_s.len();
                chunks.push(DenseChunk {
                    pts: &points[start * d..(start + len) * d],
                    w: &weights[start..start + len],
                    xnorm: &xnorm[start..start + len],
                    assign: a_s,
                    mind2: m_s,
                    lb: l_s,
                    acc: Accum::new(k, d),
                });
                start += len;
            }
            run_chunks(&mut chunks, threads, |_, ch| assign_chunk(ch, &ctx));
            chunks.into_iter().map(|c| c.acc).collect()
        };

        // Fixed-order reduction of the chunk accumulators.
        let mut sums = vec![0.0f64; k * d];
        let mut mass = vec![0.0f64; k];
        let mut obj = 0.0f64;
        for a in &accs {
            for (sv, &v) in sums.iter_mut().zip(&a.sums) {
                *sv += v;
            }
            for (mv, &v) in mass.iter_mut().zip(&a.mass) {
                *mv += v;
            }
            obj += a.obj;
            stats.dist_evals += a.evals;
            stats.dist_evals_skipped += a.skipped;
            if a.max_dd > max_dd {
                max_dd = a.max_dd;
            }
        }

        // Update step (+ drift for the next iteration's bounds).
        let mut reseeded = false;
        for c in 0..k {
            if mass[c] > 0.0 {
                let mut dr = 0.0;
                for j in 0..d {
                    let nv = sums[c * d + j] / mass[c];
                    let ov = centroids[c * d + j];
                    let t = nv - ov;
                    dr += t * t;
                    centroids[c * d + j] = nv;
                }
                drift[c] = dr.sqrt();
            } else {
                // Empty cluster: reseed at the point with the largest
                // weighted distance-to-centroid contribution.
                let far = (0..n)
                    .max_by(|&a, &b| {
                        (weights[a] * mind2[a])
                            .partial_cmp(&(weights[b] * mind2[b]))
                            .expect("finite")
                    })
                    .expect("n > 0");
                centroids[c * d..(c + 1) * d].copy_from_slice(row(far));
                mind2[far] = 0.0;
                reseeded = true;
            }
        }
        // A reseed teleports a centroid arbitrarily far; rebuild bounds
        // from scratch next iteration instead of trying to drift them.
        bounds_valid = opts.pruning && !reseeded;

        // Convergence on relative objective improvement.
        if objective.is_finite() {
            let improve = (objective - obj) / objective.abs().max(1e-30);
            if improve.abs() < cfg.tol {
                objective = obj;
                break;
            }
        }
        objective = obj;
    }

    stats.iters = iters;
    stats.wall = t0.elapsed();
    (LloydResult { centroids, assign, objective, iters }, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::for_cases;

    fn clustered(rng: &mut SplitMix64, n: usize, d: usize, spread: f64) -> (Vec<f64>, Vec<f64>) {
        // A few gaussian blobs: the regime where pruning actually bites.
        let n_blobs = 4;
        let centers: Vec<f64> = (0..n_blobs * d).map(|_| rng.uniform(-8.0, 8.0)).collect();
        let mut pts = Vec::with_capacity(n * d);
        for _ in 0..n {
            let b = rng.below(n_blobs as u64) as usize;
            for j in 0..d {
                pts.push(centers[b * d + j] + spread * rng.normal());
            }
        }
        let w = (0..n).map(|_| rng.uniform(0.1, 2.0)).collect();
        (pts, w)
    }

    #[test]
    fn pruned_skips_work_on_clustered_data() {
        let mut rng = SplitMix64::new(21);
        let (pts, w) = clustered(&mut rng, 3000, 6, 0.1);
        let cfg = LloydConfig { k: 8, max_iters: 12, tol: 0.0, seed: 5 };
        let (_, stats) = lloyd_dense(&pts, &w, 6, &cfg, &EngineOpts::pruned());
        assert!(
            stats.skip_rate() > 0.3,
            "expected meaningful pruning, got skip rate {:.3}",
            stats.skip_rate()
        );
        let (_, naive) = lloyd_dense(&pts, &w, 6, &cfg, &EngineOpts::naive_serial());
        assert_eq!(naive.dist_evals_skipped, 0);
        assert!(naive.dist_evals > stats.dist_evals);
    }

    #[test]
    fn pruned_parallel_matches_naive_bitwise() {
        for_cases(10, |rng| {
            let n = 50 + rng.below(400) as usize;
            let d = 1 + rng.below(5) as usize;
            let k = 1 + rng.below(7) as usize;
            let (pts, w) = clustered(rng, n, d, 0.3);
            let iters = 1 + rng.below(8) as usize;
            let cfg = LloydConfig { k, max_iters: iters, tol: 0.0, seed: rng.next_u64() };
            let (a, _) = lloyd_dense(&pts, &w, d, &cfg, &EngineOpts::naive_serial());
            let (b, _) = lloyd_dense(&pts, &w, d, &cfg, &EngineOpts::pruned().with_threads(3));
            assert_eq!(a.assign, b.assign);
            assert_eq!(a.centroids, b.centroids);
            assert_eq!(a.objective.to_bits(), b.objective.to_bits());
            assert_eq!(a.iters, b.iters);
        });
    }

    #[test]
    fn multi_chunk_is_thread_count_invariant() {
        // n > CHUNK exercises the chunked reduction; every thread count
        // must reduce to identical bits.
        let mut rng = SplitMix64::new(33);
        let n = CHUNK + 700;
        let (pts, w) = clustered(&mut rng, n, 3, 0.2);
        let cfg = LloydConfig { k: 6, max_iters: 5, tol: 0.0, seed: 7 };
        let (base, _) = lloyd_dense(&pts, &w, 3, &cfg, &EngineOpts::pruned().with_threads(1));
        for t in [2usize, 4, 8] {
            let (r, _) = lloyd_dense(&pts, &w, 3, &cfg, &EngineOpts::pruned().with_threads(t));
            assert_eq!(base.assign, r.assign, "threads={t}");
            assert_eq!(base.centroids, r.centroids, "threads={t}");
            assert_eq!(base.objective.to_bits(), r.objective.to_bits(), "threads={t}");
        }
    }
}
