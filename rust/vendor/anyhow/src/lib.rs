//! A minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! The build environment is offline, so instead of pulling `anyhow` from a
//! registry we vendor the small subset this repository actually uses:
//!
//! * [`Error`] — a context-chained dynamic error (`Display` prints the
//!   outermost message; `{:#}` prints the whole chain, `Debug` prints a
//!   "Caused by" listing like the real crate);
//! * [`Result<T>`] with the `Error` default;
//! * the [`anyhow!`], [`bail!`] and [`ensure!`] macros;
//! * the [`Context`] extension trait on `Result` and `Option`.
//!
//! Like the real crate, `Error` deliberately does **not** implement
//! `std::error::Error`, which is what makes the blanket
//! `From<E: std::error::Error>` conversion (and therefore `?`) coherent.

use std::fmt;

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error with a chain of context messages.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Construct from a displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: c.to_string(), source: Some(Box::new(self)) }
    }

    /// Iterate the chain outermost-first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut cur = Some(self);
        std::iter::from_fn(move || {
            let e = cur?;
            cur = e.source.as_deref();
            Some(e.msg.as_str())
        })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.source.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Flatten the std source chain into our context chain.
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut out: Option<Error> = None;
        for m in msgs.into_iter().rev() {
            out = Some(Error { msg: m, source: out.map(Box::new) });
        }
        out.expect("at least one message")
    }
}

/// Attach context to the error variant of a `Result` (or to `None`).
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T>;
    /// Wrap the error with a lazily-built context message.
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($tt)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($tt:tt)+) => {
        if !($cond) {
            $crate::bail!($($tt)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.to_string(), "gone");
    }

    #[test]
    fn context_chains_and_alternate_prints() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
        assert_eq!(Some(3u32).context("x").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(f(3).unwrap_err().to_string(), "three is right out");
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let e = anyhow!("fmt {}", 5);
        assert_eq!(e.to_string(), "fmt 5");
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.chain().collect::<Vec<_>>(), vec!["outer", "inner"]);
    }
}
