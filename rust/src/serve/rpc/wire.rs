//! Wire codec for the socket RPC tier: length-prefixed frames plus the
//! payload encodings that are *not* already covered by the model wire
//! formats (`RkModel::to_bytes` / `ModelDelta::to_bytes` travel as
//! opaque payloads).
//!
//! # Frame format
//!
//! ```text
//! [u32 LE total_len] [u8 kind] [payload; total_len - 1]
//! ```
//!
//! `total_len` counts the kind byte plus the payload, so an empty frame
//! has `total_len == 1`. Frames larger than [`MAX_FRAME`] are rejected
//! on both encode and decode — a corrupt length prefix must not drive
//! an allocation.
//!
//! # Determinism contract
//!
//! This file is covered by rklint's `unchecked-cast-in-wire` rule
//! (alongside `rkmeans/model.rs` and `serve/delta.rs`): every numeric
//! conversion goes through `try_from` / `from_le_bytes` / bit casts, so
//! a row or counter that does not fit its wire field is a checked error,
//! never a silent truncation. Encoding is bitwise-deterministic: the
//! same values always produce the same bytes (f64 travels as its IEEE
//! bit pattern).

use crate::data::Value;

/// Hard ceiling on a single frame (kind byte + payload). Snapshots of
/// production-sized models are a few MiB; 256 MiB is comfortably above
/// any legitimate frame and comfortably below an OOM from a corrupt
/// length prefix.
pub const MAX_FRAME: usize = 256 << 20;

/// Frame kinds (the `u8` after the length prefix).
pub mod kind {
    /// Client → replica: one encoded row (see [`super::encode_row`]).
    pub const ASSIGN_REQ: u8 = 1;
    /// Replica → client: `cluster u64 LE` + `version u64 LE`.
    pub const ASSIGN_RESP: u8 = 2;
    /// Any → any: empty health/version probe.
    pub const PROBE: u8 = 3;
    /// Probe answer: five `u64 LE` words (see [`super::ProbeReply`]).
    pub const PROBE_RESP: u8 = 4;
    /// Replica → writer: subscribe to the delta stream; payload is the
    /// replica's current model version (`u64 LE`).
    pub const SUBSCRIBE: u8 = 5;
    /// Writer → replica: one `ModelDelta::to_bytes` payload.
    pub const DELTA: u8 = 6;
    /// Writer → replica: one `RkModel::to_bytes` payload.
    pub const SNAPSHOT: u8 = 7;
    /// Replica → writer: request a full snapshot (empty payload).
    pub const SNAPSHOT_REQ: u8 = 8;
    /// Any → server: shut the process down cleanly (empty payload).
    pub const STOP: u8 = 9;
    /// Either direction: UTF-8 error message payload.
    pub const ERROR: u8 = 10;
}

/// Decode-side failures. Implements `std::error::Error` so call sites
/// can `?` straight into the vendored `anyhow::Error`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// Payload ended before a fixed-width field.
    Short { want: usize, have: usize },
    /// Length prefix exceeds [`MAX_FRAME`].
    TooLong { len: usize },
    /// Unknown value tag in a row payload.
    BadTag { tag: u8 },
    /// Payload length inconsistent with its declared element count.
    BadLen { want: usize, have: usize },
    /// A `u64` wire field does not fit the in-memory type.
    Range { field: &'static str, value: u64 },
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Short { want, have } => {
                write!(f, "payload too short: want {want} bytes, have {have}")
            }
            WireError::TooLong { len } => {
                write!(f, "frame length {len} exceeds MAX_FRAME {MAX_FRAME}")
            }
            WireError::BadTag { tag } => write!(f, "unknown value tag {tag}"),
            WireError::BadLen { want, have } => {
                write!(f, "payload length mismatch: want {want} bytes, have {have}")
            }
            WireError::Range { field, value } => {
                write!(f, "wire field {field} = {value} out of range for host type")
            }
        }
    }
}

impl std::error::Error for WireError {}

/// Widen a `usize` into the `u64` wire representation. Infallible on
/// every supported target (`usize` ≤ 64 bits), but routed through
/// `try_from` so the conversion stays visibly checked.
pub fn u64_of(n: usize) -> u64 {
    u64::try_from(n).expect("usize fits u64 on all supported targets")
}

/// Narrow a `u64` wire field back into a host `usize`, failing loudly
/// (with the field name) on a 32-bit host reading a too-big value.
pub fn usize_of(field: &'static str, value: u64) -> Result<usize, WireError> {
    usize::try_from(value).map_err(|_| WireError::Range { field, value })
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn get_u64(bytes: &[u8], at: usize) -> Result<u64, WireError> {
    let end = at.checked_add(8).ok_or(WireError::Short { want: usize::MAX, have: bytes.len() })?;
    let raw = bytes.get(at..end).ok_or(WireError::Short { want: end, have: bytes.len() })?;
    let mut buf = [0u8; 8];
    buf.copy_from_slice(raw);
    Ok(u64::from_le_bytes(buf))
}

/// Encode a complete frame: length prefix, kind byte, payload.
///
/// Panics if the payload would exceed [`MAX_FRAME`] — that is a caller
/// bug (the model wire formats are orders of magnitude smaller), not a
/// runtime condition.
pub fn encode_frame(frame_kind: u8, payload: &[u8]) -> Vec<u8> {
    let total = payload.len().checked_add(1).expect("frame length overflow");
    assert!(total <= MAX_FRAME, "refusing to encode a {total}-byte frame (> MAX_FRAME)");
    let len32 = u32::try_from(total).expect("MAX_FRAME fits u32");
    let mut out = Vec::with_capacity(4 + total);
    out.extend_from_slice(&len32.to_le_bytes());
    out.push(frame_kind);
    out.extend_from_slice(payload);
    out
}

/// Incremental frame reassembler: feed it whatever the socket yields
/// (including partial frames split at arbitrary byte boundaries) and
/// pull complete `(kind, payload)` pairs out as they materialize.
#[derive(Debug, Default)]
pub struct FrameBuf {
    buf: Vec<u8>,
    pos: usize,
}

impl FrameBuf {
    /// Fresh, empty reassembly buffer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append bytes read off the socket.
    pub fn extend(&mut self, bytes: &[u8]) {
        // Compact consumed prefix before growing, so a long-lived
        // connection doesn't accrete every frame it ever saw.
        if self.pos > 0 && (self.pos >= self.buf.len() || self.pos > 4096) {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet consumed as frames.
    pub fn pending(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Pop the next complete frame, `Ok(None)` if more bytes are needed.
    ///
    /// A `TooLong` error is sticky in practice: the stream is
    /// desynchronized and the caller should drop the connection.
    pub fn next_frame(&mut self) -> Result<Option<(u8, Vec<u8>)>, WireError> {
        let avail = self.buf.len() - self.pos;
        if avail < 4 {
            return Ok(None);
        }
        let mut len_raw = [0u8; 4];
        len_raw.copy_from_slice(&self.buf[self.pos..self.pos + 4]);
        let total = usize_of("frame_len", u64::from(u32::from_le_bytes(len_raw)))?;
        if total == 0 || total > MAX_FRAME {
            return Err(WireError::TooLong { len: total });
        }
        if avail < 4 + total {
            return Ok(None);
        }
        let frame_kind = self.buf[self.pos + 4];
        let payload = self.buf[self.pos + 5..self.pos + 4 + total].to_vec();
        self.pos += 4 + total;
        Ok(Some((frame_kind, payload)))
    }
}

// ---- row codec (assign plane) ----------------------------------------

/// Per-value tags inside an `ASSIGN_REQ` payload.
const TAG_INT: u8 = 0;
const TAG_DOUBLE: u8 = 1;
const TAG_CAT: u8 = 2;

/// Encode one row for the assign plane: `u32 LE` value count, then per
/// value one tag byte + 8 bytes LE (`i64` two's complement, `f64` IEEE
/// bits, or a zero-extended `CatId`). Fixed 9 bytes per value keeps the
/// decoder's length check exact.
pub fn encode_row(row: &[Value]) -> Vec<u8> {
    let n32 = u32::try_from(row.len()).expect("row arity fits u32");
    let mut out = Vec::with_capacity(4 + row.len() * 9);
    out.extend_from_slice(&n32.to_le_bytes());
    for v in row {
        match v {
            Value::Int(i) => {
                out.push(TAG_INT);
                out.extend_from_slice(&i.to_le_bytes());
            }
            Value::Double(x) => {
                out.push(TAG_DOUBLE);
                out.extend_from_slice(&x.to_bits().to_le_bytes());
            }
            Value::Cat(c) => {
                out.push(TAG_CAT);
                put_u64(&mut out, u64::from(*c));
            }
        }
    }
    out
}

/// Decode an `ASSIGN_REQ` payload back into a row, bit-exactly.
pub fn decode_row(payload: &[u8]) -> Result<Vec<Value>, WireError> {
    if payload.len() < 4 {
        return Err(WireError::Short { want: 4, have: payload.len() });
    }
    let mut n_raw = [0u8; 4];
    n_raw.copy_from_slice(&payload[..4]);
    let n = usize_of("row_arity", u64::from(u32::from_le_bytes(n_raw)))?;
    let want = n
        .checked_mul(9)
        .and_then(|b| b.checked_add(4))
        .ok_or(WireError::BadLen { want: usize::MAX, have: payload.len() })?;
    if payload.len() != want {
        return Err(WireError::BadLen { want, have: payload.len() });
    }
    let mut row = Vec::with_capacity(n);
    for i in 0..n {
        let at = 4 + i * 9;
        let tag = payload[at];
        let word = get_u64(payload, at + 1)?;
        row.push(match tag {
            TAG_INT => Value::Int(i64::from_le_bytes(word.to_le_bytes())),
            TAG_DOUBLE => Value::Double(f64::from_bits(word)),
            TAG_CAT => {
                Value::Cat(u32::try_from(word).map_err(|_| WireError::Range {
                    field: "cat_id",
                    value: word,
                })?)
            }
            other => return Err(WireError::BadTag { tag: other }),
        });
    }
    Ok(row)
}

// ---- fixed-shape payloads --------------------------------------------

/// Encode an `ASSIGN_RESP` payload: cluster index + model version.
pub fn encode_assignment(cluster: usize, version: u64) -> Vec<u8> {
    let mut out = Vec::with_capacity(16);
    put_u64(&mut out, u64_of(cluster));
    put_u64(&mut out, version);
    out
}

/// Decode an `ASSIGN_RESP` payload into `(cluster, version)`.
pub fn decode_assignment(payload: &[u8]) -> Result<(usize, u64), WireError> {
    if payload.len() != 16 {
        return Err(WireError::BadLen { want: 16, have: payload.len() });
    }
    let cluster = usize_of("cluster", get_u64(payload, 0)?)?;
    let version = get_u64(payload, 8)?;
    Ok((cluster, version))
}

/// Server roles reported by the control plane.
pub const ROLE_WRITER: u64 = 0;
/// See [`ROLE_WRITER`].
pub const ROLE_REPLICA: u64 = 1;

/// Control-plane probe answer: everything the load generator and the CI
/// harness need to decide "is this process healthy and caught up".
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ProbeReply {
    /// Current model version served by this process.
    pub version: u64,
    /// [`ROLE_WRITER`] or [`ROLE_REPLICA`].
    pub role: u64,
    /// In-process mesh slots behind this server.
    pub replicas: u64,
    /// Snapshot catch-ups completed (replica) or served (writer).
    pub catchups: u64,
    /// `VersionGap` rejections observed on the replication plane.
    pub gaps: u64,
}

impl ProbeReply {
    /// Serialize as five `u64 LE` words.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(40);
        put_u64(&mut out, self.version);
        put_u64(&mut out, self.role);
        put_u64(&mut out, self.replicas);
        put_u64(&mut out, self.catchups);
        put_u64(&mut out, self.gaps);
        out
    }

    /// Inverse of [`ProbeReply::to_bytes`].
    pub fn from_bytes(payload: &[u8]) -> Result<Self, WireError> {
        if payload.len() != 40 {
            return Err(WireError::BadLen { want: 40, have: payload.len() });
        }
        Ok(Self {
            version: get_u64(payload, 0)?,
            role: get_u64(payload, 8)?,
            replicas: get_u64(payload, 16)?,
            catchups: get_u64(payload, 24)?,
            gaps: get_u64(payload, 32)?,
        })
    }
}

/// Encode a `SUBSCRIBE` payload (the subscriber's current version).
pub fn encode_subscribe(have_version: u64) -> Vec<u8> {
    have_version.to_le_bytes().to_vec()
}

/// Decode a `SUBSCRIBE` payload.
pub fn decode_subscribe(payload: &[u8]) -> Result<u64, WireError> {
    if payload.len() != 8 {
        return Err(WireError::BadLen { want: 8, have: payload.len() });
    }
    get_u64(payload, 0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip_survives_arbitrary_splits() {
        let frames = [
            (kind::PROBE, Vec::new()),
            (kind::DELTA, vec![1, 2, 3]),
            (kind::SNAPSHOT, vec![9; 300]),
        ];
        let mut stream = Vec::new();
        for (k, p) in &frames {
            stream.extend_from_slice(&encode_frame(*k, p));
        }
        // Deliver in 7-byte chunks: every frame boundary is split.
        let mut fb = FrameBuf::new();
        let mut got = Vec::new();
        for chunk in stream.chunks(7) {
            fb.extend(chunk);
            while let Some(f) = fb.next_frame().expect("clean stream") {
                got.push(f);
            }
        }
        let want: Vec<(u8, Vec<u8>)> = frames.iter().map(|(k, p)| (*k, p.clone())).collect();
        assert_eq!(got, want);
        assert_eq!(fb.pending(), 0);
    }

    #[test]
    fn oversize_and_zero_length_prefixes_are_rejected() {
        let mut fb = FrameBuf::new();
        fb.extend(&u32::MAX.to_le_bytes());
        fb.extend(&[0u8; 8]);
        assert!(matches!(fb.next_frame(), Err(WireError::TooLong { .. })));

        let mut fb = FrameBuf::new();
        fb.extend(&0u32.to_le_bytes());
        assert!(matches!(fb.next_frame(), Err(WireError::TooLong { len: 0 })));
    }

    #[test]
    fn row_roundtrip_is_bit_exact() {
        let row = vec![
            Value::Int(-42),
            Value::Double(0.1 + 0.2), // not representable exactly — bits must survive
            Value::Double(-0.0),
            Value::Cat(u32::MAX),
            Value::Int(i64::MIN),
        ];
        let enc = encode_row(&row);
        let dec = decode_row(&enc).expect("clean payload");
        assert_eq!(dec.len(), row.len());
        for (a, b) in row.iter().zip(dec.iter()) {
            match (a, b) {
                (Value::Double(x), Value::Double(y)) => assert_eq!(x.to_bits(), y.to_bits()),
                _ => assert_eq!(a, b),
            }
        }
    }

    #[test]
    fn row_decoder_rejects_malformed_payloads() {
        assert!(matches!(decode_row(&[1, 2]), Err(WireError::Short { .. })));
        // Declared arity 2, bytes for 1.
        let mut p = 2u32.to_le_bytes().to_vec();
        p.push(TAG_INT);
        p.extend_from_slice(&7i64.to_le_bytes());
        assert!(matches!(decode_row(&p), Err(WireError::BadLen { .. })));
        // Unknown tag.
        let mut p = 1u32.to_le_bytes().to_vec();
        p.push(77);
        p.extend_from_slice(&[0u8; 8]);
        assert!(matches!(decode_row(&p), Err(WireError::BadTag { tag: 77 })));
    }

    #[test]
    fn fixed_payloads_roundtrip_and_pin_their_bytes() {
        assert_eq!(decode_assignment(&encode_assignment(3, 17)).expect("ok"), (3, 17));
        // Byte-stability pin: layout changes must be deliberate.
        assert_eq!(
            encode_assignment(3, 17),
            vec![3, 0, 0, 0, 0, 0, 0, 0, 17, 0, 0, 0, 0, 0, 0, 0]
        );

        let probe =
            ProbeReply { version: 5, role: ROLE_REPLICA, replicas: 2, catchups: 1, gaps: 4 };
        assert_eq!(ProbeReply::from_bytes(&probe.to_bytes()).expect("ok"), probe);
        assert_eq!(decode_subscribe(&encode_subscribe(9)).expect("ok"), 9);

        // Frame header pin: 5-byte empty probe frame.
        assert_eq!(encode_frame(kind::PROBE, &[]), vec![1, 0, 0, 0, kind::PROBE]);
    }

    #[test]
    fn length_mismatches_name_the_field() {
        assert!(matches!(decode_assignment(&[0; 7]), Err(WireError::BadLen { want: 16, .. })));
        let short_probe = ProbeReply::from_bytes(&[0; 39]);
        assert!(matches!(short_probe, Err(WireError::BadLen { want: 40, .. })));
        assert!(matches!(decode_subscribe(&[0; 9]), Err(WireError::BadLen { want: 8, .. })));
    }
}
