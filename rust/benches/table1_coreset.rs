//! Bench T1 — regenerates paper Table 1: dataset statistics and coreset
//! size |G| as a function of κ, per dataset.
//!
//! `RKMEANS_BENCH_SCALE` (default 0.05) controls dataset size.

use rkmeans::bench_harness::paper::{table1, PaperCfg};

fn scale() -> f64 {
    std::env::var("RKMEANS_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.05)
}

fn main() -> anyhow::Result<()> {
    let cfg = PaperCfg::new(scale());
    let t0 = std::time::Instant::now();
    let t = table1(&cfg)?;
    println!("{}", t.render());
    println!("[table1 generated in {:?}]", t0.elapsed());
    Ok(())
}
