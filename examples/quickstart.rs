//! Quickstart: cluster a relational dataset without materializing the join.
//!
//! ```sh
//! cargo run --release --offline --example quickstart
//! ```
//!
//! Generates a small synthetic Retailer database (5 relations), then runs
//! Rk-means end to end and prints the step breakdown — the 30-second tour
//! of the public API.

use rkmeans::rkmeans::{full_objective, rkmeans, RkConfig};
use rkmeans::synthetic::{retailer, Scale};
use rkmeans::util::{human_bytes, human_count};

fn main() -> anyhow::Result<()> {
    // 1. A relational database: fact table + 4 dimension tables, with
    //    FD-chains (store -> zip -> city -> state).
    let db = retailer::generate(Scale::small(), 42);
    println!(
        "database: {} relations, {} tuples, {}",
        db.relations().len(),
        human_count(db.total_rows()),
        human_bytes(db.total_bytes())
    );

    // 2. The feature-extraction query: join everything, cluster on 16
    //    mixed categorical/continuous features.
    let feq = retailer::feq();
    println!("FEQ: {} features over {:?}", feq.n_features(), feq.relations);

    // 3. Rk-means: k = 10 clusters via a grid coreset (κ = k).
    let res = rkmeans(&db, &feq, &RkConfig::new(10))?;
    println!("\nRk-means (k=10):");
    println!("  coreset |G|        : {} cells", human_count(res.grid_points as u64));
    println!("  step 1 (marginals) : {:?}", res.timings.step1_marginals);
    println!("  step 2 (subspaces) : {:?}", res.timings.step2_subspaces);
    println!("  step 3 (grid)      : {:?}", res.timings.step3_grid);
    println!("  step 4 (cluster)   : {:?} ({} Lloyd iters)", res.timings.step4_cluster, res.iters);
    println!("  total              : {:?}", res.timings.total());
    println!("  coreset objective  : {:.4e}", res.objective_grid);
    println!("  quantization cost  : {:.4e}", res.quantization_cost);

    // 4. Evaluate on the full (never materialized) join output.
    let full = full_objective(&db, &feq, &res)?;
    println!("  full-X objective   : {:.4e} (bound {:.4e})", full, res.objective_upper_bound());
    Ok(())
}
