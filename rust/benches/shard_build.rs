//! Bench W2 — sharded Step 1–3 construction: `coreset_sharded` (value-
//! hashed fact partition, per-shard counting-FAQ grids merged by exact
//! ring-ℤ addition) vs. the serial staged build. Steps 1–2 (marginals +
//! subspace solve) are timed once and shared by every arm — the sharded
//! path parallelizes Step 3 only — and every sharded grid is asserted
//! **bitwise-identical** to the serial one before a record is emitted,
//! so the speedup is pure parallelism, not approximation. Results are
//! written as one `BENCH_shard.json` document (schema: see
//! `bench_harness` docs; path override: `RKMEANS_SHARD_OUT`).
//! Acceptance target: `sharded-max` Step 3 ≥ 2× faster than serial on
//! the Retailer workload at S = available cores.
//!
//! `--test` (or `--smoke`) shrinks everything for CI smoke runs.
//! `RKMEANS_SHARD_SCALE` overrides the Retailer scale (default 0.1).

use rkmeans::bench_harness::{write_bench_shard, ShardBenchRecord};
use rkmeans::rkmeans::{Coreset, RkPipeline, SubspaceOpts};
use rkmeans::synthetic::{retailer, Scale};
use rkmeans::util::exec::resolve_threads;
use std::path::PathBuf;
use std::time::Instant;

/// Best-of-`samples` Step-3 wall time plus the last coreset built.
fn time_build(
    samples: usize,
    mut build: impl FnMut() -> anyhow::Result<Coreset>,
) -> anyhow::Result<(f64, Coreset)> {
    let mut best = f64::INFINITY;
    let mut last = None;
    for _ in 0..samples {
        let t0 = Instant::now();
        let coreset = build()?;
        best = best.min(t0.elapsed().as_secs_f64());
        last = Some(coreset);
    }
    Ok((best, last.expect("samples >= 1")))
}

/// Bitwise grid-identity check against the serial reference build.
fn ensure_bitwise(serial: &Coreset, sharded: &Coreset, shards: usize) -> anyhow::Result<()> {
    anyhow::ensure!(
        serial.grid.gids == sharded.grid.gids,
        "S={shards}: grid cell ids diverged from serial"
    );
    let bits = |c: &Coreset| c.grid.weights.iter().map(|w| w.to_bits()).collect::<Vec<_>>();
    anyhow::ensure!(
        bits(serial) == bits(sharded),
        "S={shards}: grid weights diverged bitwise from serial"
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let test_mode = std::env::args().any(|a| a == "--test" || a == "--smoke");
    let scale: f64 = std::env::var("RKMEANS_SHARD_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if test_mode { 0.02 } else { 0.1 });
    let kappa = if test_mode { 8 } else { 16 };
    let samples = if test_mode { 2 } else { 3 };
    let threads = resolve_threads(0);
    let seed = 42u64;

    let db = retailer::generate(Scale::custom(scale), seed);
    let feq = retailer::feq();
    println!(
        "shard workload: |D|={} rows (scale {scale}), κ={kappa}, pool width {threads}",
        db.total_rows()
    );

    // Steps 1–2, timed once: serial by design and shared by every arm.
    let t0 = Instant::now();
    let pipe = RkPipeline::plan(&db, &feq)?;
    let marginals = pipe.marginals()?;
    let subspaces = pipe.subspaces(&marginals, &SubspaceOpts::new(kappa))?;
    let step1_2_s = t0.elapsed().as_secs_f64();

    // Serial Step-3 reference arm.
    let (serial_s, serial) = time_build(samples, || pipe.coreset(&subspaces))?;
    let serial_rec = ShardBenchRecord::from_build(
        "retailer",
        "serial",
        1,
        1,
        step1_2_s,
        serial_s,
        serial.n(),
        serial.mass(),
    );
    println!("{}", serial_rec.line());

    // Sharded arms: a small fixed ladder plus S = available cores (the
    // acceptance point), each asserted bitwise-identical to serial.
    let mut records = vec![serial_rec.clone()];
    let mut shard_counts: Vec<usize> = vec![2, 4];
    shard_counts.retain(|&s| s < threads);
    shard_counts.push(threads.max(2));
    for (i, &shards) in shard_counts.iter().enumerate() {
        let is_max = i + 1 == shard_counts.len();
        let (step3_s, coreset) = time_build(samples, || pipe.coreset_sharded(&subspaces, shards))?;
        ensure_bitwise(&serial, &coreset, shards)?;
        let mode = if is_max { "sharded-max".to_string() } else { format!("sharded-{shards}") };
        let rec = ShardBenchRecord::from_build(
            "retailer",
            &mode,
            shards,
            threads,
            step1_2_s,
            step3_s,
            coreset.n(),
            coreset.mass(),
        )
        .with_speedup_vs(&serial_rec);
        println!("{}", rec.line());
        records.push(rec);
    }

    let max_speedup = records
        .last()
        .and_then(|r| r.speedup_vs_serial)
        .unwrap_or(0.0);
    let out = PathBuf::from(
        std::env::var("RKMEANS_SHARD_OUT").unwrap_or_else(|_| "BENCH_shard.json".to_string()),
    );
    write_bench_shard(&out, &records)?;
    println!("wrote {} records to {}", records.len(), out.display());
    println!(
        "sharded-max vs serial Step 3: {max_speedup:.2}× at S={} (acceptance target ≥ 2×, \
         bitwise-identical grids)",
        shard_counts.last().copied().unwrap_or(0)
    );
    Ok(())
}
