//! Optimal weighted 1-D k-means by dynamic programming (Wang & Song [42]),
//! with the divide-and-conquer monotone-optimizer speedup: `O(k·n·log n)`
//! instead of the naive `O(k·n²)`.
//!
//! Used by Step 2 for continuous subspaces; gives the `α = 1` per-subspace
//! approximation ratio the paper's analysis relies on (§4, Theorem 3.4).

/// Result of an optimal 1-D clustering.
#[derive(Clone, Debug)]
pub struct Kmeans1dResult {
    /// Cluster centers (weighted means), ascending.
    pub centers: Vec<f64>,
    /// Decision boundaries: midpoints between consecutive centers
    /// (`centers.len() - 1` entries). `assign` is a binary search on these.
    pub boundaries: Vec<f64>,
    /// Optimal weighted k-means cost.
    pub cost: f64,
}

impl Kmeans1dResult {
    /// Cluster id for a value (nearest center).
    pub fn assign(&self, v: f64) -> u32 {
        // boundaries are sorted; partition_point = #boundaries < v.
        self.boundaries.partition_point(|&b| b < v) as u32
    }

    /// Number of clusters.
    pub fn k(&self) -> usize {
        self.centers.len()
    }
}

/// Prefix-sum cost oracle over sorted weighted points.
struct CostOracle {
    w: Vec<f64>,  // prefix weights
    wv: Vec<f64>, // prefix weight*value
    wv2: Vec<f64>, // prefix weight*value²
}

impl CostOracle {
    fn new(pts: &[(f64, f64)]) -> Self {
        let n = pts.len();
        let (mut w, mut wv, mut wv2) =
            (Vec::with_capacity(n + 1), Vec::with_capacity(n + 1), Vec::with_capacity(n + 1));
        w.push(0.0);
        wv.push(0.0);
        wv2.push(0.0);
        for &(v, wt) in pts {
            w.push(w.last().expect("non-empty") + wt);
            wv.push(wv.last().expect("non-empty") + wt * v);
            wv2.push(wv2.last().expect("non-empty") + wt * v * v);
        }
        CostOracle { w, wv, wv2 }
    }

    /// Weighted SSE of the segment `[a, b)` around its weighted mean.
    #[inline]
    fn cost(&self, a: usize, b: usize) -> f64 {
        let wt = self.w[b] - self.w[a];
        if wt <= 0.0 {
            return 0.0;
        }
        let s = self.wv[b] - self.wv[a];
        let q = self.wv2[b] - self.wv2[a];
        // Clamp tiny negative values from cancellation.
        (q - s * s / wt).max(0.0)
    }

    /// Weighted mean of `[a, b)`.
    fn mean(&self, a: usize, b: usize) -> f64 {
        (self.wv[b] - self.wv[a]) / (self.w[b] - self.w[a])
    }
}

/// If the input has more distinct values than this, quantile-bucket it first
/// (the paper applies the same precision-reduction to Favorita's
/// `unit_sales`; the DP is quadratic-ish in distinct values otherwise).
pub const MAX_DISTINCT: usize = 65_536;

/// Optimal weighted k-means in one dimension.
///
/// `points` are `(value, weight)` pairs; duplicates are merged and values
/// sorted internally. Requests for `k >= #distinct` return one cluster per
/// distinct value (cost 0).
pub fn kmeans1d(points: &[(f64, f64)], k: usize) -> Kmeans1dResult {
    assert!(k >= 1, "k must be positive");
    // Sort + merge duplicates.
    let mut pts: Vec<(f64, f64)> = points.iter().copied().filter(|&(_, w)| w > 0.0).collect();
    pts.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("finite values"));
    let mut merged: Vec<(f64, f64)> = Vec::with_capacity(pts.len());
    for (v, w) in pts {
        match merged.last_mut() {
            Some((lv, lw)) if *lv == v => *lw += w,
            _ => merged.push((v, w)),
        }
    }
    if merged.is_empty() {
        return Kmeans1dResult { centers: vec![0.0], boundaries: vec![], cost: 0.0 };
    }
    let merged =
        if merged.len() > MAX_DISTINCT { bucketize(&merged, MAX_DISTINCT) } else { merged };
    let n = merged.len();
    if k >= n {
        let centers: Vec<f64> = merged.iter().map(|&(v, _)| v).collect();
        let boundaries = mid_boundaries(&centers);
        return Kmeans1dResult { centers, boundaries, cost: 0.0 };
    }

    let oracle = CostOracle::new(&merged);

    // DP layers with divide-and-conquer optimization.
    // prev[i] = optimal cost of clustering the first i points into j-1 parts.
    let mut prev: Vec<f64> = (0..=n).map(|i| oracle.cost(0, i)).collect(); // j = 1
    // split[j][i] = optimal first index of the j-th (last) cluster for
    // prefix length i; used to reconstruct boundaries.
    let mut splits: Vec<Vec<u32>> = vec![vec![0; n + 1]]; // layer j=1: split at 0

    for j in 2..=k {
        let mut cur = vec![f64::INFINITY; n + 1];
        let mut opt = vec![0u32; n + 1];
        // Solve for i in [lo, hi] knowing the optimal split lies in
        // [optlo, opthi]; recursion depth O(log n).
        // (Monotonicity of the argmin follows from the concave-Monge
        // property of contiguous-segment SSE costs.)
        //
        // Splits are constrained to t ≥ j−1 and prefixes to i ≥ j so every
        // one of the j clusters covers at least one distinct value. Cost
        // ties could otherwise produce empty segments, whose weighted mean
        // is 0/0 = NaN — poisoning `centers`, `boundaries` and every
        // subsequent `assign` binary search. Non-empty solutions always
        // tie-or-beat empty ones, so the optimum is unchanged.
        let t_min = j - 1;
        struct Frame {
            lo: usize,
            hi: usize,
            optlo: usize,
            opthi: usize,
        }
        let mut stack = vec![Frame { lo: j, hi: n, optlo: t_min, opthi: n - 1 }];
        while let Some(Frame { lo, hi, optlo, opthi }) = stack.pop() {
            if lo > hi {
                continue;
            }
            let mid = (lo + hi) / 2;
            let t_lo = optlo.max(t_min);
            let t_hi = opthi.min(mid - 1);
            let mut best = f64::INFINITY;
            let mut best_t = t_lo;
            for t in t_lo..=t_hi {
                let c = prev[t] + oracle.cost(t, mid);
                if c < best {
                    best = c;
                    best_t = t;
                }
            }
            cur[mid] = best;
            opt[mid] = best_t as u32;
            if mid > lo {
                stack.push(Frame { lo, hi: mid - 1, optlo, opthi: best_t });
            }
            if mid < hi {
                stack.push(Frame { lo: mid + 1, hi, optlo: best_t, opthi });
            }
        }
        prev = cur;
        splits.push(opt);
    }

    // Reconstruct segment boundaries from the split tables.
    let mut cuts = Vec::with_capacity(k + 1); // segment end indices, reversed
    let mut end = n;
    for j in (0..k).rev() {
        cuts.push(end);
        end = splits[j][end] as usize;
    }
    cuts.push(0);
    cuts.reverse(); // 0 = c_0 < c_1 < … < c_k = n

    let mut centers = Vec::with_capacity(k);
    for s in 0..k {
        centers.push(oracle.mean(cuts[s], cuts[s + 1]));
    }
    let boundaries = mid_boundaries(&centers);
    Kmeans1dResult { centers, boundaries, cost: prev[n] }
}

fn mid_boundaries(centers: &[f64]) -> Vec<f64> {
    centers.windows(2).map(|w| 0.5 * (w[0] + w[1])).collect()
}

/// Merge a long sorted distinct-value list down to ~`target` weighted
/// buckets by weight-quantile, preserving total mass and weighted mean per
/// bucket.
fn bucketize(pts: &[(f64, f64)], target: usize) -> Vec<(f64, f64)> {
    let total: f64 = pts.iter().map(|&(_, w)| w).sum();
    let per = total / target as f64;
    let mut out: Vec<(f64, f64)> = Vec::with_capacity(target);
    let (mut acc_w, mut acc_wv) = (0.0, 0.0);
    for &(v, w) in pts {
        acc_w += w;
        acc_wv += w * v;
        if acc_w >= per {
            out.push((acc_wv / acc_w, acc_w));
            acc_w = 0.0;
            acc_wv = 0.0;
        }
    }
    if acc_w > 0.0 {
        out.push((acc_wv / acc_w, acc_w));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::{assert_close, for_cases};

    /// Brute-force optimal 1-D k-means over all contiguous partitions.
    fn brute(pts: &[(f64, f64)], k: usize) -> f64 {
        let mut sorted: Vec<(f64, f64)> = pts.to_vec();
        sorted.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("finite"));
        let mut merged: Vec<(f64, f64)> = Vec::new();
        for (v, w) in sorted {
            match merged.last_mut() {
                Some((lv, lw)) if *lv == v => *lw += w,
                _ => merged.push((v, w)),
            }
        }
        let n = merged.len();
        let oracle = CostOracle::new(&merged);
        // DP without the D&C optimization (the oracle of correctness).
        let mut prev: Vec<f64> = (0..=n).map(|i| oracle.cost(0, i)).collect();
        for _ in 2..=k {
            let mut cur = vec![f64::INFINITY; n + 1];
            for i in 1..=n {
                for t in 0..i {
                    let c = prev[t] + oracle.cost(t, i);
                    if c < cur[i] {
                        cur[i] = c;
                    }
                }
            }
            prev = cur;
        }
        prev[n]
    }

    #[test]
    fn two_obvious_clusters() {
        let pts = vec![(0.0, 1.0), (0.1, 1.0), (10.0, 1.0), (10.1, 1.0)];
        let r = kmeans1d(&pts, 2);
        assert_eq!(r.centers.len(), 2);
        assert_close(r.centers[0], 0.05, 1e-12);
        assert_close(r.centers[1], 10.05, 1e-12);
        assert_close(r.cost, 2.0 * 0.05_f64.powi(2) * 2.0, 1e-9);
        assert_eq!(r.assign(-1.0), 0);
        assert_eq!(r.assign(9.0), 1);
    }

    #[test]
    fn weights_shift_centers() {
        let pts = vec![(0.0, 9.0), (1.0, 1.0)];
        let r = kmeans1d(&pts, 1);
        assert_close(r.centers[0], 0.1, 1e-12);
    }

    #[test]
    fn k_at_least_n_gives_zero_cost() {
        let pts = vec![(1.0, 1.0), (2.0, 2.0), (3.0, 1.0)];
        let r = kmeans1d(&pts, 5);
        assert_eq!(r.centers, vec![1.0, 2.0, 3.0]);
        assert_eq!(r.cost, 0.0);
        assert_eq!(r.assign(2.4), 1);
    }

    #[test]
    fn duplicates_are_merged() {
        let pts = vec![(1.0, 1.0), (1.0, 1.0), (5.0, 1.0)];
        let r = kmeans1d(&pts, 2);
        assert_eq!(r.centers, vec![1.0, 5.0]);
        assert_eq!(r.cost, 0.0);
    }

    #[test]
    fn dc_matches_bruteforce_dp() {
        for_cases(40, |rng| {
            let n = 2 + rng.below(40) as usize;
            let k = 1 + rng.below(6) as usize;
            let pts: Vec<(f64, f64)> = (0..n)
                .map(|_| (rng.uniform(-10.0, 10.0), rng.uniform(0.1, 3.0)))
                .collect();
            let fast = kmeans1d(&pts, k);
            let slow = brute(&pts, k);
            assert_close(fast.cost, slow, 1e-9);
        });
    }

    #[test]
    fn assignment_consistent_with_cost() {
        for_cases(20, |rng| {
            let n = 3 + rng.below(30) as usize;
            let k = 1 + rng.below(4) as usize;
            let pts: Vec<(f64, f64)> = (0..n)
                .map(|_| ((rng.below(20) as f64) * 0.5, rng.uniform(0.5, 2.0)))
                .collect();
            let r = kmeans1d(&pts, k);
            // Recompute cost from assignments; must match r.cost.
            let mut acc = vec![(0.0, 0.0); r.k()]; // (Σw, Σwv)
            for &(v, w) in &pts {
                let c = r.assign(v) as usize;
                acc[c].0 += w;
                acc[c].1 += w * v;
            }
            let mut cost = 0.0;
            for &(v, w) in &pts {
                let c = r.assign(v) as usize;
                if acc[c].0 > 0.0 {
                    let mu = acc[c].1 / acc[c].0;
                    cost += w * (v - mu) * (v - mu);
                }
            }
            // The DP centers ARE the weighted means of their segments, so
            // recomputed cost equals reported cost.
            assert_close(cost, r.cost, 1e-6);
        });
    }

    #[test]
    fn bucketize_preserves_mass() {
        let pts: Vec<(f64, f64)> = (0..1000).map(|i| (i as f64, 1.0)).collect();
        let b = bucketize(&pts, 10);
        assert!(b.len() <= 11);
        assert_close(b.iter().map(|&(_, w)| w).sum::<f64>(), 1000.0, 1e-9);
    }

    #[test]
    fn empty_input_is_degenerate() {
        let r = kmeans1d(&[], 3);
        assert_eq!(r.cost, 0.0);
        assert_eq!(r.assign(1.0), 0);
    }

    /// Invariants that rule out the NaN-boundary failure mode: centers
    /// finite and strictly ascending, boundaries strictly ascending, and
    /// `assign` (the `partition_point` path) returning the nearest center.
    fn check_well_formed(r: &Kmeans1dResult) {
        assert!(!r.centers.is_empty());
        for &c in &r.centers {
            assert!(c.is_finite(), "non-finite center in {:?}", r.centers);
        }
        for w in r.centers.windows(2) {
            assert!(w[0] < w[1], "centers not strictly ascending: {:?}", r.centers);
        }
        assert_eq!(r.boundaries.len(), r.centers.len() - 1);
        for w in r.boundaries.windows(2) {
            assert!(w[0] < w[1], "boundaries not sorted: {:?}", r.boundaries);
        }
        for &b in &r.boundaries {
            assert!(b.is_finite());
        }
    }

    #[test]
    fn all_duplicate_inputs_collapse_to_one_center() {
        for k in [1usize, 2, 3, 7] {
            let pts = vec![(2.5, 1.0); 6];
            let r = kmeans1d(&pts, k);
            assert_eq!(r.centers, vec![2.5]);
            assert!(r.boundaries.is_empty());
            assert_eq!(r.cost, 0.0);
            assert_eq!(r.assign(-10.0), 0);
            assert_eq!(r.assign(100.0), 0);
        }
    }

    #[test]
    fn k_ge_distinct_values_is_exact() {
        // 6 distinct values hidden in 10 weighted duplicates; any k ≥ 6
        // returns exactly the distinct values at cost 0.
        let mut pts = Vec::new();
        for v in [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 9.0, 3.0, 5.0] {
            pts.push((v, 0.5));
        }
        for k in [6usize, 7, 50] {
            let r = kmeans1d(&pts, k);
            assert_eq!(r.centers, vec![1.0, 2.0, 3.0, 4.0, 5.0, 9.0]);
            assert_eq!(r.cost, 0.0);
            check_well_formed(&r);
            for &(v, _) in &pts {
                let c = r.assign(v) as usize;
                assert_eq!(r.centers[c], v, "value {v} must map to its own center");
            }
        }
    }

    #[test]
    fn tie_heavy_inputs_never_produce_nan_boundaries() {
        // Symmetric, duplicate-heavy, zero-cost-tie-rich inputs are the
        // regime where an unconstrained DP picks empty segments (whose
        // mean is 0/0). The split constraint must keep everything finite.
        for_cases(40, |rng| {
            let n_vals = 2 + rng.below(6) as usize;
            let n = n_vals + rng.below(20) as usize;
            let pts: Vec<(f64, f64)> = (0..n)
                .map(|_| (rng.below(n_vals as u64) as f64, 1.0))
                .collect();
            let k = 1 + rng.below(8) as usize;
            let r = kmeans1d(&pts, k);
            check_well_formed(&r);
            // Assignment must pick the nearest center for every input.
            for &(v, _) in &pts {
                let c = r.assign(v) as usize;
                let best = r
                    .centers
                    .iter()
                    .map(|&m| (v - m).abs())
                    .fold(f64::INFINITY, f64::min);
                assert_close((v - r.centers[c]).abs(), best, 1e-12);
            }
        });
    }

    #[test]
    fn nonempty_constraint_preserves_optimal_cost() {
        // The constrained D&C must still match the unconstrained
        // brute-force optimum on tie-heavy grids (cost equality; the
        // brute DP tolerates empty segments, the fast one forbids them).
        for_cases(30, |rng| {
            let n = 2 + rng.below(15) as usize;
            let k = 1 + rng.below(n as u64) as usize;
            let pts: Vec<(f64, f64)> = (0..n)
                .map(|_| ((rng.below(6) as f64) * 2.0, 1.0 + rng.below(3) as f64))
                .collect();
            let fast = kmeans1d(&pts, k);
            let slow = brute(&pts, k);
            assert_close(fast.cost, slow, 1e-9);
            check_well_formed(&fast);
        });
    }
}
