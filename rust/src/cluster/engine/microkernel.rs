//! The tiled distance microkernel.
//!
//! Distances use the `‖x‖² − 2·x·c + ‖c‖²` expansion with both norms
//! hoisted: the only inner-loop work is the `x·c` contraction. Centroids
//! are kept **transposed** (`d × k`, dimension-major) so that for a fixed
//! dimension `j` the k partial dot products update a contiguous f64
//! accumulator row — a layout LLVM autovectorizes (the accumulators stay
//! in vector registers across the `k` lane loop, the centroid row streams
//! sequentially). Points are processed in tiles of [`TILE`] rows so each
//! centroid row loaded from cache is reused `TILE` times.
//!
//! Bitwise contract: every entry point accumulates its dot product over
//! `j = 0..d` in ascending order from a `0.0` start, so a distance
//! computed by [`tile_dots`], by [`dot_one`], or by any mix of the two is
//! bit-for-bit identical. The pruned engine relies on this to keep skipped
//! and scanned points on one arithmetic footing.
//!
//! The explicit **f32 tile path** ([`transpose_f32`], [`tile_dots_f32`],
//! [`dot_one_f32`], [`best_two_expanded_f32`]) mirrors the f64 kernels
//! operation-for-operation at half the lane width — the same
//! dimension-major layout autovectorizes to twice the elements per vector
//! register, which is where the ~2× kernel throughput comes from. The
//! bitwise contract holds *within* the precision: an f32 distance from
//! [`tile_dots_f32`] and from [`dot_one_f32`] is bit-for-bit identical,
//! so the pruned f32 engine is deterministic against the naive f32
//! reference. Accumulation of the objective and the centroid-update sums
//! stays f64 in the engines (see the parent module docs for the f32
//! tolerance contract).

/// Points per microkernel tile.
pub(crate) const TILE: usize = 8;

/// Transpose row-major `k × d` centroids into the kernel's `d × k` layout.
pub(crate) fn transpose(centroids: &[f64], d: usize, k: usize, out: &mut Vec<f64>) {
    debug_assert_eq!(centroids.len(), k * d);
    out.clear();
    out.resize(d * k, 0.0);
    for (c, row) in centroids.chunks_exact(d).enumerate() {
        for (j, &v) in row.iter().enumerate() {
            out[j * k + c] = v;
        }
    }
}

/// Dot products of a contiguous row-major tile (`tp × d`, `tp ≤ TILE` not
/// enforced — any `tp` works) against all `k` transposed centroids:
/// `dots[p·k + c] = Σ_j tile[p·d + j] · ct_t[j·k + c]`.
pub(crate) fn tile_dots(tile: &[f64], d: usize, k: usize, ct_t: &[f64], dots: &mut [f64]) {
    let tp = tile.len() / d;
    debug_assert_eq!(tile.len(), tp * d);
    debug_assert_eq!(ct_t.len(), d * k);
    debug_assert!(dots.len() >= tp * k);
    dots[..tp * k].fill(0.0);
    for j in 0..d {
        let col = &ct_t[j * k..(j + 1) * k];
        for p in 0..tp {
            let xj = tile[p * d + j];
            let acc = &mut dots[p * k..p * k + k];
            for (av, &cv) in acc.iter_mut().zip(col) {
                *av += xj * cv;
            }
        }
    }
}

/// One dot product against centroid `c` — the same j-ascending
/// accumulation as [`tile_dots`], so the result is bitwise identical.
pub(crate) fn dot_one(x: &[f64], ct_t: &[f64], k: usize, c: usize) -> f64 {
    let mut acc = 0.0;
    for (j, &xj) in x.iter().enumerate() {
        acc += xj * ct_t[j * k + c];
    }
    acc
}

/// Expand `dd_c = xn − 2·dot_c + cnorm_c` and return the two smallest:
/// `(best dd, best index, second-best dd)`. Strict `<` comparisons give
/// lowest-index-wins tie-breaking, matching a naive first-minimum scan.
pub(crate) fn best_two_expanded(xn: f64, dots: &[f64], cnorm: &[f64]) -> (f64, u32, f64) {
    let (mut d1, mut c1, mut d2) = (f64::INFINITY, 0u32, f64::INFINITY);
    for (c, (&dot, &cn)) in dots.iter().zip(cnorm.iter()).enumerate() {
        let dd = xn - 2.0 * dot + cn;
        if dd < d1 {
            d2 = d1;
            d1 = dd;
            c1 = c as u32;
        } else if dd < d2 {
            d2 = dd;
        }
    }
    (d1, c1, d2)
}

/// Two smallest entries of a precomputed distance buffer (the factored
/// engine's per-cell table sums), with the same tie-breaking as
/// [`best_two_expanded`].
pub(crate) fn best_two_buf(buf: &[f64]) -> (f64, u32, f64) {
    let (mut d1, mut c1, mut d2) = (f64::INFINITY, 0u32, f64::INFINITY);
    for (c, &dd) in buf.iter().enumerate() {
        if dd < d1 {
            d2 = d1;
            d1 = dd;
            c1 = c as u32;
        } else if dd < d2 {
            d2 = dd;
        }
    }
    (d1, c1, d2)
}

/// Transpose row-major `k × d` f64 centroids into the f32 kernel's
/// `d × k` layout (one narrowing cast per coordinate).
pub(crate) fn transpose_f32(centroids: &[f64], d: usize, k: usize, out: &mut Vec<f32>) {
    debug_assert_eq!(centroids.len(), k * d);
    out.clear();
    out.resize(d * k, 0.0);
    for (c, row) in centroids.chunks_exact(d).enumerate() {
        for (j, &v) in row.iter().enumerate() {
            out[j * k + c] = v as f32;
        }
    }
}

/// f32 twin of [`tile_dots`]: identical loop structure at twice the SIMD
/// lane width.
pub(crate) fn tile_dots_f32(tile: &[f32], d: usize, k: usize, ct_t: &[f32], dots: &mut [f32]) {
    let tp = tile.len() / d;
    debug_assert_eq!(tile.len(), tp * d);
    debug_assert_eq!(ct_t.len(), d * k);
    debug_assert!(dots.len() >= tp * k);
    dots[..tp * k].fill(0.0);
    for j in 0..d {
        let col = &ct_t[j * k..(j + 1) * k];
        for p in 0..tp {
            let xj = tile[p * d + j];
            let acc = &mut dots[p * k..p * k + k];
            for (av, &cv) in acc.iter_mut().zip(col) {
                *av += xj * cv;
            }
        }
    }
}

/// f32 twin of [`dot_one`] — the same j-ascending accumulation as
/// [`tile_dots_f32`], so the result is bitwise identical within f32.
pub(crate) fn dot_one_f32(x: &[f32], ct_t: &[f32], k: usize, c: usize) -> f32 {
    let mut acc = 0.0f32;
    for (j, &xj) in x.iter().enumerate() {
        acc += xj * ct_t[j * k + c];
    }
    acc
}

/// f32 twin of [`best_two_expanded`], with the same lowest-index-wins
/// tie-breaking.
pub(crate) fn best_two_expanded_f32(xn: f32, dots: &[f32], cnorm: &[f32]) -> (f32, u32, f32) {
    let (mut d1, mut c1, mut d2) = (f32::INFINITY, 0u32, f32::INFINITY);
    for (c, (&dot, &cn)) in dots.iter().zip(cnorm.iter()).enumerate() {
        let dd = xn - 2.0 * dot + cn;
        if dd < d1 {
            d2 = d1;
            d1 = dd;
            c1 = c as u32;
        } else if dd < d2 {
            d2 = dd;
        }
    }
    (d1, c1, d2)
}

/// f32 twin of [`best_two_buf`] (the factored engine's f32 table sums).
pub(crate) fn best_two_buf_f32(buf: &[f32]) -> (f32, u32, f32) {
    let (mut d1, mut c1, mut d2) = (f32::INFINITY, 0u32, f32::INFINITY);
    for (c, &dd) in buf.iter().enumerate() {
        if dd < d1 {
            d2 = d1;
            d1 = dd;
            c1 = c as u32;
        } else if dd < d2 {
            d2 = dd;
        }
    }
    (d1, c1, d2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::{assert_close, for_cases};

    #[test]
    fn tile_and_single_dots_are_bitwise_equal() {
        for_cases(25, |rng| {
            let d = 1 + rng.below(12) as usize;
            let k = 1 + rng.below(9) as usize;
            let tp = 1 + rng.below(TILE as u64) as usize;
            let tile: Vec<f64> = (0..tp * d).map(|_| rng.uniform(-3.0, 3.0)).collect();
            let cents: Vec<f64> = (0..k * d).map(|_| rng.uniform(-3.0, 3.0)).collect();
            let mut ct_t = Vec::new();
            transpose(&cents, d, k, &mut ct_t);
            let mut dots = vec![0.0; tp * k];
            tile_dots(&tile, d, k, &ct_t, &mut dots);
            for p in 0..tp {
                for c in 0..k {
                    let one = dot_one(&tile[p * d..(p + 1) * d], &ct_t, k, c);
                    assert_eq!(one.to_bits(), dots[p * k + c].to_bits());
                }
            }
        });
    }

    #[test]
    fn best_two_orders_and_breaks_ties_low() {
        // Exact tie between index 1 and 3: lowest index must win.
        let buf = [5.0, 2.0, 7.0, 2.0, 3.0];
        let (d1, c1, d2) = best_two_buf(&buf);
        assert_eq!((d1, c1, d2), (2.0, 1, 2.0));
        // k = 1: second best is infinite.
        let (d1, c1, d2) = best_two_buf(&[4.0]);
        assert_eq!((d1, c1), (4.0, 0));
        assert!(d2.is_infinite());
    }

    #[test]
    fn f32_tile_and_single_dots_are_bitwise_equal() {
        // The within-precision bitwise contract the pruned f32 path
        // relies on: Phase-1 single dots must match Phase-2 tile dots.
        for_cases(25, |rng| {
            let d = 1 + rng.below(12) as usize;
            let k = 1 + rng.below(9) as usize;
            let tp = 1 + rng.below(TILE as u64) as usize;
            let tile64: Vec<f64> = (0..tp * d).map(|_| rng.uniform(-3.0, 3.0)).collect();
            let tile: Vec<f32> = tile64.iter().map(|&v| v as f32).collect();
            let cents: Vec<f64> = (0..k * d).map(|_| rng.uniform(-3.0, 3.0)).collect();
            let mut ct_t = Vec::new();
            transpose_f32(&cents, d, k, &mut ct_t);
            let mut dots = vec![0.0f32; tp * k];
            tile_dots_f32(&tile, d, k, &ct_t, &mut dots);
            for p in 0..tp {
                for c in 0..k {
                    let one = dot_one_f32(&tile[p * d..(p + 1) * d], &ct_t, k, c);
                    assert_eq!(one.to_bits(), dots[p * k + c].to_bits());
                }
            }
        });
    }

    #[test]
    fn f32_kernel_tracks_f64_kernel_closely() {
        // Same inputs through both precisions: distances must agree to
        // f32 rounding on unit-scale data, and the argmin must agree when
        // the margin is far above f32 epsilon.
        for_cases(20, |rng| {
            let d = 1 + rng.below(10) as usize;
            let k = 2 + rng.below(6) as usize;
            let x: Vec<f64> = (0..d).map(|_| rng.uniform(-2.0, 2.0)).collect();
            let x32: Vec<f32> = x.iter().map(|&v| v as f32).collect();
            let cents: Vec<f64> = (0..k * d).map(|_| rng.uniform(-2.0, 2.0)).collect();
            let mut ct_t = Vec::new();
            transpose(&cents, d, k, &mut ct_t);
            let mut ct_t32 = Vec::new();
            transpose_f32(&cents, d, k, &mut ct_t32);
            let mut dots = vec![0.0f64; k];
            let mut dots32 = vec![0.0f32; k];
            tile_dots(&x, d, k, &ct_t, &mut dots);
            tile_dots_f32(&x32, d, k, &ct_t32, &mut dots32);
            let xn: f64 = x.iter().map(|v| v * v).sum();
            let xn32: f32 = x32.iter().map(|v| v * v).sum();
            let cnorm: Vec<f64> =
                cents.chunks_exact(d).map(|c| c.iter().map(|v| v * v).sum()).collect();
            let cnorm32: Vec<f32> = cnorm.iter().map(|&v| v as f32).collect();
            let (d1, c1, d2) = best_two_expanded(xn, &dots, &cnorm);
            let (d1f, c1f, _) = best_two_expanded_f32(xn32, &dots32, &cnorm32);
            let scale = 1.0 + xn.abs();
            assert!(
                (d1 - d1f as f64).abs() <= 1e-4 * scale,
                "f32 distance {d1f} drifted from f64 {d1}"
            );
            if d2 - d1 > 1e-3 * scale {
                assert_eq!(c1, c1f, "argmin diverged on a well-separated pair");
            }
        });
    }

    #[test]
    fn f32_best_two_buf_orders_and_breaks_ties_low() {
        let buf = [5.0f32, 2.0, 7.0, 2.0, 3.0];
        let (d1, c1, d2) = best_two_buf_f32(&buf);
        assert_eq!((d1, c1, d2), (2.0, 1, 2.0));
        let (d1, c1, d2) = best_two_buf_f32(&[4.0f32]);
        assert_eq!((d1, c1), (4.0, 0));
        assert!(d2.is_infinite());
    }

    #[test]
    fn expanded_matches_direct_distance() {
        for_cases(25, |rng| {
            let d = 1 + rng.below(8) as usize;
            let k = 1 + rng.below(6) as usize;
            let x: Vec<f64> = (0..d).map(|_| rng.uniform(-2.0, 2.0)).collect();
            let cents: Vec<f64> = (0..k * d).map(|_| rng.uniform(-2.0, 2.0)).collect();
            let mut ct_t = Vec::new();
            transpose(&cents, d, k, &mut ct_t);
            let mut dots = vec![0.0; k];
            tile_dots(&x, d, k, &ct_t, &mut dots);
            let xn: f64 = x.iter().map(|v| v * v).sum();
            let cnorm: Vec<f64> =
                cents.chunks_exact(d).map(|c| c.iter().map(|v| v * v).sum()).collect();
            let (d1, c1, _) = best_two_expanded(xn, &dots, &cnorm);
            // Compare against the naive diff-squared argmin.
            let (mut want, mut want_c) = (f64::INFINITY, 0u32);
            for (c, cc) in cents.chunks_exact(d).enumerate() {
                let dd: f64 = x.iter().zip(cc).map(|(a, b)| (a - b) * (a - b)).sum();
                if dd < want {
                    want = dd;
                    want_c = c as u32;
                }
            }
            assert_eq!(c1, want_c);
            assert_close(d1.max(0.0), want, 1e-9);
        });
    }
}
