//! The replicated model tier: N hot-swappable [`RkModel`] replicas.
//!
//! Each replica slot is an `RwLock<Arc<RkModel>>`. A reader clones the
//! `Arc` under the read lock — a pointer copy, never a model copy — so
//! it can serve off that version for as long as it likes while the
//! [`Publisher`](crate::serve::Publisher) swaps the slot underneath it;
//! the old version stays alive through its refcount until every
//! in-flight batch drains. Because the swap replaces a single pointer,
//! a reader observes either the old model or the new one, **never a
//! torn mix** — `tests/serve_mesh.rs` hammers this with readers racing
//! a swap loop.
//!
//! Multiple slots exist to spread read-lock traffic: the
//! [`AssignFront`](crate::serve::AssignFront) round-robins batches over
//! them, and the multi-process deployment is real now: each `rkmeans
//! replica` process runs its own mesh fed by the writer's delta stream
//! over [`crate::serve::rpc`] ([`install`](ModelMesh::install) is
//! exactly what the replication plane calls after byte-verifying a
//! snapshot or applying a delta). Installs walk every slot, so slots
//! may briefly disagree during a publish; the front's version floor
//! keeps served versions monotone regardless.

use crate::metrics::{Counter, Metrics};
use crate::rkmeans::RkModel;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// A fixed-size tier of hot-swappable model replicas (see module docs).
pub struct ModelMesh {
    replicas: Vec<RwLock<Arc<RkModel>>>,
    /// Version of the most recent install, for observers that don't
    /// hold a model (`serve.version` gauge mirrors it).
    latest: AtomicU64,
    /// `serve.swaps` — one increment per replica slot swapped.
    swaps: Arc<Counter>,
    metrics: Metrics,
}

impl ModelMesh {
    /// A mesh of `replicas` slots (clamped to ≥ 1), all serving
    /// `initial`. Swap and version telemetry lands in `metrics` under
    /// `serve.*`.
    pub fn new(initial: RkModel, replicas: usize, metrics: Metrics) -> Arc<ModelMesh> {
        let initial = Arc::new(initial);
        let n = replicas.max(1);
        metrics.gauge("serve.replicas").set(n as i64);
        metrics.gauge("serve.version").set(initial.version as i64);
        Arc::new(ModelMesh {
            replicas: (0..n).map(|_| RwLock::new(Arc::clone(&initial))).collect(),
            latest: AtomicU64::new(initial.version),
            swaps: metrics.counter("serve.swaps"),
            metrics,
        })
    }

    /// Number of replica slots.
    pub fn replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Pin replica `i % n`'s current model: an `Arc` clone under the
    /// read lock. The caller serves off a consistent version for the
    /// lifetime of the handle, regardless of concurrent installs.
    ///
    /// Poisoned slots still serve: the guarded state is a single `Arc`
    /// pointer, which a panicking holder can never leave half-written,
    /// so the poison flag carries no integrity information here and the
    /// mesh degrades to serving whichever model the slot last held
    /// rather than cascading the panic into every request thread.
    pub fn model(&self, i: usize) -> Arc<RkModel> {
        let slot = &self.replicas[i % self.replicas.len()];
        let guard = slot.read().unwrap_or_else(std::sync::PoisonError::into_inner);
        Arc::clone(&guard)
    }

    /// Version of the most recent install.
    pub fn latest_version(&self) -> u64 {
        self.latest.load(Ordering::Acquire)
    }

    /// Hot-swap every replica slot to `model`. Each slot flips
    /// atomically (pointer swap under its write lock); in-flight readers
    /// keep their pinned `Arc` and drain on the old version.
    pub fn install(&self, model: Arc<RkModel>) {
        for slot in &self.replicas {
            // Same poison policy as `model()`: the slot is a lone Arc
            // pointer, so installing over a poisoned lock is safe and
            // preferable to wedging the publish path forever.
            *slot.write().unwrap_or_else(std::sync::PoisonError::into_inner) = Arc::clone(&model);
            self.swaps.inc();
        }
        self.latest.store(model.version, Ordering::Release);
        self.metrics.gauge("serve.version").set(model.version as i64);
    }

    /// The registry serve telemetry lands in.
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }
}

impl std::fmt::Debug for ModelMesh {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ModelMesh")
            .field("replicas", &self.replicas())
            .field("latest_version", &self.latest_version())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::sparse_lloyd::CentroidCoord;
    use crate::data::Value;

    /// A 1-subspace model whose centroid encodes its version, so a torn
    /// read would be detectable as a version/centroid mismatch.
    fn marked_model(version: u64) -> RkModel {
        use crate::cluster::kmeans1d;
        use crate::coreset::{SubspaceModel, SubspaceSolver};
        let solver = kmeans1d(&[(0.0, 1.0), (1.0, 1.0)], 2);
        let models = vec![SubspaceModel {
            name: "x".to_string(),
            lambda: 1.0,
            cost: solver.cost,
            solver: SubspaceSolver::Continuous(solver),
        }];
        let centroids = vec![
            vec![CentroidCoord::Continuous(version as f64)],
            vec![CentroidCoord::Continuous(-(version as f64))],
        ];
        let base = RkModel::from_result(&crate::rkmeans::RkResult {
            centroids,
            models,
            objective_grid: version as f64 * 3.0,
            quantization_cost: 0.0,
            grid_points: 2,
            grid_mass: 2.0,
            iters: 1,
            timings: Default::default(),
            step4_stats: Default::default(),
        });
        base.with_version(version)
    }

    #[test]
    fn install_swaps_every_replica() {
        let metrics = Metrics::new();
        let mesh = ModelMesh::new(marked_model(1), 3, metrics.clone());
        assert_eq!(mesh.replicas(), 3);
        assert_eq!(mesh.latest_version(), 1);
        mesh.install(Arc::new(marked_model(2)));
        for i in 0..mesh.replicas() {
            assert_eq!(mesh.model(i).version, 2);
        }
        assert_eq!(mesh.latest_version(), 2);
        assert_eq!(metrics.counter("serve.swaps").get(), 3);
        assert_eq!(metrics.gauge("serve.version").get(), 2);
    }

    #[test]
    fn pinned_model_survives_a_swap() {
        let mesh = ModelMesh::new(marked_model(5), 1, Metrics::new());
        let pinned = mesh.model(0);
        mesh.install(Arc::new(marked_model(6)));
        // The pinned handle still serves version 5, consistently.
        assert_eq!(pinned.version, 5);
        assert_eq!(pinned.assign(&[Value::Double(4.9)]), 0);
        let CentroidCoord::Continuous(mu) = pinned.centroids[0][0] else { panic!() };
        assert_eq!(mu, 5.0);
        assert_eq!(mesh.model(0).version, 6);
    }

    #[test]
    fn poisoned_slot_keeps_serving_and_accepts_installs() {
        let mesh = ModelMesh::new(marked_model(1), 1, Metrics::new());
        let mesh2 = Arc::clone(&mesh);
        // Poison the sole replica slot: panic while holding its write
        // lock on another thread.
        // rklint::allow(rogue-thread, reason = "test poisons a lock; needs a real panicking thread, not the exec pool")
        let t = std::thread::spawn(move || {
            let _guard = mesh2.replicas[0].write().expect("fresh lock");
            panic!("poison the replica slot");
        });
        assert!(t.join().is_err(), "the thread must have panicked");
        // Reads degrade to the last-held model instead of propagating
        // the panic into the serving path…
        assert_eq!(mesh.model(0).version, 1);
        // …and publishes still land.
        mesh.install(Arc::new(marked_model(2)));
        assert_eq!(mesh.model(0).version, 2);
        assert_eq!(mesh.latest_version(), 2);
    }

    #[test]
    fn zero_replicas_clamps_to_one() {
        let mesh = ModelMesh::new(marked_model(1), 0, Metrics::new());
        assert_eq!(mesh.replicas(), 1);
        assert_eq!(mesh.model(7).version, 1, "indices wrap modulo n");
    }
}
